//! L3 hot-path benches: pulse trains, aggregated updates and analog MVMs
//! on the device substrate (the inner loops of every pulse-level
//! experiment). The aggregated-update cases scale from 128x128 (serial
//! batched path) to 1024x1024 (row-chunked parallel path), and the
//! rider/erider step cases measure the end-to-end optimizer hot path at
//! NN-tile width — the numbers `./ci.sh bench` records in
//! BENCH_device.json to track speedups across PRs. Cases are collected
//! by a `BenchSuite`, which also records them into the live metrics
//! facade and writes `$BENCH_JSON_OUT` itself (no awk post-processing).

use analog_rider::analog::optimizer::{self, AnalogOptimizer as _};
use analog_rider::device::{presets, DeviceArray, IoChain, TileGeometry, TiledArray};
use analog_rider::optim::Quadratic;
use analog_rider::util::bench::{consume, Bench, BenchSuite};
use analog_rider::util::metrics;
use analog_rider::util::rng::Rng;

fn main() {
    metrics::install();
    let b = Bench::default();
    let mut suite = BenchSuite::new();
    let mut rng = Rng::from_seed(1);

    let mut arr = DeviceArray::sample(128, 128, &presets::PRECISE, 0.4, 0.2, 0.1, &mut rng);
    let r = b.run("pulse_all_random/128x128", || {
        arr.pulse_all_random(&mut rng);
    });
    suite.push_throughput(&r, "pulses", (128 * 128) as f64);

    // aggregated updates: 128x128 runs the serial batched engine,
    // 256x256 and 1024x1024 fan out to the row-chunked parallel path
    for side in [128usize, 256, 1024] {
        let mut arr = DeviceArray::sample(side, side, &presets::PRECISE, 0.4, 0.2, 0.1, &mut rng);
        let dw = vec![0.01f32; side * side];
        let r = b.run(&format!("analog_update/{side}x{side}"), || {
            arr.analog_update(&dw, &mut rng);
        });
        suite.push_throughput(&r, "cells", (side * side) as f64);
    }

    // chaos layer: the same 256x256 aggregated update with a fault mask
    // armed — empty (the zero-cost-when-disarmed contract: must match
    // analog_update/256x256) and with 1% stuck + 5% drifting cells (the
    // post-update mask's real overhead)
    {
        use analog_rider::device::fault::{FaultFamily, FaultPlan};
        let side = 256usize;
        let dw = vec![0.01f32; side * side];
        let mut arr = DeviceArray::sample(side, side, &presets::PRECISE, 0.4, 0.2, 0.1, &mut rng);
        FaultPlan::none(7).arm_array(&mut arr, 0);
        let r = b.run(&format!("analog_update_fault_empty/{side}x{side}"), || {
            arr.analog_update(&dw, &mut rng);
        });
        suite.push_throughput(&r, "cells", (side * side) as f64);
        let mut arr = DeviceArray::sample(side, side, &presets::PRECISE, 0.4, 0.2, 0.1, &mut rng);
        let plan = FaultPlan {
            drift_rate: 0.05,
            drift_step: 0.05,
            ..FaultPlan::of(7, FaultFamily::StuckAtBound, 0.01)
        };
        plan.arm_array(&mut arr, 0);
        let r = b.run(&format!("analog_update_fault/{side}x{side}"), || {
            arr.analog_update(&dw, &mut rng);
        });
        suite.push_throughput(&r, "cells", (side * side) as f64);
    }

    // tiled substrate: the same 1024x1024 aggregated update as a 4x4
    // grid of 256^2 tiles, serial vs per-tile scoped-thread fan-out
    let geom = TileGeometry::new(256, 256).expect("valid geometry");
    let mut tiled =
        TiledArray::sample(1024, 1024, geom, &presets::PRECISE, 0.4, 0.2, 0.1, &mut rng);
    let dw = vec![0.01f32; 1024 * 1024];
    tiled.set_parallel(false);
    let r = b.run("tiled_update_serial/1024x1024t256", || {
        tiled.analog_update(&dw, &mut rng);
    });
    suite.push_throughput(&r, "cells", (1024 * 1024) as f64);
    tiled.set_parallel(true);
    let r = b.run("tiled_update_parallel/1024x1024t256", || {
        tiled.analog_update(&dw, &mut rng);
    });
    suite.push_throughput(&r, "cells", (1024 * 1024) as f64);

    // noisy tile read-out through the zero-alloc path
    let arr = DeviceArray::sample(1024, 1024, &presets::PRECISE, 0.4, 0.2, 0.1, &mut rng);
    let mut out = vec![0.0f32; arr.len()];
    let r = b.run("read_into/1024x1024", || {
        arr.read_into(0.01, &mut rng, &mut out);
        consume(out[0]);
    });
    suite.push_throughput(&r, "cells", (1024 * 1024) as f64);

    // end-to-end pulse-level optimizer step at NN-tile width: two device
    // updates + one read + one noisy gradient per step, all batched
    for name in ["rider", "erider"] {
        let spec = optimizer::spec(name).expect("registry name");
        let obj = Quadratic::new(4096, 1.0, 4.0, 0.3, &mut rng);
        let mut opt = spec.build(4096, &presets::PRECISE, 0.3, 0.1, 0.1, &mut rng);
        let r = b.run(&format!("{name}_step/d4096"), || {
            opt.step(&obj, &mut rng);
        });
        suite.push_throughput(&r, "steps", 1.0);
    }

    let io = IoChain::default();
    let x: Vec<f32> = (0..16 * 256).map(|i| ((i % 17) as f32 - 8.0) / 8.0).collect();
    let w: Vec<f32> = (0..256 * 128).map(|i| ((i % 13) as f32 - 6.0) / 13.0).collect();
    let r = b.run("io_mvm/16x256x128", || {
        consume(io.mvm(&x, &w, 16, 256, 128, &mut rng, false));
    });
    suite.push_throughput(&r, "flops", (2 * 16 * 256 * 128) as f64);

    suite.finish().expect("write BENCH_JSON_OUT");
}
