//! L3 hot-path benches: pulse trains and analog MVMs on the device
//! substrate (the inner loops of every pulse-level experiment).

use analog_rider::device::{presets, DeviceArray, IoChain};
use analog_rider::util::bench::{consume, Bench};
use analog_rider::util::rng::Rng;

fn main() {
    let b = Bench::default();
    let mut rng = Rng::from_seed(1);

    let mut arr = DeviceArray::sample(128, 128, &presets::PRECISE, 0.4, 0.2, 0.1, &mut rng);
    let r = b.run("pulse_all_random/128x128", || {
        arr.pulse_all_random(&mut rng);
    });
    println!("{}", r.report_throughput("pulses", (128 * 128) as f64));

    let dw = vec![0.01f32; 128 * 128];
    let r = b.run("analog_update/128x128", || {
        arr.analog_update(&dw, &mut rng);
    });
    println!("{}", r.report_throughput("cells", (128 * 128) as f64));

    let io = IoChain::default();
    let x: Vec<f32> = (0..16 * 256).map(|i| ((i % 17) as f32 - 8.0) / 8.0).collect();
    let w: Vec<f32> = (0..256 * 128).map(|i| ((i % 13) as f32 - 6.0) / 13.0).collect();
    let r = b.run("io_mvm/16x256x128", || {
        consume(io.mvm(&x, &w, 16, 256, 128, &mut rng, false));
    });
    println!("{}", r.report_throughput("flops", (2 * 16 * 256 * 128) as f64));
}
