//! Fig. 1 regeneration cost: ZS calibration throughput per table row.

use analog_rider::analog::zs::{self, ZsVariant};
use analog_rider::device::{presets, DeviceArray};
use analog_rider::util::bench::Bench;
use analog_rider::util::rng::Rng;

fn main() {
    let b = Bench {
        measure: std::time::Duration::from_millis(800),
        ..Bench::default()
    };
    for side in [64usize, 128, 256] {
        let mut rng = Rng::from_seed(2);
        let mut arr =
            DeviceArray::sample(side, side, &presets::PRECISE, 0.4, 0.2, 0.1, &mut rng);
        let r = b.run(&format!("zs_100_pulses/{side}x{side}"), || {
            zs::run(&mut arr, 100, ZsVariant::Cyclic, &mut rng);
        });
        println!("{}", r.report_throughput("pulses", (side * side * 100) as f64));
    }
}
