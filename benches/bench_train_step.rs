//! End-to-end HLO step cost per (model, algorithm): the request-path
//! latency of the coordinator (Tables 1/2, Figs 2/4/5 regeneration
//! cost). The `step/*` cases run the planned execution engine (the
//! production path); the `stepref/*` cases run the same artifacts on
//! the scalar reference walker, so one bench run quantifies the
//! planned-engine speedup. `./ci.sh bench` appends these cases into
//! BENCH_optimizers.json via `$BENCH_JSON_OUT` + `$BENCH_JSON_APPEND`,
//! gated against BENCH_baseline/ with `--check`. Skips silently when
//! artifacts are absent (leaving any existing trajectory file intact).

use analog_rider::data::Dataset;
use analog_rider::runtime::{Executor, HostTensor, Registry};
use analog_rider::train::{TrainConfig, Trainer};
use analog_rider::util::bench::{Bench, BenchSuite};
use analog_rider::util::metrics;

fn batch_xy(ds: &Dataset, d_in: usize, batch: usize) -> (Vec<f32>, Vec<i32>) {
    let d = d_in.min(ds.d);
    let mut x = vec![0.0f32; batch * d_in];
    for (i, v) in ds.x[..batch * d].iter().enumerate() {
        x[i] = *v;
    }
    (x, ds.y[..batch].to_vec())
}

fn main() {
    metrics::install();
    let dir = Registry::default_dir();
    if !dir.join("manifest.json").exists() {
        println!("BENCH\tskipped (run `make artifacts` first)");
        return;
    }
    let reg = Registry::load(dir).unwrap();
    let Ok(exec) = Executor::cpu() else {
        println!("BENCH\tskipped (PJRT/XLA backend unavailable in this build)");
        return;
    };
    let mut suite = BenchSuite::new();
    let ds = Dataset::digits(64, 5);
    let b = Bench {
        warmup: std::time::Duration::from_millis(2000),
        measure: std::time::Duration::from_secs(6),
        ..Bench::default()
    };
    for (model, algo) in [
        ("fcn", "sgd"),
        ("fcn", "ttv2"),
        ("fcn", "agad"),
        ("fcn", "erider"),
        ("lenet", "sgd"),
        ("lenet", "erider"),
        ("convnet3", "sgd"),
        ("convnet3", "erider"),
    ] {
        let mut cfg = TrainConfig::by_name(model, algo).unwrap();
        cfg.steps = 1;
        let mut t = Trainer::new(&exec, &reg, cfg).unwrap();
        let spec = reg.model(model).unwrap();
        let (x, y) = batch_xy(&ds, spec.d_in, spec.batch);
        let r = b.run(&format!("step/{model}/{algo}"), || {
            t.step(&x, &y).unwrap();
        });
        suite.push_throughput(&r, "steps", 1.0);
    }

    // scalar-walker baselines for the speedup record: same artifacts,
    // same inputs, reference path (Executor::run_ref)
    let bref = Bench {
        warmup: std::time::Duration::from_millis(500),
        measure: std::time::Duration::from_secs(4),
        ..Bench::default()
    };
    for (model, algo) in [("fcn", "sgd"), ("lenet", "erider")] {
        let cfg = TrainConfig::by_name(model, algo).unwrap();
        let t = Trainer::new(&exec, &reg, cfg.clone()).unwrap();
        let spec = reg.model(model).unwrap();
        let (x, y) = batch_xy(&ds, spec.d_in, spec.batch);
        let art = reg
            .artifact(&format!("{model}_step_{}", cfg.spec.method.nn_step_algo()))
            .unwrap();
        let mut inputs = t.state.to_inputs();
        inputs.push(HostTensor::F32(x));
        inputs.push(HostTensor::I32(y));
        inputs.push(HostTensor::U32(vec![7, 9]));
        inputs.push(HostTensor::F32(cfg.hypers.to_vec(&reg)));
        inputs.push(HostTensor::F32(cfg.dev.to_vec(&reg)));
        let r = bref.run(&format!("stepref/{model}/{algo}"), || {
            exec.run_ref(art, &inputs).unwrap();
        });
        suite.push_throughput(&r, "steps", 1.0);
    }

    suite.finish().expect("write BENCH_JSON_OUT");
}
