//! End-to-end HLO step cost per (model, algorithm): the request-path
//! latency of the coordinator (Tables 1/2, Figs 2/4/5 regeneration cost).
//! Skips silently when artifacts are absent.

use analog_rider::data::Dataset;
use analog_rider::runtime::{Executor, Registry};
use analog_rider::train::{TrainConfig, Trainer};
use analog_rider::util::bench::Bench;

fn main() {
    let dir = Registry::default_dir();
    if !dir.join("manifest.json").exists() {
        println!("BENCH\tskipped (run `make artifacts` first)");
        return;
    }
    let reg = Registry::load(dir).unwrap();
    let Ok(exec) = Executor::cpu() else {
        println!("BENCH\tskipped (PJRT/XLA backend unavailable in this build)");
        return;
    };
    let ds = Dataset::digits(64, 5);
    let b = Bench {
        warmup: std::time::Duration::from_millis(2000),
        measure: std::time::Duration::from_secs(6),
        ..Bench::default()
    };
    for (model, algo) in [
        ("fcn", "sgd"),
        ("fcn", "ttv2"),
        ("fcn", "agad"),
        ("fcn", "erider"),
        ("lenet", "erider"),
        ("convnet3", "erider"),
    ] {
        let mut cfg = TrainConfig::by_name(model, algo).unwrap();
        cfg.steps = 1;
        let mut t = Trainer::new(&exec, &reg, cfg).unwrap();
        let spec = reg.model(model).unwrap();
        let d = spec.d_in.min(ds.d);
        let mut x = vec![0.0f32; spec.batch * spec.d_in];
        for (i, v) in ds.x[..spec.batch * d].iter().enumerate() {
            x[i] = *v;
        }
        let y: Vec<i32> = ds.y[..spec.batch].to_vec();
        let r = b.run(&format!("step/{model}/{algo}"), || {
            t.step(&x, &y).unwrap();
        });
        println!("{}", r.report_throughput("steps", 1.0));
    }
}
