//! Rust-native optimizer step costs (theory-experiment inner loops).

use analog_rider::analog::*;
use analog_rider::device::presets;
use analog_rider::optim::Quadratic;
use analog_rider::util::bench::Bench;
use analog_rider::util::rng::Rng;

fn main() {
    let b = Bench::default();
    let mut rng = Rng::from_seed(3);
    let obj = Quadratic::new(256, 1.0, 4.0, 0.3, &mut rng);
    let p = presets::PRECISE;

    let mut sgd = AnalogSgd::new(256, &p, 0.3, 0.1, 0.05, 0.1, &mut rng);
    println!("{}", b.run("analog_sgd_step/d256", || {
        sgd.step(&obj, &mut rng);
    }).report());

    let mut tt = TikiTaka::new(256, &p, 0.3, 0.1, TtVariant::V2, 0.1, 0.05, 0.1, &mut rng);
    println!("{}", b.run("ttv2_step/d256", || {
        tt.step(&obj, &mut rng);
    }).report());

    let mut rider = Rider::new(256, &p, 0.3, 0.1, RiderHypers::default(), 0.1, &mut rng);
    println!("{}", b.run("erider_step/d256", || {
        rider.step(&obj, &mut rng);
    }).report());

    let mut agad = Agad::new(256, &p, 0.3, 0.1, 0.1, 0.05, 0.05, 0.1, &mut rng);
    println!("{}", b.run("agad_step/d256", || {
        agad.step(&obj, &mut rng);
    }).report());
}
