//! Rust-native optimizer step costs (theory-experiment inner loops).
//!
//! Iterates the whole method registry: any method added to
//! `analog::optimizer::METHODS` is benched here with no further edits.
//! Cases are collected by a `BenchSuite`, which writes `$BENCH_JSON_OUT`
//! itself (no awk post-processing in `./ci.sh bench`).

use analog_rider::analog::optimizer::{self, AnalogOptimizer as _};
use analog_rider::device::presets;
use analog_rider::optim::Quadratic;
use analog_rider::util::bench::{Bench, BenchSuite};
use analog_rider::util::metrics;
use analog_rider::util::rng::Rng;

fn main() {
    metrics::install();
    let b = Bench::default();
    let mut suite = BenchSuite::new();
    let mut rng = Rng::from_seed(3);
    let obj = Quadratic::new(256, 1.0, 4.0, 0.3, &mut rng);
    let p = presets::PRECISE;

    for name in optimizer::METHODS {
        let spec = optimizer::spec(name).expect("registry name");
        // `residual` pays its ZS calibration here (setup, not timed)
        let mut opt = spec.build(256, &p, 0.3, 0.1, 0.1, &mut rng);
        let r = b.run(&format!("{name}_step/d256"), || {
            opt.step(&obj, &mut rng);
        });
        suite.push(&r);
    }

    suite.finish().expect("write BENCH_JSON_OUT");
}
