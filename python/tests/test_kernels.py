"""Kernel-vs-oracle tests — the core L1 correctness signal.

Deterministic mode must match the pure-jnp oracle exactly (same graph up
to fusion); stochastic mode must match in expectation / distribution.
Hypothesis sweeps shapes, dtypes-compatible ranges and seeds.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import analog_mvm, pulse_update, ref

jax.config.update("jax_platform_name", "cpu")


def _rand(key, shape, lo=-1.0, hi=1.0):
    return jax.random.uniform(key, shape, jnp.float32, lo, hi)


# ---------------------------------------------------------------- pulse_update


@settings(max_examples=25, deadline=None)
@given(
    rows=st.integers(1, 40),
    cols=st.integers(1, 200),
    seed=st.integers(0, 2**31 - 1),
    dw_min=st.sampled_from([1e-4, 1e-3, 1e-2, 0.0949, 0.4622]),
)
def test_pulse_update_matches_ref_deterministic(rows, cols, seed, dw_min):
    k = jax.random.split(jax.random.PRNGKey(seed), 6)
    shape = (rows, cols)
    w = _rand(k[0], shape, -0.9, 0.9)
    dw = _rand(k[1], shape, -0.3, 0.3)
    gamma = jnp.exp(0.3 * jax.random.normal(k[2], shape))
    rho = 0.3 * jax.random.normal(k[3], shape)
    ap, am = gamma + jnp.abs(rho), jnp.maximum(gamma - jnp.abs(rho), 0.05)
    u = _rand(k[4], shape, 0.0, 1.0)
    z = jax.random.normal(k[5], shape)

    got = pulse_update(w, dw, ap, am, u, z, dw_min, 0.3, 1.0, 1.0, deterministic=True)
    want = ref.ref_pulse_update(
        w, dw, ap, am, u, z, dw_min=dw_min, sigma_c2c=0.3, deterministic=True
    )
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(
    rows=st.integers(1, 16),
    cols=st.integers(1, 130),
    seed=st.integers(0, 2**31 - 1),
)
def test_pulse_update_matches_ref_stochastic(rows, cols, seed):
    """With identical variates, kernel and oracle agree exactly."""
    k = jax.random.split(jax.random.PRNGKey(seed), 6)
    shape = (rows, cols)
    w = _rand(k[0], shape, -0.9, 0.9)
    dw = _rand(k[1], shape, -0.2, 0.2)
    ap = _rand(k[2], shape, 0.5, 1.5)
    am = _rand(k[3], shape, 0.5, 1.5)
    u = _rand(k[4], shape, 0.0, 1.0)
    z = jax.random.normal(k[5], shape)

    got = pulse_update(w, dw, ap, am, u, z, 1e-3, 0.2, 1.0, 1.0)
    want = ref.ref_pulse_update(
        w, dw, ap, am, u, z, dw_min=1e-3, sigma_c2c=0.2, deterministic=False
    )
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_pulse_update_1d_shape():
    shape = (37,)
    k = jax.random.split(jax.random.PRNGKey(0), 6)
    w = _rand(k[0], shape, -0.5, 0.5)
    dw = _rand(k[1], shape, -0.1, 0.1)
    one = jnp.ones(shape)
    u = _rand(k[4], shape, 0.0, 1.0)
    z = jax.random.normal(k[5], shape)
    out = pulse_update(w, dw, one, one, u, z, 1e-3, 0.0, 1.0, 1.0, deterministic=True)
    assert out.shape == shape


def test_pulse_update_stays_in_bounds():
    shape = (8, 128)
    k = jax.random.split(jax.random.PRNGKey(1), 6)
    w = _rand(k[0], shape, -1.0, 1.0)
    dw = _rand(k[1], shape, -5.0, 5.0)  # huge updates
    one = jnp.ones(shape)
    u = _rand(k[4], shape, 0.0, 1.0)
    z = jax.random.normal(k[5], shape)
    out = pulse_update(w, dw, one, one, u, z, 1e-2, 0.5, 1.0, 1.0)
    assert jnp.all(out <= 1.0) and jnp.all(out >= -1.0)


def test_pulse_update_symmetric_point_is_fixed():
    """At the SP with symmetric devices, up/down pulses cancel in expectation."""
    shape = (4, 64)
    w = jnp.zeros(shape)  # SP of a symmetric device is 0
    one = jnp.ones(shape)
    up = pulse_update(
        w, jnp.full(shape, 1e-3), one, one, 0.5 * one, 0.0 * one, 1e-3, 0.0, 1.0, 1.0,
        deterministic=True,
    )
    down = pulse_update(
        up, jnp.full(shape, -1e-3), one, one, 0.5 * one, 0.0 * one, 1e-3, 0.0, 1.0, 1.0,
        deterministic=True,
    )
    # residual is second order in dw_min (state-dependent response):
    np.testing.assert_allclose(down, w, atol=3e-6)


def test_pulse_update_asymmetry_drifts_to_sp():
    """Alternating pulses on an asymmetric device drift towards its SP
    (the SP-attraction property the whole paper builds on)."""
    shape = (1, 64)
    ap = jnp.full(shape, 1.2)  # rho = 0.2, gamma = 1.0 -> SP = 0.2
    am = jnp.full(shape, 0.8)
    sp = ref.symmetric_point(ap, am, 1.0, 1.0)
    w = jnp.zeros(shape)
    half = jnp.full(shape, 0.5)
    zero = jnp.zeros(shape)
    for i in range(400):
        s = 1.0 if i % 2 == 0 else -1.0
        w = pulse_update(
            w, jnp.full(shape, s * 1e-2), ap, am, half, zero, 1e-2, 0.0, 1.0, 1.0,
            deterministic=True,
        )
    assert jnp.max(jnp.abs(w - sp)) < 0.05


# ----------------------------------------------------------------- analog_mvm


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 33),
    kdim=st.integers(1, 96),
    n=st.integers(1, 200),
    seed=st.integers(0, 2**31 - 1),
)
def test_analog_mvm_matches_ref(b, kdim, n, seed):
    k = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = _rand(k[0], (b, kdim), -2.0, 2.0)
    w = _rand(k[1], (kdim, n), -1.0, 1.0)
    z = jax.random.normal(k[2], (b, n))
    got = analog_mvm(x, w, z)
    want = ref.ref_analog_mvm(x, w, z)
    # Tiled accumulation can land exactly on an ADC rounding boundary and
    # flip one LSB vs the oracle's summation order; allow one output
    # quantum (out_res * per-row scale <= 2) on a tiny fraction of cells.
    diff = np.abs(np.asarray(got) - np.asarray(want))
    lsb = 2.0 / 511.0 * 1.1
    assert float(diff.max()) <= lsb, f"max diff {diff.max()}"
    frac_exact = float((diff < 1e-5).mean())
    assert frac_exact > 0.99, f"only {frac_exact:.4f} exact"


def test_analog_mvm_deterministic_flag_drops_noise():
    k = jax.random.split(jax.random.PRNGKey(7), 3)
    x = _rand(k[0], (4, 16), -1.0, 1.0)
    w = _rand(k[1], (16, 8), -1.0, 1.0)
    z1 = jax.random.normal(k[2], (4, 8))
    z2 = -z1
    a = analog_mvm(x, w, z1, deterministic=True)
    b = analog_mvm(x, w, z2, deterministic=True)
    np.testing.assert_allclose(a, b, atol=0)


def test_analog_mvm_close_to_ideal_matmul():
    """The analog chain is a perturbation, not a different operator."""
    k = jax.random.split(jax.random.PRNGKey(9), 3)
    x = _rand(k[0], (16, 64), -1.0, 1.0)
    w = _rand(k[1], (64, 32), -0.5, 0.5)
    z = jax.random.normal(k[2], (16, 32))
    y = analog_mvm(x, w, z)
    ideal = x @ w
    err = jnp.abs(y - ideal)
    # per-element error dominated by quantization + 0.06 read noise, scaled
    # by the per-row ABS_MAX (<= 1 here).
    assert float(jnp.mean(err)) < 0.12
    assert float(jnp.max(err)) < 0.6


def test_analog_mvm_zero_input_row():
    """ABS_MAX noise management must not divide by zero."""
    x = jnp.zeros((2, 8))
    w = jnp.ones((8, 4))
    z = jnp.zeros((2, 4))
    y = analog_mvm(x, w, z, deterministic=True)
    np.testing.assert_allclose(y, jnp.zeros((2, 4)), atol=1e-6)
