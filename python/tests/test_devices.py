"""Device-model tests: SP control, F/G identities, response properties."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import devices
from compile.kernels import ref


@settings(max_examples=30, deadline=None)
@given(
    mean=st.floats(-0.5, 0.5),
    std=st.floats(0.0, 0.5),
    seed=st.integers(0, 2**31 - 1),
)
def test_sample_device_controls_sp(mean, std, seed):
    """The sampled array's SP distribution matches (ref_mean, ref_std)."""
    key = jax.random.PRNGKey(seed)
    ap, am = devices.sample_device(key, (64, 64), mean, std, sigma_gamma=0.1)
    sp = devices.symmetric_point(ap, am)
    # SPs are clipped to +-0.85, so compare against the clipped target.
    k1, k2 = jax.random.split(key)
    want = jnp.clip(mean + std * jax.random.normal(k2, (64, 64)), -0.85, 0.85)
    assert abs(float(sp.mean()) - float(want.mean())) < 0.06
    assert abs(float(sp.std()) - float(want.std())) < 0.06


def test_sample_device_positive_definite():
    """Training-friendly response (Definition 2.1): slopes stay positive."""
    key = jax.random.PRNGKey(0)
    ap, am = devices.sample_device(key, (128, 128), 0.4, 1.0, sigma_gamma=0.3)
    assert float(ap.min()) >= 0.05
    assert float(am.min()) >= 0.05


def test_fg_decomposition_identity():
    """F +- G recovers q_-/q_+ (Eq. 6)."""
    w = jnp.linspace(-0.9, 0.9, 13)
    ap = jnp.full_like(w, 1.3)
    am = jnp.full_like(w, 0.7)
    f = ref.f_sym(w, ap, am, 1.0, 1.0)
    g = ref.g_asym(w, ap, am, 1.0, 1.0)
    np.testing.assert_allclose(f - g, ref.q_plus(w, ap, 1.0), atol=1e-6)
    np.testing.assert_allclose(f + g, ref.q_minus(w, am, 1.0), atol=1e-6)


def test_g_vanishes_exactly_at_sp():
    """Definition 1.1: G(w_sp) = 0."""
    ap, am = jnp.array([1.4]), jnp.array([0.6])
    sp = ref.symmetric_point(ap, am, 1.0, 1.0)
    g = ref.g_asym(sp, ap, am, 1.0, 1.0)
    np.testing.assert_allclose(g, 0.0, atol=1e-7)


def test_symmetric_device_sp_is_zero():
    ap = am = jnp.array([0.9])
    assert float(ref.symmetric_point(ap, am, 1.0, 1.0)[0]) == 0.0


def test_presets_cover_paper_table3():
    assert devices.PRESETS["hfo2"]["dw_min"] == 0.4622
    assert devices.PRESETS["om"]["dw_min"] == 0.0949
    for p in devices.PRESETS.values():
        assert p["dw_min"] > 0
