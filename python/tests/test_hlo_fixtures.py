"""Pytest wrapper around the hermetic-fixture validation (numpy-only —
unlike the other python tests this needs no JAX). Skips when the
checked-in artifacts/ directory is absent."""

import os

import pytest

np = pytest.importorskip("numpy")

from python.compile import hlo_eval, validate_fixtures  # noqa: E402

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _runner():
    import json

    man_path = os.path.join(ART, "manifest.json")
    if not os.path.exists(man_path):
        pytest.skip("artifacts/ not generated")
    return validate_fixtures.Runner(ART, json.load(open(man_path)))


def test_all_artifacts_parse():
    rn = _runner()
    for name in rn.man["artifacts"]:
        assert isinstance(rn.evaluator(name), hlo_eval.Evaluator)


def test_kernel_parity():
    rn = _runner()
    validate_fixtures.check_kernels(rn, ART)


def test_fcn_trains_end_to_end():
    rn = _runner()
    validate_fixtures.check_model(rn, "fcn", steps=15, check_loss_drop=True)


def test_conv_models_roundtrip():
    rn = _runner()
    validate_fixtures.check_model(rn, "lenet")
    validate_fixtures.check_model(rn, "convnet3")
