"""Behavioural tests of the training algorithms (the paper's core claims,
at test scale): SP tracking, robustness to nonzero reference, ZS
calibration, chopper statistics."""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from compile import algorithms as A
from compile import devices
from compile import model as M

TINY = M.ModelSpec("tiny", (16,), (M.Fc(16, 12, "tanh"), M.Fc(12, 4, "none")), 4)
DEV = jnp.array([1e-3, 0.01, 1.0, 1.0, 0.02, 1 / 127, 1 / 511, 12.0])


def _hypers(**kw):
    h = np.zeros(A.N_HYPERS, np.float32)
    h[A.LR_FAST] = kw.get("lr_fast", 0.05)
    h[A.LR_TRANSFER] = kw.get("lr_transfer", 0.05)
    h[A.ETA] = kw.get("eta", 0.1)
    h[A.GAMMA] = kw.get("gamma", 0.1)
    h[A.FLIP_P] = kw.get("flip_p", 0.1)
    h[A.THRESH] = kw.get("thresh", 0.01)
    h[A.LR_DIGITAL] = kw.get("lr_digital", 0.05)
    h[A.READ_NOISE] = kw.get("read_noise", 0.005)
    return jnp.array(h)


def _data(key, n=256):
    """Tiny 4-class separable dataset."""
    kx, kw = jax.random.split(key)
    centers = 1.5 * jax.random.normal(kw, (4, 16))
    labels = jnp.arange(n) % 4
    x = centers[labels] + 0.3 * jax.random.normal(kx, (n, 16))
    return x, labels


def _train(algo, steps=250, ref_mean=0.3, ref_std=0.3, seed=0, **hkw):
    spec = TINY
    key = jax.random.PRNGKey(seed)
    tiles, biases = M.init_state(spec, key, ref_mean, ref_std, 0.1)
    x, labels = _data(jax.random.fold_in(key, 1))
    step = jax.jit(functools.partial(A.STEPS[algo], spec))
    hyp = _hypers(**hkw)
    losses = []
    for k in range(steps):
        i = (k * 16) % 256
        xb, yb = x[i : i + 16], labels[i : i + 16]
        tiles, biases, loss = step(
            tiles, biases, xb, yb, jax.random.fold_in(key, 100 + k), hyp, DEV
        )
        losses.append(float(loss))
    return tiles, biases, losses


def test_digital_sgd_converges():
    _, _, losses = _train("digital", steps=150)
    assert np.mean(losses[-10:]) < 0.55 * np.mean(losses[:10])


def test_erider_reduces_loss_under_offset():
    _, _, losses = _train("erider", steps=250, ref_mean=0.4, ref_std=0.3)
    assert np.mean(losses[-10:]) < 0.75 * np.mean(losses[:10])


def test_erider_q_tracks_sp():
    """The core paper claim (Lemma 3.5 / Thm 3.7): the digital moving
    average Q converges towards the P-device's symmetric point."""
    tiles, _, _ = _train("erider", steps=300, ref_mean=0.4, ref_std=0.2, eta=0.05)
    errs, inits = [], []
    for t in tiles:
        sp = devices.symmetric_point(t["pap"], t["pam"])
        errs.append(float(jnp.mean(jnp.abs(t["q"] - sp))))
        inits.append(float(jnp.mean(jnp.abs(sp))))  # q starts at 0
    # SP attraction is gradient-scaled, so convergence is partial at test
    # scale; require a decisive reduction of the tracking error.
    assert np.mean(errs) < 0.72 * np.mean(inits), (errs, inits)


def test_rider_is_erider_with_p0():
    """flip_p = 0 keeps the chopper fixed (RIDER reduction)."""
    tiles, _, _ = _train("erider", steps=30, flip_p=0.0)
    for t in tiles:
        assert float(t["c"].min()) == 1.0


def test_chopper_flips_with_p1():
    tiles, _, _ = _train("erider", steps=11, flip_p=1.0)
    for t in tiles:
        # 11 deterministic flips from +1 on every input line
        assert float(t["c"].max()) == -1.0


def test_analog_sgd_drifts_toward_sp():
    """Eq. 4 mechanism: under persistent gradient noise, Analog SGD's W
    array is dragged towards the device SP (here mean 0.7), while with a
    zero-SP device it stays centred. (The accuracy-ordering claims of
    Tables 1-2 are validated at experiment scale by the Rust harness,
    where the effect has thousands of steps to accumulate.)"""
    import jax

    global _data
    orig = _data

    def noisy_data(key, n=256):
        kx, kw, kf, kl = jax.random.split(key, 4)
        centers = 1.5 * jax.random.normal(kw, (4, 16))
        labels = jnp.arange(n) % 4
        x = centers[labels] + 0.3 * jax.random.normal(kx, (n, 16))
        mask = jax.random.uniform(kf, (n,)) < 0.3  # label noise => E|g| > 0
        rnd = jax.random.randint(kl, (n,), 0, 4)
        return x, jnp.where(mask, rnd, labels)

    _data = noisy_data
    try:
        t_off, _, _ = _train("sgd", steps=400, ref_mean=0.7, ref_std=0.2,
                             seed=3, lr_fast=0.2)
        t_zero, _, _ = _train("sgd", steps=400, ref_mean=0.0, ref_std=0.2,
                              seed=3, lr_fast=0.2)
    finally:
        _data = orig
    drift_off = float(jnp.mean(t_off[0]["w"]))
    drift_zero = abs(float(jnp.mean(t_zero[0]["w"])))
    assert drift_off > 0.2, drift_off
    assert drift_zero < 0.1, drift_zero


def test_zs_calibration_estimates_sp():
    """Algorithm 1 drives P to its SP; with enough pulses the stored
    reference q lands within Theta(dw_min) of the true SP."""
    spec = TINY
    key = jax.random.PRNGKey(2)
    tiles, _ = M.init_state(spec, key, 0.3, 0.2, 0.1)
    dev = jnp.array([5e-3, 0.0, 1.0, 1.0, 0.0, 1 / 127, 1 / 511, 12.0])
    zs = jax.jit(lambda t, n, k: A.zs_calibrate(spec, t, n, k, dev))
    t2 = zs(tiles, jnp.uint32(3000), jax.random.fold_in(key, 9))
    for t in t2:
        sp = devices.symmetric_point(t["pap"], t["pam"])
        err = float(jnp.mean(jnp.abs(t["q"] - sp)))
        assert err < 0.06, err


def test_zs_more_pulses_less_error():
    """Theorem 2.2 direction: error decreases with the pulse budget."""
    spec = TINY
    key = jax.random.PRNGKey(4)
    tiles, _ = M.init_state(spec, key, 0.4, 0.1, 0.1)
    dev = jnp.array([5e-3, 0.0, 1.0, 1.0, 0.0, 1 / 127, 1 / 511, 12.0])
    zs = jax.jit(lambda t, n, k: A.zs_calibrate(spec, t, n, k, dev))

    def err_at(n):
        t2 = zs(tiles, jnp.uint32(n), jax.random.fold_in(key, n))
        errs = [
            float(jnp.mean(jnp.abs(t["q"] - devices.symmetric_point(t["pap"], t["pam"]))))
            for t in t2
        ]
        return np.mean(errs)

    assert err_at(2000) < err_at(50)


def test_all_steps_keep_weights_in_window():
    for algo in ("sgd", "ttv1", "ttv2", "agad", "erider"):
        tiles, _, _ = _train(algo, steps=40, ref_mean=0.4, ref_std=0.5, seed=7)
        for t in tiles:
            assert float(jnp.abs(t["w"]).max()) <= 1.0 + 1e-5
            assert float(jnp.abs(t["p"]).max()) <= 1.0 + 1e-5
