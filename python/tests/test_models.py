"""Model tests: shapes, im2col correctness, gradient flow, init."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import state as S

TINY = M.ModelSpec("tiny", (16,), (M.Fc(16, 12, "tanh"), M.Fc(12, 4, "none")), 4)
DEV = jnp.array([1e-3, 0.0, 1.0, 1.0, 0.06, 1 / 127, 1 / 511, 12.0])


def _init(spec, seed=0):
    return M.init_state(spec, jax.random.PRNGKey(seed), 0.1, 0.2, 0.1)


@pytest.mark.parametrize("name", ["fcn", "lenet", "convnet3"])
def test_forward_shapes(name):
    spec = M.MODELS[name]
    tiles, biases = _init(spec)
    x = jnp.ones((4, spec.d_in))
    logits = M.forward(spec, tiles, biases, x, jax.random.PRNGKey(1), DEV, "plain", 0.0)
    assert logits.shape == (4, spec.n_classes)


def test_im2col_matches_conv():
    """Our patches + matmul path equals lax.conv_general_dilated."""
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (2, 3, 8, 8))
    layer = M.Conv(3, 5, 3, "SAME", 1, "none")
    wk = jax.random.normal(jax.random.PRNGKey(4), (3 * 9, 5))
    pat, (hh, ww) = M._patches(x, layer)
    got = (pat @ wk).reshape(2, hh, ww, 5).transpose(0, 3, 1, 2)
    # conv_general_dilated_patches flattens features as (C, kh, kw)
    wconv = wk.reshape(3, 3, 3, 5).transpose(3, 0, 1, 2)  # OIHW
    want = jax.lax.conv_general_dilated(
        x, wconv, (1, 1), "SAME", dimension_numbers=("NCHW", "OIHW", "NCHW")
    )
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_digital_mode_is_exact():
    tiles, biases = _init(TINY)
    x = jnp.ones((3, 16))
    y1 = M.forward(TINY, tiles, biases, x, jax.random.PRNGKey(0), DEV, "digital", 0.0)
    h = jnp.tanh(x @ tiles[0]["w"] + biases[0])
    want = h @ tiles[1]["w"] + biases[1]
    np.testing.assert_allclose(y1, want, rtol=1e-6)


def test_grads_flow_to_all_tiles():
    tiles, biases = _init(TINY)
    x = jax.random.normal(jax.random.PRNGKey(5), (8, 16))
    labels = jnp.arange(8) % 4
    loss, gw, gb = M.loss_and_grads(
        TINY, tiles, biases, x, labels, jax.random.PRNGKey(6), DEV, "plain", 0.0
    )
    assert jnp.isfinite(loss)
    for g in gw + gb:
        assert float(jnp.abs(g).max()) > 0


def test_residual_mode_grad_matches_wbar_semantics():
    """In residual mode, dL/dw equals the gradient at W-bar; dL/dp is
    gamma * (c-modulated) times that (tied activations; c is per input
    line, broadcast over output columns)."""
    tiles, biases = _init(TINY)
    x = jax.random.normal(jax.random.PRNGKey(7), (4, 16))
    labels = jnp.arange(4) % 4
    key = jax.random.PRNGKey(8)
    gamma = 0.3

    def f(ws, ps):
        t2 = [dict(t, w=w, p=p) for t, w, p in zip(tiles, ws, ps)]
        # deterministic IO so the two grads see identical noise
        devd = DEV.at[4].set(0.0)
        return M.loss_fn(TINY, t2, biases, x, labels, key, devd, "residual", gamma)

    ws = [t["w"] for t in tiles]
    ps = [t["p"] for t in tiles]
    gw, gp = jax.grad(f, argnums=(0, 1))(ws, ps)
    for t, a, b in zip(tiles, gw, gp):
        want = gamma * t["c"] * a  # [K,1] broadcasts over columns
        np.testing.assert_allclose(b, want, rtol=2e-2, atol=5e-4)


def test_flatten_unflatten_roundtrip():
    tiles, biases = _init(TINY)
    flat = S.flatten(tiles, biases)
    assert len(flat) == S.state_len(TINY)
    t2, b2 = S.unflatten(TINY, flat)
    for ta, tb in zip(tiles, t2):
        for leaf in S.TILE_LEAVES:
            np.testing.assert_array_equal(ta[leaf], tb[leaf])
    for ba, bb in zip(biases, b2):
        np.testing.assert_array_equal(ba, bb)


def test_leaf_specs_match_init_shapes():
    tiles, biases = _init(TINY)
    flat = S.flatten(tiles, biases)
    for (name, shape, role, _), arr in zip(S.leaf_specs(TINY), flat):
        assert tuple(shape) == arr.shape, name


def test_init_respects_ref_mean():
    spec = M.MODELS["fcn"]
    tiles, _ = M.init_state(spec, jax.random.PRNGKey(0), 0.4, 0.05, 0.1)
    from compile import devices
    sp = devices.symmetric_point(tiles[0]["pap"], tiles[0]["pam"])
    assert abs(float(sp.mean()) - 0.4) < 0.03
