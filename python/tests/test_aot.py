"""AOT manifest consistency: artifact inventory, state layouts, parity
vectors. Runs against a built artifacts/ directory if present (make
artifacts); otherwise validates the emitter logic on a small model."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import algorithms as A
from compile import aot
from compile import model as M
from compile import state as S

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_hlo_text_roundtrippable():
    """Lowered HLO text parses back through xla_client (the same parser
    family the Rust xla crate uses)."""
    def f(x, y):
        return (x @ y,)

    spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    text = aot.to_hlo_text(jax.jit(f).lower(spec, spec))
    assert "ENTRY" in text and "f32[4,4]" in text


def test_state_len_consistent_across_models():
    for name, spec in M.MODELS.items():
        n_layers = len(spec.layers)
        assert S.state_len(spec) == n_layers * 10
        specs = S.leaf_specs(spec)
        assert len(specs) == S.state_len(spec)
        roles = [r for _, _, r, _ in specs]
        assert roles.count("w") == n_layers
        assert roles.count("bias") == n_layers


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built",
)
def test_manifest_inventory():
    man = json.load(open(os.path.join(ART, "manifest.json")))
    for mname in ("fcn", "lenet", "convnet3"):
        assert mname in man["models"]
        for art in ("init", "eval", "eval_digital", "zs"):
            assert f"{mname}_{art}" in man["artifacts"]
        for algo in A.STEPS:
            assert f"{mname}_step_{algo}" in man["artifacts"]
    # every artifact file exists
    for name, a in man["artifacts"].items():
        assert os.path.exists(os.path.join(ART, a["file"])), name


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built",
)
def test_manifest_state_matches_leaf_specs():
    man = json.load(open(os.path.join(ART, "manifest.json")))
    for mname, spec in M.MODELS.items():
        entries = man["models"][mname]["state"]
        want = S.leaf_specs(spec)
        assert len(entries) == len(want)
        for e, (n, sh, role, ti) in zip(entries, want):
            assert e["name"] == n and e["role"] == role
            assert tuple(e["shape"]) == tuple(sh)


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "parity.json")),
    reason="artifacts not built",
)
def test_parity_vectors_valid():
    par = json.load(open(os.path.join(ART, "parity.json")))
    assert len(par["cases"]) >= 5
    for c in par["cases"]:
        if c["kind"] == "pulse_update":
            n = c["rows"] * c["cols"]
            assert len(c["w"]) == len(c["expected"]) == n
        else:
            assert len(c["expected"]) == c["b"] * c["n"]
