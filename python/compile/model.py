"""L2: analog neural-network models (paper Section 4 workloads).

Every analog layer (fully connected and convolution-as-im2col) routes its
forward MVM and its backward (transposed) MVM through the L1 `analog_mvm`
Pallas kernel via a custom VJP, so gradients are computed *through the
analog hardware*, as on-chip training requires. Weight gradients
(outer products) are returned exactly; they are then *applied* through the
L1 `pulse_update` kernel by the algorithms in `algorithms.py`, which is
where the pulsed-update non-idealities enter.

Models (paper Section 4 / Appendix F.3):
  * `fcn`      -- 784-256-128-10, sigmoid (Table 2).
  * `lenet`    -- LeNet-5-style CNN: 2x conv5 + 2 FC, tanh (Table 1).
  * `convnet3` -- 3-channel conv net, the CIFAR-100/ResNet stand-in
                  (Fig. 4 mid/right, Table 8 protocol).

State layout per analog tile (shared across ALL algorithms so one init
artifact serves every step artifact; unused leaves are simply carried):
  w    main array            p    residual/fast array (A in TT, P in RIDER)
  q    reference (digital)   h    digital transfer buffer (TT-v2/AGAD)
  wap/wam  device (alpha+, alpha-) of the W array
  pap/pam  device (alpha+, alpha-) of the P array
  c    per-input-line chopper signs, shape (fan_in, 1) — AIHWKit-style
       input chopping: each crossbar input line carries its own chopper
plus one digital bias vector per layer.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from . import devices
from .kernels import analog_mvm

# ------------------------------------------------------------------ specs


@dataclasses.dataclass(frozen=True)
class Fc:
    d_in: int
    d_out: int
    act: str  # 'tanh' | 'sigmoid' | 'none'


@dataclasses.dataclass(frozen=True)
class Conv:
    c_in: int
    c_out: int
    k: int
    padding: str  # 'SAME' | 'VALID'
    pool: int  # avg-pool window after activation (1 = none)
    act: str


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    name: str
    input_shape: Tuple[int, ...]  # (C,H,W) for conv nets, (D,) for MLPs
    layers: Tuple
    n_classes: int

    @property
    def d_in(self) -> int:
        d = 1
        for s in self.input_shape:
            d *= s
        return d


MODELS = {
    "fcn": ModelSpec(
        "fcn",
        (784,),
        (Fc(784, 256, "sigmoid"), Fc(256, 128, "sigmoid"), Fc(128, 10, "none")),
        10,
    ),
    "lenet": ModelSpec(
        "lenet",
        (1, 28, 28),
        (
            Conv(1, 8, 5, "VALID", 2, "tanh"),
            Conv(8, 16, 5, "VALID", 2, "tanh"),
            Fc(256, 128, "tanh"),
            Fc(128, 10, "none"),
        ),
        10,
    ),
    "convnet3": ModelSpec(
        "convnet3",
        (3, 16, 16),
        (
            Conv(3, 16, 3, "SAME", 2, "tanh"),
            Conv(16, 32, 3, "SAME", 2, "tanh"),
            Fc(512, 64, "tanh"),
            Fc(64, 10, "none"),
        ),
        10,
    ),
}


def tile_shape(layer) -> Tuple[int, int]:
    """Crossbar tile shape of a layer: [fan_in, fan_out]."""
    if isinstance(layer, Fc):
        return (layer.d_in, layer.d_out)
    return (layer.c_in * layer.k * layer.k, layer.c_out)


# --------------------------------------------------- analog MVM custom VJP


@jax.custom_vjp
def crossbar_mvm(x, w, z_fwd, z_bwd, inp_res, out_res, out_bound, out_noise):
    """y = x @ w through the analog crossbar, analog backward.

    z_fwd: [B, N] ADC noise for the forward pass.
    z_bwd: [B, K] ADC noise for the backward (transposed) pass.
    """
    return analog_mvm(
        x, w, z_fwd, inp_res=inp_res, out_res=out_res,
        out_bound=out_bound, out_noise=out_noise,
    )


def _crossbar_fwd(x, w, z_fwd, z_bwd, inp_res, out_res, out_bound, out_noise):
    y = analog_mvm(
        x, w, z_fwd, inp_res=inp_res, out_res=out_res,
        out_bound=out_bound, out_noise=out_noise,
    )
    return y, (x, w, z_bwd, inp_res, out_res, out_bound, out_noise)


def _crossbar_bwd(res, g):
    x, w, z_bwd, inp_res, out_res, out_bound, out_noise = res
    # Backward MVM runs through the same crossbar, transposed -- the analog
    # backward pass of on-chip training.
    dx = analog_mvm(
        g, w.T, z_bwd, inp_res=inp_res, out_res=out_res,
        out_bound=out_bound, out_noise=out_noise,
    )
    # The outer-product weight gradient is exact here; its *application*
    # is pulsed (kernels.pulse_update) inside the training algorithms.
    dw = x.T @ g
    zf = jnp.zeros_like
    return (dx, dw, jnp.zeros(g.shape, g.dtype), jnp.zeros(dx.shape, dx.dtype),
            zf(inp_res), zf(out_res), zf(out_bound), zf(out_noise))


crossbar_mvm.defvjp(_crossbar_fwd, _crossbar_bwd)


# ---------------------------------------------------------------- forward


def _act(name, x):
    if name == "tanh":
        return jnp.tanh(x)
    if name == "sigmoid":
        return jax.nn.sigmoid(x)
    return x


def _avg_pool(x, p):
    """x: [B, C, H, W] -> [B, C, H/p, W/p]."""
    b, c, h, w = x.shape
    x = x.reshape(b, c, h // p, p, w // p, p)
    return x.mean(axis=(3, 5))


def _patches(x, layer):
    """im2col: [B,C,H,W] -> ([B*H'*W', C*k*k], (H', W'))."""
    pat = jax.lax.conv_general_dilated_patches(
        x, (layer.k, layer.k), (1, 1), layer.padding
    )  # [B, C*k*k, H', W']
    b, f, hh, ww = pat.shape
    pat = pat.transpose(0, 2, 3, 1).reshape(b * hh * ww, f)
    return pat, (hh, ww)


def _tile_mvm(x2d, tile, mode, gamma, key, dev):
    """Analog MVM against a tile's effective weight.

    mode 'plain':    y = <x, W>                      (SGD / TT / AGAD fwd)
    mode 'residual': y = <x, W> + gamma*c*(<x, P> - x@Q)   (RIDER W-bar)
    mode 'digital':  y = x @ W (exact; pre-training / digital baselines)
    """
    inp_res, out_res, out_bound, out_noise = dev[5], dev[6], dev[7], dev[4]
    if mode == "digital":
        return x2d @ tile["w"]
    b = x2d.shape[0]
    n = tile["w"].shape[1]
    kdim = tile["w"].shape[0]
    k1, k2, k3, k4 = jax.random.split(key, 4)
    zf1 = jax.random.normal(k1, (b, n))
    zb1 = jax.random.normal(k2, (b, kdim))
    y = crossbar_mvm(x2d, tile["w"], zf1, zb1, inp_res, out_res, out_bound, out_noise)
    if mode == "residual":
        zf2 = jax.random.normal(k3, (b, n))
        zb2 = jax.random.normal(k4, (b, kdim))
        # per-input-line chopping: the DAC applies c to each input line,
        # so the P array sees chopped activations (and the gradient w.r.t.
        # P is automatically c-modulated, Eq. 18a).
        xc = x2d * tile["c"][:, 0][None, :]
        yp = crossbar_mvm(
            xc, tile["p"], zf2, zb2, inp_res, out_res, out_bound, out_noise
        )
        y = y + gamma * (yp - xc @ jax.lax.stop_gradient(tile["q"]))
    return y


def forward(spec, tiles, biases, x, key, dev, mode, gamma):
    """Run the model. x: [B, d_in] flat; returns logits [B, n_classes].

    `mode`/`gamma` select the effective-weight composition (see _tile_mvm).
    """
    b = x.shape[0]
    if len(spec.input_shape) == 3:
        h = x.reshape((b,) + spec.input_shape)
    else:
        h = x
    for i, layer in enumerate(spec.layers):
        lkey = jax.random.fold_in(key, i)
        if isinstance(layer, Conv):
            pat, (hh, ww) = _patches(h, layer)
            y = _tile_mvm(pat, tiles[i], mode, gamma, lkey, dev)
            y = y + biases[i][None, :]
            y = y.reshape(b, hh, ww, layer.c_out).transpose(0, 3, 1, 2)
            y = _act(layer.act, y)
            if layer.pool > 1:
                y = _avg_pool(y, layer.pool)
            h = y
        else:
            if h.ndim > 2:
                h = h.reshape(b, -1)
            y = _tile_mvm(h, tiles[i], mode, gamma, lkey, dev)
            y = y + biases[i][None, :]
            h = _act(layer.act, y)
    return h


def loss_fn(spec, tiles, biases, x, labels, key, dev, mode, gamma):
    """Mean softmax cross-entropy."""
    logits = forward(spec, tiles, biases, x, key, dev, mode, gamma)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()
    return nll


def loss_and_grads(spec, tiles, biases, x, labels, key, dev, mode, gamma):
    """Returns (loss, per-tile dL/dW at the effective weights, dL/dbias).

    dL/dW of the `w` leaf *is* the paper's grad-at-W-bar for 'residual'
    mode (the P/Q contributions are tied to the same activations), and the
    plain gradient for 'plain' mode.
    """

    def f(ws, bs):
        t2 = [dict(t, w=w) for t, w in zip(tiles, ws)]
        return loss_fn(spec, t2, bs, x, labels, key, dev, mode, gamma)

    ws = [t["w"] for t in tiles]
    loss, (gw, gb) = jax.value_and_grad(f, argnums=(0, 1))(ws, list(biases))
    return loss, gw, gb


def accuracy_count(spec, tiles, biases, x, labels, key, dev, mode, gamma):
    logits = forward(spec, tiles, biases, x, key, dev, mode, gamma)
    pred = jnp.argmax(logits, axis=-1)
    return (pred == labels).sum().astype(jnp.float32)


# ------------------------------------------------------------------- init


def init_state(spec, key, ref_mean, ref_std, sigma_gamma):
    """Fresh training state: Glorot weights + per-cell device sampling.

    The SPs of both the W-array and the P-array are drawn i.i.d. from
    N(ref_mean, ref_std) -- the paper's non-ideal-reference scenario.
    Returns (tiles, biases).
    """
    tiles = []
    biases = []
    for i, layer in enumerate(spec.layers):
        kdim, n = tile_shape(layer)
        k = jax.random.fold_in(key, i)
        kw, kdw, kdp = jax.random.split(k, 3)
        lim = jnp.sqrt(6.0 / (kdim + n))
        # Analog arrays store weights in the conductance window [-1, 1];
        # Glorot init for these fan-ins is well inside it.
        w = jax.random.uniform(kw, (kdim, n), jnp.float32, -lim, lim)
        wap, wam = devices.sample_device(kdw, (kdim, n), ref_mean, ref_std, sigma_gamma)
        pap, pam = devices.sample_device(kdp, (kdim, n), ref_mean, ref_std, sigma_gamma)
        tiles.append(
            dict(
                w=w,
                p=jnp.zeros((kdim, n), jnp.float32),
                q=jnp.zeros((kdim, n), jnp.float32),
                h=jnp.zeros((kdim, n), jnp.float32),
                wap=wap,
                wam=wam,
                pap=pap,
                pam=pam,
                c=jnp.ones((kdim, 1), jnp.float32),
            )
        )
        biases.append(jnp.zeros((n,), jnp.float32))
    return tiles, biases
