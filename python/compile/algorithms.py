"""L2: the paper's analog training algorithm family, as JAX step functions.

Every algorithm is expressed over the unified tile state of `model.py` and
mutates analog arrays exclusively through the L1 `pulse_update` kernel
(the Analog Update, paper Eq. 2). One step function per algorithm; all of
them share the signature

    step(tiles, biases, x, labels, key, hypers, dev) -> (tiles', biases', loss)

so `aot.py` can lower them uniformly and the Rust coordinator can drive
any of them through one code path.

Hyper-parameter vector `hypers` (f32[12], runtime-sweepable from Rust):
  0 lr_fast      alpha  -- P/A array learning rate
  1 lr_transfer  beta   -- W array transfer learning rate
  2 eta                 -- Q moving-average stepsize (Eq. 12)
  3 gamma               -- residual scale (Eq. 8)
  4 flip_p              -- chopper flip probability (Eq. 17)
  5 thresh              -- TT-v2/AGAD digital-buffer transfer threshold
  6 lr_digital          -- digital bias learning rate
  7 read_noise          -- analog read-out noise std for transfer reads
  8..11 reserved

Device vector `dev` (f32[8]):
  0 dw_min  1 sigma_c2c  2 tau_max  3 tau_min
  4 out_noise  5 inp_res  6 out_res  7 out_bound

Algorithms (see DESIGN.md section 3):
  sgd     -- Analog SGD (Eq. 2 applied to the gradient): drifts to SP.
  ttv1    -- Tiki-Taka v1: fast array A + direct transfer.
  ttv2    -- Tiki-Taka v2: + digital accumulation buffer w/ thresholding.
  agad    -- chopped transfer + offset-corrected reference (baseline).
  erider  -- E-RIDER (Algorithm 3); RIDER == flip_p = 0 (Algorithm 2);
             two-stage Residual Learning == eta = 0 after `zs` calibration.
  digital -- exact SGD (pre-training / upper-bound baseline).
  zs      -- Algorithm 1 zero-shifting calibration of the P arrays.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import model as M
from .kernels import pulse_update

# hyper indices
LR_FAST, LR_TRANSFER, ETA, GAMMA, FLIP_P, THRESH, LR_DIGITAL, READ_NOISE = range(8)
N_HYPERS = 12
N_DEV = 8


def _pulse(arr, dw, ap, am, key, dev):
    """Analog Update of one array through the L1 kernel."""
    ku, kz = jax.random.split(key)
    u = jax.random.uniform(ku, arr.shape)
    z = jax.random.normal(kz, arr.shape)
    return pulse_update(
        arr, dw, ap, am, u, z, dev[0], dev[1], dev[2], dev[3]
    )


def _read(arr, key, read_noise):
    """Noisy analog read-out of an array (used by transfer steps)."""
    return arr + read_noise * jax.random.normal(key, arr.shape)


def _flip_choppers(tiles, key, flip_p):
    """Draw the Markov choppers (Eq. 17), one per crossbar input line
    (AIHWKit-style input chopping; a scalar-chopper tile would swing its
    whole residual at every flip, which destabilises training).

    Returns (new tiles, per-tile mean-flip fraction)."""
    out = []
    flips = []
    for i, t in enumerate(tiles):
        kf = jax.random.fold_in(key, 7919 + i)
        flip = (jax.random.uniform(kf, t["c"].shape) < flip_p).astype(jnp.float32)
        c = jnp.where(flip > 0.5, -t["c"], t["c"])
        out.append(dict(t, c=c))
        flips.append(flip.mean())
    return out, flips


def _digital_bias(biases, gb, lr):
    return [b - lr * g for b, g in zip(biases, gb)]


# ------------------------------------------------------------------ steps


def step_sgd(spec, tiles, biases, x, labels, key, hypers, dev):
    """Plain Analog SGD: w <- AnalogUpdate(w, -alpha * grad)."""
    kg, kp = jax.random.split(jax.random.fold_in(key, 0))
    loss, gw, gb = M.loss_and_grads(
        spec, tiles, biases, x, labels, kg, dev, "plain", 0.0
    )
    new_tiles = []
    for i, (t, g) in enumerate(zip(tiles, gw)):
        kt = jax.random.fold_in(kp, i)
        w = _pulse(t["w"], -hypers[LR_FAST] * g, t["wap"], t["wam"], kt, dev)
        new_tiles.append(dict(t, w=w))
    return new_tiles, _digital_bias(biases, gb, hypers[LR_DIGITAL]), loss


def step_ttv1(spec, tiles, biases, x, labels, key, hypers, dev):
    """Tiki-Taka v1: gradient -> fast array A (the `p` leaf); every step,
    transfer the reference-corrected read  (A - q)  into W. The forward
    pass runs at the *combined* weight W + gamma (A - q) (the AIHWKit
    transfer compound): A is part of the logical weight, which damps the
    A->W loop (proportional + integral control)."""
    kg, kp = jax.random.split(jax.random.fold_in(key, 1))
    loss, gw, gb = M.loss_and_grads(
        spec, tiles, biases, x, labels, kg, dev, "residual", hypers[GAMMA]
    )
    new_tiles = []
    for i, (t, g) in enumerate(zip(tiles, gw)):
        kt = jax.random.fold_in(kp, i)
        k1, k2, k3 = jax.random.split(kt, 3)
        p = _pulse(t["p"], -hypers[LR_FAST] * g, t["pap"], t["pam"], k1, dev)
        r = _read(p, k2, hypers[READ_NOISE]) - t["q"]
        w = _pulse(t["w"], hypers[LR_TRANSFER] * r, t["wap"], t["wam"], k3, dev)
        new_tiles.append(dict(t, p=p, w=w))
    return new_tiles, _digital_bias(biases, gb, hypers[LR_DIGITAL]), loss


def _thresholded_transfer(t, h, key, hypers, dev):
    """TT-v2 digital buffer: move whole multiples of `thresh` from the
    buffer into pulsed updates of W; keep the remainder digital."""
    thresh = hypers[THRESH]
    quanta = jnp.trunc(h / thresh)
    dw = hypers[LR_TRANSFER] * quanta * thresh
    w = _pulse(t["w"], dw, t["wap"], t["wam"], key, dev)
    return w, h - quanta * thresh


def step_ttv2(spec, tiles, biases, x, labels, key, hypers, dev):
    """Tiki-Taka v2: like v1 but reads accumulate in a digital buffer and
    only threshold-crossing amounts are pulsed into W. Combined-weight
    forward as in v1."""
    kg, kp = jax.random.split(jax.random.fold_in(key, 2))
    loss, gw, gb = M.loss_and_grads(
        spec, tiles, biases, x, labels, kg, dev, "residual", hypers[GAMMA]
    )
    new_tiles = []
    for i, (t, g) in enumerate(zip(tiles, gw)):
        kt = jax.random.fold_in(kp, i)
        k1, k2, k3 = jax.random.split(kt, 3)
        p = _pulse(t["p"], -hypers[LR_FAST] * g, t["pap"], t["pam"], k1, dev)
        h = t["h"] + (_read(p, k2, hypers[READ_NOISE]) - t["q"])
        w, h = _thresholded_transfer(dict(t, p=p), h, k3, hypers, dev)
        new_tiles.append(dict(t, p=p, h=h, w=w))
    return new_tiles, _digital_bias(biases, gb, hypers[LR_DIGITAL]), loss


def step_agad(spec, tiles, biases, x, labels, key, hypers, dev):
    """AGAD-style baseline (Rasch et al.): chopped gradient accumulation
    plus reference-offset correction on chopper flips. Combined-weight
    forward W + gamma c (A - q); unlike E-RIDER, q is only refreshed at
    flip boundaries (no low-pass SP filtering) and there is no residual
    bilevel structure (paper Appendix B.2)."""
    kg, kp, kc = jax.random.split(jax.random.fold_in(key, 3), 3)
    tiles, flips = _flip_choppers(tiles, kc, hypers[FLIP_P])
    loss, gw, gb = M.loss_and_grads(
        spec, tiles, biases, x, labels, kg, dev, "residual", hypers[GAMMA]
    )
    new_tiles = []
    for i, (t, g, flip) in enumerate(zip(tiles, gw, flips)):
        kt = jax.random.fold_in(kp, i)
        k1, k2, k3 = jax.random.split(kt, 3)
        c = t["c"]  # [K,1], broadcasts over columns
        p = _pulse(t["p"], -hypers[LR_FAST] * c * g, t["pap"], t["pam"], k1, dev)
        r = _read(p, k2, hypers[READ_NOISE])
        # de-chopped, offset-corrected accumulation
        h = t["h"] + c * (r - t["q"])
        # offset estimate refresh, weighted by the fraction of lines that
        # flipped this step (Rasch-style fast offset correction)
        q = (1.0 - hypers[ETA] * flip) * t["q"] + hypers[ETA] * flip * r
        w, h = _thresholded_transfer(dict(t, p=p), h, k3, hypers, dev)
        new_tiles.append(dict(t, p=p, h=h, q=q, w=w))
    return new_tiles, _digital_bias(biases, gb, hypers[LR_DIGITAL]), loss


def step_erider(spec, tiles, biases, x, labels, key, hypers, dev):
    """E-RIDER (Algorithm 3). RIDER is flip_p = 0; two-stage Residual
    Learning is eta = 0 with `q` pre-set by `zs_calibrate`.

    Per iteration k (paper Eq. 17/18 + Eq. 12):
      1. draw chopper c_k (Markov flip w.p. p); on flip the analog shadow
         Q~ is re-programmed from digital Q (cost tracked by the
         coordinator),
      2. grads at W-bar = W + gamma c_k (P - Q),
      3. P   <- AnalogUpdate(P, -alpha c_k grad)            (18a)
      4. Q   <- (1-eta) Q + eta read(P)                     (12, digital)
      5. W   <- AnalogUpdate(W, beta c_k (read(P) - Q_k))   (18b)
    """
    kg, kp, kc = jax.random.split(jax.random.fold_in(key, 4), 3)
    tiles, _ = _flip_choppers(tiles, kc, hypers[FLIP_P])
    loss, gw, gb = M.loss_and_grads(
        spec, tiles, biases, x, labels, kg, dev, "residual", hypers[GAMMA]
    )
    new_tiles = []
    for i, (t, g) in enumerate(zip(tiles, gw)):
        kt = jax.random.fold_in(kp, i)
        k1, k2, k3 = jax.random.split(kt, 3)
        c = t["c"]  # [K,1], broadcasts over columns
        p = _pulse(t["p"], -hypers[LR_FAST] * c * g, t["pap"], t["pam"], k1, dev)
        r = _read(p, k2, hypers[READ_NOISE])
        q_old = t["q"]
        q = (1.0 - hypers[ETA]) * q_old + hypers[ETA] * r
        w = _pulse(
            t["w"], hypers[LR_TRANSFER] * c * (r - q_old), t["wap"], t["wam"], k3, dev
        )
        new_tiles.append(dict(t, p=p, q=q, w=w))
    return new_tiles, _digital_bias(biases, gb, hypers[LR_DIGITAL]), loss


def step_digital(spec, tiles, biases, x, labels, key, hypers, dev):
    """Exact digital SGD on the `w` leaves (pre-training / upper bound)."""
    loss, gw, gb = M.loss_and_grads(
        spec, tiles, biases, x, labels, key, dev, "digital", 0.0
    )
    new_tiles = [
        dict(t, w=jnp.clip(t["w"] - hypers[LR_DIGITAL] * g, -1.0, 1.0))
        for t, g in zip(tiles, gw)
    ]
    return new_tiles, _digital_bias(biases, gb, hypers[LR_DIGITAL]), loss


STEPS = {
    "sgd": step_sgd,
    "ttv1": step_ttv1,
    "ttv2": step_ttv2,
    "agad": step_agad,
    "erider": step_erider,
    "digital": step_digital,
}


# ----------------------------------------------------------- ZS calibration


def zs_calibrate(spec, tiles, n, key, dev):
    """Algorithm 1 (stochastic): n alternating +-dw_min pulses on every P
    array, then store the read-out as the reference estimate `q`.

    `n` is a traced uint32 scalar -- the Rust coordinator sweeps the pulse
    budget at runtime through ONE artifact (lax.while_loop, not unroll).
    """
    new_tiles = []
    for i, t in enumerate(tiles):
        tkey = jax.random.fold_in(key, i)

        def body(state):
            j, p, k = state
            k, ks, kp = jax.random.split(k, 3)
            sign = jnp.where(
                jax.random.uniform(ks, p.shape) < 0.5, 1.0, -1.0
            )
            p = _pulse(p, sign * dev[0], t["pap"], t["pam"], kp, dev)
            return j + 1, p, k

        def cond(state):
            return state[0] < n

        _, p, _ = jax.lax.while_loop(cond, body, (jnp.uint32(0), t["p"], tkey))
        new_tiles.append(dict(t, p=p, q=p))
    return new_tiles
