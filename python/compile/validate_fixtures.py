"""End-to-end validation of the hermetic HLO fixtures (numpy-only).

Runs the emitted artifacts through the reference evaluator
(`hlo_eval.py`): grammar check on every artifact, init/step/eval/zs
round-trips on all three models, a short E-RIDER training run on
synthetic separable data (loss must drop), ZS calibration convergence,
and kernel-artifact parity against the numpy ports in
`hlo_fixtures.py`.  Usage:

    python3 -m python.compile.validate_fixtures [--dir artifacts]
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np

from . import hlo_eval
from .hlo_fixtures import (
    DEV_INDEX,
    HYPER_INDEX,
    N_DEV,
    N_HYPERS,
    np_mvm_det,
    np_pulse_det,
)

F = np.float32


def hyp_vec(**kw):
    v = np.zeros(N_HYPERS, F)
    for k, x in kw.items():
        v[HYPER_INDEX[k]] = x
    return v


def dev_vec(**kw):
    v = np.zeros(N_DEV, F)
    for k, x in kw.items():
        v[DEV_INDEX[k]] = x
    return v


DEFAULT_HYP = dict(
    lr_fast=0.5, lr_transfer=0.3, eta=0.3, gamma=1.0, flip_p=0.05,
    thresh=0.1, lr_digital=0.05, read_noise=0.01,
)
DEFAULT_DEV = dict(
    dw_min=0.002, sigma_c2c=0.1, tau_max=1.0, tau_min=1.0, out_noise=0.06,
    inp_res=1.0 / 127.0, out_res=1.0 / 511.0, out_bound=12.0,
)


def key_of(a, b):
    return np.array([a, b], np.uint32)


def synth_data(n, d_in, n_classes, seed):
    """Separable synthetic task: class means + noise, zero-mean rows."""
    r = np.random.default_rng(seed)
    means = r.normal(0, 1.0, (n_classes, d_in)).astype(F)
    y = (np.arange(n) % n_classes).astype(np.int32)
    x = means[y] + 0.3 * r.normal(0, 1, (n, d_in)).astype(F)
    x -= x.mean(axis=1, keepdims=True)
    x = np.clip(x, -1, 1)
    return x.astype(F), y


class Runner:
    def __init__(self, art_dir, manifest):
        self.dir = art_dir
        self.man = manifest
        self.cache = {}

    def evaluator(self, name):
        if name not in self.cache:
            path = os.path.join(self.dir, self.man["artifacts"][name]["file"])
            self.cache[name] = hlo_eval.load(path)
        return self.cache[name]

    def run(self, name, inputs):
        spec = self.man["artifacts"][name]
        assert len(inputs) == len(spec["inputs"]), name
        for t, s in zip(inputs, spec["inputs"]):
            assert list(t.shape) == s["shape"], (name, s["name"], t.shape, s["shape"])
        out = self.evaluator(name).run([np.asarray(t) for t in inputs])
        assert isinstance(out, tuple), name
        assert len(out) == len(spec["outputs"]), name
        return [np.asarray(o) for o in out]


def check_model(rn: Runner, mname, steps=0, check_loss_drop=False):
    m = rn.man["models"][mname]
    d_in, ncls, batch, eb = m["d_in"], m["n_classes"], m["batch"], m["eval_batch"]
    nleaves = len(m["state"])
    hyp = hyp_vec(**DEFAULT_HYP)
    dev = dev_vec(**DEFAULT_DEV)

    state = rn.run(f"{mname}_init", [key_of(1, 2), np.array([0.3, 0.2, 0.1], F)])
    assert len(state) == nleaves
    for leaf, out in zip(m["state"], state):
        assert list(out.shape) == leaf["shape"], (leaf["name"], out.shape)
    # device sanity: alphas floored, SP distribution roughly centred
    wap = state[4]
    wam = state[5]
    assert wap.min() >= 0.05 and wam.min() >= 0.05
    sp = (wap - wam) / (wap + wam)
    assert abs(sp.mean() - 0.3) < 0.05, sp.mean()
    assert 0.1 < sp.std() < 0.3, sp.std()

    xtr, ytr = synth_data(256, d_in, ncls, 7)
    losses = []
    for algo in ("sgd", "ttv1", "ttv2", "agad", "erider", "digital"):
        out = rn.run(
            f"{mname}_step_{algo}",
            list(state)
            + [xtr[:batch], ytr[:batch], key_of(0, 9), hyp, dev],
        )
        loss = float(out[-1])
        assert np.isfinite(loss) and loss > 0, (algo, loss)
        moved = any(
            not np.allclose(a, b)
            for a, b, leaf in zip(state, out[:-1], m["state"])
            if leaf["role"] in ("w", "p")
        )
        assert moved, f"{mname}_step_{algo}: state did not move"

    if check_loss_drop and steps:
        st = [s.copy() for s in state]
        r = np.random.default_rng(3)
        first = None
        for k in range(steps):
            idx = r.integers(0, len(ytr), batch)
            out = rn.run(
                f"{mname}_step_erider",
                list(st) + [xtr[idx], ytr[idx], key_of(1, 100 + k), hyp, dev],
            )
            loss = float(out[-1])
            losses.append(loss)
            st = out[:-1]
            if first is None:
                first = loss
        head = np.mean(losses[:5])
        tail = np.mean(losses[-5:])
        print(f"    erider loss {head:.3f} -> {tail:.3f} over {steps} steps")
        assert tail < head, "erider loss did not decrease"

        # eval on the training distribution: accuracy above chance
        xe, ye = synth_data(eb, d_in, ncls, 7)
        loss_e, nc = rn.run(
            f"{mname}_eval", list(st) + [xe, ye, key_of(5, 5), hyp, dev]
        )
        acc = 100.0 * float(nc) / eb
        print(f"    eval loss {float(loss_e):.3f}, acc {acc:.1f}%")
        assert np.isfinite(float(loss_e)) and 0 <= float(nc) <= eb
        assert acc > 100.0 / ncls, "post-training accuracy at chance level"

        loss_d, nc_d = rn.run(f"{mname}_eval_digital", list(st) + [xe, ye])
        assert np.isfinite(float(loss_d)) and 0 <= float(nc_d) <= eb

        # trainer zero-pad contract: rows labelled n_classes (out of
        # range) must never count as correct, whatever the logits
        half = eb // 2
        xp = xe.copy()
        xp[half:] = 0.0
        yp = ye.copy()
        yp[half:] = ncls
        _, nc_pad = rn.run(
            f"{mname}_eval", list(st) + [xp, yp, key_of(5, 5), hyp, dev]
        )
        assert float(nc_pad) <= half, f"padded rows counted: {float(nc_pad)} > {half}"
    else:
        xe, ye = synth_data(eb, d_in, ncls, 8)
        loss_e, nc = rn.run(
            f"{mname}_eval", list(state) + [xe, ye, key_of(5, 5), hyp, dev]
        )
        assert np.isfinite(float(loss_e)) and 0 <= float(nc) <= eb

    # ZS calibration pushes q toward the P-array SP distribution
    zdev = dev_vec(**dict(DEFAULT_DEV, dw_min=0.02, sigma_c2c=0.0))
    zstate = rn.run(
        f"{mname}_init", [key_of(3, 4), np.array([0.4, 0.1, 0.1], F)]
    )
    zout = rn.run(
        f"{mname}_zs",
        list(zstate) + [np.array(300, np.uint32), key_of(7, 8), zdev],
    )
    roles = [leaf["role"] for leaf in m["state"]]
    q_mean = np.mean(
        [zout[i].mean() for i, r_ in enumerate(roles) if r_ == "q"]
    )
    p_idx = [i for i, r_ in enumerate(roles) if r_ == "p"]
    assert all(np.allclose(zout[i], zout[i + 1]) for i in p_idx)  # q == p
    print(f"    zs q mean {q_mean:.3f} (target SP ~ 0.4)")
    assert q_mean > 0.25, f"ZS calibration ineffective: q mean {q_mean}"
    print(f"  {mname}: ok")


def check_kernels(rn: Runner, art_dir):
    parity = json.load(open(os.path.join(art_dir, "parity.json")))
    n_pulse = n_mvm = 0
    for case in parity["cases"]:
        if case["kind"] == "pulse_update":
            n_pulse += 1
            sh = (case["rows"], case["cols"])
            w = np.array(case["w"], F).reshape(sh)
            dw = np.array(case["dw"], F).reshape(sh)
            ap = np.array(case["alpha_p"], F).reshape(sh)
            am = np.array(case["alpha_m"], F).reshape(sh)
            dev = dev_vec(
                dw_min=case["dw_min"], tau_max=1.0, tau_min=1.0,
                inp_res=1.0 / 127.0, out_res=1.0 / 511.0, out_bound=12.0,
            )
            (out,) = rn.run("kernel_pulse_update_det", [w, dw, ap, am, dev])
            want = np.array(case["expected"], F).reshape(sh)
            np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-6)
            np.testing.assert_allclose(
                np_pulse_det(w, dw, ap, am, case["dw_min"]), want, rtol=1e-6
            )
        else:
            n_mvm += 1
            b, k, n = case["b"], case["k"], case["n"]
            x = np.array(case["x"], F).reshape(b, k)
            w = np.array(case["w"], F).reshape(k, n)
            dev = dev_vec(**DEFAULT_DEV)
            (out,) = rn.run(f"kernel_analog_mvm_det_{b}x{k}x{n}", [x, w, dev])
            want = np.array(case["expected"], F).reshape(b, n)
            np.testing.assert_allclose(out, want, rtol=1e-5, atol=2e-6)
            np.testing.assert_allclose(np_mvm_det(x, w), want, rtol=1e-6)
    assert n_pulse >= 3 and n_mvm >= 2
    print(f"  kernels: {n_pulse} pulse + {n_mvm} mvm parity cases ok")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts")
    ap.add_argument("--steps", type=int, default=30)
    args = ap.parse_args()
    manifest = json.load(open(os.path.join(args.dir, "manifest.json")))
    rn = Runner(args.dir, manifest)
    print("validating artifacts:")
    # grammar check on everything up front
    for name in sorted(manifest["artifacts"]):
        rn.evaluator(name)
    print(f"  parsed {len(manifest['artifacts'])} artifacts")
    check_kernels(rn, args.dir)
    check_model(rn, "fcn", steps=args.steps, check_loss_drop=True)
    check_model(rn, "lenet")
    check_model(rn, "convnet3")
    print("fixtures OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
