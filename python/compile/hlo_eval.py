"""Numpy reference evaluator for the HLO-text subset of
`rust/src/runtime/interp.rs`.

Mirrors the Rust interpreter's grammar and op semantics so the emitted
fixtures (`hlo_fixtures.py`) can be validated without a Rust toolchain
(`validate_fixtures.py`), and so the two implementations can be checked
against each other through `artifacts/parity.json`. f32 throughout.
"""

from __future__ import annotations

import numpy as np

F = np.float32

DTYPES = {"f32": np.float32, "s32": np.int32, "u32": np.uint32, "pred": np.bool_}


class HloError(Exception):
    pass


def _split_top(s):
    out, depth, cur = [], 0, []
    for ch in s:
        if ch in "({[":
            depth += 1
            cur.append(ch)
        elif ch in ")}]":
            depth -= 1
            cur.append(ch)
        elif ch == "," and depth == 0:
            out.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    tail = "".join(cur).strip()
    if tail:
        out.append(tail)
    return out


def _parse_shape(s):
    s = s.strip()
    if s.startswith("("):
        return ("tuple", [_parse_shape(p) for p in _split_top(s[1:-1])])
    dt, rest = s.split("[", 1)
    dims_s, _, _ = rest.partition("]")
    dims = tuple(int(d) for d in dims_s.split(",") if d.strip())
    return (dt.strip(), dims)


class Instr:
    __slots__ = ("name", "shape", "op", "operands", "attrs", "root", "const")

    def __init__(self, name, shape, op, operands, attrs, root, const=None):
        self.name = name
        self.shape = shape
        self.op = op
        self.operands = operands
        self.attrs = attrs
        self.root = root
        self.const = const


def _parse_instr(line):
    line = line.strip()
    root = line.startswith("ROOT ")
    if root:
        line = line[5:]
    assert line.startswith("%"), line
    name, _, rest = line[1:].partition(" = ")
    rest = rest.strip()
    # shape: up to the op token.  Find the first space at depth 0
    depth = 0
    for i, ch in enumerate(rest):
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        elif ch == " " and depth == 0:
            break
    shape = _parse_shape(rest[:i])
    rest = rest[i + 1 :].strip()
    op, _, rest = rest.partition("(")
    depth = 1
    for i, ch in enumerate(rest):
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
            if depth == 0:
                break
    body = rest[:i]
    attrs = {}
    for a in _split_top(rest[i + 1 :].lstrip(", ")):
        if "=" in a:
            k, _, v = a.partition("=")
            attrs[k.strip()] = v.strip()
    const = None
    operands = []
    if op == "constant":
        const = body
    elif op not in ("parameter", "iota"):
        for tok in _split_top(body):
            operands.append(tok[tok.rfind("%") + 1 :].strip())
    elif op == "parameter":
        const = body
    return Instr(name.strip(), shape, op.strip(), operands, attrs, root, const)


class Computation:
    def __init__(self, name, entry):
        self.name = name
        self.entry = entry
        self.instrs = []


def parse(text):
    comps, cur = {}, None
    order = []
    entry = None
    for raw in text.splitlines():
        line = raw.strip()
        if not line or line.startswith("HloModule"):
            continue
        if cur is None:
            if not line.endswith("{"):
                continue
            name = line[line.find("%") + 1 :].split(" ", 1)[0].split("(", 1)[0]
            cur = Computation(name, line.startswith("ENTRY"))
            continue
        if line == "}":
            comps[cur.name] = cur
            order.append(cur.name)
            if cur.entry:
                entry = cur.name
            cur = None
            continue
        cur.instrs.append(_parse_instr(line))
    return comps, entry or order[-1]


def _dims_attr(v):
    return tuple(int(x) for x in v.strip("{}").split(",") if x.strip())


def _const_value(shape, body):
    dt, dims = shape
    toks = body.replace("{", " ").replace("}", " ").replace(",", " ").split()
    if dt == "pred":
        vals = [t in ("true", "1") for t in toks]
    else:
        vals = [float(t) if dt == "f32" else int(t) for t in toks]
    return np.array(vals, DTYPES[dt]).reshape(dims)


SUPPORTED_SIMPLE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "and", "or", "xor", "shift-left", "shift-right-logical", "not",
    "negate", "exponential", "log", "sqrt", "rsqrt", "abs", "sign", "floor",
    "ceil", "round-nearest-even", "tanh", "logistic", "sine", "cosine",
}


def _seq_dot(a, b):
    # f32 matmul; numpy's pairwise summation differs from the Rust
    # interpreter's sequential loop only in the last ulp, and every
    # fixture quantizes or adds noise downstream of a dot, so matmul is
    # used for speed.  (Parity vectors are generated with the exact
    # sequential loop — see hlo_fixtures.np_mvm_det.)
    return np.matmul(a, b, dtype=F)


class Evaluator:
    def __init__(self, comps, entry):
        self.comps = comps
        self.entry = entry

    def run(self, args):
        return self._eval(self.comps[self.entry], list(args))

    def _eval(self, comp, args):
        env = {}
        root_val = None
        for ins in comp.instrs:
            v = self._eval_instr(comp, ins, env, args)
            env[ins.name] = v
            if ins.root:
                root_val = v
        return root_val if root_val is not None else env[comp.instrs[-1].name]

    def _eval_instr(self, comp, ins, env, args):
        op = ins.op
        A = [env[o] for o in ins.operands]
        if op == "parameter":
            return args[int(ins.const)]
        if op == "constant":
            return _const_value(ins.shape, ins.const)
        if op == "iota":
            dt, dims = ins.shape
            d = int(ins.attrs["iota_dimension"])  # strict, like the Rust parser
            rng = np.arange(dims[d], dtype=DTYPES[dt])
            shape = [1] * len(dims)
            shape[d] = dims[d]
            return np.broadcast_to(rng.reshape(shape), dims).copy()
        if op in SUPPORTED_SIMPLE:
            return self._simple(op, A)
        if op == "compare":
            d = ins.attrs["direction"]
            a, b = A
            return {
                "EQ": a == b, "NE": a != b, "LT": a < b,
                "LE": a <= b, "GT": a > b, "GE": a >= b,
            }[d]
        if op == "select":
            return np.where(A[0], A[1], A[2])
        if op == "clamp":
            return np.clip(A[1], A[0], A[2]).astype(A[1].dtype)
        if op == "convert":
            dt, _ = ins.shape
            if dt in ("s32", "u32"):
                return np.trunc(np.asarray(A[0], F)).astype(DTYPES[dt])
            return np.asarray(A[0]).astype(DTYPES[dt])
        if op == "broadcast":
            dims = _dims_attr(ins.attrs.get("dimensions", "{}"))
            _, out_dims = ins.shape
            src = A[0]
            shape = [1] * len(out_dims)
            for pos, od in enumerate(dims):
                shape[od] = src.shape[pos]
            return np.broadcast_to(src.reshape(shape), out_dims).copy()
        if op == "reshape":
            _, out_dims = ins.shape
            return A[0].reshape(out_dims)
        if op == "transpose":
            return np.transpose(A[0], _dims_attr(ins.attrs["dimensions"])).copy()
        if op == "slice":
            spec = ins.attrs["slice"].strip("{}")
            sl = []
            for part in _split_top(spec):
                nums = part.strip("[]").split(":")
                s, l = int(nums[0]), int(nums[1])
                st = int(nums[2]) if len(nums) > 2 else 1
                sl.append(slice(s, l, st))
            return A[0][tuple(sl)].copy()
        if op == "concatenate":
            return np.concatenate(A, axis=_dims_attr(ins.attrs["dimensions"])[0])
        if op == "pad":
            cfg = []
            interior = False
            for dim in ins.attrs["padding"].split("x"):
                parts = [int(p) for p in dim.split("_")]
                cfg.append((parts[0], parts[1]))
                if len(parts) > 2 and parts[2]:
                    interior = True
            if interior:
                raise HloError("interior padding unsupported")
            return np.pad(A[0], cfg, constant_values=A[1].item()).astype(A[0].dtype)
        if op == "dot":
            lc = _dims_attr(ins.attrs["lhs_contracting_dims"])[0]
            rc = _dims_attr(ins.attrs["rhs_contracting_dims"])[0]
            a = A[0] if lc == 1 else A[0].T
            b = A[1] if rc == 0 else A[1].T
            return _seq_dot(a, b)
        if op == "reduce":
            dims = _dims_attr(ins.attrs["dimensions"])
            sub = self.comps[ins.attrs["to_apply"].lstrip("%")]
            rop = sub.instrs[-1].op
            if rop == "add":
                return np.add.reduce(A[0], axis=dims, dtype=F).astype(F) + A[1]
            if rop == "maximum":
                return np.maximum(np.max(A[0], axis=dims), A[1]).astype(F)
            raise HloError(f"reduce monoid {rop}")
        if op == "tuple":
            return tuple(A)
        if op == "get-tuple-element":
            return A[0][int(ins.attrs["index"])]
        if op == "while":
            cond = self.comps[ins.attrs["condition"].lstrip("%")]
            body = self.comps[ins.attrs["body"].lstrip("%")]
            state = A[0]
            while bool(np.asarray(self._eval(cond, [state])).ravel()[0]):
                state = self._eval(body, [state])
            return state
        raise HloError(f"unsupported op {op}")

    @staticmethod
    def _simple(op, A):
        a = A[0]
        if op in ("add", "subtract", "multiply", "divide", "maximum", "minimum",
                  "power", "and", "or", "xor", "shift-left",
                  "shift-right-logical"):
            b = A[1]
            if a.dtype == np.uint32:
                with np.errstate(over="ignore"):
                    if op == "add":
                        return a + b
                    if op == "subtract":
                        return a - b
                    if op == "multiply":
                        return a * b
                    if op == "and":
                        return a & b
                    if op == "or":
                        return a | b
                    if op == "xor":
                        return a ^ b
                    if op == "shift-left":
                        return (a.astype(np.uint64) << b.astype(np.uint64)).astype(
                            np.uint32
                        )
                    if op == "shift-right-logical":
                        return a >> b
            if a.dtype == np.bool_:
                return {"and": a & b, "or": a | b, "xor": a ^ b}[op]
            f = {
                "add": np.add, "subtract": np.subtract, "multiply": np.multiply,
                "divide": np.divide, "maximum": np.maximum, "minimum": np.minimum,
                "power": np.power, "xor": np.bitwise_xor, "and": np.bitwise_and,
                "or": np.bitwise_or,
            }[op]
            with np.errstate(divide="ignore", invalid="ignore", over="ignore"):
                return f(a, b).astype(a.dtype)
        un = {
            "negate": np.negative, "exponential": np.exp, "log": np.log,
            "sqrt": np.sqrt, "abs": np.abs, "sign": np.sign, "floor": np.floor,
            "ceil": np.ceil, "round-nearest-even": np.rint, "tanh": np.tanh,
            "sine": np.sin, "cosine": np.cos,
            "rsqrt": lambda x: (F(1.0) / np.sqrt(x)).astype(F),
            "logistic": lambda x: (F(1.0) / (F(1.0) + np.exp(-x))).astype(F),
            "not": np.logical_not,
        }[op]
        with np.errstate(divide="ignore", invalid="ignore", over="ignore"):
            r = un(a)
        return r.astype(a.dtype) if a.dtype != np.bool_ else r


def load(path):
    comps, entry = parse(open(path).read())
    return Evaluator(comps, entry)
