"""AOT compilation: lower every model x function to HLO text artifacts.

Emits, per model m in {fcn, lenet, convnet3}:

  m_init          (key, params[3]=[ref_mean, ref_std, sigma_gamma]) -> state
  m_step_<algo>   (state.., x, labels, key, hypers[12], dev[8]) -> state.., loss
                  for algo in {sgd, ttv1, ttv2, agad, erider, digital}
  m_eval          (state.., x, labels, key, hypers, dev) -> loss, ncorrect
  m_eval_digital  (state.., x, labels)                   -> loss, ncorrect
  m_zs            (state.., n, key, dev) -> state..      (Algorithm 1)

plus artifacts/manifest.json (shapes/dtypes/roles for the Rust runtime)
and artifacts/parity.json (deterministic kernel test vectors for the Rust
device-substrate parity tests).

HLO *text* is the interchange format, NOT serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version the published `xla` crate binds) rejects; the text
parser reassigns ids and round-trips cleanly. See /opt/xla-example.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import algorithms as A
from . import model as M
from . import state as S
from .kernels import ref

BATCH = 16
EVAL_BATCH = 200


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _io_entry(name, sds):
    dt = {jnp.float32.dtype: "f32", jnp.int32.dtype: "i32", jnp.uint32.dtype: "u32"}[
        sds.dtype
    ]
    return {"name": name, "shape": list(sds.shape), "dtype": dt}


class Emitter:
    def __init__(self, out_dir):
        self.out_dir = out_dir
        self.manifest = {"models": {}, "artifacts": {}, "hyper_index": {}, "dev_index": {}}
        self.manifest["hyper_index"] = {
            "lr_fast": 0, "lr_transfer": 1, "eta": 2, "gamma": 3,
            "flip_p": 4, "thresh": 5, "lr_digital": 6, "read_noise": 7,
            "n_hypers": A.N_HYPERS,
        }
        self.manifest["dev_index"] = {
            "dw_min": 0, "sigma_c2c": 1, "tau_max": 2, "tau_min": 3,
            "out_noise": 4, "inp_res": 5, "out_res": 6, "out_bound": 7,
            "n_dev": A.N_DEV,
        }

    def emit(self, name, fn, in_specs, in_names, out_names):
        lowered = jax.jit(fn, keep_unused=True).lower(*in_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        outs = jax.eval_shape(fn, *in_specs)
        self.manifest["artifacts"][name] = {
            "file": fname,
            "inputs": [_io_entry(n, s) for n, s in zip(in_names, in_specs)],
            "outputs": [_io_entry(n, s) for n, s in zip(out_names, outs)],
        }
        print(f"  {name}: {len(text)/1e3:.0f} kB hlo, {len(in_specs)} in / {len(outs)} out")


def emit_model(em: Emitter, mname: str):
    spec = M.MODELS[mname]
    st_specs = S.abstract_state(spec)
    st_names = [n for n, _, _, _ in S.leaf_specs(spec)]
    em.manifest["models"][mname] = {
        "batch": BATCH,
        "eval_batch": EVAL_BATCH,
        "d_in": spec.d_in,
        "n_classes": spec.n_classes,
        "state": [
            {"name": n, "shape": list(sh), "role": role, "tile": ti}
            for n, sh, role, ti in S.leaf_specs(spec)
        ],
    }
    key_s = _sds((2,), jnp.uint32)
    hyp_s = _sds((A.N_HYPERS,))
    dev_s = _sds((A.N_DEV,))
    x_s = _sds((BATCH, spec.d_in))
    y_s = _sds((BATCH,), jnp.int32)
    ex_s = _sds((EVAL_BATCH, spec.d_in))
    ey_s = _sds((EVAL_BATCH,), jnp.int32)

    # ---- init
    def init_fn(key, params):
        tiles, biases = M.init_state(spec, key, params[0], params[1], params[2])
        return tuple(S.flatten(tiles, biases))

    em.emit(
        f"{mname}_init", init_fn, [key_s, _sds((3,))], ["key", "params"], st_names
    )

    # ---- steps
    for algo, step in A.STEPS.items():
        def step_fn(*args, _step=step):
            flat = args[: len(st_specs)]
            x, labels, key, hypers, dev = args[len(st_specs):]
            tiles, biases = S.unflatten(spec, list(flat))
            t2, b2, loss = _step(spec, tiles, biases, x, labels, key, hypers, dev)
            return tuple(S.flatten(t2, b2)) + (loss,)

        em.emit(
            f"{mname}_step_{algo}",
            step_fn,
            st_specs + [x_s, y_s, key_s, hyp_s, dev_s],
            st_names + ["x", "labels", "key", "hypers", "dev"],
            st_names + ["loss"],
        )

    # ---- eval (analog, at the effective weights) and digital eval
    def eval_fn(*args):
        flat = args[: len(st_specs)]
        x, labels, key, hypers, dev = args[len(st_specs):]
        tiles, biases = S.unflatten(spec, list(flat))
        loss = M.loss_fn(
            spec, tiles, biases, x, labels, key, dev, "residual", hypers[A.GAMMA]
        )
        ncorr = M.accuracy_count(
            spec, tiles, biases, x, labels, jax.random.fold_in(key, 99), dev,
            "residual", hypers[A.GAMMA],
        )
        return loss, ncorr

    em.emit(
        f"{mname}_eval",
        eval_fn,
        st_specs + [ex_s, ey_s, key_s, hyp_s, dev_s],
        st_names + ["x", "labels", "key", "hypers", "dev"],
        ["loss", "ncorrect"],
    )

    def eval_dig_fn(*args):
        flat = args[: len(st_specs)]
        x, labels = args[len(st_specs):]
        tiles, biases = S.unflatten(spec, list(flat))
        key = jax.random.PRNGKey(0)
        dev = jnp.zeros((A.N_DEV,))
        loss = M.loss_fn(spec, tiles, biases, x, labels, key, dev, "digital", 0.0)
        ncorr = M.accuracy_count(
            spec, tiles, biases, x, labels, key, dev, "digital", 0.0
        )
        return loss, ncorr

    em.emit(
        f"{mname}_eval_digital",
        eval_dig_fn,
        st_specs + [ex_s, ey_s],
        st_names + ["x", "labels"],
        ["loss", "ncorrect"],
    )

    # ---- ZS calibration (dynamic pulse budget)
    def zs_fn(*args):
        flat = args[: len(st_specs)]
        n, key, dev = args[len(st_specs):]
        tiles, biases = S.unflatten(spec, list(flat))
        t2 = A.zs_calibrate(spec, tiles, n, key, dev)
        return tuple(S.flatten(t2, biases))

    em.emit(
        f"{mname}_zs",
        zs_fn,
        st_specs + [_sds((), jnp.uint32), key_s, dev_s],
        st_names + ["n", "key", "dev"],
        st_names,
    )


def emit_parity(out_dir):
    """Deterministic kernel test vectors for the Rust device substrate."""
    rng = np.random.default_rng(1234)
    cases = []
    for dw_min in (0.4622, 0.0949, 1e-3):
        shape = (4, 9)
        w = rng.uniform(-0.9, 0.9, shape).astype(np.float32)
        dw = rng.uniform(-0.3, 0.3, shape).astype(np.float32)
        gamma = np.exp(0.2 * rng.standard_normal(shape)).astype(np.float32)
        wsp = rng.uniform(-0.5, 0.5, shape).astype(np.float32)
        ap = np.maximum(gamma * (1 + wsp), 0.05).astype(np.float32)
        am = np.maximum(gamma * (1 - wsp), 0.05).astype(np.float32)
        z = np.zeros(shape, np.float32)
        out = ref.ref_pulse_update(
            jnp.array(w), jnp.array(dw), jnp.array(ap), jnp.array(am),
            jnp.array(z), jnp.array(z), dw_min=dw_min, sigma_c2c=0.0,
            deterministic=True,
        )
        cases.append(
            {
                "kind": "pulse_update",
                "dw_min": dw_min,
                "w": w.ravel().tolist(),
                "dw": dw.ravel().tolist(),
                "alpha_p": ap.ravel().tolist(),
                "alpha_m": am.ravel().tolist(),
                "rows": shape[0],
                "cols": shape[1],
                "expected": np.asarray(out).ravel().tolist(),
            }
        )
    # analog MVM, deterministic (quantization only)
    for b, k, n in ((3, 7, 5), (8, 16, 4)):
        x = rng.uniform(-2, 2, (b, k)).astype(np.float32)
        w = rng.uniform(-1, 1, (k, n)).astype(np.float32)
        z = np.zeros((b, n), np.float32)
        y = ref.ref_analog_mvm(jnp.array(x), jnp.array(w), jnp.array(z),
                               deterministic=True)
        cases.append(
            {
                "kind": "analog_mvm",
                "x": x.ravel().tolist(),
                "w": w.ravel().tolist(),
                "b": b, "k": k, "n": n,
                "expected": np.asarray(y).ravel().tolist(),
            }
        )
    with open(os.path.join(out_dir, "parity.json"), "w") as f:
        json.dump({"cases": cases}, f)
    print(f"  parity.json: {len(cases)} cases")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default="fcn,lenet,convnet3")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    em = Emitter(args.out)
    # Merge with an existing manifest so partial --models runs compose.
    man_path = os.path.join(args.out, "manifest.json")
    if os.path.exists(man_path):
        old = json.load(open(man_path))
        em.manifest["models"].update(old.get("models", {}))
        em.manifest["artifacts"].update(old.get("artifacts", {}))
    for mname in args.models.split(","):
        print(f"model {mname}:")
        emit_model(em, mname)
    emit_parity(args.out)
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(em.manifest, f, indent=1)
    print("manifest.json written")


if __name__ == "__main__":
    main()
