"""Flat-state interface between the JAX pytrees and the Rust coordinator.

The Rust runtime sees a model's training state as a flat, ordered list of
f32 arrays. This module defines that order, converts in both directions,
and produces the manifest entries that let Rust address leaves by role
(e.g. find every `w` leaf when deploying a digitally pre-trained
checkpoint onto the analog arrays).
"""

from __future__ import annotations

import jax.numpy as jnp

from . import model as M

# Fixed per-tile leaf order. Rust indexes state by this.
TILE_LEAVES = ("w", "p", "q", "h", "wap", "wam", "pap", "pam", "c")


def leaf_specs(spec):
    """[(name, shape, role, tile_index)] for a model's flat state."""
    out = []
    for i, layer in enumerate(spec.layers):
        kdim, n = M.tile_shape(layer)
        for leaf in TILE_LEAVES:
            shape = (kdim, 1) if leaf == "c" else (kdim, n)
            out.append((f"t{i}.{leaf}", shape, leaf, i))
    for i, layer in enumerate(spec.layers):
        _, n = M.tile_shape(layer)
        out.append((f"b{i}", (n,), "bias", i))
    return out


def flatten(tiles, biases):
    flat = []
    for t in tiles:
        for leaf in TILE_LEAVES:
            flat.append(t[leaf])
    flat.extend(biases)
    return flat


def unflatten(spec, flat):
    n_tiles = len(spec.layers)
    tiles = []
    idx = 0
    for _ in range(n_tiles):
        t = {}
        for leaf in TILE_LEAVES:
            t[leaf] = flat[idx]
            idx += 1
        tiles.append(t)
    biases = list(flat[idx : idx + n_tiles])
    assert idx + n_tiles == len(flat)
    return tiles, biases


def state_len(spec):
    return len(spec.layers) * (len(TILE_LEAVES) + 1)


def abstract_state(spec):
    """ShapeDtypeStructs for the flat state (for jit.lower)."""
    import jax

    return [
        jax.ShapeDtypeStruct(shape, jnp.float32) for _, shape, _, _ in leaf_specs(spec)
    ]
