"""JAX-side analog device model (paper Appendix F.1).

The SoftBoundsReference family: per-cell potentiation/depression slopes
(alpha_p, alpha_m) = (gamma + rho, gamma - rho), device-to-device sampled.
The symmetric point (SP, Definition 1.1) of a cell is the weight where
q_plus = q_minus; with tau = 1 it is exactly rho / gamma, so we *control*
the SP distribution of a simulated array (the paper's "reference mean /
reference std" sweeps) by sampling w_sp ~ N(ref_mean, ref_std) and setting
rho = gamma * w_sp.

Two hardware presets are mirrored from AIHWKit (paper Table 3); the Rust
substrate (`rust/src/device/presets.rs`) carries the same numbers and is
parity-tested against this module.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .kernels import ref

# ---------------------------------------------------------------- presets

# Paper Table 3. `dw_min` is the response granularity; `d2d` the
# device-to-device asymmetry spread; `c2c` the cycle-to-cycle write noise.
PRESETS = {
    # HfO2-based ReRAM (Gong et al., 2022) — ~4-5 conductance states.
    "hfo2": dict(tau_min=1.0, tau_max=1.0, dw_min=0.4622, d2d=0.7125, c2c=0.2174),
    # ReRamArrayOM preset — ~21 states.
    "om": dict(tau_min=1.0, tau_max=1.0, dw_min=0.0949, d2d=0.7829, c2c=0.4158),
    # High-precision device used in the Fig. 1 pulse-complexity study.
    "precise": dict(tau_min=1.0, tau_max=1.0, dw_min=0.001, d2d=0.7125, c2c=0.2174),
    # Idealized symmetric device (for digital-parity sanity checks).
    "ideal": dict(tau_min=1.0, tau_max=1.0, dw_min=1e-5, d2d=0.0, c2c=0.0),
}


@dataclasses.dataclass(frozen=True)
class IoConfig:
    """Analog IO chain parameters (paper Table 7)."""

    inp_res: float = 1.0 / 127.0   # 7-bit DAC
    out_res: float = 1.0 / 511.0   # 9-bit ADC
    out_bound: float = 12.0
    out_noise: float = 0.06


def sample_device(key, shape, ref_mean, ref_std, sigma_gamma=0.1, tau=1.0):
    """Sample per-cell (alpha_p, alpha_m) with a controlled SP distribution.

    Args:
      key: PRNG key.
      shape: tile shape.
      ref_mean / ref_std: SP distribution parameters (scalars, traced OK).
      sigma_gamma: lognormal spread of the common slope magnitude.

    Returns (alpha_p, alpha_m); both positive (training-friendly,
    Definition 2.1).
    """
    k1, k2 = jax.random.split(key)
    gamma = jnp.exp(sigma_gamma * jax.random.normal(k1, shape))
    w_sp = ref_mean + ref_std * jax.random.normal(k2, shape)
    # Keep the SP strictly inside the conductance window.
    w_sp = jnp.clip(w_sp, -0.85 * tau, 0.85 * tau)
    rho = gamma * w_sp / tau
    alpha_p = gamma + rho
    alpha_m = gamma - rho
    # Positive-definiteness (Definition 2.1): floor the slopes.
    floor = 0.05
    return jnp.maximum(alpha_p, floor), jnp.maximum(alpha_m, floor)


def symmetric_point(alpha_p, alpha_m, tau_max=1.0, tau_min=1.0):
    """Ground-truth per-cell SP (see kernels.ref.symmetric_point)."""
    return ref.symmetric_point(alpha_p, alpha_m, tau_max, tau_min)
