"""Hermetic HLO-text fixture emitter (numpy-only, no JAX required).

Emits the same artifact contract as `aot.py` — per model m in
{fcn, lenet, convnet3}:

  m_init          (key, params[3]) -> state
  m_step_<algo>   (state.., x, labels, key, hypers[12], dev[8]) -> state.., loss
  m_eval          (state.., x, labels, key, hypers, dev) -> loss, ncorrect
  m_eval_digital  (state.., x, labels)                   -> loss, ncorrect
  m_zs            (state.., n, key, dev) -> state..      (Algorithm 1)

plus op-level kernel artifacts (`kernel_pulse_update_det`,
`kernel_analog_mvm_det_<b>x<k>x<n>`), `manifest.json` and `parity.json`
— but as *hand-lowered* HLO text over the op set the pure-Rust
interpreter (`rust/src/runtime/interp.rs`) supports, so CI needs no
Python/JAX at all. `aot.py` (JAX) remains the authoritative lowering
when a JAX toolchain is available; this module is the hermetic
fallback with the same input/output contract and the same device
semantics (`kernels/ref.py` formulas, transcribed to HLO and to the
numpy parity port below).

RNG: artifacts draw randomness from a counter-hash (murmur3 finalizer
over iota ^ key, unique salt per draw site) — uniform via the top 24
bits, normals via Box-Muller. Not threefry, but deterministic per
(key, site) and statistically adequate for the training noise model.

Regenerate with:  python3 -m python.compile.hlo_fixtures --out artifacts
Verify with:      python3 -m python.compile.validate_fixtures
"""

from __future__ import annotations

import argparse
import json
import os
from math import prod, sqrt

import numpy as np

BATCH = 16
EVAL_BATCH = 200
N_HYPERS = 12
N_DEV = 8

HYPER_INDEX = {
    "lr_fast": 0, "lr_transfer": 1, "eta": 2, "gamma": 3,
    "flip_p": 4, "thresh": 5, "lr_digital": 6, "read_noise": 7,
}
DEV_INDEX = {
    "dw_min": 0, "sigma_c2c": 1, "tau_max": 2, "tau_min": 3,
    "out_noise": 4, "inp_res": 5, "out_res": 6, "out_bound": 7,
}

TILE_LEAVES = ("w", "p", "q", "h", "wap", "wam", "pap", "pam", "c")
STEP_ALGOS = ("sgd", "ttv1", "ttv2", "agad", "erider", "digital")


def fmt_f32(v) -> str:
    f = np.float32(v)
    if np.isinf(f):
        return "-inf" if f < 0 else "inf"
    return repr(f.item()) if f != int(f) or abs(f) > 1e16 else str(int(f))


def fmt_ty(dt, shape) -> str:
    return f"{dt}[{','.join(str(d) for d in shape)}]"


class T:
    """Handle to an emitted HLO value."""

    __slots__ = ("name", "shape", "dt", "tystr")

    def __init__(self, name, shape, dt):
        self.name = name
        self.shape = tuple(shape)
        self.dt = dt
        self.tystr = None


class Comp:
    """One HLO computation under construction."""

    def __init__(self, mod, cname, entry=False):
        self.mod = mod
        self.cname = cname
        self.entry = entry
        self.lines = []  # (name, text)
        self.n = 0
        self.params = []  # (name, tystr)
        self.root_name = None
        self.root_ty = None

    # -- plumbing ------------------------------------------------------
    def _emit(self, name, text):
        self.lines.append((name, text))

    def ins(self, shape, dt, expr) -> T:
        self.n += 1
        name = f"%v{self.n}"
        self._emit(name, f"{name} = {fmt_ty(dt, shape)} {expr}")
        return T(name, shape, dt)

    def param(self, idx, shape, dt) -> T:
        name = f"%p{idx}"
        ty = fmt_ty(dt, shape)
        self._emit(name, f"{name} = {ty} parameter({idx})")
        self.params.append((name, ty))
        return T(name, shape, dt)

    def param_tuple(self, idx, tystr) -> T:
        name = f"%p{idx}"
        self._emit(name, f"{name} = {tystr} parameter({idx})")
        self.params.append((name, tystr))
        t = T(name, (), "tuple")
        t.tystr = tystr  # type: ignore[attr-defined]
        return t

    def set_root(self, t: T, tystr=None):
        self.root_name = t.name
        self.root_ty = tystr or getattr(t, "tystr", None) or fmt_ty(t.dt, t.shape)

    def render(self) -> str:
        head = "ENTRY %main" if self.entry else f"%{self.cname}"
        plist = ", ".join(f"{n.lstrip('%')}: {ty}" for n, ty in self.params)
        out = [f"{head} ({plist}) -> {self.root_ty} {{"]
        for name, text in self.lines:
            pre = "ROOT " if name == self.root_name else ""
            out.append(f"  {pre}{text}")
        out.append("}")
        return "\n".join(out)

    # -- ops -----------------------------------------------------------
    def const(self, v, dt="f32") -> T:
        if dt == "f32":
            lit = fmt_f32(v)
        elif dt in ("s32", "u32"):
            lit = str(int(v))
        else:
            lit = "true" if v else "false"
        return self.ins((), dt, f"constant({lit})")

    def constv(self, vals, dt="f32") -> T:
        if dt == "f32":
            lit = ", ".join(fmt_f32(v) for v in vals)
        else:
            lit = ", ".join(str(int(v)) for v in vals)
        return self.ins((len(vals),), dt, f"constant({{{lit}}})")

    def bin(self, op, a: T, b: T) -> T:
        assert a.shape == b.shape and a.dt == b.dt, (op, a.shape, b.shape, a.dt, b.dt)
        return self.ins(a.shape, a.dt, f"{op}({a.name}, {b.name})")

    def add(self, a, b):
        return self.bin("add", a, b)

    def sub(self, a, b):
        return self.bin("subtract", a, b)

    def mul(self, a, b):
        return self.bin("multiply", a, b)

    def div(self, a, b):
        return self.bin("divide", a, b)

    def maximum(self, a, b):
        return self.bin("maximum", a, b)

    def un(self, op, a: T) -> T:
        return self.ins(a.shape, a.dt, f"{op}({a.name})")

    def neg(self, a):
        return self.un("negate", a)

    def exp(self, a):
        return self.un("exponential", a)

    def log(self, a):
        return self.un("log", a)

    def sqrt(self, a):
        return self.un("sqrt", a)

    def absu(self, a):
        return self.un("abs", a)

    def sign(self, a):
        return self.un("sign", a)

    def floor(self, a):
        return self.un("floor", a)

    def round(self, a):
        return self.un("round-nearest-even", a)

    def tanh(self, a):
        return self.un("tanh", a)

    def logistic(self, a):
        return self.un("logistic", a)

    def cos(self, a):
        return self.un("cosine", a)

    def bcast(self, a: T, shape, dims=()) -> T:
        d = ",".join(str(x) for x in dims)
        return self.ins(shape, a.dt, f"broadcast({a.name}), dimensions={{{d}}}")

    def bs(self, s: T, shape) -> T:
        """Broadcast a scalar."""
        assert s.shape == ()
        return self.bcast(s, shape, ())

    def bvec(self, v: T, shape, dim) -> T:
        """Broadcast a rank-1 tensor along output dim `dim`."""
        assert len(v.shape) == 1 and shape[dim] == v.shape[0]
        return self.bcast(v, shape, (dim,))

    def full(self, shape, v, dt="f32") -> T:
        c = self.const(v, dt)
        return self.bs(c, shape) if shape != () else c

    def fulllike(self, a: T, v) -> T:
        return self.full(a.shape, v, a.dt)

    def mulc(self, a: T, v) -> T:
        return self.mul(a, self.fulllike(a, v))

    def addc(self, a: T, v) -> T:
        return self.add(a, self.fulllike(a, v))

    def reshape(self, a: T, shape) -> T:
        assert prod(a.shape) == prod(shape), (a.shape, shape)
        return self.ins(shape, a.dt, f"reshape({a.name})")

    def transpose(self, a: T, perm) -> T:
        shape = tuple(a.shape[p] for p in perm)
        d = ",".join(str(p) for p in perm)
        return self.ins(shape, a.dt, f"transpose({a.name}), dimensions={{{d}}}")

    def slice(self, a: T, starts, limits) -> T:
        shape = tuple(l - s for s, l in zip(starts, limits))
        spec = ",".join(f"[{s}:{l}:1]" for s, l in zip(starts, limits))
        return self.ins(shape, a.dt, f"slice({a.name}), slice={{{spec}}}")

    def concat(self, parts, dim) -> T:
        shape = list(parts[0].shape)
        shape[dim] = sum(p.shape[dim] for p in parts)
        names = ", ".join(p.name for p in parts)
        return self.ins(
            tuple(shape), parts[0].dt, f"concatenate({names}), dimensions={{{dim}}}"
        )

    def pad(self, a: T, v, cfg) -> T:
        """cfg: [(lo, hi)] per dim; `v` the scalar pad value."""
        pv = self.const(v, a.dt)
        shape = tuple(d + lo + hi for d, (lo, hi) in zip(a.shape, cfg))
        spec = "x".join(f"{lo}_{hi}" for lo, hi in cfg)
        return self.ins(shape, a.dt, f"pad({a.name}, {pv.name}), padding={spec}")

    def dot(self, a: T, b: T) -> T:
        assert a.shape[1] == b.shape[0], (a.shape, b.shape)
        shape = (a.shape[0], b.shape[1])
        return self.ins(
            shape,
            "f32",
            f"dot({a.name}, {b.name}), lhs_contracting_dims={{1}}, "
            f"rhs_contracting_dims={{0}}",
        )

    def cmpd(self, direction, a: T, b: T) -> T:
        assert a.shape == b.shape
        return self.ins(
            a.shape, "pred", f"compare({a.name}, {b.name}), direction={direction}"
        )

    def sel(self, p: T, a: T, b: T) -> T:
        return self.ins(a.shape, a.dt, f"select({p.name}, {a.name}, {b.name})")

    def clamps(self, lo: T, x: T, hi: T) -> T:
        return self.ins(x.shape, x.dt, f"clamp({lo.name}, {x.name}, {hi.name})")

    def clampc(self, lo_v, x: T, hi_v) -> T:
        return self.clamps(self.const(lo_v), x, self.const(hi_v))

    def convert(self, a: T, dt) -> T:
        return self.ins(a.shape, dt, f"convert({a.name})")

    def iota(self, shape, dim, dt) -> T:
        return self.ins(shape, dt, f"iota(), iota_dimension={dim}")

    def reduce(self, a: T, dims, kind="add") -> T:
        init = {"add": 0.0, "max": float("-inf")}[kind]
        red = self.mod.reducer(kind)
        iv = self.const(init)
        shape = tuple(d for i, d in enumerate(a.shape) if i not in dims)
        ds = ",".join(str(d) for d in sorted(dims))
        return self.ins(
            shape,
            a.dt,
            f"reduce({a.name}, {iv.name}), dimensions={{{ds}}}, to_apply=%{red}",
        )

    def tuple_(self, parts) -> T:
        names = ", ".join(p.name for p in parts)
        tystr = (
            "(" + ", ".join(getattr(p, "tystr", None) or fmt_ty(p.dt, p.shape)
                            for p in parts) + ")"
        )
        t = self.ins((), "tuple", f"tuple({names})")
        # rewrite the emitted type (ins printed a scalar type)
        name, text = self.lines[-1]
        self.lines[-1] = (name, f"{name} = {tystr} tuple({names})")
        t.tystr = tystr  # type: ignore[attr-defined]
        return t

    def gte(self, t: T, index, shape, dt) -> T:
        return self.ins(
            shape, dt, f"get-tuple-element({t.name}), index={index}"
        )

    def while_(self, init: T, cond: "Comp", body: "Comp") -> T:
        tystr = init.tystr  # type: ignore[attr-defined]
        t = self.ins((), "tuple", "noop()")
        name, _ = self.lines[-1]
        self.lines[-1] = (
            name,
            f"{name} = {tystr} while({init.name}), condition=%{cond.cname}, "
            f"body=%{body.cname}",
        )
        t.tystr = tystr  # type: ignore[attr-defined]
        return t

    def scalar_at(self, vec: T, i) -> T:
        """Extract element i of a rank-1 tensor as a scalar."""
        return self.reshape(self.slice(vec, (i,), (i + 1,)), ())


class Module:
    def __init__(self, name):
        self.name = name
        self.comps = []
        self.entry = Comp(self, "main", entry=True)
        self.salt = 0
        self._red = {}

    def next_salt(self):
        self.salt += 1
        return (self.salt * 2654435761) % (1 << 32)

    def subcomp(self, cname) -> Comp:
        c = Comp(self, cname)
        self.comps.append(c)
        return c

    def reducer(self, kind):
        if kind not in self._red:
            c = self.subcomp(f"red_{kind}")
            a = c.param(0, (), "f32")
            b = c.param(1, (), "f32")
            c.set_root(c.bin({"add": "add", "max": "maximum"}[kind], a, b))
            self._red[kind] = c.cname
        return self._red[kind]

    def render(self) -> str:
        parts = [f"HloModule {self.name}", ""]
        for c in self.comps:
            parts.append(c.render())
            parts.append("")
        parts.append(self.entry.render())
        parts.append("")
        return "\n".join(parts)


# ------------------------------------------------------------------- RNG


class RngCtx:
    """Counter-hash RNG: murmur3 finalizer over (iota ^ k0) with a
    per-site salt and the key's second word; `extra` (e.g. a loop
    counter) decorrelates draws across while-loop iterations."""

    def __init__(self, comp: Comp, mod: Module, k0: T, k1: T, extra: T | None = None):
        self.c = comp
        self.mod = mod
        self.k0 = k0
        self.k1 = k1
        self.extra = extra

    def u32(self, shape) -> T:
        c = self.c
        n = prod(shape)
        salt = self.mod.next_salt()
        x = c.iota((n,), 0, "u32")
        x = c.bin("xor", x, c.bs(self.k0, (n,)))
        x = c.bin("multiply", x, c.full((n,), 2654435761, "u32"))
        s = c.bin("xor", self.k1, c.const(salt, "u32"))
        if self.extra is not None:
            s = c.bin(
                "add",
                s,
                c.bin("multiply", self.extra, c.const(0x9E3779B9, "u32")),
            )
        x = c.bin("add", x, c.bs(s, (n,)))
        for sh, m in ((16, 0x85EBCA6B), (13, 0xC2B2AE35)):
            x = c.bin("xor", x, c.bin("shift-right-logical", x, c.full((n,), sh, "u32")))
            x = c.bin("multiply", x, c.full((n,), m, "u32"))
        x = c.bin("xor", x, c.bin("shift-right-logical", x, c.full((n,), 16, "u32")))
        return c.reshape(x, shape) if shape != (n,) else x

    def uniform(self, shape) -> T:
        """u ~ U[0, 1) from the hash's top 24 bits."""
        c = self.c
        h = self.u32(shape)
        top = c.bin("shift-right-logical", h, c.full(shape, 8, "u32"))
        return c.mulc(c.convert(top, "f32"), 1.0 / (1 << 24))

    def uniform_open(self, shape) -> T:
        """u ~ U(0, 1] (safe for log)."""
        c = self.c
        h = self.u32(shape)
        top = c.bin("shift-right-logical", h, c.full(shape, 8, "u32"))
        top = c.bin("add", top, c.full(shape, 1, "u32"))
        return c.mulc(c.convert(top, "f32"), 1.0 / (1 << 24))

    def normal(self, shape) -> T:
        """z ~ N(0, 1) via Box-Muller."""
        c = self.c
        u1 = self.uniform_open(shape)
        u2 = self.uniform(shape)
        r = c.sqrt(c.mulc(c.log(u1), -2.0))
        return c.mul(r, c.cos(c.mulc(u2, 2.0 * np.pi)))


# --------------------------------------------------------- device kernels


def dev_scalars(c: Comp, dev: T) -> dict:
    return {k: c.scalar_at(dev, i) for k, i in DEV_INDEX.items()}


def hyp_scalars(c: Comp, hyp: T) -> dict:
    return {k: c.scalar_at(hyp, i) for k, i in HYPER_INDEX.items()}


def pulse(c: Comp, rng: RngCtx, w: T, dw: T, ap: T, am: T, dev: dict, det=False) -> T:
    """Analog Update (kernels/ref.py `ref_pulse_update`)."""
    sh = w.shape
    one = c.fulllike(w, 1.0)
    qp = c.mul(ap, c.sub(one, c.div(w, c.bs(dev["tau_max"], sh))))
    qm = c.mul(am, c.add(one, c.div(w, c.bs(dev["tau_min"], sh))))
    pos = c.cmpd("GE", dw, c.fulllike(dw, 0.0))
    q = c.maximum(c.sel(pos, qp, qm), c.fulllike(w, 0.0))
    mag = c.absu(dw)
    sgn = c.sign(dw)
    dwm = c.bs(dev["dw_min"], sh)
    pf = c.div(mag, dwm)
    if det:
        n = c.round(pf)
        delta = c.mul(c.mul(sgn, c.mul(n, dwm)), q)
    else:
        n_lo = c.floor(pf)
        frac = c.sub(pf, n_lo)
        u = rng.uniform(sh)
        n = c.add(n_lo, c.convert(c.cmpd("LT", u, frac), "f32"))
        z = rng.normal(sh)
        c2c = c.mul(c.mul(c.sqrt(n), dwm), c.bs(dev["sigma_c2c"], sh))
        delta = c.mul(c.mul(sgn, c.add(c.mul(n, dwm), c.mul(c2c, z))), q)
    return c.clamps(c.neg(dev["tau_min"]), c.add(w, delta), dev["tau_max"])


def analog_mvm(c: Comp, rng: RngCtx | None, x: T, w: T, dev: dict, det=False) -> T:
    """Analog IO chain MVM (kernels/ref.py `ref_analog_mvm`)."""
    b, k = x.shape
    n = w.shape[1]
    scale = c.reduce(c.absu(x), (1,), "max")  # [B]
    gt = c.cmpd("GT", scale, c.full((b,), 0.0))
    scale = c.sel(gt, scale, c.full((b,), 1.0))
    xn = c.div(x, c.bvec(scale, (b, k), 0))
    ir = c.bs(dev["inp_res"], (b, k))
    xq = c.mul(c.round(c.div(xn, ir)), ir)
    y = c.dot(xq, w)
    if not det:
        y = c.add(y, c.mul(c.bs(dev["out_noise"], (b, n)), rng.normal((b, n))))
    orr = c.bs(dev["out_res"], (b, n))
    yq = c.mul(c.round(c.div(y, orr)), orr)
    yq = c.clamps(c.neg(dev["out_bound"]), yq, dev["out_bound"])
    return c.mul(yq, c.bvec(scale, (b, n), 0))


def read_noisy(c: Comp, rng: RngCtx, arr: T, read_noise: T) -> T:
    return c.add(arr, c.mul(c.bs(read_noise, arr.shape), rng.normal(arr.shape)))


# ----------------------------------------------------------- model specs


def model_spec(name):
    if name == "fcn":
        layers = [
            dict(kind="fc", k=784, n=256, act="sigmoid"),
            dict(kind="fc", k=256, n=128, act="sigmoid"),
            dict(kind="fc", k=128, n=10, act="none"),
        ]
        return dict(name=name, d_in=784, n_classes=10, input=(784,), layers=layers)
    if name == "lenet":
        layers = [
            dict(kind="conv", cin=1, cout=8, ksz=5, pad=0, pool=2, act="tanh",
                 h=28, w=28),
            dict(kind="conv", cin=8, cout=16, ksz=5, pad=0, pool=2, act="tanh",
                 h=12, w=12),
            dict(kind="fc", k=256, n=128, act="tanh"),
            dict(kind="fc", k=128, n=10, act="none"),
        ]
        return dict(name=name, d_in=784, n_classes=10, input=(1, 28, 28), layers=layers)
    if name == "convnet3":
        layers = [
            dict(kind="conv", cin=3, cout=16, ksz=3, pad=1, pool=2, act="tanh",
                 h=16, w=16),
            dict(kind="conv", cin=16, cout=32, ksz=3, pad=1, pool=2, act="tanh",
                 h=8, w=8),
            dict(kind="fc", k=512, n=64, act="tanh"),
            dict(kind="fc", k=64, n=10, act="none"),
        ]
        return dict(name=name, d_in=768, n_classes=10, input=(3, 16, 16),
                    layers=layers)
    raise ValueError(name)


def tile_shape(layer):
    if layer["kind"] == "fc":
        return (layer["k"], layer["n"])
    return (layer["cin"] * layer["ksz"] * layer["ksz"], layer["cout"])


def conv_geom(layer):
    k, p = layer["ksz"], layer["pad"]
    ho = layer["h"] + 2 * p - k + 1
    wo = layer["w"] + 2 * p - k + 1
    return ho, wo


def leaf_specs(spec):
    out = []
    for i, layer in enumerate(spec["layers"]):
        kdim, n = tile_shape(layer)
        for leaf in TILE_LEAVES:
            shape = (kdim, 1) if leaf == "c" else (kdim, n)
            out.append((f"t{i}.{leaf}", shape, leaf, i))
    for i, layer in enumerate(spec["layers"]):
        _, n = tile_shape(layer)
        out.append((f"b{i}", (n,), "bias", i))
    return out


def state_params(c: Comp, spec, start=0):
    """Declare the flat state as parameters; returns (tiles, biases)."""
    tiles = []
    idx = start
    for layer in spec["layers"]:
        kdim, n = tile_shape(layer)
        t = {}
        for leaf in TILE_LEAVES:
            shape = (kdim, 1) if leaf == "c" else (kdim, n)
            t[leaf] = c.param(idx, shape, "f32")
            idx += 1
        tiles.append(t)
    biases = []
    for layer in spec["layers"]:
        _, n = tile_shape(layer)
        biases.append(c.param(idx, (n,), "f32"))
        idx += 1
    return tiles, biases, idx


def act_fwd(c: Comp, kind, y: T) -> T:
    if kind == "sigmoid":
        return c.logistic(y)
    if kind == "tanh":
        return c.tanh(y)
    return y


def act_bwd(c: Comp, kind, a: T, g: T) -> T:
    if kind == "sigmoid":
        return c.mul(g, c.mul(a, c.sub(c.fulllike(a, 1.0), a)))
    if kind == "tanh":
        return c.mul(g, c.sub(c.fulllike(a, 1.0), c.mul(a, a)))
    return g


def tile_mvm(c, rng, x2d, tile, mode, gamma_s, dev):
    """Forward MVM at the tile's effective weight; returns (y, ctx)."""
    ctx = dict(mode=mode, tile=tile, gamma_s=gamma_s, x2d=x2d)
    if mode == "digital":
        return c.dot(x2d, tile["w"]), ctx
    y = analog_mvm(c, rng, x2d, tile["w"], dev)
    if mode == "residual":
        b2, kdim = x2d.shape
        crow = c.reshape(tile["c"], (kdim,))
        ctx["crow"] = crow
        xc = c.mul(x2d, c.bvec(crow, (b2, kdim), 1))
        yp = analog_mvm(c, rng, xc, tile["p"], dev)
        yq = c.dot(xc, tile["q"])
        n = y.shape[1]
        y = c.add(y, c.mul(c.bs(gamma_s, (b2, n)), c.sub(yp, yq)))
    return y, ctx


def tile_mvm_bwd(c, rng, g, ctx, dev):
    """dL/dx of `tile_mvm` (the analog custom-VJP semantics)."""
    tile, mode = ctx["tile"], ctx["mode"]
    wt = c.transpose(tile["w"], (1, 0))
    if mode == "digital":
        return c.dot(g, wt)
    dx = analog_mvm(c, rng, g, wt, dev)
    if mode == "residual":
        gg = c.mul(g, c.bs(ctx["gamma_s"], g.shape))
        dxc = c.sub(
            analog_mvm(c, rng, gg, c.transpose(tile["p"], (1, 0)), dev),
            c.dot(gg, c.transpose(tile["q"], (1, 0))),
        )
        b2, kdim = dx.shape
        dx = c.add(dx, c.mul(dxc, c.bvec(ctx["crow"], (b2, kdim), 1)))
    return dx


def forward(c, rng, spec, tiles, biases, x, dev, mode, gamma_s):
    """Forward pass; returns (logits, per-layer saved ctx for backward)."""
    b = x.shape[0]
    saved = []
    h = x
    for li, layer in enumerate(spec["layers"]):
        if layer["kind"] == "fc":
            if len(h.shape) > 2:
                h = c.reshape(h, (b, prod(h.shape[1:])))
            y, mctx = tile_mvm(c, rng, h, tiles[li], mode, gamma_s, dev)
            y = c.add(y, c.bvec(biases[li], y.shape, 1))
            a = act_fwd(c, layer["act"], y)
            saved.append(dict(kind="fc", x2d=h, a=a, mctx=mctx, act=layer["act"]))
            h = a
        else:
            cin, cout, k, p, pool = (
                layer["cin"], layer["cout"], layer["ksz"], layer["pad"], layer["pool"],
            )
            hh, ww = layer["h"], layer["w"]
            ho, wo = conv_geom(layer)
            if len(h.shape) == 2:
                h = c.reshape(h, (b, cin, hh, ww))
            hp = h
            if p > 0:
                hp = c.pad(h, 0.0, [(0, 0), (0, 0), (p, p), (p, p)])
            pieces = []
            for ky in range(k):
                for kx in range(k):
                    s = c.slice(
                        hp, (0, 0, ky, kx), (b, cin, ky + ho, kx + wo)
                    )
                    pieces.append(c.reshape(s, (b, cin, 1, ho, wo)))
            pat5 = c.concat(pieces, 2)  # [B, C, k*k, Ho, Wo]
            pat = c.reshape(
                c.transpose(pat5, (0, 3, 4, 1, 2)), (b * ho * wo, cin * k * k)
            )
            y2d, mctx = tile_mvm(c, rng, pat, tiles[li], mode, gamma_s, dev)
            y2d = c.add(y2d, c.bvec(biases[li], y2d.shape, 1))
            y4 = c.transpose(c.reshape(y2d, (b, ho, wo, cout)), (0, 3, 1, 2))
            a4 = act_fwd(c, layer["act"], y4)
            hpool = c.mulc(
                c.reduce(
                    c.reshape(a4, (b, cout, ho // pool, pool, wo // pool, pool)),
                    (3, 5),
                    "add",
                ),
                1.0 / (pool * pool),
            )
            saved.append(
                dict(
                    kind="conv", pat=pat, a4=a4, mctx=mctx, act=layer["act"],
                    geom=(b, cin, cout, k, p, pool, hh, ww, ho, wo),
                )
            )
            h = hpool
    return h, saved


def backward(c, rng, spec, saved, g_logits, dev):
    """Manual backprop; returns (per-tile dW, per-layer dbias)."""
    n_layers = len(spec["layers"])
    dws = [None] * n_layers
    dbs = [None] * n_layers
    g = g_logits
    for li in range(n_layers - 1, -1, -1):
        sv = saved[li]
        if sv["kind"] == "fc":
            g_y = act_bwd(c, sv["act"], sv["a"], g)
            dws[li] = c.dot(c.transpose(sv["x2d"], (1, 0)), g_y)
            dbs[li] = c.reduce(g_y, (0,), "add")
            if li > 0:
                g = tile_mvm_bwd(c, rng, g_y, sv["mctx"], dev)
                prev = saved[li - 1]
                if prev["kind"] == "conv":
                    (b, _, cout_p, _, _, pool_p, _, _, ho_p, wo_p) = prev["geom"]
                    g = c.reshape(
                        g, (b, cout_p, ho_p // pool_p, wo_p // pool_p)
                    )
        else:
            (b, cin, cout, k, p, pool, hh, ww, ho, wo) = sv["geom"]
            gp = c.mulc(g, 1.0 / (pool * pool))
            g6 = c.bcast(
                gp,
                (b, cout, ho // pool, pool, wo // pool, pool),
                (0, 1, 2, 4),
            )
            g4 = c.reshape(g6, (b, cout, ho, wo))
            g_y4 = act_bwd(c, sv["act"], sv["a4"], g4)
            g_y2d = c.reshape(
                c.transpose(g_y4, (0, 2, 3, 1)), (b * ho * wo, cout)
            )
            dws[li] = c.dot(c.transpose(sv["pat"], (1, 0)), g_y2d)
            dbs[li] = c.reduce(g_y2d, (0,), "add")
            if li > 0:
                g_pat = tile_mvm_bwd(c, rng, g_y2d, sv["mctx"], dev)
                g5 = c.transpose(
                    c.reshape(g_pat, (b, ho, wo, cin, k * k)), (0, 3, 4, 1, 2)
                )
                hp2, wp2 = hh + 2 * p, ww + 2 * p
                acc = c.full((b, cin, hp2, wp2), 0.0)
                for ky in range(k):
                    for kx in range(k):
                        j = ky * k + kx
                        gs = c.reshape(
                            c.slice(g5, (0, 0, j, 0, 0), (b, cin, j + 1, ho, wo)),
                            (b, cin, ho, wo),
                        )
                        acc = c.add(
                            acc,
                            c.pad(
                                gs,
                                0.0,
                                [(0, 0), (0, 0), (ky, hp2 - ho - ky),
                                 (kx, wp2 - wo - kx)],
                            ),
                        )
                if p > 0:
                    acc = c.slice(acc, (0, 0, p, p), (b, cin, p + hh, p + ww))
                g = acc
    return dws, dbs


def softmax_loss(c, logits, labels):
    """Returns (nll scalar, g_logits)."""
    b, ncls = logits.shape
    rowmax = c.reduce(logits, (1,), "max")
    shft = c.sub(logits, c.bvec(rowmax, (b, ncls), 0))
    ex = c.exp(shft)
    sumex = c.reduce(ex, (1,), "add")
    logp = c.sub(shft, c.bvec(c.log(sumex), (b, ncls), 0))
    lab_b = c.bcast(labels, (b, ncls), (0,))
    oh = c.convert(c.cmpd("EQ", lab_b, c.iota((b, ncls), 1, "s32")), "f32")
    nll = c.mulc(
        c.neg(c.reduce(c.mul(oh, logp), (0, 1), "add")), 1.0 / b
    )
    softmax = c.div(ex, c.bvec(sumex, (b, ncls), 0))
    g = c.mulc(c.sub(softmax, oh), 1.0 / b)
    return nll, g, oh


def ncorrect_of(c, logits, oh, labels):
    """#rows whose label-logit attains the row max. Out-of-range labels
    (the trainer's zero-pad sentinel, = n_classes) never count: their
    one-hot row is all-zero, so `pick` would be 0 — mask them out
    explicitly instead of trusting sign(rowmax)."""
    b, ncls = logits.shape
    rowmax = c.reduce(logits, (1,), "max")
    pick = c.reduce(c.mul(oh, logits), (1,), "add")
    corr = c.convert(c.cmpd("GE", pick, rowmax), "f32")
    valid = c.convert(
        c.cmpd("LT", labels, c.full((b,), ncls, "s32")), "f32"
    )
    return c.reduce(c.mul(corr, valid), (0,), "add")


def flip_choppers(c, rng, tiles, flip_p_s):
    """Markov chopper flips; returns (new tiles, per-tile flip fraction)."""
    out, fracs = [], []
    for t in tiles:
        kdim = t["c"].shape[0]
        u = rng.uniform((kdim, 1))
        fl = c.cmpd("LT", u, c.bs(flip_p_s, (kdim, 1)))
        c_new = c.sel(fl, c.neg(t["c"]), t["c"])
        t2 = dict(t)
        t2["c"] = c_new
        out.append(t2)
        frac = c.mulc(
            c.reduce(c.convert(fl, "f32"), (0, 1), "add"), 1.0 / kdim
        )
        fracs.append(frac)
    return out, fracs


def grad_times_chopper(c, g, crow):
    """Per-input-line chopper applied to a [K, N] tile gradient/read."""
    kdim, n = g.shape
    return c.mul(g, c.bvec(crow, (kdim, n), 0))


def trunc(c, x):
    return c.mul(c.sign(x), c.floor(c.absu(x)))


# ------------------------------------------------------------- emitters


def io_entry(name, shape, dt):
    return {"name": name, "shape": list(shape), "dtype": dt}


def state_io(spec):
    return [io_entry(n, sh, "f32") for n, sh, _, _ in leaf_specs(spec)]


def step_io(spec, batch):
    ins = state_io(spec) + [
        io_entry("x", (batch, spec["d_in"]), "f32"),
        io_entry("labels", (batch,), "i32"),
        io_entry("key", (2,), "u32"),
        io_entry("hypers", (N_HYPERS,), "f32"),
        io_entry("dev", (N_DEV,), "f32"),
    ]
    return ins


def step_prologue(mod, spec, batch):
    c = mod.entry
    tiles, biases, idx = state_params(c, spec)
    x = c.param(idx, (batch, spec["d_in"]), "f32")
    labels = c.param(idx + 1, (batch,), "s32")
    key = c.param(idx + 2, (2,), "u32")
    hyp_v = c.param(idx + 3, (N_HYPERS,), "f32")
    dev_v = c.param(idx + 4, (N_DEV,), "f32")
    k0, k1 = c.scalar_at(key, 0), c.scalar_at(key, 1)
    rng = RngCtx(c, mod, k0, k1)
    return c, tiles, biases, x, labels, rng, hyp_scalars(c, hyp_v), dev_scalars(c, dev_v)


def root_state(c, spec, tiles, biases, extra=()):
    parts = []
    for t in tiles:
        for leaf in TILE_LEAVES:
            parts.append(t[leaf])
    parts.extend(biases)
    parts.extend(extra)
    c.set_root(c.tuple_(parts))


def scaled_grad(c, lr_s, g, negate):
    dw = c.mul(c.bs(lr_s, g.shape), g)
    return c.neg(dw) if negate else dw


def new_biases(c, biases, dbs, lr_s):
    return [
        c.sub(b, c.mul(c.bs(lr_s, b.shape), db)) for b, db in zip(biases, dbs)
    ]


def thresholded_transfer(c, rng, t, h2, hyp, dev):
    """TT-v2 digital buffer -> pulsed W transfer; returns (w2, h3)."""
    th = c.bs(hyp["thresh"], h2.shape)
    quanta = trunc(c, c.div(h2, th))
    dw = c.mul(c.bs(hyp["lr_transfer"], h2.shape), c.mul(quanta, th))
    w2 = pulse(c, rng, t["w"], dw, t["wap"], t["wam"], dev)
    return w2, c.sub(h2, c.mul(quanta, th))


def emit_step(mod, spec, algo):
    c, tiles, biases, x, labels, rng, hyp, dev = step_prologue(mod, spec, BATCH)
    if algo == "digital":
        logits, saved = forward(c, rng, spec, tiles, biases, x, dev, "digital", None)
    elif algo == "sgd":
        logits, saved = forward(c, rng, spec, tiles, biases, x, dev, "plain", None)
    else:
        if algo in ("agad", "erider"):
            tiles, fracs = flip_choppers(c, rng, tiles, hyp["flip_p"])
        logits, saved = forward(
            c, rng, spec, tiles, biases, x, dev, "residual", hyp["gamma"]
        )
    loss, g_logits, _ = softmax_loss(c, logits, labels)
    dws, dbs = backward(c, rng, spec, saved, g_logits, dev)
    one = c.const(1.0)
    new_tiles = []
    for ti, (t, g) in enumerate(zip(tiles, dws)):
        t2 = dict(t)
        if algo == "digital":
            step_w = c.mul(c.bs(hyp["lr_digital"], g.shape), g)
            t2["w"] = c.clampc(-1.0, c.sub(t["w"], step_w), 1.0)
        elif algo == "sgd":
            t2["w"] = pulse(
                c, rng, t["w"], scaled_grad(c, hyp["lr_fast"], g, True),
                t["wap"], t["wam"], dev,
            )
        elif algo in ("ttv1", "ttv2"):
            p2 = pulse(
                c, rng, t["p"], scaled_grad(c, hyp["lr_fast"], g, True),
                t["pap"], t["pam"], dev,
            )
            r = c.sub(read_noisy(c, rng, p2, hyp["read_noise"]), t["q"])
            t2["p"] = p2
            if algo == "ttv1":
                t2["w"] = pulse(
                    c, rng, t["w"], scaled_grad(c, hyp["lr_transfer"], r, False),
                    t["wap"], t["wam"], dev,
                )
            else:
                h2 = c.add(t["h"], r)
                t2["w"], t2["h"] = thresholded_transfer(c, rng, t, h2, hyp, dev)
        elif algo == "agad":
            kdim = t["c"].shape[0]
            crow = c.reshape(t["c"], (kdim,))
            cg = grad_times_chopper(c, g, crow)
            p2 = pulse(
                c, rng, t["p"], scaled_grad(c, hyp["lr_fast"], cg, True),
                t["pap"], t["pam"], dev,
            )
            r = read_noisy(c, rng, p2, hyp["read_noise"])
            h2 = c.add(
                t["h"], grad_times_chopper(c, c.sub(r, t["q"]), crow)
            )
            em = c.mul(hyp["eta"], fracs[ti])
            q2 = c.add(
                c.mul(c.bs(c.sub(one, em), t["q"].shape), t["q"]),
                c.mul(c.bs(em, r.shape), r),
            )
            t2["p"], t2["q"] = p2, q2
            t2["w"], t2["h"] = thresholded_transfer(c, rng, t, h2, hyp, dev)
        elif algo == "erider":
            kdim = t["c"].shape[0]
            crow = c.reshape(t["c"], (kdim,))
            cg = grad_times_chopper(c, g, crow)
            p2 = pulse(
                c, rng, t["p"], scaled_grad(c, hyp["lr_fast"], cg, True),
                t["pap"], t["pam"], dev,
            )
            r = read_noisy(c, rng, p2, hyp["read_noise"])
            q2 = c.add(
                c.mul(c.bs(c.sub(one, hyp["eta"]), t["q"].shape), t["q"]),
                c.mul(c.bs(hyp["eta"], r.shape), r),
            )
            dw = grad_times_chopper(c, c.sub(r, t["q"]), crow)
            t2["w"] = pulse(
                c, rng, t["w"], scaled_grad(c, hyp["lr_transfer"], dw, False),
                t["wap"], t["wam"], dev,
            )
            t2["p"], t2["q"] = p2, q2
        new_tiles.append(t2)
    root_state(c, spec, new_tiles, new_biases(c, biases, dbs, hyp["lr_digital"]),
               [loss])
    outs = [io_entry(n, sh, "f32") for n, sh, _, _ in leaf_specs(spec)]
    outs.append(io_entry("loss", (), "f32"))
    return step_io(spec, BATCH), outs


def emit_eval(mod, spec):
    c, tiles, biases, x, labels, rng, hyp, dev = step_prologue(mod, spec, EVAL_BATCH)
    logits, _ = forward(c, rng, spec, tiles, biases, x, dev, "residual",
                        hyp["gamma"])
    loss, _, oh = softmax_loss(c, logits, labels)
    logits2, _ = forward(c, rng, spec, tiles, biases, x, dev, "residual",
                         hyp["gamma"])
    nc = ncorrect_of(c, logits2, oh, labels)
    c.set_root(c.tuple_([loss, nc]))
    outs = [io_entry("loss", (), "f32"), io_entry("ncorrect", (), "f32")]
    return step_io(spec, EVAL_BATCH), outs


def emit_eval_digital(mod, spec):
    c = mod.entry
    tiles, biases, idx = state_params(c, spec)
    x = c.param(idx, (EVAL_BATCH, spec["d_in"]), "f32")
    labels = c.param(idx + 1, (EVAL_BATCH,), "s32")
    logits, _ = forward(c, None, spec, tiles, biases, x, None, "digital", None)
    loss, _, oh = softmax_loss(c, logits, labels)
    nc = ncorrect_of(c, logits, oh, labels)
    c.set_root(c.tuple_([loss, nc]))
    ins = state_io(spec) + [
        io_entry("x", (EVAL_BATCH, spec["d_in"]), "f32"),
        io_entry("labels", (EVAL_BATCH,), "i32"),
    ]
    return ins, [io_entry("loss", (), "f32"), io_entry("ncorrect", (), "f32")]


def sample_device(c, rng, shape, ref_mean_s, ref_std_s, sigma_g_s):
    gamma = c.exp(c.mul(c.bs(sigma_g_s, shape), rng.normal(shape)))
    wsp = c.add(
        c.bs(ref_mean_s, shape), c.mul(c.bs(ref_std_s, shape), rng.normal(shape))
    )
    wsp = c.clampc(-0.85, wsp, 0.85)
    rho = c.mul(gamma, wsp)
    floor = c.full(shape, 0.05)
    ap = c.maximum(c.add(gamma, rho), floor)
    am = c.maximum(c.sub(gamma, rho), floor)
    return ap, am


def emit_init(mod, spec):
    c = mod.entry
    key = c.param(0, (2,), "u32")
    prm = c.param(1, (3,), "f32")
    k0, k1 = c.scalar_at(key, 0), c.scalar_at(key, 1)
    rng = RngCtx(c, mod, k0, k1)
    ref_mean = c.scalar_at(prm, 0)
    ref_std = c.scalar_at(prm, 1)
    sigma_g = c.scalar_at(prm, 2)
    tiles, biases = [], []
    for layer in spec["layers"]:
        kdim, n = tile_shape(layer)
        lim = sqrt(6.0 / (kdim + n))
        u = rng.uniform((kdim, n))
        w = c.addc(c.mulc(u, 2.0 * lim), -lim)
        wap, wam = sample_device(c, rng, (kdim, n), ref_mean, ref_std, sigma_g)
        pap, pam = sample_device(c, rng, (kdim, n), ref_mean, ref_std, sigma_g)
        tiles.append(
            dict(
                w=w, p=c.full((kdim, n), 0.0), q=c.full((kdim, n), 0.0),
                h=c.full((kdim, n), 0.0), wap=wap, wam=wam, pap=pap, pam=pam,
                c=c.full((kdim, 1), 1.0),
            )
        )
        biases.append(c.full((n,), 0.0))
    root_state(c, spec, tiles, biases)
    ins = [io_entry("key", (2,), "u32"), io_entry("params", (3,), "f32")]
    return ins, state_io(spec)


def emit_zs(mod, spec):
    c = mod.entry
    tiles, biases, idx = state_params(c, spec)
    n = c.param(idx, (), "u32")
    key = c.param(idx + 1, (2,), "u32")
    dev_v = c.param(idx + 2, (N_DEV,), "f32")
    k0, k1 = c.scalar_at(key, 0), c.scalar_at(key, 1)
    new_tiles = []
    for ti, t in enumerate(tiles):
        kdim, ncol = t["p"].shape
        arr_ty = fmt_ty("f32", (kdim, ncol))
        tystr = (
            f"(u32[], u32[], u32[], u32[], {arr_ty}, {arr_ty}, {arr_ty}, f32[8])"
        )
        cond = mod.subcomp(f"zs_cond_t{ti}")
        s = cond.param_tuple(0, tystr)
        j_c = cond.gte(s, 0, (), "u32")
        n_c = cond.gte(s, 1, (), "u32")
        cond.set_root(cond.cmpd("LT", j_c, n_c))
        body = mod.subcomp(f"zs_body_t{ti}")
        sb = body.param_tuple(0, tystr)
        j_b = body.gte(sb, 0, (), "u32")
        n_b = body.gte(sb, 1, (), "u32")
        k0_b = body.gte(sb, 2, (), "u32")
        k1_b = body.gte(sb, 3, (), "u32")
        p_b = body.gte(sb, 4, (kdim, ncol), "f32")
        pap_b = body.gte(sb, 5, (kdim, ncol), "f32")
        pam_b = body.gte(sb, 6, (kdim, ncol), "f32")
        dev_b = body.gte(sb, 7, (N_DEV,), "f32")
        devs = dev_scalars(body, dev_b)
        brng = RngCtx(body, mod, k0_b, k1_b, extra=j_b)
        u = brng.uniform((kdim, ncol))
        sign = body.sel(
            body.cmpd("LT", u, body.full((kdim, ncol), 0.5)),
            body.full((kdim, ncol), 1.0),
            body.full((kdim, ncol), -1.0),
        )
        dw = body.mul(sign, body.bs(devs["dw_min"], (kdim, ncol)))
        p2 = pulse(body, brng, p_b, dw, pap_b, pam_b, devs)
        j2 = body.bin("add", j_b, body.const(1, "u32"))
        body.set_root(body.tuple_([j2, n_b, k0_b, k1_b, p2, pap_b, pam_b, dev_b]))
        init = c.tuple_(
            [c.const(0, "u32"), n, k0, k1, t["p"], t["pap"], t["pam"], dev_v]
        )
        w = c.while_(init, cond, body)
        p_out = c.gte(w, 4, (kdim, ncol), "f32")
        t2 = dict(t)
        t2["p"], t2["q"] = p_out, p_out
        new_tiles.append(t2)
    root_state(c, spec, new_tiles, biases)
    ins = state_io(spec) + [
        io_entry("n", (), "u32"),
        io_entry("key", (2,), "u32"),
        io_entry("dev", (N_DEV,), "f32"),
    ]
    return ins, state_io(spec)


def emit_kernel_pulse(mod):
    c = mod.entry
    shape = (4, 9)
    w = c.param(0, shape, "f32")
    dw = c.param(1, shape, "f32")
    ap = c.param(2, shape, "f32")
    am = c.param(3, shape, "f32")
    dev_v = c.param(4, (N_DEV,), "f32")
    w2 = pulse(c, None, w, dw, ap, am, dev_scalars(c, dev_v), det=True)
    c.set_root(c.tuple_([w2]))
    ins = [
        io_entry("w", shape, "f32"), io_entry("dw", shape, "f32"),
        io_entry("alpha_p", shape, "f32"), io_entry("alpha_m", shape, "f32"),
        io_entry("dev", (N_DEV,), "f32"),
    ]
    return ins, [io_entry("w_out", shape, "f32")]


def emit_kernel_mvm(mod, b, k, n):
    c = mod.entry
    x = c.param(0, (b, k), "f32")
    w = c.param(1, (k, n), "f32")
    dev_v = c.param(2, (N_DEV,), "f32")
    y = analog_mvm(c, None, x, w, dev_scalars(c, dev_v), det=True)
    c.set_root(c.tuple_([y]))
    ins = [
        io_entry("x", (b, k), "f32"), io_entry("w", (k, n), "f32"),
        io_entry("dev", (N_DEV,), "f32"),
    ]
    return ins, [io_entry("y", (b, n), "f32")]


# ------------------------------------------------------ parity (numpy)


def np_pulse_det(w, dw, ap, am, dw_min):
    f = np.float32
    w, dw, ap, am = (np.asarray(a, f) for a in (w, dw, ap, am))
    qp = (ap * (f(1.0) - w)).astype(f)
    qm = (am * (f(1.0) + w)).astype(f)
    q = np.maximum(np.where(dw >= 0, qp, qm), f(0.0)).astype(f)
    n = np.rint((np.abs(dw) / f(dw_min)).astype(f)).astype(f)
    delta = ((np.sign(dw) * (n * f(dw_min))).astype(f) * q).astype(f)
    return np.clip((w + delta).astype(f), f(-1.0), f(1.0)).astype(f)


def np_mvm_det(x, w, inp_res=1.0 / 127.0, out_res=1.0 / 511.0, out_bound=12.0):
    f = np.float32
    x, w = np.asarray(x, f), np.asarray(w, f)
    scale = np.max(np.abs(x), axis=-1, keepdims=True).astype(f)
    scale = np.where(scale > 0, scale, f(1.0)).astype(f)
    xn = (x / scale).astype(f)
    xq = (np.rint((xn / f(inp_res)).astype(f)).astype(f) * f(inp_res)).astype(f)
    # sequential f32 accumulation, matching the interpreter's dot
    b, k = x.shape
    n = w.shape[1]
    y = np.zeros((b, n), f)
    for bi in range(b):
        for kk in range(k):
            y[bi] = (y[bi] + xq[bi, kk] * w[kk]).astype(f)
    yq = (np.rint((y / f(out_res)).astype(f)).astype(f) * f(out_res)).astype(f)
    yq = np.clip(yq, f(-out_bound), f(out_bound)).astype(f)
    return (yq * scale).astype(f)


def emit_parity(out_dir):
    rng = np.random.default_rng(1234)
    cases = []
    for dw_min in (0.4622, 0.0949, 1e-3):
        shape = (4, 9)
        w = rng.uniform(-0.9, 0.9, shape).astype(np.float32)
        dw = rng.uniform(-0.3, 0.3, shape).astype(np.float32)
        gamma = np.exp(0.2 * rng.standard_normal(shape)).astype(np.float32)
        wsp = rng.uniform(-0.5, 0.5, shape).astype(np.float32)
        ap = np.maximum(gamma * (1 + wsp), 0.05).astype(np.float32)
        am = np.maximum(gamma * (1 - wsp), 0.05).astype(np.float32)
        out = np_pulse_det(w, dw, ap, am, dw_min)
        cases.append(
            {
                "kind": "pulse_update",
                "dw_min": dw_min,
                "w": w.ravel().tolist(),
                "dw": dw.ravel().tolist(),
                "alpha_p": ap.ravel().tolist(),
                "alpha_m": am.ravel().tolist(),
                "rows": shape[0],
                "cols": shape[1],
                "expected": out.ravel().tolist(),
            }
        )
    for b, k, n in ((3, 7, 5), (8, 16, 4)):
        x = rng.uniform(-2, 2, (b, k)).astype(np.float32)
        w = rng.uniform(-1, 1, (k, n)).astype(np.float32)
        y = np_mvm_det(x, w)
        cases.append(
            {
                "kind": "analog_mvm",
                "x": x.ravel().tolist(),
                "w": w.ravel().tolist(),
                "b": b, "k": k, "n": n,
                "expected": y.ravel().tolist(),
            }
        )
    with open(os.path.join(out_dir, "parity.json"), "w") as f:
        json.dump({"cases": cases}, f)
    print(f"  parity.json: {len(cases)} cases")


# ---------------------------------------------------------------- driver


def write_artifact(out_dir, manifest, name, mod, ins, outs):
    text = mod.render()
    fname = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    manifest["artifacts"][name] = {"file": fname, "inputs": ins, "outputs": outs}
    print(f"  {name}: {len(text) / 1e3:.0f} kB hlo, {len(ins)} in / {len(outs)} out")


def emit_model(out_dir, manifest, mname):
    spec = model_spec(mname)
    manifest["models"][mname] = {
        "batch": BATCH,
        "eval_batch": EVAL_BATCH,
        "d_in": spec["d_in"],
        "n_classes": spec["n_classes"],
        "state": [
            {"name": n, "shape": list(sh), "role": role, "tile": ti}
            for n, sh, role, ti in leaf_specs(spec)
        ],
    }
    mod = Module(f"{mname}_init")
    ins, outs = emit_init(mod, spec)
    write_artifact(out_dir, manifest, f"{mname}_init", mod, ins, outs)
    for algo in STEP_ALGOS:
        mod = Module(f"{mname}_step_{algo}")
        ins, outs = emit_step(mod, spec, algo)
        write_artifact(out_dir, manifest, f"{mname}_step_{algo}", mod, ins, outs)
    mod = Module(f"{mname}_eval")
    ins, outs = emit_eval(mod, spec)
    write_artifact(out_dir, manifest, f"{mname}_eval", mod, ins, outs)
    mod = Module(f"{mname}_eval_digital")
    ins, outs = emit_eval_digital(mod, spec)
    write_artifact(out_dir, manifest, f"{mname}_eval_digital", mod, ins, outs)
    mod = Module(f"{mname}_zs")
    ins, outs = emit_zs(mod, spec)
    write_artifact(out_dir, manifest, f"{mname}_zs", mod, ins, outs)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="artifacts")
    ap.add_argument("--models", default="fcn,lenet,convnet3")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    manifest = {
        "models": {},
        "artifacts": {},
        "hyper_index": dict(HYPER_INDEX, n_hypers=N_HYPERS),
        "dev_index": dict(DEV_INDEX, n_dev=N_DEV),
    }
    man_path = os.path.join(args.out, "manifest.json")
    if os.path.exists(man_path):
        old = json.load(open(man_path))
        manifest["models"].update(old.get("models", {}))
        manifest["artifacts"].update(old.get("artifacts", {}))
    for mname in args.models.split(","):
        print(f"model {mname}:")
        emit_model(args.out, manifest, mname)
    mod = Module("kernel_pulse_update_det")
    ins, outs = emit_kernel_pulse(mod)
    write_artifact(args.out, manifest, "kernel_pulse_update_det", mod, ins, outs)
    for b, k, n in ((3, 7, 5), (8, 16, 4)):
        mod = Module(f"kernel_analog_mvm_det_{b}x{k}x{n}")
        ins, outs = emit_kernel_mvm(mod, b, k, n)
        write_artifact(
            args.out, manifest, f"kernel_analog_mvm_det_{b}x{k}x{n}", mod, ins, outs
        )
    emit_parity(args.out)
    with open(man_path, "w") as f:
        json.dump(manifest, f, indent=1)
    print("manifest.json written")


if __name__ == "__main__":
    main()
