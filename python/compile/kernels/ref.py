"""Pure-jnp oracles for the Pallas kernels.

These are the CORE correctness references: every Pallas kernel in this
package must agree with its oracle here (exactly in deterministic mode,
distributionally in stochastic mode). The Rust device substrate
(`rust/src/device/`) is additionally checked against these through the
parity vectors emitted by `aot.py` (artifacts/parity.json).

Device model (paper Appendix F.1, SoftBoundsReference):

    q_plus(w)  = alpha_p * (1 - w / tau_max)
    q_minus(w) = alpha_m * (1 + w / tau_min)

Analog Update (paper Eq. 2/5), single-shot abstraction of a pulse train:

    dw >= 0:  w' = w + dw * q_plus(w)  * (1 + c2c noise) + rounding noise
    dw <  0:  w' = w + dw * q_minus(w) * (1 + c2c noise) + rounding noise

with clipping to [-tau_min, tau_max]. Noise model (Assumption 3.4 +
Eq. 108/109): the desired increment |dw| is realised as n = |dw|/dw_min
pulses; stochastic rounding of n contributes variance
dw_min^2 * frac*(1-frac) * q^2, and per-pulse c2c noise contributes
n * (dw_min * sigma_c2c)^2 * q^2.
"""

from __future__ import annotations

import jax.numpy as jnp


def q_plus(w, alpha_p, tau_max):
    """Potentiation response function (paper Eq. 103, left)."""
    return alpha_p * (1.0 - w / tau_max)


def q_minus(w, alpha_m, tau_min):
    """Depression response function (paper Eq. 103, right)."""
    return alpha_m * (1.0 + w / tau_min)


def f_sym(w, alpha_p, alpha_m, tau_max, tau_min):
    """Symmetric component F = (q- + q+)/2 (paper Eq. 6a)."""
    return 0.5 * (q_minus(w, alpha_m, tau_min) + q_plus(w, alpha_p, tau_max))


def g_asym(w, alpha_p, alpha_m, tau_max, tau_min):
    """Asymmetric component G = (q- - q+)/2 (paper Eq. 6b)."""
    return 0.5 * (q_minus(w, alpha_m, tau_min) - q_plus(w, alpha_p, tau_max))


def symmetric_point(alpha_p, alpha_m, tau_max, tau_min):
    """Ground-truth SP: solve q_plus(w) = q_minus(w) (Definition 1.1).

    Note: paper Eq. (110) as printed has a sign slip; the correct closed
    form is  w = (a+ - a-) / (a+/tau_max + a-/tau_min),  which gives
    w = rho/gamma when tau = 1 and alpha_pm = gamma +- rho.
    """
    return (alpha_p - alpha_m) / (alpha_p / tau_max + alpha_m / tau_min)


def ref_pulse_update(
    w,
    dw,
    alpha_p,
    alpha_m,
    u,
    z,
    *,
    dw_min,
    sigma_c2c,
    tau_max=1.0,
    tau_min=1.0,
    deterministic=False,
):
    """Oracle for the `pulse_update` kernel.

    Args:
      w:        current weights (any shape)
      dw:       desired increment, same shape
      alpha_p:  per-cell potentiation magnitude (gamma + rho)
      alpha_m:  per-cell depression magnitude (gamma - rho)
      u:        uniform(0,1) variates, same shape (stochastic rounding)
      z:        standard normal variates, same shape (c2c noise)
      dw_min:   response granularity (scalar)
      sigma_c2c: cycle-to-cycle relative std (scalar)
      deterministic: if True, round-to-nearest pulse count, no noise
                     (the parity mode shared with the Rust substrate).

    Returns: updated weights, clipped to [-tau_min, tau_max].
    """
    qp = q_plus(w, alpha_p, tau_max)
    qm = q_minus(w, alpha_m, tau_min)
    q = jnp.where(dw >= 0, qp, qm)
    # Response functions are only meaningful inside the conductance
    # window; clipping below keeps us there, but guard q >= 0 anyway.
    q = jnp.maximum(q, 0.0)
    mag = jnp.abs(dw)
    sign = jnp.sign(dw)
    if deterministic:
        n = jnp.round(mag / dw_min)
        delta = sign * n * dw_min * q
    else:
        n_lo = jnp.floor(mag / dw_min)
        frac = mag / dw_min - n_lo
        n = n_lo + (u < frac).astype(w.dtype)
        # c2c: per-pulse multiplicative noise aggregates with sqrt(n).
        c2c_std = jnp.sqrt(n) * dw_min * sigma_c2c
        delta = sign * (n * dw_min + c2c_std * z) * q
    return jnp.clip(w + delta, -tau_min, tau_max)


def ref_analog_mvm(
    x,
    w,
    z,
    *,
    inp_res=1.0 / 127.0,
    out_res=1.0 / 511.0,
    out_bound=12.0,
    out_noise=0.06,
    deterministic=False,
):
    """Oracle for the `analog_mvm` kernel: y = x @ w through the crossbar.

    Models the analog IO chain of Appendix F Table 7:
      1. noise management ABS_MAX: scale rows of x by their abs-max,
      2. 7-bit DAC quantization of the scaled input in [-1, 1],
      3. analog matmul,
      4. additive output (read) noise,
      5. 9-bit ADC quantization + clipping at +-out_bound,
      6. rescale by the input scale.

    Args:
      x: [B, K] activations;  w: [K, N] conductances;
      z: [B, N] standard normals (output noise).
    """
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(scale > 0, scale, 1.0)
    xn = x / scale
    xq = jnp.round(xn / inp_res) * inp_res
    y = xq @ w
    if not deterministic:
        y = y + out_noise * z
    yq = jnp.round(y / out_res) * out_res
    yq = jnp.clip(yq, -out_bound, out_bound)
    return yq * scale
