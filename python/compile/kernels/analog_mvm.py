"""Pallas kernel: noisy, quantized crossbar matrix-vector multiply.

The analog forward/backward hot-spot. The crossbar tile is the natural
MXU-shaped unit of work: each grid step loads an [bm, K] activation block
and a [K, bn] conductance block into VMEM, runs the DAC stage (ABS_MAX
noise management + input quantization) in-register, one MXU matmul, then
the ADC stage (read noise + output quantization + clipping) fused on the
way out. The HBM<->VMEM schedule that AIHWKit expresses with CUDA
threadblocks is expressed here with the BlockSpec grid.

IO chain parameters follow the paper's Appendix F Table 7 (7-bit DAC,
9-bit ADC, out_noise 0.06, out_bound 12).

interpret=True is mandatory on this CPU image (see kernels/pulse_update).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_BM = 32   # activation rows per block
_BN = 512  # output columns per block


def _analog_mvm_kernel(params_ref, x_ref, w_ref, z_ref, out_ref):
    """One [bm, K] x [K, bn] block of the analog MVM."""
    inp_res = params_ref[0]
    out_res = params_ref[1]
    out_bound = params_ref[2]
    out_noise = params_ref[3]
    det = params_ref[4]

    x = x_ref[...]
    w = w_ref[...]
    z = z_ref[...]

    # DAC: per-row ABS_MAX noise management + input quantization.
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(scale > 0.0, scale, 1.0)
    xq = jnp.round((x / scale) / inp_res) * inp_res

    # Crossbar: Kirchhoff summation == matmul on the MXU.
    y = jnp.dot(xq, w, preferred_element_type=jnp.float32)

    # ADC: read noise, quantization, output bound, undo noise management.
    y = y + jnp.where(det > 0.5, 0.0, out_noise) * z
    yq = jnp.round(y / out_res) * out_res
    yq = jnp.clip(yq, -out_bound, out_bound)
    out_ref[...] = yq * scale


def _pad_to(a, rows, cols):
    r = (-a.shape[0]) % rows
    c = (-a.shape[1]) % cols
    if r or c:
        a = jnp.pad(a, ((0, r), (0, c)))
    return a


@functools.partial(jax.jit, static_argnames=("deterministic",))
def analog_mvm(
    x,
    w,
    z,
    inp_res=1.0 / 127.0,
    out_res=1.0 / 511.0,
    out_bound=12.0,
    out_noise=0.06,
    deterministic=False,
):
    """Noisy quantized y = x @ w.

    Args:
      x: [B, K] activations.
      w: [K, N] crossbar conductances.
      z: [B, N] standard normals for ADC read noise.
      scalars: IO chain parameters (traced; sweepable from Rust at runtime).
      deterministic: disable read noise (quantization stays — it is a
        deterministic non-ideality), for parity testing.

    Returns: [B, N] float32.
    """
    b, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    xp = _pad_to(x, _BM, 1)
    wp = _pad_to(w, 1, _BN)
    zp = _pad_to(z, _BM, _BN)
    pb, pn = xp.shape[0], wp.shape[1]
    grid = (pb // _BM, pn // _BN)

    params = jnp.stack(
        [
            jnp.asarray(inp_res, jnp.float32),
            jnp.asarray(out_res, jnp.float32),
            jnp.asarray(out_bound, jnp.float32),
            jnp.asarray(out_noise, jnp.float32),
            jnp.asarray(1.0 if deterministic else 0.0, jnp.float32),
            jnp.asarray(0.0, jnp.float32),
        ]
    )

    out = pl.pallas_call(
        _analog_mvm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((6,), lambda i, j: (0,)),
            pl.BlockSpec((_BM, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, _BN), lambda i, j: (0, j)),
            pl.BlockSpec((_BM, _BN), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((_BM, _BN), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((pb, pn), jnp.float32),
        interpret=True,
    )(params, xp, wp, zp)
    return out[:b, :n]
