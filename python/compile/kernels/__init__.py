"""L1 Pallas kernels for analog in-memory training.

`analog_mvm`   — noisy quantized crossbar MVM (forward + backward MVMs).
`pulse_update` — asymmetric pulsed conductance update (the Analog Update,
                 paper Eq. 2).
`ref`          — pure-jnp oracles the kernels are tested against.
"""

from .analog_mvm import analog_mvm
from .pulse_update import pulse_update
from . import ref

__all__ = ["analog_mvm", "pulse_update", "ref"]
