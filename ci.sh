#!/usr/bin/env bash
# CI gate for the Rust substrate.
#
#   ./ci.sh         tier-1 gate (build + tests) then lint
#   ./ci.sh lint    lint only (fmt --check, clippy -D warnings)
#   ./ci.sh bench   run the device + optimizer bench suites and emit
#                   machine-readable BENCH_device.json /
#                   BENCH_optimizers.json at the repo root (parsed from
#                   the BENCH lines, throughput included) so successive
#                   PRs can track the speedup trajectory
#
# Tier-1 (ROADMAP.md): cargo build --release && cargo test -q.
# The build covers --all-targets so benches and examples can't silently
# rot out of the API. Lint runs after tier-1 and also fails the script;
# use `./ci.sh lint` to iterate on fmt/clippy alone.

set -euo pipefail
cd "$(dirname "$0")"

lint() {
    echo "== cargo fmt --check =="
    cargo fmt --check
    echo "== cargo clippy (all targets, -D warnings) =="
    cargo clippy --all-targets -- -D warnings
}

# bench_json <raw-output> <out.json>: convert `BENCH\t...` report lines
# into a JSON array. Field layout (util/bench.rs BenchResult::report):
#   BENCH <name> iters=N mean=T median=T min=T std=T [throughput=X u/s]
# with T carrying a ns/us/ms/s suffix; all times are normalized to ns.
bench_json() {
    awk -F'\t' '
    function to_ns(s) {
        if (s ~ /ns$/) return substr(s, 1, length(s) - 2) + 0
        if (s ~ /us$/) return (substr(s, 1, length(s) - 2) + 0) * 1e3
        if (s ~ /ms$/) return (substr(s, 1, length(s) - 2) + 0) * 1e6
        return (substr(s, 1, length(s) - 1) + 0) * 1e9
    }
    BEGIN { printf "["; n = 0 }
    $1 == "BENCH" && NF >= 7 {
        name = $2
        iters = substr($3, 7) + 0
        mean = to_ns(substr($4, 6))
        median = to_ns(substr($5, 8))
        min = to_ns(substr($6, 5))
        std = to_ns(substr($7, 5))
        has_thr = 0
        if (NF >= 8 && $8 ~ /^throughput=/) {
            split(substr($8, 12), a, " ")
            thr = a[1] + 0
            unit = a[2]
            sub(/\/s$/, "", unit)
            has_thr = 1
        }
        if (n++) printf ","
        printf "\n  {\"name\":\"%s\",\"iters\":%d,\"mean_ns\":%.1f,\"median_ns\":%.1f,\"min_ns\":%.1f,\"std_ns\":%.1f", \
            name, iters, mean, median, min, std
        if (has_thr) printf ",\"throughput_per_s\":%.4e,\"throughput_unit\":\"%s\"", thr, unit
        printf "}"
    }
    END { printf "\n]\n" }
    ' "$1" > "$2"
    echo "wrote $2 ($(grep -c '"name"' "$2") cases)"
}

bench() {
    local tmp
    tmp="$(mktemp -d)"
    echo "== cargo bench --bench bench_device =="
    cargo bench --bench bench_device | tee "$tmp/device.out"
    echo "== cargo bench --bench bench_optimizers =="
    cargo bench --bench bench_optimizers | tee "$tmp/optimizers.out"
    bench_json "$tmp/device.out" BENCH_device.json
    bench_json "$tmp/optimizers.out" BENCH_optimizers.json
    rm -rf "$tmp"
}

case "${1:-}" in
    lint)
        lint
        exit 0
        ;;
    bench)
        bench
        exit 0
        ;;
esac

echo "== tier-1: cargo build --release --all-targets =="
cargo build --release --all-targets

echo "== tier-1: cargo test -q =="
cargo test -q

lint
echo "CI OK"
