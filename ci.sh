#!/usr/bin/env bash
# CI gate for the Rust substrate.
#
#   ./ci.sh         tier-1 gate (build + tests) then lint
#   ./ci.sh lint    lint only (fmt --check, clippy -D warnings)
#
# Tier-1 (ROADMAP.md): cargo build --release && cargo test -q.
# The build covers --all-targets so benches and examples can't silently
# rot out of the API. Lint runs after tier-1 and also fails the script;
# use `./ci.sh lint` to iterate on fmt/clippy alone.

set -euo pipefail
cd "$(dirname "$0")"

lint() {
    echo "== cargo fmt --check =="
    cargo fmt --check
    echo "== cargo clippy (all targets, -D warnings) =="
    cargo clippy --all-targets -- -D warnings
}

if [[ "${1:-}" == "lint" ]]; then
    lint
    exit 0
fi

echo "== tier-1: cargo build --release --all-targets =="
cargo build --release --all-targets

echo "== tier-1: cargo test -q =="
cargo test -q

lint
echo "CI OK"
