#!/usr/bin/env bash
# CI gate for the Rust substrate.
#
#   ./ci.sh         tier-1 gate (build + tests), then verify, then e2e,
#                   then metrics, then doc+lint
#   ./ci.sh lint    lint only (fmt --check, clippy -D warnings plus the
#                   repo deny-set: undocumented unsafe blocks)
#   ./ci.sh verify  static plan verification: `rider verify` re-checks
#                   every compiled artifact plan (def-before-use, alias
#                   resolution, buffer-reuse soundness, shape
#                   re-inference, fusion legality, while contracts)
#                   without executing; a "skipping:" line fails the
#                   stage — the artifacts must be present
#   ./ci.sh doc     rustdoc gate only (cargo doc --no-deps with
#                   RUSTDOCFLAGS="-D warnings": broken links and
#                   missing docs on the gated modules fail)
#   ./ci.sh e2e     release-mode end-to-end stage: the artifact-gated
#                   integration tests (runtime/trainer/interp-golden/
#                   plan-equivalence) MUST run on the HLO interpreter
#                   (a "skipping:" line fails the stage — no silent
#                   skips), then train_digits_e2e and a reduced `rider
#                   table1` grid complete against the checked-in
#                   artifacts/ fixtures
#   ./ci.sh bench [--check]
#                   run the device + optimizer + train-step bench
#                   suites; each suite's BenchSuite (util/bench.rs,
#                   backed by util/metrics.rs) writes machine-readable
#                   BENCH_device.json / BENCH_optimizers.json at the
#                   repo root via $BENCH_JSON_OUT (the train-step
#                   cases — planned `step/*` and scalar-walker
#                   `stepref/*` — append into BENCH_optimizers.json
#                   with $BENCH_JSON_APPEND=1) so successive PRs can
#                   track the speedup trajectory. With --check, compare
#                   per-case min_ns against the committed
#                   BENCH_baseline/*.json and fail on a >25% regression
#                   (missing baselines are bootstrapped from the fresh
#                   run and must be committed).
#   ./ci.sh metrics observability smoke stage: a 5-step `rider table1`
#                   must leave a parseable runs/table1/metrics.jsonl
#                   trace containing every METRICS.md-required key, and
#                   `rider metrics` must emit Prometheus exposition text
#   ./ci.sh cov     report-only line-coverage summary via cargo
#                   llvm-cov, written to coverage-summary.txt (uploaded
#                   as a workflow artifact). No threshold is enforced —
#                   the stage exists to make coverage drift visible in
#                   review, not to gate. Degrades to a note when
#                   cargo-llvm-cov is not installed; never part of the
#                   default gate
#
# Tier-1 (ROADMAP.md): cargo build --release && cargo test -q.
# The build covers --all-targets so benches and examples can't silently
# rot out of the API. Lint runs after tier-1 + e2e and also fails the
# script; use `./ci.sh lint` to iterate on fmt/clippy alone.

set -euo pipefail
cd "$(dirname "$0")"

lint() {
    echo "== cargo fmt --check =="
    cargo fmt --check
    echo "== cargo clippy (all targets, -D warnings + repo deny-set) =="
    cargo clippy --all-targets -- -D warnings \
        -D clippy::undocumented_unsafe_blocks
}

verify() {
    echo "== verify: static plan checks over artifacts/ =="
    local out
    out="$(mktemp)"
    cargo run --release --quiet -- verify 2>&1 | tee "$out"
    if grep -q "skipping:" "$out"; then
        rm -f "$out"
        echo "verify FAILED: artifacts not built — the plan checks must run"
        exit 1
    fi
    rm -f "$out"
    echo "verify OK"
}

doc() {
    echo "== cargo doc --no-deps (RUSTDOCFLAGS=-D warnings) =="
    RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet
}

# The BENCH_*.json emission lives in the bench binaries themselves:
# each one drives a util/bench.rs BenchSuite, which records every case
# into the metrics facade and writes $BENCH_JSON_OUT on exit
# ($BENCH_JSON_APPEND=1 merges into an existing array, so the
# train-step cases land in the same file as the optimizer cases).
bench() {
    echo "== cargo bench --bench bench_device =="
    BENCH_JSON_OUT=BENCH_device.json cargo bench --bench bench_device
    echo "== cargo bench --bench bench_optimizers =="
    BENCH_JSON_OUT=BENCH_optimizers.json cargo bench --bench bench_optimizers
    echo "== cargo bench --bench bench_train_step =="
    BENCH_JSON_OUT=BENCH_optimizers.json BENCH_JSON_APPEND=1 \
        cargo bench --bench bench_train_step
}

# bench_check: per-case min_ns vs BENCH_baseline/<file>; >25% slower
# fails. Absent baselines are bootstrapped (first run on a new machine
# or after a reset) — commit them to arm the gate.
bench_check() {
    local fresh base fail=0
    mkdir -p BENCH_baseline
    for fresh in BENCH_device.json BENCH_optimizers.json; do
        base="BENCH_baseline/$fresh"
        if [ ! -f "$base" ]; then
            cp "$fresh" "$base"
            echo "bench --check: no baseline for $fresh; bootstrapped $base — commit it"
            continue
        fi
        if ! awk '
        function getname(s) { match(s, /"name":"[^"]*"/); return substr(s, RSTART + 8, RLENGTH - 9) }
        function getmin(s)  { match(s, /"min_ns":[0-9.]+/); return substr(s, RSTART + 9, RLENGTH - 9) + 0 }
        NR == FNR { if ($0 ~ /"name"/) base[getname($0)] = getmin($0); next }
        $0 ~ /"name"/ {
            n = getname($0); m = getmin($0)
            if (n in base && base[n] > 0) {
                if (m > base[n] * 1.25) {
                    printf "  REGRESSION %s: min_ns %.1f vs baseline %.1f (+%.0f%%)\n", n, m, base[n], 100 * (m / base[n] - 1)
                    bad = 1
                } else {
                    printf "  ok %s: min_ns %.1f vs baseline %.1f\n", n, m, base[n]
                }
            } else {
                printf "  new case %s (no baseline)\n", n
            }
        }
        END { exit bad }
        ' "$base" "$fresh"; then
            fail=1
        fi
    done
    if [ "$fail" -ne 0 ]; then
        echo "bench --check FAILED: >25% min_ns regression against BENCH_baseline/"
        exit 1
    fi
    echo "bench --check OK"
}

e2e() {
    echo "== e2e: artifact-gated tests on the HLO interpreter (release) =="
    local out
    out="$(mktemp)"
    cargo test --release --test runtime_integration --test trainer_integration \
        --test interp_golden --test plan_equivalence --test verify_plans \
        --test fault_recovery --test pipeline_equivalence --test parser_fuzz \
        -- --nocapture 2>&1 | tee "$out"
    if grep -q "skipping:" "$out"; then
        rm -f "$out"
        echo "e2e FAILED: artifact-gated tests skipped — the NN-scale path must run"
        exit 1
    fi
    rm -f "$out"
    echo "== e2e: train_digits_e2e (reduced budget) =="
    cargo run --release --example train_digits_e2e 150
    echo "== e2e: rider table1 (reduced budget) =="
    cargo run --release -- table1 --steps 20 --seeds 1
    echo "== e2e: rider table_pipeline (reduced smoke grid) =="
    cargo run --release -- table_pipeline --steps 20 --model fcn \
        --methods ttv2,erider --stages 2 --workers 2 --staleness 1
    echo "== e2e: rider faultsweep (reduced smoke grid) =="
    cargo run --release -- faultsweep --steps 20 --seeds 1 \
        --methods residual,rider --families drift --rates 0.2
    echo "e2e OK"
}

# metrics: observability smoke. A reduced `rider table1` must leave a
# JSONL metrics trace whose every line parses and which carries the
# documented required keys (util/metrics.rs REQUIRED_TRACE_KEYS /
# METRICS.md), and `rider metrics` must emit Prometheus exposition text.
metrics() {
    echo "== metrics: JSONL trace smoke (5-step rider table1) =="
    local runs trace
    runs="$(mktemp -d)"
    RIDER_RUNS="$runs" cargo run --release --quiet -- \
        table1 --steps 5 --seeds 1 > /dev/null
    trace="$runs/table1/metrics.jsonl"
    if [ ! -s "$trace" ]; then
        echo "metrics FAILED: $trace missing or empty"
        exit 1
    fi
    python3 - "$trace" <<'EOF'
import json, sys
required = {"train_loss", "train_update_pulses_total", "sp_residual"}
seen = set()
with open(sys.argv[1]) as f:
    for n, line in enumerate(f, 1):
        rec = json.loads(line)
        assert {"step", "key", "type"} <= rec.keys(), f"line {n}: missing fields"
        seen.add(rec["key"])
missing = required - seen
assert not missing, f"required keys missing from trace: {sorted(missing)}"
print(f"trace OK: {len(seen)} distinct keys")
EOF
    rm -rf "$runs"
    echo "== metrics: rider metrics (Prometheus exposition) =="
    local prom
    prom="$(mktemp)"
    cargo run --release --quiet -- metrics > "$prom"
    grep -q '^# TYPE device_pulses_total counter$' "$prom"
    grep -q '^device_sp_drift ' "$prom"
    rm -f "$prom"
    echo "metrics OK"
}

# cov: report-only coverage summary. Intentionally threshold-free and
# outside the default gate; the wording below says "skipped" (never
# "skipping:") so the e2e no-silent-skips grep can't misfire on logs
# that concatenate stages.
cov() {
    echo "== cov: cargo llvm-cov --summary-only (report-only) =="
    if ! cargo llvm-cov --version > /dev/null 2>&1; then
        echo "cov skipped: cargo-llvm-cov not installed" | tee coverage-summary.txt
        return 0
    fi
    cargo llvm-cov --summary-only 2>&1 | tee coverage-summary.txt
    echo "cov OK (report-only; summary in coverage-summary.txt)"
}

case "${1:-}" in
    lint)
        lint
        exit 0
        ;;
    cov)
        cov
        exit 0
        ;;
    metrics)
        metrics
        exit 0
        ;;
    doc)
        doc
        exit 0
        ;;
    e2e)
        e2e
        exit 0
        ;;
    verify)
        verify
        exit 0
        ;;
    bench)
        bench
        if [ "${2:-}" = "--check" ]; then
            bench_check
        fi
        exit 0
        ;;
esac

echo "== tier-1: cargo build --release --all-targets =="
cargo build --release --all-targets

echo "== tier-1: cargo test -q =="
cargo test -q

verify
e2e
metrics
doc
lint
echo "CI OK"
