#!/usr/bin/env bash
# CI gate for the Rust substrate.
#
#   ./ci.sh         tier-1 gate (build + tests), then verify, then e2e,
#                   then doc+lint
#   ./ci.sh lint    lint only (fmt --check, clippy -D warnings plus the
#                   repo deny-set: undocumented unsafe blocks)
#   ./ci.sh verify  static plan verification: `rider verify` re-checks
#                   every compiled artifact plan (def-before-use, alias
#                   resolution, buffer-reuse soundness, shape
#                   re-inference, fusion legality, while contracts)
#                   without executing; a "skipping:" line fails the
#                   stage — the artifacts must be present
#   ./ci.sh doc     rustdoc gate only (cargo doc --no-deps with
#                   RUSTDOCFLAGS="-D warnings": broken links and
#                   missing docs on the gated modules fail)
#   ./ci.sh e2e     release-mode end-to-end stage: the artifact-gated
#                   integration tests (runtime/trainer/interp-golden/
#                   plan-equivalence) MUST run on the HLO interpreter
#                   (a "skipping:" line fails the stage — no silent
#                   skips), then train_digits_e2e and a reduced `rider
#                   table1` grid complete against the checked-in
#                   artifacts/ fixtures
#   ./ci.sh bench [--check]
#                   run the device + optimizer + train-step bench
#                   suites and emit machine-readable BENCH_device.json /
#                   BENCH_optimizers.json at the repo root (the
#                   train-step cases — planned `step/*` and
#                   scalar-walker `stepref/*` — land in
#                   BENCH_optimizers.json) so successive PRs can track
#                   the speedup trajectory. With --check, compare
#                   per-case min_ns against the committed
#                   BENCH_baseline/*.json and fail on a >25% regression
#                   (missing baselines are bootstrapped from the fresh
#                   run and must be committed).
#
# Tier-1 (ROADMAP.md): cargo build --release && cargo test -q.
# The build covers --all-targets so benches and examples can't silently
# rot out of the API. Lint runs after tier-1 + e2e and also fails the
# script; use `./ci.sh lint` to iterate on fmt/clippy alone.

set -euo pipefail
cd "$(dirname "$0")"

lint() {
    echo "== cargo fmt --check =="
    cargo fmt --check
    echo "== cargo clippy (all targets, -D warnings + repo deny-set) =="
    cargo clippy --all-targets -- -D warnings \
        -D clippy::undocumented_unsafe_blocks
}

verify() {
    echo "== verify: static plan checks over artifacts/ =="
    local out
    out="$(mktemp)"
    cargo run --release --quiet -- verify 2>&1 | tee "$out"
    if grep -q "skipping:" "$out"; then
        rm -f "$out"
        echo "verify FAILED: artifacts not built — the plan checks must run"
        exit 1
    fi
    rm -f "$out"
    echo "verify OK"
}

doc() {
    echo "== cargo doc --no-deps (RUSTDOCFLAGS=-D warnings) =="
    RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet
}

# bench_json <raw-output> <out.json>: convert `BENCH\t...` report lines
# into a JSON array. Field layout (util/bench.rs BenchResult::report):
#   BENCH <name> iters=N mean=T median=T min=T std=T [throughput=X u/s]
# with T carrying a ns/us/ms/s suffix; all times are normalized to ns.
bench_json() {
    awk -F'\t' '
    function to_ns(s) {
        if (s ~ /ns$/) return substr(s, 1, length(s) - 2) + 0
        if (s ~ /us$/) return (substr(s, 1, length(s) - 2) + 0) * 1e3
        if (s ~ /ms$/) return (substr(s, 1, length(s) - 2) + 0) * 1e6
        return (substr(s, 1, length(s) - 1) + 0) * 1e9
    }
    BEGIN { printf "["; n = 0 }
    $1 == "BENCH" && NF >= 7 {
        name = $2
        iters = substr($3, 7) + 0
        mean = to_ns(substr($4, 6))
        median = to_ns(substr($5, 8))
        min = to_ns(substr($6, 5))
        std = to_ns(substr($7, 5))
        has_thr = 0
        if (NF >= 8 && $8 ~ /^throughput=/) {
            split(substr($8, 12), a, " ")
            thr = a[1] + 0
            unit = a[2]
            sub(/\/s$/, "", unit)
            has_thr = 1
        }
        if (n++) printf ","
        printf "\n  {\"name\":\"%s\",\"iters\":%d,\"mean_ns\":%.1f,\"median_ns\":%.1f,\"min_ns\":%.1f,\"std_ns\":%.1f", \
            name, iters, mean, median, min, std
        if (has_thr) printf ",\"throughput_per_s\":%.4e,\"throughput_unit\":\"%s\"", thr, unit
        printf "}"
    }
    END { printf "\n]\n" }
    ' "$1" > "$2"
    echo "wrote $2 ($(grep -c '"name"' "$2") cases)"
}

bench() {
    local tmp
    tmp="$(mktemp -d)"
    echo "== cargo bench --bench bench_device =="
    cargo bench --bench bench_device | tee "$tmp/device.out"
    echo "== cargo bench --bench bench_optimizers =="
    cargo bench --bench bench_optimizers | tee "$tmp/optimizers.out"
    echo "== cargo bench --bench bench_train_step =="
    cargo bench --bench bench_train_step | tee "$tmp/train_step.out"
    bench_json "$tmp/device.out" BENCH_device.json
    cat "$tmp/optimizers.out" "$tmp/train_step.out" > "$tmp/optimizers_all.out"
    bench_json "$tmp/optimizers_all.out" BENCH_optimizers.json
    rm -rf "$tmp"
}

# bench_check: per-case min_ns vs BENCH_baseline/<file>; >25% slower
# fails. Absent baselines are bootstrapped (first run on a new machine
# or after a reset) — commit them to arm the gate.
bench_check() {
    local fresh base fail=0
    mkdir -p BENCH_baseline
    for fresh in BENCH_device.json BENCH_optimizers.json; do
        base="BENCH_baseline/$fresh"
        if [ ! -f "$base" ]; then
            cp "$fresh" "$base"
            echo "bench --check: no baseline for $fresh; bootstrapped $base — commit it"
            continue
        fi
        if ! awk '
        function getname(s) { match(s, /"name":"[^"]*"/); return substr(s, RSTART + 8, RLENGTH - 9) }
        function getmin(s)  { match(s, /"min_ns":[0-9.]+/); return substr(s, RSTART + 9, RLENGTH - 9) + 0 }
        NR == FNR { if ($0 ~ /"name"/) base[getname($0)] = getmin($0); next }
        $0 ~ /"name"/ {
            n = getname($0); m = getmin($0)
            if (n in base && base[n] > 0) {
                if (m > base[n] * 1.25) {
                    printf "  REGRESSION %s: min_ns %.1f vs baseline %.1f (+%.0f%%)\n", n, m, base[n], 100 * (m / base[n] - 1)
                    bad = 1
                } else {
                    printf "  ok %s: min_ns %.1f vs baseline %.1f\n", n, m, base[n]
                }
            } else {
                printf "  new case %s (no baseline)\n", n
            }
        }
        END { exit bad }
        ' "$base" "$fresh"; then
            fail=1
        fi
    done
    if [ "$fail" -ne 0 ]; then
        echo "bench --check FAILED: >25% min_ns regression against BENCH_baseline/"
        exit 1
    fi
    echo "bench --check OK"
}

e2e() {
    echo "== e2e: artifact-gated tests on the HLO interpreter (release) =="
    local out
    out="$(mktemp)"
    cargo test --release --test runtime_integration --test trainer_integration \
        --test interp_golden --test plan_equivalence --test verify_plans \
        --test fault_recovery \
        -- --nocapture 2>&1 | tee "$out"
    if grep -q "skipping:" "$out"; then
        rm -f "$out"
        echo "e2e FAILED: artifact-gated tests skipped — the NN-scale path must run"
        exit 1
    fi
    rm -f "$out"
    echo "== e2e: train_digits_e2e (reduced budget) =="
    cargo run --release --example train_digits_e2e 150
    echo "== e2e: rider table1 (reduced budget) =="
    cargo run --release -- table1 --steps 20 --seeds 1
    echo "== e2e: rider faultsweep (reduced smoke grid) =="
    cargo run --release -- faultsweep --steps 20 --seeds 1 \
        --methods residual,rider --families drift --rates 0.2
    echo "e2e OK"
}

case "${1:-}" in
    lint)
        lint
        exit 0
        ;;
    doc)
        doc
        exit 0
        ;;
    e2e)
        e2e
        exit 0
        ;;
    verify)
        verify
        exit 0
        ;;
    bench)
        bench
        if [ "${2:-}" = "--check" ]; then
            bench_check
        fi
        exit 0
        ;;
esac

echo "== tier-1: cargo build --release --all-targets =="
cargo build --release --all-targets

echo "== tier-1: cargo test -q =="
cargo test -q

verify
e2e
doc
lint
echo "CI OK"
