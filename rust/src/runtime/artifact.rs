//! Artifact registry: parses `artifacts/manifest.json` produced by
//! `python/compile/aot.py` and exposes typed descriptions of every AOT
//! artifact (inputs/outputs, shapes, dtypes) and model state layout.

#![warn(missing_docs)]

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Element type of an artifact input or output tensor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    /// 32-bit float.
    F32,
    /// 32-bit signed integer.
    I32,
    /// 32-bit unsigned integer.
    U32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "f32" => Dtype::F32,
            "i32" => Dtype::I32,
            "u32" => Dtype::U32,
            other => bail!("unknown dtype {other}"),
        })
    }
}

/// One input or output tensor of an artifact, as named in the manifest.
#[derive(Clone, Debug)]
pub struct IoSpec {
    /// Manifest name of the tensor (e.g. `t0.w`, `key`, `hypers`).
    pub name: String,
    /// Row-major dimensions.
    pub shape: Vec<usize>,
    /// Element type.
    pub dtype: Dtype,
}

impl IoSpec {
    /// Total element count.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(j: &Json) -> Result<Self> {
        Ok(IoSpec {
            name: j
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("io missing name"))?
                .to_string(),
            shape: j
                .get("shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("io missing shape"))?
                .iter()
                .filter_map(Json::as_usize)
                .collect(),
            dtype: Dtype::parse(
                j.get("dtype").and_then(Json::as_str).unwrap_or("f32"),
            )?,
        })
    }
}

/// One AOT artifact: the HLO-text file plus its typed I/O contract.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    /// Registry name (e.g. `fcn_step_erider`).
    pub name: String,
    /// Path of the HLO-text file.
    pub file: PathBuf,
    /// Input tensors, in call order.
    pub inputs: Vec<IoSpec>,
    /// Output tensors, in root-tuple order.
    pub outputs: Vec<IoSpec>,
}

/// One leaf of a model's flat training state.
#[derive(Clone, Debug)]
pub struct StateLeaf {
    /// Leaf name (e.g. `t0.w`).
    pub name: String,
    /// Row-major dimensions.
    pub shape: Vec<usize>,
    /// role: w | p | q | h | wap | wam | pap | pam | c | bias
    pub role: String,
    /// Analog tile index the leaf belongs to.
    pub tile: usize,
}

impl StateLeaf {
    /// Total element count.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One trainable model as described by the manifest.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    /// Model name (`fcn | lenet | convnet3`).
    pub name: String,
    /// Training batch size the step artifacts were lowered with.
    pub batch: usize,
    /// Evaluation batch size the eval artifacts were lowered with.
    pub eval_batch: usize,
    /// Flattened input dimension.
    pub d_in: usize,
    /// Number of output classes.
    pub n_classes: usize,
    /// Flat training-state layout, in artifact I/O order.
    pub state: Vec<StateLeaf>,
}

impl ModelSpec {
    /// Total trainable analog weights (`w` leaves).
    pub fn n_weights(&self) -> usize {
        self.state
            .iter()
            .filter(|l| l.role == "w")
            .map(StateLeaf::numel)
            .sum()
    }
}

/// The parsed artifact manifest: models, artifacts and the
/// hyper/device parameter-vector layouts.
#[derive(Debug)]
pub struct Registry {
    /// Directory the manifest (and artifact files) live in.
    pub dir: PathBuf,
    /// Models by name.
    pub models: BTreeMap<String, ModelSpec>,
    /// Artifacts by name.
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    /// index of each hyperparameter in the hypers input vector
    pub hyper_index: BTreeMap<String, usize>,
    /// Length of the hypers input vector.
    pub n_hypers: usize,
    /// index of each device parameter in the dev input vector
    pub dev_index: BTreeMap<String, usize>,
    /// Length of the dev input vector.
    pub n_dev: usize,
}

impl Registry {
    /// Parse `<dir>/manifest.json` into a registry.
    pub fn load(dir: impl AsRef<Path>) -> Result<Registry> {
        let dir = dir.as_ref().to_path_buf();
        let man_path = dir.join("manifest.json");
        let src = std::fs::read_to_string(&man_path)
            .with_context(|| format!("reading {}", man_path.display()))?;
        let j = Json::parse(&src).map_err(|e| anyhow!("manifest parse: {e}"))?;

        let mut models = BTreeMap::new();
        for (name, m) in j
            .get("models")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing models"))?
        {
            let state = m
                .get("state")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("model {name} missing state"))?
                .iter()
                .map(|l| {
                    Ok(StateLeaf {
                        name: l
                            .get("name")
                            .and_then(Json::as_str)
                            .ok_or_else(|| anyhow!("leaf missing name"))?
                            .to_string(),
                        shape: l
                            .get("shape")
                            .and_then(Json::as_arr)
                            .ok_or_else(|| anyhow!("leaf missing shape"))?
                            .iter()
                            .filter_map(Json::as_usize)
                            .collect(),
                        role: l
                            .get("role")
                            .and_then(Json::as_str)
                            .unwrap_or("")
                            .to_string(),
                        tile: l.get("tile").and_then(Json::as_usize).unwrap_or(0),
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            models.insert(
                name.clone(),
                ModelSpec {
                    name: name.clone(),
                    batch: m.get("batch").and_then(Json::as_usize).unwrap_or(16),
                    eval_batch: m.get("eval_batch").and_then(Json::as_usize).unwrap_or(200),
                    d_in: m.get("d_in").and_then(Json::as_usize).unwrap_or(0),
                    n_classes: m.get("n_classes").and_then(Json::as_usize).unwrap_or(10),
                    state,
                },
            );
        }

        let mut artifacts = BTreeMap::new();
        for (name, a) in j
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?
        {
            let parse_ios = |key: &str| -> Result<Vec<IoSpec>> {
                a.get(key)
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("artifact {name} missing {key}"))?
                    .iter()
                    .map(IoSpec::parse)
                    .collect()
            };
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    file: dir.join(
                        a.get("file")
                            .and_then(Json::as_str)
                            .ok_or_else(|| anyhow!("artifact {name} missing file"))?,
                    ),
                    inputs: parse_ios("inputs")?,
                    outputs: parse_ios("outputs")?,
                },
            );
        }

        let idx_map = |key: &str| -> BTreeMap<String, usize> {
            j.get(key)
                .and_then(Json::as_obj)
                .map(|m| {
                    m.iter()
                        .filter(|(k, _)| !k.starts_with("n_"))
                        .filter_map(|(k, v)| v.as_usize().map(|i| (k.clone(), i)))
                        .collect()
                })
                .unwrap_or_default()
        };
        let hyper_index = idx_map("hyper_index");
        let dev_index = idx_map("dev_index");
        let n_hypers = j
            .get("hyper_index")
            .and_then(|h| h.get("n_hypers"))
            .and_then(Json::as_usize)
            .unwrap_or(12);
        let n_dev = j
            .get("dev_index")
            .and_then(|h| h.get("n_dev"))
            .and_then(Json::as_usize)
            .unwrap_or(8);

        Ok(Registry {
            dir,
            models,
            artifacts,
            hyper_index,
            n_hypers,
            dev_index,
            n_dev,
        })
    }

    /// Look up a model by name.
    pub fn model(&self, name: &str) -> Result<&ModelSpec> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("unknown model '{name}'"))
    }

    /// Look up an artifact by name.
    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}' (run `make artifacts`)"))
    }

    /// Default artifacts directory: $RIDER_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var("RIDER_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_manifest() {
        let dir = std::env::temp_dir().join("rider_test_manifest");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{
  "models": {"m": {"batch": 4, "eval_batch": 8, "d_in": 6, "n_classes": 2,
    "state": [{"name": "t0.w", "shape": [6, 2], "role": "w", "tile": 0}]}},
  "artifacts": {"m_init": {"file": "m_init.hlo.txt",
    "inputs": [{"name": "key", "shape": [2], "dtype": "u32"}],
    "outputs": [{"name": "t0.w", "shape": [6, 2], "dtype": "f32"}]}},
  "hyper_index": {"lr_fast": 0, "n_hypers": 12},
  "dev_index": {"dw_min": 0, "n_dev": 8}
}"#,
        )
        .unwrap();
        let reg = Registry::load(&dir).unwrap();
        let m = reg.model("m").unwrap();
        assert_eq!(m.batch, 4);
        assert_eq!(m.n_weights(), 12);
        let a = reg.artifact("m_init").unwrap();
        assert_eq!(a.inputs[0].dtype, Dtype::U32);
        assert_eq!(a.outputs[0].numel(), 12);
        assert_eq!(reg.hyper_index["lr_fast"], 0);
        assert!(reg.artifact("nope").is_err());
    }
}
