//! Static plan verifier: independent soundness checking for the
//! planned HLO engine.
//!
//! [`verify_plan`] re-derives, **without executing**, everything the
//! planner ([`crate::runtime::plan`]) promises about a compiled
//! [`Plan`] and cross-checks the plan's recorded metadata against its
//! own derivation:
//!
//! 1. **Program** — every step defines a slot exactly once, every read
//!    happens strictly after its definition, and the plan's bookkeeping
//!    tables (src, consts, literal slots, parameters, root) are
//!    internally consistent.
//! 2. **Alias** — reshape / get-tuple-element chains terminate (no
//!    cycles) and every alias records exactly the value source of its
//!    resolved producer.
//! 3. **Buffer** — the reuse plan is sound: recompute live ranges from
//!    the reads and prove that any two slots sharing a pooled buffer
//!    have disjoint ranges, with matching dtype and sufficient
//!    capacity.
//! 4. **Shape** — full per-op shape/dtype re-inference over the parsed
//!    module, compared against every instruction's declared shape.
//! 5. **Fusion** — fused groups are legal: all members elementwise with
//!    one block length, non-root members have no outside consumers,
//!    slab references point at earlier members, external inputs carry
//!    the resolved source and the right scalar-splat flag.
//! 6. **While** — loop state contracts: condition/body take exactly the
//!    loop state shape and the body's root returns it; the condition
//!    root is a scalar predicate.
//!
//! The verifier deliberately shares **no derivation code** with the
//! planner (same design as `execute` vs `execute_ref`): it reads the
//! plan's records through [`crate::runtime::plan::Plan::inspect`] but
//! re-resolves aliases, re-infers shapes, and re-computes liveness from
//! the instruction list alone. A planner bug and a matching verifier
//! bug would have to be introduced independently to slip through.
//!
//! Wired in at three layers: `PjRtClient::compile` (debug builds, or
//! `RIDER_VERIFY=1` in release), the `rider verify` CLI subcommand
//! (every module under `artifacts/`), and the `./ci.sh verify` stage.

use crate::runtime::interp::{
    iota_values, lit_dims, lit_dt, BinOp, Computation, Dt, HloModule, Op, Shape, UnOp,
};
use crate::runtime::plan::{to_sdt, CompPlan, FOp, FRef, Group, Plan, SDt, Step, ValSrc};
use crate::runtime::xla::{Data, Literal, XlaError};

/// Maximum array rank the planned engine's fixed-size index registers
/// support (re-stated here independently of the planner's constant).
const MAX_RANK: usize = 16;

// --------------------------------------------------------------- errors

/// One verification failure, tagged by check class. Each variant names
/// the computation it fired in plus a human-readable detail string; the
/// negative tests assert on the variant, never on the text.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum VerifyError {
    /// Def-before-use / single-definition / bookkeeping-table failure.
    Program {
        /// Computation the check fired in.
        comp: String,
        /// What went wrong.
        detail: String,
    },
    /// Alias chain does not terminate or records the wrong source.
    Alias {
        /// Computation the check fired in.
        comp: String,
        /// What went wrong.
        detail: String,
    },
    /// Buffer-plan unsoundness: overlapping live ranges, dtype or
    /// capacity mismatch on a pooled buffer.
    Buffer {
        /// Computation the check fired in.
        comp: String,
        /// What went wrong.
        detail: String,
    },
    /// Declared shape/dtype disagrees with re-inference.
    Shape {
        /// Computation the check fired in.
        comp: String,
        /// What went wrong.
        detail: String,
    },
    /// Fusion-group illegality.
    Fusion {
        /// Computation the check fired in.
        comp: String,
        /// What went wrong.
        detail: String,
    },
    /// `while` loop state contract violation.
    While {
        /// Computation the check fired in.
        comp: String,
        /// What went wrong.
        detail: String,
    },
}

impl VerifyError {
    fn program(comp: &str, detail: impl Into<String>) -> VerifyError {
        VerifyError::Program { comp: comp.into(), detail: detail.into() }
    }

    fn alias(comp: &str, detail: impl Into<String>) -> VerifyError {
        VerifyError::Alias { comp: comp.into(), detail: detail.into() }
    }

    fn buffer(comp: &str, detail: impl Into<String>) -> VerifyError {
        VerifyError::Buffer { comp: comp.into(), detail: detail.into() }
    }

    fn shape(comp: &str, detail: impl Into<String>) -> VerifyError {
        VerifyError::Shape { comp: comp.into(), detail: detail.into() }
    }

    fn fusion(comp: &str, detail: impl Into<String>) -> VerifyError {
        VerifyError::Fusion { comp: comp.into(), detail: detail.into() }
    }

    fn whilev(comp: &str, detail: impl Into<String>) -> VerifyError {
        VerifyError::While { comp: comp.into(), detail: detail.into() }
    }

    /// The check class, as a stable diagnostic prefix.
    pub fn class(&self) -> &'static str {
        match self {
            VerifyError::Program { .. } => "Program",
            VerifyError::Alias { .. } => "Alias",
            VerifyError::Buffer { .. } => "Buffer",
            VerifyError::Shape { .. } => "Shape",
            VerifyError::Fusion { .. } => "Fusion",
            VerifyError::While { .. } => "While",
        }
    }
}

impl std::fmt::Display for VerifyError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let (comp, detail) = match self {
            VerifyError::Program { comp, detail }
            | VerifyError::Alias { comp, detail }
            | VerifyError::Buffer { comp, detail }
            | VerifyError::Shape { comp, detail }
            | VerifyError::Fusion { comp, detail }
            | VerifyError::While { comp, detail } => (comp, detail),
        };
        write!(f, "{}[{}]: {}", self.class(), comp, detail)
    }
}

impl std::error::Error for VerifyError {}

// ---------------------------------------------------------------- stats

/// Aggregate statistics of a verified module, summed over its
/// computations (the `rider verify` subcommand prints these per
/// module).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct VerifyStats {
    /// Computations in the module.
    pub computations: usize,
    /// Total parsed instructions.
    pub instructions: usize,
    /// Executable steps across all computation programs.
    pub steps: usize,
    /// Fused elementwise groups.
    pub groups: usize,
    /// Fused members across all groups.
    pub members: usize,
    /// Pooled buffers allocated by the plans.
    pub buffers: usize,
    /// Buffer-backed slots (each occupies one pooled buffer for its
    /// live range).
    pub buffer_slots: usize,
}

impl VerifyStats {
    /// Buffer reuse ratio: buffer-backed slots per pooled buffer
    /// (1.0 when nothing is reused or no buffers exist).
    pub fn reuse_ratio(&self) -> f64 {
        if self.buffers == 0 {
            1.0
        } else {
            self.buffer_slots as f64 / self.buffers as f64
        }
    }
}

// ---------------------------------------------------------- entry points

/// Statically verify a compiled [`Plan`] against its parsed module.
///
/// Returns aggregate [`VerifyStats`] on success, or the first
/// [`VerifyError`] found. Runs all shape/while checks over every
/// computation first, then the program / alias / buffer / fusion
/// checks per computation.
pub fn verify_plan(plan: &Plan) -> Result<VerifyStats, VerifyError> {
    let ins = plan.inspect();
    let module = ins.module;
    let comps = ins.comps;
    if comps.len() != module.computations.len() {
        return Err(VerifyError::program("<module>", "plan/computation count mismatch"));
    }
    for ci in 0..module.computations.len() {
        check_shapes(module, ci)?;
    }
    let mut stats = VerifyStats {
        computations: module.computations.len(),
        ..VerifyStats::default()
    };
    for (ci, cp) in comps.iter().enumerate() {
        check_comp(module, ci, cp, &mut stats)?;
    }
    Ok(stats)
}

/// Parse, plan, and statically verify one HLO-text module (the CLI
/// `verify` subcommand and the artifact-sweep integration test).
pub fn verify_hlo_text(src: &str) -> Result<VerifyStats, XlaError> {
    let module = crate::runtime::interp::parse(src)?;
    let plan = Plan::new(std::rc::Rc::new(module))?;
    verify_plan(&plan).map_err(|e| XlaError(format!("plan verification failed: {e}")))
}

// ------------------------------------------------------ alias resolution

/// A resolved (alias-free) value source: a real producing instruction,
/// or one element of a tuple-shaped parameter / `while` result.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Res {
    Inst(usize),
    ParamPart(usize, usize),
    WhilePart(usize, usize),
}

/// Follow reshape / gte chains from operand `o` to a real producer.
/// `fuel` bounds the walk (instruction count + 1): running out means
/// the chain cycles, which the planner can never emit.
fn resolve(comp: &Computation, cname: &str, mut o: usize, mut fuel: usize) -> Result<Res, VerifyError> {
    loop {
        if fuel == 0 {
            return Err(VerifyError::alias(
                cname,
                format!("alias chain at slot {o} does not terminate (cycle)"),
            ));
        }
        fuel -= 1;
        let Some(ins) = comp.instrs.get(o) else {
            return Err(VerifyError::program(cname, format!("operand index {o} out of range")));
        };
        match &ins.op {
            Op::Reshape => {
                let (Some(&next), 1) = (ins.operands.first(), ins.operands.len()) else {
                    return Err(VerifyError::program(cname, format!("reshape at {o}: operand count")));
                };
                o = next;
            }
            Op::Gte { index } => {
                let (Some(&inner), 1) = (ins.operands.first(), ins.operands.len()) else {
                    return Err(VerifyError::program(cname, format!("gte at {o}: operand count")));
                };
                let j = *index;
                match resolve(comp, cname, inner, fuel)? {
                    Res::Inst(t) => match &comp.instrs[t].op {
                        Op::Tuple => match comp.instrs[t].operands.get(j) {
                            Some(&e) => o = e,
                            None => {
                                return Err(VerifyError::alias(
                                    cname,
                                    format!("gte at {o}: index {j} out of range"),
                                ));
                            }
                        },
                        Op::While { .. } => return Ok(Res::WhilePart(t, j)),
                        Op::Parameter(_) => return Ok(Res::ParamPart(t, j)),
                        _ => {
                            return Err(VerifyError::alias(
                                cname,
                                format!("gte at {o}: operand is not tuple-valued"),
                            ));
                        }
                    },
                    Res::ParamPart(..) | Res::WhilePart(..) => {
                        return Err(VerifyError::alias(
                            cname,
                            format!("gte at {o}: nested tuple parts"),
                        ));
                    }
                }
            }
            _ => return Ok(Res::Inst(o)),
        }
    }
}

/// Shape of a resolved source (element shape for tuple parts).
fn resolved_shape<'c>(comp: &'c Computation, cname: &str, r: Res) -> Result<&'c Shape, VerifyError> {
    match r {
        Res::Inst(s) => Ok(&comp.instrs[s].shape),
        Res::ParamPart(p, j) | Res::WhilePart(p, j) => match &comp.instrs[p].shape {
            Shape::Tuple(parts) => parts
                .get(j)
                .ok_or_else(|| VerifyError::alias(cname, "tuple element index out of range")),
            Shape::Array { .. } => {
                Err(VerifyError::alias(cname, "tuple part of non-tuple shape"))
            }
        },
    }
}

/// The [`ValSrc`] a correct plan must record for a resolved source.
fn res_valsrc(comp: &Computation, cp: &CompPlan, r: Res) -> ValSrc {
    match r {
        Res::Inst(t) => cp.src[t],
        Res::ParamPart(p, j) => match comp.instrs[p].op {
            Op::Parameter(k) => ValSrc::ParamPart(k, j),
            // unreachable: resolve only returns ParamPart for parameters
            _ => ValSrc::Dead,
        },
        Res::WhilePart(w, j) => match cp.src[w] {
            ValSrc::Lit(li) => ValSrc::LitPart(li, j),
            // a dead while: its parts are never materialized
            _ => ValSrc::Dead,
        },
    }
}

// ------------------------------------------------------ shape inference

fn arr_shape<'c>(
    comp: &'c Computation,
    cname: &str,
    i: usize,
    o: usize,
) -> Result<(Dt, &'c [usize]), VerifyError> {
    match &comp.instrs[o].shape {
        Shape::Array { dt, dims } => Ok((*dt, dims.as_slice())),
        Shape::Tuple(_) => Err(VerifyError::shape(
            cname,
            format!("slot {i}: operand {o} is tuple-shaped"),
        )),
    }
}

fn arr_of<'s>(sh: &'s Shape, cname: &str, i: usize) -> Result<(Dt, &'s [usize]), VerifyError> {
    match sh {
        Shape::Array { dt, dims } => Ok((*dt, dims.as_slice())),
        Shape::Tuple(_) => Err(VerifyError::shape(
            cname,
            format!("slot {i}: tuple shape on array-valued op"),
        )),
    }
}

fn numel(dims: &[usize]) -> usize {
    dims.iter().product()
}

fn data_len(l: &Literal) -> usize {
    match &l.data {
        Data::F32(v) => v.len(),
        Data::I32(v) => v.len(),
        Data::U32(v) => v.len(),
        Data::Pred(v) => v.len(),
        Data::Tuple(_) => 0,
    }
}

/// Exact (bit-level for f32) literal equality; tuples never compare
/// equal (plan constants are always arrays).
fn lit_eq(a: &Literal, b: &Literal) -> bool {
    if a.dims != b.dims {
        return false;
    }
    match (&a.data, &b.data) {
        (Data::F32(x), Data::F32(y)) => {
            x.len() == y.len() && x.iter().zip(y).all(|(p, q)| p.to_bits() == q.to_bits())
        }
        (Data::I32(x), Data::I32(y)) => x == y,
        (Data::U32(x), Data::U32(y)) => x == y,
        (Data::Pred(x), Data::Pred(y)) => x == y,
        _ => false,
    }
}

/// Independently re-derive the literal a folded `iota` must produce.
fn iota_literal(shape: &Shape, dim: usize) -> Option<Literal> {
    let Shape::Array { dt, dims } = shape else { return None };
    let vals = iota_values(dims, dim);
    let dims_i: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    let data = match dt {
        Dt::U32 => Data::U32(vals.iter().map(|&v| v as u32).collect()),
        Dt::S32 => Data::I32(vals.iter().map(|&v| v as i32).collect()),
        Dt::F32 => Data::F32(vals.iter().map(|&v| v as f32).collect()),
        Dt::Pred => return None,
    };
    Some(Literal { data, dims: dims_i })
}

/// Re-infer every instruction's shape/dtype from its operands and
/// compare with the declared shape (check class 4), including the
/// `while` state contracts (check class 6). Runs before the per-plan
/// checks so those can index operands without re-validating bounds.
fn check_shapes(module: &HloModule, ci: usize) -> Result<(), VerifyError> {
    let comp = &module.computations[ci];
    let cname = comp.name.as_str();
    let n = comp.instrs.len();
    for (i, ins) in comp.instrs.iter().enumerate() {
        for &o in &ins.operands {
            if o >= n {
                return Err(VerifyError::program(
                    cname,
                    format!("slot {i}: operand {o} out of range"),
                ));
            }
        }
        let fail = |what: String| VerifyError::shape(cname, format!("slot {i}: {what}"));
        let nops = |c: usize| -> Result<(), VerifyError> {
            if ins.operands.len() == c {
                Ok(())
            } else {
                Err(fail(format!("expected {c} operands, got {}", ins.operands.len())))
            }
        };
        match &ins.op {
            Op::Parameter(k) => {
                nops(0)?;
                if *k >= comp.params.len() || comp.params[*k] != i {
                    return Err(fail(format!("parameter({k}) binding mismatch")));
                }
            }
            Op::Constant(l) => {
                nops(0)?;
                let (dt, dims) = arr_of(&ins.shape, cname, i)?;
                if lit_dt(l) != Some(dt) || lit_dims(l) != dims || data_len(l) != numel(dims) {
                    return Err(fail("constant: literal/shape mismatch".into()));
                }
            }
            Op::Iota { dim } => {
                nops(0)?;
                let (dt, dims) = arr_of(&ins.shape, cname, i)?;
                if dt == Dt::Pred {
                    return Err(fail("iota dtype".into()));
                }
                if dims.is_empty() || *dim >= dims.len() {
                    return Err(fail("iota dimension out of range".into()));
                }
            }
            Op::Bin(b) => {
                nops(2)?;
                let a = arr_shape(comp, cname, i, ins.operands[0])?;
                let bb = arr_shape(comp, cname, i, ins.operands[1])?;
                if a != bb {
                    return Err(fail("binary operand shapes differ".into()));
                }
                let allowed = match a.0 {
                    Dt::F32 => matches!(
                        b,
                        BinOp::Add
                            | BinOp::Sub
                            | BinOp::Mul
                            | BinOp::Div
                            | BinOp::Max
                            | BinOp::Min
                            | BinOp::Pow
                    ),
                    Dt::S32 => matches!(
                        b,
                        BinOp::Add
                            | BinOp::Sub
                            | BinOp::Mul
                            | BinOp::Max
                            | BinOp::Min
                            | BinOp::And
                            | BinOp::Or
                            | BinOp::Xor
                    ),
                    Dt::U32 => !matches!(b, BinOp::Pow),
                    Dt::Pred => true,
                };
                if !allowed {
                    return Err(fail(format!("binary op {b:?} unsupported on {:?}", a.0)));
                }
                if arr_of(&ins.shape, cname, i)? != a {
                    return Err(fail("binary: declared shape mismatch".into()));
                }
            }
            Op::Un(u) => {
                nops(1)?;
                let a = arr_shape(comp, cname, i, ins.operands[0])?;
                let ok = match a.0 {
                    Dt::F32 => *u != UnOp::Not,
                    Dt::U32 | Dt::Pred => *u == UnOp::Not,
                    Dt::S32 => matches!(u, UnOp::Neg | UnOp::Abs),
                };
                if !ok {
                    return Err(fail(format!("unary op {u:?} unsupported on {:?}", a.0)));
                }
                if arr_of(&ins.shape, cname, i)? != a {
                    return Err(fail("unary: declared shape mismatch".into()));
                }
            }
            Op::Compare(_) => {
                nops(2)?;
                let a = arr_shape(comp, cname, i, ins.operands[0])?;
                let bb = arr_shape(comp, cname, i, ins.operands[1])?;
                if a != bb || a.0 == Dt::Pred {
                    return Err(fail("compare operand shapes".into()));
                }
                if arr_of(&ins.shape, cname, i)? != (Dt::Pred, a.1) {
                    return Err(fail("compare: declared shape mismatch".into()));
                }
            }
            Op::Select => {
                nops(3)?;
                let p = arr_shape(comp, cname, i, ins.operands[0])?;
                let a = arr_shape(comp, cname, i, ins.operands[1])?;
                let bb = arr_shape(comp, cname, i, ins.operands[2])?;
                if p.0 != Dt::Pred {
                    return Err(fail("select predicate dtype".into()));
                }
                if a != bb || !matches!(a.0, Dt::F32 | Dt::U32) {
                    return Err(fail("select branch shapes".into()));
                }
                let pn = numel(p.1);
                if pn != 1 && pn != numel(a.1) {
                    return Err(fail("select predicate numel".into()));
                }
                if arr_of(&ins.shape, cname, i)? != a {
                    return Err(fail("select: declared shape mismatch".into()));
                }
            }
            Op::Clamp => {
                nops(3)?;
                let lo = arr_shape(comp, cname, i, ins.operands[0])?;
                let x = arr_shape(comp, cname, i, ins.operands[1])?;
                let hi = arr_shape(comp, cname, i, ins.operands[2])?;
                if lo.0 != Dt::F32 || x.0 != Dt::F32 || hi.0 != Dt::F32 {
                    return Err(fail("clamp operand dtypes".into()));
                }
                let nx = numel(x.1);
                for bn in [numel(lo.1), numel(hi.1)] {
                    if bn != 1 && bn != nx {
                        return Err(fail("clamp bound numel".into()));
                    }
                }
                if arr_of(&ins.shape, cname, i)? != x {
                    return Err(fail("clamp: declared shape mismatch".into()));
                }
            }
            Op::Convert => {
                nops(1)?;
                let a = arr_shape(comp, cname, i, ins.operands[0])?;
                let (_, dims) = arr_of(&ins.shape, cname, i)?;
                if dims != a.1 {
                    return Err(fail("convert: declared dims mismatch".into()));
                }
            }
            Op::Broadcast { dims } => {
                nops(1)?;
                let a = arr_shape(comp, cname, i, ins.operands[0])?;
                let (odt, odims) = arr_of(&ins.shape, cname, i)?;
                if dims.len() != a.1.len() {
                    return Err(fail("broadcast dimensions length".into()));
                }
                for (pos, &od) in dims.iter().enumerate() {
                    if od >= odims.len() || odims[od] != a.1[pos] {
                        return Err(fail("broadcast dimension mapping".into()));
                    }
                }
                if odt != a.0 {
                    return Err(fail("broadcast dtype".into()));
                }
            }
            Op::Reshape => {
                nops(1)?;
                let a = arr_shape(comp, cname, i, ins.operands[0])?;
                let (dt, dims) = arr_of(&ins.shape, cname, i)?;
                if dt != a.0 || numel(dims) != numel(a.1) {
                    return Err(fail("reshape: dtype/numel mismatch".into()));
                }
            }
            Op::Transpose { perm } => {
                nops(1)?;
                let a = arr_shape(comp, cname, i, ins.operands[0])?;
                let mut seen = vec![false; a.1.len()];
                let is_perm = perm.len() == a.1.len()
                    && perm.iter().all(|&p| p < seen.len() && !std::mem::replace(&mut seen[p], true));
                if !is_perm {
                    return Err(fail("transpose: not a permutation".into()));
                }
                let derived: Vec<usize> = perm.iter().map(|&p| a.1[p]).collect();
                if arr_of(&ins.shape, cname, i)? != (a.0, derived.as_slice()) {
                    return Err(fail("transpose: declared shape mismatch".into()));
                }
            }
            Op::Slice { starts, limits, strides } => {
                nops(1)?;
                let a = arr_shape(comp, cname, i, ins.operands[0])?;
                if starts.len() != a.1.len() || limits.len() != a.1.len() || strides.len() != a.1.len()
                {
                    return Err(fail("slice rank".into()));
                }
                let mut derived = Vec::with_capacity(a.1.len());
                for (d, &sd) in a.1.iter().enumerate() {
                    if limits[d] > sd || starts[d] > limits[d] || strides[d] == 0 {
                        return Err(fail("slice bounds".into()));
                    }
                    derived.push((limits[d] - starts[d]).div_ceil(strides[d]));
                }
                if arr_of(&ins.shape, cname, i)? != (a.0, derived.as_slice()) {
                    return Err(fail("slice: declared shape mismatch".into()));
                }
            }
            Op::Concat { dim } => {
                if ins.operands.is_empty() {
                    return Err(fail("concatenate needs operands".into()));
                }
                let first = arr_shape(comp, cname, i, ins.operands[0])?;
                if *dim >= first.1.len() {
                    return Err(fail("concatenate dim out of range".into()));
                }
                let mut total = 0usize;
                for &o in &ins.operands {
                    let a = arr_shape(comp, cname, i, o)?;
                    if a.0 != first.0 || a.1.len() != first.1.len() {
                        return Err(fail("concatenate operand dtype/rank".into()));
                    }
                    for (dd, (&x, &y)) in a.1.iter().zip(first.1).enumerate() {
                        if dd != *dim && x != y {
                            return Err(fail(format!("concatenate dim {dd} mismatch")));
                        }
                    }
                    total += a.1[*dim];
                }
                let mut derived = first.1.to_vec();
                derived[*dim] = total;
                if arr_of(&ins.shape, cname, i)? != (first.0, derived.as_slice()) {
                    return Err(fail("concatenate: declared shape mismatch".into()));
                }
            }
            Op::Pad { low, high, interior } => {
                nops(2)?;
                let a = arr_shape(comp, cname, i, ins.operands[0])?;
                let pv = arr_shape(comp, cname, i, ins.operands[1])?;
                if low.len() != a.1.len() || high.len() != a.1.len() || interior.len() != a.1.len()
                {
                    return Err(fail("pad rank".into()));
                }
                if a.0 == Dt::Pred || pv.0 != a.0 || numel(pv.1) == 0 {
                    return Err(fail("pad value".into()));
                }
                let mut derived = Vec::with_capacity(a.1.len());
                for (d, &sd) in a.1.iter().enumerate() {
                    let od = sd as i64
                        + (sd.saturating_sub(1) * interior[d]) as i64
                        + low[d]
                        + high[d];
                    if od < 0 {
                        return Err(fail("pad: negative output dim".into()));
                    }
                    derived.push(od as usize);
                }
                if arr_of(&ins.shape, cname, i)? != (a.0, derived.as_slice()) {
                    return Err(fail("pad: declared shape mismatch".into()));
                }
            }
            Op::Dot { lc, rc } => {
                nops(2)?;
                let a = arr_shape(comp, cname, i, ins.operands[0])?;
                let b = arr_shape(comp, cname, i, ins.operands[1])?;
                if a.0 != Dt::F32 || b.0 != Dt::F32 {
                    return Err(fail("dot operand dtypes".into()));
                }
                if a.1.len() != 2 || b.1.len() != 2 || *lc > 1 || *rc > 1 {
                    return Err(fail("dot: rank-2 with one contracting dim required".into()));
                }
                if a.1[*lc] != b.1[*rc] {
                    return Err(fail("dot contracting dims differ".into()));
                }
                let derived = [a.1[1 - *lc], b.1[1 - *rc]];
                if arr_of(&ins.shape, cname, i)? != (Dt::F32, derived.as_slice()) {
                    return Err(fail("dot: declared shape mismatch".into()));
                }
            }
            Op::Reduce { dims, comp: rc } => {
                nops(2)?;
                let a = arr_shape(comp, cname, i, ins.operands[0])?;
                let iv = arr_shape(comp, cname, i, ins.operands[1])?;
                if a.0 != Dt::F32 || iv.0 != Dt::F32 || numel(iv.1) != 1 {
                    return Err(fail("reduce operand/init".into()));
                }
                if dims.iter().any(|&d| d >= a.1.len()) {
                    return Err(fail("reduce dims out of range".into()));
                }
                let Some(cc) = module.computations.get(*rc) else {
                    return Err(fail("reduce combiner out of range".into()));
                };
                if cc.params.len() != 2 {
                    return Err(fail("reduce combiner arity".into()));
                }
                let scalar_f32 = Shape::Array { dt: Dt::F32, dims: Vec::new() };
                for &pk in &cc.params {
                    if cc.instrs.get(pk).map(|p| &p.shape) != Some(&scalar_f32) {
                        return Err(fail("reduce combiner parameter must be scalar f32".into()));
                    }
                }
                if cc.instrs.get(cc.root).map(|r| &r.shape) != Some(&scalar_f32) {
                    return Err(fail("reduce combiner root must be scalar f32".into()));
                }
                let derived: Vec<usize> = a
                    .1
                    .iter()
                    .enumerate()
                    .filter(|(d, _)| !dims.contains(d))
                    .map(|(_, &sd)| sd)
                    .collect();
                if arr_of(&ins.shape, cname, i)? != (Dt::F32, derived.as_slice()) {
                    return Err(fail("reduce: declared shape mismatch".into()));
                }
            }
            Op::Tuple => {
                let Shape::Tuple(parts) = &ins.shape else {
                    return Err(fail("tuple: declared arity mismatch".into()));
                };
                if parts.len() != ins.operands.len() {
                    return Err(fail("tuple: declared arity mismatch".into()));
                }
                for (e, &o) in parts.iter().zip(&ins.operands) {
                    if *e != comp.instrs[o].shape {
                        return Err(fail("tuple: element shape mismatch".into()));
                    }
                }
            }
            Op::Gte { index } => {
                nops(1)?;
                let Shape::Tuple(parts) = &comp.instrs[ins.operands[0]].shape else {
                    return Err(fail("get-tuple-element on non-tuple".into()));
                };
                let Some(part) = parts.get(*index) else {
                    return Err(fail("get-tuple-element index out of range".into()));
                };
                if ins.shape != *part {
                    return Err(fail("get-tuple-element: declared shape mismatch".into()));
                }
            }
            Op::While { cond, body } => {
                nops(1)?;
                let wfail = |what: String| VerifyError::whilev(cname, format!("slot {i}: {what}"));
                let state = &comp.instrs[ins.operands[0]].shape;
                if ins.shape != *state {
                    return Err(wfail("while shape != loop state shape".into()));
                }
                for (which, kci) in [("condition", *cond), ("body", *body)] {
                    let Some(sub) = module.computations.get(kci) else {
                        return Err(wfail(format!("{which} out of range")));
                    };
                    if sub.params.len() != 1 {
                        return Err(wfail(format!("{which} must take one parameter")));
                    }
                    if sub.instrs.get(sub.params[0]).map(|p| &p.shape) != Some(state) {
                        return Err(wfail(format!("{which} parameter shape != loop state")));
                    }
                }
                let scalar_pred = Shape::Array { dt: Dt::Pred, dims: Vec::new() };
                let croot = &module.computations[*cond];
                if croot.instrs.get(croot.root).map(|r| &r.shape) != Some(&scalar_pred) {
                    return Err(wfail("condition root must be scalar pred".into()));
                }
                let broot = &module.computations[*body];
                if broot.instrs.get(broot.root).map(|r| &r.shape) != Some(state) {
                    return Err(wfail("body root shape != loop state".into()));
                }
            }
        }
        if let Shape::Array { dims, .. } = &ins.shape {
            if dims.len() > MAX_RANK {
                return Err(fail(format!("rank > {MAX_RANK}")));
            }
        }
    }
    Ok(())
}

// ------------------------------------- program / buffers / fusion checks

/// Whether an op executes as a step (everything else is a parameter,
/// plan constant, alias, or on-demand tuple).
fn executable(op: &Op) -> bool {
    match op {
        Op::Bin(_)
        | Op::Un(_)
        | Op::Compare(_)
        | Op::Select
        | Op::Clamp
        | Op::Convert
        | Op::Broadcast { .. }
        | Op::Transpose { .. }
        | Op::Slice { .. }
        | Op::Concat { .. }
        | Op::Pad { .. }
        | Op::Dot { .. }
        | Op::Reduce { .. }
        | Op::While { .. } => true,
        Op::Parameter(_) | Op::Constant(_) | Op::Iota { .. } | Op::Reshape | Op::Gte { .. } | Op::Tuple => false,
    }
}

/// Record one leaf read at step `pos`: def-before-use for materialized
/// slots, and extend that slot's live range.
fn read_leaf(
    cp: &CompPlan,
    defined_at: &[Option<usize>],
    last_use: &mut [Option<usize>],
    cname: &str,
    r: Res,
    pos: usize,
    what: &str,
) -> Result<(), VerifyError> {
    let Res::Inst(t) = r else {
        // param tuple element / dead-while part: no step defines it
        return Ok(());
    };
    match cp.src[t] {
        ValSrc::Dead => Err(VerifyError::program(
            cname,
            format!("{what}: reads slot {t} which is never materialized"),
        )),
        ValSrc::Buf(_) | ValSrc::Lit(_) => match defined_at[t] {
            None => Err(VerifyError::program(cname, format!("{what}: reads undefined slot {t}"))),
            Some(d) if d >= pos => Err(VerifyError::program(
                cname,
                format!("{what}: reads slot {t} defined at step {d}, used at step {pos}"),
            )),
            Some(_) => {
                match last_use[t] {
                    Some(lu) if lu >= pos => {}
                    Some(_) | None => last_use[t] = Some(pos),
                }
                Ok(())
            }
        },
        // always-available sources: plan constants, caller arguments,
        // tuple parts of either, on-demand tuples
        ValSrc::Const(_)
        | ValSrc::Param(_)
        | ValSrc::ParamPart(..)
        | ValSrc::LitPart(..)
        | ValSrc::Tuple => Ok(()),
    }
}

/// Expand a (possibly tuple-valued) operand into the leaves its
/// materialization reads, recording each (the `while` state and the
/// root materialization read whole tuples).
#[allow(clippy::too_many_arguments)]
fn expand_reads(
    comp: &Computation,
    cp: &CompPlan,
    defined_at: &[Option<usize>],
    last_use: &mut [Option<usize>],
    cname: &str,
    o: usize,
    pos: usize,
    what: &str,
    fuel: usize,
) -> Result<(), VerifyError> {
    if fuel == 0 {
        return Err(VerifyError::alias(
            cname,
            format!("{what}: tuple expansion does not terminate"),
        ));
    }
    let r = resolve(comp, cname, o, comp.instrs.len() + 1)?;
    if let Res::Inst(t) = r {
        if matches!(comp.instrs[t].op, Op::Tuple) {
            for &e in &comp.instrs[t].operands {
                expand_reads(comp, cp, defined_at, last_use, cname, e, pos, what, fuel - 1)?;
            }
            return Ok(());
        }
    }
    read_leaf(cp, defined_at, last_use, cname, r, pos, what)
}

/// Verify one computation's plan (check classes 1–3 and 5; class 4 and
/// 6 ran in [`check_shapes`]) and accumulate its statistics.
fn check_comp(
    module: &HloModule,
    ci: usize,
    cp: &CompPlan,
    stats: &mut VerifyStats,
) -> Result<(), VerifyError> {
    let comp = &module.computations[ci];
    let cname = comp.name.as_str();
    let n = comp.instrs.len();
    let fuel = n + 1;
    let n_bufs = cp.buf_dt.len();
    if cp.buf_cap.len() != n_bufs {
        return Err(VerifyError::buffer(cname, "buf_dt / buf_cap length mismatch"));
    }
    if cp.src.len() != n {
        return Err(VerifyError::program(cname, "src table length != instruction count"));
    }
    if cp.n_params != comp.params.len() {
        return Err(VerifyError::program(cname, "n_params mismatch"));
    }
    if cp.root != comp.root || cp.root >= n {
        return Err(VerifyError::program(cname, "plan root != computation root"));
    }
    for (k, &pi) in comp.params.iter().enumerate() {
        if cp.src[pi] != ValSrc::Param(k) {
            return Err(VerifyError::program(
                cname,
                format!("parameter {k}: src is not Param({k})"),
            ));
        }
    }

    // --- pass 1: walk the program, record definitions (class 1)
    let mut defined_at: Vec<Option<usize>> = vec![None; n];
    let mut group_step: Vec<Option<usize>> = vec![None; cp.groups.len()];
    let mut n_while = 0usize;
    let mut lits_defined = vec![false; cp.n_lits];
    for (pos, st) in cp.steps.iter().enumerate() {
        match *st {
            Step::Prim(x) => {
                if x >= n {
                    return Err(VerifyError::program(
                        cname,
                        format!("step {pos}: slot {x} out of range"),
                    ));
                }
                if !executable(&comp.instrs[x].op) {
                    return Err(VerifyError::program(
                        cname,
                        format!("step {pos}: slot {x} is not an executable op"),
                    ));
                }
                if let Some(prev) = defined_at[x] {
                    return Err(VerifyError::program(
                        cname,
                        format!("slot {x}: multiple definitions (steps {prev} and {pos})"),
                    ));
                }
                if matches!(comp.instrs[x].op, Op::While { .. }) {
                    n_while += 1;
                    match cp.src[x] {
                        ValSrc::Lit(li) if lits_defined.get(li) == Some(&false) => {
                            lits_defined[li] = true;
                        }
                        _ => {
                            return Err(VerifyError::program(
                                cname,
                                format!("slot {x}: while step needs a unique literal slot"),
                            ));
                        }
                    }
                } else if !matches!(cp.src[x], ValSrc::Buf(b) if b < n_bufs) {
                    return Err(VerifyError::program(
                        cname,
                        format!("slot {x}: prim step without a valid buffer"),
                    ));
                }
                defined_at[x] = Some(pos);
            }
            Step::Fused(g) => {
                let Some(grp) = cp.groups.get(g) else {
                    return Err(VerifyError::program(
                        cname,
                        format!("step {pos}: group {g} out of range"),
                    ));
                };
                if group_step[g].is_some() {
                    return Err(VerifyError::program(cname, format!("group {g}: scheduled twice")));
                }
                group_step[g] = Some(pos);
                let root = grp.root;
                if root >= n {
                    return Err(VerifyError::program(cname, format!("group {g}: root out of range")));
                }
                if defined_at[root].is_some() {
                    return Err(VerifyError::program(
                        cname,
                        format!("slot {root}: multiple definitions"),
                    ));
                }
                if !matches!(cp.src[root], ValSrc::Buf(b) if b < n_bufs) {
                    return Err(VerifyError::program(
                        cname,
                        format!("slot {root}: fused root without a valid buffer"),
                    ));
                }
                defined_at[root] = Some(pos);
            }
        }
    }
    if n_while != cp.n_lits {
        return Err(VerifyError::program(cname, "n_lits != number of while steps"));
    }
    for (g, st) in group_step.iter().enumerate() {
        if st.is_none() {
            return Err(VerifyError::program(cname, format!("group {g}: never scheduled")));
        }
    }

    // --- alias consistency (class 2): every alias's recorded source
    // must equal its resolved producer's source
    let stepped: Vec<bool> = defined_at.iter().map(Option::is_some).collect();
    for (i, ins) in comp.instrs.iter().enumerate() {
        let s = cp.src[i];
        match &ins.op {
            Op::Reshape | Op::Gte { .. } => {
                let r = resolve(comp, cname, i, fuel)?;
                let want = res_valsrc(comp, cp, r);
                if s != want {
                    return Err(VerifyError::alias(
                        cname,
                        format!("slot {i}: alias src {s:?} != resolved source {want:?}"),
                    ));
                }
            }
            Op::Parameter(_) => {} // checked against comp.params above
            Op::Constant(_) | Op::Iota { .. } => {
                if !matches!(s, ValSrc::Const(_) | ValSrc::Dead) {
                    return Err(VerifyError::program(
                        cname,
                        format!("slot {i}: constant src {s:?}"),
                    ));
                }
            }
            Op::Tuple => {
                if s != ValSrc::Tuple {
                    return Err(VerifyError::program(cname, format!("slot {i}: tuple src {s:?}")));
                }
            }
            Op::Bin(_)
            | Op::Un(_)
            | Op::Compare(_)
            | Op::Select
            | Op::Clamp
            | Op::Convert
            | Op::Broadcast { .. }
            | Op::Transpose { .. }
            | Op::Slice { .. }
            | Op::Concat { .. }
            | Op::Pad { .. }
            | Op::Dot { .. }
            | Op::Reduce { .. }
            | Op::While { .. } => {
                // executable op that never runs: dead code or a fused
                // non-root member — never buffer-backed
                if !stepped[i] {
                    match s {
                        ValSrc::Dead => {}
                        ValSrc::Buf(_) => {
                            return Err(VerifyError::program(
                                cname,
                                format!("slot {i}: buffer-backed slot is never defined"),
                            ));
                        }
                        other => {
                            return Err(VerifyError::program(
                                cname,
                                format!("slot {i}: unscheduled slot src {other:?}"),
                            ));
                        }
                    }
                }
            }
        }
    }

    // --- plan constants: re-derive and compare (class 4 metadata)
    for (i, ins) in comp.instrs.iter().enumerate() {
        let ValSrc::Const(c) = cp.src[i] else { continue };
        match &ins.op {
            Op::Constant(l) => {
                let Some(got) = cp.consts.get(c) else {
                    return Err(VerifyError::program(
                        cname,
                        format!("slot {i}: const index out of range"),
                    ));
                };
                if !lit_eq(got, l) {
                    return Err(VerifyError::shape(
                        cname,
                        format!("slot {i}: plan constant disagrees with instruction"),
                    ));
                }
            }
            Op::Iota { dim } => {
                let Some(got) = cp.consts.get(c) else {
                    return Err(VerifyError::program(
                        cname,
                        format!("slot {i}: const index out of range"),
                    ));
                };
                let want = iota_literal(&ins.shape, *dim).ok_or_else(|| {
                    VerifyError::shape(cname, format!("slot {i}: iota constant underivable"))
                })?;
                if !lit_eq(got, &want) {
                    return Err(VerifyError::shape(
                        cname,
                        format!("slot {i}: plan constant disagrees with instruction"),
                    ));
                }
            }
            // aliases of a constant share the producer's const entry
            Op::Parameter(_)
            | Op::Bin(_)
            | Op::Un(_)
            | Op::Compare(_)
            | Op::Select
            | Op::Clamp
            | Op::Convert
            | Op::Broadcast { .. }
            | Op::Reshape
            | Op::Transpose { .. }
            | Op::Slice { .. }
            | Op::Concat { .. }
            | Op::Pad { .. }
            | Op::Dot { .. }
            | Op::Reduce { .. }
            | Op::Tuple
            | Op::Gte { .. }
            | Op::While { .. } => {}
        }
    }

    // --- reads: def-before-use + live-range recomputation (classes 1, 3)
    let n_steps = cp.steps.len();
    let mut last_use: Vec<Option<usize>> = vec![None; n];
    for (pos, st) in cp.steps.iter().enumerate() {
        match *st {
            Step::Prim(x) => {
                let ins = &comp.instrs[x];
                if matches!(ins.op, Op::While { .. }) {
                    let what = format!("while at slot {x}");
                    expand_reads(
                        comp, cp, &defined_at, &mut last_use, cname, ins.operands[0], pos, &what,
                        fuel,
                    )?;
                } else {
                    let what = format!("slot {x}");
                    for &o in &ins.operands {
                        let r = resolve(comp, cname, o, fuel)?;
                        read_leaf(cp, &defined_at, &mut last_use, cname, r, pos, &what)?;
                    }
                }
            }
            Step::Fused(g) => {
                let grp = &cp.groups[g];
                for &m in &grp.slots {
                    let Some(mins) = comp.instrs.get(m) else {
                        return Err(VerifyError::fusion(
                            cname,
                            format!("group {g}: member {m} out of range"),
                        ));
                    };
                    let what = format!("group {g} member {m}");
                    for &o in &mins.operands {
                        let r = resolve(comp, cname, o, fuel)?;
                        if let Res::Inst(t) = r {
                            if grp.slots.contains(&t) {
                                continue; // in-group slab read
                            }
                        }
                        read_leaf(cp, &defined_at, &mut last_use, cname, r, pos, &what)?;
                    }
                }
            }
        }
    }
    expand_reads(
        comp,
        cp,
        &defined_at,
        &mut last_use,
        cname,
        cp.root,
        n_steps,
        "root materialization",
        fuel,
    )?;

    // --- buffer plan (class 3): per-buffer intervals must be disjoint
    let mut by_buf: Vec<Vec<(usize, usize, usize)>> = vec![Vec::new(); n_bufs];
    let mut slot_count = 0usize;
    for i in 0..n {
        let Some(dpos) = defined_at[i] else { continue };
        let ValSrc::Buf(b) = cp.src[i] else { continue };
        slot_count += 1;
        let Shape::Array { dt, dims } = &comp.instrs[i].shape else {
            return Err(VerifyError::buffer(
                cname,
                format!("slot {i}: tuple-shaped slot with a pooled buffer"),
            ));
        };
        let nel = numel(dims);
        if cp.buf_dt[b] != *dt {
            return Err(VerifyError::buffer(
                cname,
                format!("slot {i}: buffer {b} dtype {:?} != {dt:?}", cp.buf_dt[b]),
            ));
        }
        if cp.buf_cap[b] < nel {
            return Err(VerifyError::buffer(
                cname,
                format!("slot {i}: buffer {b} capacity {} < {nel}", cp.buf_cap[b]),
            ));
        }
        let lu = last_use[i].unwrap_or(dpos);
        by_buf[b].push((dpos, lu, i));
    }
    for (b, ivals) in by_buf.iter_mut().enumerate() {
        ivals.sort_unstable();
        for w in ivals.windows(2) {
            let (d0, u0, s0) = w[0];
            let (d1, _, s1) = w[1];
            // the engine releases a buffer only *after* the defining
            // instruction of its last use, so a reuse at d1 == u0 would
            // already clobber the live value
            if d1 <= u0 {
                return Err(VerifyError::buffer(
                    cname,
                    format!(
                        "buffer {b}: slots {s0} (live [{d0},{u0}]) and {s1} \
                         (defined at step {d1}) overlap"
                    ),
                ));
            }
        }
    }

    // --- fusion groups (class 5)
    for (g, grp) in cp.groups.iter().enumerate() {
        check_group(comp, cname, cp, g, grp, fuel)?;
    }

    stats.instructions += n;
    stats.steps += n_steps;
    stats.groups += cp.groups.len();
    stats.members += cp.groups.iter().map(|grp| grp.members.len()).sum::<usize>();
    stats.buffers += n_bufs;
    stats.buffer_slots += slot_count;
    Ok(())
}

/// Verify one fused group's legality (check class 5).
fn check_group(
    comp: &Computation,
    cname: &str,
    cp: &CompPlan,
    g: usize,
    grp: &Group,
    fuel: usize,
) -> Result<(), VerifyError> {
    let bad = |msg: String| VerifyError::fusion(cname, format!("group {g}: {msg}"));
    let slots = &grp.slots;
    if slots.len() != grp.members.len() || slots.len() < 2 {
        return Err(bad("member/slot list mismatch or too small".into()));
    }
    if grp.members.len() > cp.max_members {
        return Err(bad("more members than max_members (slab overflow)".into()));
    }
    if slots.windows(2).any(|w| w[1] <= w[0]) {
        return Err(bad("member slots not strictly ascending".into()));
    }
    if slots.last() != Some(&grp.root) {
        return Err(bad("root is not the last member".into()));
    }
    let Some(Shape::Array { dims: root_dims, .. }) = comp.instrs.get(grp.root).map(|r| &r.shape)
    else {
        return Err(bad("root out of range or tuple-shaped".into()));
    };
    if grp.numel != numel(root_dims) {
        return Err(bad("group numel != root numel".into()));
    }
    for (mi, (&s, mem)) in slots.iter().zip(&grp.members).enumerate() {
        let Some(ins) = comp.instrs.get(s) else {
            return Err(bad(format!("member {s}: out of range")));
        };
        match &ins.op {
            Op::Bin(_)
            | Op::Un(_)
            | Op::Compare(_)
            | Op::Select
            | Op::Clamp
            | Op::Convert
            | Op::Broadcast { .. } => {}
            Op::Parameter(_)
            | Op::Constant(_)
            | Op::Iota { .. }
            | Op::Reshape
            | Op::Transpose { .. }
            | Op::Slice { .. }
            | Op::Concat { .. }
            | Op::Pad { .. }
            | Op::Dot { .. }
            | Op::Reduce { .. }
            | Op::Tuple
            | Op::Gte { .. }
            | Op::While { .. } => {
                return Err(bad(format!("member {s}: op is not elementwise")));
            }
        }
        let Shape::Array { dt, dims } = &ins.shape else {
            return Err(bad(format!("member {s}: tuple-shaped member")));
        };
        if numel(dims) != grp.numel {
            return Err(bad(format!("member {s}: numel != group block length")));
        }
        if to_sdt(*dt) != Some(mem.sdt) {
            return Err(bad(format!(
                "member {s}: slab dtype {:?} != declared {dt:?}",
                mem.sdt
            )));
        }
        if mi + 1 < slots.len() && cp.src[s] != ValSrc::Dead {
            return Err(bad(format!("member {s}: non-root member must be Dead")));
        }
        let operand_dt = |k: usize| -> Result<Dt, VerifyError> {
            match &comp.instrs[ins.operands[k]].shape {
                Shape::Array { dt, .. } => Ok(*dt),
                Shape::Tuple(_) => Err(bad(format!("member {s}: tuple-shaped operand"))),
            }
        };
        let refs: Vec<FRef> = match (&mem.op, &ins.op) {
            (FOp::Bin(fb, a, b), Op::Bin(ib)) => {
                if fb != ib {
                    return Err(bad(format!("member {s}: binary op mismatch")));
                }
                if mem.sdt == SDt::F32
                    && !matches!(
                        fb,
                        BinOp::Add
                            | BinOp::Sub
                            | BinOp::Mul
                            | BinOp::Div
                            | BinOp::Max
                            | BinOp::Min
                            | BinOp::Pow
                    )
                {
                    return Err(bad(format!("member {s}: op not fusible on f32")));
                }
                if mem.sdt == SDt::U32 && matches!(fb, BinOp::Pow) {
                    return Err(bad(format!("member {s}: pow not fusible on u32")));
                }
                vec![*a, *b]
            }
            (FOp::Un(fu, a), Op::Un(iu)) => {
                if fu != iu {
                    return Err(bad(format!("member {s}: unary op mismatch")));
                }
                if (mem.sdt == SDt::F32) == (*fu == UnOp::Not) {
                    return Err(bad(format!(
                        "member {s}: unary op not fusible on {:?}",
                        mem.sdt
                    )));
                }
                vec![*a]
            }
            (FOp::Cmp(fd, fdt, a, b), Op::Compare(id)) => {
                if fd != id {
                    return Err(bad(format!("member {s}: compare direction mismatch")));
                }
                let odt = operand_dt(0)?;
                if !matches!(odt, Dt::F32 | Dt::U32) || to_sdt(odt) != Some(*fdt) {
                    return Err(bad(format!("member {s}: compare operand dtype")));
                }
                vec![*a, *b]
            }
            (FOp::Sel(a, b, c), Op::Select) => vec![*a, *b, *c],
            (FOp::Clamp(a, b, c), Op::Clamp) => vec![*a, *b, *c],
            (FOp::Cvt(fdt, a), Op::Convert) => {
                if *fdt != operand_dt(0)? {
                    return Err(bad(format!("member {s}: convert source dtype mismatch")));
                }
                vec![*a]
            }
            (FOp::Splat(a), Op::Broadcast { .. }) => {
                if comp.instrs[ins.operands[0]].shape.numel() != 1 {
                    return Err(bad(format!("member {s}: splat of non-scalar operand")));
                }
                vec![*a]
            }
            _ => {
                return Err(bad(format!(
                    "member {s}: fused op does not match the instruction"
                )));
            }
        };
        if refs.len() != ins.operands.len() {
            return Err(bad(format!("member {s}: operand count mismatch")));
        }
        for (&fref, &o) in refs.iter().zip(&ins.operands) {
            let r = resolve(comp, cname, o, fuel)?;
            match fref {
                FRef::Slab(j) => {
                    if j >= mi {
                        return Err(bad(format!(
                            "member {s}: slab operand {j} does not precede member {mi}"
                        )));
                    }
                    if r != Res::Inst(slots[j]) {
                        return Err(bad(format!(
                            "member {s}: slab operand {j} != resolved producer"
                        )));
                    }
                }
                FRef::Ext(e) => {
                    let Some(ext) = grp.ext.get(e) else {
                        return Err(bad(format!("member {s}: ext operand out of range")));
                    };
                    if let Res::Inst(t) = r {
                        if slots.contains(&t) {
                            return Err(bad(format!(
                                "member {s}: group member read through ext input"
                            )));
                        }
                    }
                    let want = res_valsrc(comp, cp, r);
                    if ext.src != want {
                        return Err(bad(format!(
                            "member {s}: ext src {:?} != resolved {want:?}",
                            ext.src
                        )));
                    }
                    let Shape::Array { dims, .. } = resolved_shape(comp, cname, r)? else {
                        return Err(bad(format!("member {s}: tuple-shaped ext input")));
                    };
                    let nel = numel(dims);
                    if ext.scalar != (nel == 1) {
                        return Err(bad(format!("member {s}: ext scalar flag wrong")));
                    }
                    if !ext.scalar && nel != grp.numel {
                        return Err(bad(format!(
                            "member {s}: non-scalar ext numel != block length"
                        )));
                    }
                }
            }
        }
    }
    Ok(())
}

// ----------------------------------------------------------------- tests

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::Registry;
    use crate::runtime::interp::parse;
    use std::rc::Rc;

    /// Compile one checked-in artifact into a plan; `None` (with the
    /// e2e "skipping:" marker) when artifacts are not built.
    fn load_plan(file: &str) -> Option<Plan> {
        let path = Registry::default_dir().join(file);
        if !path.exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        let src = std::fs::read_to_string(&path).expect("artifact readable");
        let module = parse(&src).expect("artifact parses");
        Some(Plan::new(Rc::new(module)).expect("artifact compiles"))
    }

    #[test]
    fn clean_artifact_plan_verifies() {
        let Some(plan) = load_plan("fcn_step_sgd.hlo.txt") else { return };
        let st = verify_plan(&plan).expect("clean plan verifies");
        assert!(st.instructions > 0 && st.steps > 0, "stats must be populated");
        assert!(st.groups > 0 && st.members >= 2 * st.groups, "fusion stats");
        assert!(st.buffer_slots >= st.buffers, "buffers are reused, never unused");
        assert!(st.reuse_ratio() >= 1.0);
    }

    #[test]
    fn clean_while_artifact_verifies() {
        let Some(plan) = load_plan("fcn_zs.hlo.txt") else { return };
        let st = verify_plan(&plan).expect("while-loop plan verifies");
        assert!(st.computations > 1, "ZS artifacts carry cond/body computations");
    }

    /// Class 3 (Buffer): give a step the pooled buffer of a live
    /// operand — the recomputed live ranges must overlap.
    #[test]
    fn corrupt_shared_buffer_is_caught() {
        let Some(mut plan) = load_plan("fcn_step_sgd.hlo.txt") else { return };
        let target = {
            let ins = plan.inspect();
            let ci = ins.module.entry;
            let comp = &ins.module.computations[ci];
            let cp = &ins.comps[ci];
            let mut found = None;
            'outer: for st in &cp.steps {
                let Step::Prim(x) = *st else { continue };
                if matches!(comp.instrs[x].op, Op::While { .. }) {
                    continue;
                }
                let ValSrc::Buf(mine) = cp.src[x] else { continue };
                for &o in &comp.instrs[x].operands {
                    let mut t = o;
                    while matches!(comp.instrs[t].op, Op::Reshape) {
                        t = comp.instrs[t].operands[0];
                    }
                    if let ValSrc::Buf(b) = cp.src[t] {
                        if b != mine {
                            found = Some((ci, x, cp.src[t]));
                            break 'outer;
                        }
                    }
                }
            }
            found.expect("a step reading another live buffer exists")
        };
        let (ci, x, stolen) = target;
        plan.comps_mut()[ci].src[x] = stolen;
        let e = verify_plan(&plan).expect_err("shared buffer must be diagnosed");
        assert!(matches!(e, VerifyError::Buffer { .. }), "got {e}");
    }

    /// Class 2 (Alias): a reshape aliasing itself must be reported as a
    /// non-terminating chain, not hang or overflow.
    #[test]
    fn corrupt_alias_cycle_is_caught() {
        let Some(mut plan) = load_plan("fcn_step_sgd.hlo.txt") else { return };
        let (ci, i) = {
            let ins = plan.inspect();
            let ci = ins.module.entry;
            let comp = &ins.module.computations[ci];
            let i = comp
                .instrs
                .iter()
                .position(|x| matches!(x.op, Op::Reshape))
                .expect("a reshape exists");
            (ci, i)
        };
        plan.module_mut().computations[ci].instrs[i].operands[0] = i;
        let e = verify_plan(&plan).expect_err("alias cycle must be diagnosed");
        assert!(matches!(e, VerifyError::Alias { .. }), "got {e}");
    }

    /// Class 5 (Fusion): a wrong fused block length breaks the
    /// numel-per-member invariant.
    #[test]
    fn corrupt_group_block_length_is_caught() {
        let Some(mut plan) = load_plan("fcn_step_sgd.hlo.txt") else { return };
        let ci = plan.inspect().module.entry;
        assert!(!plan.inspect().comps[ci].groups.is_empty(), "entry has fused groups");
        plan.comps_mut()[ci].groups[0].numel += 1;
        let e = verify_plan(&plan).expect_err("block length lie must be diagnosed");
        assert!(matches!(e, VerifyError::Fusion { .. }), "got {e}");
    }

    /// Class 4 (Shape): a `dot` declaring the wrong output dims fails
    /// re-inference.
    #[test]
    fn corrupt_declared_shape_is_caught() {
        let Some(mut plan) = load_plan("fcn_step_sgd.hlo.txt") else { return };
        let (ci, i) = {
            let ins = plan.inspect();
            let ci = ins.module.entry;
            let comp = &ins.module.computations[ci];
            let i = comp
                .instrs
                .iter()
                .position(|x| matches!(x.op, Op::Dot { .. }))
                .expect("a dot exists");
            (ci, i)
        };
        match &mut plan.module_mut().computations[ci].instrs[i].shape {
            Shape::Array { dims, .. } => dims[0] += 1,
            Shape::Tuple(_) => unreachable!("dot is array-valued"),
        }
        let e = verify_plan(&plan).expect_err("declared-shape lie must be diagnosed");
        assert!(matches!(e, VerifyError::Shape { .. }), "got {e}");
    }

    /// Class 1 (Program): scheduling a consumer before its producer is
    /// a def-before-use violation.
    #[test]
    fn corrupt_use_before_def_is_caught() {
        let Some(mut plan) = load_plan("fcn_step_sgd.hlo.txt") else { return };
        let swap = {
            let ins = plan.inspect();
            let ci = ins.module.entry;
            let comp = &ins.module.computations[ci];
            let cp = &ins.comps[ci];
            let mut found = None;
            'outer: for (pos, st) in cp.steps.iter().enumerate() {
                let Step::Prim(x) = *st else { continue };
                if matches!(comp.instrs[x].op, Op::While { .. }) {
                    continue;
                }
                for &o in &comp.instrs[x].operands {
                    let mut t = o;
                    while matches!(comp.instrs[t].op, Op::Reshape) {
                        t = comp.instrs[t].operands[0];
                    }
                    if !matches!(cp.src[t], ValSrc::Buf(_)) {
                        continue;
                    }
                    if let Some(dpos) = cp
                        .steps
                        .iter()
                        .position(|s| matches!(*s, Step::Prim(y) if y == t))
                    {
                        found = Some((ci, dpos, pos));
                        break 'outer;
                    }
                }
            }
            found.expect("a producer/consumer step pair exists")
        };
        let (ci, dpos, pos) = swap;
        plan.comps_mut()[ci].steps.swap(dpos, pos);
        let e = verify_plan(&plan).expect_err("use-before-def must be diagnosed");
        assert!(matches!(e, VerifyError::Program { .. }), "got {e}");
    }

    /// Class 1 (Program): the same slot scheduled twice violates
    /// single-definition.
    #[test]
    fn corrupt_multiple_definition_is_caught() {
        let Some(mut plan) = load_plan("fcn_step_sgd.hlo.txt") else { return };
        let (ci, dup) = {
            let ins = plan.inspect();
            let ci = ins.module.entry;
            let comp = &ins.module.computations[ci];
            let dup = ins.comps[ci]
                .steps
                .iter()
                .find_map(|st| match *st {
                    Step::Prim(x) if !matches!(comp.instrs[x].op, Op::While { .. }) => Some(x),
                    _ => None,
                })
                .expect("a prim step exists");
            (ci, dup)
        };
        plan.comps_mut()[ci].steps.push(Step::Prim(dup));
        let e = verify_plan(&plan).expect_err("double definition must be diagnosed");
        assert!(matches!(e, VerifyError::Program { .. }), "got {e}");
    }

    /// Class 6 (While): pointing the body root at a slot whose shape is
    /// not the loop state breaks the state contract.
    #[test]
    fn corrupt_while_contract_is_caught() {
        let Some(mut plan) = load_plan("fcn_zs.hlo.txt") else { return };
        let (bci, j) = {
            let ins = plan.inspect();
            let ci = ins.module.entry;
            let comp = &ins.module.computations[ci];
            let mut found = None;
            for x in &comp.instrs {
                let Op::While { body, .. } = x.op else { continue };
                let state = &comp.instrs[x.operands[0]].shape;
                let bc = &ins.module.computations[body];
                if let Some(j) = bc.instrs.iter().position(|bi| bi.shape != *state) {
                    found = Some((body, j));
                    break;
                }
            }
            found.expect("a while body with a non-state-shaped slot exists")
        };
        plan.module_mut().computations[bci].root = j;
        let e = verify_plan(&plan).expect_err("state contract break must be diagnosed");
        assert!(matches!(e, VerifyError::While { .. }), "got {e}");
    }

    #[test]
    fn verify_hlo_text_runs_end_to_end() {
        let st = verify_hlo_text(
            "HloModule t\n\nENTRY %main (p0: f32[4]) -> (f32[4]) {\n  \
             %p0 = f32[4] parameter(0)\n  %n = f32[4] negate(%p0)\n  \
             %m = f32[4] multiply(%n, %n)\n  ROOT %t = (f32[4]) tuple(%m)\n}\n",
        )
        .expect("tiny module verifies");
        assert_eq!(st.computations, 1);
        assert_eq!(st.groups, 1, "negate+multiply fuse into one group");
    }

    #[test]
    fn error_display_carries_class_and_computation() {
        let e = VerifyError::buffer("main", "slots 3 and 7 overlap");
        assert_eq!(format!("{e}"), "Buffer[main]: slots 3 and 7 overlap");
        assert_eq!(e.class(), "Buffer");
    }
}
