//! Pure-Rust HLO-text parser + reference interpreter.
//!
//! This module owns the HLO *text* parser and the scalar reference
//! evaluator [`execute_ref`] — the walk-the-instruction-list
//! interpreter that defines the semantics of every supported op. The
//! production path is the planned execution engine in
//! [`crate::runtime::plan`], which compiles a parsed [`HloModule`] into
//! a flat instruction program (fused elementwise chains, threaded
//! `dot`, liveness-planned cached buffers) and must stay *bit-for-bit*
//! equal to `execute_ref` (pinned by `rust/tests/plan_equivalence.rs`).
//! Both back the `runtime::xla` surface; real PJRT bindings remain a
//! drop-in swap there.
//!
//! Supported op set (what the checked-in FCN/LeNet/convnet3 artifacts
//! emit — see `python/compile/hlo_fixtures.py`):
//! parameter/constant/iota/tuple/get-tuple-element, dot,
//! add/subtract/multiply/divide/maximum/minimum/power,
//! and/or/xor/not/shift-left/shift-right-logical,
//! negate/exponential/log/sqrt/rsqrt/abs/sign/floor/ceil/
//! round-nearest-even/tanh/logistic/sine/cosine,
//! compare/select/clamp/convert, broadcast/reshape/transpose/slice/
//! concatenate/pad, reduce (add/max/min/multiply fast paths + generic
//! sub-computation fallback), and while.
//!
//! Numeric contract: element type f32 exactly (no widening to f64 in
//! elementwise ops); `dot` accumulates in f32 like XLA:CPU;
//! `round-nearest-even` implements ties-to-even (`jnp.round`). The
//! per-element arithmetic lives in the `*_s` scalar helpers shared with
//! the planned engine, so the two paths cannot drift. Unsupported
//! opcodes are *parse-time* errors so a bad artifact fails at compile,
//! not mid-training.

#![warn(missing_docs)]

use std::collections::BTreeMap;

use crate::runtime::xla::{Data, Literal, XlaError};

pub(crate) fn err(msg: impl Into<String>) -> XlaError {
    XlaError(msg.into())
}

// ----------------------------------------------------------------- types

/// Element type of an array shape.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dt {
    /// 32-bit IEEE float (`f32[...]`).
    F32,
    /// 32-bit signed integer (`s32[...]`).
    S32,
    /// 32-bit unsigned integer (`u32[...]`).
    U32,
    /// Boolean predicate (`pred[...]`).
    Pred,
}

impl Dt {
    fn parse(s: &str) -> Result<Dt, XlaError> {
        match s {
            "f32" => Ok(Dt::F32),
            "s32" => Ok(Dt::S32),
            "u32" => Ok(Dt::U32),
            "pred" => Ok(Dt::Pred),
            other => Err(err(format!("unsupported element type '{other}'"))),
        }
    }
}

/// Parsed HLO shape: an array or a tuple of shapes.
#[derive(Clone, Debug, PartialEq)]
pub(crate) enum Shape {
    Array { dt: Dt, dims: Vec<usize> },
    Tuple(Vec<Shape>),
}

impl Shape {
    pub(crate) fn numel(&self) -> usize {
        match self {
            Shape::Array { dims, .. } => dims.iter().product(),
            Shape::Tuple(_) => 0,
        }
    }

    pub(crate) fn dims(&self) -> Result<&[usize], XlaError> {
        match self {
            Shape::Array { dims, .. } => Ok(dims),
            Shape::Tuple(_) => Err(err("expected array shape, got tuple")),
        }
    }

    pub(crate) fn dt(&self) -> Result<Dt, XlaError> {
        match self {
            Shape::Array { dt, .. } => Ok(*dt),
            Shape::Tuple(_) => Err(err("expected array shape, got tuple")),
        }
    }
}

/// Comparison direction of a `compare` op.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum Cmp {
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
}

/// Elementwise binary opcodes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
    Max,
    Min,
    Pow,
    And,
    Or,
    Xor,
    Shl,
    Shr,
}

/// Elementwise unary opcodes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum UnOp {
    Neg,
    Exp,
    Log,
    Sqrt,
    Rsqrt,
    Abs,
    Sign,
    Floor,
    Ceil,
    RoundTiesEven,
    Tanh,
    Logistic,
    Sin,
    Cos,
    Not,
}

/// One HLO instruction's operation (attributes resolved at parse time).
#[derive(Clone, Debug)]
pub(crate) enum Op {
    Parameter(usize),
    Constant(Literal),
    Iota { dim: usize },
    Bin(BinOp),
    Un(UnOp),
    Compare(Cmp),
    Select,
    Clamp,
    Convert,
    Broadcast { dims: Vec<usize> },
    Reshape,
    Transpose { perm: Vec<usize> },
    Slice { starts: Vec<usize>, limits: Vec<usize>, strides: Vec<usize> },
    Concat { dim: usize },
    Pad { low: Vec<i64>, high: Vec<i64>, interior: Vec<usize> },
    Dot { lc: usize, rc: usize },
    Reduce { dims: Vec<usize>, comp: usize },
    Tuple,
    Gte { index: usize },
    While { cond: usize, body: usize },
}

#[derive(Clone, Debug)]
pub(crate) struct Instr {
    pub(crate) shape: Shape,
    pub(crate) op: Op,
    pub(crate) operands: Vec<usize>,
}

/// One named computation (the entry or a called sub-computation).
#[derive(Clone, Debug)]
pub(crate) struct Computation {
    pub(crate) name: String,
    pub(crate) instrs: Vec<Instr>,
    /// parameter ordinal -> instruction index
    pub(crate) params: Vec<usize>,
    pub(crate) root: usize,
    /// per instruction: operand values whose last use this is
    pub(crate) drop_after: Vec<Vec<usize>>,
}

/// A parsed HLO module: every computation plus the entry index.
///
/// Produced by [`parse`]; executed either by the scalar reference
/// walker [`execute_ref`] or by compiling it into a
/// [`crate::runtime::plan::Plan`].
#[derive(Clone, Debug)]
pub struct HloModule {
    pub(crate) computations: Vec<Computation>,
    pub(crate) entry: usize,
}

impl HloModule {
    /// Number of parameters of the entry computation (validation aid).
    pub fn entry_param_count(&self) -> usize {
        self.computations[self.entry].params.len()
    }
}

// ---------------------------------------------------------------- parser

struct Cursor<'a> {
    s: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(s: &'a str) -> Cursor<'a> {
        Cursor { s: s.as_bytes(), pos: 0 }
    }

    fn peek(&self) -> Option<u8> {
        self.s.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ') | Some(b'\t')) {
            self.pos += 1;
        }
    }

    fn eat(&mut self, c: u8) -> Result<(), XlaError> {
        self.skip_ws();
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(err(format!(
                "expected '{}' at byte {} of '{}'",
                c as char,
                self.pos,
                String::from_utf8_lossy(self.s)
            )))
        }
    }

    fn ident(&mut self) -> String {
        self.skip_ws();
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_alphanumeric() || c == b'_' || c == b'.' || c == b'-' {
                self.pos += 1;
            } else {
                break;
            }
        }
        String::from_utf8_lossy(&self.s[start..self.pos]).into_owned()
    }

    /// Content up to the matching close of the `(` just consumed.
    fn balanced(&mut self) -> Result<String, XlaError> {
        let start = self.pos;
        let mut depth = 1usize;
        while let Some(c) = self.bump() {
            match c {
                b'(' | b'{' | b'[' => depth += 1,
                b')' | b'}' | b']' => {
                    depth -= 1;
                    if depth == 0 {
                        return Ok(String::from_utf8_lossy(&self.s[start..self.pos - 1])
                            .into_owned());
                    }
                }
                _ => {}
            }
        }
        Err(err("unbalanced parentheses"))
    }

    fn rest(&self) -> String {
        String::from_utf8_lossy(&self.s[self.pos..]).into_owned()
    }
}

/// Split at top-level commas (nesting-aware for (), {}, []).
fn split_top(s: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut cur = String::new();
    for c in s.chars() {
        match c {
            '(' | '{' | '[' => {
                depth += 1;
                cur.push(c);
            }
            ')' | '}' | ']' => {
                depth = depth.saturating_sub(1);
                cur.push(c);
            }
            ',' if depth == 0 => {
                out.push(cur.trim().to_string());
                cur = String::new();
            }
            _ => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        out.push(cur.trim().to_string());
    }
    out
}

fn parse_shape(c: &mut Cursor) -> Result<Shape, XlaError> {
    c.skip_ws();
    if c.peek() == Some(b'(') {
        c.bump();
        let inner = c.balanced()?;
        let mut parts = Vec::new();
        for p in split_top(&inner) {
            let mut pc = Cursor::new(&p);
            parts.push(parse_shape(&mut pc)?);
        }
        return Ok(Shape::Tuple(parts));
    }
    let dt = Dt::parse(&c.ident())?;
    c.eat(b'[')?;
    let inner = c.balanced()?;
    let mut dims = Vec::new();
    for d in split_top(&inner) {
        dims.push(
            d.parse::<usize>()
                .map_err(|_| err(format!("bad dimension '{d}'")))?,
        );
    }
    // optional layout suffix {1,0}
    c.skip_ws();
    if c.peek() == Some(b'{') {
        c.bump();
        c.balanced()?;
    }
    Ok(Shape::Array { dt, dims })
}

/// `{1,2}` -> vec![1, 2] (also accepts an empty list).
fn parse_dims_attr(v: &str) -> Result<Vec<usize>, XlaError> {
    let inner = v
        .trim()
        .strip_prefix('{')
        .and_then(|s| s.strip_suffix('}'))
        .ok_or_else(|| err(format!("bad dims attribute '{v}'")))?;
    let mut out = Vec::new();
    for d in split_top(inner) {
        out.push(
            d.parse::<usize>()
                .map_err(|_| err(format!("bad dims attribute '{v}'")))?,
        );
    }
    Ok(out)
}

fn parse_const_literal(shape: &Shape, body: &str) -> Result<Literal, XlaError> {
    let dt = shape.dt()?;
    let dims: Vec<i64> = shape.dims()?.iter().map(|&d| d as i64).collect();
    // strip braces: nested dense literals flatten in row-major order
    let flat: String = body
        .chars()
        .map(|c| if c == '{' || c == '}' { ' ' } else { c })
        .collect();
    let toks: Vec<&str> = flat
        .split(|c: char| c == ',' || c.is_whitespace())
        .filter(|t| !t.is_empty())
        .collect();
    if toks.len() != shape.numel() {
        return Err(err(format!(
            "constant: {} values for shape with {} elements",
            toks.len(),
            shape.numel()
        )));
    }
    let data = match dt {
        Dt::F32 => {
            let mut v = Vec::with_capacity(toks.len());
            for t in &toks {
                v.push(
                    t.parse::<f32>()
                        .map_err(|_| err(format!("bad f32 constant '{t}'")))?,
                );
            }
            Data::F32(v)
        }
        Dt::S32 => {
            let mut v = Vec::with_capacity(toks.len());
            for t in &toks {
                v.push(
                    t.parse::<i32>()
                        .map_err(|_| err(format!("bad s32 constant '{t}'")))?,
                );
            }
            Data::I32(v)
        }
        Dt::U32 => {
            let mut v = Vec::with_capacity(toks.len());
            for t in &toks {
                v.push(
                    t.parse::<u32>()
                        .map_err(|_| err(format!("bad u32 constant '{t}'")))?,
                );
            }
            Data::U32(v)
        }
        Dt::Pred => {
            let mut v = Vec::with_capacity(toks.len());
            for t in &toks {
                v.push(match *t {
                    "true" | "1" => true,
                    "false" | "0" => false,
                    other => return Err(err(format!("bad pred constant '{other}'"))),
                });
            }
            Data::Pred(v)
        }
    };
    Ok(Literal { data, dims })
}

/// `lo_hi` or `lo_hi_interior`, 'x'-separated per dimension.
#[allow(clippy::type_complexity)]
fn parse_padding_attr(v: &str) -> Result<(Vec<i64>, Vec<i64>, Vec<usize>), XlaError> {
    let (mut low, mut high, mut interior) = (Vec::new(), Vec::new(), Vec::new());
    for dim in v.trim().split('x') {
        let parts: Vec<&str> = dim.split('_').collect();
        if parts.len() != 2 && parts.len() != 3 {
            return Err(err(format!("bad padding attribute '{v}'")));
        }
        let p = |s: &str| {
            s.parse::<i64>()
                .map_err(|_| err(format!("bad padding attribute '{v}'")))
        };
        low.push(p(parts[0])?);
        high.push(p(parts[1])?);
        interior.push(if parts.len() == 3 { p(parts[2])? as usize } else { 0 });
    }
    Ok((low, high, interior))
}

/// `{[0:16:1],[0:8]}` -> starts/limits/strides.
#[allow(clippy::type_complexity)]
fn parse_slice_attr(v: &str) -> Result<(Vec<usize>, Vec<usize>, Vec<usize>), XlaError> {
    let inner = v
        .trim()
        .strip_prefix('{')
        .and_then(|s| s.strip_suffix('}'))
        .ok_or_else(|| err(format!("bad slice attribute '{v}'")))?;
    let (mut starts, mut limits, mut strides) = (Vec::new(), Vec::new(), Vec::new());
    for part in split_top(inner) {
        let p = part
            .trim()
            .strip_prefix('[')
            .and_then(|s| s.strip_suffix(']'))
            .ok_or_else(|| err(format!("bad slice attribute '{v}'")))?;
        let nums: Vec<&str> = p.split(':').collect();
        if nums.len() != 2 && nums.len() != 3 {
            return Err(err(format!("bad slice attribute '{v}'")));
        }
        let q = |s: &str| {
            s.parse::<usize>()
                .map_err(|_| err(format!("bad slice attribute '{v}'")))
        };
        starts.push(q(nums[0])?);
        limits.push(q(nums[1])?);
        strides.push(if nums.len() == 3 { q(nums[2])? } else { 1 });
    }
    Ok((starts, limits, strides))
}

fn operand_name(tok: &str) -> Result<String, XlaError> {
    match tok.rfind('%') {
        Some(i) => {
            let mut c = Cursor::new(&tok[i + 1..]);
            Ok(c.ident())
        }
        None => {
            // bare names are legal in some printers
            let t = tok.trim();
            let last = t.rsplit(' ').next().unwrap_or(t);
            if last.is_empty() {
                Err(err(format!("bad operand '{tok}'")))
            } else {
                Ok(last.to_string())
            }
        }
    }
}

fn comp_ref(v: &str, comp_names: &BTreeMap<String, usize>) -> Result<usize, XlaError> {
    let name = v.trim().trim_start_matches('%');
    comp_names
        .get(name)
        .copied()
        .ok_or_else(|| err(format!("unknown computation '{name}'")))
}

fn parse_instruction(
    line: &str,
    names: &BTreeMap<String, usize>,
    comp_names: &BTreeMap<String, usize>,
) -> Result<(String, bool, Instr), XlaError> {
    let mut line = line.trim();
    let is_root = if let Some(rest) = line.strip_prefix("ROOT ") {
        line = rest;
        true
    } else {
        false
    };
    let mut c = Cursor::new(line);
    c.eat(b'%')?;
    let name = c.ident();
    c.skip_ws();
    c.eat(b'=')?;
    let shape = parse_shape(&mut c)?;
    let opcode = c.ident();
    c.eat(b'(')?;
    let body = c.balanced()?;
    // attributes after the operand list
    let mut attrs: BTreeMap<String, String> = BTreeMap::new();
    for a in split_top(&c.rest()) {
        if let Some(eq) = a.find('=') {
            attrs.insert(a[..eq].trim().to_string(), a[eq + 1..].trim().to_string());
        }
    }
    let resolve = |toks: &str| -> Result<Vec<usize>, XlaError> {
        let mut out = Vec::new();
        for t in split_top(toks) {
            let n = operand_name(&t)?;
            out.push(
                *names
                    .get(&n)
                    .ok_or_else(|| err(format!("operand '%{n}' not defined before use")))?,
            );
        }
        Ok(out)
    };
    let dims_of = |key: &str| -> Result<Vec<usize>, XlaError> {
        parse_dims_attr(
            attrs
                .get(key)
                .ok_or_else(|| err(format!("{opcode}: missing {key}")))?,
        )
    };
    let (op, operands) = match opcode.as_str() {
        "parameter" => {
            let idx = body
                .trim()
                .parse::<usize>()
                .map_err(|_| err(format!("bad parameter index '{body}'")))?;
            (Op::Parameter(idx), Vec::new())
        }
        "constant" => (Op::Constant(parse_const_literal(&shape, &body)?), Vec::new()),
        "iota" => {
            let dim = attrs
                .get("iota_dimension")
                .and_then(|v| v.parse::<usize>().ok())
                .ok_or_else(|| err("iota: missing or malformed iota_dimension"))?;
            (Op::Iota { dim }, Vec::new())
        }
        "add" => (Op::Bin(BinOp::Add), resolve(&body)?),
        "subtract" => (Op::Bin(BinOp::Sub), resolve(&body)?),
        "multiply" => (Op::Bin(BinOp::Mul), resolve(&body)?),
        "divide" => (Op::Bin(BinOp::Div), resolve(&body)?),
        "maximum" => (Op::Bin(BinOp::Max), resolve(&body)?),
        "minimum" => (Op::Bin(BinOp::Min), resolve(&body)?),
        "power" => (Op::Bin(BinOp::Pow), resolve(&body)?),
        "and" => (Op::Bin(BinOp::And), resolve(&body)?),
        "or" => (Op::Bin(BinOp::Or), resolve(&body)?),
        "xor" => (Op::Bin(BinOp::Xor), resolve(&body)?),
        "shift-left" => (Op::Bin(BinOp::Shl), resolve(&body)?),
        "shift-right-logical" => (Op::Bin(BinOp::Shr), resolve(&body)?),
        "not" => (Op::Un(UnOp::Not), resolve(&body)?),
        "negate" => (Op::Un(UnOp::Neg), resolve(&body)?),
        "exponential" | "exp" => (Op::Un(UnOp::Exp), resolve(&body)?),
        "log" => (Op::Un(UnOp::Log), resolve(&body)?),
        "sqrt" => (Op::Un(UnOp::Sqrt), resolve(&body)?),
        "rsqrt" => (Op::Un(UnOp::Rsqrt), resolve(&body)?),
        "abs" => (Op::Un(UnOp::Abs), resolve(&body)?),
        "sign" => (Op::Un(UnOp::Sign), resolve(&body)?),
        "floor" => (Op::Un(UnOp::Floor), resolve(&body)?),
        "ceil" => (Op::Un(UnOp::Ceil), resolve(&body)?),
        "round-nearest-even" => (Op::Un(UnOp::RoundTiesEven), resolve(&body)?),
        "tanh" => (Op::Un(UnOp::Tanh), resolve(&body)?),
        "logistic" => (Op::Un(UnOp::Logistic), resolve(&body)?),
        "sine" => (Op::Un(UnOp::Sin), resolve(&body)?),
        "cosine" => (Op::Un(UnOp::Cos), resolve(&body)?),
        "compare" => {
            let dir = match attrs.get("direction").map(String::as_str) {
                Some("EQ") => Cmp::Eq,
                Some("NE") => Cmp::Ne,
                Some("LT") => Cmp::Lt,
                Some("LE") => Cmp::Le,
                Some("GT") => Cmp::Gt,
                Some("GE") => Cmp::Ge,
                other => {
                    return Err(err(format!("compare: bad direction {other:?}")));
                }
            };
            (Op::Compare(dir), resolve(&body)?)
        }
        "select" => (Op::Select, resolve(&body)?),
        "clamp" => (Op::Clamp, resolve(&body)?),
        "convert" => (Op::Convert, resolve(&body)?),
        "broadcast" => (Op::Broadcast { dims: dims_of("dimensions")? }, resolve(&body)?),
        "reshape" => (Op::Reshape, resolve(&body)?),
        "transpose" => (Op::Transpose { perm: dims_of("dimensions")? }, resolve(&body)?),
        "slice" => {
            let (starts, limits, strides) = parse_slice_attr(
                attrs
                    .get("slice")
                    .ok_or_else(|| err("slice: missing slice attribute"))?,
            )?;
            (Op::Slice { starts, limits, strides }, resolve(&body)?)
        }
        "concatenate" => {
            let dims = dims_of("dimensions")?;
            if dims.len() != 1 {
                return Err(err("concatenate: expected one dimension"));
            }
            (Op::Concat { dim: dims[0] }, resolve(&body)?)
        }
        "pad" => {
            let (low, high, interior) = parse_padding_attr(
                attrs
                    .get("padding")
                    .ok_or_else(|| err("pad: missing padding attribute"))?,
            )?;
            (Op::Pad { low, high, interior }, resolve(&body)?)
        }
        "dot" => {
            let one_dim = |key: &str| -> Result<usize, XlaError> {
                let d = parse_dims_attr(attrs.get(key).map(String::as_str).unwrap_or("{}"))?;
                if d.len() != 1 {
                    return Err(err(format!("dot: {key} must name exactly one dim")));
                }
                Ok(d[0])
            };
            for key in ["lhs_batch_dims", "rhs_batch_dims"] {
                if let Some(v) = attrs.get(key) {
                    if !parse_dims_attr(v)?.is_empty() {
                        return Err(err("dot: batch dimensions are not supported"));
                    }
                }
            }
            (
                Op::Dot {
                    lc: one_dim("lhs_contracting_dims")?,
                    rc: one_dim("rhs_contracting_dims")?,
                },
                resolve(&body)?,
            )
        }
        "reduce" => {
            let comp = comp_ref(
                attrs
                    .get("to_apply")
                    .ok_or_else(|| err("reduce: missing to_apply"))?,
                comp_names,
            )?;
            (Op::Reduce { dims: dims_of("dimensions")?, comp }, resolve(&body)?)
        }
        "tuple" => (Op::Tuple, resolve(&body)?),
        "get-tuple-element" => {
            let index = attrs
                .get("index")
                .and_then(|v| v.parse::<usize>().ok())
                .ok_or_else(|| err("get-tuple-element: missing index"))?;
            (Op::Gte { index }, resolve(&body)?)
        }
        "while" => {
            let cond = comp_ref(
                attrs
                    .get("condition")
                    .ok_or_else(|| err("while: missing condition"))?,
                comp_names,
            )?;
            let body_c = comp_ref(
                attrs.get("body").ok_or_else(|| err("while: missing body"))?,
                comp_names,
            )?;
            (Op::While { cond, body: body_c }, resolve(&body)?)
        }
        other => {
            return Err(err(format!("unsupported HLO op '{other}'")));
        }
    };
    Ok((name, is_root, Instr { shape, op, operands }))
}

/// Parse a full HLO-text module.
pub fn parse(text: &str) -> Result<HloModule, XlaError> {
    // phase 1: split into computation blocks
    struct Block<'a> {
        name: String,
        entry: bool,
        lines: Vec<&'a str>,
    }
    let mut blocks: Vec<Block> = Vec::new();
    let mut cur: Option<Block> = None;
    for raw in text.lines() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with("HloModule") || line.starts_with("//") {
            continue;
        }
        if cur.is_none() {
            if !line.ends_with('{') {
                continue; // stray metadata between computations
            }
            let entry = line.starts_with("ENTRY");
            let at = line
                .find('%')
                .ok_or_else(|| err(format!("computation header without name: '{line}'")))?;
            let mut c = Cursor::new(&line[at + 1..]);
            let name = c.ident();
            cur = Some(Block { name, entry, lines: Vec::new() });
            continue;
        }
        if line == "}" {
            match cur.take() {
                Some(b) => blocks.push(b),
                None => return Err(err("unmatched '}' outside a computation")),
            }
            continue;
        }
        if let Some(b) = cur.as_mut() {
            b.lines.push(line);
        }
    }
    if cur.is_some() {
        return Err(err("unterminated computation block"));
    }
    if blocks.is_empty() {
        return Err(err("no computations found in HLO text"));
    }
    let mut comp_names = BTreeMap::new();
    for (i, b) in blocks.iter().enumerate() {
        comp_names.insert(b.name.clone(), i);
    }
    let entry = blocks
        .iter()
        .position(|b| b.entry)
        .unwrap_or(blocks.len() - 1);

    // phase 2: parse instructions per block
    let mut computations = Vec::with_capacity(blocks.len());
    for b in &blocks {
        let mut names: BTreeMap<String, usize> = BTreeMap::new();
        let mut instrs: Vec<Instr> = Vec::new();
        let mut params: Vec<(usize, usize)> = Vec::new();
        let mut root = None;
        for line in &b.lines {
            let (name, is_root, instr) = parse_instruction(line, &names, &comp_names)
                .map_err(|e| err(format!("{}: {e:?}", b.name)))?;
            let idx = instrs.len();
            if let Op::Parameter(k) = &instr.op {
                params.push((*k, idx));
            }
            if is_root {
                root = Some(idx);
            }
            names.insert(name, idx);
            instrs.push(instr);
        }
        if instrs.is_empty() {
            return Err(err(format!("computation {} is empty", b.name)));
        }
        let root = root.unwrap_or(instrs.len() - 1);
        params.sort();
        for (want, (got, _)) in params.iter().enumerate() {
            if *got != want {
                return Err(err(format!(
                    "computation {}: non-contiguous parameter numbers",
                    b.name
                )));
            }
        }
        let params: Vec<usize> = params.into_iter().map(|(_, i)| i).collect();
        // liveness: after an instruction's last consumer runs, drop it
        let n = instrs.len();
        let mut last_use = vec![usize::MAX; n];
        for (i, ins) in instrs.iter().enumerate() {
            for &o in &ins.operands {
                last_use[o] = i;
            }
        }
        let mut drop_after = vec![Vec::new(); n];
        for (j, &lu) in last_use.iter().enumerate() {
            if lu != usize::MAX && j != root {
                drop_after[lu].push(j);
            }
        }
        computations.push(Computation {
            name: b.name.clone(),
            instrs,
            params,
            root,
            drop_after,
        });
    }
    Ok(HloModule { computations, entry })
}

// ------------------------------------------------------------- evaluator

pub(crate) fn lit_dims(l: &Literal) -> Vec<usize> {
    l.dims.iter().map(|&d| d as usize).collect()
}

pub(crate) fn lit_dt(l: &Literal) -> Option<Dt> {
    match &l.data {
        Data::F32(_) => Some(Dt::F32),
        Data::I32(_) => Some(Dt::S32),
        Data::U32(_) => Some(Dt::U32),
        Data::Pred(_) => Some(Dt::Pred),
        Data::Tuple(_) => None,
    }
}

pub(crate) fn f32s(l: &Literal) -> Result<&[f32], XlaError> {
    match &l.data {
        Data::F32(v) => Ok(v),
        _ => Err(err("expected f32 operand")),
    }
}

pub(crate) fn strides_of(dims: &[usize]) -> Vec<usize> {
    let mut s = vec![1usize; dims.len()];
    for d in (0..dims.len().saturating_sub(1)).rev() {
        s[d] = s[d + 1] * dims[d + 1];
    }
    s
}

/// Row-major odometer over `dims`; returns false after the last index.
pub(crate) fn odo_next(idx: &mut [usize], dims: &[usize]) -> bool {
    for d in (0..dims.len()).rev() {
        idx[d] += 1;
        if idx[d] < dims[d] {
            return true;
        }
        idx[d] = 0;
    }
    false
}

fn round_ties_even(x: f32) -> f32 {
    let r = x.round(); // half away from zero
    if (x - x.trunc()).abs() == 0.5 && r % 2.0 != 0.0 {
        r - x.signum()
    } else {
        r
    }
}

// Per-element arithmetic, shared verbatim by this reference walker and
// the planned engine (`runtime::plan`) so the two paths stay
// bit-identical. Callers gate op/dtype validity; helpers assume it.

/// f32 arithmetic arm of a binary op (bitwise ops are gated out by
/// callers and unreachable here).
#[inline]
pub(crate) fn bin_f32_s(op: BinOp, x: f32, y: f32) -> f32 {
    match op {
        BinOp::Add => x + y,
        BinOp::Sub => x - y,
        BinOp::Mul => x * y,
        BinOp::Div => x / y,
        BinOp::Max => x.max(y),
        BinOp::Min => x.min(y),
        BinOp::Pow => x.powf(y),
        _ => unreachable!("bitwise op on f32 is gated by callers"),
    }
}

/// u32 arm of a binary op: wrapping arithmetic, `x / 0 == 0`, shifts by
/// >= 32 produce 0 (`Pow` is gated out by callers).
#[inline]
pub(crate) fn bin_u32_s(op: BinOp, x: u32, y: u32) -> u32 {
    match op {
        BinOp::Add => x.wrapping_add(y),
        BinOp::Sub => x.wrapping_sub(y),
        BinOp::Mul => x.wrapping_mul(y),
        BinOp::Div => {
            if y == 0 {
                0
            } else {
                x / y
            }
        }
        BinOp::Max => x.max(y),
        BinOp::Min => x.min(y),
        BinOp::And => x & y,
        BinOp::Or => x | y,
        BinOp::Xor => x ^ y,
        BinOp::Shl => {
            if y >= 32 {
                0
            } else {
                x << y
            }
        }
        BinOp::Shr => {
            if y >= 32 {
                0
            } else {
                x >> y
            }
        }
        BinOp::Pow => unreachable!("power on u32 is gated by callers"),
    }
}

/// s32 arm of a binary op: wrapping arithmetic plus min/max and the
/// bitwise trio (everything else is gated out by callers).
#[inline]
pub(crate) fn bin_i32_s(op: BinOp, x: i32, y: i32) -> i32 {
    match op {
        BinOp::Add => x.wrapping_add(y),
        BinOp::Sub => x.wrapping_sub(y),
        BinOp::Mul => x.wrapping_mul(y),
        BinOp::Max => x.max(y),
        BinOp::Min => x.min(y),
        BinOp::And => x & y,
        BinOp::Or => x | y,
        BinOp::Xor => x ^ y,
        _ => unreachable!("unsupported s32 binary op is gated by callers"),
    }
}

/// pred arm of a binary op (total: unknown ops map to `false`, matching
/// the historical evaluator).
#[inline]
pub(crate) fn bin_pred_s(op: BinOp, p: bool, q: bool) -> bool {
    match op {
        BinOp::And | BinOp::Min | BinOp::Mul => p && q,
        BinOp::Or | BinOp::Max | BinOp::Add => p || q,
        BinOp::Xor => p ^ q,
        _ => false,
    }
}

/// f32 arm of a unary op (`Not` is gated out by callers).
#[inline]
pub(crate) fn un_f32_s(op: UnOp, v: f32) -> f32 {
    match op {
        UnOp::Neg => -v,
        UnOp::Exp => v.exp(),
        UnOp::Log => v.ln(),
        UnOp::Sqrt => v.sqrt(),
        UnOp::Rsqrt => 1.0 / v.sqrt(),
        UnOp::Abs => v.abs(),
        UnOp::Sign => {
            if v > 0.0 {
                1.0
            } else if v < 0.0 {
                -1.0
            } else {
                v * 0.0
            }
        }
        UnOp::Floor => v.floor(),
        UnOp::Ceil => v.ceil(),
        UnOp::RoundTiesEven => round_ties_even(v),
        UnOp::Tanh => v.tanh(),
        UnOp::Logistic => 1.0 / (1.0 + (-v).exp()),
        UnOp::Sin => v.sin(),
        UnOp::Cos => v.cos(),
        UnOp::Not => unreachable!("not on f32 is gated by callers"),
    }
}

/// One comparison (shared by f32/s32/u32 compares).
#[inline]
pub(crate) fn cmp_s<T: PartialOrd + PartialEq>(dir: Cmp, a: &T, b: &T) -> bool {
    match dir {
        Cmp::Eq => a == b,
        Cmp::Ne => a != b,
        Cmp::Lt => a < b,
        Cmp::Le => a <= b,
        Cmp::Gt => a > b,
        Cmp::Ge => a >= b,
    }
}

/// XLA `convert` to s32: truncate toward zero.
#[inline]
pub(crate) fn f32_to_i32_xla(v: f32) -> i32 {
    v.trunc() as i32
}

/// XLA `convert` to u32: truncate toward zero, clamp negatives to 0.
#[inline]
pub(crate) fn f32_to_u32_xla(v: f32) -> u32 {
    v.trunc().max(0.0) as u32
}

fn bin_f32(op: BinOp, a: &[f32], b: &[f32], out: &mut [f32]) -> Result<(), XlaError> {
    if !matches!(
        op,
        BinOp::Add | BinOp::Sub | BinOp::Mul | BinOp::Div | BinOp::Max | BinOp::Min | BinOp::Pow
    ) {
        return Err(err("bitwise op on f32"));
    }
    for ((o, &x), &y) in out.iter_mut().zip(a).zip(b) {
        *o = bin_f32_s(op, x, y);
    }
    Ok(())
}

fn bin_u32(op: BinOp, a: &[u32], b: &[u32], out: &mut [u32]) -> Result<(), XlaError> {
    if matches!(op, BinOp::Pow) {
        return Err(err("power on u32 unsupported"));
    }
    for ((o, &x), &y) in out.iter_mut().zip(a).zip(b) {
        *o = bin_u32_s(op, x, y);
    }
    Ok(())
}

fn bin_i32(op: BinOp, a: &[i32], b: &[i32], out: &mut [i32]) -> Result<(), XlaError> {
    if !matches!(
        op,
        BinOp::Add
            | BinOp::Sub
            | BinOp::Mul
            | BinOp::Max
            | BinOp::Min
            | BinOp::And
            | BinOp::Or
            | BinOp::Xor
    ) {
        return Err(err("unsupported s32 binary op"));
    }
    for ((o, &x), &y) in out.iter_mut().zip(a).zip(b) {
        *o = bin_i32_s(op, x, y);
    }
    Ok(())
}

fn eval_bin(op: BinOp, a: &Literal, b: &Literal) -> Result<Literal, XlaError> {
    if a.dims != b.dims {
        return Err(err(format!(
            "binary op shape mismatch: {:?} vs {:?}",
            a.dims, b.dims
        )));
    }
    match (&a.data, &b.data) {
        (Data::F32(x), Data::F32(y)) => {
            let mut out = vec![0.0f32; x.len()];
            bin_f32(op, x, y, &mut out)?;
            Ok(Literal { data: Data::F32(out), dims: a.dims.clone() })
        }
        (Data::U32(x), Data::U32(y)) => {
            let mut out = vec![0u32; x.len()];
            bin_u32(op, x, y, &mut out)?;
            Ok(Literal { data: Data::U32(out), dims: a.dims.clone() })
        }
        (Data::I32(x), Data::I32(y)) => {
            let mut out = vec![0i32; x.len()];
            bin_i32(op, x, y, &mut out)?;
            Ok(Literal { data: Data::I32(out), dims: a.dims.clone() })
        }
        (Data::Pred(x), Data::Pred(y)) => {
            let out: Vec<bool> = x.iter().zip(y).map(|(&p, &q)| bin_pred_s(op, p, q)).collect();
            Ok(Literal { data: Data::Pred(out), dims: a.dims.clone() })
        }
        _ => Err(err("binary op element type mismatch")),
    }
}

fn eval_un(op: UnOp, a: &Literal) -> Result<Literal, XlaError> {
    if matches!((op, &a.data), (UnOp::Not, Data::F32(_))) {
        return Err(err("not on f32"));
    }
    match &a.data {
        Data::F32(x) => {
            let out: Vec<f32> = x.iter().map(|&v| un_f32_s(op, v)).collect();
            Ok(Literal { data: Data::F32(out), dims: a.dims.clone() })
        }
        Data::Pred(x) => match op {
            UnOp::Not => Ok(Literal {
                data: Data::Pred(x.iter().map(|&b| !b).collect()),
                dims: a.dims.clone(),
            }),
            _ => Err(err("unsupported unary op on pred")),
        },
        Data::I32(x) => match op {
            UnOp::Neg => Ok(Literal {
                data: Data::I32(x.iter().map(|&v| v.wrapping_neg()).collect()),
                dims: a.dims.clone(),
            }),
            UnOp::Abs => Ok(Literal {
                data: Data::I32(x.iter().map(|&v| v.wrapping_abs()).collect()),
                dims: a.dims.clone(),
            }),
            _ => Err(err("unsupported unary op on s32")),
        },
        Data::U32(x) => match op {
            UnOp::Not => Ok(Literal {
                data: Data::U32(x.iter().map(|&v| !v).collect()),
                dims: a.dims.clone(),
            }),
            _ => Err(err("unsupported unary op on u32")),
        },
        Data::Tuple(_) => Err(err("unary op on tuple")),
    }
}

fn eval_compare(dir: Cmp, a: &Literal, b: &Literal) -> Result<Literal, XlaError> {
    if a.dims != b.dims {
        return Err(err("compare shape mismatch"));
    }
    fn go<T: PartialOrd + PartialEq>(dir: Cmp, x: &[T], y: &[T]) -> Vec<bool> {
        x.iter().zip(y).map(|(a, b)| cmp_s(dir, a, b)).collect()
    }
    let out = match (&a.data, &b.data) {
        (Data::F32(x), Data::F32(y)) => go(dir, x, y),
        (Data::I32(x), Data::I32(y)) => go(dir, x, y),
        (Data::U32(x), Data::U32(y)) => go(dir, x, y),
        _ => return Err(err("compare element type mismatch")),
    };
    Ok(Literal { data: Data::Pred(out), dims: a.dims.clone() })
}

fn eval_convert(a: &Literal, to: Dt) -> Result<Literal, XlaError> {
    let n = a.dims.iter().product::<i64>() as usize;
    let as_f32: Vec<f32> = match &a.data {
        Data::F32(v) => v.clone(),
        Data::I32(v) => v.iter().map(|&x| x as f32).collect(),
        Data::U32(v) => v.iter().map(|&x| x as f32).collect(),
        Data::Pred(v) => v.iter().map(|&b| if b { 1.0 } else { 0.0 }).collect(),
        Data::Tuple(_) => return Err(err("convert on tuple")),
    };
    debug_assert_eq!(as_f32.len(), n);
    let data = match to {
        Dt::F32 => Data::F32(as_f32),
        // XLA convert truncates toward zero
        Dt::S32 => Data::I32(as_f32.iter().map(|&v| f32_to_i32_xla(v)).collect()),
        Dt::U32 => Data::U32(as_f32.iter().map(|&v| f32_to_u32_xla(v)).collect()),
        Dt::Pred => Data::Pred(as_f32.iter().map(|&v| v != 0.0).collect()),
    };
    Ok(Literal { data, dims: a.dims.clone() })
}

fn scalar_or_same(v: &Literal, n: usize, i: usize) -> Result<f32, XlaError> {
    let s = f32s(v)?;
    if s.len() == 1 {
        Ok(s[0])
    } else if s.len() == n {
        Ok(s[i])
    } else {
        Err(err("clamp: bound must be scalar or same-shape"))
    }
}

/// Resolved geometry of a rank-2 `dot`: output `m x n`, contracting
/// length `k`, and the per-operand strides the inner loops walk.
#[derive(Clone, Copy, Debug)]
pub(crate) struct DotDims {
    pub(crate) m: usize,
    pub(crate) k: usize,
    pub(crate) n: usize,
    pub(crate) lms: usize,
    pub(crate) lks: usize,
    pub(crate) rks: usize,
    pub(crate) rns: usize,
}

pub(crate) fn dot_dims(
    ld: &[usize],
    rd: &[usize],
    lc: usize,
    rc: usize,
) -> Result<DotDims, XlaError> {
    if ld.len() != 2 || rd.len() != 2 || lc > 1 || rc > 1 {
        return Err(err("dot: only rank-2 operands supported"));
    }
    let (m, k) = (ld[1 - lc], ld[lc]);
    let (k2, n) = (rd[rc], rd[1 - rc]);
    if k != k2 {
        return Err(err(format!("dot: contracting dims {k} vs {k2}")));
    }
    let (lms, lks) = if lc == 1 { (ld[1], 1) } else { (1, ld[1]) };
    let (rks, rns) = if rc == 0 { (rd[1], 1) } else { (1, rd[1]) };
    Ok(DotDims { m, k, n, lms, lks, rks, rns })
}

/// Accumulate output rows `row0 .. row0 + out.len() / n` of a rank-2
/// `dot` into `out` (which is zeroed here first). Each output element
/// accumulates over the contracting dim in ascending order, so
/// computing disjoint row ranges on different threads is bit-identical
/// to one serial pass — the planned engine's threaded path relies on
/// this.
pub(crate) fn dot_rows(lv: &[f32], rv: &[f32], d: &DotDims, row0: usize, out: &mut [f32]) {
    out.fill(0.0);
    let rows = if d.n == 0 { 0 } else { out.len() / d.n };
    for i in 0..rows {
        let orow = &mut out[i * d.n..(i + 1) * d.n];
        for kk in 0..d.k {
            // no skip-zero fast path: 0 * inf must stay NaN, as on XLA
            let a = lv[(row0 + i) * d.lms + kk * d.lks];
            let rbase = kk * d.rks;
            if d.rns == 1 {
                let rrow = &rv[rbase..rbase + d.n];
                for (o, &b) in orow.iter_mut().zip(rrow) {
                    *o += a * b;
                }
            } else {
                for (j, o) in orow.iter_mut().enumerate() {
                    *o += a * rv[rbase + j * d.rns];
                }
            }
        }
    }
}

fn eval_dot(l: &Literal, r: &Literal, lc: usize, rc: usize) -> Result<Literal, XlaError> {
    let (ld, rd) = (lit_dims(l), lit_dims(r));
    let d = dot_dims(&ld, &rd, lc, rc)?;
    let (lv, rv) = (f32s(l)?, f32s(r)?);
    let mut out = vec![0.0f32; d.m * d.n];
    dot_rows(lv, rv, &d, 0, &mut out);
    Ok(Literal {
        data: Data::F32(out),
        dims: vec![d.m as i64, d.n as i64],
    })
}

fn eval_broadcast(a: &Literal, bdims: &[usize], out_dims: &[usize]) -> Result<Literal, XlaError> {
    let sdims = lit_dims(a);
    if sdims.len() != bdims.len() {
        return Err(err("broadcast: dimensions length mismatch"));
    }
    let sstr = strides_of(&sdims);
    let mut ostr = vec![0usize; out_dims.len()];
    for (pos, &od) in bdims.iter().enumerate() {
        if od >= out_dims.len() || out_dims[od] != sdims[pos] {
            return Err(err("broadcast: dimension mapping mismatch"));
        }
        ostr[od] = sstr[pos];
    }
    let n: usize = out_dims.iter().product();
    let mut idx = vec![0usize; out_dims.len()];
    macro_rules! bc {
        ($src:expr, $mk:expr) => {{
            let src = $src;
            let mut out = Vec::with_capacity(n);
            if n > 0 {
                loop {
                    let mut off = 0usize;
                    for d in 0..idx.len() {
                        off += idx[d] * ostr[d];
                    }
                    out.push(src[off]);
                    if !odo_next(&mut idx, out_dims) {
                        break;
                    }
                }
            }
            $mk(out)
        }};
    }
    let data = match &a.data {
        Data::F32(v) => bc!(v, Data::F32),
        Data::I32(v) => bc!(v, Data::I32),
        Data::U32(v) => bc!(v, Data::U32),
        Data::Pred(v) => bc!(v, Data::Pred),
        Data::Tuple(_) => return Err(err("broadcast on tuple")),
    };
    Ok(Literal {
        data,
        dims: out_dims.iter().map(|&d| d as i64).collect(),
    })
}

/// Gather `src[f(i)]` for every output index, where `f` maps the output
/// odometer through per-dim strides/offsets — shared by transpose,
/// slice and (inverted) pad.
fn eval_transpose(a: &Literal, perm: &[usize]) -> Result<Literal, XlaError> {
    let sdims = lit_dims(a);
    if perm.len() != sdims.len() {
        return Err(err("transpose: permutation rank mismatch"));
    }
    let sstr = strides_of(&sdims);
    let out_dims: Vec<usize> = perm.iter().map(|&p| sdims[p]).collect();
    let ostr: Vec<usize> = perm.iter().map(|&p| sstr[p]).collect();
    let n: usize = out_dims.iter().product();
    let mut idx = vec![0usize; out_dims.len()];
    macro_rules! tr {
        ($src:expr, $mk:expr) => {{
            let src = $src;
            let mut out = Vec::with_capacity(n);
            if n > 0 {
                loop {
                    let mut off = 0usize;
                    for d in 0..idx.len() {
                        off += idx[d] * ostr[d];
                    }
                    out.push(src[off]);
                    if !odo_next(&mut idx, &out_dims) {
                        break;
                    }
                }
            }
            $mk(out)
        }};
    }
    let data = match &a.data {
        Data::F32(v) => tr!(v, Data::F32),
        Data::I32(v) => tr!(v, Data::I32),
        Data::U32(v) => tr!(v, Data::U32),
        Data::Pred(v) => tr!(v, Data::Pred),
        Data::Tuple(_) => return Err(err("transpose on tuple")),
    };
    Ok(Literal {
        data,
        dims: out_dims.iter().map(|&d| d as i64).collect(),
    })
}

fn eval_slice(
    a: &Literal,
    starts: &[usize],
    limits: &[usize],
    strides: &[usize],
) -> Result<Literal, XlaError> {
    let sdims = lit_dims(a);
    if starts.len() != sdims.len() {
        return Err(err("slice: rank mismatch"));
    }
    let sstr = strides_of(&sdims);
    let mut out_dims = Vec::with_capacity(sdims.len());
    for d in 0..sdims.len() {
        if limits[d] > sdims[d] || starts[d] > limits[d] || strides[d] == 0 {
            return Err(err("slice: bounds out of range"));
        }
        out_dims.push((limits[d] - starts[d]).div_ceil(strides[d]));
    }
    let n: usize = out_dims.iter().product();
    let mut idx = vec![0usize; out_dims.len()];
    macro_rules! sl {
        ($src:expr, $mk:expr) => {{
            let src = $src;
            let mut out = Vec::with_capacity(n);
            if n > 0 {
                loop {
                    let mut off = 0usize;
                    for d in 0..idx.len() {
                        off += (starts[d] + idx[d] * strides[d]) * sstr[d];
                    }
                    out.push(src[off]);
                    if !odo_next(&mut idx, &out_dims) {
                        break;
                    }
                }
            }
            $mk(out)
        }};
    }
    let data = match &a.data {
        Data::F32(v) => sl!(v, Data::F32),
        Data::I32(v) => sl!(v, Data::I32),
        Data::U32(v) => sl!(v, Data::U32),
        Data::Pred(v) => sl!(v, Data::Pred),
        Data::Tuple(_) => return Err(err("slice on tuple")),
    };
    Ok(Literal {
        data,
        dims: out_dims.iter().map(|&d| d as i64).collect(),
    })
}

fn eval_concat(parts: &[&Literal], dim: usize) -> Result<Literal, XlaError> {
    let first = lit_dims(parts[0]);
    if dim >= first.len() {
        return Err(err("concatenate: dimension out of range"));
    }
    let mut out_dims = first.clone();
    out_dims[dim] = 0;
    for p in parts {
        let d = lit_dims(p);
        if d.len() != first.len() {
            return Err(err("concatenate: rank mismatch"));
        }
        for (dd, (&a, &b)) in d.iter().zip(&first).enumerate() {
            if dd != dim && a != b {
                return Err(err(format!(
                    "concatenate: dim {dd} mismatch ({a} vs {b})"
                )));
            }
        }
        out_dims[dim] += d[dim];
    }
    let outer: usize = first[..dim].iter().product();
    macro_rules! cc {
        ($arm:ident, $t:ty) => {{
            let mut out: Vec<$t> = Vec::with_capacity(out_dims.iter().product());
            for o in 0..outer {
                for p in parts {
                    let d = lit_dims(p);
                    let inner: usize = d[dim..].iter().product();
                    let v = match &p.data {
                        Data::$arm(v) => v,
                        _ => return Err(err("concatenate element type mismatch")),
                    };
                    out.extend_from_slice(&v[o * inner..(o + 1) * inner]);
                }
            }
            Data::$arm(out)
        }};
    }
    let data = match &parts[0].data {
        Data::F32(_) => cc!(F32, f32),
        Data::I32(_) => cc!(I32, i32),
        Data::U32(_) => cc!(U32, u32),
        Data::Pred(_) => cc!(Pred, bool),
        Data::Tuple(_) => return Err(err("concatenate on tuple")),
    };
    Ok(Literal {
        data,
        dims: out_dims.iter().map(|&d| d as i64).collect(),
    })
}

fn eval_pad(
    a: &Literal,
    padv: &Literal,
    low: &[i64],
    high: &[i64],
    interior: &[usize],
) -> Result<Literal, XlaError> {
    let sdims = lit_dims(a);
    if low.len() != sdims.len() {
        return Err(err("pad: rank mismatch"));
    }
    let mut out_dims = Vec::with_capacity(sdims.len());
    for d in 0..sdims.len() {
        let span = sdims[d] as i64 + (sdims[d].saturating_sub(1) * interior[d]) as i64;
        let od = span + low[d] + high[d];
        if od < 0 {
            return Err(err("pad: negative output dimension"));
        }
        out_dims.push(od as usize);
    }
    let ostr = strides_of(&out_dims);
    let n: usize = out_dims.iter().product();
    let mut idx = vec![0usize; sdims.len()];
    macro_rules! pd {
        ($src:expr, $pv:expr, $mk:expr) => {{
            let src = $src;
            let mut out = vec![$pv; n];
            let mut soff = 0usize;
            if !src.is_empty() {
                loop {
                    let mut off = 0i64;
                    let mut ok = true;
                    for d in 0..idx.len() {
                        let o = low[d] + (idx[d] * (interior[d] + 1)) as i64;
                        if o < 0 || o as usize >= out_dims[d] {
                            ok = false;
                            break;
                        }
                        off += o * ostr[d] as i64;
                    }
                    if ok {
                        out[off as usize] = src[soff];
                    }
                    soff += 1;
                    if !odo_next(&mut idx, &sdims) {
                        break;
                    }
                }
            }
            $mk(out)
        }};
    }
    let data = match (&a.data, &padv.data) {
        (Data::F32(v), Data::F32(p)) => pd!(v, p[0], Data::F32),
        (Data::I32(v), Data::I32(p)) => pd!(v, p[0], Data::I32),
        (Data::U32(v), Data::U32(p)) => pd!(v, p[0], Data::U32),
        _ => return Err(err("pad element type mismatch")),
    };
    Ok(Literal {
        data,
        dims: out_dims.iter().map(|&d| d as i64).collect(),
    })
}

/// Which monoid a reduce sub-computation implements, if recognizable.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum Monoid {
    Add,
    Max,
    Min,
    Mul,
    Generic,
}

pub(crate) fn reduce_monoid(comp: &Computation) -> Monoid {
    // fast path: root is a single binary op over the two parameters
    let root = &comp.instrs[comp.root];
    if comp.params.len() == 2 && root.operands.len() == 2 {
        let ops: Vec<usize> = root.operands.clone();
        let is_params = (ops[0] == comp.params[0] && ops[1] == comp.params[1])
            || (ops[0] == comp.params[1] && ops[1] == comp.params[0]);
        if is_params {
            if let Op::Bin(b) = &root.op {
                return match *b {
                    BinOp::Add => Monoid::Add,
                    BinOp::Max => Monoid::Max,
                    BinOp::Min => Monoid::Min,
                    BinOp::Mul => Monoid::Mul,
                    _ => Monoid::Generic,
                };
            }
        }
    }
    Monoid::Generic
}

pub(crate) fn scalar_literal_f32(v: f32) -> Literal {
    Literal { data: Data::F32(vec![v]), dims: vec![] }
}

fn getv(env: &[Option<Literal>], o: usize) -> Result<&Literal, XlaError> {
    env[o]
        .as_ref()
        .ok_or_else(|| err("internal: operand value dropped before use"))
}

/// Row-major f32 `iota` values along `dim` (shared by the reference
/// walker and the planned engine's plan-time iota folding).
pub(crate) fn iota_values(dims: &[usize], dim: usize) -> Vec<usize> {
    let n: usize = dims.iter().product();
    let mut idx = vec![0usize; dims.len()];
    let mut vals: Vec<usize> = Vec::with_capacity(n);
    if n > 0 {
        loop {
            vals.push(idx[dim]);
            if !odo_next(&mut idx, dims) {
                break;
            }
        }
    }
    vals
}

/// The one f32 `reduce` implementation shared by both execution paths:
/// accumulates the flat row-major traversal of `v` into the kept-dims
/// output (seeded with `init`), using `monoid` fast paths or the
/// `generic` two-argument combiner. Writes into `out` (cleared first)
/// and returns the output dims — bit-identical accumulation order on
/// every path.
pub(crate) fn reduce_f32(
    v: &[f32],
    init: f32,
    sdims: &[usize],
    rdims: &[usize],
    monoid: Monoid,
    out: &mut Vec<f32>,
    mut generic: impl FnMut(f32, f32) -> Result<f32, XlaError>,
) -> Result<Vec<usize>, XlaError> {
    let keep: Vec<usize> = (0..sdims.len()).filter(|d| !rdims.contains(d)).collect();
    let out_dims: Vec<usize> = keep.iter().map(|&d| sdims[d]).collect();
    let n_out: usize = out_dims.iter().product();
    let ostr = strides_of(&out_dims);
    out.clear();
    out.resize(n_out, init);
    if v.is_empty() {
        return Ok(out_dims);
    }
    let mut idx = vec![0usize; sdims.len()];
    let mut flat = 0usize;
    loop {
        let mut off = 0usize;
        for (pos, &d) in keep.iter().enumerate() {
            off += idx[d] * ostr[pos];
        }
        let x = v[flat];
        out[off] = match monoid {
            Monoid::Add => out[off] + x,
            Monoid::Max => out[off].max(x),
            Monoid::Min => out[off].min(x),
            Monoid::Mul => out[off] * x,
            Monoid::Generic => generic(out[off], x)?,
        };
        flat += 1;
        if !odo_next(&mut idx, sdims) {
            break;
        }
    }
    Ok(out_dims)
}

impl HloModule {
    fn eval_reduce(
        &self,
        a: &Literal,
        init: &Literal,
        rdims: &[usize],
        comp_idx: usize,
    ) -> Result<Literal, XlaError> {
        let sdims = lit_dims(a);
        let monoid = reduce_monoid(&self.computations[comp_idx]);
        match (&a.data, &init.data) {
            (Data::F32(v), Data::F32(iv)) => {
                let mut out = Vec::new();
                let out_dims =
                    reduce_f32(v, iv[0], &sdims, rdims, monoid, &mut out, |acc, x| {
                        let r = self.eval_comp(
                            comp_idx,
                            vec![Some(scalar_literal_f32(acc)), Some(scalar_literal_f32(x))],
                        )?;
                        Ok(f32s(&r)?[0])
                    })?;
                Ok(Literal {
                    data: Data::F32(out),
                    dims: out_dims.iter().map(|&d| d as i64).collect(),
                })
            }
            _ => Err(err("reduce: only f32 operands supported")),
        }
    }

    fn eval_comp(&self, ci: usize, mut args: Vec<Option<Literal>>) -> Result<Literal, XlaError> {
        let comp = &self.computations[ci];
        if args.len() != comp.params.len() {
            return Err(err(format!(
                "{}: expected {} arguments, got {}",
                comp.name,
                comp.params.len(),
                args.len()
            )));
        }
        let mut env: Vec<Option<Literal>> = vec![None; comp.instrs.len()];
        for i in 0..comp.instrs.len() {
            let instr = &comp.instrs[i];
            let value: Literal = match &instr.op {
                Op::Parameter(k) => args[*k]
                    .take()
                    .ok_or_else(|| err("parameter consumed twice"))?,
                Op::Constant(l) => l.clone(),
                Op::Iota { dim } => {
                    let dims = instr.shape.dims()?.to_vec();
                    let vals = iota_values(&dims, *dim);
                    let dims_i: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                    match instr.shape.dt()? {
                        Dt::U32 => Literal {
                            data: Data::U32(vals.iter().map(|&v| v as u32).collect()),
                            dims: dims_i,
                        },
                        Dt::S32 => Literal {
                            data: Data::I32(vals.iter().map(|&v| v as i32).collect()),
                            dims: dims_i,
                        },
                        Dt::F32 => Literal {
                            data: Data::F32(vals.iter().map(|&v| v as f32).collect()),
                            dims: dims_i,
                        },
                        Dt::Pred => return Err(err("iota on pred")),
                    }
                }
                Op::Bin(b) => {
                    let x = getv(&env, instr.operands[0])?;
                    let y = getv(&env, instr.operands[1])?;
                    eval_bin(*b, x, y)?
                }
                Op::Un(u) => eval_un(*u, getv(&env, instr.operands[0])?)?,
                Op::Compare(d) => {
                    let x = getv(&env, instr.operands[0])?;
                    let y = getv(&env, instr.operands[1])?;
                    eval_compare(*d, x, y)?
                }
                Op::Select => {
                    let p = getv(&env, instr.operands[0])?;
                    let t = getv(&env, instr.operands[1])?;
                    let f = getv(&env, instr.operands[2])?;
                    let pv = match &p.data {
                        Data::Pred(v) => v,
                        _ => return Err(err("select: predicate must be pred")),
                    };
                    if t.dims != f.dims {
                        return Err(err("select: branch shape mismatch"));
                    }
                    match (&t.data, &f.data) {
                        (Data::F32(a), Data::F32(b)) => {
                            let out: Vec<f32> = (0..a.len())
                                .map(|j| {
                                    let c = if pv.len() == 1 { pv[0] } else { pv[j] };
                                    if c {
                                        a[j]
                                    } else {
                                        b[j]
                                    }
                                })
                                .collect();
                            Literal { data: Data::F32(out), dims: t.dims.clone() }
                        }
                        (Data::U32(a), Data::U32(b)) => {
                            let out: Vec<u32> = (0..a.len())
                                .map(|j| {
                                    let c = if pv.len() == 1 { pv[0] } else { pv[j] };
                                    if c {
                                        a[j]
                                    } else {
                                        b[j]
                                    }
                                })
                                .collect();
                            Literal { data: Data::U32(out), dims: t.dims.clone() }
                        }
                        _ => return Err(err("select: unsupported element types")),
                    }
                }
                Op::Clamp => {
                    let lo = getv(&env, instr.operands[0])?;
                    let x = getv(&env, instr.operands[1])?;
                    let hi = getv(&env, instr.operands[2])?;
                    let xv = f32s(x)?;
                    let mut out = vec![0.0f32; xv.len()];
                    for (j, o) in out.iter_mut().enumerate() {
                        let l = scalar_or_same(lo, xv.len(), j)?;
                        let h = scalar_or_same(hi, xv.len(), j)?;
                        *o = xv[j].clamp(l, h);
                    }
                    Literal { data: Data::F32(out), dims: x.dims.clone() }
                }
                Op::Convert => eval_convert(getv(&env, instr.operands[0])?, instr.shape.dt()?)?,
                Op::Broadcast { dims } => {
                    eval_broadcast(getv(&env, instr.operands[0])?, dims, instr.shape.dims()?)?
                }
                Op::Reshape => {
                    let a = getv(&env, instr.operands[0])?;
                    let out_dims = instr.shape.dims()?;
                    let n: usize = out_dims.iter().product();
                    if n != a.dims.iter().product::<i64>() as usize {
                        return Err(err("reshape: element count mismatch"));
                    }
                    Literal {
                        data: a.data.clone(),
                        dims: out_dims.iter().map(|&d| d as i64).collect(),
                    }
                }
                Op::Transpose { perm } => eval_transpose(getv(&env, instr.operands[0])?, perm)?,
                Op::Slice { starts, limits, strides } => {
                    eval_slice(getv(&env, instr.operands[0])?, starts, limits, strides)?
                }
                Op::Concat { dim } => {
                    let parts: Vec<&Literal> = instr
                        .operands
                        .iter()
                        .map(|o| getv(&env, *o))
                        .collect::<Result<_, _>>()?;
                    eval_concat(&parts, *dim)?
                }
                Op::Pad { low, high, interior } => eval_pad(
                    getv(&env, instr.operands[0])?,
                    getv(&env, instr.operands[1])?,
                    low,
                    high,
                    interior,
                )?,
                Op::Dot { lc, rc } => {
                    let x = getv(&env, instr.operands[0])?;
                    let y = getv(&env, instr.operands[1])?;
                    eval_dot(x, y, *lc, *rc)?
                }
                Op::Reduce { dims, comp } => self.eval_reduce(
                    getv(&env, instr.operands[0])?,
                    getv(&env, instr.operands[1])?,
                    dims,
                    *comp,
                )?,
                Op::Tuple => {
                    let parts: Vec<Literal> = instr
                        .operands
                        .iter()
                        .map(|o| getv(&env, *o).cloned())
                        .collect::<Result<_, _>>()?;
                    let n = parts.len() as i64;
                    Literal { data: Data::Tuple(parts), dims: vec![n] }
                }
                Op::Gte { index } => {
                    let t = getv(&env, instr.operands[0])?;
                    match &t.data {
                        Data::Tuple(parts) => parts
                            .get(*index)
                            .cloned()
                            .ok_or_else(|| err("get-tuple-element: index out of range"))?,
                        _ => return Err(err("get-tuple-element on non-tuple")),
                    }
                }
                Op::While { cond, body } => {
                    let mut state = getv(&env, instr.operands[0])?.clone();
                    let mut fuel = 100_000_000u64;
                    loop {
                        let c = self.eval_comp(*cond, vec![Some(state.clone())])?;
                        let go = match &c.data {
                            Data::Pred(v) => v.first().copied().unwrap_or(false),
                            _ => return Err(err("while: condition must return pred")),
                        };
                        if !go {
                            break;
                        }
                        state = self.eval_comp(*body, vec![Some(state)])?;
                        fuel = fuel
                            .checked_sub(1)
                            .ok_or_else(|| err("while: iteration limit exceeded"))?;
                    }
                    state
                }
            };
            env[i] = Some(value);
            for &j in &comp.drop_after[i] {
                if j != i {
                    env[j] = None;
                }
            }
        }
        env[comp.root]
            .take()
            .ok_or_else(|| err("root value missing"))
    }
}

/// Validate `args` against a computation's parameters (shape and
/// element type) — shared by [`execute_ref`] and the planned engine.
pub(crate) fn validate_args(comp: &Computation, args: &[Literal]) -> Result<(), XlaError> {
    if args.len() != comp.params.len() {
        return Err(err(format!(
            "entry expects {} arguments, got {}",
            comp.params.len(),
            args.len()
        )));
    }
    for (k, a) in args.iter().enumerate() {
        let pshape = &comp.instrs[comp.params[k]].shape;
        let pdims = pshape.dims()?;
        let adims = lit_dims(a);
        if adims != pdims {
            return Err(err(format!(
                "argument {k}: shape {adims:?} does not match parameter {pdims:?}"
            )));
        }
        let want = pshape.dt()?;
        let got = lit_dt(a).ok_or_else(|| err("tuple arguments unsupported"))?;
        if want != got {
            return Err(err(format!(
                "argument {k}: element type {got:?} does not match parameter {want:?}"
            )));
        }
    }
    Ok(())
}

/// Validate `args` against the entry parameters and run the module on
/// the scalar reference walker.
///
/// This path defines the op semantics; the planned engine
/// ([`crate::runtime::plan::Plan`]) must match it bit-for-bit. Use it
/// for golden tests and as the equivalence oracle — the production hot
/// path is the plan.
pub fn execute_ref(m: &HloModule, args: Vec<Literal>) -> Result<Literal, XlaError> {
    let comp = &m.computations[m.entry];
    validate_args(comp, &args)?;
    m.eval_comp(m.entry, args.into_iter().map(Some).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run1(text: &str, args: Vec<Literal>) -> Literal {
        let m = parse(text).expect("parse");
        execute_ref(&m, args).expect("execute")
    }

    fn f32v(l: &Literal) -> Vec<f32> {
        l.to_vec::<f32>().unwrap()
    }

    #[test]
    fn parses_and_adds() {
        let out = run1(
            "HloModule t\n\nENTRY %main (p0: f32[3], p1: f32[3]) -> f32[3] {\n  \
             %p0 = f32[3] parameter(0)\n  %p1 = f32[3] parameter(1)\n  \
             ROOT %v1 = f32[3] add(%p0, %p1)\n}\n",
            vec![
                Literal::vec1(&[1.0f32, 2.0, 3.0]),
                Literal::vec1(&[0.5f32, 0.5, 0.5]),
            ],
        );
        assert_eq!(f32v(&out), vec![1.5, 2.5, 3.5]);
    }

    #[test]
    fn dot_matches_hand_computed() {
        let a = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0])
            .reshape(&[2, 3])
            .unwrap();
        let b = Literal::vec1(&[1.0f32, 0.0, 0.0, 1.0, 1.0, 1.0])
            .reshape(&[3, 2])
            .unwrap();
        let out = run1(
            "ENTRY %main (p0: f32[2,3], p1: f32[3,2]) -> f32[2,2] {\n  \
             %p0 = f32[2,3] parameter(0)\n  %p1 = f32[3,2] parameter(1)\n  \
             ROOT %v1 = f32[2,2] dot(%p0, %p1), lhs_contracting_dims={1}, \
             rhs_contracting_dims={0}\n}\n",
            vec![a, b],
        );
        assert_eq!(f32v(&out), vec![4.0, 5.0, 10.0, 11.0]);
    }

    #[test]
    fn reduce_broadcast_iota_roundtrip() {
        let x = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0])
            .reshape(&[2, 3])
            .unwrap();
        let out = run1(
            "%r_add (a: f32[], b: f32[]) -> f32[] {\n  %a = f32[] parameter(0)\n  \
             %b = f32[] parameter(1)\n  ROOT %v1 = f32[] add(%a, %b)\n}\n\n\
             ENTRY %main (p0: f32[2,3]) -> f32[2,3] {\n  \
             %p0 = f32[2,3] parameter(0)\n  %c0 = f32[] constant(0)\n  \
             %s = f32[2] reduce(%p0, %c0), dimensions={1}, to_apply=%r_add\n  \
             %b = f32[2,3] broadcast(%s), dimensions={0}\n  \
             %i = f32[2,3] iota(), iota_dimension=1\n  \
             ROOT %v9 = f32[2,3] add(%b, %i)\n}\n",
            vec![x],
        );
        assert_eq!(f32v(&out), vec![6.0, 7.0, 8.0, 15.0, 16.0, 17.0]);
    }

    #[test]
    fn transpose_slice_concat_pad() {
        let x = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        let out = run1(
            "ENTRY %main (p0: f32[2,2]) -> f32[3,2] {\n  \
             %p0 = f32[2,2] parameter(0)\n  \
             %t = f32[2,2] transpose(%p0), dimensions={1,0}\n  \
             %s = f32[1,2] slice(%t), slice={[0:1:1],[0:2:1]}\n  \
             ROOT %c = f32[3,2] concatenate(%t, %s), dimensions={0}\n}\n",
            vec![x.clone()],
        );
        assert_eq!(f32v(&out), vec![1.0, 3.0, 2.0, 4.0, 1.0, 3.0]);
        let out = run1(
            "ENTRY %main (p0: f32[2,2]) -> f32[4,2] {\n  \
             %p0 = f32[2,2] parameter(0)\n  %z = f32[] constant(9)\n  \
             ROOT %p = f32[4,2] pad(%p0, %z), padding=1_1x0_0\n}\n",
            vec![x],
        );
        assert_eq!(f32v(&out), vec![9.0, 9.0, 1.0, 2.0, 3.0, 4.0, 9.0, 9.0]);
    }

    #[test]
    fn compare_select_convert_clamp() {
        let x = Literal::vec1(&[-2.0f32, 0.5, 3.0]);
        let out = run1(
            "ENTRY %main (p0: f32[3]) -> f32[3] {\n  \
             %p0 = f32[3] parameter(0)\n  %z = f32[] constant(0)\n  \
             %zb = f32[3] broadcast(%z), dimensions={}\n  \
             %m = pred[3] compare(%p0, %zb), direction=GT\n  \
             %one = f32[] constant(1)\n  \
             %ob = f32[3] broadcast(%one), dimensions={}\n  \
             %sel = f32[3] select(%m, %p0, %ob)\n  \
             %lo = f32[] constant(-1)\n  %hi = f32[] constant(2)\n  \
             ROOT %c = f32[3] clamp(%lo, %sel, %hi)\n}\n",
            vec![x],
        );
        assert_eq!(f32v(&out), vec![1.0, 1.0, 2.0]);
    }

    #[test]
    fn u32_hash_ops_work() {
        let k = Literal::vec1(&[7u32, 11]);
        let out = run1(
            "ENTRY %main (p0: u32[2]) -> u32[2] {\n  \
             %p0 = u32[2] parameter(0)\n  %c = u32[] constant(2654435761)\n  \
             %cb = u32[2] broadcast(%c), dimensions={}\n  \
             %m = u32[2] multiply(%p0, %cb)\n  %s = u32[] constant(16)\n  \
             %sb = u32[2] broadcast(%s), dimensions={}\n  \
             %h = u32[2] shift-right-logical(%m, %sb)\n  \
             ROOT %x = u32[2] xor(%m, %h)\n}\n",
            vec![k],
        );
        let v = out.to_vec::<u32>().unwrap();
        let f = |x: u32| {
            let m = x.wrapping_mul(2654435761);
            m ^ (m >> 16)
        };
        assert_eq!(v, vec![f(7), f(11)]);
    }

    #[test]
    fn round_ties_even_matches_jnp_round() {
        let x = Literal::vec1(&[0.5f32, 1.5, 2.5, -0.5, -1.5, 2.3, -2.7]);
        let out = run1(
            "ENTRY %main (p0: f32[7]) -> f32[7] {\n  \
             %p0 = f32[7] parameter(0)\n  \
             ROOT %r = f32[7] round-nearest-even(%p0)\n}\n",
            vec![x],
        );
        assert_eq!(f32v(&out), vec![0.0, 2.0, 2.0, -0.0, -2.0, 2.0, -3.0]);
    }

    #[test]
    fn while_loop_counts() {
        let text = "%cond (s: (u32[], u32[])) -> pred[] {\n  \
                    %s = (u32[], u32[]) parameter(0)\n  \
                    %j = u32[] get-tuple-element(%s), index=0\n  \
                    %n = u32[] get-tuple-element(%s), index=1\n  \
                    ROOT %lt = pred[] compare(%j, %n), direction=LT\n}\n\n\
                    %body (s: (u32[], u32[])) -> (u32[], u32[]) {\n  \
                    %s = (u32[], u32[]) parameter(0)\n  \
                    %j = u32[] get-tuple-element(%s), index=0\n  \
                    %n = u32[] get-tuple-element(%s), index=1\n  \
                    %one = u32[] constant(1)\n  %j2 = u32[] add(%j, %one)\n  \
                    ROOT %t = (u32[], u32[]) tuple(%j2, %n)\n}\n\n\
                    ENTRY %main (p0: u32[]) -> u32[] {\n  \
                    %p0 = u32[] parameter(0)\n  %z = u32[] constant(0)\n  \
                    %init = (u32[], u32[]) tuple(%z, %p0)\n  \
                    %w = (u32[], u32[]) while(%init), condition=%cond, body=%body\n  \
                    ROOT %j = u32[] get-tuple-element(%w), index=0\n}\n";
        let out = run1(text, vec![Literal::vec1(&[5u32]).reshape(&[]).unwrap()]);
        assert_eq!(out.to_vec::<u32>().unwrap(), vec![5]);
    }

    #[test]
    fn unsupported_op_is_a_parse_error() {
        let e = parse(
            "ENTRY %main (p0: f32[2]) -> f32[2] {\n  %p0 = f32[2] parameter(0)\n  \
             ROOT %f = f32[2] fft(%p0)\n}\n",
        );
        assert!(e.is_err());
        assert!(format!("{:?}", e.err().unwrap()).contains("unsupported HLO op"));
    }

    #[test]
    fn argument_mismatches_error_cleanly() {
        let m = parse(
            "ENTRY %main (p0: f32[2]) -> f32[2] {\n  %p0 = f32[2] parameter(0)\n  \
             ROOT %n = f32[2] negate(%p0)\n}\n",
        )
        .unwrap();
        // wrong arity
        assert!(execute_ref(&m, vec![]).is_err());
        // wrong shape
        assert!(execute_ref(&m, vec![Literal::vec1(&[1.0f32, 2.0, 3.0])]).is_err());
        // wrong dtype
        assert!(execute_ref(&m, vec![Literal::vec1(&[1u32, 2])]).is_err());
    }

    #[test]
    fn operands_must_be_defined_before_use() {
        let e = parse(
            "ENTRY %main (p0: f32[2]) -> f32[2] {\n  \
             %a = f32[2] add(%p0, %zz)\n  %p0 = f32[2] parameter(0)\n}\n",
        );
        assert!(e.is_err());
    }
}
