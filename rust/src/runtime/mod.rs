//! PJRT runtime: loads the AOT artifacts produced by `make artifacts`
//! and executes them from the Rust hot path (Python is never invoked).
//!
//! Layering: [`artifact`] parses the manifest, [`interp`] parses HLO
//! text and defines the reference op semantics, [`plan`] compiles a
//! parsed module into the planned execution engine (the hot path),
//! [`xla`] mirrors the PJRT API surface over both, [`verify`]
//! statically cross-checks compiled plans without executing them, and
//! [`executor`] caches compiled executables and moves host tensors
//! across the boundary.

#![warn(missing_docs)]
#![cfg_attr(not(test), warn(clippy::unwrap_used))]

pub mod artifact;
pub mod executor;
pub mod interp;
pub mod literal;
pub mod plan;
pub mod verify;
pub mod xla;

pub use artifact::{ArtifactSpec, Dtype, IoSpec, ModelSpec, Registry, StateLeaf};
pub use executor::{Executor, StageExecSpec};
pub use literal::HostTensor;
pub use verify::{verify_hlo_text, verify_plan, VerifyError, VerifyStats};
