//! PJRT runtime: loads the AOT artifacts produced by `make artifacts`
//! and executes them from the Rust hot path (Python is never invoked).

pub mod artifact;
pub mod executor;
pub mod interp;
pub mod literal;
pub mod xla;

pub use artifact::{ArtifactSpec, Dtype, IoSpec, ModelSpec, Registry, StateLeaf};
pub use executor::Executor;
pub use literal::HostTensor;
