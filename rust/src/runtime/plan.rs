//! Planned execution engine for parsed HLO modules.
//!
//! [`Plan::new`] compiles an [`HloModule`] once into a flat instruction
//! program per computation: operand indices and data sources are
//! resolved at plan time (reshape and `get-tuple-element` become
//! zero-cost aliases, `iota` folds to a constant), chains of
//! elementwise ops are fused into single blocked loops over f32 / u32 /
//! pred slabs, the rank-2 `dot` fans out to a row-chunked
//! `std::thread::scope` path, and every instruction's output buffer is
//! assigned by a liveness-based plan so buffers are reused within a
//! call *and cached across `execute` calls* — the trainer executes the
//! same step computation thousands of times.
//!
//! The engine is required to be **bit-for-bit identical** to the scalar
//! reference walker [`interp::execute_ref`]: every per-element formula
//! is the shared `*_s` scalar helper from `runtime::interp`, fused
//! loops evaluate elements independently, the threaded `dot`
//! accumulates each output element in the same contracting-dim order
//! regardless of thread count, and `reduce` runs the one shared
//! [`interp::reduce_f32`] accumulation. `rust/tests/plan_equivalence.rs`
//! pins this across every checked-in artifact; DESIGN.md "planned
//! interpreter execution" documents the layout and the rules for
//! adding ops.

#![warn(missing_docs)]

use std::cell::{Cell, RefCell};
use std::collections::BTreeMap;
use std::rc::Rc;

use crate::runtime::interp::{
    self, bin_f32_s, bin_i32_s, bin_pred_s, bin_u32_s, cmp_s, dot_dims, dot_rows, err,
    f32_to_i32_xla, f32_to_u32_xla, iota_values, odo_next, reduce_f32, reduce_monoid,
    scalar_literal_f32, strides_of, un_f32_s, validate_args, BinOp, Cmp, Computation, Dt,
    HloModule, Op, Shape, UnOp,
};
use crate::runtime::xla::{Data, Literal, XlaError};

/// Elements per fused-loop block: one slab row per fused member.
const BLOCK: usize = 256;

/// `m * k * n` threshold below which `dot` stays serial (thread spawn
/// costs more than the multiply).
const DOT_PAR_MIN_FLOPS: usize = 1 << 17;

/// Upper bound on `dot` worker threads: mirrors the fixed row-chunk
/// scheme of `device/array.rs` (`PAR_CHUNK_ROWS`) — the chunking is a
/// function of the shape, never of the machine, so results are
/// identical for every thread count.
const DOT_MAX_WORKERS: usize = 8;

/// Maximum array rank the strided-gather kernels handle (the artifacts
/// use rank <= 4).
const MAX_RANK: usize = 16;

// ------------------------------------------------------------ plan types

/// Where a slot's value lives at run time (resolved at plan time).
///
/// `pub(crate)` (with the other plan data types below) so the static
/// verifier in [`crate::runtime::verify`] can inspect compiled plans
/// through [`Plan::inspect`] — the verifier reads these records but
/// re-derives everything else independently.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum ValSrc {
    /// Pooled buffer in the computation's cached state.
    Buf(usize),
    /// Plan-owned literal (constants and folded iotas).
    Const(usize),
    /// Caller argument `k` (borrowed, never copied).
    Param(usize),
    /// Element `j` of tuple argument `k`.
    ParamPart(usize, usize),
    /// Per-run owned literal (a `while` result).
    Lit(usize),
    /// Element `j` of per-run literal `li`.
    LitPart(usize, usize),
    /// Tuple assembled on demand from the instruction's operands.
    Tuple,
    /// Dead code or a fused non-root member: never materialized.
    Dead,
}

/// Canonical data source of a slot with aliases (reshape /
/// gte-of-tuple) resolved away.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum CSrc {
    /// Produced by instruction `s` (a real producer, never an alias).
    Slot(usize),
    Param(usize),
    ParamPart(usize, usize),
    /// Element `j` of the `while` at slot `w`.
    WhilePart(usize, usize),
}

/// Slab element type of a fused member.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum SDt {
    F32,
    U32,
    Pred,
}

pub(crate) fn to_sdt(dt: Dt) -> Option<SDt> {
    match dt {
        Dt::F32 => Some(SDt::F32),
        Dt::U32 => Some(SDt::U32),
        Dt::Pred => Some(SDt::Pred),
        Dt::S32 => None,
    }
}

/// A fused operand: an earlier member's slab or an external input.
#[derive(Clone, Copy, Debug)]
pub(crate) enum FRef {
    Slab(usize),
    Ext(usize),
}

/// External input of a fused group.
#[derive(Clone, Copy, Debug)]
pub(crate) struct ExtIn {
    pub(crate) src: ValSrc,
    /// numel == 1: read once and splat.
    pub(crate) scalar: bool,
}

/// One fused member's operation over a block.
#[derive(Clone, Copy, Debug)]
pub(crate) enum FOp {
    Bin(BinOp, FRef, FRef),
    Un(UnOp, FRef),
    Cmp(Cmp, SDt, FRef, FRef),
    Sel(FRef, FRef, FRef),
    Clamp(FRef, FRef, FRef),
    Cvt(Dt, FRef),
    Splat(FRef),
}

#[derive(Clone, Debug)]
pub(crate) struct FMember {
    pub(crate) op: FOp,
    pub(crate) sdt: SDt,
}

/// A fused elementwise group: executed as one blocked loop at the
/// program position of its root (the single member with external
/// consumers).
#[derive(Clone, Debug)]
pub(crate) struct Group {
    pub(crate) root: usize,
    pub(crate) numel: usize,
    /// Member instruction indices, ascending (operands precede
    /// consumers); the root is the last member. `members[k]` is the
    /// compiled form of instruction `slots[k]`.
    pub(crate) slots: Vec<usize>,
    pub(crate) members: Vec<FMember>,
    pub(crate) ext: Vec<ExtIn>,
}

/// One executable step of a computation's program.
#[derive(Clone, Copy, Debug)]
pub(crate) enum Step {
    /// Run instruction `i` into its planned buffer (or run its `while`).
    Prim(usize),
    /// Run fused group `g`.
    Fused(usize),
}

/// Compiled program of one computation.
pub(crate) struct CompPlan {
    pub(crate) steps: Vec<Step>,
    pub(crate) src: Vec<ValSrc>,
    pub(crate) consts: Vec<Literal>,
    pub(crate) groups: Vec<Group>,
    pub(crate) buf_dt: Vec<Dt>,
    pub(crate) buf_cap: Vec<usize>,
    pub(crate) n_lits: usize,
    pub(crate) n_params: usize,
    pub(crate) root: usize,
    pub(crate) max_members: usize,
}

// --------------------------------------------------------- runtime state

/// Typed pooled storage for one planned buffer.
enum Buf {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U32(Vec<u32>),
    Pred(Vec<bool>),
}

impl Default for Buf {
    fn default() -> Self {
        Buf::F32(Vec::new())
    }
}

impl Buf {
    fn with_capacity(dt: Dt, cap: usize) -> Buf {
        match dt {
            Dt::F32 => Buf::F32(Vec::with_capacity(cap)),
            Dt::S32 => Buf::I32(Vec::with_capacity(cap)),
            Dt::U32 => Buf::U32(Vec::with_capacity(cap)),
            Dt::Pred => Buf::Pred(Vec::with_capacity(cap)),
        }
    }

    fn f32_mut(&mut self) -> Result<&mut Vec<f32>, XlaError> {
        match self {
            Buf::F32(v) => Ok(v),
            _ => Err(err("internal: buffer dtype mismatch (f32)")),
        }
    }

    fn i32_mut(&mut self) -> Result<&mut Vec<i32>, XlaError> {
        match self {
            Buf::I32(v) => Ok(v),
            _ => Err(err("internal: buffer dtype mismatch (i32)")),
        }
    }

    fn u32_mut(&mut self) -> Result<&mut Vec<u32>, XlaError> {
        match self {
            Buf::U32(v) => Ok(v),
            _ => Err(err("internal: buffer dtype mismatch (u32)")),
        }
    }

    fn pred_mut(&mut self) -> Result<&mut Vec<bool>, XlaError> {
        match self {
            Buf::Pred(v) => Ok(v),
            _ => Err(err("internal: buffer dtype mismatch (pred)")),
        }
    }

    fn view(&self) -> Ref<'_> {
        match self {
            Buf::F32(v) => Ref::F32(v),
            Buf::I32(v) => Ref::I32(v),
            Buf::U32(v) => Ref::U32(v),
            Buf::Pred(v) => Ref::Pred(v),
        }
    }
}

/// Cached per-computation run state: the pooled buffers plus the fused
/// slabs, reused across `execute` calls.
struct CompState {
    bufs: Vec<Buf>,
    fslab: Vec<f32>,
    uslab: Vec<u32>,
    pslab: Vec<bool>,
}

impl CompState {
    fn new(cp: &CompPlan) -> CompState {
        CompState {
            bufs: cp
                .buf_dt
                .iter()
                .zip(&cp.buf_cap)
                .map(|(&dt, &cap)| Buf::with_capacity(dt, cap))
                .collect(),
            fslab: vec![0.0; cp.max_members * BLOCK],
            uslab: vec![0; cp.max_members * BLOCK],
            pslab: vec![false; cp.max_members * BLOCK],
        }
    }
}

/// Borrowed typed view of a resolved value.
#[derive(Clone, Copy)]
enum Ref<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
    U32(&'a [u32]),
    Pred(&'a [bool]),
}

impl<'a> Ref<'a> {
    fn f32(self) -> Result<&'a [f32], XlaError> {
        match self {
            Ref::F32(s) => Ok(s),
            _ => Err(err("expected f32 operand")),
        }
    }

    fn pred(self) -> Result<&'a [bool], XlaError> {
        match self {
            Ref::Pred(s) => Ok(s),
            _ => Err(err("expected pred operand")),
        }
    }
}

fn data_ref(d: &Data) -> Result<Ref<'_>, XlaError> {
    match d {
        Data::F32(v) => Ok(Ref::F32(v)),
        Data::I32(v) => Ok(Ref::I32(v)),
        Data::U32(v) => Ok(Ref::U32(v)),
        Data::Pred(v) => Ok(Ref::Pred(v)),
        Data::Tuple(_) => Err(err("expected array value, got tuple")),
    }
}

fn resolve_src<'a>(
    cp: &'a CompPlan,
    st: &'a CompState,
    lits: &'a [Option<Literal>],
    args: &[&'a Literal],
    src: ValSrc,
) -> Result<Ref<'a>, XlaError> {
    match src {
        ValSrc::Buf(b) => Ok(st.bufs[b].view()),
        ValSrc::Const(c) => data_ref(&cp.consts[c].data),
        ValSrc::Param(k) => data_ref(&args[k].data),
        ValSrc::ParamPart(k, j) => match &args[k].data {
            Data::Tuple(parts) => data_ref(&parts[j].data),
            _ => Err(err("internal: tuple argument expected")),
        },
        ValSrc::Lit(li) => match &lits[li] {
            Some(l) => data_ref(&l.data),
            None => Err(err("internal: while result not yet computed")),
        },
        ValSrc::LitPart(li, j) => match &lits[li] {
            Some(l) => match &l.data {
                Data::Tuple(parts) => data_ref(&parts[j].data),
                _ => Err(err("internal: tuple while result expected")),
            },
            None => Err(err("internal: while result not yet computed")),
        },
        ValSrc::Tuple => Err(err("internal: tuple value read as array")),
        ValSrc::Dead => Err(err("internal: dead slot read")),
    }
}

fn resolve<'a>(
    cp: &'a CompPlan,
    st: &'a CompState,
    lits: &'a [Option<Literal>],
    args: &[&'a Literal],
    slot: usize,
) -> Result<Ref<'a>, XlaError> {
    resolve_src(cp, st, lits, args, cp.src[slot])
}

// ------------------------------------------------------------- the plan

/// A compiled, reusable execution plan for an [`HloModule`].
///
/// Build once with [`Plan::new`] (the `compile` step of the
/// `runtime::xla` backend), then call [`Plan::execute`] per step — the
/// instruction program, fusion groups and buffer assignment are
/// computed once, and the output buffers persist across calls.
///
/// Not `Sync`: a `Plan` is confined to one thread (the `dot` kernel
/// spawns scoped workers internally).
pub struct Plan {
    module: Rc<HloModule>,
    comps: Vec<CompPlan>,
    states: Vec<RefCell<CompState>>,
    threads: Cell<usize>,
}

impl Plan {
    /// Compile a parsed module into a plan. Shape or dtype
    /// inconsistencies that the reference walker would only hit at run
    /// time surface here, at compile time.
    pub fn new(module: Rc<HloModule>) -> Result<Plan, XlaError> {
        let mut comps = Vec::with_capacity(module.computations.len());
        for ci in 0..module.computations.len() {
            comps.push(
                plan_comp(&module, ci)
                    .map_err(|e| err(format!("{}: {e:?}", module.computations[ci].name)))?,
            );
        }
        let states = comps.iter().map(|cp| RefCell::new(CompState::new(cp))).collect();
        let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        Ok(Plan {
            module,
            comps,
            states,
            threads: Cell::new(threads),
        })
    }

    /// Override the `dot` worker-thread budget (default: the machine's
    /// available parallelism). `1` forces the serial path; results are
    /// bit-identical for every setting.
    pub fn set_threads(&self, n: usize) {
        self.threads.set(n.max(1));
    }

    /// Read-only view of the compiled plan for the static verifier
    /// (`runtime::verify`): the module plus every per-computation
    /// program. Deliberately the *only* non-test window into plan
    /// internals — the planner's derivation helpers stay private so the
    /// verifier cannot accidentally share them.
    pub(crate) fn inspect(&self) -> PlanInspect<'_> {
        PlanInspect { module: &self.module, comps: &self.comps }
    }

    /// Buffer-assignment summary across every computation: the number
    /// of planned output buffers and the number of instruction value
    /// slots that resolved to a buffer (the reuse the planner bought).
    /// Feeds the `plan_buffers_total` / `plan_buffer_slots_total`
    /// metrics at compile time.
    pub fn buffer_stats(&self) -> (usize, usize) {
        let bufs = self.comps.iter().map(|cp| cp.buf_dt.len()).sum();
        let slots = self
            .comps
            .iter()
            .map(|cp| {
                cp.src
                    .iter()
                    .filter(|s| matches!(s, ValSrc::Buf(_)))
                    .count()
            })
            .sum();
        (bufs, slots)
    }

    /// Validate `args` against the entry parameters and run the planned
    /// program. Bit-identical to [`interp::execute_ref`] on the same
    /// module and arguments.
    pub fn execute(&self, args: Vec<Literal>) -> Result<Literal, XlaError> {
        let entry = self.module.entry;
        validate_args(&self.module.computations[entry], &args)?;
        let refs: Vec<&Literal> = args.iter().collect();
        self.run(entry, &refs)
    }

    /// Run computation `ci` with borrowed arguments.
    fn run(&self, ci: usize, args: &[&Literal]) -> Result<Literal, XlaError> {
        let cp = &self.comps[ci];
        let comp = &self.module.computations[ci];
        if args.len() != cp.n_params {
            return Err(err(format!(
                "{}: expected {} arguments, got {}",
                comp.name,
                cp.n_params,
                args.len()
            )));
        }
        let mut st = self.states[ci]
            .try_borrow_mut()
            .map_err(|_| err(format!("internal: computation {} re-entered", comp.name)))?;
        let mut lits: Vec<Option<Literal>> = (0..cp.n_lits).map(|_| None).collect();
        for step in &cp.steps {
            match *step {
                Step::Prim(i) => self.exec_prim(ci, cp, comp, &mut st, &mut lits, args, i)?,
                Step::Fused(g) => exec_fused(cp, &mut st, &lits, args, &cp.groups[g])?,
            }
        }
        materialize(cp, comp, &st, &lits, args, cp.root)
    }

    #[allow(clippy::too_many_arguments)]
    fn exec_prim(
        &self,
        ci: usize,
        cp: &CompPlan,
        comp: &Computation,
        st: &mut CompState,
        lits: &mut [Option<Literal>],
        args: &[&Literal],
        i: usize,
    ) -> Result<(), XlaError> {
        if let Op::While { cond, body } = &comp.instrs[i].op {
            let li = match cp.src[i] {
                ValSrc::Lit(li) => li,
                _ => return Err(err("internal: while step without literal slot")),
            };
            let mut state = materialize(cp, comp, st, lits, args, comp.instrs[i].operands[0])?;
            let mut fuel = 100_000_000u64;
            loop {
                let c = self.run(*cond, &[&state])?;
                let go = match &c.data {
                    Data::Pred(v) => v.first().copied().unwrap_or(false),
                    _ => return Err(err("while: condition must return pred")),
                };
                if !go {
                    break;
                }
                state = self.run(*body, &[&state])?;
                fuel = fuel
                    .checked_sub(1)
                    .ok_or_else(|| err("while: iteration limit exceeded"))?;
            }
            lits[li] = Some(state);
            return Ok(());
        }
        let b = match cp.src[i] {
            ValSrc::Buf(b) => b,
            _ => return Err(err("internal: prim step without buffer")),
        };
        let mut out = std::mem::take(&mut st.bufs[b]);
        let r = self.prim_into(ci, cp, comp, st, lits, args, i, &mut out);
        st.bufs[b] = out;
        r
    }

    /// Execute one primitive instruction into `out`. `st` is only read
    /// here — `out` is the (taken) output buffer.
    #[allow(clippy::too_many_arguments)]
    fn prim_into(
        &self,
        _ci: usize,
        cp: &CompPlan,
        comp: &Computation,
        st: &CompState,
        lits: &[Option<Literal>],
        args: &[&Literal],
        i: usize,
        out: &mut Buf,
    ) -> Result<(), XlaError> {
        let instr = &comp.instrs[i];
        let ops = &instr.operands;
        let sh = |o: usize| -> &Shape { &comp.instrs[o].shape };
        let val = |o: usize| resolve(cp, st, lits, args, o);
        match &instr.op {
            Op::Bin(bop) => {
                let (a, b) = (val(ops[0])?, val(ops[1])?);
                match (a, b) {
                    (Ref::F32(x), Ref::F32(y)) => {
                        let o = out.f32_mut()?;
                        o.clear();
                        o.extend(x.iter().zip(y).map(|(&p, &q)| bin_f32_s(*bop, p, q)));
                    }
                    (Ref::U32(x), Ref::U32(y)) => {
                        let o = out.u32_mut()?;
                        o.clear();
                        o.extend(x.iter().zip(y).map(|(&p, &q)| bin_u32_s(*bop, p, q)));
                    }
                    (Ref::I32(x), Ref::I32(y)) => {
                        let o = out.i32_mut()?;
                        o.clear();
                        o.extend(x.iter().zip(y).map(|(&p, &q)| bin_i32_s(*bop, p, q)));
                    }
                    (Ref::Pred(x), Ref::Pred(y)) => {
                        let o = out.pred_mut()?;
                        o.clear();
                        o.extend(x.iter().zip(y).map(|(&p, &q)| bin_pred_s(*bop, p, q)));
                    }
                    _ => return Err(err("binary op element type mismatch")),
                }
            }
            Op::Un(uop) => match val(ops[0])? {
                Ref::F32(x) => {
                    let o = out.f32_mut()?;
                    o.clear();
                    o.extend(x.iter().map(|&v| un_f32_s(*uop, v)));
                }
                Ref::Pred(x) => {
                    let o = out.pred_mut()?;
                    o.clear();
                    o.extend(x.iter().map(|&b| !b));
                }
                Ref::U32(x) => {
                    let o = out.u32_mut()?;
                    o.clear();
                    o.extend(x.iter().map(|&v| !v));
                }
                Ref::I32(x) => {
                    let o = out.i32_mut()?;
                    o.clear();
                    match uop {
                        UnOp::Neg => o.extend(x.iter().map(|&v| v.wrapping_neg())),
                        UnOp::Abs => o.extend(x.iter().map(|&v| v.wrapping_abs())),
                        _ => return Err(err("unsupported unary op on s32")),
                    }
                }
            },
            Op::Compare(dir) => {
                let (a, b) = (val(ops[0])?, val(ops[1])?);
                let o = out.pred_mut()?;
                o.clear();
                match (a, b) {
                    (Ref::F32(x), Ref::F32(y)) => {
                        o.extend(x.iter().zip(y).map(|(p, q)| cmp_s(*dir, p, q)));
                    }
                    (Ref::I32(x), Ref::I32(y)) => {
                        o.extend(x.iter().zip(y).map(|(p, q)| cmp_s(*dir, p, q)));
                    }
                    (Ref::U32(x), Ref::U32(y)) => {
                        o.extend(x.iter().zip(y).map(|(p, q)| cmp_s(*dir, p, q)));
                    }
                    _ => return Err(err("compare element type mismatch")),
                }
            }
            Op::Select => {
                let p = val(ops[0])?.pred()?;
                let (t, f) = (val(ops[1])?, val(ops[2])?);
                let pick = |j: usize| if p.len() == 1 { p[0] } else { p[j] };
                match (t, f) {
                    (Ref::F32(a), Ref::F32(b)) => {
                        let o = out.f32_mut()?;
                        o.clear();
                        o.extend((0..a.len()).map(|j| if pick(j) { a[j] } else { b[j] }));
                    }
                    (Ref::U32(a), Ref::U32(b)) => {
                        let o = out.u32_mut()?;
                        o.clear();
                        o.extend((0..a.len()).map(|j| if pick(j) { a[j] } else { b[j] }));
                    }
                    _ => return Err(err("select: unsupported element types")),
                }
            }
            Op::Clamp => {
                let lo = val(ops[0])?.f32()?;
                let x = val(ops[1])?.f32()?;
                let hi = val(ops[2])?.f32()?;
                let o = out.f32_mut()?;
                o.clear();
                o.extend((0..x.len()).map(|j| {
                    let l = if lo.len() == 1 { lo[0] } else { lo[j] };
                    let h = if hi.len() == 1 { hi[0] } else { hi[j] };
                    x[j].clamp(l, h)
                }));
            }
            Op::Convert => {
                let a = val(ops[0])?;
                macro_rules! cvt {
                    ($dst:expr, $map:expr) => {{
                        let d = $dst;
                        d.clear();
                        match a {
                            Ref::F32(s) => d.extend(s.iter().map(|&v| $map(v))),
                            Ref::I32(s) => d.extend(s.iter().map(|&v| $map(v as f32))),
                            Ref::U32(s) => d.extend(s.iter().map(|&v| $map(v as f32))),
                            Ref::Pred(s) => {
                                d.extend(s.iter().map(|&b| $map(if b { 1.0f32 } else { 0.0 })))
                            }
                        }
                    }};
                }
                match instr.shape.dt()? {
                    Dt::F32 => cvt!(out.f32_mut()?, |v: f32| v),
                    Dt::S32 => cvt!(out.i32_mut()?, f32_to_i32_xla),
                    Dt::U32 => cvt!(out.u32_mut()?, f32_to_u32_xla),
                    Dt::Pred => cvt!(out.pred_mut()?, |v: f32| v != 0.0),
                }
            }
            Op::Broadcast { dims } => {
                let sdims = sh(ops[0]).dims()?;
                let out_dims = instr.shape.dims()?;
                let sstr = strides_of(sdims);
                let mut steps = [0usize; MAX_RANK];
                for (pos, &od) in dims.iter().enumerate() {
                    steps[od] = sstr[pos];
                }
                gather_any(val(ops[0])?, out, out_dims, 0, &steps[..out_dims.len()])?;
            }
            Op::Transpose { perm } => {
                let sdims = sh(ops[0]).dims()?;
                let sstr = strides_of(sdims);
                let out_dims = instr.shape.dims()?;
                let mut steps = [0usize; MAX_RANK];
                for (d, &p) in perm.iter().enumerate() {
                    steps[d] = sstr[p];
                }
                gather_any(val(ops[0])?, out, out_dims, 0, &steps[..out_dims.len()])?;
            }
            Op::Slice { starts, strides, .. } => {
                let sdims = sh(ops[0]).dims()?;
                let sstr = strides_of(sdims);
                let out_dims = instr.shape.dims()?;
                let mut base = 0usize;
                let mut steps = [0usize; MAX_RANK];
                for (d, &ss) in sstr.iter().enumerate() {
                    base += starts[d] * ss;
                    steps[d] = strides[d] * ss;
                }
                gather_any(val(ops[0])?, out, out_dims, base, &steps[..out_dims.len()])?;
            }
            Op::Concat { dim } => {
                let parts: Vec<Ref> = ops.iter().map(|&o| val(o)).collect::<Result<_, _>>()?;
                let inners: Vec<usize> = ops
                    .iter()
                    .map(|&o| Ok(sh(o).dims()?[*dim..].iter().product()))
                    .collect::<Result<_, XlaError>>()?;
                let outer: usize = sh(ops[0]).dims()?[..*dim].iter().product();
                macro_rules! cc {
                    ($arm:ident, $get:expr) => {{
                        let slices: Vec<_> = parts
                            .iter()
                            .map($get)
                            .collect::<Result<Vec<_>, XlaError>>()?;
                        let o = $arm;
                        o.clear();
                        for ou in 0..outer {
                            for (s, &inner) in slices.iter().zip(&inners) {
                                o.extend_from_slice(&s[ou * inner..(ou + 1) * inner]);
                            }
                        }
                    }};
                }
                match parts[0] {
                    Ref::F32(_) => cc!(out.f32_mut()?, |r| match r {
                        Ref::F32(s) => Ok(*s),
                        _ => Err(err("concatenate element type mismatch")),
                    }),
                    Ref::I32(_) => cc!(out.i32_mut()?, |r| match r {
                        Ref::I32(s) => Ok(*s),
                        _ => Err(err("concatenate element type mismatch")),
                    }),
                    Ref::U32(_) => cc!(out.u32_mut()?, |r| match r {
                        Ref::U32(s) => Ok(*s),
                        _ => Err(err("concatenate element type mismatch")),
                    }),
                    Ref::Pred(_) => cc!(out.pred_mut()?, |r| match r {
                        Ref::Pred(s) => Ok(*s),
                        _ => Err(err("concatenate element type mismatch")),
                    }),
                }
            }
            Op::Pad { low, interior, .. } => {
                let sdims = sh(ops[0]).dims()?;
                let out_dims = instr.shape.dims()?;
                match (val(ops[0])?, val(ops[1])?) {
                    (Ref::F32(s), Ref::F32(p)) => {
                        pad_into(s, p[0], sdims, out_dims, low, interior, out.f32_mut()?)?;
                    }
                    (Ref::I32(s), Ref::I32(p)) => {
                        pad_into(s, p[0], sdims, out_dims, low, interior, out.i32_mut()?)?;
                    }
                    (Ref::U32(s), Ref::U32(p)) => {
                        pad_into(s, p[0], sdims, out_dims, low, interior, out.u32_mut()?)?;
                    }
                    _ => return Err(err("pad element type mismatch")),
                }
            }
            Op::Dot { lc, rc } => {
                let d = dot_dims(sh(ops[0]).dims()?, sh(ops[1]).dims()?, *lc, *rc)?;
                let lv = val(ops[0])?.f32()?;
                let rv = val(ops[1])?.f32()?;
                let o = out.f32_mut()?;
                o.clear();
                o.resize(d.m * d.n, 0.0);
                let work = d.m * d.k * d.n;
                let w = if work >= DOT_PAR_MIN_FLOPS && d.n > 0 {
                    self.threads
                        .get()
                        .min(DOT_MAX_WORKERS)
                        .min(d.m)
                        .min((work / DOT_PAR_MIN_FLOPS).max(1))
                } else {
                    1
                };
                if w <= 1 {
                    dot_rows(lv, rv, &d, 0, o);
                } else {
                    let rows_per = d.m.div_ceil(w);
                    let chunk = rows_per * d.n;
                    std::thread::scope(|s| {
                        for (c, och) in o.chunks_mut(chunk).enumerate() {
                            let dd = d;
                            s.spawn(move || dot_rows(lv, rv, &dd, c * rows_per, och));
                        }
                    });
                }
            }
            Op::Reduce { dims, comp: rcomp } => {
                let a = val(ops[0])?.f32()?;
                let iv = val(ops[1])?.f32()?;
                let sdims = sh(ops[0]).dims()?;
                let monoid = reduce_monoid(&self.module.computations[*rcomp]);
                let o = out.f32_mut()?;
                reduce_f32(a, iv[0], sdims, dims, monoid, o, |acc, x| {
                    let r = self.run(*rcomp, &[&scalar_literal_f32(acc), &scalar_literal_f32(x)])?;
                    Ok(interp::f32s(&r)?[0])
                })?;
            }
            Op::Iota { .. }
            | Op::Parameter(_)
            | Op::Constant(_)
            | Op::Reshape
            | Op::Gte { .. }
            | Op::Tuple
            | Op::While { .. } => {
                return Err(err("internal: non-primitive op reached prim_into"));
            }
        }
        Ok(())
    }
}

/// Borrowed, read-only introspection surface over a compiled [`Plan`]:
/// everything the static verifier may look at.
pub(crate) struct PlanInspect<'p> {
    /// The parsed module the plan was compiled from.
    pub(crate) module: &'p HloModule,
    /// One compiled program per computation, in `module.computations`
    /// order.
    pub(crate) comps: &'p [CompPlan],
}

/// Test-only mutation hooks: the negative tests in `runtime::verify`
/// corrupt real compiled plans through these and assert that the
/// matching diagnostic fires.
#[cfg(test)]
impl Plan {
    pub(crate) fn comps_mut(&mut self) -> &mut Vec<CompPlan> {
        &mut self.comps
    }

    pub(crate) fn module_mut(&mut self) -> &mut HloModule {
        Rc::make_mut(&mut self.module)
    }
}

/// Materialize a slot into an owned [`Literal`] (the root of a run and
/// `while` loop states).
fn materialize(
    cp: &CompPlan,
    comp: &Computation,
    st: &CompState,
    lits: &[Option<Literal>],
    args: &[&Literal],
    slot: usize,
) -> Result<Literal, XlaError> {
    match cp.src[slot] {
        ValSrc::Tuple => {
            let parts: Vec<Literal> = comp.instrs[slot]
                .operands
                .iter()
                .map(|&o| materialize(cp, comp, st, lits, args, o))
                .collect::<Result<_, _>>()?;
            Ok(Literal {
                dims: vec![parts.len() as i64],
                data: Data::Tuple(parts),
            })
        }
        ValSrc::Lit(li) => lits[li]
            .clone()
            .ok_or_else(|| err("internal: while result not yet computed")),
        ValSrc::Param(k) if matches!(args[k].data, Data::Tuple(_)) => Ok((*args[k]).clone()),
        ValSrc::Dead => Err(err("internal: dead slot materialized")),
        src => {
            let dims: Vec<i64> = comp.instrs[slot]
                .shape
                .dims()?
                .iter()
                .map(|&d| d as i64)
                .collect();
            let data = match resolve_src(cp, st, lits, args, src)? {
                Ref::F32(s) => Data::F32(s.to_vec()),
                Ref::I32(s) => Data::I32(s.to_vec()),
                Ref::U32(s) => Data::U32(s.to_vec()),
                Ref::Pred(s) => Data::Pred(s.to_vec()),
            };
            Ok(Literal { data, dims })
        }
    }
}

// ------------------------------------------------------ gather / pad kernels

/// Row-major strided gather: `out[idx] = src[base + sum(idx[d] *
/// steps[d])]` over `out_dims`, with contiguous (`step == 1`) and
/// splat (`step == 0`) fast paths on the innermost dim. Pure data
/// movement — bit-identical to the reference odometer by construction.
fn gather<T: Copy>(
    src: &[T],
    out: &mut Vec<T>,
    out_dims: &[usize],
    base: usize,
    steps: &[usize],
) -> Result<(), XlaError> {
    if out_dims.len() > MAX_RANK {
        return Err(err("gather: rank too large"));
    }
    let n: usize = out_dims.iter().product();
    out.clear();
    out.reserve(n);
    if n == 0 {
        return Ok(());
    }
    if out_dims.is_empty() {
        out.push(src[base]);
        return Ok(());
    }
    let last = out_dims.len() - 1;
    let ld = out_dims[last];
    let ls = steps[last];
    let outer: usize = out_dims[..last].iter().product();
    let mut idx = [0usize; MAX_RANK];
    for _ in 0..outer {
        let mut off = base;
        for d in 0..last {
            off += idx[d] * steps[d];
        }
        if ls == 1 {
            out.extend_from_slice(&src[off..off + ld]);
        } else if ls == 0 {
            let v = src[off];
            out.extend(std::iter::repeat_n(v, ld));
        } else {
            let mut o = off;
            for _ in 0..ld {
                out.push(src[o]);
                o += ls;
            }
        }
        odo_next(&mut idx[..last], &out_dims[..last]);
    }
    Ok(())
}

fn gather_any(
    src: Ref<'_>,
    out: &mut Buf,
    out_dims: &[usize],
    base: usize,
    steps: &[usize],
) -> Result<(), XlaError> {
    match src {
        Ref::F32(s) => gather(s, out.f32_mut()?, out_dims, base, steps),
        Ref::I32(s) => gather(s, out.i32_mut()?, out_dims, base, steps),
        Ref::U32(s) => gather(s, out.u32_mut()?, out_dims, base, steps),
        Ref::Pred(s) => gather(s, out.pred_mut()?, out_dims, base, steps),
    }
}

/// Scatter `src` into a pad-value-filled output, mapping source index
/// `idx[d]` to output coordinate `low[d] + idx[d] * (interior[d] + 1)`
/// and skipping out-of-bounds coordinates — the same mapping as the
/// reference `eval_pad`, with a contiguous row fast path.
fn pad_into<T: Copy>(
    src: &[T],
    padv: T,
    sdims: &[usize],
    out_dims: &[usize],
    low: &[i64],
    interior: &[usize],
    out: &mut Vec<T>,
) -> Result<(), XlaError> {
    if sdims.len() > MAX_RANK {
        return Err(err("pad: rank too large"));
    }
    let n: usize = out_dims.iter().product();
    out.clear();
    out.resize(n, padv);
    if src.is_empty() {
        return Ok(());
    }
    if sdims.is_empty() {
        out[0] = src[0];
        return Ok(());
    }
    let ostr = strides_of(out_dims);
    let last = sdims.len() - 1;
    let sd_last = sdims[last];
    let il = interior[last];
    let outer: usize = sdims[..last].iter().product();
    let row_contig = il == 0
        && low[last] >= 0
        && sd_last > 0
        && low[last] as usize + sd_last <= out_dims[last];
    let mut idx = [0usize; MAX_RANK];
    for row in 0..outer {
        let mut off: i64 = 0;
        let mut ok = true;
        for d in 0..last {
            let o = low[d] + (idx[d] * (interior[d] + 1)) as i64;
            if o < 0 || o as usize >= out_dims[d] {
                ok = false;
                break;
            }
            off += o * ostr[d] as i64;
        }
        if ok {
            let srow = &src[row * sd_last..(row + 1) * sd_last];
            if row_contig {
                let s = off as usize + low[last] as usize;
                out[s..s + sd_last].copy_from_slice(srow);
            } else {
                for (j, &v) in srow.iter().enumerate() {
                    let o = low[last] + (j * (il + 1)) as i64;
                    if o >= 0 && (o as usize) < out_dims[last] {
                        out[(off + o) as usize] = v;
                    }
                }
            }
        }
        odo_next(&mut idx[..last], &sdims[..last]);
    }
    Ok(())
}

// ------------------------------------------------------------ fused loops

/// Per-block accessor for one fused f32 input.
#[derive(Clone, Copy)]
enum In<'a, T: Copy> {
    S(&'a [T]),
    K(T),
}

impl<'a, T: Copy> In<'a, T> {
    #[inline]
    fn at(self, t: usize) -> T {
        match self {
            In::S(s) => s[t],
            In::K(v) => v,
        }
    }
}

struct FusedCtx<'a> {
    exts: &'a [Ref<'a>],
    ext_meta: &'a [ExtIn],
    start: usize,
    len: usize,
}

impl<'a> FusedCtx<'a> {
    fn in_f32<'b>(&'b self, pre: &'b [f32], r: FRef) -> Result<In<'b, f32>, XlaError>
    where
        'a: 'b,
    {
        match r {
            FRef::Slab(j) => Ok(In::S(&pre[j * BLOCK..j * BLOCK + self.len])),
            FRef::Ext(e) => match (self.exts[e], self.ext_meta[e].scalar) {
                (Ref::F32(s), true) => Ok(In::K(s[0])),
                (Ref::F32(s), false) => Ok(In::S(&s[self.start..self.start + self.len])),
                _ => Err(err("internal: fused f32 input type mismatch")),
            },
        }
    }

    fn in_u32<'b>(&'b self, pre: &'b [u32], r: FRef) -> Result<In<'b, u32>, XlaError>
    where
        'a: 'b,
    {
        match r {
            FRef::Slab(j) => Ok(In::S(&pre[j * BLOCK..j * BLOCK + self.len])),
            FRef::Ext(e) => match (self.exts[e], self.ext_meta[e].scalar) {
                (Ref::U32(s), true) => Ok(In::K(s[0])),
                (Ref::U32(s), false) => Ok(In::S(&s[self.start..self.start + self.len])),
                _ => Err(err("internal: fused u32 input type mismatch")),
            },
        }
    }

    fn in_i32<'b>(&'b self, r: FRef) -> Result<In<'b, i32>, XlaError>
    where
        'a: 'b,
    {
        match r {
            FRef::Slab(_) => Err(err("internal: fused i32 slab input")),
            FRef::Ext(e) => match (self.exts[e], self.ext_meta[e].scalar) {
                (Ref::I32(s), true) => Ok(In::K(s[0])),
                (Ref::I32(s), false) => Ok(In::S(&s[self.start..self.start + self.len])),
                _ => Err(err("internal: fused i32 input type mismatch")),
            },
        }
    }

    fn in_pred<'b>(&'b self, pre: &'b [bool], r: FRef) -> Result<In<'b, bool>, XlaError>
    where
        'a: 'b,
    {
        match r {
            FRef::Slab(j) => Ok(In::S(&pre[j * BLOCK..j * BLOCK + self.len])),
            FRef::Ext(e) => match (self.exts[e], self.ext_meta[e].scalar) {
                (Ref::Pred(s), true) => Ok(In::K(s[0])),
                (Ref::Pred(s), false) => Ok(In::S(&s[self.start..self.start + self.len])),
                _ => Err(err("internal: fused pred input type mismatch")),
            },
        }
    }
}

fn exec_fused(
    cp: &CompPlan,
    st: &mut CompState,
    lits: &[Option<Literal>],
    args: &[&Literal],
    g: &Group,
) -> Result<(), XlaError> {
    let b = match cp.src[g.root] {
        ValSrc::Buf(b) => b,
        _ => return Err(err("internal: fused root without buffer")),
    };
    let mut out = std::mem::take(&mut st.bufs[b]);
    let mut fsl = std::mem::take(&mut st.fslab);
    let mut usl = std::mem::take(&mut st.uslab);
    let mut psl = std::mem::take(&mut st.pslab);
    let r = fused_body(cp, st, lits, args, g, &mut out, &mut fsl, &mut usl, &mut psl);
    st.fslab = fsl;
    st.uslab = usl;
    st.pslab = psl;
    st.bufs[b] = out;
    r
}

#[allow(clippy::too_many_arguments)]
fn fused_body(
    cp: &CompPlan,
    st: &CompState,
    lits: &[Option<Literal>],
    args: &[&Literal],
    g: &Group,
    out: &mut Buf,
    fsl: &mut [f32],
    usl: &mut [u32],
    psl: &mut [bool],
) -> Result<(), XlaError> {
    let n = g.numel;
    let nm = g.members.len();
    let exts: Vec<Ref> = g
        .ext
        .iter()
        .map(|e| resolve_src(cp, st, lits, args, e.src))
        .collect::<Result<_, _>>()?;
    let root_sdt = g.members[nm - 1].sdt;
    match root_sdt {
        SDt::F32 => {
            let o = out.f32_mut()?;
            o.clear();
            o.reserve(n);
        }
        SDt::U32 => {
            let o = out.u32_mut()?;
            o.clear();
            o.reserve(n);
        }
        SDt::Pred => {
            let o = out.pred_mut()?;
            o.clear();
            o.reserve(n);
        }
    }
    let mut start = 0usize;
    while start < n {
        let len = (n - start).min(BLOCK);
        let ctx = FusedCtx { exts: &exts, ext_meta: &g.ext, start, len };
        for (mi, m) in g.members.iter().enumerate() {
            match m.sdt {
                SDt::F32 => {
                    let (pre, cur) = fsl.split_at_mut(mi * BLOCK);
                    let dst = &mut cur[..len];
                    eval_member_f32(&ctx, m, dst, pre, usl, psl)?;
                }
                SDt::U32 => {
                    let (pre, cur) = usl.split_at_mut(mi * BLOCK);
                    let dst = &mut cur[..len];
                    eval_member_u32(&ctx, m, dst, pre, fsl, psl)?;
                }
                SDt::Pred => {
                    let (pre, cur) = psl.split_at_mut(mi * BLOCK);
                    let dst = &mut cur[..len];
                    eval_member_pred(&ctx, m, dst, pre, fsl, usl)?;
                }
            }
        }
        let rbase = (nm - 1) * BLOCK;
        match root_sdt {
            SDt::F32 => out.f32_mut()?.extend_from_slice(&fsl[rbase..rbase + len]),
            SDt::U32 => out.u32_mut()?.extend_from_slice(&usl[rbase..rbase + len]),
            SDt::Pred => out.pred_mut()?.extend_from_slice(&psl[rbase..rbase + len]),
        }
        start += len;
    }
    Ok(())
}

/// Evaluate one f32-valued member over a block. `pre` holds the f32
/// slabs of earlier members (fused operands always precede their
/// consumers), `usl`/`psl` are the full u32/pred slabs for cross-type
/// inputs (convert, select).
fn eval_member_f32(
    ctx: &FusedCtx<'_>,
    m: &FMember,
    dst: &mut [f32],
    pre: &[f32],
    usl: &[u32],
    psl: &[bool],
) -> Result<(), XlaError> {
    let len = ctx.len;
    match m.op {
        FOp::Bin(bop, a, b) => {
            let av = ctx.in_f32(pre, a)?;
            let bv = ctx.in_f32(pre, b)?;
            macro_rules! arm {
                ($($v:ident),*) => {
                    match bop {
                        $(BinOp::$v => {
                            for t in 0..len {
                                dst[t] = bin_f32_s(BinOp::$v, av.at(t), bv.at(t));
                            }
                        })*
                        _ => return Err(err("internal: fused f32 bin op")),
                    }
                };
            }
            arm!(Add, Sub, Mul, Div, Max, Min, Pow);
        }
        FOp::Un(uop, a) => {
            let av = ctx.in_f32(pre, a)?;
            macro_rules! arm {
                ($($v:ident),*) => {
                    match uop {
                        $(UnOp::$v => {
                            for t in 0..len {
                                dst[t] = un_f32_s(UnOp::$v, av.at(t));
                            }
                        })*
                        UnOp::Not => return Err(err("internal: fused not on f32")),
                    }
                };
            }
            arm!(
                Neg, Exp, Log, Sqrt, Rsqrt, Abs, Sign, Floor, Ceil, RoundTiesEven, Tanh,
                Logistic, Sin, Cos
            );
        }
        FOp::Sel(p, a, b) => {
            let pv = ctx.in_pred(psl, p)?;
            let av = ctx.in_f32(pre, a)?;
            let bv = ctx.in_f32(pre, b)?;
            for t in 0..len {
                dst[t] = if pv.at(t) { av.at(t) } else { bv.at(t) };
            }
        }
        FOp::Clamp(lo, x, hi) => {
            let lv = ctx.in_f32(pre, lo)?;
            let xv = ctx.in_f32(pre, x)?;
            let hv = ctx.in_f32(pre, hi)?;
            for t in 0..len {
                dst[t] = xv.at(t).clamp(lv.at(t), hv.at(t));
            }
        }
        FOp::Cvt(src_dt, a) => match src_dt {
            Dt::F32 => {
                let av = ctx.in_f32(pre, a)?;
                for t in 0..len {
                    dst[t] = av.at(t);
                }
            }
            Dt::I32 => {
                let av = ctx.in_i32(a)?;
                for t in 0..len {
                    dst[t] = av.at(t) as f32;
                }
            }
            Dt::U32 => {
                let av = ctx.in_u32(usl, a)?;
                for t in 0..len {
                    dst[t] = av.at(t) as f32;
                }
            }
            Dt::Pred => {
                let av = ctx.in_pred(psl, a)?;
                for t in 0..len {
                    dst[t] = if av.at(t) { 1.0 } else { 0.0 };
                }
            }
        },
        FOp::Splat(a) => {
            let v = ctx.in_f32(pre, a)?.at(0);
            dst.fill(v);
        }
        FOp::Cmp(..) => return Err(err("internal: compare is pred-valued")),
    }
    Ok(())
}

/// Evaluate one u32-valued member over a block (see
/// [`eval_member_f32`]).
fn eval_member_u32(
    ctx: &FusedCtx<'_>,
    m: &FMember,
    dst: &mut [u32],
    pre: &[u32],
    fsl: &[f32],
    psl: &[bool],
) -> Result<(), XlaError> {
    let len = ctx.len;
    match m.op {
        FOp::Bin(bop, a, b) => {
            let av = ctx.in_u32(pre, a)?;
            let bv = ctx.in_u32(pre, b)?;
            macro_rules! arm {
                ($($v:ident),*) => {
                    match bop {
                        $(BinOp::$v => {
                            for t in 0..len {
                                dst[t] = bin_u32_s(BinOp::$v, av.at(t), bv.at(t));
                            }
                        })*
                        BinOp::Pow => return Err(err("internal: fused pow on u32")),
                    }
                };
            }
            arm!(Add, Sub, Mul, Div, Max, Min, And, Or, Xor, Shl, Shr);
        }
        FOp::Un(uop, a) => {
            if uop != UnOp::Not {
                return Err(err("internal: fused unary on u32"));
            }
            let av = ctx.in_u32(pre, a)?;
            for t in 0..len {
                dst[t] = !av.at(t);
            }
        }
        FOp::Sel(p, a, b) => {
            let pv = ctx.in_pred(psl, p)?;
            let av = ctx.in_u32(pre, a)?;
            let bv = ctx.in_u32(pre, b)?;
            for t in 0..len {
                dst[t] = if pv.at(t) { av.at(t) } else { bv.at(t) };
            }
        }
        FOp::Cvt(src_dt, a) => match src_dt {
            Dt::F32 => {
                let av = ctx.in_f32(fsl, a)?;
                for t in 0..len {
                    dst[t] = f32_to_u32_xla(av.at(t));
                }
            }
            Dt::I32 => {
                let av = ctx.in_i32(a)?;
                for t in 0..len {
                    dst[t] = f32_to_u32_xla(av.at(t) as f32);
                }
            }
            Dt::U32 => {
                let av = ctx.in_u32(pre, a)?;
                for t in 0..len {
                    dst[t] = f32_to_u32_xla(av.at(t) as f32);
                }
            }
            Dt::Pred => {
                let av = ctx.in_pred(psl, a)?;
                for t in 0..len {
                    dst[t] = f32_to_u32_xla(if av.at(t) { 1.0 } else { 0.0 });
                }
            }
        },
        FOp::Splat(a) => {
            let v = ctx.in_u32(pre, a)?.at(0);
            dst.fill(v);
        }
        FOp::Clamp(..) | FOp::Cmp(..) => {
            return Err(err("internal: fused op not u32-valued"));
        }
    }
    Ok(())
}

/// Evaluate one pred-valued member over a block (see
/// [`eval_member_f32`]).
fn eval_member_pred(
    ctx: &FusedCtx<'_>,
    m: &FMember,
    dst: &mut [bool],
    pre: &[bool],
    fsl: &[f32],
    usl: &[u32],
) -> Result<(), XlaError> {
    let len = ctx.len;
    match m.op {
        FOp::Cmp(dir, sdt, a, b) => match sdt {
            SDt::F32 => {
                let av = ctx.in_f32(fsl, a)?;
                let bv = ctx.in_f32(fsl, b)?;
                for t in 0..len {
                    dst[t] = cmp_s(dir, &av.at(t), &bv.at(t));
                }
            }
            SDt::U32 => {
                let av = ctx.in_u32(usl, a)?;
                let bv = ctx.in_u32(usl, b)?;
                for t in 0..len {
                    dst[t] = cmp_s(dir, &av.at(t), &bv.at(t));
                }
            }
            SDt::Pred => return Err(err("internal: fused compare on pred")),
        },
        FOp::Bin(bop, a, b) => {
            let av = ctx.in_pred(pre, a)?;
            let bv = ctx.in_pred(pre, b)?;
            for t in 0..len {
                dst[t] = bin_pred_s(bop, av.at(t), bv.at(t));
            }
        }
        FOp::Un(uop, a) => {
            if uop != UnOp::Not {
                return Err(err("internal: fused unary on pred"));
            }
            let av = ctx.in_pred(pre, a)?;
            for t in 0..len {
                dst[t] = !av.at(t);
            }
        }
        FOp::Cvt(src_dt, a) => match src_dt {
            Dt::F32 => {
                let av = ctx.in_f32(fsl, a)?;
                for t in 0..len {
                    dst[t] = av.at(t) != 0.0;
                }
            }
            Dt::I32 => {
                let av = ctx.in_i32(a)?;
                for t in 0..len {
                    dst[t] = av.at(t) as f32 != 0.0;
                }
            }
            Dt::U32 => {
                let av = ctx.in_u32(usl, a)?;
                for t in 0..len {
                    dst[t] = av.at(t) as f32 != 0.0;
                }
            }
            Dt::Pred => {
                let av = ctx.in_pred(pre, a)?;
                for t in 0..len {
                    let v = if av.at(t) { 1.0f32 } else { 0.0 };
                    dst[t] = v != 0.0;
                }
            }
        },
        FOp::Splat(a) => {
            let v = ctx.in_pred(pre, a)?.at(0);
            dst.fill(v);
        }
        FOp::Sel(..) | FOp::Clamp(..) => {
            return Err(err("internal: fused op not pred-valued"));
        }
    }
    Ok(())
}

// --------------------------------------------------------------- planner

/// Consumer index used for the virtual "materialize the root" step.
const VIRT: usize = usize::MAX;

/// Shape of a canonical data source.
fn csrc_shape<'a>(comp: &'a Computation, c: CSrc) -> Result<&'a Shape, XlaError> {
    match c {
        CSrc::Slot(s) => Ok(&comp.instrs[s].shape),
        CSrc::Param(k) => Ok(&comp.instrs[comp.params[k]].shape),
        CSrc::ParamPart(k, j) => match &comp.instrs[comp.params[k]].shape {
            Shape::Tuple(parts) => parts
                .get(j)
                .ok_or_else(|| err("get-tuple-element: index out of range")),
            _ => Err(err("get-tuple-element on non-tuple parameter")),
        },
        CSrc::WhilePart(w, j) => match &comp.instrs[w].shape {
            Shape::Tuple(parts) => parts
                .get(j)
                .ok_or_else(|| err("get-tuple-element: index out of range")),
            _ => Err(err("get-tuple-element on non-tuple while")),
        },
    }
}

/// The canonical sources an instruction reads at run time (tuple
/// operands of `while` expand recursively to their element sources).
fn read_csrcs(comp: &Computation, canon: &[CSrc], i: usize) -> Vec<CSrc> {
    let mut out = Vec::new();
    match &comp.instrs[i].op {
        Op::Parameter(_)
        | Op::Constant(_)
        | Op::Iota { .. }
        | Op::Reshape
        | Op::Gte { .. }
        | Op::Tuple => {}
        Op::While { .. } => expand_parts(comp, canon, comp.instrs[i].operands[0], &mut out),
        _ => {
            for &o in &comp.instrs[i].operands {
                out.push(canon[o]);
            }
        }
    }
    out
}

/// Expand a (possibly tuple-typed) slot into the canonical sources its
/// materialization reads.
fn expand_parts(comp: &Computation, canon: &[CSrc], o: usize, out: &mut Vec<CSrc>) {
    match canon[o] {
        CSrc::Slot(s) if matches!(comp.instrs[s].op, Op::Tuple) => {
            for &e in &comp.instrs[s].operands {
                expand_parts(comp, canon, e, out);
            }
        }
        c => out.push(c),
    }
}

/// Whether instruction `i` may join a fused group, and its slab dtype.
fn fusible(comp: &Computation, i: usize) -> Option<SDt> {
    let instr = &comp.instrs[i];
    let dt = instr.shape.dt().ok()?;
    let sdt = to_sdt(dt)?;
    let op_dims = |k: usize| comp.instrs[instr.operands[k]].shape.numel();
    let n = instr.shape.numel();
    match &instr.op {
        Op::Bin(b) => {
            let ok = match sdt {
                SDt::F32 => matches!(
                    b,
                    BinOp::Add
                        | BinOp::Sub
                        | BinOp::Mul
                        | BinOp::Div
                        | BinOp::Max
                        | BinOp::Min
                        | BinOp::Pow
                ),
                SDt::U32 => !matches!(b, BinOp::Pow),
                SDt::Pred => true,
            };
            ok.then_some(sdt)
        }
        Op::Un(u) => {
            let ok = match sdt {
                SDt::F32 => *u != UnOp::Not,
                SDt::U32 | SDt::Pred => *u == UnOp::Not,
            };
            ok.then_some(sdt)
        }
        Op::Compare(_) => {
            let odt = comp.instrs[instr.operands[0]].shape.dt().ok()?;
            matches!(odt, Dt::F32 | Dt::U32).then_some(SDt::Pred)
        }
        Op::Select => {
            let pn = op_dims(0);
            (matches!(sdt, SDt::F32 | SDt::U32) && (pn == 1 || pn == n)).then_some(sdt)
        }
        Op::Clamp => {
            let (l, h) = (op_dims(0), op_dims(2));
            (sdt == SDt::F32 && (l == 1 || l == n) && (h == 1 || h == n)).then_some(sdt)
        }
        Op::Convert => {
            let odt = comp.instrs[instr.operands[0]].shape.dt().ok()?;
            matches!(odt, Dt::F32 | Dt::S32 | Dt::U32 | Dt::Pred).then_some(sdt)
        }
        Op::Broadcast { .. } => (op_dims(0) == 1).then_some(sdt),
        _ => None,
    }
}

/// Validate one live instruction at plan time, mirroring every check
/// the reference walker performs at run time (plus static-shape
/// consistency the walker derives on the fly).
fn validate_instr(module: &HloModule, comp: &Computation, i: usize) -> Result<(), XlaError> {
    let instr = &comp.instrs[i];
    let ops = &instr.operands;
    let osh = |k: usize| -> &Shape { &comp.instrs[ops[k]].shape };
    let adims = |k: usize| -> Result<&[usize], XlaError> { osh(k).dims() };
    // the gather/pad kernels use fixed-size index registers: bound the
    // rank at compile time instead of panicking at run time
    let rank_ok = |sh: &Shape| match sh {
        Shape::Array { dims, .. } => dims.len() <= MAX_RANK,
        Shape::Tuple(_) => true,
    };
    if !rank_ok(&instr.shape) || !ops.iter().all(|&o| rank_ok(&comp.instrs[o].shape)) {
        return Err(err(format!(
            "rank > {MAX_RANK} unsupported by the planned engine"
        )));
    }
    match &instr.op {
        Op::Bin(b) => {
            if adims(0)? != adims(1)? {
                return Err(err(format!(
                    "binary op shape mismatch: {:?} vs {:?}",
                    adims(0)?,
                    adims(1)?
                )));
            }
            let dt = osh(0).dt()?;
            if osh(1).dt()? != dt {
                return Err(err("binary op element type mismatch"));
            }
            if instr.shape.dims()? != adims(0)? || instr.shape.dt()? != dt {
                return Err(err("binary op: declared shape mismatch"));
            }
            match dt {
                Dt::F32 => {
                    if !matches!(
                        b,
                        BinOp::Add
                            | BinOp::Sub
                            | BinOp::Mul
                            | BinOp::Div
                            | BinOp::Max
                            | BinOp::Min
                            | BinOp::Pow
                    ) {
                        return Err(err("bitwise op on f32"));
                    }
                }
                Dt::U32 => {
                    if matches!(b, BinOp::Pow) {
                        return Err(err("power on u32 unsupported"));
                    }
                }
                Dt::S32 => {
                    if !matches!(
                        b,
                        BinOp::Add
                            | BinOp::Sub
                            | BinOp::Mul
                            | BinOp::Max
                            | BinOp::Min
                            | BinOp::And
                            | BinOp::Or
                            | BinOp::Xor
                    ) {
                        return Err(err("unsupported s32 binary op"));
                    }
                }
                Dt::Pred => {}
            }
        }
        Op::Un(u) => {
            let dt = osh(0).dt()?;
            let ok = match dt {
                Dt::F32 => *u != UnOp::Not,
                Dt::Pred => *u == UnOp::Not,
                Dt::U32 => *u == UnOp::Not,
                Dt::S32 => matches!(u, UnOp::Neg | UnOp::Abs),
            };
            if !ok {
                return Err(err(format!("unsupported unary op on {dt:?}")));
            }
            if instr.shape.dims()? != adims(0)? || instr.shape.dt()? != dt {
                return Err(err("unary op: declared shape mismatch"));
            }
        }
        Op::Compare(_) => {
            if adims(0)? != adims(1)? {
                return Err(err("compare shape mismatch"));
            }
            let dt = osh(0).dt()?;
            if osh(1).dt()? != dt || dt == Dt::Pred {
                return Err(err("compare element type mismatch"));
            }
            if instr.shape.dims()? != adims(0)? || instr.shape.dt()? != Dt::Pred {
                return Err(err("compare: declared shape mismatch"));
            }
        }
        Op::Select => {
            if osh(0).dt()? != Dt::Pred {
                return Err(err("select: predicate must be pred"));
            }
            if adims(1)? != adims(2)? {
                return Err(err("select: branch shape mismatch"));
            }
            let n = osh(1).numel();
            let pn = osh(0).numel();
            if pn != 1 && pn != n {
                return Err(err("select: predicate must be scalar or same-shape"));
            }
            if !matches!(osh(1).dt()?, Dt::F32 | Dt::U32) || osh(2).dt()? != osh(1).dt()? {
                return Err(err("select: unsupported element types"));
            }
            if instr.shape.dims()? != adims(1)? || instr.shape.dt()? != osh(1).dt()? {
                return Err(err("select: declared shape mismatch"));
            }
        }
        Op::Clamp => {
            for k in [0, 1, 2] {
                if osh(k).dt()? != Dt::F32 {
                    return Err(err("clamp: operands must be f32"));
                }
            }
            let n = osh(1).numel();
            for k in [0, 2] {
                let b = osh(k).numel();
                if b != 1 && b != n {
                    return Err(err("clamp: bound must be scalar or same-shape"));
                }
            }
            if instr.shape.dims()? != adims(1)? {
                return Err(err("clamp: declared shape mismatch"));
            }
        }
        Op::Convert => {
            osh(0).dt()?;
            instr.shape.dt()?;
            if instr.shape.dims()? != adims(0)? {
                return Err(err("convert: declared shape mismatch"));
            }
        }
        Op::Broadcast { dims } => {
            let sdims = adims(0)?;
            let out_dims = instr.shape.dims()?;
            if sdims.len() != dims.len() {
                return Err(err("broadcast: dimensions length mismatch"));
            }
            for (pos, &od) in dims.iter().enumerate() {
                if od >= out_dims.len() || out_dims[od] != sdims[pos] {
                    return Err(err("broadcast: dimension mapping mismatch"));
                }
            }
            if osh(0).dt()? != instr.shape.dt()? {
                return Err(err("broadcast: element type mismatch"));
            }
        }
        Op::Reshape => {
            if osh(0).numel() != instr.shape.numel() {
                return Err(err("reshape: element count mismatch"));
            }
        }
        Op::Transpose { perm } => {
            let sdims = adims(0)?;
            if perm.len() != sdims.len() {
                return Err(err("transpose: permutation rank mismatch"));
            }
            let derived: Vec<usize> = perm.iter().map(|&p| sdims[p]).collect();
            if derived != instr.shape.dims()? {
                return Err(err("transpose: declared shape mismatch"));
            }
        }
        Op::Slice { starts, limits, strides } => {
            let sdims = adims(0)?;
            if starts.len() != sdims.len() {
                return Err(err("slice: rank mismatch"));
            }
            let mut derived = Vec::with_capacity(sdims.len());
            for (d, &sd) in sdims.iter().enumerate() {
                if limits[d] > sd || starts[d] > limits[d] || strides[d] == 0 {
                    return Err(err("slice: bounds out of range"));
                }
                derived.push((limits[d] - starts[d]).div_ceil(strides[d]));
            }
            if derived != instr.shape.dims()? {
                return Err(err("slice: declared shape mismatch"));
            }
        }
        Op::Concat { dim } => {
            let first = adims(0)?;
            if *dim >= first.len() {
                return Err(err("concatenate: dimension out of range"));
            }
            let dt = osh(0).dt()?;
            let mut total = 0usize;
            for k in 0..ops.len() {
                let d = adims(k)?;
                if d.len() != first.len() {
                    return Err(err("concatenate: rank mismatch"));
                }
                for (dd, (&a, &b)) in d.iter().zip(first).enumerate() {
                    if dd != *dim && a != b {
                        return Err(err(format!("concatenate: dim {dd} mismatch ({a} vs {b})")));
                    }
                }
                if osh(k).dt()? != dt {
                    return Err(err("concatenate element type mismatch"));
                }
                total += d[*dim];
            }
            let mut derived = first.to_vec();
            derived[*dim] = total;
            if derived != instr.shape.dims()? {
                return Err(err("concatenate: declared shape mismatch"));
            }
        }
        Op::Pad { low, high, interior } => {
            let sdims = adims(0)?;
            if low.len() != sdims.len() {
                return Err(err("pad: rank mismatch"));
            }
            if osh(0).dt()? == Dt::Pred {
                return Err(err("pad element type mismatch"));
            }
            if osh(1).dt()? != osh(0).dt()? || osh(1).numel() == 0 {
                return Err(err("pad element type mismatch"));
            }
            let mut derived = Vec::with_capacity(sdims.len());
            for (d, &sd) in sdims.iter().enumerate() {
                let span = sd as i64 + (sd.saturating_sub(1) * interior[d]) as i64;
                let od = span + low[d] + high[d];
                if od < 0 {
                    return Err(err("pad: negative output dimension"));
                }
                derived.push(od as usize);
            }
            if derived != instr.shape.dims()? {
                return Err(err("pad: declared shape mismatch"));
            }
        }
        Op::Dot { lc, rc } => {
            let d = dot_dims(adims(0)?, adims(1)?, *lc, *rc)?;
            if osh(0).dt()? != Dt::F32 || osh(1).dt()? != Dt::F32 {
                return Err(err("dot: operands must be f32"));
            }
            if instr.shape.dims()? != [d.m, d.n] {
                return Err(err("dot: declared shape mismatch"));
            }
        }
        Op::Reduce { dims, comp: rc } => {
            if osh(0).dt()? != Dt::F32 || osh(1).dt()? != Dt::F32 {
                return Err(err("reduce: only f32 operands supported"));
            }
            if osh(1).numel() != 1 {
                return Err(err("reduce: init value must be scalar"));
            }
            if module.computations[*rc].params.len() != 2 {
                return Err(err("reduce: combiner must take two parameters"));
            }
            let sdims = adims(0)?;
            let derived: Vec<usize> = (0..sdims.len())
                .filter(|d| !dims.contains(d))
                .map(|d| sdims[d])
                .collect();
            if derived != instr.shape.dims()? {
                return Err(err("reduce: declared shape mismatch"));
            }
        }
        Op::While { cond, body } => {
            if module.computations[*cond].params.len() != 1
                || module.computations[*body].params.len() != 1
            {
                return Err(err("while: condition and body must take one parameter"));
            }
        }
        Op::Iota { .. } => {
            if instr.shape.dt()? == Dt::Pred {
                return Err(err("iota on pred"));
            }
        }
        Op::Parameter(_) | Op::Constant(_) | Op::Gte { .. } | Op::Tuple => {}
    }
    Ok(())
}

/// Compile one computation into its instruction program: canonical
/// sources, transitive liveness, fusion groups, plan-time constants
/// (including folded iotas), and the liveness-based static buffer
/// assignment.
fn plan_comp(module: &HloModule, ci: usize) -> Result<CompPlan, XlaError> {
    let comp = &module.computations[ci];
    let instrs = &comp.instrs;
    let n = instrs.len();

    // pass A: canonical data sources (reshape / gte-of-tuple aliases)
    let mut canon: Vec<CSrc> = Vec::with_capacity(n);
    for i in 0..n {
        let c = match &instrs[i].op {
            Op::Parameter(k) => CSrc::Param(*k),
            Op::Reshape => canon[instrs[i].operands[0]],
            Op::Gte { index } => {
                let o = instrs[i].operands[0];
                // bounds-check against the operand's tuple shape
                csrc_shape(comp, canon[o]).and_then(|sh| match sh {
                    Shape::Tuple(parts) if *index < parts.len() => Ok(()),
                    Shape::Tuple(_) => Err(err("get-tuple-element: index out of range")),
                    _ => Err(err("get-tuple-element on non-tuple")),
                })?;
                match canon[o] {
                    CSrc::Slot(s) => match &instrs[s].op {
                        Op::Tuple => canon[instrs[s].operands[*index]],
                        Op::While { .. } => CSrc::WhilePart(s, *index),
                        _ => return Err(err("get-tuple-element on non-tuple")),
                    },
                    CSrc::Param(k) => CSrc::ParamPart(k, *index),
                    _ => {
                        return Err(err("get-tuple-element: nested tuple parts unsupported"));
                    }
                }
            }
            _ => CSrc::Slot(i),
        };
        canon.push(c);
    }

    // pass B: transitive liveness from the root
    let mut root_reads = Vec::new();
    expand_parts(comp, &canon, comp.root, &mut root_reads);
    let mut live = vec![false; n];
    let mut stack: Vec<usize> = Vec::new();
    let seed = |c: CSrc, stack: &mut Vec<usize>| match c {
        CSrc::Slot(s) | CSrc::WhilePart(s, _) => stack.push(s),
        _ => {}
    };
    for &c in &root_reads {
        seed(c, &mut stack);
    }
    seed(canon[comp.root], &mut stack);
    while let Some(s) = stack.pop() {
        if live[s] {
            continue;
        }
        live[s] = true;
        for c in read_csrcs(comp, &canon, s) {
            seed(c, &mut stack);
        }
    }

    // pass C: uses per producing slot, from *live* consumers only
    // (consumer instr indices + VIRT for the root materialization) —
    // dead consumers must neither block fusion nor pin buffers
    let mut uses: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mark = |c: CSrc, at: usize, uses: &mut Vec<Vec<usize>>| match c {
        CSrc::Slot(s) | CSrc::WhilePart(s, _) => uses[s].push(at),
        _ => {}
    };
    for i in 0..n {
        if !live[i] {
            continue;
        }
        for c in read_csrcs(comp, &canon, i) {
            mark(c, i, &mut uses);
        }
    }
    for &c in &root_reads {
        mark(c, VIRT, &mut uses);
    }

    // pass D: fused elementwise groups (greedy, largest root first)
    let mut member_of: Vec<Option<usize>> = vec![None; n];
    let mut group_slots: Vec<Vec<usize>> = Vec::new();
    for i in (0..n).rev() {
        if !live[i] || member_of[i].is_some() || !matches!(canon[i], CSrc::Slot(s) if s == i) {
            continue;
        }
        if fusible(comp, i).is_none() {
            continue;
        }
        let numel = instrs[i].shape.numel();
        let gid = group_slots.len();
        member_of[i] = Some(gid);
        let mut members = vec![i];
        let mut work = vec![i];
        while let Some(m) = work.pop() {
            for &o in &instrs[m].operands {
                let CSrc::Slot(s) = canon[o] else { continue };
                if member_of[s].is_some() || !live[s] {
                    continue;
                }
                if fusible(comp, s).is_none() || instrs[s].shape.numel() != numel {
                    continue;
                }
                if !uses[s]
                    .iter()
                    .all(|&c| c != VIRT && member_of[c] == Some(gid))
                {
                    continue;
                }
                member_of[s] = Some(gid);
                members.push(s);
                work.push(s);
            }
        }
        if members.len() < 2 {
            member_of[i] = None;
            continue;
        }
        members.sort_unstable();
        group_slots.push(members);
    }
    let group_root: Vec<usize> =
        group_slots.iter().map(|m| *m.last().expect("groups have >= 2 members")).collect();

    // last use per producing slot, in *step* positions (a use inside a
    // fused group pins the value until the group's root executes)
    let step_of = |c: usize| -> usize {
        if c == VIRT {
            VIRT
        } else {
            member_of[c].map(|g| group_root[g]).unwrap_or(c)
        }
    };
    let mut free_at: Vec<Vec<usize>> = vec![Vec::new(); n];
    for s in 0..n {
        if !live[s] || uses[s].is_empty() {
            continue;
        }
        let last = uses[s].iter().map(|&c| step_of(c)).max().expect("uses checked non-empty");
        if last != VIRT {
            free_at[last].push(s);
        }
    }

    // pass E: steps, constants, buffer assignment
    let mut src = vec![ValSrc::Dead; n];
    let mut consts: Vec<Literal> = Vec::new();
    let mut steps: Vec<Step> = Vec::new();
    let mut buf_dt: Vec<Dt> = Vec::new();
    let mut buf_cap: Vec<usize> = Vec::new();
    let mut free: BTreeMap<u8, Vec<usize>> = BTreeMap::new();
    let mut lit_of: BTreeMap<usize, usize> = BTreeMap::new();
    let dt_key = |dt: Dt| -> u8 {
        match dt {
            Dt::F32 => 0,
            Dt::S32 => 1,
            Dt::U32 => 2,
            Dt::Pred => 3,
        }
    };
    let csrc_to_valsrc = |c: CSrc, src: &[ValSrc], lit_of: &BTreeMap<usize, usize>| match c {
        CSrc::Slot(s) => src[s],
        CSrc::Param(k) => ValSrc::Param(k),
        CSrc::ParamPart(k, j) => ValSrc::ParamPart(k, j),
        CSrc::WhilePart(w, j) => lit_of
            .get(&w)
            .map(|&li| ValSrc::LitPart(li, j))
            .unwrap_or(ValSrc::Dead),
    };
    let mut groups: Vec<Group> = Vec::new();
    let mut group_built: Vec<bool> = vec![false; group_slots.len()];
    for i in 0..n {
        match &instrs[i].op {
            Op::Parameter(k) => {
                src[i] = ValSrc::Param(*k);
                continue;
            }
            Op::Constant(l) => {
                // dead constants stay in the Rc'd module only — don't
                // duplicate their data into the plan
                if live[i] {
                    src[i] = ValSrc::Const(consts.len());
                    consts.push(l.clone());
                }
                continue;
            }
            Op::Reshape | Op::Gte { .. } => {
                validate_instr(module, comp, i)?;
                src[i] = csrc_to_valsrc(canon[i], &src, &lit_of);
                continue;
            }
            Op::Tuple => {
                src[i] = ValSrc::Tuple;
                continue;
            }
            _ => {}
        }
        // validate dead instructions too: the reference walker evaluates
        // every instruction, so a plan must reject at least what the
        // walker rejects ("stricter than the walker", DESIGN.md)
        validate_instr(module, comp, i)?;
        if !live[i] {
            continue;
        }
        match &instrs[i].op {
            Op::Iota { dim } => {
                let dims = instrs[i].shape.dims()?.to_vec();
                let vals = iota_values(&dims, *dim);
                let dims_i: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                let lit = match instrs[i].shape.dt()? {
                    Dt::U32 => Literal {
                        data: Data::U32(vals.iter().map(|&v| v as u32).collect()),
                        dims: dims_i,
                    },
                    Dt::S32 => Literal {
                        data: Data::I32(vals.iter().map(|&v| v as i32).collect()),
                        dims: dims_i,
                    },
                    Dt::F32 => Literal {
                        data: Data::F32(vals.iter().map(|&v| v as f32).collect()),
                        dims: dims_i,
                    },
                    Dt::Pred => return Err(err("iota on pred")),
                };
                src[i] = ValSrc::Const(consts.len());
                consts.push(lit);
                continue;
            }
            Op::While { .. } => {
                let li = lit_of.len();
                lit_of.insert(i, li);
                src[i] = ValSrc::Lit(li);
                steps.push(Step::Prim(i));
            }
            _ => {
                let is_member = member_of[i].is_some();
                let is_root = matches!(member_of[i], Some(g) if group_root[g] == i);
                if is_member && !is_root {
                    // slab-only member: no buffer, no step
                    continue;
                }
                let dt = instrs[i].shape.dt()?;
                let numel = instrs[i].shape.numel();
                let b = match free.entry(dt_key(dt)).or_default().pop() {
                    Some(b) => {
                        buf_cap[b] = buf_cap[b].max(numel);
                        b
                    }
                    None => {
                        buf_dt.push(dt);
                        buf_cap.push(numel);
                        buf_dt.len() - 1
                    }
                };
                src[i] = ValSrc::Buf(b);
                if is_root {
                    let gid = member_of[i].expect("fused root is a member");
                    group_built[gid] = true;
                    groups.push(build_group(
                        comp,
                        &canon,
                        &member_of,
                        gid,
                        &group_slots[gid],
                        &src,
                        &lit_of,
                    )?);
                    steps.push(Step::Fused(groups.len() - 1));
                } else {
                    steps.push(Step::Prim(i));
                }
            }
        }
        // release buffers whose last (step-level) use is this step
        for &s in &free_at[i] {
            if let ValSrc::Buf(b) = src[s] {
                free.entry(dt_key(comp.instrs[s].shape.dt()?)).or_default().push(b);
            }
        }
    }
    debug_assert!(group_built.iter().all(|&b| b));

    let max_members = groups.iter().map(|g| g.members.len()).max().unwrap_or(0);
    Ok(CompPlan {
        steps,
        src,
        consts,
        groups,
        buf_dt,
        buf_cap,
        n_lits: lit_of.len(),
        n_params: comp.params.len(),
        root: comp.root,
        max_members,
    })
}

/// Assemble the runtime form of one fused group: members in ascending
/// (topological) instruction order with operand references resolved to
/// earlier slabs or interned external inputs.
fn build_group(
    comp: &Computation,
    canon: &[CSrc],
    member_of: &[Option<usize>],
    gid: usize,
    slots: &[usize],
    src: &[ValSrc],
    lit_of: &BTreeMap<usize, usize>,
) -> Result<Group, XlaError> {
    let root = *slots.last().expect("groups have >= 2 members");
    let numel = comp.instrs[root].shape.numel();
    let midx: BTreeMap<usize, usize> = slots.iter().enumerate().map(|(k, &s)| (s, k)).collect();
    let mut pool = ExtPool {
        comp,
        src,
        lit_of,
        ext: Vec::new(),
        ext_src: Vec::new(),
    };
    let mut members = Vec::with_capacity(slots.len());
    for &m in slots {
        let instr = &comp.instrs[m];
        let fref = |k: usize, pool: &mut ExtPool<'_>| -> Result<FRef, XlaError> {
            let c = canon[instr.operands[k]];
            if let CSrc::Slot(s) = c {
                if member_of[s] == Some(gid) {
                    return Ok(FRef::Slab(midx[&s]));
                }
            }
            Ok(FRef::Ext(pool.intern(c)?))
        };
        let sdt = fusible(comp, m).ok_or_else(|| err("internal: non-fusible member"))?;
        let op = match &instr.op {
            Op::Bin(b) => FOp::Bin(*b, fref(0, &mut pool)?, fref(1, &mut pool)?),
            Op::Un(u) => FOp::Un(*u, fref(0, &mut pool)?),
            Op::Compare(d) => {
                let odt = comp.instrs[instr.operands[0]].shape.dt()?;
                let osdt = to_sdt(odt).ok_or_else(|| err("internal: compare operand dt"))?;
                FOp::Cmp(*d, osdt, fref(0, &mut pool)?, fref(1, &mut pool)?)
            }
            Op::Select => {
                FOp::Sel(fref(0, &mut pool)?, fref(1, &mut pool)?, fref(2, &mut pool)?)
            }
            Op::Clamp => {
                FOp::Clamp(fref(0, &mut pool)?, fref(1, &mut pool)?, fref(2, &mut pool)?)
            }
            Op::Convert => {
                let odt = comp.instrs[instr.operands[0]].shape.dt()?;
                FOp::Cvt(odt, fref(0, &mut pool)?)
            }
            Op::Broadcast { .. } => FOp::Splat(fref(0, &mut pool)?),
            _ => return Err(err("internal: non-fusible member op")),
        };
        members.push(FMember { op, sdt });
    }
    Ok(Group {
        root,
        numel,
        slots: slots.to_vec(),
        members,
        ext: pool.ext,
    })
}

/// External-input interner of one group under construction.
struct ExtPool<'p> {
    comp: &'p Computation,
    src: &'p [ValSrc],
    lit_of: &'p BTreeMap<usize, usize>,
    ext: Vec<ExtIn>,
    ext_src: Vec<CSrc>,
}

impl ExtPool<'_> {
    fn intern(&mut self, c: CSrc) -> Result<usize, XlaError> {
        if let Some(p) = self.ext_src.iter().position(|&e| e == c) {
            return Ok(p);
        }
        let sh = csrc_shape(self.comp, c)?;
        let vs = match c {
            CSrc::Slot(s) => self.src[s],
            CSrc::Param(k) => ValSrc::Param(k),
            CSrc::ParamPart(k, j) => ValSrc::ParamPart(k, j),
            CSrc::WhilePart(w, j) => {
                let li = self
                    .lit_of
                    .get(&w)
                    .ok_or_else(|| err("internal: while literal missing"))?;
                ValSrc::LitPart(*li, j)
            }
        };
        self.ext_src.push(c);
        self.ext.push(ExtIn { src: vs, scalar: sh.numel() == 1 });
        Ok(self.ext.len() - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::interp::{execute_ref, parse};

    /// Bit-exact literal comparison (NaN bit patterns included).
    fn assert_bit_eq(a: &Literal, b: &Literal, path: &str) {
        assert_eq!(a.dims, b.dims, "{path}: dims");
        match (&a.data, &b.data) {
            (Data::F32(x), Data::F32(y)) => {
                assert_eq!(x.len(), y.len(), "{path}: len");
                for (i, (p, q)) in x.iter().zip(y).enumerate() {
                    assert_eq!(p.to_bits(), q.to_bits(), "{path}[{i}]: {p} vs {q}");
                }
            }
            (Data::I32(x), Data::I32(y)) => assert_eq!(x, y, "{path}"),
            (Data::U32(x), Data::U32(y)) => assert_eq!(x, y, "{path}"),
            (Data::Pred(x), Data::Pred(y)) => assert_eq!(x, y, "{path}"),
            (Data::Tuple(x), Data::Tuple(y)) => {
                assert_eq!(x.len(), y.len(), "{path}: tuple len");
                for (i, (p, q)) in x.iter().zip(y).enumerate() {
                    assert_bit_eq(p, q, &format!("{path}.{i}"));
                }
            }
            _ => panic!("{path}: element type mismatch"),
        }
    }

    /// Run a module on both paths and require bit equality.
    fn run_both(text: &str, args: Vec<Literal>) -> Literal {
        let m = parse(text).expect("parse");
        let want = execute_ref(&m, args.clone()).expect("execute_ref");
        let plan = Plan::new(Rc::new(m)).expect("plan");
        let got = plan.execute(args.clone()).expect("plan execute");
        assert_bit_eq(&got, &want, "root");
        // second run through the cached buffers must be identical
        let again = plan.execute(args).expect("plan re-execute");
        assert_bit_eq(&again, &want, "root (cached rerun)");
        got
    }

    fn f32v(n: usize, seed: u32) -> Vec<f32> {
        // deterministic, sign-mixed, includes exact halves for rounding
        (0..n)
            .map(|i| {
                let k = (i as u32).wrapping_mul(2654435761).wrapping_add(seed);
                ((k >> 8) as f32 / 16_777_216.0 - 0.5) * 8.0
            })
            .collect()
    }

    #[test]
    fn fused_f32_chain_matches_reference() {
        // splat const -> mul -> neg -> exp -> add chain, single consumers
        let text = "ENTRY %main (p0: f32[300]) -> f32[300] {\n  \
            %p0 = f32[300] parameter(0)\n  \
            %c = f32[] constant(0.25)\n  \
            %cb = f32[300] broadcast(%c), dimensions={}\n  \
            %m = f32[300] multiply(%p0, %cb)\n  \
            %n = f32[300] negate(%m)\n  \
            %e = f32[300] exponential(%n)\n  \
            ROOT %a = f32[300] add(%e, %p0)\n}\n";
        let m = parse(text).unwrap();
        let plan = Plan::new(Rc::new(m)).unwrap();
        // the chain must actually have fused into one group
        assert_eq!(plan.comps[plan.module.entry].groups.len(), 1);
        assert!(plan.comps[plan.module.entry].groups[0].members.len() >= 4);
        run_both(text, vec![Literal::vec1(&f32v(300, 3))]);
    }

    #[test]
    fn fused_chain_with_external_consumer_stays_correct() {
        // %m is consumed by the chain AND by the root tuple: it must be
        // materialized (group output or unfused) and stay bit-exact
        let text = "ENTRY %main (p0: f32[64]) -> (f32[64], f32[64]) {\n  \
            %p0 = f32[64] parameter(0)\n  \
            %m = f32[64] multiply(%p0, %p0)\n  \
            %s = f32[64] sqrt(%m)\n  \
            %t = f32[64] tanh(%s)\n  \
            ROOT %r = (f32[64], f32[64]) tuple(%t, %m)\n}\n";
        run_both(text, vec![Literal::vec1(&f32v(64, 9))]);
    }

    #[test]
    fn fused_u32_hash_and_convert_matches_reference() {
        // counter-hash RNG shape: iota ^ key -> mul -> shr -> xor ->
        // convert to f32 -> scale -> sine (crosses u32 -> f32 slabs)
        let text = "ENTRY %main (p0: u32[500]) -> f32[500] {\n  \
            %p0 = u32[500] parameter(0)\n  \
            %i = u32[500] iota(), iota_dimension=0\n  \
            %x = u32[500] xor(%p0, %i)\n  \
            %c = u32[] constant(2654435761)\n  \
            %cb = u32[500] broadcast(%c), dimensions={}\n  \
            %m = u32[500] multiply(%x, %cb)\n  \
            %s = u32[] constant(16)\n  \
            %sb = u32[500] broadcast(%s), dimensions={}\n  \
            %h = u32[500] shift-right-logical(%m, %sb)\n  \
            %x2 = u32[500] xor(%m, %h)\n  \
            %f = f32[500] convert(%x2)\n  \
            %k = f32[] constant(2.3283064e-10)\n  \
            %kb = f32[500] broadcast(%k), dimensions={}\n  \
            %u = f32[500] multiply(%f, %kb)\n  \
            ROOT %sn = f32[500] sine(%u)\n}\n";
        let keys: Vec<u32> = (0..500u32).map(|i| i.wrapping_mul(0x9E3779B9)).collect();
        run_both(text, vec![Literal::vec1(&keys)]);
    }

    #[test]
    fn fused_compare_select_clamp_matches_reference() {
        let text = "ENTRY %main (p0: f32[200], p1: f32[200]) -> f32[200] {\n  \
            %p0 = f32[200] parameter(0)\n  \
            %p1 = f32[200] parameter(1)\n  \
            %z = f32[] constant(0)\n  \
            %zb = f32[200] broadcast(%z), dimensions={}\n  \
            %g = pred[200] compare(%p0, %zb), direction=GT\n  \
            %s = f32[200] select(%g, %p0, %p1)\n  \
            %lo = f32[] constant(-1)\n  \
            %hi = f32[] constant(1.5)\n  \
            ROOT %c = f32[200] clamp(%lo, %s, %hi)\n}\n";
        run_both(
            text,
            vec![
                Literal::vec1(&f32v(200, 1)),
                Literal::vec1(&f32v(200, 2)),
            ],
        );
    }

    #[test]
    fn fused_nan_semantics_match_reference() {
        // log of negatives -> NaN; NaN through max/min/select must keep
        // the reference's exact bit patterns
        let text = "ENTRY %main (p0: f32[100]) -> f32[100] {\n  \
            %p0 = f32[100] parameter(0)\n  \
            %l = f32[100] log(%p0)\n  \
            %z = f32[] constant(0)\n  \
            %zb = f32[100] broadcast(%z), dimensions={}\n  \
            %mx = f32[100] maximum(%l, %zb)\n  \
            ROOT %mn = f32[100] minimum(%mx, %p0)\n}\n";
        run_both(text, vec![Literal::vec1(&f32v(100, 7))]);
    }

    #[test]
    fn dot_is_threaded_and_bit_identical_across_thread_counts() {
        // 64x96 . 96x80 = 491520 flops > DOT_PAR_MIN_FLOPS
        let a = Literal::vec1(&f32v(64 * 96, 11)).reshape(&[64, 96]).unwrap();
        let b = Literal::vec1(&f32v(96 * 80, 12)).reshape(&[96, 80]).unwrap();
        let text = "ENTRY %main (p0: f32[64,96], p1: f32[96,80]) -> f32[64,80] {\n  \
            %p0 = f32[64,96] parameter(0)\n  \
            %p1 = f32[96,80] parameter(1)\n  \
            ROOT %d = f32[64,80] dot(%p0, %p1), lhs_contracting_dims={1}, \
            rhs_contracting_dims={0}\n}\n";
        let m = parse(text).unwrap();
        let want = execute_ref(&m, vec![a.clone(), b.clone()]).unwrap();
        let plan = Plan::new(Rc::new(m)).unwrap();
        for threads in [1usize, 2, 3, 8, 64] {
            plan.set_threads(threads);
            let got = plan.execute(vec![a.clone(), b.clone()]).unwrap();
            assert_bit_eq(&got, &want, &format!("threads={threads}"));
        }
    }

    #[test]
    fn dot_transposed_contractions_match_reference() {
        // lhs_contracting_dims={0} exercises the strided operand path
        let a = Literal::vec1(&f32v(12 * 5, 21)).reshape(&[12, 5]).unwrap();
        let b = Literal::vec1(&f32v(7 * 12, 22)).reshape(&[7, 12]).unwrap();
        let text = "ENTRY %main (p0: f32[12,5], p1: f32[7,12]) -> f32[5,7] {\n  \
            %p0 = f32[12,5] parameter(0)\n  \
            %p1 = f32[7,12] parameter(1)\n  \
            ROOT %d = f32[5,7] dot(%p0, %p1), lhs_contracting_dims={0}, \
            rhs_contracting_dims={1}\n}\n";
        run_both(text, vec![a, b]);
    }

    #[test]
    fn gather_ops_match_reference() {
        let x = Literal::vec1(&f32v(6 * 8, 5)).reshape(&[6, 8]).unwrap();
        let text = "ENTRY %main (p0: f32[6,8]) -> (f32[8,6], f32[3,3], f32[12,8], f32[9,10]) {\n  \
            %p0 = f32[6,8] parameter(0)\n  \
            %t = f32[8,6] transpose(%p0), dimensions={1,0}\n  \
            %s = f32[3,3] slice(%p0), slice={[1:6:2],[0:8:3]}\n  \
            %c = f32[12,8] concatenate(%p0, %p0), dimensions={0}\n  \
            %z = f32[] constant(7)\n  \
            %pd = f32[9,10] pad(%p0, %z), padding=2_1x1_1\n  \
            ROOT %r = (f32[8,6], f32[3,3], f32[12,8], f32[9,10]) \
            tuple(%t, %s, %c, %pd)\n}\n";
        run_both(text, vec![x]);
    }

    #[test]
    fn pad_negative_and_interior_matches_reference() {
        let x = Literal::vec1(&f32v(4 * 5, 31)).reshape(&[4, 5]).unwrap();
        let text = "ENTRY %main (p0: f32[4,5]) -> (f32[2,9], f32[7,5]) {\n  \
            %p0 = f32[4,5] parameter(0)\n  \
            %z = f32[] constant(-3)\n  \
            %a = f32[2,9] pad(%p0, %z), padding=-1_-1x0_0_1\n  \
            %b = f32[7,5] pad(%p0, %z), padding=0_0_1x0_0\n  \
            ROOT %r = (f32[2,9], f32[7,5]) tuple(%a, %b)\n}\n";
        run_both(text, vec![x]);
    }

    #[test]
    fn broadcast_row_and_col_match_reference() {
        let v = Literal::vec1(&f32v(6, 41));
        let text = "ENTRY %main (p0: f32[6]) -> (f32[4,6], f32[6,3]) {\n  \
            %p0 = f32[6] parameter(0)\n  \
            %r = f32[4,6] broadcast(%p0), dimensions={1}\n  \
            %c = f32[6,3] broadcast(%p0), dimensions={0}\n  \
            ROOT %t = (f32[4,6], f32[6,3]) tuple(%r, %c)\n}\n";
        run_both(text, vec![v]);
    }

    #[test]
    fn reduce_monoids_and_generic_match_reference() {
        let x = Literal::vec1(&f32v(5 * 7, 51)).reshape(&[5, 7]).unwrap();
        let text = "%r_add (a: f32[], b: f32[]) -> f32[] {\n  \
            %a = f32[] parameter(0)\n  %b = f32[] parameter(1)\n  \
            ROOT %v = f32[] add(%a, %b)\n}\n\n\
            %r_max (a: f32[], b: f32[]) -> f32[] {\n  \
            %a = f32[] parameter(0)\n  %b = f32[] parameter(1)\n  \
            ROOT %v = f32[] maximum(%a, %b)\n}\n\n\
            %r_sub (a: f32[], b: f32[]) -> f32[] {\n  \
            %a = f32[] parameter(0)\n  %b = f32[] parameter(1)\n  \
            ROOT %v = f32[] subtract(%a, %b)\n}\n\n\
            ENTRY %main (p0: f32[5,7]) -> (f32[5], f32[7], f32[5]) {\n  \
            %p0 = f32[5,7] parameter(0)\n  \
            %z = f32[] constant(0)\n  \
            %lo = f32[] constant(-1e30)\n  \
            %a = f32[5] reduce(%p0, %z), dimensions={1}, to_apply=%r_add\n  \
            %m = f32[7] reduce(%p0, %lo), dimensions={0}, to_apply=%r_max\n  \
            %g = f32[5] reduce(%p0, %z), dimensions={1}, to_apply=%r_sub\n  \
            ROOT %r = (f32[5], f32[7], f32[5]) tuple(%a, %m, %g)\n}\n";
        run_both(text, vec![x]);
    }

    #[test]
    fn while_loop_and_gte_match_reference() {
        // state: (counter, bound, accumulating array)
        let text = "%cond (s: (u32[], u32[], f32[8])) -> pred[] {\n  \
            %s = (u32[], u32[], f32[8]) parameter(0)\n  \
            %j = u32[] get-tuple-element(%s), index=0\n  \
            %n = u32[] get-tuple-element(%s), index=1\n  \
            ROOT %lt = pred[] compare(%j, %n), direction=LT\n}\n\n\
            %body (s: (u32[], u32[], f32[8])) -> (u32[], u32[], f32[8]) {\n  \
            %s = (u32[], u32[], f32[8]) parameter(0)\n  \
            %j = u32[] get-tuple-element(%s), index=0\n  \
            %n = u32[] get-tuple-element(%s), index=1\n  \
            %w = f32[8] get-tuple-element(%s), index=2\n  \
            %one = u32[] constant(1)\n  \
            %j2 = u32[] add(%j, %one)\n  \
            %h = f32[] constant(1.5)\n  \
            %hb = f32[8] broadcast(%h), dimensions={}\n  \
            %w2 = f32[8] multiply(%w, %hb)\n  \
            %w3 = f32[8] add(%w2, %hb)\n  \
            ROOT %t = (u32[], u32[], f32[8]) tuple(%j2, %n, %w3)\n}\n\n\
            ENTRY %main (p0: u32[], p1: f32[8]) -> f32[8] {\n  \
            %p0 = u32[] parameter(0)\n  \
            %p1 = f32[8] parameter(1)\n  \
            %z = u32[] constant(0)\n  \
            %init = (u32[], u32[], f32[8]) tuple(%z, %p0, %p1)\n  \
            %w = (u32[], u32[], f32[8]) while(%init), condition=%cond, body=%body\n  \
            ROOT %out = f32[8] get-tuple-element(%w), index=2\n}\n";
        let n = Literal::vec1(&[5u32]).reshape(&[]).unwrap();
        run_both(text, vec![n, Literal::vec1(&f32v(8, 61))]);
        // zero-trip while
        let text2 = text;
        let n0 = Literal::vec1(&[0u32]).reshape(&[]).unwrap();
        run_both(text2, vec![n0, Literal::vec1(&f32v(8, 62))]);
    }

    #[test]
    fn reshape_aliases_fuse_through_and_match_reference() {
        // reshape sits inside an elementwise chain and on a slice result
        let x = Literal::vec1(&f32v(24, 71)).reshape(&[4, 6]).unwrap();
        let text = "ENTRY %main (p0: f32[4,6]) -> f32[24] {\n  \
            %p0 = f32[4,6] parameter(0)\n  \
            %f = f32[24] reshape(%p0)\n  \
            %n = f32[24] negate(%f)\n  \
            %r = f32[4,6] reshape(%n)\n  \
            %s = f32[4,6] multiply(%r, %p0)\n  \
            ROOT %o = f32[24] reshape(%s)\n}\n";
        run_both(text, vec![x]);
    }

    #[test]
    fn gte_of_tuple_aliases_match_reference() {
        let a = Literal::vec1(&f32v(10, 81));
        let text = "ENTRY %main (p0: f32[10]) -> f32[10] {\n  \
            %p0 = f32[10] parameter(0)\n  \
            %n = f32[10] negate(%p0)\n  \
            %t = (f32[10], f32[10]) tuple(%p0, %n)\n  \
            %g = f32[10] get-tuple-element(%t), index=1\n  \
            ROOT %a = f32[10] add(%g, %p0)\n}\n";
        run_both(text, vec![a]);
    }

    #[test]
    fn scalar_and_empty_shapes_match_reference() {
        let s = Literal::vec1(&[2.5f32]).reshape(&[]).unwrap();
        let text = "ENTRY %main (p0: f32[]) -> f32[] {\n  \
            %p0 = f32[] parameter(0)\n  \
            %c = f32[] constant(4)\n  \
            %m = f32[] multiply(%p0, %c)\n  \
            ROOT %s = f32[] sqrt(%m)\n}\n";
        run_both(text, vec![s]);
        let e = Literal::vec1(&[] as &[f32]);
        let text2 = "ENTRY %main (p0: f32[0]) -> f32[0] {\n  \
            %p0 = f32[0] parameter(0)\n  \
            ROOT %n = f32[0] negate(%p0)\n}\n";
        run_both(text2, vec![e]);
    }

    #[test]
    fn iota_folds_to_constant_and_matches_reference() {
        let text = "ENTRY %main (p0: f32[3,4]) -> (f32[3,4], f32[3,4]) {\n  \
            %p0 = f32[3,4] parameter(0)\n  \
            %i0 = f32[3,4] iota(), iota_dimension=0\n  \
            %i1 = f32[3,4] iota(), iota_dimension=1\n  \
            %a = f32[3,4] add(%i0, %p0)\n  \
            %b = f32[3,4] multiply(%i1, %p0)\n  \
            ROOT %t = (f32[3,4], f32[3,4]) tuple(%a, %b)\n}\n";
        run_both(
            text,
            vec![Literal::vec1(&f32v(12, 91)).reshape(&[3, 4]).unwrap()],
        );
    }

    #[test]
    fn round_convert_sign_paths_match_reference() {
        // stochastic-rounding shape: round/floor/sign/abs + converts
        let text = "ENTRY %main (p0: f32[64]) -> (f32[64], s32[64], u32[64], f32[64]) {\n  \
            %p0 = f32[64] parameter(0)\n  \
            %r = f32[64] round-nearest-even(%p0)\n  \
            %i = s32[64] convert(%p0)\n  \
            %u = u32[64] convert(%p0)\n  \
            %sg = f32[64] sign(%p0)\n  \
            %ab = f32[64] abs(%p0)\n  \
            %m = f32[64] multiply(%sg, %ab)\n  \
            ROOT %t = (f32[64], s32[64], u32[64], f32[64]) tuple(%r, %i, %u, %m)\n}\n";
        let mut v = f32v(64, 13);
        // exact halves exercise ties-to-even on both paths
        v[0] = 0.5;
        v[1] = 1.5;
        v[2] = -2.5;
        v[3] = -0.5;
        run_both(text, vec![Literal::vec1(&v)]);
    }

    #[test]
    fn invalid_modules_fail_at_plan_time() {
        // dot on u32 operands
        let bad = parse(
            "ENTRY %main (p0: u32[2,2]) -> u32[2,2] {\n  \
             %p0 = u32[2,2] parameter(0)\n  \
             ROOT %d = u32[2,2] dot(%p0, %p0), lhs_contracting_dims={1}, \
             rhs_contracting_dims={0}\n}\n",
        )
        .unwrap();
        assert!(Plan::new(Rc::new(bad)).is_err());
        // declared shape inconsistent with operands
        let bad2 = parse(
            "ENTRY %main (p0: f32[4]) -> f32[5] {\n  \
             %p0 = f32[4] parameter(0)\n  \
             ROOT %n = f32[5] negate(%p0)\n}\n",
        )
        .unwrap();
        assert!(Plan::new(Rc::new(bad2)).is_err());
    }

    #[test]
    fn argument_validation_matches_reference_behavior() {
        let m = parse(
            "ENTRY %main (p0: f32[2]) -> f32[2] {\n  %p0 = f32[2] parameter(0)\n  \
             ROOT %n = f32[2] negate(%p0)\n}\n",
        )
        .unwrap();
        let plan = Plan::new(Rc::new(m)).unwrap();
        assert!(plan.execute(vec![]).is_err());
        assert!(plan.execute(vec![Literal::vec1(&[1.0f32, 2.0, 3.0])]).is_err());
        assert!(plan.execute(vec![Literal::vec1(&[1u32, 2])]).is_err());
        assert!(plan.execute(vec![Literal::vec1(&[1.0f32, -2.0])]).is_ok());
    }
}

