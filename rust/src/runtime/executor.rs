//! PJRT executor: loads HLO-text artifacts, compiles them once (cached,
//! which also caches the planned engine's output buffers across steps),
//! and executes them with host tensors. HLO *text* is the interchange
//! format (see DESIGN.md / /opt/xla-example/README.md): jax >= 0.5 emits
//! serialized protos with 64-bit ids that xla_extension 0.5.1 rejects;
//! the text parser reassigns ids.

#![warn(missing_docs)]

use std::cell::RefCell;
use std::collections::BTreeMap;

use anyhow::{anyhow, Context, Result};

use crate::runtime::artifact::{ArtifactSpec, Registry};
use crate::runtime::literal::{to_literal, HostTensor};
// the in-crate PJRT/XLA stand-in; see its module docs for swapping in
// real bindings
use crate::runtime::xla;

/// Artifact executor: a PJRT-shaped client plus a per-artifact compile
/// cache. Compiling an artifact builds its execution plan once; the
/// plan's buffers then persist across every `run` of that artifact.
pub struct Executor {
    /// The backend client (interpreter-backed by default).
    pub client: xla::PjRtClient,
    cache: RefCell<BTreeMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>>>,
}

impl Executor {
    /// Build an executor on the CPU (interpreter-backed) client.
    pub fn cpu() -> Result<Executor> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Executor {
            client,
            cache: RefCell::new(BTreeMap::new()),
        })
    }

    /// Compile (or fetch from cache) an artifact.
    pub fn compile(&self, spec: &ArtifactSpec) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(&spec.name) {
            return Ok(exe.clone());
        }
        let path = spec
            .file
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 artifact path"))?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parse {path}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e:?}", spec.name))?;
        let exe = std::rc::Rc::new(exe);
        {
            use crate::util::metrics::{self, MetricId};
            metrics::counter(MetricId::ExecutorCompilesTotal, 1);
            let (bufs, slots) = exe.buffer_stats();
            metrics::counter(MetricId::PlanBuffersTotal, bufs as u64);
            metrics::counter(MetricId::PlanBufferSlotsTotal, slots as u64);
        }
        self.cache
            .borrow_mut()
            .insert(spec.name.clone(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact with host inputs; returns host f32 outputs in
    /// manifest order. Inputs are validated against the manifest spec.
    pub fn run(&self, spec: &ArtifactSpec, inputs: &[HostTensor]) -> Result<Vec<Vec<f32>>> {
        self.run_with(spec, inputs, false)
    }

    /// [`Executor::run`] on the scalar reference walker instead of the
    /// planned engine — bit-identical output by contract; used by the
    /// plan-equivalence tests and the `stepref/*` bench cases.
    pub fn run_ref(&self, spec: &ArtifactSpec, inputs: &[HostTensor]) -> Result<Vec<Vec<f32>>> {
        self.run_with(spec, inputs, true)
    }

    fn run_with(
        &self,
        spec: &ArtifactSpec,
        inputs: &[HostTensor],
        reference: bool,
    ) -> Result<Vec<Vec<f32>>> {
        if inputs.len() != spec.inputs.len() {
            return Err(anyhow!(
                "{}: expected {} inputs, got {}",
                spec.name,
                spec.inputs.len(),
                inputs.len()
            ));
        }
        let exe = self.compile(spec)?;
        crate::util::metrics::counter(crate::util::metrics::MetricId::ExecutorRunsTotal, 1);
        let lits: Vec<xla::Literal> = spec
            .inputs
            .iter()
            .zip(inputs)
            .map(|(s, t)| to_literal(s, t))
            .collect::<Result<_>>()?;
        // owned args + consuming read-back: the state tensors are not
        // re-copied on the way in or out of the backend
        let result = if reference {
            exe.execute_ref_owned(lits)
        } else {
            exe.execute_owned(lits)
        }
        .map_err(|e| anyhow!("execute {}: {e:?}", spec.name))?;
        let buf = result
            .into_iter()
            .next()
            .and_then(|r| r.into_iter().next())
            .ok_or_else(|| anyhow!("{}: no output buffer", spec.name))?;
        let root = buf.into_literal();
        // aot.py lowers with return_tuple=True: the root is one tuple.
        let parts = root
            .into_tuple()
            .map_err(|e| anyhow!("untuple: {e:?}"))?;
        if parts.len() != spec.outputs.len() {
            return Err(anyhow!(
                "{}: expected {} outputs, got {}",
                spec.name,
                spec.outputs.len(),
                parts.len()
            ));
        }
        parts
            .into_iter()
            .map(|l| l.into_vec::<f32>().map_err(|e| anyhow!("output to f32: {e:?}")))
            .collect()
    }

    /// Convenience: run an artifact by name from a registry.
    pub fn run_named(
        &self,
        reg: &Registry,
        name: &str,
        inputs: &[HostTensor],
    ) -> Result<Vec<Vec<f32>>> {
        let spec = reg.artifact(name)?;
        self.run(spec, inputs)
            .with_context(|| format!("running artifact {name}"))
    }

    /// Number of artifacts compiled into the cache so far.
    pub fn cached_count(&self) -> usize {
        self.cache.borrow().len()
    }
}

/// A `Send`-able recipe for building per-stage/per-worker executors.
///
/// `Executor` itself is deliberately thread-local (`Rc`/`RefCell`
/// compile cache), so pipeline workers can't share one handle. This
/// spec carries everything needed to rebuild an equivalent executor on
/// another thread: the artifact names to precompile eagerly (so
/// compile cost lands at worker startup, not mid-pipeline) and the
/// planned-engine thread count to pin. Plan execution is bit-identical
/// across executor instances and thread counts by the planned-engine
/// contract, so handing each worker its own executor does not affect
/// results.
#[derive(Clone, Debug)]
pub struct StageExecSpec {
    /// Artifact names compiled eagerly by [`StageExecSpec::build`].
    pub precompile: Vec<String>,
    /// Planned-engine worker threads per executable (`0` = backend
    /// default).
    pub plan_threads: usize,
}

impl StageExecSpec {
    /// Recipe that precompiles the given artifacts with default plan
    /// threading.
    pub fn new(precompile: Vec<String>) -> StageExecSpec {
        StageExecSpec {
            precompile,
            plan_threads: 0,
        }
    }

    /// Build a fresh thread-local executor and precompile the recipe's
    /// artifacts from `reg`.
    pub fn build(&self, reg: &Registry) -> Result<Executor> {
        let exec = Executor::cpu()?;
        for name in &self.precompile {
            let spec = reg.artifact(name)?;
            let exe = exec
                .compile(spec)
                .with_context(|| format!("precompiling {name}"))?;
            if self.plan_threads > 0 {
                exe.set_threads(self.plan_threads);
            }
        }
        Ok(exec)
    }
}
