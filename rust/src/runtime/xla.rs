//! Minimal in-crate stand-in for the `xla` PJRT bindings.
//!
//! The crate must stay dependency-free (ROADMAP: `anyhow` only), and the
//! real `xla_extension` bindings are not installable in every build
//! environment — so this module mirrors the exact API surface
//! `runtime::{executor, literal}` consume, and the use sites import it
//! as `use crate::runtime::xla;`. Swapping in real bindings is a
//! one-line change at each use site (drop that import so the extern
//! crate resolves) plus the Cargo dependency.
//!
//! Host-side pieces ([`Literal`]) are fully functional: they carry typed
//! data + dims, so literal packing/reshaping and its unit tests behave
//! exactly like the real thing. Backend pieces (HLO parsing, PJRT
//! compile/execute) report [`XlaError`] at *runtime*; the artifact-gated
//! integration tests, benches and experiments already skip or error
//! cleanly when no artifact manifest is present, so a missing backend
//! degrades to "runtime unavailable", never a build failure.

/// Error type of the backend surface; rendered with `{:?}` at use sites.
#[derive(Clone)]
pub struct XlaError(pub String);

impl std::fmt::Debug for XlaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::fmt::Display for XlaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

fn unavailable(what: &str) -> XlaError {
    XlaError(format!(
        "XLA backend is not linked into this build: {what} unavailable \
         (see rust/src/runtime/xla.rs for how to swap in real bindings)"
    ))
}

// ------------------------------------------------------------ literals

#[derive(Clone, Debug)]
enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U32(Vec<u32>),
    Tuple(Vec<Literal>),
}

/// Typed host tensor with dims — the functional half of the stub.
#[derive(Clone, Debug)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

/// Element types `Literal` can carry (the three the artifacts use).
pub trait NativeType: Sized {
    fn wrap(v: &[Self]) -> Data;
    fn unwrap(d: &Data) -> Option<Vec<Self>>;
}

macro_rules! native {
    ($t:ty, $arm:ident) => {
        impl NativeType for $t {
            fn wrap(v: &[Self]) -> Data {
                Data::$arm(v.to_vec())
            }
            fn unwrap(d: &Data) -> Option<Vec<Self>> {
                match d {
                    Data::$arm(v) => Some(v.clone()),
                    _ => None,
                }
            }
        }
    };
}

native!(f32, F32);
native!(i32, I32);
native!(u32, U32);

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal {
            dims: vec![v.len() as i64],
            data: T::wrap(v),
        }
    }

    /// Tuple literal from parts (the root shape of every AOT artifact).
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal {
            dims: vec![parts.len() as i64],
            data: Data::Tuple(parts),
        }
    }

    fn numel(&self) -> i64 {
        match &self.data {
            Data::F32(v) => v.len() as i64,
            Data::I32(v) => v.len() as i64,
            Data::U32(v) => v.len() as i64,
            Data::Tuple(_) => 0,
        }
    }

    /// Same data, new dims; errors when the element counts disagree.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, XlaError> {
        let n: i64 = dims.iter().product();
        if n != self.numel() {
            return Err(XlaError(format!(
                "reshape: {} elements into dims {dims:?}",
                self.numel()
            )));
        }
        Ok(Literal {
            data: self.data.clone(),
            dims: dims.to_vec(),
        })
    }

    /// Read back the host data (element type must match).
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, XlaError> {
        T::unwrap(&self.data).ok_or_else(|| XlaError("literal element type mismatch".into()))
    }

    /// Decompose a tuple literal into its parts.
    pub fn to_tuple(&self) -> Result<Vec<Literal>, XlaError> {
        match &self.data {
            Data::Tuple(parts) => Ok(parts.clone()),
            _ => Err(XlaError("literal is not a tuple".into())),
        }
    }
}

// ------------------------------------------------------------- backend

/// Parsed HLO module (backend-only; parsing needs the real bindings).
pub struct HloModuleProto {
    _p: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, XlaError> {
        Err(unavailable("HLO text parsing"))
    }
}

pub struct XlaComputation {
    _p: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _p: () }
    }
}

pub struct PjRtClient {
    _p: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        Err(unavailable("PJRT CPU client"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(unavailable("PJRT compilation"))
    }
}

pub struct PjRtLoadedExecutable {
    _p: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(unavailable("PJRT execution"))
    }
}

pub struct PjRtBuffer {
    _p: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(unavailable("device-to-host transfer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3]).is_err());
        assert!(l.to_vec::<u32>().is_err(), "typed read-back must not cast");
        assert!(l.to_tuple().is_err());
    }

    #[test]
    fn tuple_literals_decompose() {
        let t = Literal::tuple(vec![
            Literal::vec1(&[1.0f32]),
            Literal::vec1(&[7u32, 8]),
        ]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[1].to_vec::<u32>().unwrap(), vec![7, 8]);
        assert!(t.to_vec::<f32>().is_err());
    }

    #[test]
    fn backend_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
