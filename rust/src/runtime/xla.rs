//! In-crate stand-in for the `xla` PJRT bindings, backed by the
//! pure-Rust HLO interpreter (`runtime::interp`) and its planned
//! execution engine (`runtime::plan`).
//!
//! The crate must stay dependency-free (ROADMAP: `anyhow` only), and the
//! real `xla_extension` bindings are not installable in every build
//! environment — so this module mirrors the exact API surface
//! `runtime::{executor, literal}` consume, and the use sites import it
//! as `use crate::runtime::xla;`. The backend half is *functional*:
//! `HloModuleProto::from_text_file` parses HLO text,
//! `PjRtClient::compile` builds the planned execution engine once
//! (fused elementwise chains, threaded `dot`, cached buffers), and
//! `PjRtLoadedExecutable::execute` runs it — so the NN-scale trainer
//! and every artifact-gated test run end-to-end with `cargo` alone.
//! The scalar reference walker stays reachable through
//! [`PjRtLoadedExecutable::execute_ref_owned`] for golden and
//! equivalence tests.
//!
//! Swapping in real PJRT bindings stays a drop-in change: add the
//! `xla` crate to Cargo.toml and drop the `use crate::runtime::xla;`
//! import at each use site (executor.rs, literal.rs) so the extern
//! crate resolves; nothing else in the runtime knows which backend it
//! is talking to. See DESIGN.md "HLO interpreter fallback" and
//! "planned interpreter execution" for the numeric contracts.

#![warn(missing_docs)]

use crate::runtime::interp;
use crate::runtime::plan::Plan;

/// Error type of the backend surface; rendered with `{:?}` at use sites.
#[derive(Clone)]
pub struct XlaError(#[doc = "Backend error message."] pub String);

impl std::fmt::Debug for XlaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::fmt::Display for XlaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

// ------------------------------------------------------------ literals

#[derive(Clone, Debug)]
pub(crate) enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U32(Vec<u32>),
    Pred(Vec<bool>),
    Tuple(Vec<Literal>),
}

/// Typed host tensor with dims — shared by the host-side helpers and
/// the interpreter (`pred` is interpreter-internal: artifacts never
/// return it).
#[derive(Clone, Debug)]
pub struct Literal {
    pub(crate) data: Data,
    pub(crate) dims: Vec<i64>,
}

/// Element types `Literal` can carry across the API (the three the
/// artifacts use).
pub trait NativeType: Sized {
    /// Wrap a host slice into the matching [`Literal`] storage arm.
    fn wrap(v: &[Self]) -> Data;
    /// Copy the data out if the storage arm matches this type.
    fn unwrap(d: &Data) -> Option<Vec<Self>>;
    /// Move the data out if the storage arm matches this type.
    fn unwrap_owned(d: Data) -> Option<Vec<Self>>;
}

macro_rules! native {
    ($t:ty, $arm:ident) => {
        impl NativeType for $t {
            fn wrap(v: &[Self]) -> Data {
                Data::$arm(v.to_vec())
            }
            fn unwrap(d: &Data) -> Option<Vec<Self>> {
                match d {
                    Data::$arm(v) => Some(v.clone()),
                    _ => None,
                }
            }
            fn unwrap_owned(d: Data) -> Option<Vec<Self>> {
                match d {
                    Data::$arm(v) => Some(v),
                    _ => None,
                }
            }
        }
    };
}

native!(f32, F32);
native!(i32, I32);
native!(u32, U32);

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal {
            dims: vec![v.len() as i64],
            data: T::wrap(v),
        }
    }

    /// Tuple literal from parts (the root shape of every AOT artifact).
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal {
            dims: vec![parts.len() as i64],
            data: Data::Tuple(parts),
        }
    }

    /// Dimensions of the literal.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    fn numel(&self) -> i64 {
        match &self.data {
            Data::F32(v) => v.len() as i64,
            Data::I32(v) => v.len() as i64,
            Data::U32(v) => v.len() as i64,
            Data::Pred(v) => v.len() as i64,
            Data::Tuple(_) => 0,
        }
    }

    /// Same data, new dims; errors when the element counts disagree.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, XlaError> {
        let n: i64 = dims.iter().product();
        if n != self.numel() {
            return Err(XlaError(format!(
                "reshape: {} elements into dims {dims:?}",
                self.numel()
            )));
        }
        Ok(Literal {
            data: self.data.clone(),
            dims: dims.to_vec(),
        })
    }

    /// Read back the host data (element type must match).
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, XlaError> {
        T::unwrap(&self.data).ok_or_else(|| XlaError("literal element type mismatch".into()))
    }

    /// Consuming read-back: moves the host data out without a copy (the
    /// executor's per-step output path).
    pub fn into_vec<T: NativeType>(self) -> Result<Vec<T>, XlaError> {
        T::unwrap_owned(self.data)
            .ok_or_else(|| XlaError("literal element type mismatch".into()))
    }

    /// Decompose a tuple literal into its parts.
    pub fn to_tuple(&self) -> Result<Vec<Literal>, XlaError> {
        match &self.data {
            Data::Tuple(parts) => Ok(parts.clone()),
            _ => Err(XlaError("literal is not a tuple".into())),
        }
    }

    /// Consuming variant of [`Literal::to_tuple`] (no copy of the
    /// parts — the executor's per-step hot path).
    pub fn into_tuple(self) -> Result<Vec<Literal>, XlaError> {
        match self.data {
            Data::Tuple(parts) => Ok(parts),
            _ => Err(XlaError("literal is not a tuple".into())),
        }
    }
}

// ------------------------------------------------------------- backend

/// Parsed HLO module (interpreter-backed).
pub struct HloModuleProto {
    module: std::rc::Rc<interp::HloModule>,
}

impl HloModuleProto {
    /// Parse an HLO-text artifact from disk.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto, XlaError> {
        let src = std::fs::read_to_string(path)
            .map_err(|e| XlaError(format!("reading {path}: {e}")))?;
        Self::from_text(&src)
    }

    /// Parse HLO text from memory (tests and tools).
    pub fn from_text(src: &str) -> Result<HloModuleProto, XlaError> {
        Ok(HloModuleProto {
            module: std::rc::Rc::new(interp::parse(src)?),
        })
    }
}

/// Computation handle passed from `from_proto` to `compile` (mirrors
/// the PJRT API shape).
pub struct XlaComputation {
    module: std::rc::Rc<interp::HloModule>,
}

impl XlaComputation {
    /// Wrap a parsed module as a compilable computation.
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {
            module: proto.module.clone(),
        }
    }
}

/// The interpreter-backed "device" client.
pub struct PjRtClient {
    _p: (),
}

impl PjRtClient {
    /// The interpreter "device" is always available.
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        Ok(PjRtClient { _p: () })
    }

    /// Compile a computation: builds the planned execution engine once
    /// (instruction program, fusion groups, buffer plan). Shape or
    /// dtype inconsistencies in the module surface here rather than at
    /// execute time.
    ///
    /// Debug builds additionally run the static plan verifier
    /// ([`crate::runtime::verify`]) over the result; release builds do
    /// the same when `RIDER_VERIFY` is set to anything but `0`.
    pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        let plan = Plan::new(comp.module.clone())?;
        let verify_on = cfg!(debug_assertions)
            || std::env::var_os("RIDER_VERIFY").is_some_and(|v| v != "0");
        if verify_on {
            crate::runtime::verify::verify_plan(&plan)
                .map_err(|e| XlaError(format!("plan verification failed: {e}")))?;
        }
        Ok(PjRtLoadedExecutable {
            module: comp.module.clone(),
            plan,
        })
    }
}

/// A compiled executable: the parsed module plus its execution plan
/// (whose output buffers are cached across `execute` calls).
pub struct PjRtLoadedExecutable {
    module: std::rc::Rc<interp::HloModule>,
    plan: Plan,
}

impl PjRtLoadedExecutable {
    /// Run the module on the planned engine. Mirrors the PJRT shape:
    /// one replica, one output buffer holding the root (tuple) literal.
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        self.execute_owned(args.iter().map(|a| a.borrow().clone()).collect())
    }

    /// Owned-argument variant (the executor hot path: avoids
    /// re-copying every state tensor on every training step).
    pub fn execute_owned(&self, args: Vec<Literal>) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        let root = self.plan.execute(args)?;
        Ok(vec![vec![PjRtBuffer { literal: root }]])
    }

    /// Run on the scalar reference walker instead of the plan — the
    /// equivalence oracle (`rust/tests/plan_equivalence.rs`) and the
    /// `stepref/*` bench cases. Bit-identical to [`Self::execute_owned`]
    /// by contract, just slower.
    pub fn execute_ref_owned(&self, args: Vec<Literal>) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        let root = interp::execute_ref(&self.module, args)?;
        Ok(vec![vec![PjRtBuffer { literal: root }]])
    }

    /// Buffer-assignment summary of the compiled plan: (planned output
    /// buffers, buffer-backed value slots). See [`Plan::buffer_stats`].
    pub fn buffer_stats(&self) -> (usize, usize) {
        self.plan.buffer_stats()
    }

    /// Override the plan's `dot` worker-thread budget (testing hook;
    /// results are bit-identical for every setting).
    pub fn set_threads(&self, n: usize) {
        self.plan.set_threads(n);
    }
}

/// One device output buffer (host-resident here).
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    /// Copy the buffer out as a literal.
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Ok(self.literal.clone())
    }

    /// Consuming read-back (no copy).
    pub fn into_literal(self) -> Literal {
        self.literal
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3]).is_err());
        assert!(l.to_vec::<u32>().is_err(), "typed read-back must not cast");
        assert!(l.to_tuple().is_err());
    }

    #[test]
    fn tuple_literals_decompose() {
        let t = Literal::tuple(vec![
            Literal::vec1(&[1.0f32]),
            Literal::vec1(&[7u32, 8]),
        ]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[1].to_vec::<u32>().unwrap(), vec![7, 8]);
        assert!(t.to_vec::<f32>().is_err());
    }

    #[test]
    fn backend_compiles_and_executes_hlo_text() {
        let proto = HloModuleProto::from_text(
            "HloModule t\n\nENTRY %main (p0: f32[2]) -> (f32[2]) {\n  \
             %p0 = f32[2] parameter(0)\n  %n = f32[2] negate(%p0)\n  \
             ROOT %t = (f32[2]) tuple(%n)\n}\n",
        )
        .expect("parse");
        let comp = XlaComputation::from_proto(&proto);
        let client = PjRtClient::cpu().expect("client");
        let exe = client.compile(&comp).expect("compile");
        let out = exe
            .execute::<Literal>(&[Literal::vec1(&[1.0f32, -2.0])])
            .expect("execute");
        let root = out[0][0].to_literal_sync().unwrap();
        let parts = root.to_tuple().unwrap();
        assert_eq!(parts[0].to_vec::<f32>().unwrap(), vec![-1.0, 2.0]);
    }

    #[test]
    fn missing_artifact_file_errors() {
        assert!(HloModuleProto::from_text_file("does_not_exist.hlo.txt").is_err());
    }
}
