//! Host array <-> xla::Literal conversion helpers.

#![warn(missing_docs)]

use anyhow::{anyhow, Result};

use crate::runtime::artifact::{Dtype, IoSpec};
// the in-crate PJRT/XLA stand-in; see its module docs for swapping in
// real bindings
use crate::runtime::xla;

/// A host-side tensor matching an IoSpec.
#[derive(Clone, Debug)]
pub enum HostTensor {
    /// 32-bit float data.
    F32(Vec<f32>),
    /// 32-bit signed integer data (labels).
    I32(Vec<i32>),
    /// 32-bit unsigned integer data (PRNG keys, counters).
    U32(Vec<u32>),
}

impl HostTensor {
    /// Number of elements.
    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32(v) => v.len(),
            HostTensor::I32(v) => v.len(),
            HostTensor::U32(v) => v.len(),
        }
    }

    /// Whether the tensor has no elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Borrow the data as f32 (errors on other element types).
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32(v) => Ok(v),
            _ => Err(anyhow!("tensor is not f32")),
        }
    }
}

/// Build a Literal of the given spec's shape/dtype from host data.
pub fn to_literal(spec: &IoSpec, t: &HostTensor) -> Result<xla::Literal> {
    if t.len() != spec.numel() {
        return Err(anyhow!(
            "{}: expected {} elements, got {}",
            spec.name,
            spec.numel(),
            t.len()
        ));
    }
    let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
    let lit = match (spec.dtype, t) {
        (Dtype::F32, HostTensor::F32(v)) => xla::Literal::vec1(v),
        (Dtype::I32, HostTensor::I32(v)) => xla::Literal::vec1(v),
        (Dtype::U32, HostTensor::U32(v)) => xla::Literal::vec1(v),
        _ => return Err(anyhow!("{}: dtype mismatch", spec.name)),
    };
    Ok(lit.reshape(&dims)?)
}

/// Extract f32 data from a literal (any shape).
pub fn to_f32_vec(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Extract a scalar f32.
pub fn scalar_f32(lit: &xla::Literal) -> Result<f32> {
    let v = lit.to_vec::<f32>()?;
    v.first()
        .copied()
        .ok_or_else(|| anyhow!("empty literal for scalar"))
}

/// PRNG key literal (uint32[2]) from a u64 counter.
pub fn key_literal(counter: u64) -> Result<xla::Literal> {
    let k = [(counter >> 32) as u32, counter as u32];
    Ok(xla::Literal::vec1(&k).reshape(&[2])?)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(shape: &[usize], dtype: Dtype) -> IoSpec {
        IoSpec {
            name: "t".into(),
            shape: shape.to_vec(),
            dtype,
        }
    }

    #[test]
    fn roundtrip_f32() {
        let s = spec(&[2, 3], Dtype::F32);
        let data = HostTensor::F32(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let lit = to_literal(&s, &data).unwrap();
        assert_eq!(to_f32_vec(&lit).unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let s = spec(&[4], Dtype::F32);
        assert!(to_literal(&s, &HostTensor::F32(vec![1.0])).is_err());
    }

    #[test]
    fn dtype_mismatch_rejected() {
        let s = spec(&[1], Dtype::I32);
        assert!(to_literal(&s, &HostTensor::F32(vec![1.0])).is_err());
    }

    #[test]
    fn key_literal_packs_counter() {
        let lit = key_literal(0x1234_5678_9ABC_DEF0).unwrap();
        let v = lit.to_vec::<u32>().unwrap();
        assert_eq!(v, vec![0x1234_5678, 0x9ABC_DEF0]);
    }
}
