//! Analog IO chain (paper Appendix F Table 7): DAC input quantization
//! with ABS_MAX noise management, crossbar MVM, ADC read noise + output
//! quantization + clipping. Mirrors `kernels/analog_mvm.py` (parity-
//! tested on the shared vectors in artifacts/parity.json).

use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct IoChain {
    pub inp_res: f32,
    pub out_res: f32,
    pub out_bound: f32,
    pub out_noise: f32,
}

impl Default for IoChain {
    fn default() -> Self {
        Self {
            inp_res: 1.0 / 127.0, // 7-bit DAC
            out_res: 1.0 / 511.0, // 9-bit ADC
            out_bound: 12.0,
            out_noise: 0.06,
        }
    }
}

impl IoChain {
    pub fn ideal() -> Self {
        Self {
            inp_res: 1e-9,
            out_res: 1e-9,
            out_bound: 1e9,
            out_noise: 0.0,
        }
    }

    /// y[b,n] = x[b,k] @ w[k,n] through the analog chain.
    /// `deterministic` drops read noise (quantization stays).
    pub fn mvm(
        &self,
        x: &[f32],
        w: &[f32],
        b: usize,
        k: usize,
        n: usize,
        rng: &mut Rng,
        deterministic: bool,
    ) -> Vec<f32> {
        assert_eq!(x.len(), b * k);
        assert_eq!(w.len(), k * n);
        let mut out = vec![0.0f32; b * n];
        let mut xq = vec![0.0f32; k];
        for bi in 0..b {
            let row = &x[bi * k..(bi + 1) * k];
            // ABS_MAX noise management
            let mut scale = 0.0f32;
            for &v in row {
                scale = scale.max(v.abs());
            }
            let scale = if scale > 0.0 { scale } else { 1.0 };
            // DAC quantization
            for (j, &v) in row.iter().enumerate() {
                xq[j] = ((v / scale) / self.inp_res).round() * self.inp_res;
            }
            // crossbar (Kirchhoff summation)
            let orow = &mut out[bi * n..(bi + 1) * n];
            for (j, &xv) in xq.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                let wrow = &w[j * n..(j + 1) * n];
                for (o, &wv) in orow.iter_mut().zip(wrow) {
                    *o += xv * wv;
                }
            }
            // ADC: noise, quantization, bound, undo scaling
            for o in orow.iter_mut() {
                let mut y = *o;
                if !deterministic && self.out_noise > 0.0 {
                    y += self.out_noise * rng.normal() as f32;
                }
                y = (y / self.out_res).round() * self.out_res;
                y = y.clamp(-self.out_bound, self.out_bound);
                *o = y * scale;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn close_to_ideal_matmul() {
        let io = IoChain::default();
        let mut rng = Rng::from_seed(5);
        let (b, k, n) = (4, 16, 8);
        let x: Vec<f32> = (0..b * k).map(|i| ((i * 37 % 17) as f32 - 8.0) / 8.0).collect();
        let w: Vec<f32> = (0..k * n).map(|i| ((i * 53 % 13) as f32 - 6.0) / 13.0).collect();
        let y = io.mvm(&x, &w, b, k, n, &mut rng, true);
        // ideal
        for bi in 0..b {
            for ni in 0..n {
                let mut s = 0.0f32;
                for ki in 0..k {
                    s += x[bi * k + ki] * w[ki * n + ni];
                }
                assert!((y[bi * n + ni] - s).abs() < 0.1, "{} vs {}", y[bi * n + ni], s);
            }
        }
    }

    #[test]
    fn zero_rows_safe() {
        let io = IoChain::default();
        let mut rng = Rng::from_seed(1);
        let y = io.mvm(&[0.0; 8], &[1.0; 8], 1, 8, 1, &mut rng, true);
        assert_eq!(y[0], 0.0);
    }

    #[test]
    fn output_bound_clips() {
        let io = IoChain {
            out_bound: 0.5,
            ..IoChain::default()
        };
        let mut rng = Rng::from_seed(1);
        let y = io.mvm(&[1.0; 4], &[1.0; 4], 1, 4, 1, &mut rng, true);
        // scale = 1, raw product = 4 -> clipped to 0.5
        assert!((y[0] - 0.5).abs() < 1e-6);
    }
}
