//! Analog IO chain (paper Appendix F Table 7): DAC input quantization
//! with ABS_MAX noise management, crossbar MVM, ADC read noise + output
//! quantization + clipping. Mirrors `kernels/analog_mvm.py` (parity-
//! tested on the shared vectors in artifacts/parity.json).

use crate::util::rng::Rng;

/// Peripheral circuit parameters of one crossbar MVM: DAC input
/// resolution, ADC output resolution/bound, and ADC read noise.
#[derive(Clone, Debug)]
pub struct IoChain {
    /// DAC input quantization step (1/127 ≙ 7-bit).
    pub inp_res: f32,
    /// ADC output quantization step (1/511 ≙ 9-bit).
    pub out_res: f32,
    /// ADC output clipping bound (pre-rescale units).
    pub out_bound: f32,
    /// ADC read-noise std (pre-rescale units).
    pub out_noise: f32,
    /// Injected ADC fault: constant output offset (pre-rescale units;
    /// 0 = healthy). Armed by the fault layer (`device/fault.rs`).
    pub adc_offset: f32,
    /// Injected ADC fault: early saturation bound tighter than
    /// `out_bound` (`f32::INFINITY` = healthy).
    pub adc_sat: f32,
}

impl Default for IoChain {
    fn default() -> Self {
        Self {
            inp_res: 1.0 / 127.0, // 7-bit DAC
            out_res: 1.0 / 511.0, // 9-bit ADC
            out_bound: 12.0,
            out_noise: 0.06,
            adc_offset: 0.0,
            adc_sat: f32::INFINITY,
        }
    }
}

impl IoChain {
    /// A noiseless, effectively-unquantized chain (digital-parity
    /// sanity checks).
    pub fn ideal() -> Self {
        Self {
            inp_res: 1e-9,
            out_res: 1e-9,
            out_bound: 1e9,
            out_noise: 0.0,
            adc_offset: 0.0,
            adc_sat: f32::INFINITY,
        }
    }

    /// Whether an ADC fault is armed on this chain.
    pub fn adc_faulty(&self) -> bool {
        self.adc_offset != 0.0 || self.adc_sat.is_finite()
    }

    /// Reset the injected ADC fault fields to healthy.
    pub fn clear_faults(&mut self) {
        self.adc_offset = 0.0;
        self.adc_sat = f32::INFINITY;
    }

    /// y[b,n] = x[b,k] @ w[k,n] through the analog chain.
    /// `deterministic` drops read noise (quantization stays).
    /// Allocating wrapper over [`IoChain::mvm_into`].
    pub fn mvm(
        &self,
        x: &[f32],
        w: &[f32],
        b: usize,
        k: usize,
        n: usize,
        rng: &mut Rng,
        deterministic: bool,
    ) -> Vec<f32> {
        let mut out = vec![0.0f32; b * n];
        let mut xq = vec![0.0f32; k];
        self.mvm_into(x, w, b, k, n, rng, deterministic, &mut out, &mut xq);
        out
    }

    /// Allocation-free MVM into caller-owned scratch: `out` receives
    /// the `b x n` result (overwritten), `xq` is the DAC staging buffer
    /// (length `k`). Bit-identical to [`IoChain::mvm`] — the tiled
    /// partial-sum path uses this to stop allocating two `Vec`s per
    /// tile per call.
    #[allow(clippy::too_many_arguments)]
    pub fn mvm_into(
        &self,
        x: &[f32],
        w: &[f32],
        b: usize,
        k: usize,
        n: usize,
        rng: &mut Rng,
        deterministic: bool,
        out: &mut [f32],
        xq: &mut [f32],
    ) {
        assert_eq!(x.len(), b * k);
        assert_eq!(w.len(), k * n);
        assert_eq!(out.len(), b * n);
        assert_eq!(xq.len(), k);
        out.fill(0.0);
        let faulty = self.adc_faulty();
        for bi in 0..b {
            let row = &x[bi * k..(bi + 1) * k];
            // ABS_MAX noise management
            let mut scale = 0.0f32;
            for &v in row {
                scale = scale.max(v.abs());
            }
            let scale = if scale > 0.0 { scale } else { 1.0 };
            // DAC quantization
            for (j, &v) in row.iter().enumerate() {
                xq[j] = ((v / scale) / self.inp_res).round() * self.inp_res;
            }
            // crossbar (Kirchhoff summation)
            let orow = &mut out[bi * n..(bi + 1) * n];
            for (j, &xv) in xq.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                let wrow = &w[j * n..(j + 1) * n];
                for (o, &wv) in orow.iter_mut().zip(wrow) {
                    *o += xv * wv;
                }
            }
            // ADC: batch-sampled read noise (distribution-stable with
            // the old per-element scalar draw), then quantization,
            // bound, undo scaling
            if !deterministic && self.out_noise > 0.0 {
                rng.add_normal_f32(orow, self.out_noise);
            }
            for o in orow.iter_mut() {
                let mut y = (*o / self.out_res).round() * self.out_res;
                // injected ADC fault (offset / early saturation):
                // branch-guarded so a healthy chain stays bit-identical
                if faulty {
                    y = (y + self.adc_offset).clamp(-self.adc_sat, self.adc_sat);
                }
                *o = y.clamp(-self.out_bound, self.out_bound) * scale;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn close_to_ideal_matmul() {
        let io = IoChain::default();
        let mut rng = Rng::from_seed(5);
        let (b, k, n) = (4, 16, 8);
        let x: Vec<f32> = (0..b * k).map(|i| ((i * 37 % 17) as f32 - 8.0) / 8.0).collect();
        let w: Vec<f32> = (0..k * n).map(|i| ((i * 53 % 13) as f32 - 6.0) / 13.0).collect();
        let y = io.mvm(&x, &w, b, k, n, &mut rng, true);
        // ideal
        for bi in 0..b {
            for ni in 0..n {
                let mut s = 0.0f32;
                for ki in 0..k {
                    s += x[bi * k + ki] * w[ki * n + ni];
                }
                assert!((y[bi * n + ni] - s).abs() < 0.1, "{} vs {}", y[bi * n + ni], s);
            }
        }
    }

    #[test]
    fn zero_rows_safe() {
        let io = IoChain::default();
        let mut rng = Rng::from_seed(1);
        let y = io.mvm(&[0.0; 8], &[1.0; 8], 1, 8, 1, &mut rng, true);
        assert_eq!(y[0], 0.0);
    }

    #[test]
    fn adc_noise_mean_and_variance_pinned() {
        // the batched ADC noise must stay N(0, out_noise²) in
        // pre-rescale units: the empirical mean matches the
        // deterministic output and the variance is (out_noise·scale)²
        // (quantization at 1/511 contributes negligibly)
        let io = IoChain::default();
        let mut rng = Rng::from_seed(33);
        let k = 8;
        let x = vec![0.5f32; k]; // ABS_MAX scale = 0.5
        let w: Vec<f32> = (0..k).map(|i| 0.05 * (i as f32 + 1.0)).collect();
        let det = io.mvm(&x, &w, 1, k, 1, &mut rng, true)[0] as f64;
        let trials = 4000;
        let (mut s, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..trials {
            let y = io.mvm(&x, &w, 1, k, 1, &mut rng, false)[0] as f64;
            s += y;
            s2 += y * y;
        }
        let mean = s / trials as f64;
        let var = s2 / trials as f64 - mean * mean;
        let want_var = (io.out_noise as f64 * 0.5).powi(2);
        assert!((mean - det).abs() < 0.005, "mean {mean} vs det {det}");
        assert!(
            (var - want_var).abs() < 0.15 * want_var,
            "var {var} vs {want_var}"
        );
    }

    #[test]
    fn mvm_into_bit_identical_to_mvm() {
        let io = IoChain::default();
        let (b, k, n) = (3, 16, 8);
        let x: Vec<f32> = (0..b * k).map(|i| ((i * 37 % 17) as f32 - 8.0) / 8.0).collect();
        let w: Vec<f32> = (0..k * n).map(|i| ((i * 53 % 13) as f32 - 6.0) / 13.0).collect();
        let mut r1 = Rng::from_seed(77);
        let mut r2 = Rng::from_seed(77);
        let y1 = io.mvm(&x, &w, b, k, n, &mut r1, false);
        let mut y2 = vec![1.0f32; b * n]; // stale scratch must be overwritten
        let mut xq = vec![1.0f32; k];
        io.mvm_into(&x, &w, b, k, n, &mut r2, false, &mut y2, &mut xq);
        assert_eq!(y1, y2);
        assert_eq!(r1.next_u64(), r2.next_u64(), "same RNG consumption");
    }

    #[test]
    fn adc_offset_fault_shifts_output() {
        let healthy = IoChain::default();
        let faulty = IoChain {
            adc_offset: 0.25,
            ..IoChain::default()
        };
        let mut rng = Rng::from_seed(2);
        let x = vec![1.0f32; 4]; // scale = 1
        let w = vec![0.1f32; 4];
        let yh = healthy.mvm(&x, &w, 1, 4, 1, &mut rng, true)[0];
        let yf = faulty.mvm(&x, &w, 1, 4, 1, &mut rng, true)[0];
        assert!((yf - yh - 0.25).abs() < 1e-6, "{yf} vs {yh}");
    }

    #[test]
    fn adc_saturation_fault_clips_early() {
        let faulty = IoChain {
            adc_sat: 0.2,
            ..IoChain::default()
        };
        assert!(faulty.adc_faulty());
        let mut rng = Rng::from_seed(2);
        let y = faulty.mvm(&[1.0; 4], &[1.0; 4], 1, 4, 1, &mut rng, true)[0];
        assert!((y - 0.2).abs() < 1e-6, "{y}");
        let mut healed = faulty;
        healed.clear_faults();
        assert!(!healed.adc_faulty());
    }

    #[test]
    fn output_bound_clips() {
        let io = IoChain {
            out_bound: 0.5,
            ..IoChain::default()
        };
        let mut rng = Rng::from_seed(1);
        let y = io.mvm(&[1.0; 4], &[1.0; 4], 1, 4, 1, &mut rng, true);
        // scale = 1, raw product = 4 -> clipped to 0.5
        assert!((y[0] - 0.5).abs() < 1e-6);
    }
}
