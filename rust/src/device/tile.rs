//! Tiled crossbar substrate: a grid of fixed-size [`DeviceArray`] tiles
//! behind the single-slab surface.
//!
//! Real AIMC chips are grids of fixed-size physical tiles with per-tile
//! periphery, not one unbounded array. [`TiledArray`] composes the
//! existing `DeviceArray` kernels into such a grid: each tile owns its
//! own SP map (sampled from its own RNG sub-stream), its own pulse
//! counter, and its own [`IoChain`] periphery. Geometry is described by
//! a params-validated [`TileGeometry`] (default 256×256); edge tiles
//! are ragged when the logical shape does not divide evenly.
//!
//! Determinism contract (pinned by `rust/tests/tiled_equivalence.rs`):
//!
//! * a single-tile `TiledArray` (grid 1×1) passes the caller's RNG
//!   straight through to the underlying `DeviceArray`, so it is
//!   **bit-identical** to a bare `DeviceArray` on every path —
//!   sampling, stochastic and deterministic updates, pulse cycles,
//!   reads and MVMs;
//! * a multi-tile update draws one `base = rng.next_u64()` from the
//!   caller's stream and gives tile `k` the sub-stream
//!   `Rng::new(base, k)` — the same derivation as the row-chunked
//!   parallel path in `device/array.rs` — so results depend only on
//!   the tile geometry, never on the worker-thread count, and the
//!   serial and scoped-thread fan-out paths are bit-identical.
//!
//! The multi-tile residual method (`analog/mtres.rs`) builds on this
//! substrate: one logical weight vector realised as a stack of 1×dim
//! tiles trained on successive residuals and summed at read-out.

use crate::device::array::DeviceArray;
use crate::device::fault::{FaultPlan, FaultState};
use crate::device::io::IoChain;
use crate::device::presets::Preset;
use crate::device::response::SoftBounds;
use crate::util::rng::Rng;

/// Tile-grid geometry: the fixed physical tile shape the logical array
/// is partitioned into. Validated at construction (the sram22-style
/// params-validated component idiom): both dimensions must be nonzero.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TileGeometry {
    /// Rows per physical tile.
    pub tile_rows: usize,
    /// Columns per physical tile.
    pub tile_cols: usize,
}

impl Default for TileGeometry {
    /// The default 256×256 physical tile.
    fn default() -> Self {
        Self { tile_rows: 256, tile_cols: 256 }
    }
}

impl TileGeometry {
    /// Validated constructor: rejects zero-sized tiles with a
    /// descriptive error instead of panicking downstream.
    pub fn new(tile_rows: usize, tile_cols: usize) -> Result<Self, String> {
        if tile_rows == 0 || tile_cols == 0 {
            return Err(format!(
                "tile geometry must be nonzero, got {tile_rows}x{tile_cols}"
            ));
        }
        Ok(Self { tile_rows, tile_cols })
    }

    /// Grid shape (tile-rows, tile-cols) needed to cover a logical
    /// `rows x cols` array; edge tiles are ragged. An empty logical
    /// array still gets one (empty) tile so the single-tile fast path
    /// applies.
    pub fn grid(&self, rows: usize, cols: usize) -> (usize, usize) {
        let up = |n: usize, t: usize| ((n + t - 1) / t).max(1);
        (up(rows, self.tile_rows), up(cols, self.tile_cols))
    }
}

/// A logical crossbar array realised as a grid of [`DeviceArray`]
/// tiles, exposing the single-slab `DeviceArray` surface. See the
/// module docs for the determinism contract.
#[derive(Clone, Debug)]
pub struct TiledArray {
    /// Logical rows of the composed array.
    pub rows: usize,
    /// Logical columns of the composed array.
    pub cols: usize,
    geom: TileGeometry,
    grid_rows: usize,
    grid_cols: usize,
    /// Row-major grid of physical tiles.
    tiles: Vec<DeviceArray>,
    /// Per-tile IO periphery (one chain per tile, like real hardware).
    io: Vec<IoChain>,
    /// Per-tile gather/scatter staging buffers (sized at construction,
    /// so steady-state updates never grow them).
    scratch: Vec<Vec<f32>>,
    /// Worker-thread cap for the fan-out; 0 means use the machine's
    /// available parallelism. Never affects results.
    workers: usize,
    /// Whether updates/reads fan out to scoped threads at all.
    parallel: bool,
}

impl TiledArray {
    /// Per-tile dimensions of tile `k` under `geom` for a logical
    /// `rows x cols` array.
    fn tile_dims(
        geom: &TileGeometry,
        grid_cols: usize,
        rows: usize,
        cols: usize,
        k: usize,
    ) -> (usize, usize) {
        let r0 = (k / grid_cols) * geom.tile_rows;
        let c0 = (k % grid_cols) * geom.tile_cols;
        (
            geom.tile_rows.min(rows - r0.min(rows)),
            geom.tile_cols.min(cols - c0.min(cols)),
        )
    }

    fn assemble(
        rows: usize,
        cols: usize,
        geom: TileGeometry,
        tiles: Vec<DeviceArray>,
    ) -> Self {
        let (grid_rows, grid_cols) = geom.grid(rows, cols);
        debug_assert_eq!(tiles.len(), grid_rows * grid_cols);
        let scratch = tiles.iter().map(|t| vec![0.0f32; t.len()]).collect();
        let io = vec![IoChain::default(); tiles.len()];
        Self {
            rows,
            cols,
            geom,
            grid_rows,
            grid_cols,
            tiles,
            io,
            scratch,
            workers: 0,
            parallel: true,
        }
    }

    /// Sample a tiled array from a preset with a controlled SP
    /// distribution (the [`DeviceArray::sample`] semantics per tile).
    ///
    /// Single-tile grids pass `rng` straight through (bit-identical to
    /// `DeviceArray::sample`); multi-tile grids draw one base value and
    /// give tile `k` the sub-stream `Rng::new(base, k)`.
    #[allow(clippy::too_many_arguments)]
    pub fn sample(
        rows: usize,
        cols: usize,
        geom: TileGeometry,
        preset: &Preset,
        ref_mean: f64,
        ref_std: f64,
        sigma_gamma: f64,
        rng: &mut Rng,
    ) -> Self {
        let (grid_rows, grid_cols) = geom.grid(rows, cols);
        let n_tiles = grid_rows * grid_cols;
        let mut tiles = Vec::with_capacity(n_tiles);
        if n_tiles == 1 {
            tiles.push(DeviceArray::sample(
                rows, cols, preset, ref_mean, ref_std, sigma_gamma, rng,
            ));
        } else {
            let base = rng.next_u64();
            for k in 0..n_tiles {
                let (tr, tc) = Self::tile_dims(&geom, grid_cols, rows, cols, k);
                let mut sub = Rng::new(base, k as u64);
                tiles.push(DeviceArray::sample(
                    tr, tc, preset, ref_mean, ref_std, sigma_gamma, &mut sub,
                ));
            }
        }
        Self::assemble(rows, cols, geom, tiles)
    }

    /// A tiled array where every cell shares one response model (the
    /// [`DeviceArray::uniform`] semantics per tile). Deterministic, so
    /// no sub-stream derivation is involved.
    pub fn uniform(
        rows: usize,
        cols: usize,
        geom: TileGeometry,
        dev: &SoftBounds,
        dw_min: f64,
        c2c: f64,
    ) -> Self {
        let (grid_rows, grid_cols) = geom.grid(rows, cols);
        let tiles = (0..grid_rows * grid_cols)
            .map(|k| {
                let (tr, tc) = Self::tile_dims(&geom, grid_cols, rows, cols, k);
                DeviceArray::uniform(tr, tc, dev, dw_min, c2c)
            })
            .collect();
        Self::assemble(rows, cols, geom, tiles)
    }

    /// Total number of logical cells.
    pub fn len(&self) -> usize {
        self.rows * self.cols
    }

    /// Whether the array holds no cells.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The tile geometry this array was built with.
    pub fn geometry(&self) -> TileGeometry {
        self.geom
    }

    /// Grid shape as (tile-rows, tile-cols).
    pub fn grid_shape(&self) -> (usize, usize) {
        (self.grid_rows, self.grid_cols)
    }

    /// Number of physical tiles in the grid.
    pub fn n_tiles(&self) -> usize {
        self.tiles.len()
    }

    /// Borrow tile `k` (row-major grid order).
    pub fn tile(&self, k: usize) -> &DeviceArray {
        &self.tiles[k]
    }

    /// Mutably borrow tile `k` (row-major grid order) — the seam the
    /// multi-tile residual optimizer trains individual tiles through.
    pub fn tile_mut(&mut self, k: usize) -> &mut DeviceArray {
        &mut self.tiles[k]
    }

    /// Borrow tile `k`'s IO chain.
    pub fn io(&self, k: usize) -> &IoChain {
        &self.io[k]
    }

    /// Install the same IO chain on every tile.
    pub fn set_io(&mut self, io: IoChain) {
        for c in self.io.iter_mut() {
            *c = io.clone();
        }
    }

    /// Total pulses applied across all tiles (pulse accounting).
    pub fn pulse_count(&self) -> u64 {
        self.tiles.iter().map(|t| t.pulse_count).sum()
    }

    /// Arm a [`FaultPlan`] across the grid: tile `k` compiles the plan
    /// against its own SP map with the sub-stream `Rng::new(plan.seed,
    /// k)` — the same derivation as every other per-tile fan-out — and
    /// the plan's ADC fault fields are installed on every tile's IO
    /// chain. Applying the compiled masks consumes no randomness, so
    /// the serial and threaded fan-outs stay bit-identical with faults
    /// armed.
    pub fn arm_faults(&mut self, plan: &FaultPlan) {
        let mut sp = Vec::new();
        for (k, tile) in self.tiles.iter_mut().enumerate() {
            sp.resize(tile.len(), 0.0);
            tile.symmetric_points_into(&mut sp);
            let mut sub = Rng::new(plan.seed, k as u64);
            let st = plan.compile(tile.rows, tile.cols, &sp, -tile.tau_min, tile.tau_max, &mut sub);
            tile.arm_faults(st);
            self.io[k].adc_offset = plan.adc_offset;
            self.io[k].adc_sat = plan.adc_sat;
        }
    }

    /// Disarm every tile's fault mask and heal the IO chains.
    pub fn clear_faults(&mut self) {
        for tile in self.tiles.iter_mut() {
            tile.clear_faults();
        }
        for io in self.io.iter_mut() {
            io.clear_faults();
        }
    }

    /// Tile `k`'s compiled fault mask, if a plan is armed.
    pub fn tile_fault(&self, k: usize) -> Option<&FaultState> {
        self.tiles[k].fault_state()
    }

    /// Per-tile fault status: the indices of tiles whose compiled mask
    /// touches at least one cell (the selective-recalibration work
    /// list of the recovery layer).
    pub fn faulty_tiles(&self) -> Vec<usize> {
        (0..self.tiles.len())
            .filter(|&k| {
                self.tiles[k]
                    .fault_state()
                    .map(|f| !f.is_empty())
                    .unwrap_or(false)
            })
            .collect()
    }

    /// Total number of fault-masked cells across the grid.
    pub fn faulty_cells(&self) -> usize {
        self.tiles
            .iter()
            .filter_map(|t| t.fault_state().map(|f| f.n_faulty()))
            .sum()
    }

    /// Cap the fan-out worker-thread count (0 = available parallelism).
    /// Affects scheduling only — results are identical for any value.
    pub fn set_workers(&mut self, workers: usize) {
        self.workers = workers;
    }

    /// Enable or disable the scoped-thread fan-out. The serial path
    /// derives the same per-tile sub-streams, so results are identical.
    pub fn set_parallel(&mut self, parallel: bool) {
        self.parallel = parallel;
    }

    fn worker_count(&self) -> usize {
        let n = if self.workers > 0 {
            self.workers
        } else {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        };
        n.min(self.tiles.len()).max(1)
    }

    /// Logical (row, col) origin of tile `k`.
    fn tile_origin(&self, k: usize) -> (usize, usize) {
        (
            (k / self.grid_cols) * self.geom.tile_rows,
            (k % self.grid_cols) * self.geom.tile_cols,
        )
    }

    /// Gather the per-tile blocks of a logical row-major `src` into the
    /// per-tile staging buffers.
    fn gather_blocks(&mut self, src: &[f32]) {
        debug_assert_eq!(src.len(), self.len());
        let cols = self.cols;
        for k in 0..self.tiles.len() {
            let (r0, c0) = self.tile_origin(k);
            let (tr, tc) = (self.tiles[k].rows, self.tiles[k].cols);
            let buf = &mut self.scratch[k];
            for lr in 0..tr {
                let s = (r0 + lr) * cols + c0;
                buf[lr * tc..(lr + 1) * tc].copy_from_slice(&src[s..s + tc]);
            }
        }
    }

    /// Scatter every tile's weights into a logical row-major `out`.
    fn scatter_weights(&self, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.len());
        let cols = self.cols;
        for (k, tile) in self.tiles.iter().enumerate() {
            let (r0, c0) = self.tile_origin(k);
            for lr in 0..tile.rows {
                let d = (r0 + lr) * cols + c0;
                out[d..d + tile.cols]
                    .copy_from_slice(&tile.w[lr * tile.cols..(lr + 1) * tile.cols]);
            }
        }
    }

    /// Run `f(tile, staged_block, sub_rng)` over every tile, serially
    /// or bucketed over scoped threads (`k % workers`, like the
    /// row-chunked path in `DeviceArray`). Tile `k` always gets the
    /// sub-stream `Rng::new(base, k)`, so the two schedules — and any
    /// worker count — produce bit-identical results.
    fn fan_out<F>(&mut self, base: u64, f: F)
    where
        F: Fn(&mut DeviceArray, &[f32], &mut Rng) + Sync,
    {
        let workers = self.worker_count();
        if !self.parallel || workers <= 1 {
            for (k, (tile, buf)) in
                self.tiles.iter_mut().zip(self.scratch.iter()).enumerate()
            {
                let mut sub = Rng::new(base, k as u64);
                f(tile, buf.as_slice(), &mut sub);
            }
            return;
        }
        struct Job<'a> {
            idx: u64,
            tile: &'a mut DeviceArray,
            buf: &'a [f32],
        }
        let mut buckets: Vec<Vec<Job>> = (0..workers).map(|_| Vec::new()).collect();
        for (k, (tile, buf)) in
            self.tiles.iter_mut().zip(self.scratch.iter()).enumerate()
        {
            buckets[k % workers].push(Job { idx: k as u64, tile, buf: buf.as_slice() });
        }
        let fr = &f;
        std::thread::scope(|s| {
            for bucket in buckets {
                s.spawn(move || {
                    for job in bucket {
                        let mut sub = Rng::new(base, job.idx);
                        fr(job.tile, job.buf, &mut sub);
                    }
                });
            }
        });
    }

    /// Aggregated analog update (paper Eq. 2) of the logical increment
    /// `dw`, fanned out per tile. Single-tile grids delegate with the
    /// caller's RNG (bit-identical to [`DeviceArray::analog_update`]).
    pub fn analog_update(&mut self, dw: &[f32], rng: &mut Rng) {
        debug_assert_eq!(dw.len(), self.len());
        if self.tiles.len() == 1 {
            self.tiles[0].analog_update(dw, rng);
            return;
        }
        self.gather_blocks(dw);
        let base = rng.next_u64();
        self.fan_out(base, |tile, buf, sub| tile.analog_update(buf, sub));
    }

    /// Deterministic update (round-to-nearest, no noise) — the
    /// Python-parity mode, per tile. Consumes no randomness, so the
    /// fan-out is trivially schedule-independent.
    pub fn analog_update_det(&mut self, dw: &[f32]) {
        debug_assert_eq!(dw.len(), self.len());
        if self.tiles.len() == 1 {
            self.tiles[0].analog_update_det(dw);
            return;
        }
        self.gather_blocks(dw);
        self.fan_out(0, |tile, buf, _| tile.analog_update_det(buf));
    }

    /// One ZS cycle: the same polarity pulse on every cell of every
    /// tile.
    pub fn pulse_all(&mut self, up: bool, rng: &mut Rng) {
        if self.tiles.len() == 1 {
            self.tiles[0].pulse_all(up, rng);
            return;
        }
        let base = rng.next_u64();
        self.fan_out(base, |tile, _, sub| tile.pulse_all(up, sub));
    }

    /// One stochastic ZS cycle: independent random polarity per cell.
    pub fn pulse_all_random(&mut self, rng: &mut Rng) {
        if self.tiles.len() == 1 {
            self.tiles[0].pulse_all_random(rng);
            return;
        }
        let base = rng.next_u64();
        self.fan_out(base, |tile, _, sub| tile.pulse_all_random(sub));
    }

    /// Program the logical array to `target` weights (per-tile
    /// programming pulses; counts into the tiles' pulse counters).
    pub fn program(&mut self, target: &[f32], rng: &mut Rng) {
        debug_assert_eq!(target.len(), self.len());
        if self.tiles.len() == 1 {
            self.tiles[0].program(target, rng);
            return;
        }
        self.gather_blocks(target);
        let base = rng.next_u64();
        self.fan_out(base, |tile, buf, sub| tile.program(buf, sub));
    }

    /// Noisy read-out of the whole logical array into `out`
    /// (allocation-free). Read noise for tile `k` comes from the
    /// sub-stream `Rng::new(base, k)`, applied per tile row — parallel
    /// bands (one per tile-row of the grid) produce results identical
    /// to the serial order for any worker count. A zero `read_noise`
    /// is a pure scatter and consumes no randomness.
    pub fn read_into(&self, read_noise: f64, rng: &mut Rng, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.len());
        if self.tiles.len() == 1 {
            self.tiles[0].read_into(read_noise, rng, out);
            return;
        }
        if read_noise <= 0.0 {
            self.scatter_weights(out);
            return;
        }
        let base = rng.next_u64();
        let noise = read_noise as f32;
        let cols = self.cols;
        let grid_cols = self.grid_cols;
        // one band = one tile-row of the grid = a contiguous span of
        // `out`; each tile inside it scatters + perturbs its own
        // column stripe from its own sub-stream
        let read_band = |tr: usize, band: &mut [f32], tiles: &[DeviceArray]| {
            let mut c0 = 0;
            for (tj, tile) in tiles.iter().enumerate() {
                let mut sub = Rng::new(base, (tr * grid_cols + tj) as u64);
                for lr in 0..tile.rows {
                    let d = lr * cols + c0;
                    let dst = &mut band[d..d + tile.cols];
                    dst.copy_from_slice(&tile.w[lr * tile.cols..(lr + 1) * tile.cols]);
                    sub.add_normal_f32(dst, noise);
                }
                c0 += tile.cols;
            }
        };
        let band_span = self.geom.tile_rows * cols;
        let bands = out.chunks_mut(band_span).zip(self.tiles.chunks(grid_cols));
        let workers = self.worker_count().min(self.grid_rows).max(1);
        if !self.parallel || workers <= 1 {
            for (tr, (band, tiles)) in bands.enumerate() {
                read_band(tr, band, tiles);
            }
            return;
        }
        let mut buckets: Vec<Vec<(usize, &mut [f32], &[DeviceArray])>> =
            (0..workers).map(|_| Vec::new()).collect();
        for (tr, (band, tiles)) in bands.enumerate() {
            buckets[tr % workers].push((tr, band, tiles));
        }
        let rb = &read_band;
        std::thread::scope(|s| {
            for bucket in buckets {
                s.spawn(move || {
                    for (tr, band, tiles) in bucket {
                        rb(tr, band, tiles);
                    }
                });
            }
        });
    }

    /// Noisy read-out of the whole logical array (allocating wrapper).
    pub fn read(&self, read_noise: f64, rng: &mut Rng) -> Vec<f32> {
        let mut out = vec![0.0; self.len()];
        self.read_into(read_noise, rng, &mut out);
        out
    }

    /// Ground-truth SP of every logical cell, written into `out` — the
    /// soft-bounds closed form inlined per tile, bit-identical to
    /// [`DeviceArray::symmetric_points_into`] on the same cells.
    pub fn symmetric_points_into(&self, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.len());
        let cols = self.cols;
        for (k, tile) in self.tiles.iter().enumerate() {
            let (r0, c0) = self.tile_origin(k);
            let tmax = tile.tau_max as f64;
            let tmin = tile.tau_min as f64;
            for lr in 0..tile.rows {
                for lc in 0..tile.cols {
                    let i = lr * tile.cols + lc;
                    let ap = tile.alpha_p[i] as f64;
                    let am = tile.alpha_m[i] as f64;
                    out[(r0 + lr) * cols + c0 + lc] =
                        ((ap - am) / (ap / tmax + am / tmin)) as f32;
                }
            }
        }
    }

    /// Ground-truth SP of every logical cell (allocating wrapper).
    pub fn symmetric_points(&self) -> Vec<f32> {
        let mut out = vec![0.0; self.len()];
        self.symmetric_points_into(&mut out);
        out
    }

    /// Mean asymmetric magnitude ||G(w)||² / n over the logical array —
    /// the cell-weighted mean of the per-tile metric (delegates for a
    /// single tile, so the 1×1 contract holds bit-exactly).
    pub fn mean_g_sq(&self) -> f64 {
        if self.tiles.len() == 1 {
            return self.tiles[0].mean_g_sq();
        }
        if self.is_empty() {
            return 0.0;
        }
        let s: f64 = self
            .tiles
            .iter()
            .map(|t| t.mean_g_sq() * t.len() as f64)
            .sum();
        s / self.len() as f64
    }

    /// `y[b, cols] = x[b, rows] @ W` through each tile's IO chain with
    /// digital accumulation of the per-tile partial products (the
    /// standard partial-sum tile architecture). Single-tile grids
    /// delegate to the tile's own chain (bit-identical to
    /// [`IoChain::mvm`]); multi-tile ADC noise comes from per-tile
    /// sub-streams. `deterministic` consumes no randomness.
    pub fn mvm(&self, x: &[f32], b: usize, rng: &mut Rng, deterministic: bool) -> Vec<f32> {
        assert_eq!(x.len(), b * self.rows);
        if self.tiles.len() == 1 {
            return self.io[0].mvm(
                x,
                &self.tiles[0].w,
                b,
                self.rows,
                self.cols,
                rng,
                deterministic,
            );
        }
        let base = if deterministic { 0 } else { rng.next_u64() };
        let mut y = vec![0.0f32; b * self.cols];
        // per-call staging (sized for the largest tile) reused across
        // all tiles: the per-tile partial-sum loop itself is
        // allocation-free via `IoChain::mvm_into`
        let mut xblock = vec![0.0f32; b * self.geom.tile_rows];
        let mut part = vec![0.0f32; b * self.geom.tile_cols];
        let mut xq = vec![0.0f32; self.geom.tile_rows];
        for (k, tile) in self.tiles.iter().enumerate() {
            let (r0, c0) = self.tile_origin(k);
            let xb = &mut xblock[..b * tile.rows];
            for bi in 0..b {
                xb[bi * tile.rows..(bi + 1) * tile.rows]
                    .copy_from_slice(&x[bi * self.rows + r0..bi * self.rows + r0 + tile.rows]);
            }
            let mut sub = Rng::new(base, k as u64);
            let pt = &mut part[..b * tile.cols];
            self.io[k].mvm_into(
                xb,
                &tile.w,
                b,
                tile.rows,
                tile.cols,
                &mut sub,
                deterministic,
                pt,
                &mut xq[..tile.rows],
            );
            for bi in 0..b {
                let dst = &mut y[bi * self.cols + c0..bi * self.cols + c0 + tile.cols];
                for (o, p) in dst.iter_mut().zip(&pt[bi * tile.cols..(bi + 1) * tile.cols]) {
                    *o += *p;
                }
            }
        }
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::presets;

    #[test]
    fn geometry_is_validated() {
        assert!(TileGeometry::new(0, 32).is_err());
        assert!(TileGeometry::new(32, 0).is_err());
        let g = TileGeometry::new(32, 16).unwrap();
        assert_eq!(g.grid(64, 64), (2, 4));
        assert_eq!(g.grid(65, 17), (3, 2), "ragged edges round up");
        assert_eq!(g.grid(1, 1), (1, 1));
        assert_eq!(TileGeometry::default(), TileGeometry::new(256, 256).unwrap());
    }

    #[test]
    fn ragged_grid_covers_every_cell_exactly_once() {
        let geom = TileGeometry::new(32, 32).unwrap();
        let arr = TiledArray::sample(
            70,
            50,
            geom,
            &presets::preset("om").unwrap(),
            0.3,
            0.1,
            0.1,
            &mut Rng::from_seed(3),
        );
        assert_eq!(arr.grid_shape(), (3, 2));
        assert_eq!(arr.n_tiles(), 6);
        let cells: usize = (0..arr.n_tiles()).map(|k| arr.tile(k).len()).sum();
        assert_eq!(cells, arr.len());
        // edge tiles are ragged
        assert_eq!(arr.tile(5).rows, 6);
        assert_eq!(arr.tile(5).cols, 18);
    }

    #[test]
    fn ragged_uniform_det_update_matches_single_slab() {
        // uniform cells: the det path is purely per-cell, so any tiling
        // must reproduce the single-slab result bit-for-bit
        let dev = SoftBounds::from_gamma_rho(1.0, 0.2);
        let geom = TileGeometry::new(32, 32).unwrap();
        let mut tiled = TiledArray::uniform(70, 50, geom, &dev, 0.01, 0.0);
        let mut flat = DeviceArray::uniform(70, 50, &dev, 0.01, 0.0);
        let dw: Vec<f32> = (0..70 * 50)
            .map(|i| ((i % 13) as f32 - 6.0) * 0.005)
            .collect();
        for _ in 0..3 {
            tiled.analog_update_det(&dw);
            flat.analog_update_det(&dw);
        }
        let mut got = vec![0.0f32; tiled.len()];
        tiled.read_into(0.0, &mut Rng::from_seed(1), &mut got);
        assert_eq!(got, flat.w);
        assert_eq!(tiled.pulse_count(), flat.pulse_count);
    }

    #[test]
    fn parallel_and_serial_fanout_agree() {
        let geom = TileGeometry::new(32, 32).unwrap();
        let preset = presets::preset("om").unwrap();
        let mut a =
            TiledArray::sample(96, 96, geom, &preset, 0.3, 0.1, 0.1, &mut Rng::from_seed(7));
        let mut b = a.clone();
        a.set_parallel(false);
        b.set_parallel(true);
        b.set_workers(3);
        let dw = vec![0.02f32; 96 * 96];
        let mut ra = Rng::from_seed(9);
        let mut rb = Rng::from_seed(9);
        for _ in 0..4 {
            a.analog_update(&dw, &mut ra);
            b.analog_update(&dw, &mut rb);
        }
        let wa = a.read(0.0, &mut ra);
        let wb = b.read(0.0, &mut rb);
        assert_eq!(wa, wb);
        assert_eq!(a.pulse_count(), b.pulse_count());
    }

    #[test]
    fn symmetric_points_match_per_tile() {
        let geom = TileGeometry::new(32, 32).unwrap();
        let arr = TiledArray::sample(
            48,
            40,
            geom,
            &presets::preset("om").unwrap(),
            0.4,
            0.1,
            0.1,
            &mut Rng::from_seed(11),
        );
        let sps = arr.symmetric_points();
        for k in 0..arr.n_tiles() {
            let tile_sps = arr.tile(k).symmetric_points();
            let (r0, c0) = ((k / 2) * 32, (k % 2) * 32);
            for lr in 0..arr.tile(k).rows {
                for lc in 0..arr.tile(k).cols {
                    assert_eq!(
                        sps[(r0 + lr) * arr.cols + c0 + lc],
                        tile_sps[lr * arr.tile(k).cols + lc],
                        "tile {k} cell ({lr},{lc})"
                    );
                }
            }
        }
    }

    #[test]
    fn tiled_mvm_close_to_ideal() {
        let geom = TileGeometry::new(16, 16).unwrap();
        let dev = SoftBounds::symmetric();
        let mut arr = TiledArray::uniform(48, 32, geom, &dev, 1e-4, 0.0);
        let mut rng = Rng::from_seed(13);
        let target: Vec<f32> = (0..48 * 32).map(|i| ((i % 11) as f32 - 5.0) / 20.0).collect();
        for _ in 0..6 {
            arr.program(&target, &mut rng);
        }
        let x: Vec<f32> = (0..2 * 48).map(|i| ((i % 7) as f32 - 3.0) / 4.0).collect();
        let y = arr.mvm(&x, 2, &mut rng, true);
        let mut got = vec![0.0f32; arr.len()];
        arr.read_into(0.0, &mut rng, &mut got);
        for bi in 0..2 {
            for c in 0..32 {
                let mut s = 0.0f32;
                for r in 0..48 {
                    s += x[bi * 48 + r] * got[r * 32 + c];
                }
                assert!(
                    (y[bi * 32 + c] - s).abs() < 0.15,
                    "({bi},{c}): {} vs {s}",
                    y[bi * 32 + c]
                );
            }
        }
    }

    #[test]
    fn mean_g_sq_is_cell_weighted() {
        let geom = TileGeometry::new(32, 32).unwrap();
        let arr = TiledArray::sample(
            40,
            40,
            geom,
            &presets::preset("om").unwrap(),
            0.3,
            0.2,
            0.1,
            &mut Rng::from_seed(17),
        );
        let want: f64 = (0..arr.n_tiles())
            .map(|k| arr.tile(k).mean_g_sq() * arr.tile(k).len() as f64)
            .sum::<f64>()
            / arr.len() as f64;
        assert!((arr.mean_g_sq() - want).abs() < 1e-15);
    }
}
