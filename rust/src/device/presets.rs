//! Hardware presets mirrored from AIHWKit (paper Table 3) plus synthetic
//! sweeps over the number of conductance states (Fig. 4 left).

/// Static device-family parameters (per-cell slopes are sampled at array
/// construction; see `DeviceArray::sample`).
#[derive(Clone, Debug)]
pub struct Preset {
    /// Registry name of the preset.
    pub name: &'static str,
    /// Upper weight bound τ_max.
    pub tau_max: f64,
    /// Lower weight bound magnitude τ_min.
    pub tau_min: f64,
    /// response granularity Δw_min
    pub dw_min: f64,
    /// device-to-device asymmetry spread σ± (paper Table 3)
    pub d2d: f64,
    /// cycle-to-cycle write noise σ_c2c
    pub c2c: f64,
}

impl Preset {
    /// Number of conductance states ≈ window / Δw_min.
    pub fn n_states(&self) -> f64 {
        (self.tau_max + self.tau_min) / self.dw_min
    }
}

/// HfO2-based ReRAM (Gong et al., 2022): ~4–5 states, the low-state
/// regime of Tables 1–2.
pub const HFO2: Preset = Preset {
    name: "hfo2",
    tau_max: 1.0,
    tau_min: 1.0,
    dw_min: 0.4622,
    d2d: 0.7125,
    c2c: 0.2174,
};

/// ReRamArrayOM preset (Gong et al., 2022): ~21 states.
pub const OM: Preset = Preset {
    name: "om",
    tau_max: 1.0,
    tau_min: 1.0,
    dw_min: 0.0949,
    d2d: 0.7829,
    c2c: 0.4158,
};

/// High-precision device used for the Fig. 1 pulse-complexity study.
pub const PRECISE: Preset = Preset {
    name: "precise",
    tau_max: 1.0,
    tau_min: 1.0,
    dw_min: 0.001,
    d2d: 0.7125,
    c2c: 0.2174,
};

/// Near-ideal device (digital-parity sanity checks).
pub const IDEAL: Preset = Preset {
    name: "ideal",
    tau_max: 1.0,
    tau_min: 1.0,
    dw_min: 1e-5,
    d2d: 0.0,
    c2c: 0.0,
};

/// Registry lookup by preset name (`"hfo2"`, `"om"`, `"precise"`,
/// `"ideal"`); `None` for unknown names.
pub fn preset(name: &str) -> Option<Preset> {
    match name {
        "hfo2" => Some(HFO2),
        "om" => Some(OM),
        "precise" => Some(PRECISE),
        "ideal" => Some(IDEAL),
        _ => None,
    }
}

/// A preset with a given number of conductance states (Fig. 4 left sweep).
pub fn with_states(base: &Preset, n_states: f64) -> Preset {
    Preset {
        name: "states-sweep",
        dw_min: (base.tau_max + base.tau_min) / n_states,
        ..base.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_numbers() {
        assert_eq!(HFO2.dw_min, 0.4622);
        assert_eq!(HFO2.d2d, 0.7125);
        assert_eq!(HFO2.c2c, 0.2174);
        assert_eq!(OM.dw_min, 0.0949);
    }

    #[test]
    fn states_counts() {
        assert!((HFO2.n_states() - 4.327).abs() < 0.01);
        assert!((OM.n_states() - 21.07).abs() < 0.05);
        let p = with_states(&HFO2, 2000.0);
        assert!((p.n_states() - 2000.0).abs() < 1e-9);
    }

    #[test]
    fn lookup() {
        assert!(preset("hfo2").is_some());
        assert!(preset("nope").is_none());
    }
}
