//! Response-function families (paper Definition 2.1 / C.1).
//!
//! A response function pair `(q_plus, q_minus)` describes how a cell's
//! conductance reacts to a single up/down pulse at its current weight.
//! The soft-bounds family is the paper's experimental model (Eq. 103);
//! linear/exponential/power variants cover the monotone class of
//! Definition C.1 used by the last-iterate theory (Theorem C.2).

/// A scalar response model. All implementations must be
/// *training-friendly*: 0 < q_min <= q±(w) <= q_max on the weight window.
pub trait Response: Clone + Send + Sync {
    /// Potentiation response q_plus(w).
    fn q_plus(&self, w: f64) -> f64;
    /// Depression response q_minus(w).
    fn q_minus(&self, w: f64) -> f64;
    /// Weight window [lo, hi].
    fn bounds(&self) -> (f64, f64);

    /// Symmetric component F(w) = (q_- + q_+)/2 (Eq. 6a).
    fn f_sym(&self, w: f64) -> f64 {
        0.5 * (self.q_minus(w) + self.q_plus(w))
    }

    /// Asymmetric component G(w) = (q_- - q_+)/2 (Eq. 6b).
    fn g_asym(&self, w: f64) -> f64 {
        0.5 * (self.q_minus(w) - self.q_plus(w))
    }

    /// Ground-truth symmetric point: root of G (Definition 1.1).
    /// Default: bisection on the window (G is monotone for Def. C.1
    /// devices; soft-bounds overrides with the closed form).
    fn symmetric_point(&self) -> f64 {
        let (lo, hi) = self.bounds();
        let (mut a, mut b) = (lo + 1e-9, hi - 1e-9);
        let ga = self.g_asym(a);
        if ga.abs() < 1e-15 {
            return a;
        }
        for _ in 0..200 {
            let m = 0.5 * (a + b);
            let gm = self.g_asym(m);
            if gm == 0.0 {
                return m;
            }
            if (gm > 0.0) == (ga > 0.0) {
                a = m;
            } else {
                b = m;
            }
        }
        0.5 * (a + b)
    }
}

/// Soft-bounds reference device (paper Eq. 103):
///   q_plus(w)  = alpha_p (1 - w/tau_max)
///   q_minus(w) = alpha_m (1 + w/tau_min)
#[derive(Clone, Debug, PartialEq)]
pub struct SoftBounds {
    /// Potentiation slope α₊.
    pub alpha_p: f64,
    /// Depression slope α₋.
    pub alpha_m: f64,
    /// Upper weight bound τ_max.
    pub tau_max: f64,
    /// Lower weight bound magnitude τ_min.
    pub tau_min: f64,
}

impl SoftBounds {
    /// Construct from slopes and bounds; all four must be positive.
    pub fn new(alpha_p: f64, alpha_m: f64, tau_max: f64, tau_min: f64) -> Self {
        assert!(alpha_p > 0.0 && alpha_m > 0.0 && tau_max > 0.0 && tau_min > 0.0);
        Self { alpha_p, alpha_m, tau_max, tau_min }
    }

    /// Symmetric device with unit slopes.
    pub fn symmetric() -> Self {
        Self::new(1.0, 1.0, 1.0, 1.0)
    }

    /// From (gamma, rho) decomposition (paper Eq. 104): alpha± = gamma ± rho.
    pub fn from_gamma_rho(gamma: f64, rho: f64) -> Self {
        let floor = 0.05;
        Self::new(
            (gamma + rho).max(floor),
            (gamma - rho).max(floor),
            1.0,
            1.0,
        )
    }
}

impl Response for SoftBounds {
    #[inline]
    fn q_plus(&self, w: f64) -> f64 {
        (self.alpha_p * (1.0 - w / self.tau_max)).max(0.0)
    }

    #[inline]
    fn q_minus(&self, w: f64) -> f64 {
        (self.alpha_m * (1.0 + w / self.tau_min)).max(0.0)
    }

    fn bounds(&self) -> (f64, f64) {
        (-self.tau_min, self.tau_max)
    }

    /// Closed form: solve alpha_p (1 - w/tau_max) = alpha_m (1 + w/tau_min).
    /// (Paper Eq. 110 as printed has a sign slip — see DESIGN.md §2.)
    fn symmetric_point(&self) -> f64 {
        (self.alpha_p - self.alpha_m)
            / (self.alpha_p / self.tau_max + self.alpha_m / self.tau_min)
    }
}

/// Linear-monotone device (Definition C.1): q± = a ∓ b w, SP at 0-crossing.
#[derive(Clone, Debug)]
pub struct LinearMonotone {
    /// Base response magnitude.
    pub a: f64,
    /// Response slope vs. weight.
    pub b: f64,
    /// SP location (the response's 0-crossing shift).
    pub shift: f64,
    /// Symmetric weight window half-width.
    pub window: f64,
}

impl Response for LinearMonotone {
    fn q_plus(&self, w: f64) -> f64 {
        (self.a - self.b * (w - self.shift)).max(1e-6)
    }

    fn q_minus(&self, w: f64) -> f64 {
        (self.a + self.b * (w - self.shift)).max(1e-6)
    }

    fn bounds(&self) -> (f64, f64) {
        (-self.window, self.window)
    }

    fn symmetric_point(&self) -> f64 {
        self.shift
    }
}

/// Exponential device: q±(w) = a exp(∓ k (w - shift)); strongly monotone G.
#[derive(Clone, Debug)]
pub struct ExpDevice {
    /// Response magnitude at the SP.
    pub a: f64,
    /// Exponential rate.
    pub k: f64,
    /// SP location.
    pub shift: f64,
    /// Symmetric weight window half-width.
    pub window: f64,
}

impl Response for ExpDevice {
    fn q_plus(&self, w: f64) -> f64 {
        self.a * (-self.k * (w - self.shift)).exp()
    }

    fn q_minus(&self, w: f64) -> f64 {
        self.a * (self.k * (w - self.shift)).exp()
    }

    fn bounds(&self) -> (f64, f64) {
        (-self.window, self.window)
    }

    fn symmetric_point(&self) -> f64 {
        self.shift
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softbounds_sp_closed_form_matches_root() {
        let d = SoftBounds::from_gamma_rho(1.1, 0.3);
        let sp = d.symmetric_point();
        assert!(d.g_asym(sp).abs() < 1e-12, "G(sp) = {}", d.g_asym(sp));
        // rho/gamma when floors don't bind and tau = 1
        assert!((sp - 0.3 / 1.1).abs() < 1e-12);
    }

    #[test]
    fn symmetric_device_sp_zero() {
        assert_eq!(SoftBounds::symmetric().symmetric_point(), 0.0);
    }

    #[test]
    fn fg_recover_q() {
        let d = SoftBounds::from_gamma_rho(0.9, -0.2);
        for w in [-0.8, -0.1, 0.0, 0.3, 0.7] {
            let f = d.f_sym(w);
            let g = d.g_asym(w);
            assert!((f - g - d.q_plus(w)).abs() < 1e-12);
            assert!((f + g - d.q_minus(w)).abs() < 1e-12);
        }
    }

    #[test]
    fn bisection_matches_closed_form_for_monotone() {
        let d = ExpDevice { a: 1.0, k: 0.8, shift: 0.25, window: 1.0 };
        // default trait bisection
        let (lo, hi) = d.bounds();
        let _ = (lo, hi);
        let via_bisect = {
            // re-run the default implementation manually
            struct Wrap(ExpDevice);
            impl Clone for Wrap {
                fn clone(&self) -> Self {
                    Wrap(self.0.clone())
                }
            }
            impl Response for Wrap {
                fn q_plus(&self, w: f64) -> f64 {
                    self.0.q_plus(w)
                }
                fn q_minus(&self, w: f64) -> f64 {
                    self.0.q_minus(w)
                }
                fn bounds(&self) -> (f64, f64) {
                    self.0.bounds()
                }
            }
            Wrap(d.clone()).symmetric_point()
        };
        assert!((via_bisect - 0.25).abs() < 1e-6, "{via_bisect}");
    }

    #[test]
    fn training_friendly_on_window() {
        let d = SoftBounds::from_gamma_rho(1.0, 0.4);
        for i in 0..100 {
            let w = -0.95 + 1.9 * (i as f64) / 99.0;
            assert!(d.q_plus(w) >= 0.0);
            assert!(d.q_minus(w) >= 0.0);
            assert!(d.q_plus(w) <= 3.0 && d.q_minus(w) <= 3.0);
        }
    }
}
