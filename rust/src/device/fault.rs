//! Deterministic device fault injection: the chaos layer of the
//! crossbar substrate.
//!
//! Real crossbars fail in more ways than a biased symmetric point:
//! cells get stuck at a conductance bound or at their SP, conductances
//! drift toward the SP between programming cycles, whole rows/columns
//! lose their drivers, entire tiles die, and ADC periphery develops
//! offsets or early saturation (the general non-ideality axis of
//! arXiv:2502.06309). This module models all of those as a declarative
//! [`FaultPlan`] that is *compiled once* into a per-tile [`FaultState`]
//! and then applied as a pure post-update mask.
//!
//! Contracts (pinned by `rust/tests/fault_equivalence.rs`):
//!
//! * **Zero-cost when disarmed.** With no plan armed, every substrate
//!   path is bit-for-bit identical to a build without this module: the
//!   only addition to the hot paths is one `if let Some` on a `None`.
//! * **Deterministic.** All randomness is consumed at *arm* time from
//!   the sub-stream `Rng::new(plan.seed, k)` — the same derivation the
//!   tiled fan-out and the row-chunked parallel update use — where `k`
//!   is the tile index (or a caller-chosen stream for bare arrays).
//!   Applying a compiled [`FaultState`] consumes no randomness at all,
//!   so the serial and scoped-thread fan-outs stay bit-identical at
//!   any worker count, faults armed or not.
//! * **Pulse accounting is unchanged.** Stuck and dead cells still
//!   receive (and count) pulses; the fault mask simply forces their
//!   conductance afterwards, like a real defect would.

use crate::device::array::DeviceArray;
use crate::util::rng::Rng;

/// The fault families the chaos layer can inject. Each maps a single
/// `rate` knob onto one [`FaultPlan`] field via [`FaultPlan::of`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultFamily {
    /// Cells stuck at a window bound (±τ), polarity chosen at arm time.
    StuckAtBound,
    /// Cells stuck exactly at their own symmetric point.
    StuckAtSp,
    /// Cells whose conductance relaxes toward the SP a little after
    /// every update cycle (retention loss).
    DriftToSp,
    /// Whole rows/columns whose drivers are dead (cells read as 0).
    DeadLines,
    /// Entire tiles failing (every cell pinned to 0).
    TileFailure,
    /// ADC periphery fault: a constant output offset on the IO chain.
    Adc,
}

impl FaultFamily {
    /// Every injectable family, in sweep order.
    pub const ALL: [FaultFamily; 6] = [
        FaultFamily::StuckAtBound,
        FaultFamily::StuckAtSp,
        FaultFamily::DriftToSp,
        FaultFamily::DeadLines,
        FaultFamily::TileFailure,
        FaultFamily::Adc,
    ];

    /// Stable CLI / report name.
    pub fn name(&self) -> &'static str {
        match self {
            FaultFamily::StuckAtBound => "stuckbound",
            FaultFamily::StuckAtSp => "stucksp",
            FaultFamily::DriftToSp => "drift",
            FaultFamily::DeadLines => "deadlines",
            FaultFamily::TileFailure => "tilefail",
            FaultFamily::Adc => "adc",
        }
    }

    /// Parse a CLI name produced by [`FaultFamily::name`].
    pub fn parse(s: &str) -> Option<Self> {
        Self::ALL.iter().copied().find(|f| f.name() == s)
    }
}

/// Declarative fault-injection plan: which families to inject and how
/// hard. A plan is plain data; compiling it against a tile (shape +
/// SP map + seeded sub-stream) yields the [`FaultState`] mask that the
/// substrate applies after every update. The all-zero plan compiles to
/// an empty state everywhere.
#[derive(Clone, Copy, Debug)]
pub struct FaultPlan {
    /// Base seed of the fault sub-streams; tile `k` compiles with
    /// `Rng::new(seed, k)`.
    pub seed: u64,
    /// Probability each cell is stuck at a window bound.
    pub stuck_bound_rate: f64,
    /// Probability each cell is stuck at its own SP.
    pub stuck_sp_rate: f64,
    /// Probability each cell suffers retention drift toward its SP.
    pub drift_rate: f64,
    /// Per-update fractional relaxation toward the SP of drifting
    /// cells (0.05 = 5% of the remaining distance per update cycle).
    pub drift_step: f64,
    /// Probability each physical row / column has a dead driver.
    pub dead_line_rate: f64,
    /// Probability an entire tile is dead.
    pub tile_fail_rate: f64,
    /// Constant ADC output offset (pre-rescale units; 0 = disabled).
    pub adc_offset: f32,
    /// ADC saturation bound tighter than the chain's own
    /// (`f32::INFINITY` = disabled).
    pub adc_sat: f32,
}

impl FaultPlan {
    /// A plan with every family disabled (compiles to empty states).
    pub fn none(seed: u64) -> Self {
        Self {
            seed,
            stuck_bound_rate: 0.0,
            stuck_sp_rate: 0.0,
            drift_rate: 0.0,
            drift_step: 0.0,
            dead_line_rate: 0.0,
            tile_fail_rate: 0.0,
            adc_offset: 0.0,
            adc_sat: f32::INFINITY,
        }
    }

    /// A single-family plan at the given rate — the sweep axis of
    /// `rider faultsweep`. For [`FaultFamily::DriftToSp`] the rate is
    /// the fraction of drifting cells (relaxation step fixed at 5%);
    /// for [`FaultFamily::Adc`] the rate is the output offset.
    pub fn of(seed: u64, family: FaultFamily, rate: f64) -> Self {
        let mut p = Self::none(seed);
        match family {
            FaultFamily::StuckAtBound => p.stuck_bound_rate = rate,
            FaultFamily::StuckAtSp => p.stuck_sp_rate = rate,
            FaultFamily::DriftToSp => {
                p.drift_rate = rate;
                p.drift_step = 0.05;
            }
            FaultFamily::DeadLines => p.dead_line_rate = rate,
            FaultFamily::TileFailure => p.tile_fail_rate = rate,
            FaultFamily::Adc => p.adc_offset = rate as f32,
        }
        p
    }

    /// Whether the plan injects nothing at all.
    pub fn is_noop(&self) -> bool {
        self.stuck_bound_rate == 0.0
            && self.stuck_sp_rate == 0.0
            && self.drift_rate == 0.0
            && self.dead_line_rate == 0.0
            && self.tile_fail_rate == 0.0
            && self.adc_offset == 0.0
            && !self.adc_sat.is_finite()
    }

    /// Compile the plan for one `rows x cols` tile into a concrete
    /// fault mask. `sp` is the tile's per-cell SP map (row-major), and
    /// `lo`/`hi` the conductance window. All randomness is consumed
    /// here, in a fixed order (tile failure, dead rows, dead columns,
    /// stuck-at-bound, stuck-at-SP, drift); families at rate 0 consume
    /// none, so the all-zero plan compiles without touching `rng`.
    pub fn compile(
        &self,
        rows: usize,
        cols: usize,
        sp: &[f32],
        lo: f32,
        hi: f32,
        rng: &mut Rng,
    ) -> FaultState {
        debug_assert_eq!(sp.len(), rows * cols);
        let n = rows * cols;
        let mut st = FaultState::default();
        if self.tile_fail_rate > 0.0 && rng.uniform() < self.tile_fail_rate {
            st.dead_tile = true;
            st.stuck = (0..n as u32).map(|i| (i, 0.0)).collect();
            return st;
        }
        // dead lines pin every cell of the row/column to 0
        let mut pinned = vec![false; n];
        if self.dead_line_rate > 0.0 {
            for r in 0..rows {
                if rng.uniform() < self.dead_line_rate {
                    for c in 0..cols {
                        pinned[r * cols + c] = true;
                    }
                }
            }
            for c in 0..cols {
                if rng.uniform() < self.dead_line_rate {
                    for r in 0..rows {
                        pinned[r * cols + c] = true;
                    }
                }
            }
            for (i, &p) in pinned.iter().enumerate() {
                if p {
                    st.stuck.push((i as u32, 0.0));
                }
            }
        }
        if self.stuck_bound_rate > 0.0 {
            for i in 0..n {
                if rng.uniform() < self.stuck_bound_rate && !pinned[i] {
                    let v = if rng.uniform() < 0.5 { hi } else { lo };
                    st.stuck.push((i as u32, v));
                    pinned[i] = true;
                }
            }
        }
        if self.stuck_sp_rate > 0.0 {
            for i in 0..n {
                if rng.uniform() < self.stuck_sp_rate && !pinned[i] {
                    st.stuck.push((i as u32, sp[i]));
                    pinned[i] = true;
                }
            }
        }
        if self.drift_rate > 0.0 && self.drift_step > 0.0 {
            st.drift_step = self.drift_step as f32;
            for i in 0..n {
                if rng.uniform() < self.drift_rate && !pinned[i] {
                    st.drift.push((i as u32, sp[i]));
                }
            }
        }
        st
    }

    /// Compile and arm directly on a bare [`DeviceArray`], using the
    /// sub-stream `Rng::new(self.seed, stream)` — the seam the
    /// pulse-level optimizers use (one stream index per owned array).
    pub fn arm_array(&self, arr: &mut DeviceArray, stream: u64) {
        let mut sub = Rng::new(self.seed, stream);
        let mut sp = vec![0.0f32; arr.len()];
        arr.symmetric_points_into(&mut sp);
        let st = self.compile(arr.rows, arr.cols, &sp, -arr.tau_min, arr.tau_max, &mut sub);
        arr.arm_faults(st);
    }
}

/// A compiled, per-tile fault mask: everything random has already been
/// decided, so applying it is a deterministic, allocation-free pass
/// over the weight slab (drift first, then stuck pins — a cell that is
/// both stuck and drifting stays stuck).
#[derive(Clone, Debug, Default)]
pub struct FaultState {
    /// Cells pinned to a fixed conductance: `(cell index, value)`.
    pub stuck: Vec<(u32, f32)>,
    /// Cells relaxing toward a target (their SP): `(cell index, sp)`.
    pub drift: Vec<(u32, f32)>,
    /// Fractional relaxation per update cycle for `drift` cells.
    pub drift_step: f32,
    /// Whether the whole tile failed (reported by tile status; the
    /// cells are also all in `stuck`).
    pub dead_tile: bool,
}

impl FaultState {
    /// Whether the mask injects nothing (the armed-but-empty case —
    /// still allocation-free and bit-identical to disarmed).
    pub fn is_empty(&self) -> bool {
        self.stuck.is_empty() && self.drift.is_empty()
    }

    /// Number of cells this mask touches.
    pub fn n_faulty(&self) -> usize {
        self.stuck.len() + self.drift.len()
    }

    /// Apply the mask to a weight slab: drift cells relax toward their
    /// target, stuck cells snap to their pin. Consumes no randomness
    /// and performs no allocation.
    pub fn apply(&self, w: &mut [f32]) {
        let step = self.drift_step;
        if step != 0.0 {
            for &(i, sp) in &self.drift {
                let wv = w[i as usize];
                w[i as usize] = wv + step * (sp - wv);
            }
        }
        for &(i, v) in &self.stuck {
            w[i as usize] = v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::presets;

    fn arr(seed: u64) -> DeviceArray {
        DeviceArray::sample(
            16,
            16,
            &presets::preset("om").unwrap(),
            0.3,
            0.1,
            0.1,
            &mut Rng::from_seed(seed),
        )
    }

    #[test]
    fn noop_plan_compiles_empty_and_draws_nothing() {
        let plan = FaultPlan::none(7);
        assert!(plan.is_noop());
        let mut rng = Rng::new(7, 0);
        let before = rng.next_u64();
        let mut rng = Rng::new(7, 0);
        let sp = vec![0.0f32; 16];
        let st = plan.compile(4, 4, &sp, -1.0, 1.0, &mut rng);
        assert!(st.is_empty());
        assert_eq!(rng.next_u64(), before, "no-op compile must not draw");
    }

    #[test]
    fn compile_is_deterministic() {
        let plan = FaultPlan::of(11, FaultFamily::StuckAtBound, 0.1);
        let a = arr(1);
        let sp = a.symmetric_points();
        let s1 = plan.compile(16, 16, &sp, -1.0, 1.0, &mut Rng::new(11, 3));
        let s2 = plan.compile(16, 16, &sp, -1.0, 1.0, &mut Rng::new(11, 3));
        assert_eq!(s1.stuck, s2.stuck);
    }

    #[test]
    fn stuck_cells_stay_pinned_under_updates() {
        let mut a = arr(2);
        let plan = FaultPlan::of(5, FaultFamily::StuckAtBound, 0.2);
        plan.arm_array(&mut a, 0);
        let pins: Vec<(u32, f32)> = a.fault_state().unwrap().stuck.clone();
        assert!(!pins.is_empty(), "rate 0.2 over 256 cells must pin some");
        let mut rng = Rng::from_seed(3);
        let dw = vec![0.05f32; a.len()];
        for _ in 0..5 {
            a.analog_update(&dw, &mut rng);
        }
        for &(i, v) in &pins {
            assert_eq!(a.w[i as usize], v, "cell {i}");
        }
    }

    #[test]
    fn drift_relaxes_toward_sp() {
        let mut a = arr(3);
        let sp = a.symmetric_points();
        let plan = FaultPlan::of(9, FaultFamily::DriftToSp, 1.0);
        plan.arm_array(&mut a, 0);
        let n_drift = a.fault_state().unwrap().drift.len();
        assert!(n_drift > 200, "rate 1.0 must catch nearly all cells");
        let d0: f64 = a
            .w
            .iter()
            .zip(&sp)
            .map(|(w, s)| (w - s).abs() as f64)
            .sum();
        // deterministic zero update: only the fault mask acts
        let dw = vec![0.0f32; a.len()];
        for _ in 0..50 {
            a.analog_update_det(&dw);
        }
        let d1: f64 = a
            .w
            .iter()
            .zip(&sp)
            .map(|(w, s)| (w - s).abs() as f64)
            .sum();
        assert!(d1 < 0.1 * d0 + 1e-6, "distance {d0} -> {d1}");
    }

    #[test]
    fn dead_lines_pin_whole_rows() {
        let plan = FaultPlan::of(21, FaultFamily::DeadLines, 0.5);
        let a = arr(4);
        let sp = a.symmetric_points();
        let st = plan.compile(16, 16, &sp, -1.0, 1.0, &mut Rng::new(21, 0));
        assert!(!st.stuck.is_empty());
        assert!(st.stuck.iter().all(|&(_, v)| v == 0.0));
        // dead lines come in full rows/cols: count must be a multiple
        // of nothing in general (rows and cols overlap), but every
        // pinned cell shares a row or column with 15 other pins
        for &(i, _) in &st.stuck {
            let (r, c) = (i as usize / 16, i as usize % 16);
            let row_pins = st.stuck.iter().filter(|&&(j, _)| j as usize / 16 == r).count();
            let col_pins = st.stuck.iter().filter(|&&(j, _)| j as usize % 16 == c).count();
            assert!(row_pins == 16 || col_pins == 16, "cell {i} not on a dead line");
        }
    }

    #[test]
    fn tile_failure_pins_everything() {
        let plan = FaultPlan::of(13, FaultFamily::TileFailure, 1.0);
        let st = plan.compile(4, 4, &[0.0; 16], -1.0, 1.0, &mut Rng::new(13, 0));
        assert!(st.dead_tile);
        assert_eq!(st.stuck.len(), 16);
    }

    #[test]
    fn family_names_round_trip() {
        for f in FaultFamily::ALL {
            assert_eq!(FaultFamily::parse(f.name()), Some(f));
        }
        assert_eq!(FaultFamily::parse("nope"), None);
    }
}
