//! Pulse-accurate analog crossbar substrate (mirrors the JAX device model
//! in `python/compile/devices.py`; parity-tested via artifacts/parity.json).

#![warn(missing_docs)]

pub mod array;
pub mod fault;
pub mod io;
pub mod presets;
pub mod response;
pub mod tile;

pub use array::DeviceArray;
pub use fault::{FaultFamily, FaultPlan, FaultState};
pub use io::IoChain;
pub use presets::{preset, Preset, HFO2, IDEAL, OM, PRECISE};
pub use response::{ExpDevice, LinearMonotone, Response, SoftBounds};
pub use tile::{TileGeometry, TiledArray};
