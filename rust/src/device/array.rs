//! Crossbar device array: a tile of soft-bounds cells in SoA layout,
//! pulse-accurate. This is the substrate for the pulse-level experiments
//! (Fig. 1, Theorems 2.2/C.2) and the Rust-native algorithm family; it
//! mirrors the JAX device model exactly (parity-tested on shared vectors).

use crate::device::presets::Preset;
use crate::device::response::{Response, SoftBounds};
use crate::util::rng::Rng;

/// A crossbar tile: per-cell weights and device parameters, flat
/// row-major `rows x cols` storage.
#[derive(Clone, Debug)]
pub struct DeviceArray {
    pub rows: usize,
    pub cols: usize,
    pub w: Vec<f32>,
    pub alpha_p: Vec<f32>,
    pub alpha_m: Vec<f32>,
    pub tau_max: f32,
    pub tau_min: f32,
    /// response granularity (weight change per pulse at q = 1)
    pub dw_min: f32,
    /// cycle-to-cycle multiplicative noise std
    pub c2c: f32,
    /// pulses applied so far (pulse accounting)
    pub pulse_count: u64,
}

impl DeviceArray {
    /// Sample a tile from a preset with a controlled SP distribution:
    /// per-cell SP ~ N(ref_mean, ref_std) (clipped inside the window),
    /// slope magnitude gamma ~ exp(sigma_gamma * N(0,1)).
    pub fn sample(
        rows: usize,
        cols: usize,
        preset: &Preset,
        ref_mean: f64,
        ref_std: f64,
        sigma_gamma: f64,
        rng: &mut Rng,
    ) -> Self {
        let n = rows * cols;
        let mut ap = Vec::with_capacity(n);
        let mut am = Vec::with_capacity(n);
        let floor = 0.05f64;
        for _ in 0..n {
            let gamma = (sigma_gamma * rng.normal()).exp();
            let sp = (ref_mean + ref_std * rng.normal())
                .clamp(-0.85 * preset.tau_min, 0.85 * preset.tau_max);
            let rho = gamma * sp / preset.tau_max;
            ap.push(((gamma + rho).max(floor)) as f32);
            am.push(((gamma - rho).max(floor)) as f32);
        }
        Self {
            rows,
            cols,
            w: vec![0.0; n],
            alpha_p: ap,
            alpha_m: am,
            tau_max: preset.tau_max as f32,
            tau_min: preset.tau_min as f32,
            dw_min: preset.dw_min as f32,
            c2c: preset.c2c as f32,
            pulse_count: 0,
        }
    }

    /// A uniform tile where every cell shares one response model.
    pub fn uniform(rows: usize, cols: usize, dev: &SoftBounds, dw_min: f64, c2c: f64) -> Self {
        let n = rows * cols;
        Self {
            rows,
            cols,
            w: vec![0.0; n],
            alpha_p: vec![dev.alpha_p as f32; n],
            alpha_m: vec![dev.alpha_m as f32; n],
            tau_max: dev.tau_max as f32,
            tau_min: dev.tau_min as f32,
            dw_min: dw_min as f32,
            c2c: c2c as f32,
            pulse_count: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.w.len()
    }

    pub fn is_empty(&self) -> bool {
        self.w.is_empty()
    }

    /// Per-cell response model.
    pub fn cell(&self, i: usize) -> SoftBounds {
        SoftBounds::new(
            self.alpha_p[i] as f64,
            self.alpha_m[i] as f64,
            self.tau_max as f64,
            self.tau_min as f64,
        )
    }

    /// Ground-truth SP of every cell.
    pub fn symmetric_points(&self) -> Vec<f32> {
        (0..self.len())
            .map(|i| self.cell(i).symmetric_point() as f32)
            .collect()
    }

    #[inline]
    fn q_at(&self, i: usize, w: f32, up: bool) -> f32 {
        if up {
            (self.alpha_p[i] * (1.0 - w / self.tau_max)).max(0.0)
        } else {
            (self.alpha_m[i] * (1.0 + w / self.tau_min)).max(0.0)
        }
    }

    /// Apply a single ±dw_min pulse to cell `i` (the hardware primitive).
    #[inline]
    pub fn pulse_cell(&mut self, i: usize, up: bool, rng: &mut Rng) {
        let w = self.w[i];
        let q = self.q_at(i, w, up);
        let noise = if self.c2c > 0.0 {
            1.0 + self.c2c * rng.normal() as f32
        } else {
            1.0
        };
        let step = self.dw_min * q * noise;
        let nw = if up { w + step } else { w - step };
        self.w[i] = nw.clamp(-self.tau_min, self.tau_max);
        self.pulse_count += 1;
    }

    /// One ZS cycle: apply the same polarity to every cell.
    pub fn pulse_all(&mut self, up: bool, rng: &mut Rng) {
        for i in 0..self.len() {
            self.pulse_cell(i, up, rng);
        }
    }

    /// One stochastic ZS cycle: independent random polarity per cell.
    pub fn pulse_all_random(&mut self, rng: &mut Rng) {
        for i in 0..self.len() {
            let up = rng.next_u32() & 1 == 0;
            self.pulse_cell(i, up, rng);
        }
    }

    /// Analog Update (paper Eq. 2): realise the desired per-cell
    /// increment `dw` as a stochastically-rounded pulse train with c2c
    /// noise — the aggregated (single-shot) model shared with the JAX
    /// kernel. Counts the pulses it would have sent.
    pub fn analog_update(&mut self, dw: &[f32], rng: &mut Rng) {
        debug_assert_eq!(dw.len(), self.len());
        let dwm = self.dw_min;
        for i in 0..self.len() {
            let d = dw[i];
            if d == 0.0 {
                continue;
            }
            let up = d >= 0.0;
            let q = self.q_at(i, self.w[i], up);
            let mag = d.abs();
            let pulses_f = mag / dwm;
            let n_lo = pulses_f.floor();
            let frac = pulses_f - n_lo;
            let n = n_lo + if (rng.uniform() as f32) < frac { 1.0 } else { 0.0 };
            if n == 0.0 {
                continue;
            }
            let c2c = if self.c2c > 0.0 {
                n.sqrt() * dwm * self.c2c * rng.normal() as f32
            } else {
                0.0
            };
            let delta = (n * dwm + c2c) * q;
            let nw = if up { self.w[i] + delta } else { self.w[i] - delta };
            self.w[i] = nw.clamp(-self.tau_min, self.tau_max);
            self.pulse_count += n as u64;
        }
    }

    /// Deterministic variant (round-to-nearest, no noise) — the parity
    /// mode shared with `kernels/ref.py`.
    pub fn analog_update_det(&mut self, dw: &[f32]) {
        let dwm = self.dw_min;
        for i in 0..self.len() {
            let d = dw[i];
            let up = d >= 0.0;
            let q = self.q_at(i, self.w[i], up);
            let n = (d.abs() / dwm).round();
            if n == 0.0 {
                continue;
            }
            let delta = n * dwm * q;
            let nw = if up { self.w[i] + delta } else { self.w[i] - delta };
            self.w[i] = nw.clamp(-self.tau_min, self.tau_max);
            self.pulse_count += n as u64;
        }
    }

    /// Noisy read-out of the full tile.
    pub fn read(&self, read_noise: f64, rng: &mut Rng) -> Vec<f32> {
        self.w
            .iter()
            .map(|&w| w + (read_noise * rng.normal()) as f32)
            .collect()
    }

    /// Program the tile to target weights (counts programming pulses).
    pub fn program(&mut self, target: &[f32], rng: &mut Rng) {
        debug_assert_eq!(target.len(), self.len());
        let dw: Vec<f32> = target.iter().zip(&self.w).map(|(t, w)| t - w).collect();
        self.analog_update(&dw, rng);
    }

    /// Mean asymmetric magnitude ||G(w)||^2 / n over the tile — the
    /// Theorem 2.2 convergence metric.
    pub fn mean_g_sq(&self) -> f64 {
        let mut s = 0.0;
        for i in 0..self.len() {
            let g = self.cell(i).g_asym(self.w[i] as f64);
            s += g * g;
        }
        s / self.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::presets;
    use crate::prop_assert;
    use crate::util::prop;

    fn small(rng: &mut Rng) -> DeviceArray {
        DeviceArray::sample(8, 8, &presets::preset("om").unwrap(), 0.3, 0.2, 0.1, rng)
    }

    #[test]
    fn sample_controls_sp() {
        let mut rng = Rng::from_seed(1);
        let arr = DeviceArray::sample(
            64,
            64,
            &presets::preset("precise").unwrap(),
            0.4,
            0.1,
            0.1,
            &mut rng,
        );
        let sps = arr.symmetric_points();
        let mean = sps.iter().map(|&x| x as f64).sum::<f64>() / sps.len() as f64;
        assert!((mean - 0.4).abs() < 0.02, "{mean}");
    }

    #[test]
    fn pulses_stay_in_window() {
        prop::check("bounds", 20, |rng| {
            let mut arr = small(rng);
            for _ in 0..200 {
                arr.pulse_all_random(rng);
            }
            prop_assert!(arr
                .w
                .iter()
                .all(|&w| (-arr.tau_min..=arr.tau_max).contains(&w)));
            Ok(())
        });
    }

    #[test]
    fn pulse_count_accounting() {
        let mut rng = Rng::from_seed(2);
        let mut arr = small(&mut rng);
        arr.pulse_all(true, &mut rng);
        assert_eq!(arr.pulse_count, 64);
        let dw = vec![3.5 * arr.dw_min; arr.len()];
        let before = arr.pulse_count;
        arr.analog_update_det(&dw);
        // round(3.5) = 4 pulses per cell
        assert_eq!(arr.pulse_count - before, 4 * 64);
    }

    #[test]
    fn alternating_pulses_drift_to_sp() {
        // The SP-attraction property that ZS exploits.
        let mut rng = Rng::from_seed(3);
        let dev = SoftBounds::from_gamma_rho(1.0, 0.3);
        let sp = dev.symmetric_point();
        let mut arr = DeviceArray::uniform(4, 4, &dev, 0.01, 0.0);
        for k in 0..2000 {
            arr.pulse_all(k % 2 == 0, &mut rng);
        }
        for &w in &arr.w {
            assert!((w as f64 - sp).abs() < 0.05, "w={w} sp={sp}");
        }
    }

    #[test]
    fn deterministic_update_matches_expected_value() {
        let dev = SoftBounds::from_gamma_rho(1.2, 0.1);
        let mut arr = DeviceArray::uniform(1, 1, &dev, 0.001, 0.0);
        arr.w[0] = 0.25;
        arr.analog_update_det(&[0.1]);
        let q = dev.q_plus(0.25);
        let want = 0.25 + 0.1 * q;
        assert!((arr.w[0] as f64 - want).abs() < 1e-3, "{} vs {want}", arr.w[0]);
    }

    #[test]
    fn stochastic_rounding_unbiased() {
        // E[update] must equal the desired dw * q even when |dw| < dw_min.
        let dev = SoftBounds::symmetric();
        let mut rng = Rng::from_seed(7);
        let mut sum = 0.0;
        let trials = 20_000;
        for _ in 0..trials {
            let mut arr = DeviceArray::uniform(1, 1, &dev, 0.01, 0.0);
            arr.analog_update(&[0.0037], &mut rng);
            sum += arr.w[0] as f64;
        }
        let mean = sum / trials as f64;
        assert!((mean - 0.0037).abs() < 2e-4, "{mean}");
    }

    #[test]
    fn program_reaches_target() {
        let mut rng = Rng::from_seed(9);
        let dev = SoftBounds::from_gamma_rho(1.0, 0.2);
        let mut arr = DeviceArray::uniform(2, 2, &dev, 1e-4, 0.0);
        let target = vec![0.5f32, -0.3, 0.1, 0.0];
        // a couple of programming iterations (response scales the step)
        for _ in 0..8 {
            arr.program(&target, &mut rng);
        }
        for (w, t) in arr.w.iter().zip(&target) {
            assert!((w - t).abs() < 0.02, "{w} vs {t}");
        }
    }
}
