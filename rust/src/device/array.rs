//! Crossbar device array: a tile of soft-bounds cells in SoA layout,
//! pulse-accurate. This is the substrate for the pulse-level experiments
//! (Fig. 1, Theorems 2.2/C.2) and the Rust-native algorithm family; it
//! mirrors the JAX device model exactly (parity-tested on shared vectors).
//!
//! The stochastic hot paths (`analog_update`, `pulse_all*`, `read_into`)
//! run a batched engine: noise for a block of cells is pre-filled into
//! stack slabs by the polar batch sampler, then applied by a
//! branch-light pass over the SoA slices — the serial kernels never
//! touch the heap and draw no per-cell trig. Large tiles fan
//! `analog_update` out to a row-chunked parallel path (which does
//! allocate per-call chunk bookkeeping and spawns scoped threads — it
//! trades a few allocations for core-count throughput); its per-chunk
//! RNG sub-streams are derived from the tile stream, so results depend
//! on the (fixed) chunk size but never on the machine's thread count.
//!
//! `analog_update_det` is the deterministic Python-parity mode and
//! keeps the original scalar arithmetic bit-for-bit — unconditionally.
//! `analog_update_ref` retains the scalar stochastic path as the
//! reference the equivalence tests compare against; note the batched
//! kernels use reciprocal multiplies where the scalar path divides, so
//! noise-free batched-vs-ref runs are bit-identical when `tau = 1`
//! (every shipped preset) and `dw_min` is a power of two (as in the
//! equivalence tests) and agree to the last ulp otherwise.

use crate::device::fault::FaultState;
use crate::device::presets::Preset;
use crate::device::response::SoftBounds;
use crate::util::metrics::{self, MetricId};
use crate::util::rng::Rng;

/// Cells per batched inner block: noise for a block is pre-filled into
/// stack slabs, then applied in a branch-light pass.
const BLOCK: usize = 256;

/// Rows per chunk of the parallel update path. Fixed (not derived from
/// the machine's thread count) so chunk sub-streams — and therefore
/// stochastic results — are reproducible on any machine.
pub const PAR_CHUNK_ROWS: usize = 64;

/// Minimum number of cells before `analog_update` fans out to the
/// row-chunked parallel path.
pub const PAR_MIN_CELLS: usize = 1 << 16;

/// Loop-invariant per-tile constants of the batched kernels
/// (reciprocals replace the per-cell divisions of the scalar path).
#[derive(Clone, Copy)]
struct TileParams {
    dw_min: f32,
    inv_dw_min: f32,
    /// c2c noise scale per aggregated pulse train (dw_min * c2c);
    /// exactly 0 when c2c is disabled, so the noise term vanishes
    nc: f32,
    c2c: f32,
    c2c_on: bool,
    inv_tau_max: f32,
    inv_tau_min: f32,
    lo: f32,
    hi: f32,
}

/// Polarity pattern of a batched pulse cycle.
#[derive(Clone, Copy, PartialEq, Eq)]
enum PulseDir {
    Up,
    Down,
    Random,
}

/// Batched aggregated-update kernel (paper Eq. 2) over one span of
/// cells: pre-fills per-block noise slabs from `rng`, then applies
/// stochastic rounding + c2c noise in a branch-light pass. Returns the
/// number of pulses sent.
fn update_span(
    w: &mut [f32],
    ap: &[f32],
    am: &[f32],
    dw: &[f32],
    p: &TileParams,
    rng: &mut Rng,
) -> u64 {
    let mut unif = [0.0f32; BLOCK];
    let mut nrm = [0.0f32; BLOCK];
    let mut pulses = 0u64;
    let mut start = 0;
    while start < w.len() {
        let n = (w.len() - start).min(BLOCK);
        rng.fill_uniform_f32(&mut unif[..n]);
        if p.c2c_on {
            rng.fill_normal_f32(&mut nrm[..n]);
        }
        for j in 0..n {
            let i = start + j;
            let d = dw[i];
            let wv = w[i];
            let up = d >= 0.0;
            let q = if up {
                (ap[i] * (1.0 - wv * p.inv_tau_max)).max(0.0)
            } else {
                (am[i] * (1.0 + wv * p.inv_tau_min)).max(0.0)
            };
            let pulses_f = d.abs() * p.inv_dw_min;
            let n_lo = pulses_f.floor();
            let np = n_lo + if unif[j] < pulses_f - n_lo { 1.0 } else { 0.0 };
            if np == 0.0 {
                continue;
            }
            // nc == 0 when c2c is off, so the noise term is exactly 0
            let delta = (np * p.dw_min + np.sqrt() * p.nc * nrm[j]) * q;
            let nw = if up { wv + delta } else { wv - delta };
            w[i] = nw.clamp(p.lo, p.hi);
            pulses += np as u64;
        }
        start += n;
    }
    pulses
}

/// Batched single-pulse cycle over one span of cells (the ZS inner
/// loop): one ±dw_min pulse per cell with pre-filled polarity / c2c
/// noise slabs.
fn pulse_span(
    w: &mut [f32],
    ap: &[f32],
    am: &[f32],
    dir: PulseDir,
    p: &TileParams,
    rng: &mut Rng,
) {
    let mut unif = [0.0f32; BLOCK];
    let mut nrm = [0.0f32; BLOCK];
    let mut start = 0;
    while start < w.len() {
        let n = (w.len() - start).min(BLOCK);
        if dir == PulseDir::Random {
            rng.fill_uniform_f32(&mut unif[..n]);
        }
        if p.c2c_on {
            rng.fill_normal_f32(&mut nrm[..n]);
        }
        for j in 0..n {
            let i = start + j;
            let wv = w[i];
            let up = match dir {
                PulseDir::Up => true,
                PulseDir::Down => false,
                PulseDir::Random => unif[j] < 0.5,
            };
            let q = if up {
                (ap[i] * (1.0 - wv * p.inv_tau_max)).max(0.0)
            } else {
                (am[i] * (1.0 + wv * p.inv_tau_min)).max(0.0)
            };
            let step = p.dw_min * q * (1.0 + p.c2c * nrm[j]);
            let nw = if up { wv + step } else { wv - step };
            w[i] = nw.clamp(p.lo, p.hi);
        }
        start += n;
    }
}

/// A crossbar tile: per-cell weights and device parameters, flat
/// row-major `rows x cols` storage.
#[derive(Clone, Debug)]
pub struct DeviceArray {
    /// Tile rows.
    pub rows: usize,
    /// Tile columns.
    pub cols: usize,
    /// Per-cell weights (conductances), row-major.
    pub w: Vec<f32>,
    /// Per-cell potentiation slopes α₊.
    pub alpha_p: Vec<f32>,
    /// Per-cell depression slopes α₋.
    pub alpha_m: Vec<f32>,
    /// Upper weight bound τ_max (shared by all cells).
    pub tau_max: f32,
    /// Lower weight bound magnitude τ_min (window is [-τ_min, τ_max]).
    pub tau_min: f32,
    /// response granularity (weight change per pulse at q = 1)
    pub dw_min: f32,
    /// cycle-to-cycle multiplicative noise std
    pub c2c: f32,
    /// pulses applied so far (pulse accounting)
    pub pulse_count: u64,
    /// reusable scratch for `program` (grown once, then allocation-free)
    scratch: Vec<f32>,
    /// armed fault mask (`device/fault.rs`), applied after every
    /// mutating path; `None` keeps every path bit-identical to a build
    /// without the chaos layer
    fault: Option<FaultState>,
}

impl DeviceArray {
    /// Sample a tile from a preset with a controlled SP distribution:
    /// per-cell SP ~ N(ref_mean, ref_std) (clipped inside the window),
    /// slope magnitude gamma ~ exp(sigma_gamma * N(0,1)).
    ///
    /// Normals come from the batched polar sampler
    /// (`Rng::fill_normal_f32`) rather than per-cell scalar draws —
    /// distribution-stable with the pre-batching construction, not
    /// draw-for-draw identical (the per-cell response math is
    /// unchanged f64).
    pub fn sample(
        rows: usize,
        cols: usize,
        preset: &Preset,
        ref_mean: f64,
        ref_std: f64,
        sigma_gamma: f64,
        rng: &mut Rng,
    ) -> Self {
        let n = rows * cols;
        let mut z_gamma = vec![0.0f32; n];
        let mut z_sp = vec![0.0f32; n];
        rng.fill_normal_f32(&mut z_gamma);
        rng.fill_normal_f32(&mut z_sp);
        let mut ap = Vec::with_capacity(n);
        let mut am = Vec::with_capacity(n);
        let floor = 0.05f64;
        for (&zg, &zs) in z_gamma.iter().zip(&z_sp) {
            let gamma = (sigma_gamma * zg as f64).exp();
            let sp = (ref_mean + ref_std * zs as f64)
                .clamp(-0.85 * preset.tau_min, 0.85 * preset.tau_max);
            let rho = gamma * sp / preset.tau_max;
            ap.push(((gamma + rho).max(floor)) as f32);
            am.push(((gamma - rho).max(floor)) as f32);
        }
        Self {
            rows,
            cols,
            w: vec![0.0; n],
            alpha_p: ap,
            alpha_m: am,
            tau_max: preset.tau_max as f32,
            tau_min: preset.tau_min as f32,
            dw_min: preset.dw_min as f32,
            c2c: preset.c2c as f32,
            pulse_count: 0,
            scratch: Vec::new(),
            fault: None,
        }
    }

    /// A uniform tile where every cell shares one response model.
    pub fn uniform(rows: usize, cols: usize, dev: &SoftBounds, dw_min: f64, c2c: f64) -> Self {
        let n = rows * cols;
        Self {
            rows,
            cols,
            w: vec![0.0; n],
            alpha_p: vec![dev.alpha_p as f32; n],
            alpha_m: vec![dev.alpha_m as f32; n],
            tau_max: dev.tau_max as f32,
            tau_min: dev.tau_min as f32,
            dw_min: dw_min as f32,
            c2c: c2c as f32,
            pulse_count: 0,
            scratch: Vec::new(),
            fault: None,
        }
    }

    /// Arm a compiled fault mask: stuck pins snap immediately (a real
    /// defect is present before the next update), then the mask is
    /// re-applied after every mutating path. See `device/fault.rs`.
    pub fn arm_faults(&mut self, state: FaultState) {
        for &(i, v) in &state.stuck {
            self.w[i as usize] = v;
        }
        self.fault = Some(state);
    }

    /// Disarm the fault mask (already-pinned weights keep their last
    /// value; subsequent updates move them freely again).
    pub fn clear_faults(&mut self) {
        self.fault = None;
    }

    /// The armed fault mask, if any.
    pub fn fault_state(&self) -> Option<&FaultState> {
        self.fault.as_ref()
    }

    /// Post-update fault hook: one `None` check on the clean path.
    #[inline]
    fn apply_faults(&mut self) {
        if let Some(f) = &self.fault {
            f.apply(&mut self.w);
        }
    }

    /// Number of cells in the tile.
    pub fn len(&self) -> usize {
        self.w.len()
    }

    /// Whether the tile holds no cells.
    pub fn is_empty(&self) -> bool {
        self.w.is_empty()
    }

    fn params(&self) -> TileParams {
        TileParams {
            dw_min: self.dw_min,
            inv_dw_min: 1.0 / self.dw_min,
            nc: self.dw_min * self.c2c,
            c2c: self.c2c,
            c2c_on: self.c2c > 0.0,
            inv_tau_max: 1.0 / self.tau_max,
            inv_tau_min: 1.0 / self.tau_min,
            lo: -self.tau_min,
            hi: self.tau_max,
        }
    }

    /// Per-cell response model.
    pub fn cell(&self, i: usize) -> SoftBounds {
        SoftBounds::new(
            self.alpha_p[i] as f64,
            self.alpha_m[i] as f64,
            self.tau_max as f64,
            self.tau_min as f64,
        )
    }

    /// Ground-truth SP of every cell, written into `out` — the
    /// soft-bounds closed form inlined (no per-cell `SoftBounds`
    /// construction), bit-identical to `cell(i).symmetric_point()`.
    pub fn symmetric_points_into(&self, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.len());
        let tmax = self.tau_max as f64;
        let tmin = self.tau_min as f64;
        for i in 0..self.len() {
            let ap = self.alpha_p[i] as f64;
            let am = self.alpha_m[i] as f64;
            out[i] = ((ap - am) / (ap / tmax + am / tmin)) as f32;
        }
    }

    /// Ground-truth SP of every cell (allocating wrapper).
    pub fn symmetric_points(&self) -> Vec<f32> {
        let mut out = vec![0.0; self.len()];
        self.symmetric_points_into(&mut out);
        out
    }

    #[inline]
    fn q_at(&self, i: usize, w: f32, up: bool) -> f32 {
        if up {
            (self.alpha_p[i] * (1.0 - w / self.tau_max)).max(0.0)
        } else {
            (self.alpha_m[i] * (1.0 + w / self.tau_min)).max(0.0)
        }
    }

    /// Apply a single ±dw_min pulse to cell `i` (the scalar hardware
    /// primitive; the batched cycles below are its vectorized form).
    #[inline]
    pub fn pulse_cell(&mut self, i: usize, up: bool, rng: &mut Rng) {
        let w = self.w[i];
        let q = self.q_at(i, w, up);
        let noise = if self.c2c > 0.0 {
            1.0 + self.c2c * rng.normal() as f32
        } else {
            1.0
        };
        let step = self.dw_min * q * noise;
        let nw = if up { w + step } else { w - step };
        self.w[i] = nw.clamp(-self.tau_min, self.tau_max);
        self.pulse_count += 1;
        self.apply_faults();
    }

    /// One ZS cycle: apply the same polarity to every cell (batched).
    pub fn pulse_all(&mut self, up: bool, rng: &mut Rng) {
        let p = self.params();
        let dir = if up { PulseDir::Up } else { PulseDir::Down };
        pulse_span(&mut self.w, &self.alpha_p, &self.alpha_m, dir, &p, rng);
        self.pulse_count += self.w.len() as u64;
        metrics::counter(MetricId::DevicePulsesTotal, self.w.len() as u64);
        self.apply_faults();
    }

    /// One stochastic ZS cycle: independent random polarity per cell.
    pub fn pulse_all_random(&mut self, rng: &mut Rng) {
        let p = self.params();
        pulse_span(&mut self.w, &self.alpha_p, &self.alpha_m, PulseDir::Random, &p, rng);
        self.pulse_count += self.w.len() as u64;
        metrics::counter(MetricId::DevicePulsesTotal, self.w.len() as u64);
        self.apply_faults();
    }

    /// Analog Update (paper Eq. 2): realise the desired per-cell
    /// increment `dw` as a stochastically-rounded pulse train with c2c
    /// noise — the aggregated (single-shot) model shared with the JAX
    /// kernel. Counts the pulses it would have sent. Batched; large
    /// tiles fan out to the row-chunked parallel path.
    pub fn analog_update(&mut self, dw: &[f32], rng: &mut Rng) {
        debug_assert_eq!(dw.len(), self.len());
        if self.len() >= PAR_MIN_CELLS && self.rows > PAR_CHUNK_ROWS {
            self.analog_update_chunked(dw, rng);
        } else {
            let p = self.params();
            let sent = update_span(&mut self.w, &self.alpha_p, &self.alpha_m, dw, &p, rng);
            self.pulse_count += sent;
            metrics::counter(MetricId::DevicePulsesTotal, sent);
        }
        self.apply_faults();
    }

    /// Row-chunked parallel aggregated update for large tiles. Chunks
    /// are `PAR_CHUNK_ROWS` rows each; chunk `k` draws its noise from an
    /// independent sub-stream `Rng::new(base, k)` where `base` is a
    /// single draw from the tile stream — results depend only on the
    /// chunk size, never on how many worker threads the machine has.
    fn analog_update_chunked(&mut self, dw: &[f32], rng: &mut Rng) {
        struct Job<'a> {
            idx: u64,
            w: &'a mut [f32],
            ap: &'a [f32],
            am: &'a [f32],
            dw: &'a [f32],
        }
        let span = PAR_CHUNK_ROWS * self.cols;
        let base = rng.next_u64();
        let p = self.params();
        let n_chunks = (self.len() + span - 1) / span;
        let workers = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(n_chunks)
            .max(1);
        let mut buckets: Vec<Vec<Job>> = (0..workers).map(|_| Vec::new()).collect();
        for (k, (((w, ap), am), d)) in self
            .w
            .chunks_mut(span)
            .zip(self.alpha_p.chunks(span))
            .zip(self.alpha_m.chunks(span))
            .zip(dw.chunks(span))
            .enumerate()
        {
            buckets[k % workers].push(Job { idx: k as u64, w, ap, am, dw: d });
        }
        let sent: u64 = std::thread::scope(|s| {
            let handles: Vec<_> = buckets
                .into_iter()
                .map(|bucket| {
                    s.spawn(move || {
                        let mut pulses = 0u64;
                        for job in bucket {
                            let mut sub = Rng::new(base, job.idx);
                            pulses += update_span(job.w, job.ap, job.am, job.dw, &p, &mut sub);
                        }
                        pulses
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).sum()
        });
        self.pulse_count += sent;
        metrics::counter(MetricId::DevicePulsesTotal, sent);
    }

    /// Scalar reference implementation of [`DeviceArray::analog_update`]
    /// — the pre-batching code path, one cell and one f64 RNG draw at a
    /// time. Retained for the batched-engine equivalence tests
    /// (`rust/tests/batched_engine.rs`); not a hot path.
    pub fn analog_update_ref(&mut self, dw: &[f32], rng: &mut Rng) {
        debug_assert_eq!(dw.len(), self.len());
        let before = self.pulse_count;
        let dwm = self.dw_min;
        for i in 0..self.len() {
            let d = dw[i];
            if d == 0.0 {
                continue;
            }
            let up = d >= 0.0;
            let q = self.q_at(i, self.w[i], up);
            let mag = d.abs();
            let pulses_f = mag / dwm;
            let n_lo = pulses_f.floor();
            let frac = pulses_f - n_lo;
            let n = n_lo + if (rng.uniform() as f32) < frac { 1.0 } else { 0.0 };
            if n == 0.0 {
                continue;
            }
            let c2c = if self.c2c > 0.0 {
                n.sqrt() * dwm * self.c2c * rng.normal() as f32
            } else {
                0.0
            };
            let delta = (n * dwm + c2c) * q;
            let nw = if up { self.w[i] + delta } else { self.w[i] - delta };
            self.w[i] = nw.clamp(-self.tau_min, self.tau_max);
            self.pulse_count += n as u64;
        }
        metrics::counter(MetricId::DevicePulsesTotal, self.pulse_count - before);
        self.apply_faults();
    }

    /// Deterministic variant (round-to-nearest, no noise) — the parity
    /// mode shared with `kernels/ref.py`. Bit-stable: keeps the original
    /// scalar arithmetic untouched (the fault hook is a no-op unless a
    /// mask is armed).
    pub fn analog_update_det(&mut self, dw: &[f32]) {
        let before = self.pulse_count;
        let dwm = self.dw_min;
        for i in 0..self.len() {
            let d = dw[i];
            let up = d >= 0.0;
            let q = self.q_at(i, self.w[i], up);
            let n = (d.abs() / dwm).round();
            if n == 0.0 {
                continue;
            }
            let delta = n * dwm * q;
            let nw = if up { self.w[i] + delta } else { self.w[i] - delta };
            self.w[i] = nw.clamp(-self.tau_min, self.tau_max);
            self.pulse_count += n as u64;
        }
        metrics::counter(MetricId::DevicePulsesTotal, self.pulse_count - before);
        self.apply_faults();
    }

    /// Noisy read-out of the full tile into a caller-owned buffer
    /// (allocation-free; batch-sampled read noise).
    pub fn read_into(&self, read_noise: f64, rng: &mut Rng, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.len());
        out.copy_from_slice(&self.w);
        if read_noise > 0.0 {
            rng.add_normal_f32(out, read_noise as f32);
        }
    }

    /// Noisy read-out of the full tile (allocating wrapper).
    pub fn read(&self, read_noise: f64, rng: &mut Rng) -> Vec<f32> {
        let mut out = vec![0.0; self.len()];
        self.read_into(read_noise, rng, &mut out);
        out
    }

    /// Program the tile to target weights (counts programming pulses).
    /// The increment is staged in an internal scratch buffer, so repeat
    /// calls are allocation-free.
    pub fn program(&mut self, target: &[f32], rng: &mut Rng) {
        debug_assert_eq!(target.len(), self.len());
        let mut buf = std::mem::take(&mut self.scratch);
        buf.resize(self.len(), 0.0);
        for ((b, t), w) in buf.iter_mut().zip(target).zip(&self.w) {
            *b = t - w;
        }
        self.analog_update(&buf, rng);
        self.scratch = buf;
    }

    /// Mean asymmetric magnitude ||G(w)||^2 / n over the tile — the
    /// Theorem 2.2 convergence metric. The soft-bounds G is inlined
    /// (no per-cell `SoftBounds` construction), bit-identical to
    /// `cell(i).g_asym(w)`.
    pub fn mean_g_sq(&self) -> f64 {
        let tmax = self.tau_max as f64;
        let tmin = self.tau_min as f64;
        let mut s = 0.0;
        for i in 0..self.len() {
            let w = self.w[i] as f64;
            let qp = (self.alpha_p[i] as f64 * (1.0 - w / tmax)).max(0.0);
            let qm = (self.alpha_m[i] as f64 * (1.0 + w / tmin)).max(0.0);
            let g = 0.5 * (qm - qp);
            s += g * g;
        }
        s / self.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::presets;
    use crate::device::response::Response;
    use crate::prop_assert;
    use crate::util::prop;

    fn small(rng: &mut Rng) -> DeviceArray {
        DeviceArray::sample(8, 8, &presets::preset("om").unwrap(), 0.3, 0.2, 0.1, rng)
    }

    #[test]
    fn sample_controls_sp() {
        let mut rng = Rng::from_seed(1);
        let arr = DeviceArray::sample(
            64,
            64,
            &presets::preset("precise").unwrap(),
            0.4,
            0.1,
            0.1,
            &mut rng,
        );
        let sps = arr.symmetric_points();
        let mean = sps.iter().map(|&x| x as f64).sum::<f64>() / sps.len() as f64;
        assert!((mean - 0.4).abs() < 0.02, "{mean}");
    }

    #[test]
    fn symmetric_points_match_cell_closed_form() {
        let mut rng = Rng::from_seed(4);
        let arr = small(&mut rng);
        let sps = arr.symmetric_points();
        for i in 0..arr.len() {
            assert_eq!(sps[i], arr.cell(i).symmetric_point() as f32, "cell {i}");
        }
    }

    #[test]
    fn mean_g_sq_matches_cell_response() {
        let mut rng = Rng::from_seed(5);
        let mut arr = small(&mut rng);
        for _ in 0..20 {
            arr.pulse_all_random(&mut rng);
        }
        let want = (0..arr.len())
            .map(|i| arr.cell(i).g_asym(arr.w[i] as f64).powi(2))
            .sum::<f64>()
            / arr.len() as f64;
        assert_eq!(arr.mean_g_sq(), want);
    }

    #[test]
    fn pulses_stay_in_window() {
        prop::check("bounds", 20, |rng| {
            let mut arr = small(rng);
            for _ in 0..200 {
                arr.pulse_all_random(rng);
            }
            prop_assert!(arr
                .w
                .iter()
                .all(|&w| (-arr.tau_min..=arr.tau_max).contains(&w)));
            Ok(())
        });
    }

    #[test]
    fn pulse_count_accounting() {
        let mut rng = Rng::from_seed(2);
        let mut arr = small(&mut rng);
        arr.pulse_all(true, &mut rng);
        assert_eq!(arr.pulse_count, 64);
        let dw = vec![3.5 * arr.dw_min; arr.len()];
        let before = arr.pulse_count;
        arr.analog_update_det(&dw);
        // round(3.5) = 4 pulses per cell
        assert_eq!(arr.pulse_count - before, 4 * 64);
    }

    #[test]
    fn alternating_pulses_drift_to_sp() {
        // The SP-attraction property that ZS exploits.
        let mut rng = Rng::from_seed(3);
        let dev = SoftBounds::from_gamma_rho(1.0, 0.3);
        let sp = dev.symmetric_point();
        let mut arr = DeviceArray::uniform(4, 4, &dev, 0.01, 0.0);
        for k in 0..2000 {
            arr.pulse_all(k % 2 == 0, &mut rng);
        }
        for &w in &arr.w {
            assert!((w as f64 - sp).abs() < 0.05, "w={w} sp={sp}");
        }
    }

    #[test]
    fn deterministic_update_matches_expected_value() {
        let dev = SoftBounds::from_gamma_rho(1.2, 0.1);
        let mut arr = DeviceArray::uniform(1, 1, &dev, 0.001, 0.0);
        arr.w[0] = 0.25;
        arr.analog_update_det(&[0.1]);
        let q = dev.q_plus(0.25);
        let want = 0.25 + 0.1 * q;
        assert!((arr.w[0] as f64 - want).abs() < 1e-3, "{} vs {want}", arr.w[0]);
    }

    #[test]
    fn stochastic_rounding_unbiased() {
        // E[update] must equal the desired dw * q even when |dw| < dw_min.
        let dev = SoftBounds::symmetric();
        let mut rng = Rng::from_seed(7);
        let mut sum = 0.0;
        let trials = 20_000;
        for _ in 0..trials {
            let mut arr = DeviceArray::uniform(1, 1, &dev, 0.01, 0.0);
            arr.analog_update(&[0.0037], &mut rng);
            sum += arr.w[0] as f64;
        }
        let mean = sum / trials as f64;
        assert!((mean - 0.0037).abs() < 2e-4, "{mean}");
    }

    #[test]
    fn program_reaches_target() {
        let mut rng = Rng::from_seed(9);
        let dev = SoftBounds::from_gamma_rho(1.0, 0.2);
        let mut arr = DeviceArray::uniform(2, 2, &dev, 1e-4, 0.0);
        let target = vec![0.5f32, -0.3, 0.1, 0.0];
        // a couple of programming iterations (response scales the step)
        for _ in 0..8 {
            arr.program(&target, &mut rng);
        }
        for (w, t) in arr.w.iter().zip(&target) {
            assert!((w - t).abs() < 0.02, "{w} vs {t}");
        }
    }
}
