//! `analog-rider` — Rust + JAX + Pallas reproduction of
//! "Dynamic Symmetric Point Tracking: Tackling Non-ideal Reference in
//! Analog In-memory Training" (RIDER / E-RIDER).
//!
//! Layers (see DESIGN.md):
//! * L1/L2 (build-time Python): Pallas kernels + JAX models/algorithms,
//!   AOT-lowered to HLO text artifacts.
//! * L3 (this crate): pulse-accurate device substrate, the algorithm
//!   family at pulse level (unified behind `analog::AnalogOptimizer`
//!   and its name registry), the PJRT runtime that executes the AOT
//!   artifacts, the training coordinator, and the experiment harness
//!   that regenerates every figure and table of the paper.

#![forbid(unsafe_code)]

pub mod analog;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod device;
pub mod optim;
pub mod runtime;
pub mod train;
pub mod util;
