//! Hand-rolled CLI argument parser (clap is unavailable offline).
//!
//! Grammar: `rider <subcommand> [--flag value]... [--switch]...`
//! Values are typed lazily (`get_f64`, `get_usize`, ...), with defaults
//! supplied at the call site so every experiment documents its knobs.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: String,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse from an explicit token list (testable) — first token is the
    /// subcommand, the rest `--key value` or bare `--switch` pairs.
    pub fn parse_tokens(tokens: &[String]) -> Result<Args, String> {
        let mut args = Args::default();
        let mut it = tokens.iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                args.subcommand = it.next().unwrap().clone();
            }
        }
        while let Some(tok) = it.next() {
            let Some(key) = tok.strip_prefix("--") else {
                return Err(format!("unexpected positional argument '{}'", tok));
            };
            if let Some((k, v)) = key.split_once('=') {
                args.flags.insert(k.to_string(), v.to_string());
                continue;
            }
            match it.peek() {
                Some(next) if !next.starts_with("--") => {
                    args.flags.insert(key.to_string(), it.next().unwrap().clone());
                }
                _ => args.switches.push(key.to_string()),
            }
        }
        Ok(args)
    }

    pub fn from_env() -> Result<Args, String> {
        let toks: Vec<String> = std::env::args().skip(1).collect();
        Args::parse_tokens(&toks)
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch) || self.flags.contains_key(switch)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    /// Comma-separated string list (`--methods sgd,ttv2,erider`).
    pub fn get_str_list(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.get(key) {
            None => default.iter().map(|s| s.to_string()).collect(),
            Some(s) => s
                .split(',')
                .map(|t| t.trim())
                .filter(|t| !t.is_empty())
                .map(|t| t.to_string())
                .collect(),
        }
    }

    /// Comma-separated f64 list.
    pub fn get_f64_list(&self, key: &str, default: &[f64]) -> Vec<f64> {
        match self.get(key) {
            None => default.to_vec(),
            Some(s) => s
                .split(',')
                .filter(|t| !t.is_empty())
                .filter_map(|t| t.trim().parse().ok())
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(|t| t.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_flags_switches() {
        let a = Args::parse_tokens(&toks("train --model fcn --steps 500 --verbose")).unwrap();
        assert_eq!(a.subcommand, "train");
        assert_eq!(a.get("model"), Some("fcn"));
        assert_eq!(a.get_usize("steps", 0), 500);
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn equals_form() {
        let a = Args::parse_tokens(&toks("x --lr=0.5 --list=1,2,3")).unwrap();
        assert_eq!(a.get_f64("lr", 0.0), 0.5);
        assert_eq!(a.get_f64_list("list", &[]), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn str_lists() {
        let a = Args::parse_tokens(&toks("x --methods sgd,ttv2,,erider")).unwrap();
        assert_eq!(a.get_str_list("methods", &[]), vec!["sgd", "ttv2", "erider"]);
        assert_eq!(a.get_str_list("missing", &["a", "b"]), vec!["a", "b"]);
    }

    #[test]
    fn negative_values() {
        let a = Args::parse_tokens(&toks("x --mean=-0.4")).unwrap();
        assert_eq!(a.get_f64("mean", 0.0), -0.4);
    }

    #[test]
    fn rejects_positional() {
        assert!(Args::parse_tokens(&toks("x stray")).is_err());
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse_tokens(&toks("run")).unwrap();
        assert_eq!(a.get_f64("missing", 1.5), 1.5);
        assert_eq!(a.get_str("m", "fcn"), "fcn");
    }
}
