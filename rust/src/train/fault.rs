//! NN-scale fault injection and self-healing recovery.
//!
//! The pulse-level chaos layer lives in [`crate::device::fault`]; this
//! module lifts it to the HLO training path, where the crossbar state
//! is a set of flat host tensors (one per manifest leaf) rather than a
//! live [`crate::device::DeviceArray`]. A [`NnFaultInjector`] compiles
//! a [`FaultPlan`] against the model manifest once — per-element SPs
//! are reconstructed from the `wap`/`wam` (and `pap`/`pam`) slope
//! leaves, and each leaf gets its own sub-stream `Rng::new(plan.seed,
//! leaf_index)` — and is then applied as a pure post-step mask on
//! [`ModelState`], exactly mirroring the post-update hook the device
//! arrays use.
//!
//! On top sit the recovery primitives: a loss-spike monitor and an
//! SP-residual probe for detection, a [`RecoveryPolicy`] budget, and an
//! atomic, crash-consistent [`Checkpoint`] of the model state plus
//! pulse accounting, so a recovery (or a crash) can rewind training to
//! a known-good point bit-for-bit.
//!
//! ADC-family plans are a no-op at this level: the IO chain is baked
//! into the AOT artifacts, so ADC faults only exist on the pulse-level
//! substrate (`IoChain::adc_offset`/`adc_sat`).

use anyhow::{anyhow, Result};
use std::fs;
use std::io::{Read, Write};
use std::path::Path;

use crate::analog::pulse_counter::PulseCost;
use crate::device::fault::{FaultPlan, FaultState};
use crate::runtime::ModelSpec;
use crate::train::hypers::DevParams;
use crate::train::state::ModelState;
use crate::util::rng::Rng;

/// The analog roles that live on physical crossbars at NN scale, with
/// the slope-leaf roles their per-element SPs are derived from.
const ANALOG_ROLES: [(&str, &str, &str); 2] = [("w", "wap", "wam"), ("p", "pap", "pam")];

/// Per-element symmetric point from the device slope maps:
/// `sp = (a+ - a-)/(a+/tau_max + a-/tau_min)` (paper Eq. 3 rearranged),
/// with a zero fallback when the denominator vanishes.
fn sp_from_slopes(ap: f32, am: f32, tau_max: f32, tau_min: f32) -> f32 {
    let den = ap / tau_max + am / tau_min;
    if den.abs() < 1e-12 {
        0.0
    } else {
        (ap - am) / den
    }
}

fn leaf_by_role_tile(spec: &ModelSpec, role: &str, tile: usize) -> Option<usize> {
    spec.state
        .iter()
        .position(|l| l.role == role && l.tile == tile)
}

/// A [`FaultPlan`] compiled against a model manifest: one
/// [`FaultState`] per analog leaf, applied to the flat state tensors
/// after every optimizer step. Compilation consumes all randomness;
/// [`NnFaultInjector::apply`] is deterministic and allocation-free.
#[derive(Clone, Debug)]
pub struct NnFaultInjector {
    /// `(leaf index, compiled mask)` for every faulted analog leaf.
    masks: Vec<(usize, FaultState)>,
    /// Sorted, deduplicated tile indices with at least one faulty cell
    /// — the recovery layer's work list.
    tiles: Vec<usize>,
}

impl NnFaultInjector {
    /// Compile `plan` against the manifest. Leaf `i` (with an analog
    /// role) compiles from the sub-stream `Rng::new(plan.seed, i)`, so
    /// the result is independent of iteration order and of which other
    /// leaves exist. The conductance window is `[-dev.tau_min,
    /// dev.tau_max]`, as on the pulse-level arrays.
    pub fn compile(
        plan: &FaultPlan,
        spec: &ModelSpec,
        state: &ModelState,
        dev: &DevParams,
    ) -> NnFaultInjector {
        let mut masks = Vec::new();
        let mut tiles = Vec::new();
        for (i, leaf) in spec.state.iter().enumerate() {
            let Some((_, ap_role, am_role)) =
                ANALOG_ROLES.iter().find(|(r, _, _)| leaf.role == *r)
            else {
                continue;
            };
            let n = leaf.numel();
            let (rows, cols) = if leaf.shape.len() >= 2 && leaf.shape[0] > 0 {
                (leaf.shape[0], n / leaf.shape[0])
            } else {
                (1, n)
            };
            let ap = leaf_by_role_tile(spec, ap_role, leaf.tile);
            let am = leaf_by_role_tile(spec, am_role, leaf.tile);
            let sp: Vec<f32> = match (ap, am) {
                (Some(ap), Some(am)) => (0..n)
                    .map(|j| {
                        sp_from_slopes(
                            state.leaves[ap][j],
                            state.leaves[am][j],
                            dev.tau_max,
                            dev.tau_min,
                        )
                    })
                    .collect(),
                _ => vec![0.0; n],
            };
            let mut sub = Rng::new(plan.seed, i as u64);
            let st = plan.compile(rows, cols, &sp, -dev.tau_min, dev.tau_max, &mut sub);
            if !st.is_empty() {
                tiles.push(leaf.tile);
                masks.push((i, st));
            }
        }
        tiles.sort_unstable();
        tiles.dedup();
        NnFaultInjector { masks, tiles }
    }

    /// Apply the compiled masks to the state (call after each step).
    /// Stuck pins snap immediately; drift cells relax one step.
    pub fn apply(&self, state: &mut ModelState) {
        for (i, st) in &self.masks {
            st.apply(&mut state.leaves[*i]);
        }
    }

    /// Tiles with at least one faulty cell — what selective
    /// recalibration should target.
    pub fn affected_tiles(&self) -> &[usize] {
        &self.tiles
    }

    /// Total number of faulty cells across all leaves.
    pub fn n_faulty(&self) -> usize {
        self.masks.iter().map(|(_, s)| s.n_faulty()).sum()
    }

    /// Whether the compiled plan injects nothing.
    pub fn is_empty(&self) -> bool {
        self.masks.is_empty()
    }
}

/// Mean absolute gap between the stored reference `q` and the *actual*
/// per-element SP of the fast array `p` (reconstructed from the
/// `pap`/`pam` slopes) — the detection signal the paper's SP-tracking
/// argument suggests: drift faults move the effective SP landscape
/// away from whatever was calibrated. Returns 0 when the manifest has
/// no `(p, q)` tile pairs.
pub fn sp_residual(spec: &ModelSpec, state: &ModelState, dev: &DevParams) -> f64 {
    sp_residual_leaves(spec, &state.leaves, dev)
}

/// `sp_residual` over bare leaf vectors in manifest order, for callers
/// (the pipelined trainer) that hold state outside a `ModelState`.
pub fn sp_residual_leaves(spec: &ModelSpec, leaves: &[Vec<f32>], dev: &DevParams) -> f64 {
    let mut sum = 0.0f64;
    let mut n = 0usize;
    for leaf in &spec.state {
        if leaf.role != "p" {
            continue;
        }
        let (Some(ap), Some(am), Some(q)) = (
            leaf_by_role_tile(spec, "pap", leaf.tile),
            leaf_by_role_tile(spec, "pam", leaf.tile),
            leaf_by_role_tile(spec, "q", leaf.tile),
        ) else {
            continue;
        };
        for j in 0..leaf.numel().min(leaves[q].len()) {
            let sp = sp_from_slopes(leaves[ap][j], leaves[am][j], dev.tau_max, dev.tau_min);
            sum += (sp - leaves[q][j]).abs() as f64;
            n += 1;
        }
    }
    if n == 0 {
        0.0
    } else {
        sum / n as f64
    }
}

/// EMA-based loss-spike detector: fires when the instantaneous loss
/// exceeds `factor` times the running EMA after a warmup period. The
/// EMA uses the trainer's own 0.95/0.05 smoothing so the two curves
/// are directly comparable.
#[derive(Clone, Copy, Debug)]
pub struct LossSpikeMonitor {
    ema: f64,
    factor: f64,
    warmup: usize,
    seen: usize,
}

impl LossSpikeMonitor {
    /// `factor` = spike threshold relative to the EMA; `warmup` = steps
    /// observed before the monitor may fire.
    pub fn new(factor: f64, warmup: usize) -> Self {
        Self {
            ema: f64::NAN,
            factor,
            warmup,
            seen: 0,
        }
    }

    /// Feed one training loss; returns `true` on a spike. The spike
    /// test runs against the EMA *before* this observation so a single
    /// bad step cannot mask itself.
    pub fn observe(&mut self, loss: f64) -> bool {
        self.seen += 1;
        let spiked = self.seen > self.warmup
            && self.ema.is_finite()
            && loss.is_finite()
            && loss > self.factor * self.ema;
        // a non-finite loss is itself a spike, and must not poison the EMA
        if !loss.is_finite() {
            return self.seen > self.warmup;
        }
        self.ema = if self.ema.is_nan() {
            loss
        } else {
            0.95 * self.ema + 0.05 * loss
        };
        spiked
    }

    /// Current EMA of the observed losses.
    pub fn ema(&self) -> f64 {
        self.ema
    }
}

/// Budgeted recovery policy: how many ZS pulses a recalibration may
/// spend per tile, how many recoveries a run may attempt, and the
/// minimum step gap between attempts (so one persistent fault cannot
/// burn the whole pulse budget in consecutive steps).
#[derive(Clone, Copy, Debug)]
pub struct RecoveryPolicy {
    /// ZS pulse cycles per recalibrated tile.
    pub zs_pulses: u64,
    /// Maximum number of recovery attempts per training run.
    pub max_recoveries: u32,
    /// Minimum steps between two recovery attempts.
    pub cooldown: usize,
}

impl Default for RecoveryPolicy {
    fn default() -> Self {
        Self {
            zs_pulses: 500,
            max_recoveries: 3,
            cooldown: 25,
        }
    }
}

impl RecoveryPolicy {
    /// Whether another recovery is allowed given the attempts so far
    /// and the steps elapsed since the last one.
    pub fn allows(&self, attempts: u32, steps_since_last: usize) -> bool {
        attempts < self.max_recoveries && steps_since_last >= self.cooldown
    }
}

const CKPT_MAGIC: u64 = 0x5250_434B_5054_0001; // "RPCKPT" + version 1

/// A crash-consistent snapshot of a training run: the model state
/// tensors plus everything needed to resume bit-for-bit (the artifact
/// key counter and the pulse accounting). Saved atomically — the file
/// is fully written and synced under a temporary name, then renamed
/// into place, so a reader never observes a torn checkpoint.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    /// Step index the snapshot was taken at.
    pub step: u64,
    /// The trainer's artifact key counter (RNG stream position).
    pub key_counter: u64,
    /// Pulse accounting at snapshot time (calibration + recovery).
    pub cost: PulseCost,
    /// One flat tensor per manifest leaf, in manifest order.
    pub leaves: Vec<Vec<f32>>,
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn get_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

impl Checkpoint {
    /// Serialize to a little-endian, length-prefixed binary buffer.
    fn to_bytes(&self) -> Vec<u8> {
        let payload: usize = self.leaves.iter().map(|l| 8 + 4 * l.len()).sum();
        let mut buf = Vec::with_capacity(8 * 7 + payload);
        put_u64(&mut buf, CKPT_MAGIC);
        put_u64(&mut buf, self.step);
        put_u64(&mut buf, self.key_counter);
        put_u64(&mut buf, self.cost.update_pulses);
        put_u64(&mut buf, self.cost.calibration_pulses);
        put_u64(&mut buf, self.cost.programming_events);
        put_u64(&mut buf, self.cost.digital_ops);
        put_u64(&mut buf, self.leaves.len() as u64);
        for leaf in &self.leaves {
            put_u64(&mut buf, leaf.len() as u64);
            for &v in leaf {
                buf.extend_from_slice(&v.to_le_bytes());
            }
        }
        buf
    }

    /// Atomically write the checkpoint to `path` (write + sync a
    /// sibling `.tmp`, then rename over the target).
    pub fn save(&self, path: &Path) -> Result<()> {
        let tmp = path.with_extension("tmp");
        {
            let mut f = fs::File::create(&tmp)
                .map_err(|e| anyhow!("checkpoint {}: {e}", tmp.display()))?;
            f.write_all(&self.to_bytes())?;
            f.sync_all()?;
        }
        fs::rename(&tmp, path)
            .map_err(|e| anyhow!("checkpoint rename to {}: {e}", path.display()))?;
        Ok(())
    }

    /// Load a checkpoint written by [`Checkpoint::save`].
    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut f = fs::File::open(path)
            .map_err(|e| anyhow!("checkpoint {}: {e}", path.display()))?;
        if get_u64(&mut f)? != CKPT_MAGIC {
            return Err(anyhow!("{}: not a checkpoint file", path.display()));
        }
        let step = get_u64(&mut f)?;
        let key_counter = get_u64(&mut f)?;
        let cost = PulseCost {
            update_pulses: get_u64(&mut f)?,
            calibration_pulses: get_u64(&mut f)?,
            programming_events: get_u64(&mut f)?,
            digital_ops: get_u64(&mut f)?,
        };
        let n_leaves = get_u64(&mut f)? as usize;
        if n_leaves > 1 << 20 {
            return Err(anyhow!("{}: implausible leaf count {n_leaves}", path.display()));
        }
        let mut leaves = Vec::with_capacity(n_leaves);
        for _ in 0..n_leaves {
            let len = get_u64(&mut f)? as usize;
            if len > 1 << 28 {
                return Err(anyhow!("{}: implausible leaf length {len}", path.display()));
            }
            let mut bytes = vec![0u8; 4 * len];
            f.read_exact(&mut bytes)?;
            let leaf = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            leaves.push(leaf);
        }
        Ok(Checkpoint {
            step,
            key_counter,
            cost,
            leaves,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::fault::FaultFamily;
    use crate::runtime::{ModelSpec, StateLeaf};

    fn leaf(name: &str, shape: Vec<usize>, role: &str, tile: usize) -> StateLeaf {
        StateLeaf {
            name: name.into(),
            shape,
            role: role.into(),
            tile,
        }
    }

    /// A two-tile manifest with full analog role sets.
    fn spec() -> ModelSpec {
        let mut state = Vec::new();
        for t in 0..2usize {
            for role in ["w", "wap", "wam", "p", "pap", "pam", "q"] {
                state.push(leaf(&format!("t{t}.{role}"), vec![4, 4], role, t));
            }
        }
        state.push(leaf("b", vec![4], "bias", 0));
        ModelSpec {
            name: "toy".into(),
            batch: 2,
            eval_batch: 2,
            d_in: 4,
            n_classes: 4,
            state,
        }
    }

    fn state_for(spec: &ModelSpec) -> ModelState {
        let leaves = spec
            .state
            .iter()
            .map(|l| {
                let v = match l.role.as_str() {
                    "wap" | "pap" => 1.2,
                    "wam" | "pam" => 0.8,
                    _ => 0.25,
                };
                vec![v; l.numel()]
            })
            .collect();
        ModelState { leaves }
    }

    fn dev() -> DevParams {
        DevParams {
            tau_max: 1.0,
            tau_min: 1.0,
            ..DevParams::from_preset(&crate::device::OM)
        }
    }

    #[test]
    fn sp_matches_closed_form() {
        // tau = 1: sp = (ap - am) / (ap + am)
        let sp = sp_from_slopes(1.2, 0.8, 1.0, 1.0);
        assert!((sp - 0.2).abs() < 1e-6, "{sp}");
        assert_eq!(sp_from_slopes(0.0, 0.0, 1.0, 1.0), 0.0);
    }

    #[test]
    fn noop_plan_compiles_empty() {
        let s = spec();
        let st = state_for(&s);
        let inj = NnFaultInjector::compile(&FaultPlan::none(3), &s, &st, &dev());
        assert!(inj.is_empty());
        assert!(inj.affected_tiles().is_empty());
        let mut after = st.clone();
        inj.apply(&mut after);
        for (a, b) in after.leaves.iter().zip(&st.leaves) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn stuck_sp_pins_to_slope_derived_sp() {
        let s = spec();
        let mut st = state_for(&s);
        let plan = FaultPlan::of(5, FaultFamily::StuckAtSp, 1.0);
        let inj = NnFaultInjector::compile(&plan, &s, &st, &dev());
        assert!(!inj.is_empty());
        assert_eq!(inj.affected_tiles(), &[0, 1]);
        // 4 analog leaves (w, p on both tiles) x 16 cells
        assert_eq!(inj.n_faulty(), 4 * 16);
        inj.apply(&mut st);
        for (i, l) in s.state.iter().enumerate() {
            match l.role.as_str() {
                "w" | "p" => {
                    for &v in &st.leaves[i] {
                        assert!((v - 0.2).abs() < 1e-6, "{} pinned to {v}", l.name);
                    }
                }
                _ => assert!(st.leaves[i].iter().all(|&v| v != 0.2)),
            }
        }
    }

    #[test]
    fn compile_is_deterministic_per_leaf() {
        let s = spec();
        let st = state_for(&s);
        let plan = FaultPlan::of(9, FaultFamily::StuckAtBound, 0.3);
        let a = NnFaultInjector::compile(&plan, &s, &st, &dev());
        let b = NnFaultInjector::compile(&plan, &s, &st, &dev());
        assert_eq!(a.masks.len(), b.masks.len());
        for ((ia, sa), (ib, sb)) in a.masks.iter().zip(&b.masks) {
            assert_eq!(ia, ib);
            assert_eq!(sa.stuck, sb.stuck);
        }
    }

    #[test]
    fn sp_residual_sees_calibration_gap() {
        let s = spec();
        let mut st = state_for(&s);
        // q == true SP (0.2) -> zero residual
        for (i, l) in s.state.iter().enumerate() {
            if l.role == "q" {
                st.leaves[i] = vec![0.2; l.numel()];
            }
        }
        assert!(sp_residual(&s, &st, &dev()) < 1e-6);
        // stale q -> residual equals the gap
        for (i, l) in s.state.iter().enumerate() {
            if l.role == "q" {
                st.leaves[i] = vec![0.0; l.numel()];
            }
        }
        let r = sp_residual(&s, &st, &dev());
        assert!((r - 0.2).abs() < 1e-6, "{r}");
    }

    #[test]
    fn loss_spike_monitor_fires_after_warmup() {
        let mut m = LossSpikeMonitor::new(2.0, 3);
        assert!(!m.observe(1.0));
        assert!(!m.observe(1.0));
        assert!(!m.observe(1.0));
        assert!(!m.observe(1.05), "steady loss must not trip");
        assert!(m.observe(5.0), "5x the EMA is a spike");
        assert!(m.observe(f64::NAN), "non-finite loss is a spike");
        assert!(m.ema().is_finite(), "NaN must not poison the EMA");
    }

    #[test]
    fn recovery_policy_budget_and_cooldown() {
        let p = RecoveryPolicy {
            zs_pulses: 100,
            max_recoveries: 2,
            cooldown: 10,
        };
        assert!(p.allows(0, 10));
        assert!(!p.allows(0, 9), "cooldown not elapsed");
        assert!(!p.allows(2, 100), "budget exhausted");
    }

    #[test]
    fn checkpoint_round_trips_bit_exact() {
        let ck = Checkpoint {
            step: 42,
            key_counter: 0xDEAD_BEEF_0001,
            cost: PulseCost {
                update_pulses: 7,
                calibration_pulses: 11,
                programming_events: 2,
                digital_ops: 3,
            },
            leaves: vec![vec![1.5, -0.0, f32::MIN_POSITIVE], vec![], vec![42.0; 9]],
        };
        let path = std::env::temp_dir().join(format!(
            "rpallas_ckpt_test_{}.ckpt",
            std::process::id()
        ));
        ck.save(&path).unwrap();
        // atomic save leaves no temp file behind
        assert!(!path.with_extension("tmp").exists());
        let back = Checkpoint::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(back, ck);
        // -0.0 survives bit-exactly
        assert_eq!(back.leaves[0][1].to_bits(), (-0.0f32).to_bits());
    }

    #[test]
    fn checkpoint_rejects_garbage() {
        let path = std::env::temp_dir().join(format!(
            "rpallas_ckpt_garbage_{}.ckpt",
            std::process::id()
        ));
        std::fs::write(&path, b"not a checkpoint at all....").unwrap();
        let err = Checkpoint::load(&path);
        std::fs::remove_file(&path).ok();
        assert!(err.is_err());
    }
}
