//! The HLO-driven training loop: Rust owns data, batching, state and
//! metrics; every step executes one AOT artifact on the PJRT client.
//! Python is never on this path.

use anyhow::{anyhow, Result};

use crate::analog::pulse_counter::PulseCost;
use crate::data::{Batcher, Dataset};
use crate::runtime::{Executor, HostTensor, Registry};
use crate::train::hypers::{DevParams, Hypers};
use crate::train::state::ModelState;
use crate::util::rng::Rng;

/// Average pulse train length per weight update event (Fig. 4 caption).
pub const BL: u64 = 5;

#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub model: String,
    pub algo: String,
    pub hypers: Hypers,
    pub dev: DevParams,
    pub ref_mean: f32,
    pub ref_std: f32,
    pub sigma_gamma: f32,
    pub seed: u64,
    pub steps: usize,
    pub eval_every: usize,
    /// stop once train loss (EMA) falls below this (0 disables)
    pub target_loss: f64,
    /// ZS calibration pulses before training (two-stage pipelines)
    pub zs_pulses: u64,
    pub log: bool,
}

impl TrainConfig {
    pub fn new(model: &str, algo: &str) -> TrainConfig {
        TrainConfig {
            model: model.to_string(),
            algo: algo.to_string(),
            hypers: Hypers::for_algo(if algo == "rider" { "erider" } else { algo }),
            // default: a fine-grained device (experiments override with
            // the paper presets; the harsh presets need epoch-scale runs)
            dev: DevParams {
                dw_min: 0.002,
                sigma_c2c: 0.1,
                ..DevParams::from_preset(&crate::device::OM)
            },
            ref_mean: 0.0,
            ref_std: 0.0,
            sigma_gamma: 0.1,
            seed: 0,
            steps: 500,
            eval_every: 0,
            target_loss: 0.0,
            zs_pulses: 0,
            log: false,
        }
    }

    /// Artifact name of this config's step function.
    fn step_artifact(&self) -> String {
        let algo = if self.algo == "rider" { "erider" } else { &self.algo };
        format!("{}_step_{}", self.model, algo)
    }
}

#[derive(Clone, Debug, Default)]
pub struct TrainResult {
    pub losses: Vec<f64>,
    /// (step, eval loss, eval accuracy %) samples
    pub evals: Vec<(usize, f64, f64)>,
    pub steps_run: usize,
    pub reached_target_at: Option<usize>,
    pub cost: PulseCost,
    pub final_eval_acc: f64,
}

impl TrainResult {
    pub fn final_loss(&self, window: usize) -> f64 {
        let n = self.losses.len();
        if n == 0 {
            return f64::NAN;
        }
        let w = window.min(n);
        crate::util::stats::mean(&self.losses[n - w..])
    }
}

pub struct Trainer<'a> {
    pub exec: &'a Executor,
    pub reg: &'a Registry,
    pub cfg: TrainConfig,
    pub state: ModelState,
    key_counter: u64,
}

impl<'a> Trainer<'a> {
    /// Initialize model state via the `<model>_init` artifact (and run
    /// the ZS calibration artifact if `zs_pulses > 0`).
    pub fn new(exec: &'a Executor, reg: &'a Registry, cfg: TrainConfig) -> Result<Trainer<'a>> {
        let spec = reg.model(&cfg.model)?;
        let init = reg.artifact(&format!("{}_init", cfg.model))?;
        let key = [(cfg.seed >> 32) as u32, cfg.seed as u32];
        let outputs = exec.run(
            init,
            &[
                HostTensor::U32(key.to_vec()),
                HostTensor::F32(vec![cfg.ref_mean, cfg.ref_std, cfg.sigma_gamma]),
            ],
        )?;
        let mut state = ModelState::from_outputs(spec, outputs)?;
        let mut cost = PulseCost::default();
        if cfg.zs_pulses > 0 {
            let zs = reg.artifact(&format!("{}_zs", cfg.model))?;
            let mut inputs = state.to_inputs();
            inputs.push(HostTensor::U32(vec![cfg.zs_pulses as u32]));
            inputs.push(HostTensor::U32(vec![7, cfg.seed as u32]));
            inputs.push(HostTensor::F32(cfg.dev.to_vec(reg)));
            let outputs = exec.run(zs, &inputs)?;
            state = ModelState::from_outputs(spec, outputs)?;
            cost.calibration_pulses = cfg.zs_pulses * spec.n_weights() as u64;
        }
        let mut t = Trainer {
            exec,
            reg,
            cfg,
            state,
            key_counter: 0x5EED_0000,
        };
        t.key_counter ^= t.cfg.seed.rotate_left(17);
        let _ = cost; // folded into train() result below
        Ok(t)
    }

    fn next_key(&mut self) -> HostTensor {
        self.key_counter = self.key_counter.wrapping_add(1);
        HostTensor::U32(vec![
            (self.key_counter >> 32) as u32,
            self.key_counter as u32,
        ])
    }

    /// One optimizer step on a batch; returns the loss.
    pub fn step(&mut self, x: &[f32], y: &[i32]) -> Result<f64> {
        let spec = self.reg.model(&self.cfg.model)?;
        let art = self.reg.artifact(&self.cfg.step_artifact())?;
        let mut hypers = self.cfg.hypers;
        if self.cfg.algo == "rider" {
            hypers.flip_p = 0.0;
        }
        let mut inputs = self.state.to_inputs();
        inputs.push(HostTensor::F32(x.to_vec()));
        inputs.push(HostTensor::I32(y.to_vec()));
        inputs.push(self.next_key());
        inputs.push(HostTensor::F32(hypers.to_vec(self.reg)));
        inputs.push(HostTensor::F32(self.cfg.dev.to_vec(self.reg)));
        let mut outputs = self.exec.run(art, &inputs)?;
        let loss = outputs
            .pop()
            .and_then(|v| v.first().copied())
            .ok_or_else(|| anyhow!("step returned no loss"))? as f64;
        self.state = ModelState::from_outputs(spec, outputs)?;
        Ok(loss)
    }

    /// Evaluate on a dataset via the eval artifact (analog forward).
    pub fn eval(&mut self, ds: &Dataset) -> Result<(f64, f64)> {
        let spec = self.reg.model(&self.cfg.model)?;
        let art = self.reg.artifact(&format!("{}_eval", self.cfg.model))?;
        let eb = spec.eval_batch;
        let n_batches = (ds.n / eb).max(1);
        let (mut tot_loss, mut tot_correct, mut tot_n) = (0.0, 0.0, 0usize);
        for b in 0..n_batches {
            let lo = b * eb;
            let x = &ds.x[lo * ds.d..(lo + eb) * ds.d];
            let y = &ds.y[lo..lo + eb];
            let mut inputs = self.state.to_inputs();
            inputs.push(HostTensor::F32(x.to_vec()));
            inputs.push(HostTensor::I32(y.to_vec()));
            inputs.push(self.next_key());
            inputs.push(HostTensor::F32(self.cfg.hypers.to_vec(self.reg)));
            inputs.push(HostTensor::F32(self.cfg.dev.to_vec(self.reg)));
            let out = self.exec.run(art, &inputs)?;
            tot_loss += out[0][0] as f64;
            tot_correct += out[1][0] as f64;
            tot_n += eb;
        }
        Ok((
            tot_loss / n_batches as f64,
            100.0 * tot_correct / tot_n as f64,
        ))
    }

    /// Full training run over a dataset.
    pub fn train(&mut self, train_ds: &Dataset, test_ds: Option<&Dataset>) -> Result<TrainResult> {
        let spec = self.reg.model(&self.cfg.model)?;
        let batch = spec.batch;
        let mut batcher = Batcher::new(train_ds.n, batch, self.cfg.seed ^ 0xB00C);
        let mut res = TrainResult::default();
        if self.cfg.zs_pulses > 0 {
            res.cost.calibration_pulses = self.cfg.zs_pulses * spec.n_weights() as u64;
        }
        let mut x = Vec::new();
        let mut y = Vec::new();
        let mut ema = f64::NAN;
        let mut rng = Rng::new(self.cfg.seed, 0x7EA1);
        let _ = &mut rng;
        for k in 0..self.cfg.steps {
            batcher.next_batch(train_ds, &mut x, &mut y);
            let loss = self.step(&x, &y)?;
            res.losses.push(loss);
            res.steps_run = k + 1;
            ema = if ema.is_nan() { loss } else { 0.95 * ema + 0.05 * loss };
            if self.cfg.log && (k % 50 == 0 || k + 1 == self.cfg.steps) {
                println!("  step {k:5}  loss {loss:.4}  ema {ema:.4}");
            }
            if self.cfg.eval_every > 0 && (k + 1) % self.cfg.eval_every == 0 {
                if let Some(ds) = test_ds {
                    let (el, ea) = self.eval(ds)?;
                    if self.cfg.log {
                        println!("  step {k:5}  eval loss {el:.4}  acc {ea:.2}%");
                    }
                    res.evals.push((k + 1, el, ea));
                }
            }
            if self.cfg.target_loss > 0.0
                && ema < self.cfg.target_loss
                && res.reached_target_at.is_none()
            {
                res.reached_target_at = Some(k + 1);
                break;
            }
        }
        res.cost.update_pulses =
            PulseCost::training_estimate(res.steps_run as u64, spec.n_weights() as u64, BL);
        if let Some(ds) = test_ds {
            let (el, ea) = self.eval(ds)?;
            res.evals.push((res.steps_run, el, ea));
            res.final_eval_acc = ea;
        }
        Ok(res)
    }
}
