//! The HLO-driven training loop: Rust owns data, batching, state and
//! metrics; every step executes one AOT artifact on the PJRT client.
//! Python is never on this path.
//!
//! Methods are selected through the shared `analog::optimizer` registry:
//! [`TrainConfig`] holds an [`OptimizerSpec`], the artifact name and the
//! NN-scale hyperparameter defaults are resolved from its [`Method`]
//! (`Method::nn_step_algo`, `Hypers::for_method`), and unknown names
//! surface as `Err` from [`TrainConfig::by_name`] — never a panic.

use anyhow::{anyhow, Result};

use crate::analog::optimizer::{self, Method, OptimizerSpec};
use crate::analog::pulse_counter::PulseCost;
use crate::data::{Batcher, Dataset};
use crate::runtime::{Executor, HostTensor, Registry};
use crate::train::fault::Checkpoint;
use crate::train::hypers::{DevParams, Hypers};
use crate::train::state::ModelState;
use crate::util::metrics::{self, MetricId};

/// Average pulse train length per weight update event (Fig. 4 caption).
pub const BL: u64 = 5;

#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub model: String,
    /// The method, from the shared two-layer registry. Only the method
    /// identity and `zs_pulses` are read at NN scale — the live NN-scale
    /// knobs are `hypers` (the spec's numeric fields are pulse-level
    /// defaults, tuned for the quadratic objectives; editing them here
    /// does not affect the artifacts).
    pub spec: OptimizerSpec,
    pub hypers: Hypers,
    pub dev: DevParams,
    pub ref_mean: f32,
    pub ref_std: f32,
    pub sigma_gamma: f32,
    pub seed: u64,
    pub steps: usize,
    pub eval_every: usize,
    /// stop once train loss (EMA) falls below this (0 disables)
    pub target_loss: f64,
    /// ZS calibration pulses before training (seeded from the method's
    /// registry policy: the two-stage residual pipeline calibrates by
    /// default, everything else starts at 0)
    pub zs_pulses: u64,
    pub log: bool,
}

impl TrainConfig {
    pub fn new(model: &str, spec: OptimizerSpec) -> TrainConfig {
        TrainConfig {
            model: model.to_string(),
            spec,
            hypers: Hypers::for_method(spec.method),
            // default: a fine-grained device (experiments override with
            // the paper presets; the harsh presets need epoch-scale runs)
            dev: DevParams {
                dw_min: 0.002,
                sigma_c2c: 0.1,
                ..DevParams::from_preset(&crate::device::OM)
            },
            ref_mean: 0.0,
            ref_std: 0.0,
            sigma_gamma: 0.1,
            seed: 0,
            steps: 500,
            eval_every: 0,
            target_loss: 0.0,
            zs_pulses: if spec.method.nn_needs_zs() { spec.zs_pulses } else { 0 },
            log: false,
        }
    }

    /// Name-driven constructor through the registry; unknown names
    /// report the available set instead of panicking.
    pub fn by_name(model: &str, method: &str) -> Result<TrainConfig> {
        let spec = optimizer::spec_or_err(method).map_err(|e| anyhow!(e))?;
        Ok(TrainConfig::new(model, spec))
    }

    /// Registry name of the configured method.
    pub fn algo(&self) -> &'static str {
        self.spec.method.name()
    }

    /// Artifact name of this config's step function.
    pub(crate) fn step_artifact(&self) -> String {
        format!("{}_step_{}", self.model, self.spec.method.nn_step_algo())
    }
}

#[derive(Clone, Debug, Default)]
pub struct TrainResult {
    pub losses: Vec<f64>,
    /// (step, eval loss, eval accuracy %) samples
    pub evals: Vec<(usize, f64, f64)>,
    pub steps_run: usize,
    pub reached_target_at: Option<usize>,
    /// calibration + update pulses, produced by the trainer (the one
    /// code path behind Fig. 4-left's totals)
    pub cost: PulseCost,
    pub final_eval_acc: f64,
}

impl TrainResult {
    pub fn final_loss(&self, window: usize) -> f64 {
        let n = self.losses.len();
        if n == 0 {
            return f64::NAN;
        }
        let w = window.min(n);
        crate::util::stats::mean(&self.losses[n - w..])
    }
}

pub struct Trainer<'a> {
    pub exec: &'a Executor,
    pub reg: &'a Registry,
    pub cfg: TrainConfig,
    pub state: ModelState,
    /// pulse cost of the ZS calibration run in `new` (charged into every
    /// subsequent `train` result)
    pub(crate) calib_cost: PulseCost,
    pub(crate) key_counter: u64,
}

impl<'a> Trainer<'a> {
    /// Initialize model state via the `<model>_init` artifact (and run
    /// the ZS calibration artifact if `zs_pulses > 0`).
    pub fn new(exec: &'a Executor, reg: &'a Registry, cfg: TrainConfig) -> Result<Trainer<'a>> {
        let spec = reg.model(&cfg.model)?;
        let init = reg.artifact(&format!("{}_init", cfg.model))?;
        let key = [(cfg.seed >> 32) as u32, cfg.seed as u32];
        let outputs = exec.run(
            init,
            &[
                HostTensor::U32(key.to_vec()),
                HostTensor::F32(vec![cfg.ref_mean, cfg.ref_std, cfg.sigma_gamma]),
            ],
        )?;
        let mut state = ModelState::from_outputs(spec, outputs)?;
        let mut calib_cost = PulseCost::default();
        if cfg.zs_pulses > 0 {
            let zs = reg.artifact(&format!("{}_zs", cfg.model))?;
            let mut inputs = state.to_inputs();
            inputs.push(HostTensor::U32(vec![cfg.zs_pulses as u32]));
            inputs.push(HostTensor::U32(vec![7, cfg.seed as u32]));
            inputs.push(HostTensor::F32(cfg.dev.to_vec(reg)));
            let outputs = exec.run(zs, &inputs)?;
            state = ModelState::from_outputs(spec, outputs)?;
            calib_cost.calibration_pulses = cfg.zs_pulses * spec.n_weights() as u64;
            metrics::counter(
                MetricId::TrainCalibrationPulsesTotal,
                calib_cost.calibration_pulses,
            );
        }
        let mut t = Trainer {
            exec,
            reg,
            cfg,
            state,
            calib_cost,
            key_counter: 0x5EED_0000,
        };
        t.key_counter ^= t.cfg.seed.rotate_left(17);
        Ok(t)
    }

    /// Snapshot the run for crash-consistent recovery: the state
    /// tensors plus the key counter and pulse accounting, so a
    /// [`Trainer::restore`] continues training bit-for-bit.
    pub fn checkpoint(&self, step: u64) -> Checkpoint {
        Checkpoint {
            step,
            key_counter: self.key_counter,
            cost: self.calib_cost,
            leaves: self.state.leaves.clone(),
        }
    }

    /// Rewind to a [`Checkpoint`] taken from this trainer (state, key
    /// counter and pulse accounting are all restored, so replaying the
    /// same batches reproduces the original trajectory exactly).
    pub fn restore(&mut self, ck: &Checkpoint) {
        self.state.leaves = ck.leaves.clone();
        self.key_counter = ck.key_counter;
        self.calib_cost = ck.cost;
    }

    /// Pulse accounting accrued outside `train` (initial ZS calibration
    /// plus any recovery recalibrations).
    pub fn calibration_cost(&self) -> PulseCost {
        self.calib_cost
    }

    /// Self-healing recalibration: re-run the ZS calibration artifact
    /// and keep its output only for the leaves on `tiles`, leaving every
    /// healthy tile's state untouched. The pulse bill — `zs_pulses`
    /// cycles times the number of weights on the affected tiles — is
    /// charged to `calibration_pulses`, where `train` carries it into
    /// `TrainResult.cost`. Returns the pulses spent; an empty tile list
    /// costs nothing and runs nothing.
    pub fn recalibrate_tiles(&mut self, tiles: &[usize], zs_pulses: u64) -> Result<u64> {
        if tiles.is_empty() || zs_pulses == 0 {
            return Ok(0);
        }
        let spec = self.reg.model(&self.cfg.model)?;
        let zs = self.reg.artifact(&format!("{}_zs", self.cfg.model))?;
        let mut inputs = self.state.to_inputs();
        inputs.push(HostTensor::U32(vec![zs_pulses as u32]));
        inputs.push(self.next_key());
        inputs.push(HostTensor::F32(self.cfg.dev.to_vec(self.reg)));
        let outputs = self.exec.run(zs, &inputs)?;
        let mut fresh = ModelState::from_outputs(spec, outputs)?;
        for (i, leaf) in spec.state.iter().enumerate() {
            if tiles.contains(&leaf.tile) {
                self.state.leaves[i] = std::mem::take(&mut fresh.leaves[i]);
            }
        }
        let affected: u64 = spec
            .state
            .iter()
            .filter(|l| l.role == "w" && tiles.contains(&l.tile))
            .map(|l| l.numel() as u64)
            .sum();
        let spent = zs_pulses * affected;
        self.calib_cost.calibration_pulses += spent;
        metrics::counter(MetricId::TrainCalibrationPulsesTotal, spent);
        Ok(spent)
    }

    fn next_key(&mut self) -> HostTensor {
        self.key_counter = self.key_counter.wrapping_add(1);
        HostTensor::U32(vec![
            (self.key_counter >> 32) as u32,
            self.key_counter as u32,
        ])
    }

    /// One optimizer step on a batch; returns the loss.
    pub fn step(&mut self, x: &[f32], y: &[i32]) -> Result<f64> {
        let t0 = metrics::enabled().then(std::time::Instant::now);
        let spec = self.reg.model(&self.cfg.model)?;
        let art = self.reg.artifact(&self.cfg.step_artifact())?;
        let mut inputs = self.state.to_inputs();
        inputs.push(HostTensor::F32(x.to_vec()));
        inputs.push(HostTensor::I32(y.to_vec()));
        inputs.push(self.next_key());
        inputs.push(HostTensor::F32(self.cfg.hypers.to_vec(self.reg)));
        inputs.push(HostTensor::F32(self.cfg.dev.to_vec(self.reg)));
        let mut outputs = self.exec.run(art, &inputs)?;
        let loss = outputs
            .pop()
            .and_then(|v| v.first().copied())
            .ok_or_else(|| anyhow!("step returned no loss"))? as f64;
        self.state = ModelState::from_outputs(spec, outputs)?;
        if let Some(t0) = t0 {
            metrics::counter(MetricId::TrainStepsTotal, 1);
            metrics::histogram(MetricId::TrainStepSeconds, t0.elapsed().as_secs_f64());
        }
        Ok(loss)
    }

    /// One eval-artifact execution on a fixed-shape batch.
    fn eval_batch_run(&mut self, x: Vec<f32>, y: Vec<i32>) -> Result<Vec<Vec<f32>>> {
        let art = self.reg.artifact(&format!("{}_eval", self.cfg.model))?;
        let mut inputs = self.state.to_inputs();
        inputs.push(HostTensor::F32(x));
        inputs.push(HostTensor::I32(y));
        inputs.push(self.next_key());
        inputs.push(HostTensor::F32(self.cfg.hypers.to_vec(self.reg)));
        inputs.push(HostTensor::F32(self.cfg.dev.to_vec(self.reg)));
        self.exec.run(art, &inputs)
    }

    /// Evaluate on a dataset via the eval artifact (analog forward).
    ///
    /// The artifact's batch shape is fixed at `eval_batch` and it
    /// reports batch-aggregated loss/ncorrect, so the final partial
    /// batch (including `ds.n < eval_batch`) needs care on both metrics:
    ///
    /// * accuracy: the tail is zero-padded with an out-of-range label —
    ///   argmax over `n_classes` logits never matches it, so a padded
    ///   row can never count as correct and the count stays exact;
    /// * loss: the artifact's batch *mean* would mix the padded rows'
    ///   clamped-label nll into the average, so the tail's loss comes
    ///   from a second execution with the tail's own samples cycled
    ///   into the padded slots — every row is real, each tail sample
    ///   weighted by its repeat count (exact when `eb % take == 0`,
    ///   near-uniform otherwise).
    ///
    /// Both averages are weighted by the number of real samples.
    pub fn eval(&mut self, ds: &Dataset) -> Result<(f64, f64)> {
        let spec = self.reg.model(&self.cfg.model)?;
        let eb = spec.eval_batch;
        let n_classes = spec.n_classes;
        if ds.n == 0 {
            return Err(anyhow!("eval on an empty dataset"));
        }
        let (mut loss_sum, mut tot_correct, mut tot_n) = (0.0, 0.0, 0usize);
        let mut lo = 0;
        while lo < ds.n {
            let take = eb.min(ds.n - lo);
            // accuracy pass: zero-pad, out-of-range pad label
            let mut x = vec![0.0f32; eb * ds.d];
            x[..take * ds.d].copy_from_slice(&ds.x[lo * ds.d..(lo + take) * ds.d]);
            let mut y = vec![n_classes as i32; eb];
            y[..take].copy_from_slice(&ds.y[lo..lo + take]);
            let out = self.eval_batch_run(x, y)?;
            tot_correct += out[1][0] as f64;
            let batch_loss = if take == eb {
                out[0][0] as f64
            } else {
                // loss pass for the ragged tail: cycle the tail's own
                // samples into the padded slots
                let mut x2 = vec![0.0f32; eb * ds.d];
                let mut y2 = vec![0i32; eb];
                for i in 0..eb {
                    let src = lo + (i % take);
                    x2[i * ds.d..(i + 1) * ds.d]
                        .copy_from_slice(&ds.x[src * ds.d..(src + 1) * ds.d]);
                    y2[i] = ds.y[src];
                }
                let out2 = self.eval_batch_run(x2, y2)?;
                out2[0][0] as f64
            };
            loss_sum += batch_loss * take as f64;
            tot_n += take;
            lo += take;
        }
        Ok((
            loss_sum / tot_n as f64,
            100.0 * tot_correct / tot_n as f64,
        ))
    }

    /// Full training run over a dataset.
    pub fn train(&mut self, train_ds: &Dataset, test_ds: Option<&Dataset>) -> Result<TrainResult> {
        let spec = self.reg.model(&self.cfg.model)?;
        let batch = spec.batch;
        let mut batcher = Batcher::new(train_ds.n, batch, self.cfg.seed ^ 0xB00C);
        let mut res = TrainResult {
            // calibration cost is charged where it was paid (Trainer::new),
            // not re-derived from the config by every consumer
            cost: self.calib_cost,
            ..TrainResult::default()
        };
        let mut x = Vec::new();
        let mut y = Vec::new();
        let mut ema = f64::NAN;
        for k in 0..self.cfg.steps {
            batcher.next_batch(train_ds, &mut x, &mut y);
            let loss = self.step(&x, &y)?;
            res.losses.push(loss);
            res.steps_run = k + 1;
            if metrics::enabled() {
                metrics::gauge(MetricId::TrainLoss, loss);
                if self.cfg.spec.method != Method::Digital {
                    metrics::counter(
                        MetricId::TrainUpdatePulsesTotal,
                        spec.n_weights() as u64 * BL,
                    );
                }
                metrics::gauge(
                    MetricId::SpResidual,
                    crate::train::fault::sp_residual(spec, &self.state, &self.cfg.dev),
                );
                metrics::trace_sample(k as u64);
            }
            ema = if ema.is_nan() { loss } else { 0.95 * ema + 0.05 * loss };
            if self.cfg.log && (k % 50 == 0 || k + 1 == self.cfg.steps) {
                println!("  step {k:5}  loss {loss:.4}  ema {ema:.4}");
            }
            if self.cfg.eval_every > 0 && (k + 1) % self.cfg.eval_every == 0 {
                if let Some(ds) = test_ds {
                    let (el, ea) = self.eval(ds)?;
                    if self.cfg.log {
                        println!("  step {k:5}  eval loss {el:.4}  acc {ea:.2}%");
                    }
                    res.evals.push((k + 1, el, ea));
                }
            }
            if self.cfg.target_loss > 0.0
                && ema < self.cfg.target_loss
                && res.reached_target_at.is_none()
            {
                res.reached_target_at = Some(k + 1);
                break;
            }
        }
        if self.cfg.spec.method == Method::Digital {
            // exact SGD touches every weight once per step, pulse-free
            res.cost.digital_ops += res.steps_run as u64 * spec.n_weights() as u64;
        } else {
            res.cost.update_pulses =
                PulseCost::training_estimate(res.steps_run as u64, spec.n_weights() as u64, BL);
        }
        if let Some(ds) = test_ds {
            let (el, ea) = self.eval(ds)?;
            res.evals.push((res.steps_run, el, ea));
            res.final_eval_acc = ea;
        }
        Ok(res)
    }
}
