//! Model training state held on the Rust side as flat host tensors,
//! addressed by role through the manifest's leaf table.

use anyhow::{anyhow, Result};

use crate::runtime::{HostTensor, ModelSpec};

/// Flat training state: one host tensor per manifest leaf.
#[derive(Clone, Debug)]
pub struct ModelState {
    pub leaves: Vec<Vec<f32>>,
}

impl ModelState {
    pub fn from_outputs(spec: &ModelSpec, outputs: Vec<Vec<f32>>) -> Result<ModelState> {
        if outputs.len() < spec.state.len() {
            return Err(anyhow!(
                "expected >= {} state outputs, got {}",
                spec.state.len(),
                outputs.len()
            ));
        }
        let mut outputs = outputs;
        outputs.truncate(spec.state.len());
        for (leaf, out) in spec.state.iter().zip(&outputs) {
            if leaf.numel() != out.len() {
                return Err(anyhow!(
                    "leaf {}: expected {} elements, got {}",
                    leaf.name,
                    leaf.numel(),
                    out.len()
                ));
            }
        }
        Ok(ModelState { leaves: outputs })
    }

    /// Inputs for a step/eval artifact: the state tensors in order.
    pub fn to_inputs(&self) -> Vec<HostTensor> {
        self.leaves
            .iter()
            .map(|v| HostTensor::F32(v.clone()))
            .collect()
    }

    /// Indices of leaves with a given role.
    pub fn role_indices(spec: &ModelSpec, role: &str) -> Vec<usize> {
        spec.state
            .iter()
            .enumerate()
            .filter(|(_, l)| l.role == role)
            .map(|(i, _)| i)
            .collect()
    }

    /// Copy the `w` and `bias` leaves from another state (deploying a
    /// digitally pre-trained checkpoint onto the analog arrays, Table 8).
    pub fn deploy_weights_from(&mut self, spec: &ModelSpec, src: &ModelState) {
        for role in ["w", "bias"] {
            for i in Self::role_indices(spec, role) {
                self.leaves[i].clone_from(&src.leaves[i]);
            }
        }
    }

    /// Mean absolute value of a role's leaves (diagnostics).
    pub fn role_mean_abs(&self, spec: &ModelSpec, role: &str) -> f64 {
        let idx = Self::role_indices(spec, role);
        let mut s = 0.0;
        let mut n = 0usize;
        for i in idx {
            s += self.leaves[i].iter().map(|&v| v.abs() as f64).sum::<f64>();
            n += self.leaves[i].len();
        }
        if n == 0 {
            0.0
        } else {
            s / n as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{ModelSpec, StateLeaf};

    fn spec() -> ModelSpec {
        ModelSpec {
            name: "m".into(),
            batch: 2,
            eval_batch: 4,
            d_in: 3,
            n_classes: 2,
            state: vec![
                StateLeaf {
                    name: "t0.w".into(),
                    shape: vec![3, 2],
                    role: "w".into(),
                    tile: 0,
                },
                StateLeaf {
                    name: "t0.p".into(),
                    shape: vec![3, 2],
                    role: "p".into(),
                    tile: 0,
                },
                StateLeaf {
                    name: "b0".into(),
                    shape: vec![2],
                    role: "bias".into(),
                    tile: 0,
                },
            ],
        }
    }

    #[test]
    fn from_outputs_validates() {
        let s = spec();
        let ok = ModelState::from_outputs(&s, vec![vec![0.0; 6], vec![0.0; 6], vec![0.0; 2]]);
        assert!(ok.is_ok());
        let bad = ModelState::from_outputs(&s, vec![vec![0.0; 5], vec![0.0; 6], vec![0.0; 2]]);
        assert!(bad.is_err());
    }

    #[test]
    fn deploy_copies_w_and_bias_only() {
        let s = spec();
        let mut dst =
            ModelState::from_outputs(&s, vec![vec![0.0; 6], vec![0.0; 6], vec![0.0; 2]]).unwrap();
        let src =
            ModelState::from_outputs(&s, vec![vec![1.0; 6], vec![2.0; 6], vec![3.0; 2]]).unwrap();
        dst.deploy_weights_from(&s, &src);
        assert_eq!(dst.leaves[0], vec![1.0; 6]); // w copied
        assert_eq!(dst.leaves[1], vec![0.0; 6]); // p untouched
        assert_eq!(dst.leaves[2], vec![3.0; 2]); // bias copied
    }

    #[test]
    fn role_mean_abs_works() {
        let s = spec();
        let st =
            ModelState::from_outputs(&s, vec![vec![-2.0; 6], vec![0.0; 6], vec![0.0; 2]]).unwrap();
        assert!((st.role_mean_abs(&s, "w") - 2.0).abs() < 1e-12);
    }
}
