//! Pipeline-parallel training with a bit-exact synchronous oracle.
//!
//! The pipelined trainer runs the same AOT step artifacts as
//! [`Trainer`](crate::train::Trainer) but lets several microbatches be
//! in flight at once, following the delayed-gradient pipeline analysis
//! of arXiv:2410.15155 (PAPERS.md): a worker computing microbatch `m`
//! reads the model state of version `base(m) = max(m - D, 0)`, where
//! `D` is the configured staleness. The step artifacts are monolithic
//! (forward + backward + device update fused per model), so staleness
//! is realized as *delta application*: the state delta produced by a
//! step against the stale snapshot is re-based onto the newest state by
//! a chain of channel-connected stage appliers, each owning the leaves
//! of a contiguous tile range.
//!
//! ## Topology
//!
//! ```text
//!  claim m, wait published >= m-D        ordered Apply(m) messages
//!  ┌─────────┐  snapshot    ┌────────────┐      ┌────────────┐
//!  │ worker  │─────────────▶│  stage 0   │─────▶│  stage S-1 │─▶ publish m+1
//!  │ pool ×W │  done(m)     │ tiles 0..a │ mpsc │ tiles b..T │
//!  └─────────┘──▶ commit    └────────────┘      └────────────┘
//! ```
//!
//! * **Workers** (×W) each own a thread-local [`Executor`] built from a
//!   [`StageExecSpec`] (the shared executor is deliberately `!Send`).
//!   They claim microbatch indices from a shared counter, block until
//!   the input version is published, run the step artifact, and post
//!   `(loss, output leaves)` to the hub.
//! * **Stage appliers** (×S) receive `Apply(m)` messages strictly in
//!   microbatch order over an mpsc chain and fold step `m`'s delta into
//!   their own leaf group: `new = cur + (out - base)` elementwise —
//!   except when `base == m` (always true at `D = 0`), where the output
//!   *replaces* the group, because `a + (b - a) != b` in `f32` and the
//!   bit-exactness contract below would not survive a zero-delta add.
//! * **The coordinator** (caller thread) commits results in microbatch
//!   order: losses, EMA, logging, metrics, evals and the target-loss
//!   stop all happen exactly as in the synchronous loop.
//!
//! ## Determinism and the `D = 0` contract
//!
//! Every quantity that feeds an artifact execution is a pure function
//! of the microbatch index `m`: the batch (pre-drawn from the same
//! `Batcher` stream as the synchronous trainer), the RNG key
//! (`key(m) = kc0 + m + 1 + kpe * evals_before(m)`, the same sub-stream
//! derivation discipline as `TiledArray` and the row-chunked
//! `analog_update` — worker count never enters), and the input version
//! `base(m)`. Apply order is fixed by the channel chain, and commit
//! order by the coordinator. Hence results are bit-identical across
//! worker *and* stage counts for any `D`; and at `D = 0` the claim/wait
//! protocol serializes workers so the run is bit-identical to
//! [`Trainer::train`](crate::train::Trainer::train) — enforced by
//! `rust/tests/pipeline_equivalence.rs`.
//!
//! Evaluation points (`eval_every`) and the final eval run on the
//! coordinator thread against the fully-published state with the
//! synchronous key counter re-derived, so eval results and the
//! post-run `Trainer` state (checkpointable via
//! [`PipelineTrainer::checkpoint`]) match the oracle bit for bit.

use std::collections::BTreeMap;
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::analog::optimizer::Method;
use crate::analog::pulse_counter::PulseCost;
use crate::data::{Batcher, Dataset};
use crate::runtime::{ArtifactSpec, Executor, HostTensor, ModelSpec, Registry, StageExecSpec};
use crate::train::fault::{self, Checkpoint};
use crate::train::state::ModelState;
use crate::train::trainer::{TrainConfig, TrainResult, Trainer, BL};
use crate::util::metrics::{self, MetricId};

/// Pipeline topology knobs; see the module docs for semantics.
#[derive(Clone, Copy, Debug)]
pub struct PipelineConfig {
    /// Stage appliers: the model's tiles are split into this many
    /// contiguous groups (1 ..= number of distinct tiles).
    pub stages: usize,
    /// Compute workers claiming microbatches (>= 1). More workers only
    /// help when `staleness > 0`; at `D = 0` they serialize.
    pub workers: usize,
    /// Gradient staleness bound `D`: microbatch `m` may read state as
    /// old as version `m - D`. `0` reproduces the synchronous schedule
    /// bit for bit.
    pub staleness: u64,
    /// Planned-engine threads pinned per worker executable (`0` =
    /// backend default; results are thread-count independent either
    /// way).
    pub plan_threads: usize,
}

impl Default for PipelineConfig {
    fn default() -> PipelineConfig {
        PipelineConfig {
            stages: 2,
            workers: 2,
            staleness: 0,
            plan_threads: 0,
        }
    }
}

/// One stage's slice of the model state at one version.
type GroupLeaves = Arc<Vec<Vec<f32>>>;

/// A completed step waiting for its in-order commit.
struct WorkerOut {
    loss: f64,
    /// Version the step's inputs were read from.
    base: u64,
    /// Full output leaves of the step artifact, in manifest order.
    out: Arc<Vec<Vec<f32>>>,
}

/// In-order apply message travelling down the stage chain.
enum ApplyMsg {
    Step {
        task: u64,
        base: u64,
        out: Arc<Vec<Vec<f32>>>,
    },
    Stop,
}

struct HubState {
    /// `(version, stage)` -> that stage's leaf group at that version.
    groups: BTreeMap<(u64, usize), GroupLeaves>,
    /// Highest version present for *all* stages (set by the last stage).
    published: u64,
    /// Next unclaimed microbatch index.
    next_task: u64,
    /// Completed steps not yet committed by the coordinator.
    done: BTreeMap<u64, WorkerOut>,
    /// Claim freeze: set on target-loss stop and at shutdown.
    stop: bool,
    /// First error from any thread; everyone drains once set.
    error: Option<String>,
    /// Per-worker `(busy, alive)` seconds for the occupancy gauge.
    occ: Vec<(f64, f64)>,
}

/// Shared mutable pipeline state: one mutex + condvar, notified on
/// publish, completion, error and stop.
struct Hub {
    stages: usize,
    m: Mutex<HubState>,
    cv: Condvar,
}

impl Hub {
    fn new(stages: usize, init_groups: Vec<Vec<Vec<f32>>>) -> Hub {
        let mut groups = BTreeMap::new();
        for (s, g) in init_groups.into_iter().enumerate() {
            groups.insert((0u64, s), Arc::new(g));
        }
        Hub {
            stages,
            m: Mutex::new(HubState {
                groups,
                published: 0,
                next_task: 0,
                done: BTreeMap::new(),
                stop: false,
                error: None,
                occ: Vec::new(),
            }),
            cv: Condvar::new(),
        }
    }

    fn lock(&self) -> MutexGuard<'_, HubState> {
        self.m.lock().unwrap_or_else(|p| p.into_inner())
    }

    fn wait<'g>(&self, g: MutexGuard<'g, HubState>) -> MutexGuard<'g, HubState> {
        self.cv.wait(g).unwrap_or_else(|p| p.into_inner())
    }

    /// Claim the next microbatch, or `None` when stopped/exhausted.
    fn claim(&self, steps: u64) -> Option<u64> {
        let mut g = self.lock();
        if g.stop || g.error.is_some() || g.next_task >= steps {
            return None;
        }
        let m = g.next_task;
        g.next_task += 1;
        Some(m)
    }

    /// Block until version `v` is published; returns per-stage group
    /// snapshots and the stall time, or `None` on stop/error.
    fn wait_version(&self, v: u64) -> Option<(Vec<GroupLeaves>, f64)> {
        let t0 = Instant::now();
        let mut g = self.lock();
        while g.published < v && !g.stop && g.error.is_none() {
            g = self.wait(g);
        }
        if g.stop || g.error.is_some() {
            return None;
        }
        let mut snap = Vec::with_capacity(self.stages);
        for s in 0..self.stages {
            match g.groups.get(&(v, s)) {
                Some(a) => snap.push(a.clone()),
                None => {
                    g.error
                        .get_or_insert_with(|| format!("pipeline: version {v} stage {s} evicted"));
                    drop(g);
                    self.cv.notify_all();
                    return None;
                }
            }
        }
        Some((snap, t0.elapsed().as_secs_f64()))
    }

    fn complete(&self, m: u64, wo: WorkerOut) {
        let mut g = self.lock();
        g.done.insert(m, wo);
        drop(g);
        self.cv.notify_all();
    }

    /// Block until step `k` has a completed result to commit.
    fn wait_done(&self, k: u64) -> Result<WorkerOut> {
        let mut g = self.lock();
        loop {
            if let Some(wo) = g.done.remove(&k) {
                return Ok(wo);
            }
            if let Some(e) = &g.error {
                return Err(anyhow!("{e}"));
            }
            g = self.wait(g);
        }
    }

    /// Block until version `v` is published, then reassemble the full
    /// leaf vector in manifest order (the coordinator's eval/drain
    /// path; `v` never trails the retention window because the
    /// coordinator only asks for versions it just had applied).
    fn wait_assembled(
        &self,
        v: u64,
        members: &[Vec<usize>],
        spec: &ModelSpec,
    ) -> Result<Vec<Vec<f32>>> {
        let mut g = self.lock();
        while g.published < v && g.error.is_none() {
            g = self.wait(g);
        }
        if let Some(e) = &g.error {
            return Err(anyhow!("{e}"));
        }
        let mut leaves = vec![Vec::new(); spec.state.len()];
        for (s, m) in members.iter().enumerate() {
            let group = g
                .groups
                .get(&(v, s))
                .ok_or_else(|| anyhow!("pipeline: version {v} stage {s} evicted"))?;
            for (p, &li) in m.iter().enumerate() {
                leaves[li] = group[p].clone();
            }
        }
        Ok(leaves)
    }

    /// Freeze the claim frontier and wake everyone.
    fn halt(&self) {
        let mut g = self.lock();
        g.stop = true;
        drop(g);
        self.cv.notify_all();
    }

    /// Record the first error and wake everyone.
    fn fail(&self, msg: String) {
        let mut g = self.lock();
        g.error.get_or_insert(msg);
        drop(g);
        self.cv.notify_all();
    }

    fn error_or(&self, fallback: &str) -> String {
        let g = self.lock();
        g.error.clone().unwrap_or_else(|| fallback.to_string())
    }

    /// Microbatches claimed but not yet committed.
    fn inflight(&self, committed: u64) -> f64 {
        let g = self.lock();
        g.next_task.saturating_sub(committed) as f64
    }

    fn push_occupancy(&self, busy: f64, alive: f64) {
        let mut g = self.lock();
        g.occ.push((busy, alive));
    }
}

/// Everything a compute worker needs, shareable across scoped threads.
struct WorkerCtx<'r> {
    reg: &'r Registry,
    spec: &'r ModelSpec,
    art: &'r ArtifactSpec,
    exec_spec: StageExecSpec,
    batches: &'r [(Vec<f32>, Vec<i32>)],
    /// Leaf index -> (stage, position inside the stage's group).
    locate: &'r [(usize, usize)],
    hyp: &'r [f32],
    devv: &'r [f32],
    steps: u64,
    staleness: u64,
    /// Key counter at train start; worker keys are derived statically.
    kc0: u64,
    /// Eval period in steps (0 = no evals consume keys).
    eval_every: u64,
    /// RNG keys one eval sweep consumes.
    keys_per_eval: u64,
}

impl WorkerCtx<'_> {
    fn key_for(&self, m: u64) -> u64 {
        step_key(self.kc0, self.keys_per_eval, self.eval_every, m)
    }
}

/// The key the synchronous trainer would draw for step `m`: one per
/// prior step, plus `keys_per_eval` per eval boundary passed — a pure
/// function of the microbatch index, so worker count never enters.
fn step_key(kc0: u64, keys_per_eval: u64, eval_every: u64, m: u64) -> u64 {
    let evals = if eval_every > 0 { m / eval_every } else { 0 };
    kc0.wrapping_add(m + 1)
        .wrapping_add(keys_per_eval.wrapping_mul(evals))
}

/// Run the step artifact for microbatch `m` against a version snapshot.
fn run_step(
    ctx: &WorkerCtx<'_>,
    exec: &Executor,
    snap: &[GroupLeaves],
    m: u64,
) -> Result<(f64, Vec<Vec<f32>>)> {
    let t0 = metrics::enabled().then(Instant::now);
    let mut inputs = Vec::with_capacity(ctx.locate.len() + 5);
    for &(s, p) in ctx.locate {
        inputs.push(HostTensor::F32(snap[s][p].clone()));
    }
    let (x, y) = &ctx.batches[m as usize];
    inputs.push(HostTensor::F32(x.clone()));
    inputs.push(HostTensor::I32(y.clone()));
    let key = ctx.key_for(m);
    inputs.push(HostTensor::U32(vec![(key >> 32) as u32, key as u32]));
    inputs.push(HostTensor::F32(ctx.hyp.to_vec()));
    inputs.push(HostTensor::F32(ctx.devv.to_vec()));
    let mut outputs = exec.run(ctx.art, &inputs)?;
    let loss = outputs
        .pop()
        .and_then(|v| v.first().copied())
        .ok_or_else(|| anyhow!("step returned no loss"))? as f64;
    let out = ModelState::from_outputs(ctx.spec, outputs)?.leaves;
    if let Some(t0) = t0 {
        metrics::counter(MetricId::TrainStepsTotal, 1);
        metrics::histogram(MetricId::TrainStepSeconds, t0.elapsed().as_secs_f64());
    }
    Ok((loss, out))
}

/// Compute-worker loop: claim, wait for the input version, execute,
/// post the result. Exits on stop, error, or task exhaustion.
fn worker(ctx: &WorkerCtx<'_>, hub: &Hub) {
    let alive0 = Instant::now();
    let mut busy = 0.0f64;
    let exec = match ctx.exec_spec.build(ctx.reg) {
        Ok(e) => e,
        Err(e) => {
            hub.fail(format!("pipeline worker executor: {e:#}"));
            return;
        }
    };
    while let Some(m) = hub.claim(ctx.steps) {
        let base = m.saturating_sub(ctx.staleness);
        let Some((snap, stall)) = hub.wait_version(base) else {
            break;
        };
        metrics::histogram(MetricId::PipelineStallSeconds, stall);
        let t0 = Instant::now();
        let r = run_step(ctx, &exec, &snap, m);
        busy += t0.elapsed().as_secs_f64();
        match r {
            Ok((loss, out)) => hub.complete(
                m,
                WorkerOut {
                    loss,
                    base,
                    out: Arc::new(out),
                },
            ),
            Err(e) => {
                hub.fail(format!("pipeline step {m}: {e:#}"));
                break;
            }
        }
    }
    hub.push_occupancy(busy, alive0.elapsed().as_secs_f64());
}

/// One stage applier: owns the leaves of a contiguous tile range.
struct StageCtx {
    idx: usize,
    /// Manifest leaf indices in this stage's group, ascending.
    members: Vec<usize>,
    last: bool,
    /// Versions kept behind the newest: `staleness + 1`.
    retain: u64,
}

/// Stage-applier loop: fold each in-order `Apply` into this stage's
/// leaf group and hand the message on. The last stage publishes.
fn stage(
    ctx: &StageCtx,
    hub: &Hub,
    rx: mpsc::Receiver<ApplyMsg>,
    tx: Option<mpsc::Sender<ApplyMsg>>,
) {
    while let Ok(msg) = rx.recv() {
        let ApplyMsg::Step { task, base, out } = msg else {
            break;
        };
        let (cur, prev) = {
            let g = hub.lock();
            (
                g.groups.get(&(task, ctx.idx)).cloned(),
                g.groups.get(&(base, ctx.idx)).cloned(),
            )
        };
        let (Some(cur), Some(prev)) = (cur, prev) else {
            hub.fail(format!(
                "pipeline stage {}: versions {task}/{base} evicted",
                ctx.idx
            ));
            break;
        };
        let new: Vec<Vec<f32>> = if base == task {
            // the step ran against the newest state: its output *is*
            // version task+1 — applying it as a delta (cur + (out -
            // cur)) would flip low bits and break the D=0 contract
            ctx.members.iter().map(|&li| out[li].clone()).collect()
        } else {
            ctx.members
                .iter()
                .enumerate()
                .map(|(p, &li)| {
                    cur[p]
                        .iter()
                        .zip(prev[p].iter())
                        .zip(out[li].iter())
                        .map(|((&c, &b), &o)| c + (o - b))
                        .collect()
                })
                .collect()
        };
        {
            let mut g = hub.lock();
            g.groups.insert((task + 1, ctx.idx), Arc::new(new));
            let keep_from = (task + 1).saturating_sub(ctx.retain);
            let idx = ctx.idx;
            g.groups.retain(|&(v, s), _| s != idx || v >= keep_from);
            if ctx.last {
                g.published = task + 1;
            }
        }
        if ctx.last {
            hub.cv.notify_all();
        }
        if let Some(tx) = &tx {
            if tx.send(ApplyMsg::Step { task, base, out }).is_err() {
                break;
            }
        }
    }
    // rx/tx drop here, cascading shutdown down the chain
    if let Some(tx) = tx {
        let _ = tx.send(ApplyMsg::Stop);
    }
}

/// Coordinator-side constants derived before the threads start.
struct CoordCtx<'r> {
    spec: &'r ModelSpec,
    members: &'r [Vec<usize>],
    steps: u64,
    /// Eval period (0 = none); implies `test_ds` is present.
    e: u64,
    kpe: u64,
    kc0: u64,
}

/// Keys one full eval sweep consumes: one per batch, two for a ragged
/// tail batch (its loss needs a second artifact execution).
fn keys_per_eval(n: usize, eval_batch: usize) -> u64 {
    let mut keys = 0u64;
    let mut lo = 0;
    while lo < n {
        let take = eval_batch.min(n - lo);
        keys += if take == eval_batch { 1 } else { 2 };
        lo += take;
    }
    keys
}

/// The in-order commit loop; mirrors `Trainer::train` line for line on
/// everything observable (losses, EMA, logging, metrics, evals, cost).
fn run_coordinator(
    inner: &mut Trainer<'_>,
    ctx: &CoordCtx<'_>,
    hub: &Hub,
    tx: &mpsc::Sender<ApplyMsg>,
    test_ds: Option<&Dataset>,
) -> Result<TrainResult> {
    let spec = ctx.spec;
    let n_weights = spec.n_weights() as u64;
    let digital = inner.cfg.spec.method == Method::Digital;
    let mut res = TrainResult {
        cost: inner.calib_cost,
        ..TrainResult::default()
    };
    let mut ema = f64::NAN;
    let mut evals_done: u64 = 0;
    for k in 0..ctx.steps {
        let wo = hub.wait_done(k)?;
        res.losses.push(wo.loss);
        res.steps_run = (k + 1) as usize;
        let ema_next = if ema.is_nan() {
            wo.loss
        } else {
            0.95 * ema + 0.05 * wo.loss
        };
        let target_hit = inner.cfg.target_loss > 0.0
            && ema_next < inner.cfg.target_loss
            && res.reached_target_at.is_none();
        if target_hit {
            // freeze claims *before* version k+1 is published: workers
            // blocked on it re-check `stop` on wake, so no speculative
            // step beyond the break point runs at D=0
            hub.halt();
        }
        if tx
            .send(ApplyMsg::Step {
                task: k,
                base: wo.base,
                out: wo.out.clone(),
            })
            .is_err()
        {
            return Err(anyhow!(hub.error_or("pipeline stage chain closed early")));
        }
        if metrics::enabled() {
            metrics::gauge(MetricId::TrainLoss, wo.loss);
            if !digital {
                metrics::counter(MetricId::TrainUpdatePulsesTotal, n_weights * BL);
            }
            // post-step residual: at base==k the output IS state k+1;
            // otherwise wait for the appliers to rebase it
            let resid = if wo.base == k {
                fault::sp_residual_leaves(spec, &wo.out, &inner.cfg.dev)
            } else {
                let leaves = hub.wait_assembled(k + 1, ctx.members, spec)?;
                fault::sp_residual_leaves(spec, &leaves, &inner.cfg.dev)
            };
            metrics::gauge(MetricId::SpResidual, resid);
            metrics::gauge(MetricId::PipelineInflight, hub.inflight(k + 1));
            metrics::trace_sample(k);
        }
        ema = ema_next;
        if inner.cfg.log && (k % 50 == 0 || k + 1 == ctx.steps) {
            let loss = wo.loss;
            println!("  step {k:5}  loss {loss:.4}  ema {ema:.4}");
        }
        if ctx.e > 0 && (k + 1) % ctx.e == 0 {
            if let Some(ds) = test_ds {
                let leaves = hub.wait_assembled(k + 1, ctx.members, spec)?;
                inner.state.leaves = leaves;
                inner.key_counter = ctx
                    .kc0
                    .wrapping_add(k + 1)
                    .wrapping_add(ctx.kpe.wrapping_mul(evals_done));
                let (el, ea) = inner.eval(ds)?;
                evals_done += 1;
                if inner.cfg.log {
                    println!("  step {k:5}  eval loss {el:.4}  acc {ea:.2}%");
                }
                res.evals.push(((k + 1) as usize, el, ea));
            }
        }
        if target_hit {
            res.reached_target_at = Some((k + 1) as usize);
            break;
        }
    }
    // drain: the state after the last committed step becomes the
    // trainer state, with the synchronous key counter re-derived
    let final_v = res.steps_run as u64;
    inner.state.leaves = hub.wait_assembled(final_v, ctx.members, spec)?;
    inner.key_counter = ctx
        .kc0
        .wrapping_add(final_v)
        .wrapping_add(ctx.kpe.wrapping_mul(evals_done));
    if digital {
        res.cost.digital_ops += final_v * n_weights;
    } else {
        res.cost.update_pulses = PulseCost::training_estimate(final_v, n_weights, BL);
    }
    if let Some(ds) = test_ds {
        let (el, ea) = inner.eval(ds)?;
        res.evals.push((res.steps_run, el, ea));
        res.final_eval_acc = ea;
    }
    Ok(res)
}

/// Pipelined trainer over a wrapped synchronous [`Trainer`].
///
/// Construction, checkpointing and evaluation delegate to the inner
/// trainer; only `train` replaces the step loop with the
/// worker/stage-chain topology described in the module docs. After
/// `train` returns, the inner trainer's state and key counter are
/// exactly what the synchronous schedule would have left (for `D = 0`
/// bit for bit), so sync and pipelined segments can be freely
/// interleaved on one model.
pub struct PipelineTrainer<'a> {
    inner: Trainer<'a>,
    pcfg: PipelineConfig,
}

impl<'a> PipelineTrainer<'a> {
    /// Validate the topology against the model manifest and initialize
    /// the model exactly like [`Trainer::new`].
    pub fn new(
        exec: &'a Executor,
        reg: &'a Registry,
        cfg: TrainConfig,
        pcfg: PipelineConfig,
    ) -> Result<PipelineTrainer<'a>> {
        let spec = reg.model(&cfg.model)?;
        let tiles = distinct_tiles(spec);
        if pcfg.stages == 0 || pcfg.workers == 0 {
            return Err(anyhow!("pipeline needs at least one stage and one worker"));
        }
        if pcfg.stages > tiles.len() {
            return Err(anyhow!(
                "model {} has {} tiles; cannot split into {} stages",
                cfg.model,
                tiles.len(),
                pcfg.stages
            ));
        }
        let inner = Trainer::new(exec, reg, cfg)?;
        Ok(PipelineTrainer { inner, pcfg })
    }

    /// The wrapped synchronous trainer (state, config, eval).
    pub fn inner(&self) -> &Trainer<'a> {
        &self.inner
    }

    /// Mutable access to the wrapped trainer (e.g. to extend
    /// `cfg.steps` between segments).
    pub fn inner_mut(&mut self) -> &mut Trainer<'a> {
        &mut self.inner
    }

    /// Snapshot the full trainer state; round-trips through
    /// [`Checkpoint::save`]/[`Checkpoint::load`] like the synchronous
    /// trainer's.
    pub fn checkpoint(&self, step: u64) -> Checkpoint {
        self.inner.checkpoint(step)
    }

    /// Restore a checkpoint taken from either trainer flavor.
    pub fn restore(&mut self, ck: &Checkpoint) {
        self.inner.restore(ck)
    }

    /// Pipelined training run; the observable result contract is
    /// documented on the module.
    pub fn train(&mut self, train_ds: &Dataset, test_ds: Option<&Dataset>) -> Result<TrainResult> {
        let reg = self.inner.reg;
        let spec = reg.model(&self.inner.cfg.model)?;
        let art = reg.artifact(&self.inner.cfg.step_artifact())?;
        let s_n = self.pcfg.stages;

        // contiguous tile partition -> leaf groups and the reverse map
        let tiles = distinct_tiles(spec);
        if s_n == 0 || s_n > tiles.len() {
            return Err(anyhow!("invalid stage count {s_n} for {} tiles", tiles.len()));
        }
        let mut members = vec![Vec::new(); s_n];
        for (li, leaf) in spec.state.iter().enumerate() {
            let ti = tiles.iter().position(|&t| t == leaf.tile).unwrap_or(0);
            members[ti * s_n / tiles.len()].push(li);
        }
        let mut locate = vec![(0usize, 0usize); spec.state.len()];
        for (s, m) in members.iter().enumerate() {
            for (p, &li) in m.iter().enumerate() {
                locate[li] = (s, p);
            }
        }

        // pre-draw every batch from the synchronous Batcher stream
        // (memory: steps x batch samples; fine at experiment scale)
        let steps = self.inner.cfg.steps;
        let mut batcher = Batcher::new(train_ds.n, spec.batch, self.inner.cfg.seed ^ 0xB00C);
        let mut batches = Vec::with_capacity(steps);
        let (mut bx, mut by) = (Vec::new(), Vec::new());
        for _ in 0..steps {
            batcher.next_batch(train_ds, &mut bx, &mut by);
            batches.push((bx.clone(), by.clone()));
        }

        let e = if self.inner.cfg.eval_every > 0 && test_ds.is_some() {
            self.inner.cfg.eval_every as u64
        } else {
            0
        };
        let kpe = match (e, test_ds) {
            (1.., Some(ds)) => keys_per_eval(ds.n, spec.eval_batch),
            _ => 0,
        };
        let hyp = self.inner.cfg.hypers.to_vec(reg);
        let devv = self.inner.cfg.dev.to_vec(reg);
        let kc0 = self.inner.key_counter;

        let init_groups: Vec<Vec<Vec<f32>>> = members
            .iter()
            .map(|m| m.iter().map(|&li| self.inner.state.leaves[li].clone()).collect())
            .collect();
        let hub = Hub::new(s_n, init_groups);
        let wctx = WorkerCtx {
            reg,
            spec,
            art,
            exec_spec: StageExecSpec {
                precompile: vec![art.name.clone()],
                plan_threads: self.pcfg.plan_threads,
            },
            batches: &batches,
            locate: &locate,
            hyp: &hyp,
            devv: &devv,
            steps: steps as u64,
            staleness: self.pcfg.staleness,
            kc0,
            eval_every: e,
            keys_per_eval: kpe,
        };
        let stage_ctxs: Vec<StageCtx> = members
            .iter()
            .enumerate()
            .map(|(i, m)| StageCtx {
                idx: i,
                members: m.clone(),
                last: i + 1 == s_n,
                retain: self.pcfg.staleness + 1,
            })
            .collect();
        let cctx = CoordCtx {
            spec,
            members: &members,
            steps: steps as u64,
            e,
            kpe,
            kc0,
        };

        let inner = &mut self.inner;
        let workers = self.pcfg.workers;
        let result = std::thread::scope(|sc| {
            let (tx0, mut rx_prev) = mpsc::channel::<ApplyMsg>();
            for (i, sctx) in stage_ctxs.iter().enumerate() {
                let (tx_next, rx_next) = mpsc::channel::<ApplyMsg>();
                let rx = std::mem::replace(&mut rx_prev, rx_next);
                let tx = (i + 1 < s_n).then_some(tx_next);
                let hub = &hub;
                sc.spawn(move || stage(sctx, hub, rx, tx));
            }
            drop(rx_prev);
            for _ in 0..workers {
                let (wctx, hub) = (&wctx, &hub);
                sc.spawn(move || worker(wctx, hub));
            }
            let out = run_coordinator(inner, &cctx, &hub, &tx0, test_ds);
            hub.halt();
            let _ = tx0.send(ApplyMsg::Stop);
            out
        });
        if metrics::enabled() {
            let g = hub.lock();
            let (busy, alive) = g
                .occ
                .iter()
                .fold((0.0, 0.0), |(b, a), &(wb, wa)| (b + wb, a + wa));
            if alive > 0.0 {
                metrics::gauge(MetricId::PipelineStageOccupancy, busy / alive);
            }
        }
        result
    }
}

/// Sorted distinct tile ids in the model manifest.
fn distinct_tiles(spec: &ModelSpec) -> Vec<usize> {
    let mut tiles: Vec<usize> = spec.state.iter().map(|l| l.tile).collect();
    tiles.sort_unstable();
    tiles.dedup();
    tiles
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keys_per_eval_counts_ragged_tail() {
        // 200 samples, batch 200: one full batch, one key
        assert_eq!(keys_per_eval(200, 200), 1);
        // 250 samples, batch 200: full batch + ragged tail (2 keys)
        assert_eq!(keys_per_eval(250, 200), 3);
        // 90 samples, batch 200: single ragged batch
        assert_eq!(keys_per_eval(90, 200), 2);
        // exact multiple
        assert_eq!(keys_per_eval(400, 200), 2);
    }

    #[test]
    fn key_derivation_matches_sync_discipline() {
        // kc0=100, eval every 3 steps consuming 2 keys: steps 0,1,2
        // draw 101,102,103; the eval after step 2 consumes 104,105;
        // step 3 draws 106
        assert_eq!(step_key(100, 2, 3, 0), 101);
        assert_eq!(step_key(100, 2, 3, 2), 103);
        assert_eq!(step_key(100, 2, 3, 3), 106);
        assert_eq!(step_key(100, 2, 3, 5), 108);
        assert_eq!(step_key(100, 2, 3, 6), 111);
        // no evals: plain successor counter
        assert_eq!(step_key(7, 0, 0, 4), 12);
    }
}
