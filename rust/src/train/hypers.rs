//! Hyper-parameter and device-parameter vectors for the AOT step
//! artifacts, with per-method defaults patterned on the paper's
//! Tables 4–6 (adapted to this simulator's scale).
//!
//! Methods are identified by `analog::optimizer::Method` — the same
//! registry the pulse-level layer uses — so resolution is total (no
//! string matching, no panic on unknown names).

use crate::analog::optimizer::Method;
use crate::device::Preset;
use crate::runtime::Registry;

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Hypers {
    pub lr_fast: f32,
    pub lr_transfer: f32,
    pub eta: f32,
    pub gamma: f32,
    pub flip_p: f32,
    pub thresh: f32,
    pub lr_digital: f32,
    pub read_noise: f32,
}

impl Hypers {
    /// NN-scale per-method defaults (Tables 4–6 analogues). Total over
    /// the registry: the structural constraints that used to live at
    /// call sites — RIDER = E-RIDER with the chopper off (Section 4),
    /// two-stage residual = E-RIDER with a frozen reference after ZS
    /// (Algorithm 4) — are resolved here, from the [`Method`] alone.
    pub fn for_method(method: Method) -> Hypers {
        // E-RIDER (paper Table 4/6 analogues, re-tuned for this
        // simulator: fast residual array, fast Q filter, per-line
        // choppers at p = 0.05)
        let erider = Hypers {
            lr_fast: 0.5,
            lr_transfer: 0.3,
            eta: 0.3,
            gamma: 1.0,
            flip_p: 0.05,
            thresh: 0.1,
            lr_digital: 0.05,
            read_noise: 0.01,
        };
        match method {
            Method::Sgd => Hypers {
                lr_transfer: 0.0,
                eta: 0.0,
                gamma: 0.0,
                flip_p: 0.0,
                ..erider
            },
            Method::TtV1 | Method::TtV2 => Hypers {
                lr_transfer: 0.1,
                eta: 0.0,
                flip_p: 0.0,
                ..erider
            },
            Method::Agad => Hypers {
                lr_transfer: 0.1,
                ..erider
            },
            Method::Erider => erider,
            Method::Rider => Hypers { flip_p: 0.0, ..erider },
            Method::Residual => Hypers {
                eta: 0.0,
                flip_p: 0.0,
                ..erider
            },
            // multi-tile residual: at NN scale the tile stack has no
            // dedicated lowered step yet, so it runs the E-RIDER step
            // as a chopper-free single-tile stand-in (the true stack
            // lives at the pulse level, analog/mtres.rs)
            Method::Mtres => Hypers {
                eta: 0.0,
                flip_p: 0.0,
                ..erider
            },
            Method::Digital => Hypers {
                lr_fast: 0.0,
                lr_transfer: 0.0,
                eta: 0.0,
                gamma: 0.0,
                flip_p: 0.0,
                lr_digital: 0.1,
                read_noise: 0.0,
                ..erider
            },
        }
    }

    /// Pack into the artifact's hypers input vector.
    pub fn to_vec(&self, reg: &Registry) -> Vec<f32> {
        let mut v = vec![0.0f32; reg.n_hypers];
        let mut set = |k: &str, val: f32| {
            if let Some(&i) = reg.hyper_index.get(k) {
                v[i] = val;
            }
        };
        set("lr_fast", self.lr_fast);
        set("lr_transfer", self.lr_transfer);
        set("eta", self.eta);
        set("gamma", self.gamma);
        set("flip_p", self.flip_p);
        set("thresh", self.thresh);
        set("lr_digital", self.lr_digital);
        set("read_noise", self.read_noise);
        v
    }
}

/// Device parameter vector for the artifacts.
#[derive(Clone, Copy, Debug)]
pub struct DevParams {
    pub dw_min: f32,
    pub sigma_c2c: f32,
    pub tau_max: f32,
    pub tau_min: f32,
    pub out_noise: f32,
    pub inp_res: f32,
    pub out_res: f32,
    pub out_bound: f32,
}

impl DevParams {
    pub fn from_preset(p: &Preset) -> DevParams {
        DevParams {
            dw_min: p.dw_min as f32,
            sigma_c2c: p.c2c as f32,
            tau_max: p.tau_max as f32,
            tau_min: p.tau_min as f32,
            out_noise: 0.06,
            inp_res: 1.0 / 127.0,
            out_res: 1.0 / 511.0,
            out_bound: 12.0,
        }
    }

    pub fn to_vec(&self, reg: &Registry) -> Vec<f32> {
        let mut v = vec![0.0f32; reg.n_dev];
        let mut set = |k: &str, val: f32| {
            if let Some(&i) = reg.dev_index.get(k) {
                v[i] = val;
            }
        };
        set("dw_min", self.dw_min);
        set("sigma_c2c", self.sigma_c2c);
        set("tau_max", self.tau_max);
        set("tau_min", self.tau_min);
        set("out_noise", self.out_noise);
        set("inp_res", self.inp_res);
        set("out_res", self.out_res);
        set("out_bound", self.out_bound);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rider_is_erider_without_chopper() {
        let e = Hypers::for_method(Method::Erider);
        let r = Hypers::for_method(Method::Rider);
        assert_eq!(r.flip_p, 0.0);
        assert_eq!(r.lr_fast, e.lr_fast);
        assert_eq!(r.eta, e.eta);
    }

    #[test]
    fn residual_freezes_the_reference() {
        let res = Hypers::for_method(Method::Residual);
        assert_eq!(res.eta, 0.0);
        assert_eq!(res.flip_p, 0.0);
    }

    #[test]
    fn every_registry_method_has_defaults() {
        for name in crate::analog::optimizer::METHODS {
            let m = Method::parse(name).expect(name);
            let h = Hypers::for_method(m);
            assert!(h.lr_digital >= 0.0, "{name}");
        }
    }

    #[test]
    fn preset_to_dev() {
        let d = DevParams::from_preset(&crate::device::HFO2);
        assert!((d.dw_min - 0.4622).abs() < 1e-6);
        assert!((d.sigma_c2c - 0.2174).abs() < 1e-6);
    }
}
