//! HLO-driven training: state management, hyperparameters and the
//! trainer loop over the AOT step artifacts.

pub mod hypers;
pub mod state;
pub mod trainer;

pub use hypers::{DevParams, Hypers};
pub use state::ModelState;
pub use trainer::{TrainConfig, TrainResult, Trainer, BL};
