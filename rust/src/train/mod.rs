//! HLO-driven training: state management, hyperparameters and the
//! trainer loop over the AOT step artifacts.

pub mod fault;
pub mod hypers;
pub mod pipeline;
pub mod state;
pub mod trainer;

pub use fault::{Checkpoint, LossSpikeMonitor, NnFaultInjector, RecoveryPolicy};
pub use hypers::{DevParams, Hypers};
pub use pipeline::{PipelineConfig, PipelineTrainer};
pub use state::ModelState;
pub use trainer::{TrainConfig, TrainResult, Trainer, BL};
