//! Experiment configuration: typed structs + a TOML-subset parser so
//! runs can be driven by config files (`rider train --config runs/x.toml`)
//! as well as CLI flags. The subset covers what configs need: `[section]`
//! headers, `key = value` with strings, numbers, booleans and flat arrays.

use std::collections::BTreeMap;

/// A parsed config: section -> key -> raw value string.
#[derive(Clone, Debug, Default)]
pub struct Config {
    sections: BTreeMap<String, BTreeMap<String, Value>>,
}

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Num(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

impl Config {
    pub fn parse(src: &str) -> Result<Config, String> {
        let mut cfg = Config::default();
        let mut section = String::new();
        for (lineno, raw) in src.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
                section = name.trim().to_string();
                cfg.sections.entry(section.clone()).or_default();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let value = parse_value(v.trim())
                .map_err(|e| format!("line {}: {}", lineno + 1, e))?;
            cfg.sections
                .entry(section.clone())
                .or_default()
                .insert(k.trim().to_string(), value);
        }
        Ok(cfg)
    }

    pub fn load(path: &str) -> Result<Config, String> {
        let src = std::fs::read_to_string(path).map_err(|e| format!("{}: {}", path, e))?;
        Config::parse(&src)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section)?.get(key)
    }

    pub fn f64(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(Value::as_f64).unwrap_or(default)
    }

    pub fn usize(&self, section: &str, key: &str, default: usize) -> usize {
        self.f64(section, key, default as f64) as usize
    }

    pub fn str(&self, section: &str, key: &str, default: &str) -> String {
        self.get(section, key)
            .and_then(Value::as_str)
            .unwrap_or(default)
            .to_string()
    }

    pub fn bool(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key).and_then(Value::as_bool).unwrap_or(default)
    }

    pub fn f64_list(&self, section: &str, key: &str, default: &[f64]) -> Vec<f64> {
        match self.get(section, key) {
            Some(Value::Arr(xs)) => xs.iter().filter_map(Value::as_f64).collect(),
            _ => default.to_vec(),
        }
    }

    pub fn sections(&self) -> impl Iterator<Item = &String> {
        self.sections.keys()
    }
}

fn strip_comment(line: &str) -> &str {
    // respect '#' outside of quotes
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('"').and_then(|t| t.strip_suffix('"')) {
        return Ok(Value::Str(inner.to_string()));
    }
    if let Some(inner) = s.strip_prefix('[').and_then(|t| t.strip_suffix(']')) {
        let mut out = Vec::new();
        for part in inner.split(',') {
            let p = part.trim();
            if p.is_empty() {
                continue;
            }
            out.push(parse_value(p)?);
        }
        return Ok(Value::Arr(out));
    }
    s.parse::<f64>()
        .map(Value::Num)
        .map_err(|_| format!("cannot parse value '{}'", s))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SRC: &str = r#"
# experiment config
[train]
model = "fcn"          # model name
steps = 2000
lr_fast = 0.5
use_chopper = true
ref_means = [0.0, 0.2, 0.4]

[device]
preset = "hfo2"
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SRC).unwrap();
        assert_eq!(c.str("train", "model", ""), "fcn");
        assert_eq!(c.usize("train", "steps", 0), 2000);
        assert_eq!(c.f64("train", "lr_fast", 0.0), 0.5);
        assert!(c.bool("train", "use_chopper", false));
        assert_eq!(c.f64_list("train", "ref_means", &[]), vec![0.0, 0.2, 0.4]);
        assert_eq!(c.str("device", "preset", ""), "hfo2");
    }

    #[test]
    fn defaults_for_missing() {
        let c = Config::parse(SRC).unwrap();
        assert_eq!(c.f64("train", "nope", 7.5), 7.5);
        assert_eq!(c.str("nosection", "x", "d"), "d");
    }

    #[test]
    fn comment_inside_string_kept() {
        let c = Config::parse("[s]\nname = \"a#b\"\n").unwrap();
        assert_eq!(c.str("s", "name", ""), "a#b");
    }

    #[test]
    fn bad_line_errors() {
        assert!(Config::parse("[s]\njust a line\n").is_err());
        assert!(Config::parse("[s]\nx = @@\n").is_err());
    }
}
