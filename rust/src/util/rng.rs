//! Deterministic pseudo-random number generation.
//!
//! The offline crate set has no `rand`, so we carry our own: SplitMix64
//! for seeding/streams and PCG32 (XSH-RR) for the bulk stream, plus
//! Box–Muller normals with caching for scalar draws and a Marsaglia
//! polar batch sampler (no `sin`/`cos`) for the buffer-fill hot paths.
//! Everything is reproducible from a `u64` seed, which the experiment
//! configs record.

/// SplitMix64 — tiny, well-distributed; used to expand seeds into streams.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Start a sequence from `seed`.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64-bit value in the sequence.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG32 (XSH-RR 64/32): the main generator.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
    inc: u64,
    /// cached second Box–Muller normal
    spare: Option<f64>,
}

impl Rng {
    /// Construct from a seed; `stream` selects an independent sequence
    /// (used to give worker threads / array tiles their own streams).
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut sm = SplitMix64::new(seed ^ stream.wrapping_mul(0xA076_1D64_78BD_642F));
        let mut rng = Self {
            state: 0,
            inc: (sm.next_u64() << 1) | 1,
            spare: None,
        };
        rng.state = sm.next_u64();
        rng.next_u32();
        rng
    }

    /// Construct stream 0 of `seed` (the common single-stream case).
    pub fn from_seed(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Next 32-bit value (one PCG32 step).
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64-bit value (two PCG32 steps, high word first).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u32() as f64) * (1.0 / 4_294_967_296.0)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        // Lemire-style rejection-free for our purposes (n << 2^32).
        ((self.next_u32() as u64 * n as u64) >> 32) as usize
    }

    /// Random sign: +1.0 or -1.0 with equal probability.
    #[inline]
    pub fn sign(&mut self) -> f64 {
        if self.next_u32() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// N(mu, sigma^2).
    #[inline]
    pub fn normal_scaled(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.normal()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// One accepted Marsaglia polar pair: two independent standard
    /// normals per ~1.27 (u, v) candidates, with no `sin`/`cos` and all
    /// arithmetic in f32 — the batch-fill workhorse.
    #[inline]
    fn polar_pair_f32(&mut self) -> (f32, f32) {
        const SCALE: f32 = 2.0 / 4_294_967_296.0;
        loop {
            let u = self.next_u32() as f32 * SCALE - 1.0;
            let v = self.next_u32() as f32 * SCALE - 1.0;
            let s = u * u + v * v;
            if s < 1.0 && s > f32::MIN_POSITIVE {
                let k = (-2.0 * s.ln() / s).sqrt();
                return (u * k, v * k);
            }
        }
    }

    /// Fill a buffer with standard normals (f32) via the polar method.
    /// Faster than per-element [`Rng::normal`] (no trig, no f64); the
    /// stream it consumes differs from the scalar path, so the two are
    /// equivalent in distribution, not draw-for-draw.
    pub fn fill_normal_f32(&mut self, out: &mut [f32]) {
        let mut i = 0;
        while i + 1 < out.len() {
            let (z0, z1) = self.polar_pair_f32();
            out[i] = z0;
            out[i + 1] = z1;
            i += 2;
        }
        if i < out.len() {
            out[i] = self.polar_pair_f32().0;
        }
    }

    /// `out[i] += scale * z_i` with batch-sampled standard normals —
    /// the allocation-free noisy-gradient / noisy-read primitive.
    pub fn add_normal_f32(&mut self, out: &mut [f32], scale: f32) {
        let mut z = [0.0f32; 256];
        let mut start = 0;
        while start < out.len() {
            let n = (out.len() - start).min(z.len());
            self.fill_normal_f32(&mut z[..n]);
            for (o, zi) in out[start..start + n].iter_mut().zip(&z[..n]) {
                *o += scale * *zi;
            }
            start += n;
        }
    }

    /// Fill a buffer with U[0,1) (f32, 24-bit resolution — exact on the
    /// f32 lattice, one `next_u32` per element).
    pub fn fill_uniform_f32(&mut self, out: &mut [f32]) {
        const SCALE: f32 = 1.0 / 16_777_216.0;
        for v in out.iter_mut() {
            *v = (self.next_u32() >> 8) as f32 * SCALE;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42, 0);
        let mut b = Rng::new(42, 0);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Rng::new(42, 0);
        let mut b = Rng::new(42, 1);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_moments() {
        let mut r = Rng::from_seed(7);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            s += u;
            s2 += u * u;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
        assert!((var - 1.0 / 12.0).abs() < 0.01, "{var}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::from_seed(11);
        let n = 200_000;
        let (mut s, mut s2, mut s3) = (0.0, 0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            s += z;
            s2 += z * z;
            s3 += z * z * z;
        }
        assert!((s / n as f64).abs() < 0.02);
        assert!((s2 / n as f64 - 1.0).abs() < 0.02);
        assert!((s3 / n as f64).abs() < 0.05); // symmetry
    }

    #[test]
    fn batch_normal_moments() {
        let mut r = Rng::from_seed(13);
        let n = 400_000;
        let mut buf = vec![0.0f32; n];
        r.fill_normal_f32(&mut buf);
        let (mut s, mut s2, mut s3, mut s4) = (0.0f64, 0.0, 0.0, 0.0);
        for &z in &buf {
            let z = z as f64;
            s += z;
            s2 += z * z;
            s3 += z * z * z;
            s4 += z * z * z * z;
        }
        let n = n as f64;
        assert!((s / n).abs() < 0.01, "mean {}", s / n);
        assert!((s2 / n - 1.0).abs() < 0.02, "var {}", s2 / n);
        assert!((s3 / n).abs() < 0.05, "skew {}", s3 / n);
        assert!((s4 / n - 3.0).abs() < 0.1, "kurtosis {}", s4 / n);
    }

    #[test]
    fn batch_normal_tail_probabilities() {
        // P(|Z| > 1) = 0.3173, P(|Z| > 2) = 0.04550, P(|Z| > 3) = 0.00270
        let mut r = Rng::from_seed(17);
        let n = 400_000;
        let mut buf = vec![0.0f32; n];
        r.fill_normal_f32(&mut buf);
        let frac = |t: f32| buf.iter().filter(|z| z.abs() > t).count() as f64 / n as f64;
        assert!((frac(1.0) - 0.3173).abs() < 0.005, "{}", frac(1.0));
        assert!((frac(2.0) - 0.0455).abs() < 0.002, "{}", frac(2.0));
        assert!((frac(3.0) - 0.0027).abs() < 0.0006, "{}", frac(3.0));
        assert!(buf.iter().all(|z| z.is_finite()));
    }

    #[test]
    fn batch_fill_handles_every_length() {
        let mut r = Rng::from_seed(19);
        for len in [0usize, 1, 2, 3, 7, 255, 256, 257] {
            let mut buf = vec![f32::NAN; len];
            r.fill_normal_f32(&mut buf);
            assert!(buf.iter().all(|z| z.is_finite()), "len {len}");
            let mut buf = vec![f32::NAN; len];
            r.fill_uniform_f32(&mut buf);
            assert!(buf.iter().all(|u| (0.0..1.0).contains(u)), "len {len}");
        }
    }

    #[test]
    fn batch_uniform_moments() {
        let mut r = Rng::from_seed(23);
        let mut buf = vec![0.0f32; 200_000];
        r.fill_uniform_f32(&mut buf);
        let n = buf.len() as f64;
        let s: f64 = buf.iter().map(|&u| u as f64).sum();
        let s2: f64 = buf.iter().map(|&u| (u as f64).powi(2)).sum();
        let mean = s / n;
        let var = s2 / n - mean * mean;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
        assert!((var - 1.0 / 12.0).abs() < 0.01, "{var}");
    }

    #[test]
    fn add_normal_scales_and_accumulates() {
        let mut r = Rng::from_seed(29);
        let mut buf = vec![2.0f32; 100_000];
        r.add_normal_f32(&mut buf, 0.5);
        let n = buf.len() as f64;
        let mean: f64 = buf.iter().map(|&x| x as f64).sum::<f64>() / n;
        let var: f64 =
            buf.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n;
        assert!((mean - 2.0).abs() < 0.02, "{mean}");
        assert!((var - 0.25).abs() < 0.01, "{var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::from_seed(3);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::from_seed(9);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn sign_balanced() {
        let mut r = Rng::from_seed(5);
        let pos = (0..100_000).filter(|_| r.sign() > 0.0).count();
        assert!((pos as f64 / 100_000.0 - 0.5).abs() < 0.01);
    }
}
