//! Deterministic pseudo-random number generation.
//!
//! The offline crate set has no `rand`, so we carry our own: SplitMix64
//! for seeding/streams and PCG32 (XSH-RR) for the bulk stream, plus
//! Box–Muller normals with caching. Everything is reproducible from a
//! `u64` seed, which the experiment configs record.

/// SplitMix64 — tiny, well-distributed; used to expand seeds into streams.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG32 (XSH-RR 64/32): the main generator.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
    inc: u64,
    /// cached second Box–Muller normal
    spare: Option<f64>,
}

impl Rng {
    /// Construct from a seed; `stream` selects an independent sequence
    /// (used to give worker threads / array tiles their own streams).
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut sm = SplitMix64::new(seed ^ stream.wrapping_mul(0xA076_1D64_78BD_642F));
        let mut rng = Self {
            state: 0,
            inc: (sm.next_u64() << 1) | 1,
            spare: None,
        };
        rng.state = sm.next_u64();
        rng.next_u32();
        rng
    }

    pub fn from_seed(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u32() as f64) * (1.0 / 4_294_967_296.0)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        // Lemire-style rejection-free for our purposes (n << 2^32).
        ((self.next_u32() as u64 * n as u64) >> 32) as usize
    }

    /// Random sign: +1.0 or -1.0 with equal probability.
    #[inline]
    pub fn sign(&mut self) -> f64 {
        if self.next_u32() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// N(mu, sigma^2).
    #[inline]
    pub fn normal_scaled(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.normal()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Fill a buffer with standard normals (f32).
    pub fn fill_normal_f32(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.normal() as f32;
        }
    }

    /// Fill a buffer with U[0,1) (f32).
    pub fn fill_uniform_f32(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.uniform() as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42, 0);
        let mut b = Rng::new(42, 0);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Rng::new(42, 0);
        let mut b = Rng::new(42, 1);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_moments() {
        let mut r = Rng::from_seed(7);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            s += u;
            s2 += u * u;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
        assert!((var - 1.0 / 12.0).abs() < 0.01, "{var}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::from_seed(11);
        let n = 200_000;
        let (mut s, mut s2, mut s3) = (0.0, 0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            s += z;
            s2 += z * z;
            s3 += z * z * z;
        }
        assert!((s / n as f64).abs() < 0.02);
        assert!((s2 / n as f64 - 1.0).abs() < 0.02);
        assert!((s3 / n as f64).abs() < 0.05); // symmetry
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::from_seed(3);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::from_seed(9);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn sign_balanced() {
        let mut r = Rng::from_seed(5);
        let pos = (0..100_000).filter(|_| r.sign() > 0.0).count();
        assert!((pos as f64 / 100_000.0 - 0.5).abs() < 0.01);
    }
}
