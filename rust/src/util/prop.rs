//! Tiny property-testing harness (proptest is not available offline).
//!
//! A property is a closure over a seeded [`crate::util::rng::Rng`]; the
//! harness runs it across many seeds and reports the first failing seed,
//! so failures are reproducible by construction. Coordinator invariants
//! (batcher coverage, state round-trips, pulse accounting, device bounds)
//! are tested with this.

use crate::util::rng::Rng;

/// Run `cases` random cases of a property. The closure receives a fresh
/// deterministic RNG per case and returns `Err(msg)` to fail.
pub fn check<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = 0xDEAD_BEEF ^ case.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(seed, case);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{}' failed at case {} (seed {:#x}): {}",
                name, case, seed, msg
            );
        }
    }
}

/// Assert helper producing `Result` for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err(format!($($arg)+));
        }
    };
    ($cond:expr) => {
        if !($cond) {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

/// Generators for common shapes.
pub mod gen {
    use super::Rng;

    /// Vector of f64 in [lo, hi).
    pub fn vec_uniform(rng: &mut Rng, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| rng.uniform_in(lo, hi)).collect()
    }

    /// Vector of f32 in [lo, hi).
    pub fn vec_uniform_f32(rng: &mut Rng, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n)
            .map(|_| rng.uniform_in(lo as f64, hi as f64) as f32)
            .collect()
    }

    /// Size in [lo, hi].
    pub fn size(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        lo + rng.below(hi - lo + 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("trivial", 50, |_| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics_with_seed() {
        check("fails", 10, |rng| {
            let x = rng.uniform();
            prop_assert!(x < 0.5, "x was {}", x);
            Ok(())
        });
    }

    #[test]
    fn generators_in_range() {
        check("gen ranges", 20, |rng| {
            let n = gen::size(rng, 1, 64);
            prop_assert!((1..=64).contains(&n));
            let v = gen::vec_uniform(rng, n, -2.0, 3.0);
            prop_assert!(v.iter().all(|x| (-2.0..3.0).contains(x)));
            Ok(())
        });
    }
}
