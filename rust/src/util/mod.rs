//! Foundational substrates built in-repo (the offline crate set has no
//! rand / serde / clap / criterion / proptest): RNG, JSON, statistics,
//! table rendering, a bench harness, a property-testing harness and
//! the live metrics facade.

#![warn(missing_docs)]

pub mod bench;
pub mod json;
pub mod metrics;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
