//! Foundational substrates built in-repo (the offline crate set has no
//! rand / serde / clap / criterion / proptest): RNG, JSON, statistics,
//! table rendering, a bench harness and a property-testing harness.

pub mod bench;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
