//! Summary statistics, confidence intervals and least squares — the
//! numeric toolbox behind the experiment harness (offsets in Fig. 1,
//! slope checks for Theorem 2.2, mean±std cells of Tables 1/2).

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator; 0.0 for n < 2).
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Population standard deviation.
pub fn std_pop(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// p-th percentile (p in [0,100], linear interpolation). NaN-safe:
/// `total_cmp` sorts NaN samples to the top instead of panicking —
/// a faulty device can legitimately produce them.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Simple linear regression y = a + b x; returns (a, b, r2).
pub fn linreg(x: &[f64], y: &[f64]) -> (f64, f64, f64) {
    assert_eq!(x.len(), y.len());
    if x.len() < 2 {
        return (mean(y), 0.0, 0.0);
    }
    let mx = mean(x);
    let my = mean(y);
    let _n = x.len() as f64;
    let sxy: f64 = x.iter().zip(y).map(|(a, b)| (a - mx) * (b - my)).sum();
    let sxx: f64 = x.iter().map(|a| (a - mx) * (a - mx)).sum();
    let syy: f64 = y.iter().map(|b| (b - my) * (b - my)).sum();
    if sxx == 0.0 {
        return (my, 0.0, 0.0);
    }
    let b = sxy / sxx;
    let a = my - b * mx;
    let r2 = if syy == 0.0 { 1.0 } else { (sxy * sxy) / (sxx * syy) };
    (a, b, r2)
}

/// Log–log slope: fit log(y) = a + b log(x). Used to check power laws
/// like N ~ 1/dw_min (expected slope about -1, Theorem 2.2).
pub fn loglog_slope(x: &[f64], y: &[f64]) -> f64 {
    let lx: Vec<f64> = x.iter().map(|v| v.ln()).collect();
    let ly: Vec<f64> = y.iter().map(|v| v.ln()).collect();
    linreg(&lx, &ly).1
}

/// Exponential moving average trace of a signal.
pub fn ema(xs: &[f64], alpha: f64) -> Vec<f64> {
    let mut out = Vec::with_capacity(xs.len());
    let mut m = None;
    for &x in xs {
        let v = match m {
            None => x,
            Some(prev) => (1.0 - alpha) * prev + alpha * x,
        };
        m = Some(v);
        out.push(v);
    }
    out
}

/// Running summary accumulator (single pass, Welford).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    /// Empty accumulator.
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Fold one sample into the running moments.
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Number of samples folded in.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Running mean (0.0 when empty).
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n−1 denominator; 0.0 for n < 2).
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    /// Sample standard deviation.
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    /// Smallest sample seen (+inf when empty).
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Largest sample seen (−inf when empty).
    pub fn max(&self) -> f64 {
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_known() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_pop(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn welford_matches_batch() {
        let xs: Vec<f64> = (0..1000).map(|i| (i as f64 * 0.37).sin()).collect();
        let mut s = Summary::new();
        for &x in &xs {
            s.add(x);
        }
        assert!((s.mean() - mean(&xs)).abs() < 1e-12);
        assert!((s.std() - std(&xs)).abs() < 1e-10);
    }

    #[test]
    fn linreg_exact_line() {
        let x: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|v| 3.0 + 2.0 * v).collect();
        let (a, b, r2) = linreg(&x, &y);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
        assert!((r2 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn loglog_powerlaw() {
        let x: Vec<f64> = (1..40).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|v| 10.0 / v).collect();
        assert!((loglog_slope(&x, &y) + 1.0).abs() < 1e-9);
    }

    #[test]
    fn percentile_bounds() {
        let xs: Vec<f64> = (0..101).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert_eq!(percentile(&xs, 50.0), 50.0);
    }

    #[test]
    fn percentile_survives_nan_samples() {
        // a faulty device can emit NaN losses; percentile must not
        // panic, and total_cmp sorts NaNs above every finite value so
        // low/mid percentiles stay meaningful
        let xs = [3.0, f64::NAN, 1.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert!(percentile(&xs, 100.0).is_nan());
    }

    #[test]
    fn ema_converges_to_constant() {
        let xs = vec![5.0; 200];
        let t = ema(&xs, 0.1);
        assert!((t.last().unwrap() - 5.0).abs() < 1e-9);
    }
}
