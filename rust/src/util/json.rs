//! Minimal JSON parser + emitter (no serde offline).
//!
//! Parses the AOT `manifest.json` / `parity.json` files and emits metric
//! records. Supports the full JSON value grammar; numbers are f64.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value; numbers are uniformly `f64`.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number.
    Num(f64),
    /// A string.
    Str(String),
    /// An array of values.
    Arr(Vec<Json>),
    /// An object (sorted key order).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse `src` as a single JSON value; trailing non-space is an
    /// error.
    pub fn parse(src: &str) -> Result<Json, String> {
        let mut p = Parser {
            b: src.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    // -------- accessors ------------------------------------------------

    /// Object field lookup (`None` on non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Array element lookup (`None` on non-arrays / out of range).
    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    /// The string payload, if this is a [`Json::Str`].
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The numeric payload, if this is a [`Json::Num`].
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The numeric payload truncated to `usize`.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// The element slice, if this is a [`Json::Arr`].
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// The key/value map, if this is a [`Json::Obj`].
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Collect a numeric array into `Vec<f32>` (non-numbers skipped).
    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        self.as_arr()
            .map(|v| v.iter().filter_map(|x| x.as_f64()).map(|x| x as f32).collect())
    }

    // -------- emit ------------------------------------------------------

    /// Serialize to compact JSON text (round-trips through [`parse`]).
    ///
    /// [`parse`]: Json::parse
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => {
                out.push('"');
                for ch in s.chars() {
                    match ch {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience builders for metric emission.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Shorthand for [`Json::Num`].
pub fn num(n: f64) -> Json {
    Json::Num(n)
}

/// Shorthand for an owned [`Json::Str`].
pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

/// Build a numeric [`Json::Arr`] from an `f64` slice.
pub fn arr_f64(v: &[f64]) -> Json {
    Json::Arr(v.iter().map(|x| Json::Num(*x)).collect())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\n' | b'\t' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
            None => Err("unexpected end".into()),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected , or }} at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected , or ] at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u".into());
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| "bad \\u")?;
                            let cp =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u hex")?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a UTF-8 run
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| "bad utf8")?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).map_err(|_| "bad num")?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number '{}': {}", s, e))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": "hi\nthere", "c": null, "d": true}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn nested_access() {
        let v = Json::parse(r#"{"models": {"fcn": {"batch": 16}}}"#).unwrap();
        assert_eq!(
            v.get("models").unwrap().get("fcn").unwrap().get("batch").unwrap().as_usize(),
            Some(16)
        );
    }

    #[test]
    fn f32_vec() {
        let v = Json::parse("[1, 2, 3.5]").unwrap();
        assert_eq!(v.as_f32_vec().unwrap(), vec![1.0, 2.0, 3.5]);
    }

    #[test]
    fn rejects_trailing() {
        assert!(Json::parse("{} x").is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{\"a\": }").is_err());
        assert!(Json::parse("[1,]").is_err());
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd".to_string());
        assert_eq!(Json::parse(&v.dump()).unwrap(), v);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }
}
