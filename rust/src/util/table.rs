//! Paper-style ASCII table rendering for the experiment harness output
//! (Tables 1/2/8/9/10 and the figure-series printers).

/// A simple column-aligned table with a header row.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    /// Headers are owned `String`s; both `&["a", "b"]` literals and
    /// runtime-built `Vec<String>` column sets are accepted (no leaking
    /// boxed strs to fabricate `&'static str` headers).
    pub fn new<S: AsRef<str>>(title: &str, headers: &[S]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.as_ref().to_string()).collect(),
            rows: Vec::new(),
            title: title.to_string(),
        }
    }

    /// Append a data row (must match the header column count).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells);
    }

    /// Format a `mean ± std` cell the way the paper prints accuracy.
    pub fn pm(mean: f64, std: f64) -> String {
        format!("{:.2}±{:.1}", mean, std)
    }

    /// Render the column-aligned ASCII table with separators.
    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let sep: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                let pad = widths[i] - c.chars().count();
                s.push(' ');
                s.push_str(c);
                s.push_str(&" ".repeat(pad + 1));
                s.push('|');
            }
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        let _ = ncols;
        out
    }

    /// CSV form of the same data (written into the run directory).
    pub fn to_csv(&self) -> String {
        let esc = |c: &str| {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let mut out = self
            .headers
            .iter()
            .map(|h| esc(h))
            .collect::<Vec<_>>()
            .join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["method", "acc"]);
        t.row(vec!["TT-v2".into(), "75.19".into()]);
        t.row(vec!["E-RIDER".into(), "93.75".into()]);
        let s = t.render();
        assert!(s.contains("| method  | acc   |") || s.contains("| method"));
        assert!(s.lines().all(|l| l.is_empty() || l.starts_with('+') || l.starts_with('|') || l.starts_with("==")));
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["x,y".into(), "plain".into()]);
        assert_eq!(t.to_csv(), "a,b\n\"x,y\",plain\n");
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["only one".into()]);
    }

    #[test]
    fn pm_format() {
        assert_eq!(Table::pm(93.7512, 0.14), "93.75±0.1");
    }
}
