//! Mini-criterion: a benchmarking harness for `cargo bench` targets
//! (criterion is not available offline). Provides warmup, timed
//! iterations, outlier-robust statistics and throughput reporting.
//!
//! Bench binaries are declared with `harness = false` and call
//! [`Bench::run`] per case; output is both human-readable and
//! machine-parseable (one `BENCH\t...` line per case). A [`BenchSuite`]
//! additionally records every case into the live metrics facade
//! (`util::metrics`, labeled `bench_*` gauges) and writes the
//! `BENCH_*.json` trajectory file when `$BENCH_JSON_OUT` is set — the
//! pipeline `./ci.sh bench` consumes.

use crate::util::metrics;
use std::hint::black_box as bb;
use std::time::{Duration, Instant};

pub use std::hint::black_box;

/// Configuration for a bench run.
#[derive(Clone, Debug)]
pub struct Bench {
    /// Warmup phase length (also used to estimate per-iter cost).
    pub warmup: Duration,
    /// Target measurement phase length.
    pub measure: Duration,
    /// Lower bound on timed iterations.
    pub min_iters: u32,
    /// Upper bound on timed iterations.
    pub max_iters: u32,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(300),
            measure: Duration::from_millis(1500),
            min_iters: 10,
            max_iters: 1_000_000,
        }
    }
}

/// Result of one bench case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// Case name, e.g. `analog_update/256x256`.
    pub name: String,
    /// Timed iterations.
    pub iters: u32,
    /// Mean wall-clock per iteration, nanoseconds.
    pub mean_ns: f64,
    /// Sample standard deviation (n−1 denominator), nanoseconds.
    pub std_ns: f64,
    /// Median wall-clock per iteration, nanoseconds.
    pub median_ns: f64,
    /// Fastest iteration, nanoseconds.
    pub min_ns: f64,
}

impl BenchResult {
    /// Summarize raw per-iteration timings (ns). The std is the sample
    /// standard deviation (n−1 denominator), computed by
    /// `util::stats::std` so the two toolboxes cannot drift apart.
    pub fn from_samples(name: &str, mut samples: Vec<f64>) -> BenchResult {
        assert!(!samples.is_empty(), "bench case produced no samples");
        // total_cmp: NaN samples sort to the top instead of panicking
        samples.sort_by(f64::total_cmp);
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        BenchResult {
            name: name.to_string(),
            iters: samples.len() as u32,
            mean_ns: mean,
            std_ns: crate::util::stats::std(&samples),
            median_ns: samples[samples.len() / 2],
            min_ns: samples[0],
        }
    }

    /// The machine-parseable one-line report (`BENCH\t...` fields).
    pub fn report(&self) -> String {
        format!(
            "BENCH\t{}\titers={}\tmean={}\tmedian={}\tmin={}\tstd={}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.median_ns),
            fmt_ns(self.min_ns),
            fmt_ns(self.std_ns),
        )
    }

    /// Report with an ops/sec throughput figure (e.g. pulses/s, steps/s).
    pub fn report_throughput(&self, unit: &str, per_iter: f64) -> String {
        let per_sec = per_iter / (self.mean_ns * 1e-9);
        format!("{}\tthroughput={:.3e} {}/s", self.report(), per_sec, unit)
    }
}

/// Render a nanosecond figure with an auto-selected ns/us/ms/s unit.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{:.1}ns", ns)
    } else if ns < 1e6 {
        format!("{:.2}us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

impl Bench {
    /// Quick preset used inside `cargo test` smoke checks.
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(20),
            measure: Duration::from_millis(100),
            min_iters: 3,
            max_iters: 10_000,
        }
    }

    /// Run `f` repeatedly, timing each call.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        // Warmup + estimate per-iter cost.
        let wstart = Instant::now();
        let mut wcount = 0u32;
        while wstart.elapsed() < self.warmup || wcount < 1 {
            f();
            wcount += 1;
            if wcount >= self.max_iters {
                break;
            }
        }
        let est_ns = (wstart.elapsed().as_nanos() as f64 / wcount.max(1) as f64).max(1.0);
        let target = (self.measure.as_nanos() as f64 / est_ns) as u32;
        let iters = target.clamp(self.min_iters, self.max_iters);

        let mut samples = Vec::with_capacity(iters as usize);
        for _ in 0..iters {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_nanos() as f64);
        }
        BenchResult::from_samples(name, samples)
    }

    /// Run and print the default report; returns the result for further use.
    pub fn run_print<F: FnMut()>(&self, name: &str, f: F) -> BenchResult {
        let r = self.run(name, f);
        println!("{}", r.report());
        r
    }
}

/// Re-exported for bench bodies that need to defeat the optimizer.
pub fn consume<T>(x: T) -> T {
    bb(x)
}

/// Suite-level collector: prints each case's `BENCH\t...` line, records
/// it into the metrics facade (labeled `bench_*` gauges) and, on
/// [`finish`], writes the collected cases to `$BENCH_JSON_OUT` in the
/// `BENCH_*.json` array schema (`$BENCH_JSON_APPEND=1` merges into an
/// existing file so several suites can share one trajectory file).
///
/// [`finish`]: BenchSuite::finish
#[derive(Default)]
pub struct BenchSuite {
    cases: Vec<metrics::BenchCase>,
}

impl BenchSuite {
    /// Empty suite.
    pub fn new() -> Self {
        Self::default()
    }

    /// Print `r`'s plain report, record it, and collect it for export.
    pub fn push(&mut self, r: &BenchResult) {
        println!("{}", r.report());
        self.collect(r, None);
    }

    /// Print `r`'s throughput report (`per_iter` items per iteration,
    /// labeled `unit`), record it, and collect it for export.
    pub fn push_throughput(&mut self, r: &BenchResult, unit: &str, per_iter: f64) {
        println!("{}", r.report_throughput(unit, per_iter));
        let per_sec = per_iter / (r.mean_ns * 1e-9);
        self.collect(r, Some((per_sec, unit.to_string())));
    }

    fn collect(&mut self, r: &BenchResult, throughput: Option<(f64, String)>) {
        let case = metrics::BenchCase {
            name: r.name.clone(),
            iters: u64::from(r.iters),
            mean_ns: r.mean_ns,
            median_ns: r.median_ns,
            min_ns: r.min_ns,
            std_ns: r.std_ns,
            throughput,
        };
        metrics::record_bench(&case);
        self.cases.push(case);
    }

    /// Export the collected cases to `$BENCH_JSON_OUT` if set (no-op
    /// otherwise, so ad-hoc `cargo bench` runs stay file-free).
    pub fn finish(self) -> std::io::Result<()> {
        let Ok(path) = std::env::var("BENCH_JSON_OUT") else {
            return Ok(());
        };
        let append = std::env::var("BENCH_JSON_APPEND").map(|v| v == "1").unwrap_or(false);
        metrics::write_bench_json(&self.cases, std::path::Path::new(&path), append)?;
        println!("wrote {path} ({} cases)", self.cases.len());
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bench::quick();
        let mut acc = 0u64;
        let r = b.run("spin", || {
            for i in 0..1000u64 {
                acc = acc.wrapping_add(consume(i * i));
            }
        });
        assert!(r.iters >= 3);
        assert!(r.mean_ns > 0.0);
        assert!(r.min_ns <= r.median_ns);
    }

    #[test]
    fn std_uses_sample_denominator() {
        // n−1 denominator: var = (4 + 0 + 4) / 2 = 4 → std = 2
        let r = BenchResult::from_samples("s", vec![94.0, 90.0, 92.0]);
        assert!((r.std_ns - 2.0).abs() < 1e-12, "{}", r.std_ns);
        assert!((r.mean_ns - 92.0).abs() < 1e-12);
        assert_eq!(r.median_ns, 92.0);
        assert_eq!(r.min_ns, 90.0);
        assert_eq!(r.iters, 3);
        // ... and agrees with the stats toolbox by construction
        assert_eq!(r.std_ns, crate::util::stats::std(&[90.0, 92.0, 94.0]));
    }

    #[test]
    fn from_samples_survives_nan() {
        // must not panic; total_cmp sorts the NaN to the top so min
        // and median stay finite
        let r = BenchResult::from_samples("n", vec![2.0, f64::NAN, 1.0]);
        assert_eq!(r.min_ns, 1.0);
        assert_eq!(r.median_ns, 2.0);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(5.0).ends_with("ns"));
        assert!(fmt_ns(5e3).ends_with("us"));
        assert!(fmt_ns(5e6).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with('s'));
    }

    #[test]
    fn suite_collects_cases() {
        let mut s = BenchSuite::new();
        let r = BenchResult::from_samples("c/1", vec![10.0, 20.0, 30.0]);
        s.push(&r);
        s.push_throughput(&r, "ops", 100.0);
        assert_eq!(s.cases.len(), 2);
        assert!(s.cases[0].throughput.is_none());
        let t = s.cases[1].throughput.as_ref().expect("throughput case");
        assert_eq!(t.1, "ops");
        assert!(t.0 > 0.0);
    }

    #[test]
    fn throughput_positive() {
        let b = Bench::quick();
        let r = b.run("t", || {
            consume(1 + 1);
        });
        let s = r.report_throughput("ops", 100.0);
        assert!(s.contains("throughput="));
    }
}
