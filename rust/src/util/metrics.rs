//! Live metrics facade: dependency-free counters, gauges and
//! fixed-bucket histograms behind a pluggable [`Recorder`] trait.
//!
//! Call sites record through the free functions ([`counter`],
//! [`gauge`], [`histogram`]) using compile-time [`MetricId`] keys, so
//! an instrumented hot path costs a single relaxed atomic load while
//! no recorder is installed (the default — the library never installs
//! one; the `rider` binary and the bench suites opt in). [`install`]
//! activates the process-wide [`MemorySink`], whose aggregates feed
//! three exporters:
//!
//! * a JSON-lines snapshot trace ([`attach_trace`] / [`trace_sample`]),
//!   routed through `coordinator::metrics::RunDir` so experiment
//!   telemetry lands next to the tables under `runs/`;
//! * a plain-text Prometheus exposition dump ([`prometheus_text`],
//!   served by the `rider metrics` subcommand);
//! * the `BENCH_*.json` bench-trajectory files ([`write_bench_json`]),
//!   fed by the same labeled `bench_*` gauge series the bench binaries
//!   record via [`record_bench`].
//!
//! Every key is registered in [`SPECS`]; `METRICS.md` documents the
//! table and `rust/tests/doc_drift.rs` pins the two to each other.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::fs;
use std::fs::File;
use std::io::{BufWriter, Write as _};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

/// Metric kind: monotone counter, last-value gauge, or fixed-bucket
/// histogram.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Kind {
    /// Monotonically increasing `u64` total.
    Counter,
    /// Last-written `f64` value.
    Gauge,
    /// Fixed-bucket distribution of `f64` observations (buckets are
    /// [`SECONDS_BUCKETS`] for every histogram in the registry).
    Histogram,
}

/// Compile-time key for a registered metric.
///
/// The discriminant indexes [`SPECS`]; `registry_is_aligned` in this
/// module's tests pins the two orderings together, so recording is an
/// array index — no string hashing on the hot path (the "interning"
/// is done by the compiler).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum MetricId {
    /// Pulses charged on crossbar cells (`device/array.rs`).
    DevicePulsesTotal,
    /// Mean |SP − q| after a zero-shifting calibration (`analog/zs.rs`).
    DeviceSpDrift,
    /// Training loss of the latest step (`train/trainer.rs`).
    TrainLoss,
    /// NN-scale symmetric-point residual probe (`train/fault.rs`).
    SpResidual,
    /// Completed trainer steps.
    TrainStepsTotal,
    /// Wall-clock seconds per trainer step.
    TrainStepSeconds,
    /// Update pulses charged by trainer steps.
    TrainUpdatePulsesTotal,
    /// Pulses spent on ZS calibration (initial + selective re-runs).
    TrainCalibrationPulsesTotal,
    /// Plan compilations (executor cache misses).
    ExecutorCompilesTotal,
    /// Planned-engine executions.
    ExecutorRunsTotal,
    /// Reusable buffers allocated by freshly compiled plans.
    PlanBuffersTotal,
    /// Buffer-backed value slots in freshly compiled plans.
    PlanBufferSlotsTotal,
    /// Sweep jobs completed (including failed ones).
    SweepJobsTotal,
    /// Sweep jobs that panicked and were reported as failures.
    SweepJobFailuresTotal,
    /// Bench: measured iterations per case.
    BenchIters,
    /// Bench: mean wall-clock per iteration, nanoseconds.
    BenchMeanNs,
    /// Bench: median wall-clock per iteration, nanoseconds.
    BenchMedianNs,
    /// Bench: fastest iteration, nanoseconds (the regression-gated
    /// series in `BENCH_baseline/`).
    BenchMinNs,
    /// Bench: sample standard deviation, nanoseconds.
    BenchStdNs,
    /// Bench: throughput, items per second.
    BenchThroughputPerS,
    /// Pipeline: mean busy fraction across compute workers
    /// (`train/pipeline.rs`).
    PipelineStageOccupancy,
    /// Pipeline: seconds workers spent waiting for an input state
    /// version.
    PipelineStallSeconds,
    /// Pipeline: microbatches claimed but not yet committed.
    PipelineInflight,
}

impl MetricId {
    /// Every registered metric in registry (documentation) order.
    pub const ALL: &'static [MetricId] = &[
        MetricId::DevicePulsesTotal,
        MetricId::DeviceSpDrift,
        MetricId::TrainLoss,
        MetricId::SpResidual,
        MetricId::TrainStepsTotal,
        MetricId::TrainStepSeconds,
        MetricId::TrainUpdatePulsesTotal,
        MetricId::TrainCalibrationPulsesTotal,
        MetricId::ExecutorCompilesTotal,
        MetricId::ExecutorRunsTotal,
        MetricId::PlanBuffersTotal,
        MetricId::PlanBufferSlotsTotal,
        MetricId::SweepJobsTotal,
        MetricId::SweepJobFailuresTotal,
        MetricId::BenchIters,
        MetricId::BenchMeanNs,
        MetricId::BenchMedianNs,
        MetricId::BenchMinNs,
        MetricId::BenchStdNs,
        MetricId::BenchThroughputPerS,
        MetricId::PipelineStageOccupancy,
        MetricId::PipelineStallSeconds,
        MetricId::PipelineInflight,
    ];
}

/// Registry entry describing one metric key — the canonical source of
/// the `METRICS.md` reference table.
pub struct KeySpec {
    /// Exported key name (JSONL `key` field / Prometheus family name).
    pub name: &'static str,
    /// Aggregation kind.
    pub kind: Kind,
    /// Unit of the recorded value (`"1"` for dimensionless counts).
    pub unit: &'static str,
    /// Label dimension (`"-"` for unlabeled series).
    pub labels: &'static str,
    /// Module that records the key.
    pub module: &'static str,
    /// One-line description (the Prometheus `# HELP` text).
    pub help: &'static str,
}

/// Canonical key registry, indexed by `MetricId as usize`. `METRICS.md`
/// mirrors this table and `rust/tests/doc_drift.rs` fails on drift.
pub const SPECS: &[KeySpec] = &[
    KeySpec {
        name: "device_pulses_total",
        kind: Kind::Counter,
        unit: "pulses",
        labels: "-",
        module: "device/array.rs",
        help: "Pulses charged on crossbar cells across all update paths",
    },
    KeySpec {
        name: "device_sp_drift",
        kind: Kind::Gauge,
        unit: "norm. conductance",
        labels: "-",
        module: "analog/zs.rs",
        help: "Mean abs(SP - q) over the array after the latest ZS calibration",
    },
    KeySpec {
        name: "train_loss",
        kind: Kind::Gauge,
        unit: "loss",
        labels: "-",
        module: "train/trainer.rs",
        help: "Training loss of the latest completed step",
    },
    KeySpec {
        name: "sp_residual",
        kind: Kind::Gauge,
        unit: "norm. conductance",
        labels: "-",
        module: "train/trainer.rs",
        help: "NN-scale symmetric-point residual (train/fault.rs probe)",
    },
    KeySpec {
        name: "train_steps_total",
        kind: Kind::Counter,
        unit: "1",
        labels: "-",
        module: "train/trainer.rs",
        help: "Completed trainer steps",
    },
    KeySpec {
        name: "train_step_seconds",
        kind: Kind::Histogram,
        unit: "seconds",
        labels: "-",
        module: "train/trainer.rs",
        help: "Wall-clock seconds per trainer step",
    },
    KeySpec {
        name: "train_update_pulses_total",
        kind: Kind::Counter,
        unit: "pulses",
        labels: "-",
        module: "train/trainer.rs",
        help: "Update pulses charged by trainer steps (BL per weight)",
    },
    KeySpec {
        name: "train_calibration_pulses_total",
        kind: Kind::Counter,
        unit: "pulses",
        labels: "-",
        module: "train/trainer.rs",
        help: "Pulses spent on ZS calibration (initial and selective re-runs)",
    },
    KeySpec {
        name: "executor_compiles_total",
        kind: Kind::Counter,
        unit: "1",
        labels: "-",
        module: "runtime/executor.rs",
        help: "Plan compilations (executor cache misses)",
    },
    KeySpec {
        name: "executor_runs_total",
        kind: Kind::Counter,
        unit: "1",
        labels: "-",
        module: "runtime/executor.rs",
        help: "Planned-engine executions dispatched by the executor",
    },
    KeySpec {
        name: "plan_buffers_total",
        kind: Kind::Counter,
        unit: "1",
        labels: "-",
        module: "runtime/executor.rs",
        help: "Reusable buffers allocated by freshly compiled plans",
    },
    KeySpec {
        name: "plan_buffer_slots_total",
        kind: Kind::Counter,
        unit: "1",
        labels: "-",
        module: "runtime/executor.rs",
        help: "Buffer-backed value slots in freshly compiled plans",
    },
    KeySpec {
        name: "sweep_jobs_total",
        kind: Kind::Counter,
        unit: "1",
        labels: "-",
        module: "coordinator/sweep.rs",
        help: "Sweep jobs completed, including failed ones",
    },
    KeySpec {
        name: "sweep_job_failures_total",
        kind: Kind::Counter,
        unit: "1",
        labels: "-",
        module: "coordinator/sweep.rs",
        help: "Sweep jobs that panicked and were reported as failures",
    },
    KeySpec {
        name: "bench_iters",
        kind: Kind::Gauge,
        unit: "1",
        labels: "case",
        module: "util/bench.rs",
        help: "Bench: measured iterations per case",
    },
    KeySpec {
        name: "bench_mean_ns",
        kind: Kind::Gauge,
        unit: "ns",
        labels: "case",
        module: "util/bench.rs",
        help: "Bench: mean wall-clock per iteration",
    },
    KeySpec {
        name: "bench_median_ns",
        kind: Kind::Gauge,
        unit: "ns",
        labels: "case",
        module: "util/bench.rs",
        help: "Bench: median wall-clock per iteration",
    },
    KeySpec {
        name: "bench_min_ns",
        kind: Kind::Gauge,
        unit: "ns",
        labels: "case",
        module: "util/bench.rs",
        help: "Bench: fastest iteration (the regression-gated series)",
    },
    KeySpec {
        name: "bench_std_ns",
        kind: Kind::Gauge,
        unit: "ns",
        labels: "case",
        module: "util/bench.rs",
        help: "Bench: sample standard deviation",
    },
    KeySpec {
        name: "bench_throughput_per_s",
        kind: Kind::Gauge,
        unit: "items/s",
        labels: "case",
        module: "util/bench.rs",
        help: "Bench: throughput in case-specific items per second",
    },
    KeySpec {
        name: "pipeline_stage_occupancy",
        kind: Kind::Gauge,
        unit: "fraction",
        labels: "-",
        module: "train/pipeline.rs",
        help: "Mean busy fraction across pipeline compute workers in the last run",
    },
    KeySpec {
        name: "pipeline_stall_seconds",
        kind: Kind::Histogram,
        unit: "seconds",
        labels: "-",
        module: "train/pipeline.rs",
        help: "Seconds pipeline workers spent waiting for their input state version",
    },
    KeySpec {
        name: "pipeline_inflight",
        kind: Kind::Gauge,
        unit: "1",
        labels: "-",
        module: "train/pipeline.rs",
        help: "Microbatches claimed but not yet committed, sampled at each commit",
    },
];

/// Bucket upper bounds (seconds) shared by every histogram in the
/// registry; the implicit `+Inf` bucket is appended on export.
pub const SECONDS_BUCKETS: &[f64] = &[1e-4, 1e-3, 1e-2, 1e-1, 1.0, 10.0];

/// Keys the `./ci.sh metrics` smoke stage requires in every JSONL run
/// trace (the trainer-level series every NN-scale experiment emits).
pub const REQUIRED_TRACE_KEYS: &[&str] =
    &["train_loss", "train_update_pulses_total", "sp_residual"];

/// A metrics sink: receives every recorded sample.
///
/// Implementations must be thread-safe — recording happens from the
/// scoped-thread fan-outs in `device/`, `coordinator/sweep.rs` and the
/// planned-engine row pools without external synchronization.
pub trait Recorder: Sync {
    /// Add `delta` to a monotone counter.
    fn counter(&self, id: MetricId, delta: u64);
    /// Set a gauge to `value` (last write wins).
    fn gauge(&self, id: MetricId, value: f64);
    /// Observe `value` into a fixed-bucket histogram.
    fn histogram(&self, id: MetricId, value: f64);
    /// Set the `label`-tagged series of a labeled gauge to `value`.
    fn gauge_labeled(&self, id: MetricId, label: &str, value: f64);
}

/// The do-nothing sink: what every call site effectively sees until
/// [`install`] runs (the facade short-circuits on a disabled flag, so
/// this type exists for tests and explicit composition).
pub struct NoopRecorder;

impl Recorder for NoopRecorder {
    fn counter(&self, _id: MetricId, _delta: u64) {}
    fn gauge(&self, _id: MetricId, _value: f64) {}
    fn histogram(&self, _id: MetricId, _value: f64) {}
    fn gauge_labeled(&self, _id: MetricId, _label: &str, _value: f64) {}
}

/// Gauge-slot sentinel: a quiet-NaN bit pattern meaning "never set".
const UNSET_BITS: u64 = 0x7ff8_dead_beef_0000;

/// In-memory aggregating sink: lock-free atomics for the unlabeled
/// series (pre-allocated per [`SPECS`] entry, so recording never
/// touches the heap), a mutex-guarded map for the cold labeled
/// `bench_*` series.
pub struct MemorySink {
    counters: Vec<AtomicU64>,
    /// f64 bit patterns; `UNSET_BITS` marks a never-written gauge.
    gauges: Vec<AtomicU64>,
    /// Per-metric bucket counts (`SECONDS_BUCKETS.len() + 1` slots,
    /// the last being `+Inf`); empty for non-histogram entries.
    hist_counts: Vec<Vec<AtomicU64>>,
    /// f64 bit patterns updated by compare-exchange.
    hist_sums: Vec<AtomicU64>,
    hist_totals: Vec<AtomicU64>,
    labeled: Mutex<BTreeMap<(usize, String), f64>>,
}

impl Default for MemorySink {
    fn default() -> Self {
        Self::new()
    }
}

impl MemorySink {
    /// Build a sink with one pre-allocated slot per registry entry.
    pub fn new() -> Self {
        let n = SPECS.len();
        MemorySink {
            counters: (0..n).map(|_| AtomicU64::new(0)).collect(),
            gauges: (0..n).map(|_| AtomicU64::new(UNSET_BITS)).collect(),
            hist_counts: SPECS
                .iter()
                .map(|s| {
                    let slots = if s.kind == Kind::Histogram {
                        SECONDS_BUCKETS.len() + 1
                    } else {
                        0
                    };
                    (0..slots).map(|_| AtomicU64::new(0)).collect()
                })
                .collect(),
            hist_sums: (0..n).map(|_| AtomicU64::new(0f64.to_bits())).collect(),
            hist_totals: (0..n).map(|_| AtomicU64::new(0)).collect(),
            labeled: Mutex::new(BTreeMap::new()),
        }
    }

    fn labeled_guard(&self) -> std::sync::MutexGuard<'_, BTreeMap<(usize, String), f64>> {
        self.labeled.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Current value of a counter.
    pub fn counter_value(&self, id: MetricId) -> u64 {
        self.counters[id as usize].load(Ordering::Relaxed)
    }

    /// Current value of an unlabeled gauge, `None` if never written.
    pub fn gauge_value(&self, id: MetricId) -> Option<f64> {
        let bits = self.gauges[id as usize].load(Ordering::Relaxed);
        if bits == UNSET_BITS {
            None
        } else {
            Some(f64::from_bits(bits))
        }
    }

    /// `(count, sum)` of a histogram.
    pub fn histogram_totals(&self, id: MetricId) -> (u64, f64) {
        let i = id as usize;
        (
            self.hist_totals[i].load(Ordering::Relaxed),
            f64::from_bits(self.hist_sums[i].load(Ordering::Relaxed)),
        )
    }

    /// Render the sink as Prometheus exposition text: `# HELP`/`# TYPE`
    /// headers per family, cumulative `_bucket{le=...}` lines plus
    /// `_sum`/`_count` for histograms, `name{case="..."}` samples for
    /// the labeled bench series. Counters always appear; gauges and
    /// labeled series only once written.
    pub fn prometheus_text(&self) -> String {
        let mut out = String::new();
        for &id in MetricId::ALL {
            let i = id as usize;
            let spec = &SPECS[i];
            match spec.kind {
                Kind::Counter => {
                    let _ = writeln!(out, "# HELP {} {}", spec.name, spec.help);
                    let _ = writeln!(out, "# TYPE {} counter", spec.name);
                    let v = self.counters[i].load(Ordering::Relaxed);
                    let _ = writeln!(out, "{} {}", spec.name, v);
                }
                Kind::Gauge if spec.labels == "-" => {
                    let Some(v) = self.gauge_value(id) else {
                        continue;
                    };
                    if !v.is_finite() {
                        continue;
                    }
                    let _ = writeln!(out, "# HELP {} {}", spec.name, spec.help);
                    let _ = writeln!(out, "# TYPE {} gauge", spec.name);
                    let _ = writeln!(out, "{} {}", spec.name, v);
                }
                Kind::Gauge => {
                    let map = self.labeled_guard();
                    let series: Vec<(String, f64)> = map
                        .iter()
                        .filter(|((k, _), _)| *k == i)
                        .map(|((_, label), v)| (label.clone(), *v))
                        .collect();
                    drop(map);
                    if series.is_empty() {
                        continue;
                    }
                    let _ = writeln!(out, "# HELP {} {}", spec.name, spec.help);
                    let _ = writeln!(out, "# TYPE {} gauge", spec.name);
                    for (label, v) in series {
                        let _ = writeln!(
                            out,
                            "{}{{{}=\"{}\"}} {}",
                            spec.name,
                            spec.labels,
                            escape_label(&label),
                            v
                        );
                    }
                }
                Kind::Histogram => {
                    let _ = writeln!(out, "# HELP {} {}", spec.name, spec.help);
                    let _ = writeln!(out, "# TYPE {} histogram", spec.name);
                    let mut cum = 0u64;
                    for (bi, b) in SECONDS_BUCKETS.iter().enumerate() {
                        cum += self.hist_counts[i][bi].load(Ordering::Relaxed);
                        let _ = writeln!(out, "{}_bucket{{le=\"{}\"}} {}", spec.name, b, cum);
                    }
                    cum += self.hist_counts[i][SECONDS_BUCKETS.len()].load(Ordering::Relaxed);
                    let _ = writeln!(out, "{}_bucket{{le=\"+Inf\"}} {}", spec.name, cum);
                    let (n, s) = self.histogram_totals(id);
                    let _ = writeln!(out, "{}_sum {}", spec.name, s);
                    let _ = writeln!(out, "{}_count {}", spec.name, n);
                }
            }
        }
        out
    }

    /// Append one JSONL snapshot line per populated unlabeled metric to
    /// `out`, stamped with `step`. Counters always appear (a zero total
    /// is data); gauges once written and finite; histograms once they
    /// hold at least one observation. Labeled bench series stay out of
    /// run traces.
    pub fn trace_lines(&self, step: u64, out: &mut String) {
        for &id in MetricId::ALL {
            let i = id as usize;
            let spec = &SPECS[i];
            match spec.kind {
                Kind::Counter => {
                    let v = self.counters[i].load(Ordering::Relaxed);
                    let _ = writeln!(
                        out,
                        "{{\"step\":{step},\"key\":\"{}\",\"type\":\"counter\",\"value\":{v}}}",
                        spec.name
                    );
                }
                Kind::Gauge => {
                    if spec.labels != "-" {
                        continue;
                    }
                    let Some(v) = self.gauge_value(id) else {
                        continue;
                    };
                    if !v.is_finite() {
                        continue;
                    }
                    let _ = writeln!(
                        out,
                        "{{\"step\":{step},\"key\":\"{}\",\"type\":\"gauge\",\"value\":{v}}}",
                        spec.name
                    );
                }
                Kind::Histogram => {
                    let (n, s) = self.histogram_totals(id);
                    if n == 0 {
                        continue;
                    }
                    let _ = writeln!(
                        out,
                        "{{\"step\":{step},\"key\":\"{}\",\"type\":\"histogram\",\
                         \"count\":{n},\"sum\":{s}}}",
                        spec.name
                    );
                }
            }
        }
    }
}

impl Recorder for MemorySink {
    fn counter(&self, id: MetricId, delta: u64) {
        self.counters[id as usize].fetch_add(delta, Ordering::Relaxed);
    }

    fn gauge(&self, id: MetricId, value: f64) {
        self.gauges[id as usize].store(value.to_bits(), Ordering::Relaxed);
    }

    fn histogram(&self, id: MetricId, value: f64) {
        let i = id as usize;
        let counts = &self.hist_counts[i];
        if counts.is_empty() {
            return; // not registered as a histogram
        }
        let bi = SECONDS_BUCKETS
            .iter()
            .position(|b| value <= *b)
            .unwrap_or(SECONDS_BUCKETS.len());
        counts[bi].fetch_add(1, Ordering::Relaxed);
        let cell = &self.hist_sums[i];
        let mut cur = cell.load(Ordering::Relaxed);
        loop {
            let new = (f64::from_bits(cur) + value).to_bits();
            match cell.compare_exchange_weak(cur, new, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
        self.hist_totals[i].fetch_add(1, Ordering::Relaxed);
    }

    fn gauge_labeled(&self, id: MetricId, label: &str, value: f64) {
        let mut map = self.labeled_guard();
        map.insert((id as usize, label.to_string()), value);
    }
}

fn escape_label(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

// ---------------------------------------------------------------------
// Global facade
// ---------------------------------------------------------------------

static ENABLED: AtomicBool = AtomicBool::new(false);
static SINK: OnceLock<MemorySink> = OnceLock::new();
static TRACE_ON: AtomicBool = AtomicBool::new(false);
static TRACE: Mutex<Option<BufWriter<File>>> = Mutex::new(None);

/// `true` once [`install`] has activated the global sink. Call sites
/// can use this to skip *computing* an expensive value (the recording
/// functions already self-guard).
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Install the process-wide [`MemorySink`] and enable recording.
///
/// One-way and idempotent: the first call wins, later calls are
/// no-ops. The library never calls this — binaries opt in at startup.
pub fn install() {
    let _ = SINK.get_or_init(MemorySink::new);
    ENABLED.store(true, Ordering::Relaxed);
}

/// Add `delta` to the counter `id`. One relaxed load when disabled.
#[inline]
pub fn counter(id: MetricId, delta: u64) {
    if !ENABLED.load(Ordering::Relaxed) {
        return;
    }
    if let Some(s) = SINK.get() {
        s.counter(id, delta);
    }
}

/// Set the gauge `id` to `value`. One relaxed load when disabled.
#[inline]
pub fn gauge(id: MetricId, value: f64) {
    if !ENABLED.load(Ordering::Relaxed) {
        return;
    }
    if let Some(s) = SINK.get() {
        s.gauge(id, value);
    }
}

/// Observe `value` into the histogram `id`. One relaxed load when
/// disabled.
#[inline]
pub fn histogram(id: MetricId, value: f64) {
    if !ENABLED.load(Ordering::Relaxed) {
        return;
    }
    if let Some(s) = SINK.get() {
        s.histogram(id, value);
    }
}

/// Set the `label`-tagged series of labeled gauge `id` to `value`.
/// Takes a mutex — cold paths only (the bench suites).
#[inline]
pub fn gauge_labeled(id: MetricId, label: &str, value: f64) {
    if !ENABLED.load(Ordering::Relaxed) {
        return;
    }
    if let Some(s) = SINK.get() {
        s.gauge_labeled(id, label, value);
    }
}

/// Render the global sink as Prometheus exposition text (empty string
/// if no recorder is installed).
pub fn prometheus_text() -> String {
    match SINK.get() {
        Some(s) => s.prometheus_text(),
        None => String::new(),
    }
}

fn trace_guard() -> std::sync::MutexGuard<'static, Option<BufWriter<File>>> {
    TRACE.lock().unwrap_or_else(|e| e.into_inner())
}

/// Attach the JSONL snapshot trace writer to `path` (truncating).
/// Subsequent [`trace_sample`] calls append one snapshot per call.
pub fn attach_trace(path: &Path) -> std::io::Result<()> {
    let f = File::create(path)?;
    let mut g = trace_guard();
    *g = Some(BufWriter::new(f));
    drop(g);
    TRACE_ON.store(true, Ordering::Relaxed);
    Ok(())
}

/// Flush and detach the JSONL trace writer. Safe to call when no
/// trace is attached.
pub fn detach_trace() {
    TRACE_ON.store(false, Ordering::Relaxed);
    let mut g = trace_guard();
    if let Some(mut w) = g.take() {
        let _ = w.flush();
    }
}

/// Append a snapshot of every populated metric to the attached trace,
/// stamped with `step`. One relaxed load when no trace is attached,
/// so per-step call sites cost nothing outside traced runs. Lines
/// from concurrent callers never interleave (one buffered write per
/// call under the writer lock).
pub fn trace_sample(step: u64) {
    if !TRACE_ON.load(Ordering::Relaxed) || !ENABLED.load(Ordering::Relaxed) {
        return;
    }
    let Some(sink) = SINK.get() else {
        return;
    };
    let mut buf = String::new();
    sink.trace_lines(step, &mut buf);
    let mut g = trace_guard();
    if let Some(w) = g.as_mut() {
        let _ = w.write_all(buf.as_bytes());
    }
}

// ---------------------------------------------------------------------
// Bench exporter
// ---------------------------------------------------------------------

/// One measured bench case, as recorded into the labeled `bench_*`
/// series and exported to the `BENCH_*.json` trajectory files.
#[derive(Clone, Debug)]
pub struct BenchCase {
    /// Case name, e.g. `analog_update/256x256`.
    pub name: String,
    /// Measured iterations.
    pub iters: u64,
    /// Mean wall-clock per iteration, nanoseconds.
    pub mean_ns: f64,
    /// Median wall-clock per iteration, nanoseconds.
    pub median_ns: f64,
    /// Fastest iteration, nanoseconds (the regression-gated series).
    pub min_ns: f64,
    /// Sample standard deviation (n−1 denominator), nanoseconds.
    pub std_ns: f64,
    /// Optional throughput: (items per second, item unit).
    pub throughput: Option<(f64, String)>,
}

/// Record `case` into the labeled `bench_*` gauge series.
pub fn record_bench(case: &BenchCase) {
    gauge_labeled(MetricId::BenchIters, &case.name, case.iters as f64);
    gauge_labeled(MetricId::BenchMeanNs, &case.name, case.mean_ns);
    gauge_labeled(MetricId::BenchMedianNs, &case.name, case.median_ns);
    gauge_labeled(MetricId::BenchMinNs, &case.name, case.min_ns);
    gauge_labeled(MetricId::BenchStdNs, &case.name, case.std_ns);
    if let Some((per_s, _)) = &case.throughput {
        gauge_labeled(MetricId::BenchThroughputPerS, &case.name, *per_s);
    }
}

/// Write `cases` as a `BENCH_*.json` array — the `./ci.sh bench`
/// trajectory schema (one object per line; `min_ns` is gated against
/// `BENCH_baseline/` by `./ci.sh bench --check`). With `append`, the
/// cases are merged into an existing array written by an earlier
/// suite in the same run.
pub fn write_bench_json(cases: &[BenchCase], path: &Path, append: bool) -> std::io::Result<()> {
    let mut body = String::new();
    for (n, c) in cases.iter().enumerate() {
        if n > 0 {
            body.push_str(",\n");
        }
        let _ = write!(
            body,
            "  {{\"name\":\"{}\",\"iters\":{},\"mean_ns\":{:.1},\"median_ns\":{:.1},\
             \"min_ns\":{:.1},\"std_ns\":{:.1}",
            c.name, c.iters, c.mean_ns, c.median_ns, c.min_ns, c.std_ns
        );
        if let Some((per_s, unit)) = &c.throughput {
            let _ = write!(
                body,
                ",\"throughput_per_s\":{per_s:.4e},\"throughput_unit\":\"{unit}\""
            );
        }
        body.push('}');
    }
    let text = if append && path.exists() {
        let prev = fs::read_to_string(path)?;
        if cases.is_empty() {
            prev
        } else {
            let head = prev
                .trim_end()
                .strip_suffix(']')
                .ok_or_else(|| {
                    std::io::Error::new(
                        std::io::ErrorKind::InvalidData,
                        "existing bench json is not an array",
                    )
                })?
                .trim_end()
                .to_string();
            if head.ends_with('[') {
                format!("{head}\n{body}\n]\n")
            } else {
                format!("{head},\n{body}\n]\n")
            }
        }
    } else if cases.is_empty() {
        "[]\n".to_string()
    } else {
        format!("[\n{body}\n]\n")
    };
    fs::write(path, text)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_is_aligned() {
        assert_eq!(MetricId::ALL.len(), SPECS.len());
        for (k, &id) in MetricId::ALL.iter().enumerate() {
            assert_eq!(id as usize, k, "{id:?} out of registry order");
        }
        for a in 0..SPECS.len() {
            for b in a + 1..SPECS.len() {
                assert_ne!(SPECS[a].name, SPECS[b].name, "duplicate key name");
            }
        }
    }

    #[test]
    fn required_trace_keys_are_registered_and_unlabeled() {
        for key in REQUIRED_TRACE_KEYS {
            let spec = SPECS.iter().find(|s| s.name == *key);
            let spec = spec.unwrap_or_else(|| panic!("{key} not in SPECS"));
            assert_eq!(spec.labels, "-", "{key} must be an unlabeled series");
        }
    }

    #[test]
    fn buckets_are_sorted() {
        for w in SECONDS_BUCKETS.windows(2) {
            assert!(w[0] < w[1]);
        }
    }

    #[test]
    fn sink_aggregates_and_renders_prometheus() {
        let s = MemorySink::new();
        s.counter(MetricId::DevicePulsesTotal, 3);
        s.counter(MetricId::DevicePulsesTotal, 4);
        s.gauge(MetricId::TrainLoss, 0.5);
        s.gauge(MetricId::TrainLoss, 0.25);
        s.histogram(MetricId::TrainStepSeconds, 5e-4);
        s.histogram(MetricId::TrainStepSeconds, 20.0);
        s.gauge_labeled(MetricId::BenchMinNs, "analog_update/128x128", 125.0);
        assert_eq!(s.counter_value(MetricId::DevicePulsesTotal), 7);
        assert_eq!(s.gauge_value(MetricId::TrainLoss), Some(0.25));
        assert_eq!(s.gauge_value(MetricId::SpResidual), None);
        let text = s.prometheus_text();
        assert!(text.contains("# TYPE device_pulses_total counter"));
        assert!(text.contains("device_pulses_total 7"));
        assert!(text.contains("train_loss 0.25"));
        assert!(!text.contains("sp_residual"), "unset gauge must not render");
        assert!(text.contains("train_step_seconds_bucket{le=\"0.0001\"} 0"));
        assert!(text.contains("train_step_seconds_bucket{le=\"0.001\"} 1"));
        assert!(text.contains("train_step_seconds_bucket{le=\"+Inf\"} 2"));
        assert!(text.contains("train_step_seconds_count 2"));
        assert!(text.contains("bench_min_ns{case=\"analog_update/128x128\"} 125"));
    }

    #[test]
    fn trace_lines_parse_as_json_and_cover_required_keys() {
        let s = MemorySink::new();
        s.counter(MetricId::TrainUpdatePulsesTotal, 320);
        s.gauge(MetricId::TrainLoss, 0.5);
        s.gauge(MetricId::SpResidual, 0.0125);
        s.histogram(MetricId::TrainStepSeconds, 0.25);
        s.gauge_labeled(MetricId::BenchMinNs, "never/in-trace", 1.0);
        let mut out = String::new();
        s.trace_lines(7, &mut out);
        let mut keys = Vec::new();
        for line in out.lines() {
            let j = crate::util::json::Json::parse(line).expect("trace line parses");
            let key = j.get("key").and_then(|k| k.as_str()).expect("key field");
            keys.push(key.to_string());
            assert_eq!(j.get("step").and_then(|v| v.as_f64()), Some(7.0));
        }
        for key in REQUIRED_TRACE_KEYS {
            assert!(keys.iter().any(|k| k == key), "{key} missing from trace");
        }
        assert!(!keys.iter().any(|k| k.starts_with("bench_")));
    }

    #[test]
    fn histogram_on_non_histogram_id_is_ignored() {
        let s = MemorySink::new();
        s.histogram(MetricId::TrainLoss, 1.0);
        assert_eq!(s.histogram_totals(MetricId::TrainLoss), (0, 0.0));
    }

    #[test]
    fn bench_json_roundtrip_and_append() {
        let path = std::env::temp_dir()
            .join(format!("rider_bench_{}.json", std::process::id()));
        let a = BenchCase {
            name: "a/1".into(),
            iters: 10,
            mean_ns: 1.25,
            median_ns: 1.0,
            min_ns: 0.7,
            std_ns: 0.5,
            throughput: Some((1.5e6, "cells".into())),
        };
        let b = BenchCase {
            name: "b/2".into(),
            iters: 3,
            mean_ns: 9.0,
            median_ns: 9.0,
            min_ns: 8.0,
            std_ns: 0.1,
            throughput: None,
        };
        write_bench_json(&[a], &path, false).expect("write");
        write_bench_json(&[b], &path, true).expect("append");
        let text = fs::read_to_string(&path).expect("read back");
        let _ = fs::remove_file(&path);
        assert!(text.contains("\"name\":\"a/1\""));
        assert!(text.contains("\"min_ns\":0.7"));
        assert!(text.contains("\"throughput_per_s\":1.5000e6"));
        assert!(text.contains("\"name\":\"b/2\""));
        assert!(!text.contains("\"throughput_unit\":\"\""));
        let opens = text.matches('{').count();
        let closes = text.matches('}').count();
        assert_eq!(opens, 2);
        assert_eq!(closes, 2);
        assert!(text.trim_end().ends_with(']'));
        assert!(text.starts_with('['));
    }

    #[test]
    fn noop_recorder_is_callable() {
        let r = NoopRecorder;
        r.counter(MetricId::DevicePulsesTotal, 1);
        r.gauge(MetricId::TrainLoss, 1.0);
        r.histogram(MetricId::TrainStepSeconds, 1.0);
        r.gauge_labeled(MetricId::BenchIters, "x", 1.0);
    }
}
