//! Tiki-Taka v1/v2 (Gokmen & Haensch 2020; Gokmen 2021): the zero-SP
//! two-array baselines of Tables 1–2. A fast array A integrates the
//! gradient; its (reference-subtracted) read-out is transferred into the
//! slow array W — directly in v1, through a thresholded digital buffer in
//! v2. Both assume the reference `q` equals the A-device SP; the paper's
//! point is that a nonzero/unknown SP breaks that assumption.

use crate::analog::optimizer::AnalogOptimizer;
use crate::analog::pulse_counter::PulseCost;
use crate::device::{DeviceArray, Preset};
use crate::optim::Objective;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TtVariant {
    V1,
    V2,
}

#[derive(Clone, Copy, Debug)]
pub struct TtHypers {
    pub variant: TtVariant,
    /// A-array learning rate
    pub lr_fast: f64,
    /// A → W transfer learning rate
    pub lr_transfer: f64,
    /// analog read-out noise std
    pub read_noise: f64,
    /// mixing weight γ_tt of the fast array in the forward pass: the
    /// logical weight is W_eff = W + γ_tt (A − q) (AIHWKit transfer
    /// compound)
    pub gamma: f64,
}

impl Default for TtHypers {
    fn default() -> Self {
        Self {
            variant: TtVariant::V2,
            lr_fast: 0.1,
            lr_transfer: 0.05,
            read_noise: 0.01,
            gamma: 1.0,
        }
    }
}

pub struct TikiTaka {
    pub a: DeviceArray,
    pub w: DeviceArray,
    /// digital accumulation buffer (v2)
    pub h: Vec<f32>,
    /// assumed reference (SP estimate; zero unless calibrated)
    pub q: Vec<f32>,
    pub hypers: TtHypers,
    /// v2 transfer threshold, derived from the preset granularity
    pub thresh: f64,
    pub sigma: f64,
    grad_buf: Vec<f32>,
    dw_buf: Vec<f32>,
    weff_buf: Vec<f32>,
    read_buf: Vec<f32>,
}

impl TikiTaka {
    pub fn new(
        dim: usize,
        preset: &Preset,
        ref_mean: f64,
        ref_std: f64,
        hypers: TtHypers,
        sigma: f64,
        rng: &mut Rng,
    ) -> Self {
        let a = DeviceArray::sample(1, dim, preset, ref_mean, ref_std, 0.1, rng);
        let w = DeviceArray::sample(1, dim, preset, ref_mean, ref_std, 0.1, rng);
        Self {
            a,
            w,
            h: vec![0.0; dim],
            q: vec![0.0; dim],
            hypers,
            thresh: preset.dw_min.max(1e-3),
            sigma,
            grad_buf: vec![0.0; dim],
            dw_buf: vec![0.0; dim],
            weff_buf: vec![0.0; dim],
            read_buf: vec![0.0; dim],
        }
    }

    /// Logical (effective) weights W + γ_tt (A − q).
    pub fn w_eff(&mut self) -> &[f32] {
        let g = self.hypers.gamma as f32;
        for i in 0..self.weff_buf.len() {
            self.weff_buf[i] = self.w.w[i] + g * (self.a.w[i] - self.q[i]);
        }
        &self.weff_buf
    }
}

impl AnalogOptimizer for TikiTaka {
    fn step(&mut self, obj: &dyn Objective, rng: &mut Rng) -> f64 {
        // gradient at the effective (combined) weight: the A-array is part
        // of the logical weight, which is what damps the A->W transfer
        // loop (proportional + integral control).
        let h = self.hypers;
        self.w_eff();
        let loss = obj.loss(&self.weff_buf);
        obj.noisy_grad(&self.weff_buf, self.sigma, rng, &mut self.grad_buf);
        // A <- AnalogUpdate(A, -lr_fast * g)
        for (d, g) in self.dw_buf.iter_mut().zip(&self.grad_buf) {
            *d = (-h.lr_fast * *g as f64) as f32;
        }
        self.a.analog_update(&self.dw_buf, rng);
        // reference-corrected read (into the scratch buffer — no alloc)
        self.a.read_into(h.read_noise, rng, &mut self.read_buf);
        match h.variant {
            TtVariant::V1 => {
                for i in 0..self.read_buf.len() {
                    self.dw_buf[i] = (h.lr_transfer * (self.read_buf[i] - self.q[i]) as f64) as f32;
                }
                self.w.analog_update(&self.dw_buf, rng);
            }
            TtVariant::V2 => {
                let t = self.thresh as f32;
                for i in 0..self.read_buf.len() {
                    self.h[i] += self.read_buf[i] - self.q[i];
                    let quanta = (self.h[i] / t).trunc();
                    self.dw_buf[i] = (h.lr_transfer * (quanta * t) as f64) as f32;
                    self.h[i] -= quanta * t;
                }
                self.w.analog_update(&self.dw_buf, rng);
            }
        }
        loss
    }

    fn weights(&mut self) -> &[f32] {
        self.w_eff()
    }

    /// Calibrate the reference to an SP estimate (two-stage pipelines).
    fn set_reference(&mut self, q: Vec<f32>) {
        assert_eq!(q.len(), self.q.len());
        self.q = q;
    }

    fn sp_reference(&self) -> &[f32] {
        &self.q
    }

    fn cost(&self) -> PulseCost {
        PulseCost {
            update_pulses: self.a.pulse_count + self.w.pulse_count,
            digital_ops: if self.hypers.variant == TtVariant::V2 {
                self.h.len() as u64
            } else {
                0
            },
            ..Default::default()
        }
    }

    fn name(&self) -> &'static str {
        match self.hypers.variant {
            TtVariant::V1 => "ttv1",
            TtVariant::V2 => "ttv2",
        }
    }

    /// Chaos-layer seam: stream 0 faults the fast array A, stream 1
    /// the slow array W.
    fn arm_faults(&mut self, plan: &crate::device::fault::FaultPlan) {
        plan.arm_array(&mut self.a, 0);
        plan.arm_array(&mut self.w, 1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::presets;
    use crate::optim::Quadratic;
    use crate::util::stats;

    fn hypers(variant: TtVariant) -> TtHypers {
        TtHypers {
            variant,
            ..TtHypers::default()
        }
    }

    fn run(variant: TtVariant, ref_mean: f64, steps: usize, seed: u64) -> (f64, f64) {
        let mut rng = Rng::from_seed(seed);
        let obj = Quadratic::new(16, 1.0, 4.0, 0.3, &mut rng);
        let mut opt = TikiTaka::new(
            16,
            &presets::preset("om").unwrap(),
            ref_mean,
            0.1,
            hypers(variant),
            0.1,
            &mut rng,
        );
        let mut losses = Vec::new();
        for _ in 0..steps {
            losses.push(opt.step(&obj, &mut rng));
        }
        (
            losses[0],
            stats::mean(&losses[losses.len() - 50..]),
        )
    }

    #[test]
    fn v1_converges_zero_sp() {
        let (init, tail) = run(TtVariant::V1, 0.0, 1500, 1);
        assert!(tail < 0.35 * init, "init {init} tail {tail}");
    }

    #[test]
    fn v2_converges_zero_sp() {
        let (init, tail) = run(TtVariant::V2, 0.0, 1500, 2);
        assert!(tail < 0.35 * init, "init {init} tail {tail}");
    }

    #[test]
    fn v2_buffer_keeps_remainder() {
        let mut rng = Rng::from_seed(3);
        let obj = Quadratic::new(4, 1.0, 1.0, 0.3, &mut rng);
        let mut opt = TikiTaka::new(
            4,
            &presets::preset("om").unwrap(),
            0.0,
            0.0,
            hypers(TtVariant::V2),
            0.1,
            &mut rng,
        );
        for _ in 0..50 {
            opt.step(&obj, &mut rng);
        }
        let t = opt.thresh as f32;
        assert!(opt.h.iter().all(|&h| h.abs() <= t * 1.001), "{:?}", opt.h);
    }

    #[test]
    fn calibrated_reference_helps_under_offset() {
        // Two-stage logic: with q set to the true SPs, TT under a large
        // SP offset matches (or beats) the uncalibrated run.
        let mut rng = Rng::from_seed(4);
        let obj = Quadratic::new(16, 1.0, 4.0, 0.3, &mut rng);
        let preset = presets::preset("om").unwrap();
        let mk = |rng: &mut Rng| {
            TikiTaka::new(16, &preset, 0.6, 0.1, hypers(TtVariant::V2), 0.3, rng)
        };
        let mut uncal = mk(&mut rng);
        let mut cal = mk(&mut rng);
        let truth = cal.a.symmetric_points();
        cal.set_reference(truth);
        let (mut lu, mut lc) = (Vec::new(), Vec::new());
        for _ in 0..2000 {
            lu.push(uncal.step(&obj, &mut rng));
            lc.push(cal.step(&obj, &mut rng));
        }
        let tu = stats::mean(&lu[lu.len() - 100..]);
        let tc = stats::mean(&lc[lc.len() - 100..]);
        assert!(tc <= tu * 1.2, "calibrated {tc} vs uncalibrated {tu}");
    }
}
