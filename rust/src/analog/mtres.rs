//! Multi-tile residual learning (`mtres`, after arXiv:2510.02516):
//! compensate low-conductance-state devices by summing a stack of
//! tiles trained on successive residuals.
//!
//! The logical weight is read out as the scaled sum
//! `W̄ = Σ_t s^t · P_t` over a [`TiledArray`] stack of 1×dim tiles
//! (each with its own SP map and RNG sub-stream). Training proceeds in
//! stages: for `stage_steps` iterations only tile `t` receives pulsed
//! updates, with the gradient rescaled by `1/s^t` so the *logical*
//! stepsize stays `lr`. Because tile `t` contributes at scale `s^t`,
//! its effective granularity is `s^t · dw_min` — each stage refines
//! the frozen coarse approximation of the previous ones, and the
//! logical imprint of each tile's SP bias shrinks geometrically. This
//! is the structural alternative to reference subtraction: no ZS
//! calibration, no chopper, no programming events.

use crate::analog::optimizer::AnalogOptimizer;
use crate::analog::pulse_counter::PulseCost;
use crate::device::tile::{TileGeometry, TiledArray};
use crate::device::Preset;
use crate::optim::Objective;
use crate::util::rng::Rng;

/// Hyperparameters of multi-tile residual learning.
#[derive(Clone, Copy, Debug)]
pub struct MtresHypers {
    /// α — logical learning rate (the active tile's update is
    /// rescaled by `1/s^t` so this is the stepsize of `W̄`)
    pub lr: f64,
    /// s — per-tile read-out gain ratio; tile `t` contributes at
    /// `s^t`, so smaller gains give finer late-stage granularity at
    /// the cost of less residual head-room per tile
    pub tile_gain: f64,
    /// steps per residual stage before the next tile activates (the
    /// last tile trains for the remainder of the run)
    pub stage_steps: u64,
    /// number of stacked tiles
    pub tiles: usize,
}

impl Default for MtresHypers {
    fn default() -> Self {
        Self {
            lr: 0.05,
            tile_gain: 0.5,
            stage_steps: 400,
            tiles: 3,
        }
    }
}

/// Multi-tile residual learning on the tiled crossbar substrate.
pub struct Mtres {
    /// The tile stack: a `tiles x dim` logical array with geometry
    /// `(1, dim)`, so each grid tile is one 1×dim device row.
    pub arr: TiledArray,
    /// Hyperparameters.
    pub hypers: MtresHypers,
    /// Gradient noise scale.
    pub sigma: f64,
    /// Per-tile read-out scales `s^t`.
    scales: Vec<f32>,
    step_count: u64,
    digital_ops: u64,
    /// stored reference; mtres compensates structurally (residual
    /// stack), so this is inspectable but never applied
    q: Vec<f32>,
    wbar_buf: Vec<f32>,
    grad_buf: Vec<f32>,
    dw_buf: Vec<f32>,
}

impl Mtres {
    /// Build a stack of `hypers.tiles` freshly-sampled 1×dim tiles,
    /// each from its own RNG sub-stream of `rng`.
    pub fn new(
        dim: usize,
        preset: &Preset,
        ref_mean: f64,
        ref_std: f64,
        hypers: MtresHypers,
        sigma: f64,
        rng: &mut Rng,
    ) -> Self {
        let tiles = hypers.tiles.max(1);
        let geom = TileGeometry::new(1, dim.max(1)).expect("1 x dim tile geometry is valid");
        let arr = TiledArray::sample(tiles, dim, geom, preset, ref_mean, ref_std, 0.1, rng);
        let scales = (0..tiles)
            .map(|t| hypers.tile_gain.powi(t as i32) as f32)
            .collect();
        Self {
            arr,
            hypers,
            sigma,
            scales,
            step_count: 0,
            digital_ops: 0,
            q: vec![0.0; dim],
            wbar_buf: vec![0.0; dim],
            grad_buf: vec![0.0; dim],
            dw_buf: vec![0.0; dim],
        }
    }

    /// Index of the tile the current stage trains.
    pub fn active_tile(&self) -> usize {
        let stage = self.step_count / self.hypers.stage_steps.max(1);
        (stage as usize).min(self.arr.n_tiles() - 1)
    }

    /// Recompute the summed read-out `W̄ = Σ_t s^t · P_t` into the
    /// member buffer (allocation-free).
    fn compute_wbar(&mut self) {
        self.wbar_buf.fill(0.0);
        for t in 0..self.arr.n_tiles() {
            let s = self.scales[t];
            let tw = &self.arr.tile(t).w;
            for (o, w) in self.wbar_buf.iter_mut().zip(tw) {
                *o += s * *w;
            }
        }
    }
}

impl AnalogOptimizer for Mtres {
    /// One residual-stage step: read out `W̄`, take the noisy gradient
    /// there, and pulse only the active tile with the `1/s^t`-rescaled
    /// increment. Returns the loss at the pre-step `W̄`.
    fn step(&mut self, obj: &dyn Objective, rng: &mut Rng) -> f64 {
        self.compute_wbar();
        let loss = obj.loss(&self.wbar_buf);
        obj.noisy_grad(&self.wbar_buf, self.sigma, rng, &mut self.grad_buf);
        let a = self.active_tile();
        let lr_t = (self.hypers.lr / self.scales[a] as f64) as f32;
        for (d, g) in self.dw_buf.iter_mut().zip(&self.grad_buf) {
            *d = -lr_t * *g;
        }
        self.arr.tile_mut(a).analog_update(&self.dw_buf, rng);
        // the scaled summed read-out is digital work: one
        // multiply-accumulate per tile per weight
        self.digital_ops += (self.arr.n_tiles() * self.dw_buf.len()) as u64;
        self.step_count += 1;
        loss
    }

    fn weights(&mut self) -> &[f32] {
        self.compute_wbar();
        &self.wbar_buf
    }

    fn set_reference(&mut self, q: Vec<f32>) {
        assert_eq!(q.len(), self.q.len());
        self.q = q;
    }

    fn sp_reference(&self) -> &[f32] {
        &self.q
    }

    fn cost(&self) -> PulseCost {
        PulseCost {
            update_pulses: self.arr.pulse_count(),
            digital_ops: self.digital_ops,
            ..Default::default()
        }
    }

    fn name(&self) -> &'static str {
        "mtres"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analog::sgd::{AnalogSgd, SgdHypers};
    use crate::device::presets::Preset;
    use crate::optim::Quadratic;
    use crate::util::stats;

    /// A deliberately coarse, biased device: few conductance states and
    /// a displaced SP — the regime arXiv:2510.02516 targets.
    fn coarse() -> Preset {
        Preset {
            name: "coarse",
            tau_max: 1.0,
            tau_min: 1.0,
            dw_min: 0.25,
            d2d: 0.0,
            c2c: 0.1,
        }
    }

    #[test]
    fn stage_schedule_freezes_earlier_tiles() {
        let mut rng = Rng::from_seed(1);
        let obj = Quadratic::new(8, 1.0, 4.0, 0.3, &mut rng);
        let hypers = MtresHypers { stage_steps: 50, tiles: 3, ..MtresHypers::default() };
        let mut opt = Mtres::new(8, &coarse(), 0.3, 0.05, hypers, 0.2, &mut rng);
        for _ in 0..50 {
            opt.step(&obj, &mut rng);
        }
        assert_eq!(opt.active_tile(), 1);
        let frozen = opt.arr.tile(0).pulse_count;
        assert!(frozen > 0, "stage 0 must have pulsed tile 0");
        for _ in 0..50 {
            opt.step(&obj, &mut rng);
        }
        assert_eq!(
            opt.arr.tile(0).pulse_count,
            frozen,
            "frozen tiles must receive no further pulses"
        );
        assert!(opt.arr.tile(1).pulse_count > 0, "stage 1 must pulse tile 1");
        assert_eq!(opt.active_tile(), 2);
        for _ in 0..200 {
            opt.step(&obj, &mut rng);
        }
        // the last tile trains for the remainder of the run
        assert_eq!(opt.active_tile(), 2);
    }

    #[test]
    fn summed_readout_matches_scaled_tiles() {
        let mut rng = Rng::from_seed(2);
        let obj = Quadratic::new(6, 1.0, 4.0, 0.3, &mut rng);
        let mut opt = Mtres::new(6, &coarse(), 0.3, 0.05, MtresHypers::default(), 0.2, &mut rng);
        for _ in 0..30 {
            opt.step(&obj, &mut rng);
        }
        let mut want = vec![0.0f32; 6];
        for t in 0..opt.arr.n_tiles() {
            let s = opt.hypers.tile_gain.powi(t as i32) as f32;
            for (o, w) in want.iter_mut().zip(&opt.arr.tile(t).w) {
                *o += s * *w;
            }
        }
        assert_eq!(opt.weights(), &want[..]);
    }

    #[test]
    fn pulse_cost_flows_through_the_trait() {
        let mut rng = Rng::from_seed(3);
        let obj = Quadratic::new(8, 1.0, 4.0, 0.3, &mut rng);
        let mut opt = Mtres::new(8, &coarse(), 0.3, 0.05, MtresHypers::default(), 0.2, &mut rng);
        for _ in 0..40 {
            opt.step(&obj, &mut rng);
        }
        let c = opt.cost();
        assert_eq!(c.update_pulses, opt.arr.pulse_count());
        assert!(c.update_pulses > 0);
        assert!(c.digital_ops > 0, "summed read-out is digital work");
        // structural compensation: no calibration, no chopper
        assert_eq!(c.calibration_pulses, 0);
        assert_eq!(c.programming_events, 0);
    }

    #[test]
    fn beats_plain_sgd_on_a_coarse_biased_device() {
        // the point of the residual stack: on a few-state device with a
        // displaced SP, plain Analog SGD stalls at a quantization/bias
        // floor while later mtres stages keep refining at s^t * dw_min
        // granularity (typical tail ratio is well below the asserted
        // margin)
        let mut rng = Rng::from_seed(5);
        let obj = Quadratic::new(8, 1.0, 4.0, 0.3, &mut rng);
        let steps = 1600;
        let tail = 200;

        let mut sgd = AnalogSgd::new(
            8,
            &coarse(),
            0.4,
            0.05,
            SgdHypers { lr: 0.05 },
            0.3,
            &mut rng,
        );
        let mut sgd_losses = Vec::new();
        for _ in 0..steps {
            sgd_losses.push(sgd.step(&obj, &mut rng));
        }

        let mut mt = Mtres::new(8, &coarse(), 0.4, 0.05, MtresHypers::default(), 0.3, &mut rng);
        let mut mt_losses = Vec::new();
        for _ in 0..steps {
            mt_losses.push(mt.step(&obj, &mut rng));
        }

        let sgd_tail = stats::mean(&sgd_losses[steps - tail..]);
        let mt_tail = stats::mean(&mt_losses[steps - tail..]);
        let mt_head = stats::mean(&mt_losses[..50]);
        assert!(mt_tail < mt_head, "mtres must learn: {mt_head} -> {mt_tail}");
        assert!(
            mt_tail < 0.8 * sgd_tail,
            "mtres tail {mt_tail} should beat sgd tail {sgd_tail}"
        );
    }
}
