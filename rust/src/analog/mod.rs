//! The paper's analog-training algorithm family on the Rust substrate
//! (pulse-level; used by the theory experiments and Fig. 1/Fig. 4-left).
//! The NN-scale variants of the same algorithms live in the AOT
//! artifacts (python/compile/algorithms.py) and are driven by `train`.

pub mod agad;
pub mod digital;
pub mod mtres;
pub mod optimizer;
pub mod pulse_counter;
pub mod residual;
pub mod rider;
pub mod sgd;
pub mod tiki_taka;
pub mod zs;

pub use agad::{Agad, AgadHypers};
pub use digital::{DigitalHypers, DigitalSgd};
pub use mtres::{Mtres, MtresHypers};
pub use optimizer::{AnalogOptimizer, Method, OptimizerSpec, METHODS};
pub use pulse_counter::PulseCost;
pub use residual::{ResidualHypers, TwoStageResidual};
pub use rider::{Rider, RiderHypers};
pub use sgd::{AnalogSgd, SgdHypers};
pub use tiki_taka::{TikiTaka, TtHypers, TtVariant};
pub use zs::{ZsResult, ZsVariant};
