//! Analog SGD (paper Eq. 2 applied directly): the baseline whose bias
//! towards the device SP (Eq. 4) motivates everything else.

use crate::analog::optimizer::AnalogOptimizer;
use crate::analog::pulse_counter::PulseCost;
use crate::device::{DeviceArray, Preset};
use crate::optim::Objective;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug)]
pub struct SgdHypers {
    /// α — learning rate
    pub lr: f64,
}

impl Default for SgdHypers {
    fn default() -> Self {
        Self { lr: 0.05 }
    }
}

pub struct AnalogSgd {
    pub w: DeviceArray,
    pub hypers: SgdHypers,
    pub sigma: f64,
    /// stored reference; Analog SGD has no compensation path, so this
    /// is inspectable (`sp_reference`) but never applied
    q: Vec<f32>,
    grad_buf: Vec<f32>,
    dw_buf: Vec<f32>,
}

impl AnalogSgd {
    pub fn new(
        dim: usize,
        preset: &Preset,
        ref_mean: f64,
        ref_std: f64,
        hypers: SgdHypers,
        sigma: f64,
        rng: &mut Rng,
    ) -> Self {
        Self {
            w: DeviceArray::sample(1, dim, preset, ref_mean, ref_std, 0.1, rng),
            hypers,
            sigma,
            q: vec![0.0; dim],
            grad_buf: vec![0.0; dim],
            dw_buf: vec![0.0; dim],
        }
    }
}

impl AnalogOptimizer for AnalogSgd {
    /// One SGD step; returns the loss at the pre-step iterate.
    fn step(&mut self, obj: &dyn Objective, rng: &mut Rng) -> f64 {
        let loss = obj.loss(&self.w.w);
        obj.noisy_grad(&self.w.w, self.sigma, rng, &mut self.grad_buf);
        for (d, g) in self.dw_buf.iter_mut().zip(&self.grad_buf) {
            *d = (-self.hypers.lr * *g as f64) as f32;
        }
        self.w.analog_update(&self.dw_buf, rng);
        loss
    }

    fn weights(&mut self) -> &[f32] {
        &self.w.w
    }

    fn set_reference(&mut self, q: Vec<f32>) {
        assert_eq!(q.len(), self.q.len());
        self.q = q;
    }

    fn sp_reference(&self) -> &[f32] {
        &self.q
    }

    fn cost(&self) -> PulseCost {
        PulseCost {
            update_pulses: self.w.pulse_count,
            ..Default::default()
        }
    }

    fn name(&self) -> &'static str {
        "sgd"
    }

    /// Chaos-layer seam: stream 0 faults the single weight array.
    fn arm_faults(&mut self, plan: &crate::device::fault::FaultPlan) {
        plan.arm_array(&mut self.w, 0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::presets;
    use crate::optim::Quadratic;
    use crate::util::stats;

    #[test]
    fn converges_on_zero_sp_device() {
        let mut rng = Rng::from_seed(1);
        let obj = Quadratic::new(16, 1.0, 4.0, 0.3, &mut rng);
        let mut opt = AnalogSgd::new(
            16,
            &presets::preset("ideal").unwrap(),
            0.0,
            0.0,
            SgdHypers { lr: 0.05 },
            0.01,
            &mut rng,
        );
        let mut losses = Vec::new();
        for _ in 0..2000 {
            losses.push(opt.step(&obj, &mut rng));
        }
        let head = stats::mean(&losses[..50]);
        let tail = stats::mean(&losses[losses.len() - 50..]);
        assert!(tail < 0.05 * head, "head {head} tail {tail}");
    }

    #[test]
    fn biased_towards_sp_under_noise() {
        // Eq. 4: with gradient noise and nonzero SP, the iterate settles
        // displaced from the optimum, towards the SP.
        let mut rng = Rng::from_seed(2);
        let obj = Quadratic {
            lambda: vec![1.0; 8],
            w_star: vec![0.0; 8],
        };
        let mut opt = AnalogSgd::new(
            8,
            &presets::preset("om").unwrap(),
            0.6,
            0.05,
            SgdHypers { lr: 0.05 },
            0.5,
            &mut rng,
        );
        for _ in 0..4000 {
            opt.step(&obj, &mut rng);
        }
        let mean_w: f64 =
            opt.weights().iter().map(|&x| x as f64).sum::<f64>() / 8.0;
        assert!(mean_w > 0.1, "expected drift towards SP 0.6, got {mean_w}");
    }

    #[test]
    fn counts_pulses() {
        let mut rng = Rng::from_seed(3);
        let obj = Quadratic::new(4, 1.0, 1.0, 0.3, &mut rng);
        let mut opt = AnalogSgd::new(
            4,
            &presets::preset("om").unwrap(),
            0.0,
            0.0,
            SgdHypers { lr: 0.1 },
            0.0,
            &mut rng,
        );
        for _ in 0..10 {
            opt.step(&obj, &mut rng);
        }
        assert!(opt.cost().update_pulses > 0);
    }
}
