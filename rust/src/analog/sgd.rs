//! Analog SGD (paper Eq. 2 applied directly): the baseline whose bias
//! towards the device SP (Eq. 4) motivates everything else.

use crate::analog::pulse_counter::PulseCost;
use crate::device::{DeviceArray, Preset};
use crate::optim::Objective;
use crate::util::rng::Rng;

pub struct AnalogSgd {
    pub w: DeviceArray,
    pub alpha: f64,
    pub sigma: f64,
    grad_buf: Vec<f32>,
    dw_buf: Vec<f32>,
}

impl AnalogSgd {
    pub fn new(
        dim: usize,
        preset: &Preset,
        ref_mean: f64,
        ref_std: f64,
        alpha: f64,
        sigma: f64,
        rng: &mut Rng,
    ) -> Self {
        Self {
            w: DeviceArray::sample(1, dim, preset, ref_mean, ref_std, 0.1, rng),
            alpha,
            sigma,
            grad_buf: vec![0.0; dim],
            dw_buf: vec![0.0; dim],
        }
    }

    /// One SGD step; returns the loss at the pre-step iterate.
    pub fn step(&mut self, obj: &dyn Objective, rng: &mut Rng) -> f64 {
        let loss = obj.loss(&self.w.w);
        obj.noisy_grad(&self.w.w, self.sigma, rng, &mut self.grad_buf);
        for (d, g) in self.dw_buf.iter_mut().zip(&self.grad_buf) {
            *d = (-self.alpha * *g as f64) as f32;
        }
        self.w.analog_update(&self.dw_buf, rng);
        loss
    }

    pub fn weights(&self) -> &[f32] {
        &self.w.w
    }

    pub fn cost(&self) -> PulseCost {
        PulseCost {
            update_pulses: self.w.pulse_count,
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::presets;
    use crate::optim::Quadratic;
    use crate::util::stats;

    #[test]
    fn converges_on_zero_sp_device() {
        let mut rng = Rng::from_seed(1);
        let obj = Quadratic::new(16, 1.0, 4.0, 0.3, &mut rng);
        let mut opt = AnalogSgd::new(
            16, &presets::preset("ideal").unwrap(), 0.0, 0.0, 0.05, 0.01, &mut rng,
        );
        let mut losses = Vec::new();
        for _ in 0..2000 {
            losses.push(opt.step(&obj, &mut rng));
        }
        let head = stats::mean(&losses[..50]);
        let tail = stats::mean(&losses[losses.len() - 50..]);
        assert!(tail < 0.05 * head, "head {head} tail {tail}");
    }

    #[test]
    fn biased_towards_sp_under_noise() {
        // Eq. 4: with gradient noise and nonzero SP, the iterate settles
        // displaced from the optimum, towards the SP.
        let mut rng = Rng::from_seed(2);
        let obj = Quadratic {
            lambda: vec![1.0; 8],
            w_star: vec![0.0; 8],
        };
        let mut opt = AnalogSgd::new(
            8, &presets::preset("om").unwrap(), 0.6, 0.05, 0.05, 0.5, &mut rng,
        );
        for _ in 0..4000 {
            opt.step(&obj, &mut rng);
        }
        let mean_w: f64 =
            opt.weights().iter().map(|&x| x as f64).sum::<f64>() / 8.0;
        assert!(mean_w > 0.1, "expected drift towards SP 0.6, got {mean_w}");
    }

    #[test]
    fn counts_pulses() {
        let mut rng = Rng::from_seed(3);
        let obj = Quadratic::new(4, 1.0, 1.0, 0.3, &mut rng);
        let mut opt = AnalogSgd::new(
            4, &presets::preset("om").unwrap(), 0.0, 0.0, 0.1, 0.0, &mut rng,
        );
        for _ in 0..10 {
            opt.step(&obj, &mut rng);
        }
        assert!(opt.cost().update_pulses > 0);
    }
}
