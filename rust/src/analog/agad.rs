//! AGAD baseline (Rasch et al., 2023/2024): chopped gradient accumulation
//! with reference-offset correction on chopper flips. The dynamic-SP
//! baseline E-RIDER is compared against; unlike E-RIDER it computes
//! gradients at W only (no residual mixing, paper Appendix B.2) and has
//! no residual-learning mechanism.

use crate::analog::optimizer::AnalogOptimizer;
use crate::analog::pulse_counter::PulseCost;
use crate::device::{DeviceArray, Preset};
use crate::optim::Objective;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug)]
pub struct AgadHypers {
    /// A-array learning rate
    pub lr_fast: f64,
    /// A → W transfer learning rate
    pub lr_transfer: f64,
    /// offset-refresh stepsize applied at chopper flips
    pub eta: f64,
    /// chopper flip probability
    pub flip_p: f64,
    /// analog read-out noise std
    pub read_noise: f64,
    /// mixing weight γ_a of the fast array in the forward pass
    pub gamma: f64,
}

impl Default for AgadHypers {
    fn default() -> Self {
        Self {
            lr_fast: 0.2,
            lr_transfer: 0.02,
            eta: 0.2,
            flip_p: 0.05,
            read_noise: 0.01,
            gamma: 1.0,
        }
    }
}

pub struct Agad {
    pub a: DeviceArray,
    pub w: DeviceArray,
    pub h: Vec<f32>,
    /// offset (reference) estimate, refreshed at chopper flips
    pub q: Vec<f32>,
    pub c: f64,
    pub hypers: AgadHypers,
    /// transfer threshold, derived from the preset granularity
    pub thresh: f64,
    pub sigma: f64,
    pub programming_events: u64,
    grad_buf: Vec<f32>,
    dw_buf: Vec<f32>,
    weff_buf: Vec<f32>,
    read_buf: Vec<f32>,
}

impl Agad {
    pub fn new(
        dim: usize,
        preset: &Preset,
        ref_mean: f64,
        ref_std: f64,
        hypers: AgadHypers,
        sigma: f64,
        rng: &mut Rng,
    ) -> Self {
        Self {
            a: DeviceArray::sample(1, dim, preset, ref_mean, ref_std, 0.1, rng),
            w: DeviceArray::sample(1, dim, preset, ref_mean, ref_std, 0.1, rng),
            h: vec![0.0; dim],
            q: vec![0.0; dim],
            c: 1.0,
            hypers,
            thresh: preset.dw_min.max(1e-3),
            sigma,
            programming_events: 0,
            grad_buf: vec![0.0; dim],
            dw_buf: vec![0.0; dim],
            weff_buf: vec![0.0; dim],
            read_buf: vec![0.0; dim],
        }
    }

    /// Effective weights W + γ_a c (A - q): the chopped fast array is
    /// part of the logical weight (de-chopped by the c factor); q is the
    /// flip-time offset estimate, NOT a filtered SP track — that, plus
    /// the missing residual bilevel structure, is what separates AGAD
    /// from E-RIDER (paper Appendix B.2).
    pub fn w_eff(&mut self) -> &[f32] {
        let g = (self.hypers.gamma * self.c) as f32;
        for i in 0..self.weff_buf.len() {
            self.weff_buf[i] = self.w.w[i] + g * (self.a.w[i] - self.q[i]);
        }
        &self.weff_buf
    }

    /// ||q - SP(A-device)||_mean — the offset-estimate error.
    pub fn q_tracking_error(&self) -> f64 {
        let sps = self.a.symmetric_points();
        self.q
            .iter()
            .zip(&sps)
            .map(|(q, s)| (q - s).abs() as f64)
            .sum::<f64>()
            / self.q.len() as f64
    }
}

impl AnalogOptimizer for Agad {
    fn step(&mut self, obj: &dyn Objective, rng: &mut Rng) -> f64 {
        let h = self.hypers;
        let flipped = h.flip_p > 0.0 && rng.bernoulli(h.flip_p);
        if flipped {
            self.c = -self.c;
        }
        self.w_eff();
        let loss = obj.loss(&self.weff_buf);
        obj.noisy_grad(&self.weff_buf, self.sigma, rng, &mut self.grad_buf);
        // chopped gradient into A
        let ac = (h.lr_fast * self.c) as f32;
        for (d, g) in self.dw_buf.iter_mut().zip(&self.grad_buf) {
            *d = -ac * *g;
        }
        self.a.analog_update(&self.dw_buf, rng);
        self.a.read_into(h.read_noise, rng, &mut self.read_buf);
        // offset refresh on flips: the de-chopped mean of A drifts to the
        // SP, so the read at a flip boundary estimates it.
        if flipped {
            let eta = h.eta as f32;
            for i in 0..self.read_buf.len() {
                self.q[i] = (1.0 - eta) * self.q[i] + eta * self.read_buf[i];
            }
            self.programming_events += self.q.len() as u64;
        }
        // de-chopped, offset-corrected accumulation + thresholded transfer
        let t = self.thresh as f32;
        let cs = self.c as f32;
        for i in 0..self.read_buf.len() {
            self.h[i] += cs * (self.read_buf[i] - self.q[i]);
            let quanta = (self.h[i] / t).trunc();
            self.dw_buf[i] = (h.lr_transfer * (quanta * t) as f64) as f32;
            self.h[i] -= quanta * t;
        }
        self.w.analog_update(&self.dw_buf, rng);
        loss
    }

    fn weights(&mut self) -> &[f32] {
        self.w_eff()
    }

    /// Seed the offset estimate (e.g. from an external calibration).
    fn set_reference(&mut self, q: Vec<f32>) {
        assert_eq!(q.len(), self.q.len());
        self.q = q;
    }

    fn sp_reference(&self) -> &[f32] {
        &self.q
    }

    fn cost(&self) -> PulseCost {
        PulseCost {
            update_pulses: self.a.pulse_count + self.w.pulse_count,
            programming_events: self.programming_events,
            digital_ops: self.h.len() as u64 * 2,
            ..Default::default()
        }
    }

    fn name(&self) -> &'static str {
        "agad"
    }

    fn sp_tracking_error(&self) -> Option<f64> {
        Some(self.q_tracking_error())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::presets;
    use crate::optim::Quadratic;
    use crate::util::stats;

    #[test]
    fn converges_under_nonzero_sp() {
        let mut rng = Rng::from_seed(1);
        let obj = Quadratic::new(16, 1.0, 4.0, 0.3, &mut rng);
        let mut opt = Agad::new(
            16,
            &presets::preset("om").unwrap(),
            0.4,
            0.2,
            AgadHypers::default(),
            0.2,
            &mut rng,
        );
        let mut losses = Vec::new();
        for _ in 0..5000 {
            losses.push(opt.step(&obj, &mut rng));
        }
        let init = losses[0];
        let tail = stats::mean(&losses[losses.len() - 200..]);
        assert!(tail < 0.4 * init, "init {init} tail {tail}");
    }

    #[test]
    fn offset_estimate_moves_towards_sp() {
        let mut rng = Rng::from_seed(2);
        let obj = Quadratic {
            lambda: vec![1.0; 8],
            w_star: vec![0.0; 8],
        };
        let mut opt = Agad::new(
            8,
            &presets::preset("om").unwrap(),
            0.5,
            0.1,
            AgadHypers {
                flip_p: 0.2,
                ..Default::default()
            },
            0.4,
            &mut rng,
        );
        let init = opt.q_tracking_error();
        for _ in 0..4000 {
            opt.step(&obj, &mut rng);
        }
        assert!(
            opt.q_tracking_error() < init,
            "init {init} now {}",
            opt.q_tracking_error()
        );
    }

    #[test]
    fn programming_cost_proportional_to_flips() {
        let mut rng = Rng::from_seed(3);
        let obj = Quadratic::new(4, 1.0, 1.0, 0.3, &mut rng);
        let mut opt = Agad::new(
            4,
            &presets::preset("ideal").unwrap(),
            0.0,
            0.0,
            AgadHypers {
                lr_fast: 0.1,
                lr_transfer: 0.05,
                flip_p: 1.0, // flip every step
                ..Default::default()
            },
            0.1,
            &mut rng,
        );
        for _ in 0..100 {
            opt.step(&obj, &mut rng);
        }
        assert_eq!(opt.programming_events, 100 * 4);
    }
}
