//! Pulse / programming cost accounting (the currency of Fig. 4 left and
//! Corollary 3.9): update pulses on analog arrays, weight-programming
//! events for reference synchronization, and digital ops for context.

/// Accumulated costs of a training or calibration run.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PulseCost {
    /// pulses applied to analog arrays during optimizer updates
    pub update_pulses: u64,
    /// pulses spent on SP calibration (ZS stage)
    pub calibration_pulses: u64,
    /// weight-programming events (cells reprogrammed, e.g. Q-tilde sync)
    pub programming_events: u64,
    /// digital scalar ops (moving averages, buffers) — context only
    pub digital_ops: u64,
}

impl PulseCost {
    pub fn total_pulses(&self) -> u64 {
        self.update_pulses + self.calibration_pulses
    }

    pub fn add(&mut self, other: &PulseCost) {
        self.update_pulses += other.update_pulses;
        self.calibration_pulses += other.calibration_pulses;
        self.programming_events += other.programming_events;
        self.digital_ops += other.digital_ops;
    }

    /// The paper's training-cost formula for HLO-driven runs where
    /// per-pulse counts aren't observable: steps × weights × BL, with
    /// average update pulse length BL (Fig. 4 caption uses BL = 5).
    pub fn training_estimate(steps: u64, weights: u64, bl: u64) -> u64 {
        steps * weights * bl
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn additivity() {
        let mut a = PulseCost {
            update_pulses: 10,
            calibration_pulses: 5,
            programming_events: 2,
            digital_ops: 100,
        };
        let b = a;
        a.add(&b);
        assert_eq!(a.update_pulses, 20);
        assert_eq!(a.total_pulses(), 30);
    }

    #[test]
    fn paper_formula() {
        // epochs × (data/B) × BL per weight: 2 epochs × 100 steps × BL 5
        assert_eq!(PulseCost::training_estimate(200, 1, 5), 1000);
    }
}
