//! The pulse-level algorithm family behind one interface: the
//! [`AnalogOptimizer`] trait plus a spec-driven string registry.
//!
//! The paper's central comparison (Tables 1–2, Fig. 4, Theorem 3.7 vs
//! Corollary 3.9) is a sweep *across methods* — Analog SGD, Tiki-Taka
//! v1/v2, AGAD, two-stage residual learning, RIDER/E-RIDER — all
//! instances of one transfer-compound family. This module makes that
//! family addressable by name and config, mirroring the preset registry
//! in `device/presets.rs`:
//!
//! ```text
//! "sgd" | "ttv1" | "ttv2" | "agad" | "residual" | "rider" | "erider" | "mtres" | "digital"
//! ```
//!
//! [`OptimizerSpec`] is plain data (serde-friendly: flat scalars, no
//! borrowed state) carrying the union of the per-method hyperparameters
//! with per-method defaults; [`OptimizerSpec::build`] instantiates the
//! concrete struct behind a `Box<dyn AnalogOptimizer>`. Adding a method
//! is a one-file change: implement the trait, add a [`Method`] arm, and
//! it appears in every table, sweep, bench, and the registry test.
//!
//! The same registry drives the NN-scale (HLO-driven) layer: [`Method`]
//! carries the artifact-name mapping (`<model>_step_<suffix>`, see
//! [`Method::nn_step_algo`]) and the per-method ZS-calibration policy
//! ([`Method::nn_needs_zs`]); `train::Hypers::for_method` resolves the
//! NN-scale hyperparameter defaults. `train::TrainConfig` holds an
//! `OptimizerSpec`, so `rider psweep --methods all` and the NN-scale
//! experiments accept one shared name set.
//!
//! # Example: build and step a method by name
//!
//! ```
//! use analog_rider::analog::optimizer::{spec, METHODS};
//! use analog_rider::device::presets;
//! use analog_rider::optim::Quadratic;
//! use analog_rider::util::rng::Rng;
//!
//! let preset = presets::preset("om").unwrap();
//! let mut rng = Rng::from_seed(7);
//! let obj = Quadratic::new(4, 1.0, 2.0, 0.3, &mut rng);
//! // every registry name builds the same way; "erider" is the paper's
//! // chopped dynamic SP-tracking method
//! assert!(METHODS.contains(&"erider"));
//! let mut opt = spec("erider").unwrap().build(4, &preset, 0.3, 0.1, 0.1, &mut rng);
//! let loss = opt.step(&obj, &mut rng);
//! assert!(loss.is_finite());
//! assert_eq!(opt.name(), "erider");
//! assert_eq!(opt.weights().len(), 4);
//! ```

#![warn(missing_docs)]

use crate::analog::agad::{Agad, AgadHypers};
use crate::analog::digital::{DigitalHypers, DigitalSgd};
use crate::analog::mtres::{Mtres, MtresHypers};
use crate::analog::pulse_counter::PulseCost;
use crate::analog::residual::{ResidualHypers, TwoStageResidual};
use crate::analog::rider::{Rider, RiderHypers};
use crate::analog::sgd::{AnalogSgd, SgdHypers};
use crate::analog::tiki_taka::{TikiTaka, TtHypers, TtVariant};
use crate::cli::Args;
use crate::config::Config;
use crate::device::fault::FaultPlan;
use crate::device::Preset;
use crate::optim::Objective;
use crate::util::rng::Rng;

/// A pulse-level analog training method (one logical weight vector,
/// stepped against an [`Objective`] on the device substrate).
pub trait AnalogOptimizer {
    /// One optimizer iteration; returns the loss at the pre-step
    /// logical weight.
    fn step(&mut self, obj: &dyn Objective, rng: &mut Rng) -> f64;

    /// The logical (effective) weights the method exposes to the
    /// forward pass — e.g. `W + γ c (P − Q)` for RIDER.
    ///
    /// Takes `&mut self` by design: multi-array methods recompute the
    /// effective weight into an internal scratch buffer on every call
    /// (allocation-free), so the receiver must be mutable even though
    /// the method is logically a read. Single-array methods simply
    /// return their weight slice.
    fn weights(&mut self) -> &[f32];

    /// Install an external reference (SP estimate) `q` — the two-stage
    /// pipelines seed this from a ZS calibration run.
    fn set_reference(&mut self, q: Vec<f32>);

    /// The current reference / SP estimate `q` the method corrects
    /// reads against (zeros when uncalibrated, fixed for frozen
    /// references, tracked online for RIDER/E-RIDER/AGAD).
    fn sp_reference(&self) -> &[f32];

    /// Accumulated pulse / programming cost (the currency of Fig. 4
    /// left and Corollary 3.9).
    fn cost(&self) -> PulseCost;

    /// Registry name of the method (`"erider"`, `"ttv2"`, ...).
    fn name(&self) -> &'static str;

    /// Mean `|q − SP|` over the tracked array, when the method keeps a
    /// reference estimate (Lemma 3.5 metric); `None` otherwise.
    fn sp_tracking_error(&self) -> Option<f64> {
        None
    }

    /// The Eq. (14) convergence terms `(||W̄ − W*||², ||P − Q||²,
    /// ||G_P(P)||²)` for residual-type methods; `None` otherwise.
    fn convergence_metrics(&mut self, _obj: &dyn Objective) -> Option<(f64, f64, f64)> {
        None
    }

    /// Arm a device [`FaultPlan`] on the arrays the method owns, one
    /// fault sub-stream per array (the chaos-layer seam; see
    /// `device/fault.rs`). Methods that have not wired the seam yet
    /// keep the default no-op — their substrate simply stays healthy.
    fn arm_faults(&mut self, _plan: &FaultPlan) {}
}

/// Registry identifier of a method (both layers address methods through
/// this one enum).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Analog SGD: direct pulsed updates on one array.
    Sgd,
    /// Tiki-Taka v1: fast array + transfer array.
    TtV1,
    /// Tiki-Taka v2: v1 with a digital accumulator before transfer.
    TtV2,
    /// AGAD: chopped gradient accumulation with flip-time reference
    /// refresh.
    Agad,
    /// Two-stage residual learning: ZS-calibrated frozen reference.
    Residual,
    /// RIDER: dynamic symmetric-point tracking (no chopper).
    Rider,
    /// E-RIDER: RIDER with the chopper enabled (Eq. 17).
    Erider,
    /// Multi-tile residual learning: a stack of tiles trained on
    /// successive residuals, summed at read-out (arXiv:2510.02516).
    Mtres,
    /// exact-SGD baseline arm (pre-training / upper bound; pulse-free)
    Digital,
}

/// Every registry name, in canonical (paper-table) order; the digital
/// baseline arm closes the list.
pub const METHODS: &[&str] = &[
    "sgd", "ttv1", "ttv2", "agad", "residual", "rider", "erider", "mtres", "digital",
];

impl Method {
    /// Every registry method, in the same canonical order as
    /// [`METHODS`]. Tables, sweeps, and the registry tests iterate this
    /// const, so a [`Method`] arm missing from any name mapping fails
    /// the build (exhaustive matches) or the tests (order pinning).
    pub const ALL: &'static [Method] = &[
        Method::Sgd,
        Method::TtV1,
        Method::TtV2,
        Method::Agad,
        Method::Residual,
        Method::Rider,
        Method::Erider,
        Method::Mtres,
        Method::Digital,
    ];

    /// Parse a registry name (`None` for unknown names — callers decide
    /// how to report; see [`spec_or_err`]).
    pub fn parse(name: &str) -> Option<Method> {
        match name {
            "sgd" => Some(Method::Sgd),
            "ttv1" => Some(Method::TtV1),
            "ttv2" => Some(Method::TtV2),
            "agad" => Some(Method::Agad),
            "residual" => Some(Method::Residual),
            "rider" => Some(Method::Rider),
            "erider" => Some(Method::Erider),
            "mtres" => Some(Method::Mtres),
            "digital" => Some(Method::Digital),
            _ => None,
        }
    }

    /// The method's canonical registry name.
    pub fn name(self) -> &'static str {
        match self {
            Method::Sgd => "sgd",
            Method::TtV1 => "ttv1",
            Method::TtV2 => "ttv2",
            Method::Agad => "agad",
            Method::Residual => "residual",
            Method::Rider => "rider",
            Method::Erider => "erider",
            Method::Mtres => "mtres",
            Method::Digital => "digital",
        }
    }

    /// Artifact-name suffix of the method's NN-scale step function
    /// (`<model>_step_<suffix>`, lowered by `python/compile/aot.py`).
    /// RIDER and two-stage residual learning reuse the E-RIDER step:
    /// they are hyperparameter slices of it (chopper off, and frozen
    /// reference after ZS, respectively — see `Hypers::for_method`).
    /// Multi-tile residual learning has no dedicated lowered step yet
    /// either; at NN scale it runs the E-RIDER step as its
    /// single-tile-stack stand-in (chopper off, see
    /// `Hypers::for_method`), while the true tile stack lives at the
    /// pulse level (`analog/mtres.rs`).
    pub fn nn_step_algo(self) -> &'static str {
        match self {
            Method::Rider | Method::Erider | Method::Residual | Method::Mtres => "erider",
            m => m.name(),
        }
    }

    /// Whether the NN-scale pipeline runs ZS calibration before training
    /// by default: only the two-stage residual pipeline calibrates its
    /// reference up front (Algorithm 4); every other method either
    /// tracks it online or ignores it.
    pub fn nn_needs_zs(self) -> bool {
        matches!(self, Method::Residual)
    }
}

/// Plain-data description of a pulse-level optimizer: the method name
/// plus the union of the family's hyperparameters. Fields a method does
/// not use are ignored by its builder (documented per field). Defaults
/// are per-method (see [`OptimizerSpec::new`]).
#[derive(Clone, Copy, Debug)]
pub struct OptimizerSpec {
    /// Which registry method this spec instantiates.
    pub method: Method,
    /// α — fast-array (or plain SGD) learning rate
    pub lr_fast: f64,
    /// β — transfer learning rate (unused by `sgd`)
    pub lr_transfer: f64,
    /// η — reference moving-average stepsize (RIDER Eq. 12; AGAD
    /// flip-time refresh; unused by `sgd`/`ttv1`/`ttv2`)
    pub eta: f64,
    /// γ — residual / fast-array mixing weight in the logical weight
    pub gamma: f64,
    /// chopper flip probability p (Eq. 17); 0 disables chopping
    pub flip_p: f64,
    /// analog read-out noise std
    pub read_noise: f64,
    /// ZS calibration budget of the two-stage pipeline (`residual` only)
    pub zs_pulses: u64,
    /// number of stacked residual tiles (`mtres` only)
    pub tiles: usize,
    /// optimizer steps per residual stage before the next tile
    /// activates (`mtres` only)
    pub stage_steps: u64,
}

impl OptimizerSpec {
    /// The method's paper-default hyperparameters.
    pub fn new(method: Method) -> OptimizerSpec {
        let r = RiderHypers::default();
        let m = MtresHypers::default();
        let mut s = OptimizerSpec {
            method,
            lr_fast: r.lr_fast,
            lr_transfer: r.lr_transfer,
            eta: r.eta,
            gamma: r.gamma,
            flip_p: r.flip_p,
            read_noise: r.read_noise,
            zs_pulses: 2000,
            tiles: m.tiles,
            stage_steps: m.stage_steps,
        };
        match method {
            Method::Sgd => {
                s.lr_fast = SgdHypers::default().lr;
                s.eta = 0.0;
                s.flip_p = 0.0;
            }
            Method::TtV1 | Method::TtV2 => {
                let t = TtHypers::default();
                s.lr_fast = t.lr_fast;
                s.lr_transfer = t.lr_transfer;
                s.read_noise = t.read_noise;
                s.gamma = t.gamma;
                s.eta = 0.0;
                s.flip_p = 0.0;
            }
            Method::Agad => {
                let a = AgadHypers::default();
                s.lr_fast = a.lr_fast;
                s.lr_transfer = a.lr_transfer;
                s.eta = a.eta;
                s.flip_p = a.flip_p;
                s.read_noise = a.read_noise;
                s.gamma = a.gamma;
            }
            // pure RIDER: no chopper
            Method::Rider => s.flip_p = 0.0,
            // E-RIDER: RiderHypers::default() as is
            Method::Erider => {}
            // stage 2 freezes the reference: η = p = 0 (Algorithm 4)
            Method::Residual => {
                s.eta = 0.0;
                s.flip_p = 0.0;
            }
            // residual *stack*: γ is reused as the per-tile read-out
            // gain ratio s; no reference filter, no chopper
            Method::Mtres => {
                s.lr_fast = m.lr;
                s.gamma = m.tile_gain;
                s.lr_transfer = 0.0;
                s.eta = 0.0;
                s.flip_p = 0.0;
            }
            // exact SGD: no device, no reference, no chopper
            Method::Digital => {
                s.lr_fast = DigitalHypers::default().lr;
                s.lr_transfer = 0.0;
                s.eta = 0.0;
                s.gamma = 0.0;
                s.flip_p = 0.0;
                s.read_noise = 0.0;
            }
        }
        s
    }

    /// Override hyperparameters from CLI flags (`--lr-fast`,
    /// `--lr-transfer`, `--eta`, `--gamma`, `--flip-p`, `--read-noise`,
    /// `--zs-pulses`, `--tiles`, `--stage-steps`); absent flags keep
    /// the spec's value.
    pub fn apply_args(&mut self, args: &Args) {
        self.lr_fast = args.get_f64("lr-fast", self.lr_fast);
        self.lr_transfer = args.get_f64("lr-transfer", self.lr_transfer);
        self.eta = args.get_f64("eta", self.eta);
        self.gamma = args.get_f64("gamma", self.gamma);
        self.flip_p = args.get_f64("flip-p", self.flip_p);
        self.read_noise = args.get_f64("read-noise", self.read_noise);
        self.zs_pulses = args.get_u64("zs-pulses", self.zs_pulses);
        self.tiles = args.get_usize("tiles", self.tiles);
        self.stage_steps = args.get_u64("stage-steps", self.stage_steps);
    }

    /// Override hyperparameters from a config-file section (underscore
    /// keys: `lr_fast = 0.3`, ...); absent keys keep the spec's value.
    pub fn apply_config(&mut self, cfg: &Config, section: &str) {
        self.lr_fast = cfg.f64(section, "lr_fast", self.lr_fast);
        self.lr_transfer = cfg.f64(section, "lr_transfer", self.lr_transfer);
        self.eta = cfg.f64(section, "eta", self.eta);
        self.gamma = cfg.f64(section, "gamma", self.gamma);
        self.flip_p = cfg.f64(section, "flip_p", self.flip_p);
        self.read_noise = cfg.f64(section, "read_noise", self.read_noise);
        self.zs_pulses = cfg.f64(section, "zs_pulses", self.zs_pulses as f64) as u64;
        self.tiles = cfg.f64(section, "tiles", self.tiles as f64) as usize;
        self.stage_steps = cfg.f64(section, "stage_steps", self.stage_steps as f64) as u64;
    }

    fn rider_hypers(&self) -> RiderHypers {
        RiderHypers {
            lr_fast: self.lr_fast,
            lr_transfer: self.lr_transfer,
            eta: self.eta,
            gamma: self.gamma,
            flip_p: self.flip_p,
            read_noise: self.read_noise,
        }
    }

    fn tt_hypers(&self, variant: TtVariant) -> TtHypers {
        TtHypers {
            variant,
            lr_fast: self.lr_fast,
            lr_transfer: self.lr_transfer,
            read_noise: self.read_noise,
            gamma: self.gamma,
        }
    }

    /// Instantiate the method on a freshly-sampled device tile:
    /// per-cell SP ~ N(`ref_mean`, `ref_std`) under `preset`, gradient
    /// noise scale `sigma`.
    pub fn build(
        &self,
        dim: usize,
        preset: &Preset,
        ref_mean: f64,
        ref_std: f64,
        sigma: f64,
        rng: &mut Rng,
    ) -> Box<dyn AnalogOptimizer> {
        match self.method {
            Method::Sgd => Box::new(AnalogSgd::new(
                dim,
                preset,
                ref_mean,
                ref_std,
                SgdHypers { lr: self.lr_fast },
                sigma,
                rng,
            )),
            Method::TtV1 => Box::new(TikiTaka::new(
                dim,
                preset,
                ref_mean,
                ref_std,
                self.tt_hypers(TtVariant::V1),
                sigma,
                rng,
            )),
            Method::TtV2 => Box::new(TikiTaka::new(
                dim,
                preset,
                ref_mean,
                ref_std,
                self.tt_hypers(TtVariant::V2),
                sigma,
                rng,
            )),
            Method::Agad => Box::new(Agad::new(
                dim,
                preset,
                ref_mean,
                ref_std,
                AgadHypers {
                    lr_fast: self.lr_fast,
                    lr_transfer: self.lr_transfer,
                    eta: self.eta,
                    flip_p: self.flip_p,
                    read_noise: self.read_noise,
                    gamma: self.gamma,
                },
                sigma,
                rng,
            )),
            // stamp the selected registry name so hyper overrides (e.g.
            // --flip-p on "rider") don't relabel the optimizer
            Method::Rider | Method::Erider => Box::new(
                Rider::new(
                    dim,
                    preset,
                    ref_mean,
                    ref_std,
                    self.rider_hypers(),
                    sigma,
                    rng,
                )
                .with_name(self.method.name()),
            ),
            Method::Residual => Box::new(TwoStageResidual::new(
                dim,
                preset,
                ref_mean,
                ref_std,
                ResidualHypers {
                    rider: self.rider_hypers(),
                    zs_pulses: self.zs_pulses,
                },
                sigma,
                rng,
            )),
            Method::Mtres => Box::new(Mtres::new(
                dim,
                preset,
                ref_mean,
                ref_std,
                MtresHypers {
                    lr: self.lr_fast,
                    tile_gain: self.gamma,
                    stage_steps: self.stage_steps,
                    tiles: self.tiles,
                },
                sigma,
                rng,
            )),
            Method::Digital => Box::new(DigitalSgd::new(
                dim,
                DigitalHypers { lr: self.lr_fast },
                sigma,
            )),
        }
    }
}

/// Registry lookup: the default spec for a method name, mirroring
/// `device::presets::preset`.
pub fn spec(name: &str) -> Option<OptimizerSpec> {
    Method::parse(name).map(OptimizerSpec::new)
}

/// Registry lookup that reports the available names on failure — the
/// one error message every name-driven consumer shares.
pub fn spec_or_err(name: &str) -> Result<OptimizerSpec, String> {
    spec(name).ok_or_else(|| {
        format!("unknown method '{name}' (registry: {})", METHODS.join(", "))
    })
}

/// Validate a user-supplied method-name list against the registry,
/// expanding the shorthand `"all"` and dropping duplicates (first
/// occurrence wins, order preserved).
pub fn resolve_names(names: &[String]) -> Result<Vec<String>, String> {
    let mut out: Vec<String> = Vec::new();
    let mut push = |out: &mut Vec<String>, n: &str| {
        if !out.iter().any(|o| o == n) {
            out.push(n.to_string());
        }
    };
    for n in names {
        if n == "all" {
            for m in METHODS {
                push(&mut out, m);
            }
        } else {
            spec_or_err(n)?;
            push(&mut out, n);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::presets;
    use crate::optim::Quadratic;

    #[test]
    fn registry_covers_every_name() {
        for name in METHODS {
            let s = spec(name).expect(name);
            assert_eq!(s.method.name(), *name);
        }
        assert!(spec("nope").is_none());
    }

    #[test]
    fn all_const_mirrors_the_name_registry() {
        // Method::ALL and METHODS must stay in lock-step: same length,
        // same canonical order, round-tripping through parse/name
        assert_eq!(Method::ALL.len(), METHODS.len());
        for (m, name) in Method::ALL.iter().zip(METHODS) {
            assert_eq!(m.name(), *name);
            assert_eq!(Method::parse(name), Some(*m));
        }
    }

    #[test]
    fn every_method_builds_and_steps() {
        let preset = presets::preset("om").unwrap();
        for name in METHODS {
            let mut rng = Rng::from_seed(5);
            let obj = Quadratic::new(4, 1.0, 2.0, 0.3, &mut rng);
            let mut opt = spec(name).unwrap().build(4, &preset, 0.3, 0.1, 0.1, &mut rng);
            assert_eq!(opt.name(), *name, "registry name must round-trip");
            for _ in 0..5 {
                let l = opt.step(&obj, &mut rng);
                assert!(l.is_finite(), "{name}: non-finite loss");
            }
            assert_eq!(opt.weights().len(), 4);
            assert_eq!(opt.sp_reference().len(), 4);
        }
    }

    #[test]
    fn cli_flags_override_defaults() {
        let toks: Vec<String> = ["x", "--lr-fast", "0.77", "--flip-p", "0.5", "--zs-pulses", "42"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let args = Args::parse_tokens(&toks).unwrap();
        let mut s = spec("erider").unwrap();
        s.apply_args(&args);
        assert_eq!(s.lr_fast, 0.77);
        assert_eq!(s.flip_p, 0.5);
        assert_eq!(s.zs_pulses, 42);
        // untouched flags keep the method default
        assert_eq!(s.eta, RiderHypers::default().eta);
    }

    #[test]
    fn config_section_overrides_defaults() {
        let cfg = Config::parse("[optimizer]\nlr_transfer = 0.5\neta = 0.25\n").unwrap();
        let mut s = spec("rider").unwrap();
        s.apply_config(&cfg, "optimizer");
        assert_eq!(s.lr_transfer, 0.5);
        assert_eq!(s.eta, 0.25);
        assert_eq!(s.flip_p, 0.0, "rider stays chopper-free by default");
    }

    #[test]
    fn nn_mapping_covers_every_method() {
        // the NN-scale step suffix must be one of the lowered artifacts
        // (python/compile/algorithms.py STEPS) for every registry name
        let lowered = ["sgd", "ttv1", "ttv2", "agad", "erider", "digital"];
        for name in METHODS {
            let m = Method::parse(name).unwrap();
            assert!(
                lowered.contains(&m.nn_step_algo()),
                "{name}: step suffix {} has no artifact",
                m.nn_step_algo()
            );
        }
        // only the two-stage pipeline calibrates by default
        for name in METHODS {
            let m = Method::parse(name).unwrap();
            assert_eq!(m.nn_needs_zs(), *name == "residual", "{name}");
        }
    }

    #[test]
    fn resolve_expands_all_dedups_and_rejects_unknown() {
        let all = resolve_names(&["all".to_string()]).unwrap();
        assert_eq!(all.len(), METHODS.len());
        // "all" plus an explicit repeat must not double-run a method
        let deduped = resolve_names(&["erider".into(), "all".into()]).unwrap();
        assert_eq!(deduped.len(), METHODS.len());
        assert_eq!(deduped[0], "erider");
        assert!(resolve_names(&["ttv2".into(), "bogus".into()]).is_err());
    }
}
