//! Two-stage Residual Learning (paper Algorithm 4): ZS calibration of the
//! P-device SP first (N pulses), then residual training with the
//! reference frozen at the estimate (RIDER with eta = 0, flip_p = 0).
//! This is the theoretical baseline of Corollary 3.9: total pulse cost
//! O(K + N) = O(δ⁻² + δ⁻¹ Δw_min⁻¹) versus RIDER's O(δ⁻²).

use crate::analog::pulse_counter::PulseCost;
use crate::analog::rider::{Rider, RiderHypers};
use crate::analog::zs::{self, ZsVariant};
use crate::device::Preset;
use crate::optim::Objective;
use crate::util::rng::Rng;

pub struct TwoStageResidual {
    pub inner: Rider,
    pub calibration_pulses: u64,
}

impl TwoStageResidual {
    /// Build the optimizer and immediately run the ZS stage with
    /// `zs_pulses` pulse cycles on the P array.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        dim: usize,
        preset: &Preset,
        ref_mean: f64,
        ref_std: f64,
        mut hypers: RiderHypers,
        sigma: f64,
        zs_pulses: u64,
        rng: &mut Rng,
    ) -> Self {
        // stage 2 runs with the reference frozen
        hypers.eta = 0.0;
        hypers.flip_p = 0.0;
        let mut inner = Rider::new(dim, preset, ref_mean, ref_std, hypers, sigma, rng);
        // stage 1: ZS on the P device
        let before = inner.p.pulse_count;
        let res = zs::run(&mut inner.p, zs_pulses, ZsVariant::Cyclic, rng);
        inner.set_reference(res.estimate);
        let calibration_pulses = inner.p.pulse_count - before;
        Self {
            inner,
            calibration_pulses,
        }
    }

    pub fn step(&mut self, obj: &dyn Objective, rng: &mut Rng) -> f64 {
        self.inner.step(obj, rng)
    }

    pub fn cost(&self) -> PulseCost {
        let mut c = self.inner.cost();
        // ZS pulses were counted into p.pulse_count; reclassify them.
        c.update_pulses -= self.calibration_pulses;
        c.calibration_pulses = self.calibration_pulses;
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::presets;
    use crate::optim::Quadratic;
    use crate::util::stats;

    #[test]
    fn well_calibrated_two_stage_converges() {
        let mut rng = Rng::from_seed(1);
        let obj = Quadratic::new(16, 1.0, 4.0, 0.3, &mut rng);
        let mut opt = TwoStageResidual::new(
            16,
            &presets::preset("om").unwrap(),
            0.4,
            0.1,
            RiderHypers::default(),
            0.2,
            4000,
            &mut rng,
        );
        let mut losses = Vec::new();
        for _ in 0..5000 {
            losses.push(opt.step(&obj, &mut rng));
        }
        let tail = stats::mean(&losses[losses.len() - 200..]);
        let init = losses[0];
        assert!(tail < 0.4 * init, "init {init} tail {tail}");
    }

    #[test]
    fn calibration_pulses_accounted() {
        let mut rng = Rng::from_seed(2);
        let opt = TwoStageResidual::new(
            8,
            &presets::preset("om").unwrap(),
            0.3,
            0.1,
            RiderHypers::default(),
            0.1,
            100,
            &mut rng,
        );
        let c = opt.cost();
        assert_eq!(c.calibration_pulses, 100 * 8);
        assert_eq!(c.update_pulses, 0); // no training steps yet
    }

    #[test]
    fn poor_calibration_leaves_reference_error() {
        // Figure 2's mechanism: too few ZS pulses => reference error.
        let mut rng = Rng::from_seed(3);
        let few = TwoStageResidual::new(
            16,
            &presets::preset("precise").unwrap(),
            0.4,
            0.1,
            RiderHypers::default(),
            0.1,
            20,
            &mut rng,
        );
        let mut rng2 = Rng::from_seed(3);
        let many = TwoStageResidual::new(
            16,
            &presets::preset("precise").unwrap(),
            0.4,
            0.1,
            RiderHypers::default(),
            0.1,
            4000,
            &mut rng2,
        );
        assert!(
            many.inner.q_tracking_error() < few.inner.q_tracking_error(),
            "many {} few {}",
            many.inner.q_tracking_error(),
            few.inner.q_tracking_error()
        );
    }
}
