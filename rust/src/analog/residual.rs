//! Two-stage Residual Learning (paper Algorithm 4): ZS calibration of the
//! P-device SP first (N pulses), then residual training with the
//! reference frozen at the estimate (RIDER with eta = 0, flip_p = 0).
//! This is the theoretical baseline of Corollary 3.9: total pulse cost
//! O(K + N) = O(δ⁻² + δ⁻¹ Δw_min⁻¹) versus RIDER's O(δ⁻²).

use crate::analog::optimizer::AnalogOptimizer;
use crate::analog::pulse_counter::PulseCost;
use crate::analog::rider::{Rider, RiderHypers};
use crate::analog::zs::{self, ZsVariant};
use crate::device::Preset;
use crate::optim::Objective;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug)]
pub struct ResidualHypers {
    /// stage-2 residual-training hypers; eta and flip_p are forced to 0
    /// at construction (the reference stays frozen after stage 1)
    pub rider: RiderHypers,
    /// stage-1 ZS pulse-cycle budget on the P array
    pub zs_pulses: u64,
}

impl Default for ResidualHypers {
    fn default() -> Self {
        Self {
            rider: RiderHypers::default(),
            zs_pulses: 2000,
        }
    }
}

pub struct TwoStageResidual {
    pub inner: Rider,
    pub calibration_pulses: u64,
}

impl TwoStageResidual {
    /// Build the optimizer and immediately run the ZS stage with
    /// `hypers.zs_pulses` pulse cycles on the P array.
    pub fn new(
        dim: usize,
        preset: &Preset,
        ref_mean: f64,
        ref_std: f64,
        hypers: ResidualHypers,
        sigma: f64,
        rng: &mut Rng,
    ) -> Self {
        // stage 2 runs with the reference frozen
        let mut rh = hypers.rider;
        rh.eta = 0.0;
        rh.flip_p = 0.0;
        let mut inner = Rider::new(dim, preset, ref_mean, ref_std, rh, sigma, rng);
        // stage 1: ZS on the P device
        let before = inner.p.pulse_count;
        let res = zs::run(&mut inner.p, hypers.zs_pulses, ZsVariant::Cyclic, rng);
        inner.set_reference(res.estimate);
        let calibration_pulses = inner.p.pulse_count - before;
        Self {
            inner,
            calibration_pulses,
        }
    }
}

impl AnalogOptimizer for TwoStageResidual {
    fn step(&mut self, obj: &dyn Objective, rng: &mut Rng) -> f64 {
        self.inner.step(obj, rng)
    }

    fn weights(&mut self) -> &[f32] {
        self.inner.weights()
    }

    /// Replace the frozen reference (overrides the stage-1 ZS estimate).
    fn set_reference(&mut self, q: Vec<f32>) {
        self.inner.set_reference(q);
    }

    fn sp_reference(&self) -> &[f32] {
        self.inner.sp_reference()
    }

    fn cost(&self) -> PulseCost {
        let mut c = self.inner.cost();
        // ZS pulses were counted into p.pulse_count; reclassify them.
        c.update_pulses -= self.calibration_pulses;
        c.calibration_pulses = self.calibration_pulses;
        c
    }

    fn name(&self) -> &'static str {
        "residual"
    }

    fn sp_tracking_error(&self) -> Option<f64> {
        Some(self.inner.q_tracking_error())
    }

    fn convergence_metrics(&mut self, obj: &dyn Objective) -> Option<(f64, f64, f64)> {
        Some(self.inner.metrics(obj))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::presets;
    use crate::optim::Quadratic;
    use crate::util::stats;

    fn hypers(zs_pulses: u64) -> ResidualHypers {
        ResidualHypers {
            rider: RiderHypers::default(),
            zs_pulses,
        }
    }

    #[test]
    fn well_calibrated_two_stage_converges() {
        let mut rng = Rng::from_seed(1);
        let obj = Quadratic::new(16, 1.0, 4.0, 0.3, &mut rng);
        let mut opt = TwoStageResidual::new(
            16,
            &presets::preset("om").unwrap(),
            0.4,
            0.1,
            hypers(4000),
            0.2,
            &mut rng,
        );
        let mut losses = Vec::new();
        for _ in 0..5000 {
            losses.push(opt.step(&obj, &mut rng));
        }
        let tail = stats::mean(&losses[losses.len() - 200..]);
        let init = losses[0];
        assert!(tail < 0.4 * init, "init {init} tail {tail}");
    }

    #[test]
    fn calibration_pulses_accounted() {
        let mut rng = Rng::from_seed(2);
        let opt = TwoStageResidual::new(
            8,
            &presets::preset("om").unwrap(),
            0.3,
            0.1,
            hypers(100),
            0.1,
            &mut rng,
        );
        let c = opt.cost();
        assert_eq!(c.calibration_pulses, 100 * 8);
        assert_eq!(c.update_pulses, 0); // no training steps yet
    }

    #[test]
    fn poor_calibration_leaves_reference_error() {
        // Figure 2's mechanism: too few ZS pulses => reference error.
        let mut rng = Rng::from_seed(3);
        let few = TwoStageResidual::new(
            16,
            &presets::preset("precise").unwrap(),
            0.4,
            0.1,
            hypers(20),
            0.1,
            &mut rng,
        );
        let mut rng2 = Rng::from_seed(3);
        let many = TwoStageResidual::new(
            16,
            &presets::preset("precise").unwrap(),
            0.4,
            0.1,
            hypers(4000),
            0.1,
            &mut rng2,
        );
        assert!(
            many.inner.q_tracking_error() < few.inner.q_tracking_error(),
            "many {} few {}",
            many.inner.q_tracking_error(),
            few.inner.q_tracking_error()
        );
    }
}
