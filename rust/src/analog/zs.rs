//! Algorithm 1: the zero-shifting (ZS) SP-estimation procedure
//! (Kim et al., 2019), in both the stochastic variant analysed by
//! Theorem 2.2 and the cyclic variant of Appendix C.3/C.4.
//!
//! ZS sends alternating up/down pulses; the asymmetric component G drives
//! the weight towards the device SP, so after N pulses the read-out is an
//! SP estimate. Pulse accounting is exact (DeviceArray counts pulses).

use crate::device::{DeviceArray, TiledArray};
use crate::util::rng::Rng;
use crate::util::stats;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ZsVariant {
    /// ε_n uniformly ±Δw_min per cell (Algorithm 1 as analysed).
    Stochastic,
    /// strict up/down alternation (the hardware implementation).
    Cyclic,
}

/// Result of a calibration run.
#[derive(Clone, Debug)]
pub struct ZsResult {
    /// per-cell SP estimates (final read-out)
    pub estimate: Vec<f32>,
    /// per-cell ground-truth SPs
    pub truth: Vec<f32>,
    /// pulses spent
    pub pulses: u64,
    /// trajectory of mean ||G(W_n)||^2 (Theorem 2.2 metric), sampled
    /// every `sample_every` cycles
    pub g_sq_trace: Vec<f64>,
}

impl ZsResult {
    /// Offset of the estimated mean from the true mean (Fig. 1a).
    pub fn mean_offset(&self) -> f64 {
        let est: Vec<f64> = self.estimate.iter().map(|&x| x as f64).collect();
        let tru: Vec<f64> = self.truth.iter().map(|&x| x as f64).collect();
        stats::mean(&tru) - stats::mean(&est)
    }

    /// Offset of the estimated std from the true std (Fig. 1a).
    pub fn std_offset(&self) -> f64 {
        let est: Vec<f64> = self.estimate.iter().map(|&x| x as f64).collect();
        let tru: Vec<f64> = self.truth.iter().map(|&x| x as f64).collect();
        stats::std(&tru) - stats::std(&est)
    }

    /// Relative error of the estimated mean (Fig. 1b criterion).
    pub fn rel_mean_error(&self) -> f64 {
        let est: Vec<f64> = self.estimate.iter().map(|&x| x as f64).collect();
        let tru: Vec<f64> = self.truth.iter().map(|&x| x as f64).collect();
        let tm = stats::mean(&tru);
        if tm.abs() < 1e-12 {
            return (stats::mean(&est) - tm).abs();
        }
        ((stats::mean(&est) - tm) / tm).abs()
    }

    /// Mean absolute per-cell estimation error.
    pub fn mean_abs_error(&self) -> f64 {
        self.estimate
            .iter()
            .zip(&self.truth)
            .map(|(e, t)| (e - t).abs() as f64)
            .sum::<f64>()
            / self.estimate.len() as f64
    }
}

/// Run ZS for `n_pulses` pulse cycles on the array (mutates it).
pub fn run(
    arr: &mut DeviceArray,
    n_pulses: u64,
    variant: ZsVariant,
    rng: &mut Rng,
) -> ZsResult {
    let truth = arr.symmetric_points();
    let before = arr.pulse_count;
    let sample_every = (n_pulses / 64).max(1);
    let mut trace = Vec::new();
    for k in 0..n_pulses {
        match variant {
            ZsVariant::Stochastic => arr.pulse_all_random(rng),
            ZsVariant::Cyclic => arr.pulse_all(k % 2 == 0, rng),
        }
        if k % sample_every == 0 {
            trace.push(arr.mean_g_sq());
        }
    }
    let res = ZsResult {
        estimate: arr.w.clone(),
        truth,
        pulses: arr.pulse_count - before,
        g_sq_trace: trace,
    };
    if crate::util::metrics::enabled() {
        crate::util::metrics::gauge(
            crate::util::metrics::MetricId::DeviceSpDrift,
            res.mean_abs_error(),
        );
    }
    res
}

/// Selective re-calibration of a tiled array: run ZS on the listed
/// tiles only (the recovery layer's response to detected faults) and
/// return the pulses spent. One `base` is drawn from the caller's
/// stream; tile `k` recalibrates from the sub-stream `Rng::new(base,
/// k)` — the standard fan-out derivation — so the result is
/// independent of the order and grouping of recovery batches with the
/// same base. An empty tile list consumes no randomness.
pub fn recalibrate_tiles(
    arr: &mut TiledArray,
    tiles: &[usize],
    n_pulses: u64,
    variant: ZsVariant,
    rng: &mut Rng,
) -> u64 {
    if tiles.is_empty() {
        return 0;
    }
    let base = rng.next_u64();
    let mut spent = 0u64;
    for &k in tiles {
        let mut sub = Rng::new(base, k as u64);
        let res = run(arr.tile_mut(k), n_pulses, variant, &mut sub);
        spent += res.pulses;
    }
    spent
}

/// Smallest pulse budget (from a doubling schedule) whose relative
/// SP-mean error is below `target` — the Fig. 1b measurement.
pub fn pulses_to_target(
    make_array: impl Fn(&mut Rng) -> DeviceArray,
    target_rel_err: f64,
    schedule: &[u64],
    variant: ZsVariant,
    seed: u64,
) -> Option<(u64, f64)> {
    for &n in schedule {
        let mut rng = Rng::new(seed, n);
        let mut arr = make_array(&mut rng);
        let res = run(&mut arr, n, variant, &mut rng);
        let err = res.rel_mean_error();
        if err <= target_rel_err {
            return Some((n, err));
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::presets;
    use crate::device::response::{Response, SoftBounds};

    #[test]
    fn zs_converges_to_sp_uniform_device() {
        let dev = SoftBounds::from_gamma_rho(1.0, 0.25);
        let sp = dev.symmetric_point();
        let mut arr = DeviceArray::uniform(8, 8, &dev, 0.005, 0.0);
        let mut rng = Rng::from_seed(1);
        let res = run(&mut arr, 4000, ZsVariant::Stochastic, &mut rng);
        // per-cell spread of the stochastic variant is Theta(sqrt(dw_min))
        assert!(res.mean_abs_error() < 0.1, "{}", res.mean_abs_error());
        // ... but the array mean is tight
        let est_mean = res.estimate.iter().map(|&x| x as f64).sum::<f64>()
            / res.estimate.len() as f64;
        assert!((est_mean - sp).abs() < 0.03, "{est_mean} vs {sp}");
        assert_eq!(res.pulses, 4000 * 64);
    }

    #[test]
    fn cyclic_matches_stochastic_scale() {
        let dev = SoftBounds::from_gamma_rho(1.0, 0.2);
        let mut rng = Rng::from_seed(2);
        let mut a1 = DeviceArray::uniform(4, 4, &dev, 0.01, 0.0);
        let mut a2 = a1.clone();
        let r1 = run(&mut a1, 2000, ZsVariant::Stochastic, &mut rng);
        let r2 = run(&mut a2, 2000, ZsVariant::Cyclic, &mut rng);
        assert!(r1.mean_abs_error() < 0.15, "{}", r1.mean_abs_error());
        // the cyclic variant cancels the random-walk term: tighter
        assert!(r2.mean_abs_error() < 0.05, "{}", r2.mean_abs_error());
    }

    #[test]
    fn g_sq_decreases() {
        // Theorem 2.2: average ||G||^2 shrinks towards the Θ(Δw) floor.
        let mut rng = Rng::from_seed(3);
        let mut arr = DeviceArray::sample(
            16, 16, &presets::preset("precise").unwrap(), 0.3, 0.2, 0.1, &mut rng,
        );
        let res = run(&mut arr, 3000, ZsVariant::Stochastic, &mut rng);
        let first = res.g_sq_trace[0];
        let last = *res.g_sq_trace.last().unwrap();
        assert!(last < 0.2 * first, "first {first} last {last}");
    }

    #[test]
    fn recalibrate_tiles_touches_only_listed_tiles() {
        use crate::device::TileGeometry;
        let geom = TileGeometry::new(16, 16).unwrap();
        let mut arr = TiledArray::sample(
            32,
            32,
            geom,
            &presets::preset("om").unwrap(),
            0.3,
            0.1,
            0.1,
            &mut Rng::from_seed(5),
        );
        let before: Vec<u64> = (0..4).map(|k| arr.tile(k).pulse_count).collect();
        let mut rng = Rng::from_seed(6);
        let spent = recalibrate_tiles(&mut arr, &[1, 3], 100, ZsVariant::Cyclic, &mut rng);
        assert_eq!(spent, 2 * 100 * 256);
        for k in [0usize, 2] {
            assert_eq!(arr.tile(k).pulse_count, before[k], "tile {k} untouched");
        }
        for k in [1usize, 3] {
            assert_eq!(arr.tile(k).pulse_count, before[k] + 100 * 256, "tile {k}");
        }
        // empty work list: free and draws nothing
        let mut r1 = Rng::from_seed(9);
        assert_eq!(recalibrate_tiles(&mut arr, &[], 100, ZsVariant::Cyclic, &mut r1), 0);
        assert_eq!(r1.next_u64(), Rng::from_seed(9).next_u64());
    }

    #[test]
    fn more_pulses_better_estimate() {
        let mk = |rng: &mut Rng| {
            DeviceArray::sample(
                16, 16, &presets::preset("precise").unwrap(), 0.4, 0.1, 0.1, rng,
            )
        };
        let mut errs = Vec::new();
        for &n in &[50u64, 500, 5000] {
            let mut rng = Rng::new(7, n);
            let mut arr = mk(&mut rng);
            let res = run(&mut arr, n, ZsVariant::Stochastic, &mut rng);
            errs.push(res.mean_abs_error());
        }
        assert!(errs[2] < errs[0], "{errs:?}");
    }
}
