//! RIDER / E-RIDER (paper Algorithms 2 and 3) — the contribution.
//!
//! Three sequences:
//!   P  (analog)  — residual array; absorbs the stochastic gradient and,
//!                  through the |·|G term, is *attracted to its own SP*;
//!   Q  (digital) — moving average of P reads (Eq. 12): a first-order
//!                  low-pass filter (Lemma 3.10) that isolates the
//!                  low-frequency SP drift => Q tracks the SP;
//!   W  (analog)  — main array, updated by the zero-shifted residual
//!                  β c (P - Q) (Eq. 18b).
//! The chopper c (Eq. 17) moves the gradient component of P's update into
//! the high-frequency band so the filter separates it from the SP drift;
//! the analog shadow Q~ is re-programmed from digital Q only on chopper
//! flips (programming cost accounting below).

use crate::analog::optimizer::AnalogOptimizer;
use crate::analog::pulse_counter::PulseCost;
use crate::device::{DeviceArray, Preset};
use crate::optim::Objective;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug)]
pub struct RiderHypers {
    /// alpha — P array learning rate
    pub lr_fast: f64,
    /// beta — W transfer learning rate
    pub lr_transfer: f64,
    /// eta — Q moving-average stepsize (Eq. 12)
    pub eta: f64,
    /// gamma — residual scale in W-bar (Eq. 8)
    pub gamma: f64,
    /// chopper flip probability p (Eq. 17); 0 => RIDER
    pub flip_p: f64,
    /// analog read-out noise std
    pub read_noise: f64,
}

impl Default for RiderHypers {
    fn default() -> Self {
        Self {
            lr_fast: 0.3,
            lr_transfer: 0.02,
            eta: 0.005,
            gamma: 0.3,
            flip_p: 0.02,
            read_noise: 0.005,
        }
    }
}

pub struct Rider {
    pub p: DeviceArray,
    pub w: DeviceArray,
    /// digital SP-tracking sequence Q_k
    pub q: Vec<f32>,
    /// chopper sign c_k
    pub c: f64,
    pub hypers: RiderHypers,
    pub sigma: f64,
    pub programming_events: u64,
    /// registry name; inferred from `flip_p` at construction, pinned by
    /// the spec builder so hyper overrides don't relabel the method
    name: &'static str,
    wbar_buf: Vec<f32>,
    grad_buf: Vec<f32>,
    dw_buf: Vec<f32>,
    read_buf: Vec<f32>,
}

impl Rider {
    pub fn new(
        dim: usize,
        preset: &Preset,
        ref_mean: f64,
        ref_std: f64,
        hypers: RiderHypers,
        sigma: f64,
        rng: &mut Rng,
    ) -> Self {
        Self {
            p: DeviceArray::sample(1, dim, preset, ref_mean, ref_std, 0.1, rng),
            w: DeviceArray::sample(1, dim, preset, ref_mean, ref_std, 0.1, rng),
            q: vec![0.0; dim],
            c: 1.0,
            name: if hypers.flip_p > 0.0 { "erider" } else { "rider" },
            hypers,
            sigma,
            programming_events: 0,
            wbar_buf: vec![0.0; dim],
            grad_buf: vec![0.0; dim],
            dw_buf: vec![0.0; dim],
            read_buf: vec![0.0; dim],
        }
    }

    /// Pin the registry name (used by `OptimizerSpec::build`).
    pub fn with_name(mut self, name: &'static str) -> Self {
        self.name = name;
        self
    }

    /// Recompute W-bar = W + gamma c (P - Q) into the scratch buffer.
    /// Kept separate from [`Rider::wbar`] so `step` can borrow the
    /// buffer alongside other fields without cloning it.
    fn compute_wbar(&mut self) {
        let g = (self.hypers.gamma * self.c) as f32;
        for i in 0..self.q.len() {
            self.wbar_buf[i] = self.w.w[i] + g * (self.p.w[i] - self.q[i]);
        }
    }

    /// Effective weights W-bar = W + gamma c (P - Q).
    pub fn wbar(&mut self) -> &[f32] {
        self.compute_wbar();
        &self.wbar_buf
    }

    /// ||Q - SP(P-device)||_mean — the SP-tracking error (Lemma 3.5).
    pub fn q_tracking_error(&self) -> f64 {
        let sps = self.p.symmetric_points();
        self.q
            .iter()
            .zip(&sps)
            .map(|(q, s)| (q - s).abs() as f64)
            .sum::<f64>()
            / self.q.len() as f64
    }

    /// Convergence metric terms of Eq. (14).
    pub fn metrics(&mut self, obj: &dyn Objective) -> (f64, f64, f64) {
        let w_err = match obj.optimum() {
            Some(ws) => {
                self.compute_wbar();
                self.wbar_buf
                    .iter()
                    .zip(&ws)
                    .map(|(a, b)| ((a - b) as f64).powi(2))
                    .sum::<f64>()
            }
            None => f64::NAN,
        };
        let pq = self
            .p
            .w
            .iter()
            .zip(&self.q)
            .map(|(p, q)| ((p - q) as f64).powi(2))
            .sum::<f64>();
        let g_sq = self.p.mean_g_sq() * self.p.len() as f64;
        (w_err, pq, g_sq)
    }
}

impl AnalogOptimizer for Rider {
    /// One E-RIDER iteration (Algorithm 3). Returns loss at W-bar.
    fn step(&mut self, obj: &dyn Objective, rng: &mut Rng) -> f64 {
        let h = self.hypers;
        // 1. chopper draw; on flip, the analog shadow Q~ is re-programmed
        //    from the digital Q (cost: one programming event per cell).
        if h.flip_p > 0.0 && rng.bernoulli(h.flip_p) {
            self.c = -self.c;
            self.programming_events += self.q.len() as u64;
        }
        // 2. gradient at W-bar (the buffer and grad_buf are disjoint
        //    fields, so no clone is needed to borrow both)
        self.compute_wbar();
        let loss = obj.loss(&self.wbar_buf);
        obj.noisy_grad(&self.wbar_buf, self.sigma, rng, &mut self.grad_buf);
        // 3. P <- AnalogUpdate(P, -alpha c g)      (Eq. 18a)
        let ac = (h.lr_fast * self.c) as f32;
        for (d, g) in self.dw_buf.iter_mut().zip(&self.grad_buf) {
            *d = -ac * *g;
        }
        self.p.analog_update(&self.dw_buf, rng);
        // 4. read P into the scratch buffer (allocation-free);
        //    Q <- (1-eta) Q + eta r                 (Eq. 12, digital)
        self.p.read_into(h.read_noise, rng, &mut self.read_buf);
        let eta = h.eta as f32;
        // 5. W <- AnalogUpdate(W, beta c (r - Q_k)) (Eq. 18b, uses old Q)
        let bc = (h.lr_transfer * self.c) as f32;
        for i in 0..self.read_buf.len() {
            let r = self.read_buf[i];
            self.dw_buf[i] = bc * (r - self.q[i]);
            self.q[i] = (1.0 - eta) * self.q[i] + eta * r;
        }
        self.w.analog_update(&self.dw_buf, rng);
        loss
    }

    /// The logical weight is W-bar (what the forward pass sees), not the
    /// raw W array.
    fn weights(&mut self) -> &[f32] {
        self.wbar()
    }

    /// Pre-set Q (two-stage Residual Learning uses a ZS estimate here,
    /// then freezes it with eta = 0).
    fn set_reference(&mut self, q: Vec<f32>) {
        assert_eq!(q.len(), self.q.len());
        self.q = q;
    }

    fn sp_reference(&self) -> &[f32] {
        &self.q
    }

    fn cost(&self) -> PulseCost {
        PulseCost {
            update_pulses: self.p.pulse_count + self.w.pulse_count,
            programming_events: self.programming_events,
            digital_ops: self.q.len() as u64,
            ..Default::default()
        }
    }

    fn name(&self) -> &'static str {
        self.name
    }

    fn sp_tracking_error(&self) -> Option<f64> {
        Some(self.q_tracking_error())
    }

    /// Chaos-layer seam: stream 0 faults the fast array P, stream 1
    /// the slow array W.
    fn arm_faults(&mut self, plan: &crate::device::fault::FaultPlan) {
        plan.arm_array(&mut self.p, 0);
        plan.arm_array(&mut self.w, 1);
    }

    fn convergence_metrics(&mut self, obj: &dyn Objective) -> Option<(f64, f64, f64)> {
        Some(self.metrics(obj))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::presets;
    use crate::optim::Quadratic;
    use crate::util::stats;

    fn quad(dim: usize, rng: &mut Rng) -> Quadratic {
        Quadratic::new(dim, 1.0, 4.0, 0.3, rng)
    }

    #[test]
    fn converges_under_nonzero_sp() {
        let mut rng = Rng::from_seed(1);
        let obj = quad(16, &mut rng);
        let mut opt = Rider::new(
            16,
            &presets::preset("om").unwrap(),
            0.5,
            0.2,
            RiderHypers::default(),
            0.2,
            &mut rng,
        );
        let mut losses = Vec::new();
        for _ in 0..5000 {
            losses.push(opt.step(&obj, &mut rng));
        }
        let init = losses[0];
        let tail = stats::mean(&losses[losses.len() - 200..]);
        assert!(tail < 0.35 * init, "init {init} tail {tail}");
    }

    #[test]
    fn q_tracks_sp() {
        // Lemma 3.5 / Theorem 3.7: the tracking error shrinks decisively
        // from its initial value (Q starts at 0, SPs near 0.5).
        let mut rng = Rng::from_seed(2);
        let obj = quad(16, &mut rng);
        let mut opt = Rider::new(
            16,
            &presets::preset("om").unwrap(),
            0.5,
            0.1,
            RiderHypers {
                lr_fast: 0.3,
                eta: 0.01,
                flip_p: 0.1,
                ..Default::default()
            },
            0.3,
            &mut rng,
        );
        let init_err = opt.q_tracking_error();
        for _ in 0..4000 {
            opt.step(&obj, &mut rng);
        }
        let final_err = opt.q_tracking_error();
        assert!(
            final_err < 0.5 * init_err,
            "init {init_err} final {final_err}"
        );
    }

    #[test]
    fn chopper_flip_probability_respected() {
        let mut rng = Rng::from_seed(3);
        let obj = quad(4, &mut rng);
        let mut opt = Rider::new(
            4,
            &presets::preset("ideal").unwrap(),
            0.0,
            0.0,
            RiderHypers {
                flip_p: 0.5,
                ..Default::default()
            },
            0.1,
            &mut rng,
        );
        let mut flips = 0;
        let mut prev = opt.c;
        for _ in 0..2000 {
            opt.step(&obj, &mut rng);
            if opt.c != prev {
                flips += 1;
                prev = opt.c;
            }
        }
        let rate = flips as f64 / 2000.0;
        assert!((rate - 0.5).abs() < 0.05, "{rate}");
        // every flip costs dim programming events
        assert_eq!(opt.programming_events, flips * 4);
    }

    #[test]
    fn rider_no_flips_when_p_zero() {
        let mut rng = Rng::from_seed(4);
        let obj = quad(4, &mut rng);
        let mut opt = Rider::new(
            4,
            &presets::preset("om").unwrap(),
            0.2,
            0.1,
            RiderHypers {
                flip_p: 0.0,
                ..Default::default()
            },
            0.1,
            &mut rng,
        );
        for _ in 0..200 {
            opt.step(&obj, &mut rng);
        }
        assert_eq!(opt.c, 1.0);
        assert_eq!(opt.programming_events, 0);
        assert_eq!(opt.name(), "rider");
    }

    #[test]
    fn beats_analog_sgd_under_offset() {
        // the headline ordering at theory scale: RIDER's compensated
        // iterate ends closer to the optimum than raw analog SGD when the
        // SP is far from 0 and gradients are noisy.
        use crate::analog::sgd::{AnalogSgd, SgdHypers};
        let mut rng = Rng::from_seed(5);
        let obj = Quadratic {
            lambda: vec![1.0; 8],
            w_star: vec![0.1; 8],
        };
        let preset = presets::preset("om").unwrap();
        let mut sgd = AnalogSgd::new(
            8,
            &preset,
            0.7,
            0.05,
            SgdHypers { lr: 0.05 },
            0.5,
            &mut rng,
        );
        let mut rider = Rider::new(
            8,
            &preset,
            0.7,
            0.05,
            RiderHypers::default(),
            0.5,
            &mut rng,
        );
        for _ in 0..5000 {
            sgd.step(&obj, &mut rng);
            rider.step(&obj, &mut rng);
        }
        let dist = |w: &[f32]| {
            w.iter()
                .zip(&obj.w_star)
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
        };
        let d_sgd = dist(sgd.weights());
        let d_rider = dist(rider.wbar());
        assert!(
            d_rider < d_sgd,
            "rider {d_rider} should beat sgd {d_sgd} under SP offset"
        );
    }
}
