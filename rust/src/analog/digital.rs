//! Digital SGD — the noise-free baseline arm of the registry (the
//! paper's "Digital" rows in Tables 1/2 and the pre-training stage of
//! the Table 8 protocol). No device substrate, no pulses: every update
//! is an exact float write, accounted as `digital_ops` so the Fig. 4
//! pulse comparisons show it as a zero-pulse floor.

use crate::analog::optimizer::AnalogOptimizer;
use crate::analog::pulse_counter::PulseCost;
use crate::optim::Objective;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug)]
pub struct DigitalHypers {
    /// learning rate of the exact SGD update
    pub lr: f64,
}

impl Default for DigitalHypers {
    fn default() -> Self {
        Self { lr: 0.05 }
    }
}

/// Exact SGD on a plain float vector: the upper-bound / floor baseline
/// the analog family is compared against.
pub struct DigitalSgd {
    w: Vec<f32>,
    hypers: DigitalHypers,
    /// gradient-noise scale of the stochastic oracle (kept: the noise
    /// models the data, not the hardware)
    sigma: f64,
    /// inspectable reference slot for trait parity; digital needs none
    q: Vec<f32>,
    grad_buf: Vec<f32>,
    digital_ops: u64,
}

impl DigitalSgd {
    pub fn new(dim: usize, hypers: DigitalHypers, sigma: f64) -> Self {
        Self {
            w: vec![0.0; dim],
            hypers,
            sigma,
            q: vec![0.0; dim],
            grad_buf: vec![0.0; dim],
            digital_ops: 0,
        }
    }
}

impl AnalogOptimizer for DigitalSgd {
    fn step(&mut self, obj: &dyn Objective, rng: &mut Rng) -> f64 {
        let loss = obj.loss(&self.w);
        obj.noisy_grad(&self.w, self.sigma, rng, &mut self.grad_buf);
        for (w, g) in self.w.iter_mut().zip(&self.grad_buf) {
            *w -= (self.hypers.lr * *g as f64) as f32;
        }
        self.digital_ops += self.w.len() as u64;
        loss
    }

    fn weights(&mut self) -> &[f32] {
        &self.w
    }

    fn set_reference(&mut self, q: Vec<f32>) {
        assert_eq!(q.len(), self.q.len());
        self.q = q;
    }

    fn sp_reference(&self) -> &[f32] {
        &self.q
    }

    fn cost(&self) -> PulseCost {
        PulseCost {
            digital_ops: self.digital_ops,
            ..Default::default()
        }
    }

    fn name(&self) -> &'static str {
        "digital"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::Quadratic;
    use crate::util::stats;

    #[test]
    fn converges_and_counts_no_pulses() {
        let mut rng = Rng::from_seed(4);
        let obj = Quadratic::new(16, 1.0, 4.0, 0.3, &mut rng);
        let mut opt = DigitalSgd::new(16, DigitalHypers::default(), 0.01);
        let mut losses = Vec::new();
        for _ in 0..2000 {
            losses.push(opt.step(&obj, &mut rng));
        }
        let head = stats::mean(&losses[..50]);
        let tail = stats::mean(&losses[losses.len() - 50..]);
        assert!(tail < 0.05 * head, "head {head} tail {tail}");
        let c = opt.cost();
        assert_eq!(c.total_pulses(), 0, "digital must be pulse-free");
        assert!(c.digital_ops > 0);
    }
}
