//! Experiment coordination: run directories, metric sinks, sweeps, and
//! the per-figure/table experiment harness.

#![warn(missing_docs)]

pub mod experiments;
pub mod metrics;
pub mod sweep;

pub use metrics::RunDir;
