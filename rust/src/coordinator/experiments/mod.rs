//! One module per paper figure/table (DESIGN.md section 4 index).

pub mod faults;
pub mod fig1;
pub mod theory;
pub mod training;
