//! `rider faultsweep` — the chaos-layer experiment: registry methods x
//! fault families x rates, each run three ways (clean, faulted, and
//! faulted with self-healing recovery). The axis the sweep is built to
//! show: ZS-precalibrated pipelines (`residual`) bake their reference
//! in once and lose more accuracy under post-calibration faults (drift
//! in particular) than the SP-tracking methods (`rider`, `erider`) at
//! the same pulse budget — and budgeted recovery (rewind to the last
//! healthy checkpoint + selective ZS recalibration of the affected
//! tiles) recoups part of the gap at a pulse cost the table reports.

use anyhow::Result;

use crate::coordinator::experiments::training::{data_for, ExpCtx};
use crate::coordinator::metrics::RunDir;
use crate::coordinator::sweep::Cell;
use crate::data::Batcher;
use crate::device::fault::{FaultFamily, FaultPlan};
use crate::train::fault::{LossSpikeMonitor, NnFaultInjector, RecoveryPolicy};
use crate::train::{TrainConfig, Trainer};
use crate::util::table::Table;

/// Default method set: one ZS-precalibrated pipeline against the
/// paper's SP-tracking methods.
pub const DEFAULT_METHODS: &[&str] = &["residual", "rider", "erider"];

/// Default fault families for the sweep (the two most distinct
/// degradation shapes: gradual retention drift vs hard stuck cells).
pub const DEFAULT_FAMILIES: &[FaultFamily] =
    &[FaultFamily::DriftToSp, FaultFamily::StuckAtBound];

/// One training run under an armed fault plan, optionally with the
/// self-healing loop. Returns (test acc %, recovery pulses,
/// recoveries). Detection combines the spike monitor with an
/// EMA-degradation check (gradual drift never "spikes"); recovery
/// rewinds to the last healthy checkpoint, recalibrates only the
/// affected tiles, and re-applies the (persistent) defects.
fn run_one(
    ctx: &ExpCtx,
    mut cfg: TrainConfig,
    plan: &FaultPlan,
    policy: &RecoveryPolicy,
    recover: bool,
    seed: u64,
) -> Result<(f64, u64, u32)> {
    cfg.seed = seed;
    cfg.steps = ctx.steps;
    let train = data_for(&cfg.model, 320, seed ^ 0xDA7A);
    let test = data_for(&cfg.model, 200, seed ^ 0x7E57);
    let spec = ctx.reg.model(&cfg.model)?;
    let dev = cfg.dev;
    let mut t = Trainer::new(ctx.exec, ctx.reg, cfg)?;
    let inj = NnFaultInjector::compile(plan, spec, &t.state, &dev);
    // defects exist from step zero
    inj.apply(&mut t.state);
    let mut batcher = Batcher::new(train.n, spec.batch, seed ^ 0xB00C);
    let (mut x, mut y) = (Vec::new(), Vec::new());
    let mut monitor = LossSpikeMonitor::new(2.5, 10);
    let mut best_ema = f64::INFINITY;
    let mut good = t.checkpoint(0);
    let mut recoveries = 0u32;
    let mut last_rec = 0usize;
    let mut recovery_pulses = 0u64;
    for k in 0..ctx.steps {
        batcher.next_batch(&train, &mut x, &mut y);
        let loss = t.step(&x, &y)?;
        inj.apply(&mut t.state);
        let spiked = monitor.observe(loss);
        let ema = monitor.ema();
        if ema.is_finite() && ema < best_ema {
            best_ema = ema;
            if k % 10 == 0 {
                good = t.checkpoint(k as u64);
            }
        }
        let degraded =
            spiked || (k > 20 && ema.is_finite() && ema > 1.3 * best_ema);
        if recover
            && degraded
            && !inj.is_empty()
            && policy.allows(recoveries, k - last_rec)
        {
            t.restore(&good);
            recovery_pulses +=
                t.recalibrate_tiles(inj.affected_tiles(), policy.zs_pulses)?;
            inj.apply(&mut t.state);
            recoveries += 1;
            last_rec = k;
            good = t.checkpoint(k as u64);
            monitor = LossSpikeMonitor::new(2.5, 10);
            best_ema = f64::INFINITY;
        }
    }
    let (_, acc) = t.eval(&test)?;
    Ok((acc, recovery_pulses, recoveries))
}

fn base_cfg(model: &str, method: &str) -> Result<TrainConfig> {
    let mut cfg = TrainConfig::by_name(model, method)?;
    cfg.ref_mean = 0.4;
    cfg.ref_std = 0.2;
    Ok(cfg)
}

/// The sweep: methods x families x rates, seeds averaged. Every row
/// reports the clean baseline, the faulted accuracy, the self-healed
/// accuracy and what the healing cost in ZS pulses.
pub fn faultsweep(
    ctx: &ExpCtx,
    model: &str,
    methods: &[String],
    families: &[FaultFamily],
    rates: &[f64],
    policy: &RecoveryPolicy,
) -> Result<Table> {
    let rd = RunDir::create("faultsweep")?;
    let mut t = Table::new(
        &format!(
            "Fault sweep: test accuracy (model {model}, {} steps, \
             {} seed(s); recovery budget {} ZS pulses/tile)",
            ctx.steps,
            ctx.seeds.len(),
            policy.zs_pulses
        ),
        &[
            "method",
            "family",
            "rate",
            "clean %",
            "faulted %",
            "healed %",
            "recoveries",
            "recovery pulses",
        ],
    );
    for m in methods {
        let mut clean = Cell::default();
        for &seed in &ctx.seeds {
            let plan = FaultPlan::none(seed);
            let (acc, _, _) = run_one(ctx, base_cfg(model, m)?, &plan, policy, false, seed)?;
            clean.samples.push(acc);
        }
        for &fam in families {
            for &rate in rates {
                let mut faulted = Cell::default();
                let mut healed = Cell::default();
                let mut recs = 0u32;
                let mut pulses = 0u64;
                for &seed in &ctx.seeds {
                    let plan = FaultPlan::of(seed ^ 0xFA17, fam, rate);
                    let (a, _, _) =
                        run_one(ctx, base_cfg(model, m)?, &plan, policy, false, seed)?;
                    faulted.samples.push(a);
                    let (a, p, r) =
                        run_one(ctx, base_cfg(model, m)?, &plan, policy, true, seed)?;
                    healed.samples.push(a);
                    recs += r;
                    pulses += p;
                }
                t.row(vec![
                    m.clone(),
                    fam.name().into(),
                    format!("{rate}"),
                    clean.pm(),
                    faulted.pm(),
                    healed.pm(),
                    recs.to_string(),
                    pulses.to_string(),
                ]);
            }
        }
    }
    rd.write_table("faultsweep", &t)?;
    Ok(t)
}
