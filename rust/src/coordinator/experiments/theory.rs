//! Theory validation: Theorem 2.2 (ZS rate + Θ(Δw) floor), Theorem C.2
//! (last-iterate geometric convergence), Theorem 3.7 (RIDER O(1/sqrt K)
//! on a strongly convex quadratic), Corollary 3.9 (pulse-complexity
//! crossover across the whole method family), Lemma 3.10 (filter
//! response).
//!
//! The cross-method comparisons are name-driven through the optimizer
//! registry (`analog::optimizer`): `rider theory --method a,b,...`
//! selects which family members appear in the Cor 3.9 table.

use crate::analog::optimizer::{self, AnalogOptimizer as _};
use crate::analog::zs::{self, ZsVariant};
use crate::coordinator::metrics::RunDir;
use crate::device::{presets, DeviceArray};
use crate::optim::Quadratic;
use crate::util::rng::Rng;
use crate::util::stats;
use crate::util::table::Table;

/// Methods the Cor 3.9 comparison runs when `--method` is not given:
/// the paper's headline pair.
pub const DEFAULT_METHODS: &[&str] = &["erider", "residual"];

/// Run every theory-validation table (`methods` selects the Cor 3.9
/// family members) and write them under `runs/theory/`.
pub fn run(seed: u64, methods: &[String]) -> anyhow::Result<Vec<Table>> {
    let rd = RunDir::create("theory")?;
    let mut out = Vec::new();

    // --- Theorem 2.2: avg ||G||^2 vs N, and the Θ(Δw_min) floor
    let mut t1 = Table::new(
        "Thm 2.2: ZS average ||G(W_n)||^2 vs N (precise device)",
        &["N", "avg ||G||^2", "floor est"],
    );
    for &n in &[250u64, 1000, 4000, 16000] {
        let mut rng = Rng::new(seed, n);
        let mut arr =
            DeviceArray::sample(32, 32, &presets::PRECISE, 0.3, 0.2, 0.1, &mut rng);
        let res = zs::run(&mut arr, n, ZsVariant::Stochastic, &mut rng);
        let avg = stats::mean(&res.g_sq_trace);
        let floor = *res.g_sq_trace.last().unwrap();
        t1.row(vec![n.to_string(), format!("{avg:.5}"), format!("{floor:.5}")]);
    }
    rd.write_table("thm22", &t1)?;
    out.push(t1);

    // --- Theorem C.2: last-iterate error is geometric in N
    let mut t2 = Table::new(
        "Thm C.2: last-iterate |w - sp| vs N (uniform monotone device)",
        &["N", "mean |w - sp|"],
    );
    for &n in &[50u64, 200, 800, 3200] {
        let dev = crate::device::SoftBounds::from_gamma_rho(1.0, 0.3);
        let mut arr = DeviceArray::uniform(16, 16, &dev, 1e-3, 0.0);
        let mut rng = Rng::new(seed, n);
        let res = zs::run(&mut arr, n, ZsVariant::Cyclic, &mut rng);
        t2.row(vec![n.to_string(), format!("{:.5}", res.mean_abs_error())]);
    }
    rd.write_table("thmC2", &t2)?;
    out.push(t2);

    // --- Theorem 3.7: E-RIDER error metric E_K ~ O(1/sqrt(K)) + floor,
    //     built by name so the Eq. 14 terms come through the trait.
    let mut t3 = Table::new(
        "Thm 3.7: RIDER E_K terms vs K (strongly convex quadratic)",
        &["K", "||W-W*||^2", "||P-Q||^2", "||G_p(P)||^2"],
    );
    let erider = optimizer::spec("erider")
        .expect("erider is a registry method");
    for &k_total in &[500usize, 2000, 8000] {
        let mut rng = Rng::new(seed, k_total as u64);
        let obj = Quadratic::new(16, 1.0, 4.0, 0.3, &mut rng);
        let mut opt = erider.build(16, &presets::PRECISE, 0.4, 0.1, 0.3, &mut rng);
        let (mut sw, mut spq, mut sg) = (0.0, 0.0, 0.0);
        for _ in 0..k_total {
            opt.step(&obj, &mut rng);
            let (a, b, c) = opt
                .convergence_metrics(&obj)
                .expect("erider reports the Eq. 14 terms");
            sw += a;
            spq += b;
            sg += c;
        }
        let k = k_total as f64;
        t3.row(vec![
            k_total.to_string(),
            format!("{:.4}", sw / k),
            format!("{:.4}", spq / k),
            format!("{:.4}", sg / k),
        ]);
    }
    rd.write_table("thm37", &t3)?;
    out.push(t3);

    // --- Corollary 3.9: total pulses to a target loss, across the
    //     requested slice of the method family (registry-driven).
    let mut t4 = Table::new(
        "Cor 3.9: pulses to reach loss<=0.05 (EMA), by method",
        &["method", "calib pulses", "update pulses", "prog events", "total", "steps"],
    );
    let target = 0.05;
    let max_steps = 30_000;
    for name in methods {
        let spec = optimizer::spec_or_err(name).map_err(|e| anyhow::anyhow!(e))?;
        let mut rng = Rng::new(seed, 99);
        let obj = Quadratic::new(16, 1.0, 4.0, 0.3, &mut rng);
        let mut opt = spec.build(16, &presets::PRECISE, 0.4, 0.1, 0.3, &mut rng);
        let mut ema = f64::NAN;
        let mut steps = None;
        for k in 0..max_steps {
            let l = opt.step(&obj, &mut rng);
            ema = if ema.is_nan() { l } else { 0.98 * ema + 0.02 * l };
            if ema < target {
                steps = Some(k + 1);
                break;
            }
        }
        let c = opt.cost();
        t4.row(vec![
            name.clone(),
            c.calibration_pulses.to_string(),
            c.update_pulses.to_string(),
            c.programming_events.to_string(),
            c.total_pulses().to_string(),
            match steps {
                Some(k) => k.to_string(),
                None => format!(">{max_steps}"),
            },
        ]);
    }
    rd.write_table("cor39", &t4)?;
    out.push(t4);
    Ok(out)
}

/// Lemma 3.10: |H(e^{jw})|^2 of the moving-average filter + an empirical
/// chopping demo (Fig. 3): the filter passes the DC drift and kills the
/// chopped (sign-flipping) component.
pub fn fig3(eta: f64) -> anyhow::Result<Table> {
    let rd = RunDir::create("fig3")?;
    let mut t = Table::new(
        &format!("Fig 3 / Lemma 3.10: |H|^2 at eta={eta}"),
        &["omega/pi", "|H|^2 analytic", "|H|^2 empirical"],
    );
    for &wpi in &[0.0, 0.1, 0.25, 0.5, 0.75, 1.0] {
        let w = wpi * std::f64::consts::PI;
        let denom = 1.0 + (1.0 - eta) * (1.0 - eta) - 2.0 * (1.0 - eta) * w.cos();
        let analytic = if denom == 0.0 { f64::INFINITY } else { eta * eta / denom };
        // empirical: drive the MA filter with a sinusoid, measure gain^2
        let n = 4096;
        let mut q = 0.0f64;
        let mut out_pow = 0.0;
        let mut in_pow = 0.0;
        for k in 0..n {
            let x = (w * k as f64).cos();
            q = (1.0 - eta) * q + eta * x;
            if k > n / 2 {
                in_pow += x * x;
                out_pow += q * q;
            }
        }
        let empirical = out_pow / in_pow;
        t.row(vec![
            format!("{wpi:.2}"),
            format!("{analytic:.4}"),
            format!("{empirical:.4}"),
        ]);
    }
    rd.write_table("fig3", &t)?;
    Ok(t)
}
