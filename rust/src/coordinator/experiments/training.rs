//! HLO-driven paper experiments: Fig. 2 (SP-estimation error degrades
//! training), Fig. 4 (pulse cost vs #states; robustness curves on the
//! conv stand-in), Fig. 5 (chopper probability), Tables 1/2 (robustness
//! grids), Table 8 (fine-tune protocol), Tables 9/10 (eta / gamma
//! ablations). All reduced in scale by default (flags scale them up);
//! the *shapes* are the reproduction target (DESIGN.md section 4).
//!
//! Methods are addressed by registry name (`analog::optimizer`): every
//! grid accepts any subset of the shared name set — the same one
//! `rider psweep` takes — and unknown names error with the registry
//! listing instead of panicking.

use std::time::Instant;

use anyhow::Result;

use crate::coordinator::metrics::RunDir;
use crate::coordinator::sweep::Cell;
use crate::data::{synth_cifar, Dataset};
use crate::runtime::{Executor, Registry};
use crate::train::{PipelineConfig, PipelineTrainer, TrainConfig, Trainer};
use crate::util::table::Table;

/// Shared context for the HLO-driven experiments: executor, artifact
/// registry and the common (steps, seeds) scale knobs.
pub struct ExpCtx<'a> {
    /// Executor the trainers run on.
    pub exec: &'a Executor,
    /// Artifact registry (model manifests).
    pub reg: &'a Registry,
    /// Trainer steps per run.
    pub steps: usize,
    /// Seeds per cell (mean±std over these).
    pub seeds: Vec<u64>,
}

pub(crate) fn data_for(model: &str, n: usize, seed: u64) -> Dataset {
    if model == "convnet3" {
        synth_cifar::dataset(n, seed)
    } else {
        Dataset::digits(n, seed)
    }
}

fn one_run(
    ctx: &ExpCtx,
    mut cfg: TrainConfig,
    train_n: usize,
    seed: u64,
) -> Result<(f64, f64, crate::analog::PulseCost)> {
    cfg.seed = seed;
    cfg.steps = ctx.steps;
    let train = data_for(&cfg.model, train_n, seed ^ 0xDA7A);
    let test = data_for(&cfg.model, 200, seed ^ 0x7E57);
    let mut t = Trainer::new(ctx.exec, ctx.reg, cfg)?;
    let res = t.train(&train, Some(&test))?;
    Ok((res.final_loss(30), res.final_eval_acc, res.cost))
}

/// Fig. 2: train with TT-v1 after ZS calibration at different budgets.
pub fn fig2(ctx: &ExpCtx) -> Result<Table> {
    let rd = RunDir::create("fig2")?;
    let mut t = Table::new(
        "Fig 2: final train loss (fcn, ttv1) vs ZS pulse budget",
        &["ZS pulses", "final loss", "test acc %"],
    );
    // ground truth = dynamic tracking reference unnecessary: emulate the
    // paper's ground-truth-SP run with a huge budget.
    for &n in &[0u64, 50, 200, 1000, 4000] {
        let mut cell_l = Vec::new();
        let mut cell_a = Vec::new();
        for &seed in &ctx.seeds {
            let mut cfg = TrainConfig::by_name("fcn", "ttv1")?;
            cfg.ref_mean = 0.4;
            cfg.ref_std = 0.2;
            cfg.zs_pulses = n;
            let (l, a, _) = one_run(ctx, cfg, 320, seed)?;
            cell_l.push(l);
            cell_a.push(a);
        }
        t.row(vec![
            if n == 0 { "0 (uncalibrated)".into() } else { n.to_string() },
            format!("{:.3}", crate::util::stats::mean(&cell_l)),
            format!("{:.1}", crate::util::stats::mean(&cell_a)),
        ]);
    }
    rd.write_table("fig2", &t)?;
    Ok(t)
}

/// Fig. 4 left: total pulse cost to reach a target loss vs #states.
/// Pulse totals come straight out of `TrainResult.cost` — the trainer is
/// the single source of calibration + update accounting.
pub fn fig4_left(ctx: &ExpCtx, target_loss: f64) -> Result<Table> {
    let rd = RunDir::create("fig4")?;
    let mut t = Table::new(
        &format!("Fig 4 left: pulses to train-loss <= {target_loss} vs #states (fcn)"),
        &["#states", "method", "calib", "training", "total", "hit target"],
    );
    for &states in &[20.0f64, 100.0, 500.0, 2000.0] {
        let dwm = 2.0 / states;
        // E-RIDER: no calibration
        for (name, algo, zs) in [
            ("E-RIDER", "erider", 0u64),
            ("ZS(N=4000)+TT-v2", "ttv2", 4000),
        ] {
            let mut cfg = TrainConfig::by_name("fcn", algo)?;
            cfg.ref_mean = 0.4;
            cfg.ref_std = 0.2;
            cfg.dev.dw_min = dwm as f32;
            cfg.zs_pulses = zs;
            cfg.target_loss = target_loss;
            cfg.seed = ctx.seeds[0];
            cfg.steps = ctx.steps;
            let train = data_for("fcn", 320, 1);
            let mut tr = Trainer::new(ctx.exec, ctx.reg, cfg)?;
            let res = tr.train(&train, None)?;
            let cost = res.cost;
            t.row(vec![
                format!("{states:.0}"),
                name.into(),
                cost.calibration_pulses.to_string(),
                cost.update_pulses.to_string(),
                cost.total_pulses().to_string(),
                res.reached_target_at.map(|s| format!("yes@{s}")).unwrap_or("no".into()),
            ]);
        }
    }
    rd.write_table("fig4_left", &t)?;
    Ok(t)
}

/// Fig. 4 mid/right + Tables 1/2/8-style grids: accuracy per method over
/// reference mean/std settings. `methods` are registry names — both
/// `&["ttv2", "erider"]` literals and the `Vec<String>` produced by
/// `optimizer::resolve_names` (i.e. `--methods all`) are accepted.
///
/// While the grid runs, the live metrics facade's JSONL snapshot trace
/// is attached to `<run dir>/metrics.jsonl`, so every `rider table1/
/// table2/fig4` invocation leaves a per-step telemetry trace (loss,
/// SP residual, pulse totals) next to its tables.
pub fn robustness_grid<S: AsRef<str>>(
    ctx: &ExpCtx,
    name: &str,
    model: &str,
    methods: &[S],
    means: &[f64],
    stds: &[f64],
    dev: Option<crate::train::DevParams>,
) -> Result<Table> {
    let rd = RunDir::create(name)?;
    rd.attach_metrics_trace()?;
    let built = (|| -> Result<Table> {
        let mut headers = vec!["method".to_string(), "mean\\std".to_string()];
        headers.extend(stds.iter().map(|s| format!("{s}")));
        let mut t = Table::new(
            &format!("{name}: test accuracy (model {model}, {} steps)", ctx.steps),
            &headers,
        );
        for algo in methods {
            let algo = algo.as_ref();
            for &m in means {
                let mut row = vec![algo.to_string(), format!("{m}")];
                for &sd in stds {
                    let mut cell = Cell::default();
                    for &seed in &ctx.seeds {
                        let mut cfg = TrainConfig::by_name(model, algo)?;
                        cfg.ref_mean = m as f32;
                        cfg.ref_std = sd as f32;
                        if let Some(d) = dev {
                            cfg.dev = d;
                        }
                        let (_, acc, _) = one_run(ctx, cfg, 320, seed)?;
                        cell.samples.push(acc);
                    }
                    row.push(cell.pm());
                }
                t.row(row);
            }
        }
        Ok(t)
    })();
    crate::util::metrics::detach_trace();
    let t = built?;
    rd.write_table(name, &t)?;
    Ok(t)
}

/// Fig. 5: chopper probability ablation on the FCN.
pub fn fig5(ctx: &ExpCtx) -> Result<Table> {
    let rd = RunDir::create("fig5")?;
    let mut t = Table::new(
        "Fig 5: E-RIDER test acc vs chopper probability p (fcn)",
        &["p", "test acc %"],
    );
    for &p in &[0.0f32, 0.02, 0.05, 0.1, 0.2, 0.5] {
        let mut cell = Cell::default();
        for &seed in &ctx.seeds {
            let mut cfg = TrainConfig::by_name("fcn", "erider")?;
            cfg.ref_mean = 0.4;
            cfg.ref_std = 0.2;
            cfg.hypers.flip_p = p;
            let (_, acc, _) = one_run(ctx, cfg, 320, seed)?;
            cell.samples.push(acc);
        }
        t.row(vec![format!("{p}"), cell.pm()]);
    }
    rd.write_table("fig5", &t)?;
    Ok(t)
}

/// Tables 9/10: eta and gamma ablations.
pub fn ablations(ctx: &ExpCtx) -> Result<(Table, Table)> {
    let rd = RunDir::create("ablations")?;
    let mut t9 = Table::new("Table 9: eta ablation (E-RIDER, fcn)", &["eta", "acc %"]);
    for &eta in &[0.0f32, 0.1, 0.3, 0.5, 0.8, 1.0] {
        let mut cell = Cell::default();
        for &seed in &ctx.seeds {
            let mut cfg = TrainConfig::by_name("fcn", "erider")?;
            cfg.ref_mean = 0.4;
            cfg.ref_std = 0.2;
            cfg.hypers.eta = eta;
            let (_, acc, _) = one_run(ctx, cfg, 320, seed)?;
            cell.samples.push(acc);
        }
        t9.row(vec![format!("{eta}"), cell.pm()]);
    }
    rd.write_table("table9_eta", &t9)?;
    let mut t10 = Table::new("Table 10: gamma ablation (E-RIDER, fcn)", &["gamma", "acc %"]);
    for &g in &[0.1f32, 0.3, 0.5, 1.0, 2.0, 4.0] {
        let mut cell = Cell::default();
        for &seed in &ctx.seeds {
            let mut cfg = TrainConfig::by_name("fcn", "erider")?;
            cfg.ref_mean = 0.4;
            cfg.ref_std = 0.2;
            cfg.hypers.gamma = g;
            let (_, acc, _) = one_run(ctx, cfg, 320, seed)?;
            cell.samples.push(acc);
        }
        t10.row(vec![format!("{g}"), cell.pm()]);
    }
    rd.write_table("table10_gamma", &t10)?;
    Ok((t9, t10))
}

/// Pipeline experiment: synchronous vs pipelined training per method at
/// equal pulse budgets (same step count, so identical update-pulse
/// bills by construction — the "update pulses" column shows it). For
/// each method the table reports the synchronous oracle, the `D=0`
/// pipelined run (with a live bit-exactness check against the oracle:
/// every per-step loss and the final eval accuracy compared by bits),
/// and — when `staleness > 0` — the stale run with its accuracy delta.
/// Wall-clock per schedule makes the pipelining overhead/benefit a
/// first-class reported number.
pub fn table_pipeline<S: AsRef<str>>(
    ctx: &ExpCtx,
    model: &str,
    methods: &[S],
    stages: usize,
    workers: usize,
    staleness: u64,
) -> Result<Table> {
    let rd = RunDir::create("table_pipeline")?;
    rd.attach_metrics_trace()?;
    let built = (|| -> Result<Table> {
        let mut t = Table::new(
            &format!(
                "table_pipeline: sync vs pipelined, {stages} stages x {workers} workers \
                 (model {model}, {} steps, equal pulse budgets)",
                ctx.steps
            ),
            &[
                "method",
                "schedule",
                "final loss",
                "test acc %",
                "update pulses",
                "wall s",
                "vs sync",
            ],
        );
        let seed = ctx.seeds.first().copied().unwrap_or(1);
        let train = data_for(model, 320, seed ^ 0xDA7A);
        let test = data_for(model, 200, seed ^ 0x7E57);
        for algo in methods {
            let algo = algo.as_ref();
            let mk_cfg = || -> Result<TrainConfig> {
                let mut cfg = TrainConfig::by_name(model, algo)?;
                cfg.ref_mean = 0.3;
                cfg.ref_std = 0.2;
                cfg.seed = seed;
                cfg.steps = ctx.steps;
                Ok(cfg)
            };
            let t0 = Instant::now();
            let mut st = Trainer::new(ctx.exec, ctx.reg, mk_cfg()?)?;
            let sres = st.train(&train, Some(&test))?;
            t.row(vec![
                algo.to_string(),
                "sync".into(),
                format!("{:.4}", sres.final_loss(30)),
                format!("{:.2}", sres.final_eval_acc),
                sres.cost.update_pulses.to_string(),
                format!("{:.2}", t0.elapsed().as_secs_f64()),
                "-".into(),
            ]);
            let mut depths = vec![0u64];
            if staleness > 0 {
                depths.push(staleness);
            }
            for d in depths {
                let pcfg = PipelineConfig {
                    stages,
                    workers,
                    staleness: d,
                    plan_threads: 0,
                };
                let t0 = Instant::now();
                let mut pt = PipelineTrainer::new(ctx.exec, ctx.reg, mk_cfg()?, pcfg)?;
                let pres = pt.train(&train, Some(&test))?;
                let wall = t0.elapsed().as_secs_f64();
                let vs = if d == 0 {
                    let exact = pres.losses.len() == sres.losses.len()
                        && pres
                            .losses
                            .iter()
                            .zip(&sres.losses)
                            .all(|(a, b)| a.to_bits() == b.to_bits())
                        && pres.final_eval_acc.to_bits() == sres.final_eval_acc.to_bits();
                    if exact { "bit-exact".to_string() } else { "DIVERGED".to_string() }
                } else {
                    format!("{:+.2} acc", pres.final_eval_acc - sres.final_eval_acc)
                };
                t.row(vec![
                    algo.to_string(),
                    format!("pipe D={d}"),
                    format!("{:.4}", pres.final_loss(30)),
                    format!("{:.2}", pres.final_eval_acc),
                    pres.cost.update_pulses.to_string(),
                    format!("{wall:.2}"),
                    vs,
                ]);
            }
        }
        Ok(t)
    })();
    crate::util::metrics::detach_trace();
    let t = built?;
    rd.write_table("table_pipeline", &t)?;
    Ok(t)
}

/// Table 8 protocol: digital pre-train -> analog deploy (acc drop) ->
/// fine-tune with AGAD vs E-RIDER across reference offsets.
pub fn table8(ctx: &ExpCtx) -> Result<Table> {
    let rd = RunDir::create("table8")?;
    let model = "convnet3";
    let spec = ctx.reg.model(model)?;
    let train = data_for(model, 320, 0xF00D);
    let test = data_for(model, 200, 0xBEEF);
    // digital pre-train (the registry's baseline arm)
    let mut dcfg = TrainConfig::by_name(model, "digital")?;
    dcfg.steps = ctx.steps * 2;
    dcfg.hypers.lr_digital = 0.3;
    dcfg.seed = 1;
    let mut dt = Trainer::new(ctx.exec, ctx.reg, dcfg)?;
    let dres = dt.train(&train, Some(&test))?;
    let mut t = Table::new(
        "Table 8 protocol: digital pre-train -> analog deploy -> fine-tune",
        &["stage", "ref mean", "acc %"],
    );
    t.row(vec!["digital pre-train".into(), "-".into(),
               format!("{:.1}", dres.final_eval_acc)]);
    for &m in &[0.05f32, 0.4] {
        for algo in ["agad", "erider"] {
            let mut cfg = TrainConfig::by_name(model, algo)?;
            cfg.ref_mean = m;
            cfg.ref_std = 0.2;
            cfg.steps = ctx.steps;
            cfg.seed = 2;
            let mut tr = Trainer::new(ctx.exec, ctx.reg, cfg)?;
            tr.state.deploy_weights_from(spec, &dt.state);
            let (_, acc0) = tr.eval(&test)?; // deploy drop
            let res = tr.train(&train, Some(&test))?;
            t.row(vec![
                format!("deploy+{algo}"),
                format!("{m}"),
                format!("{:.1} -> {:.1}", acc0, res.final_eval_acc),
            ]);
        }
    }
    rd.write_table("table8", &t)?;
    Ok(t)
}
