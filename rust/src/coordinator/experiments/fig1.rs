//! Figure 1: ZS pulse-complexity study.
//! (a) SP-estimate mean/std offsets vs pulse budget N on a large array;
//! (b) smallest N reaching <= 1% relative mean error vs dw_min
//!     (near-inverse-linear, Theorem 2.2).

use crate::analog::zs::{self, ZsVariant};
use crate::coordinator::metrics::RunDir;
use crate::device::{presets, DeviceArray};
use crate::util::rng::Rng;
use crate::util::stats;
use crate::util::table::Table;

/// Scale knobs for the Fig. 1 ZS pulse-complexity study.
pub struct Fig1Params {
    /// Array side length (paper: 512).
    pub side: usize,
    /// ZS pulse budgets for panel (a).
    pub budgets: Vec<u64>,
    /// `dw_min` sweep values for panel (b).
    pub dw_mins: Vec<f64>,
    /// Base RNG seed.
    pub seed: u64,
}

impl Default for Fig1Params {
    fn default() -> Self {
        Fig1Params {
            // paper: 512x512; default reduced for wall-clock, override
            // with --side 512 to match exactly.
            side: 128,
            budgets: vec![500, 1000, 2000, 4000, 8000],
            dw_mins: vec![5e-3, 2e-3, 1e-3, 5e-4, 2e-4],
            seed: 42,
        }
    }
}

/// Run both Fig. 1 panels and write them under `runs/fig1/`.
pub fn run(p: &Fig1Params) -> anyhow::Result<(Table, Table)> {
    let rd = RunDir::create("fig1")?;

    // (a) offsets vs N at dw_min = 1e-3 (the paper's `precise` preset)
    let mut ta = Table::new(
        &format!("Fig 1a: SP offsets vs pulse budget ({0}x{0}, dw_min=1e-3)", p.side),
        &["N", "mean offset", "std offset", "rel mean err"],
    );
    for &n in &p.budgets {
        let mut rng = Rng::new(p.seed, n);
        let mut arr = DeviceArray::sample(
            p.side, p.side, &presets::PRECISE, 0.4, 0.2, 0.1, &mut rng,
        );
        let res = zs::run(&mut arr, n, ZsVariant::Cyclic, &mut rng);
        ta.row(vec![
            n.to_string(),
            format!("{:+.4}", res.mean_offset()),
            format!("{:+.4}", res.std_offset()),
            format!("{:.3}%", 100.0 * res.rel_mean_error()),
        ]);
    }
    rd.write_table("fig1a", &ta)?;

    // (b) pulses to 1% relative mean error vs dw_min
    let mut tb = Table::new(
        "Fig 1b: pulse cost to <=1% rel. mean error vs dw_min",
        &["dw_min", "N needed", "achieved err"],
    );
    let schedule: Vec<u64> = (0..16).map(|i| 200u64 << i).collect();
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for &dwm in &p.dw_mins {
        let side = p.side.min(96); // per-dwm sweep is the expensive part
        let mk = |rng: &mut Rng| {
            let mut pr = presets::PRECISE.clone();
            pr.dw_min = dwm;
            DeviceArray::sample(side, side, &pr, 0.4, 0.2, 0.1, rng)
        };
        match zs::pulses_to_target(mk, 0.01, &schedule, ZsVariant::Cyclic, p.seed) {
            Some((n, err)) => {
                xs.push(dwm);
                ys.push(n as f64);
                tb.row(vec![
                    format!("{dwm:.1e}"),
                    n.to_string(),
                    format!("{:.3}%", 100.0 * err),
                ]);
            }
            None => tb.row(vec![format!("{dwm:.1e}"), ">max".into(), "-".into()]),
        }
    }
    if xs.len() >= 3 {
        let slope = stats::loglog_slope(&xs, &ys);
        tb.row(vec![
            "log-log slope".into(),
            format!("{slope:.2}"),
            "(Thm 2.2 predicts ~ -1)".into(),
        ]);
    }
    rd.write_table("fig1b", &tb)?;
    Ok((ta, tb))
}
