//! Run-directory management and metric emission (CSV + JSONL), so every
//! experiment leaves a machine-readable trace under `runs/`.

use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;
use crate::util::table::Table;

/// A per-experiment output directory under `runs/` (override the base
/// with `$RIDER_RUNS`): tables, curves, JSONL records and the live
/// metrics trace land next to each other.
pub struct RunDir {
    /// Absolute-or-relative directory path, already created.
    pub path: PathBuf,
}

impl RunDir {
    /// Create (or reuse) `runs/<name>`.
    pub fn create(name: &str) -> Result<RunDir> {
        let base = std::env::var("RIDER_RUNS").unwrap_or_else(|_| "runs".to_string());
        let path = Path::new(&base).join(name);
        fs::create_dir_all(&path).with_context(|| format!("mkdir {}", path.display()))?;
        Ok(RunDir { path })
    }

    /// Path of `name` inside the run directory.
    pub fn file(&self, name: &str) -> PathBuf {
        self.path.join(name)
    }

    /// Attach the live metrics facade's JSONL snapshot trace to
    /// `metrics.jsonl` in this run directory (no-op unless a recorder
    /// is installed — detach with `util::metrics::detach_trace`).
    pub fn attach_metrics_trace(&self) -> Result<()> {
        crate::util::metrics::attach_trace(&self.file("metrics.jsonl"))
            .with_context(|| format!("attach metrics trace in {}", self.path.display()))
    }

    /// Write a table both as rendered text and CSV.
    pub fn write_table(&self, name: &str, table: &Table) -> Result<()> {
        fs::write(self.file(&format!("{name}.txt")), table.render())?;
        fs::write(self.file(&format!("{name}.csv")), table.to_csv())?;
        Ok(())
    }

    /// Write a loss/metric curve as CSV: step,value.
    pub fn write_curve(&self, name: &str, values: &[f64]) -> Result<()> {
        let mut s = String::from("step,value\n");
        for (i, v) in values.iter().enumerate() {
            s.push_str(&format!("{i},{v}\n"));
        }
        fs::write(self.file(&format!("{name}.csv")), s)?;
        Ok(())
    }

    /// Append one JSON record to `<name>.jsonl`.
    pub fn append_jsonl(&self, name: &str, record: &Json) -> Result<()> {
        let mut f = fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(self.file(&format!("{name}.jsonl")))?;
        writeln!(f, "{}", record.dump())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::{num, obj};

    fn tmp_rundir(name: &str) -> RunDir {
        std::env::set_var("RIDER_RUNS", std::env::temp_dir().join("rider_runs_test"));
        RunDir::create(name).unwrap()
    }

    #[test]
    fn writes_curve_and_table() {
        let rd = tmp_rundir("t1");
        rd.write_curve("loss", &[1.0, 0.5, 0.25]).unwrap();
        let csv = fs::read_to_string(rd.file("loss.csv")).unwrap();
        assert!(csv.contains("2,0.25"));
        let mut t = Table::new("x", &["a"]);
        t.row(vec!["1".into()]);
        rd.write_table("tab", &t).unwrap();
        assert!(rd.file("tab.csv").exists());
        assert!(rd.file("tab.txt").exists());
    }

    #[test]
    fn jsonl_appends() {
        let rd = tmp_rundir("t2");
        let _ = fs::remove_file(rd.file("m.jsonl"));
        rd.append_jsonl("m", &obj(vec![("v", num(1.0))])).unwrap();
        rd.append_jsonl("m", &obj(vec![("v", num(2.0))])).unwrap();
        let s = fs::read_to_string(rd.file("m.jsonl")).unwrap();
        assert_eq!(s.lines().count(), 2);
    }
}
