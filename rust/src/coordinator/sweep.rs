//! Grid sweeps over (ref_mean, ref_std, seed) — the measurement pattern
//! behind Tables 1/2/8 and Fig. 4. Rust-native (device-substrate)
//! experiments fan out over worker threads; HLO-driven sweeps run on one
//! PJRT client (the artifacts themselves are multi-threaded by XLA).
//!
//! [`pulse_robustness_grid`] is the pulse-level twin of the NN-scale
//! `training::robustness_grid`: methods are addressed by registry name
//! and instantiated per cell through `OptimizerSpec::build`.

use crate::analog::optimizer::{self, AnalogOptimizer as _, OptimizerSpec};
use crate::device::Preset;
use crate::optim::Quadratic;
use crate::util::metrics;
use crate::util::rng::Rng;
use crate::util::stats;
use crate::util::table::Table;

/// One cell of a robustness grid: per-seed metric samples.
#[derive(Clone, Debug, Default)]
pub struct Cell {
    /// Metric samples in seed order (NaN for failed jobs).
    pub samples: Vec<f64>,
}

impl Cell {
    /// Mean over the cell's samples.
    pub fn mean(&self) -> f64 {
        stats::mean(&self.samples)
    }

    /// Sample standard deviation over the cell's samples.
    pub fn std(&self) -> f64 {
        stats::std(&self.samples)
    }

    /// `mean±std` in the paper's table format.
    pub fn pm(&self) -> String {
        crate::util::table::Table::pm(self.mean(), self.std())
    }
}

/// One failed grid job: which (mean, std, seed) cell panicked and what
/// the panic payload said. The rest of the grid still completes; the
/// failed sample is recorded as NaN (which the NaN-safe stats absorb).
#[derive(Clone, Debug)]
pub struct GridFailure {
    /// `ref_mean` coordinate of the failed cell.
    pub mean: f64,
    /// `ref_std` coordinate of the failed cell.
    pub std: f64,
    /// Seed of the failed job.
    pub seed: u64,
    /// Text of the panic payload.
    pub message: String,
}

/// A (mean x std) grid of cells for one method.
#[derive(Clone, Debug)]
pub struct Grid {
    /// `ref_mean` axis values.
    pub means: Vec<f64>,
    /// `ref_std` axis values.
    pub stds: Vec<f64>,
    /// Cells in row-major `[mean][std]` order.
    pub cells: Vec<Cell>,
    /// Jobs that panicked instead of returning a metric (empty on a
    /// healthy sweep).
    pub failures: Vec<GridFailure>,
}

impl Grid {
    /// Empty grid over the given axes.
    pub fn new(means: &[f64], stds: &[f64]) -> Grid {
        Grid {
            means: means.to_vec(),
            stds: stds.to_vec(),
            cells: vec![Cell::default(); means.len() * stds.len()],
            failures: Vec::new(),
        }
    }

    /// Mutable cell at (mean index, std index).
    pub fn cell_mut(&mut self, mi: usize, si: usize) -> &mut Cell {
        &mut self.cells[mi * self.stds.len() + si]
    }

    /// Cell at (mean index, std index).
    pub fn cell(&self, mi: usize, si: usize) -> &Cell {
        &self.cells[mi * self.stds.len() + si]
    }
}

/// Run a closure over every (mean, std, seed) combination on `threads`
/// worker threads; the closure must be Sync and return the metric.
///
/// Workers accumulate `(job index, value)` pairs thread-locally and the
/// results are merged once per worker at exit — the only shared state in
/// the job loop is the work-stealing counter, so fine-grained grids pay
/// no lock traffic. Merging by job index also makes the per-cell sample
/// *order* deterministic (seed order, as enumerated), independent of
/// thread interleaving.
pub fn run_grid<F>(
    means: &[f64],
    stds: &[f64],
    seeds: &[u64],
    threads: usize,
    f: F,
) -> Grid
where
    F: Fn(f64, f64, u64) -> f64 + Sync,
{
    let mut jobs = Vec::new();
    for (mi, &m) in means.iter().enumerate() {
        for (si, &s) in stds.iter().enumerate() {
            for &seed in seeds {
                jobs.push((mi, si, m, s, seed));
            }
        }
    }
    let next = std::sync::atomic::AtomicUsize::new(0);
    // Each job runs under catch_unwind: a panicking cell becomes a NaN
    // sample plus a recorded (mean, std, seed, message) failure instead
    // of aborting the whole sweep. The per-worker join can therefore
    // only fail on a panic *outside* the job loop; that too is caught
    // and surfaced rather than unwrapped.
    let locals: Vec<Vec<(usize, Result<f64, String>)>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..threads.max(1))
            .map(|_| {
                scope.spawn(|| {
                    let mut local = Vec::new();
                    loop {
                        let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        if i >= jobs.len() {
                            break;
                        }
                        let (_, _, m, s, seed) = jobs[i];
                        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                            || f(m, s, seed),
                        ))
                        .map_err(|e| panic_message(&e));
                        local.push((i, r));
                    }
                    local
                })
            })
            .collect();
        handles
            .into_iter()
            .filter_map(|h| match h.join() {
                Ok(local) => Some(local),
                Err(e) => {
                    eprintln!("sweep: worker thread died: {}", panic_message(&e));
                    None
                }
            })
            .collect()
    });
    let mut flat: Vec<Option<Result<f64, String>>> = vec![None; jobs.len()];
    for (i, v) in locals.into_iter().flatten() {
        flat[i] = Some(v);
    }
    let mut grid = Grid::new(means, stds);
    for (&(mi, si, m, s, seed), v) in jobs.iter().zip(flat) {
        let sample = match v {
            Some(Ok(v)) => v,
            Some(Err(message)) => {
                grid.failures.push(GridFailure { mean: m, std: s, seed, message });
                f64::NAN
            }
            None => {
                grid.failures.push(GridFailure {
                    mean: m,
                    std: s,
                    seed,
                    message: "lost with its worker thread".to_string(),
                });
                f64::NAN
            }
        };
        grid.cells[mi * stds.len() + si].samples.push(sample);
    }
    metrics::counter(metrics::MetricId::SweepJobsTotal, jobs.len() as u64);
    metrics::counter(
        metrics::MetricId::SweepJobFailuresTotal,
        grid.failures.len() as u64,
    );
    grid
}

/// Best-effort text of a panic payload (the `&str` / `String` forms
/// `panic!` produces; anything else gets a placeholder).
fn panic_message(e: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = e.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = e.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Scale parameters of a pulse-level robustness sweep (one quadratic
/// objective per cell, methods built from the registry).
pub struct PulseSweep<'a> {
    /// Problem / tile dimension per cell.
    pub dim: usize,
    /// Device response preset the cells run on.
    pub preset: &'a Preset,
    /// optimizer steps per cell; the metric is the mean loss over the
    /// final fifth of the run
    pub steps: usize,
    /// gradient-noise scale of the stochastic oracle
    pub sigma: f64,
    /// Worker threads for the job fan-out.
    pub threads: usize,
}

/// Tail-mean loss of one (method, mean, std, seed) cell. The stream id
/// is derived from the cell coordinates so every cell is deterministic
/// regardless of thread interleaving.
fn pulse_cell(spec: &OptimizerSpec, p: &PulseSweep, m: f64, s: f64, seed: u64) -> f64 {
    let stream = m.to_bits() ^ s.to_bits().rotate_left(17);
    let mut rng = Rng::new(seed, stream);
    let obj = Quadratic::new(p.dim, 1.0, 4.0, 0.3, &mut rng);
    let mut opt = spec.build(p.dim, p.preset, m, s, p.sigma, &mut rng);
    let tail_n = (p.steps / 5).max(1);
    let mut tail = 0.0;
    for k in 0..p.steps {
        let l = opt.step(&obj, &mut rng);
        if k + tail_n >= p.steps {
            tail += l;
        }
    }
    tail / tail_n as f64
}

/// Sweep prebuilt (label, spec) pairs — the core the name-driven entry
/// point wraps; use this when specs carry CLI/config hyper overrides.
pub fn pulse_robustness_grid_specs(
    specs: &[(String, OptimizerSpec)],
    means: &[f64],
    stds: &[f64],
    seeds: &[u64],
    p: &PulseSweep,
) -> Vec<(String, Grid)> {
    specs
        .iter()
        .map(|(name, spec)| {
            let grid = run_grid(means, stds, seeds, p.threads, |m, s, seed| {
                pulse_cell(spec, p, m, s, seed)
            });
            for fail in &grid.failures {
                eprintln!(
                    "sweep: method {} cell (mean={:.3}, std={:.3}) seed {} panicked: {}",
                    name, fail.mean, fail.std, fail.seed, fail.message
                );
            }
            (name.clone(), grid)
        })
        .collect()
}

/// Name-driven pulse-level robustness sweep: one [`Grid`] per method,
/// fanned out over worker threads. Unknown names error with the
/// registry listing.
pub fn pulse_robustness_grid(
    methods: &[String],
    means: &[f64],
    stds: &[f64],
    seeds: &[u64],
    p: &PulseSweep,
) -> anyhow::Result<Vec<(String, Grid)>> {
    let specs = methods
        .iter()
        .map(|name| {
            optimizer::spec_or_err(name)
                .map(|s| (name.clone(), s))
                .map_err(|e| anyhow::anyhow!(e))
        })
        .collect::<anyhow::Result<Vec<_>>>()?;
    Ok(pulse_robustness_grid_specs(&specs, means, stds, seeds, p))
}

/// Render per-method grids in the Tables 1–2 layout: one row per
/// method, one `mean±std` column per (ref_mean, ref_std) cell.
pub fn render_pulse_grid(title: &str, grids: &[(String, Grid)]) -> Table {
    let Some((_, g0)) = grids.first() else {
        return Table::new(title, &["method"]);
    };
    let mut headers = vec!["method".to_string()];
    for &m in &g0.means {
        for &s in &g0.stds {
            headers.push(format!("m={m:.2} s={s:.2}"));
        }
    }
    let mut t = Table::new(title, &headers);
    for (name, g) in grids {
        let mut row = vec![name.clone()];
        for mi in 0..g.means.len() {
            for si in 0..g.stds.len() {
                row.push(g.cell(mi, si).pm());
            }
        }
        t.row(row);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::presets;

    #[test]
    fn grid_runs_all_combinations() {
        let g = run_grid(&[0.0, 0.5], &[0.1, 0.2, 0.3], &[1, 2, 3, 4], 4, |m, s, seed| {
            m + s + seed as f64
        });
        for mi in 0..2 {
            for si in 0..3 {
                assert_eq!(g.cell(mi, si).samples.len(), 4);
            }
        }
        // deterministic content AND order (seed order) regardless of
        // thread interleaving — the per-worker merge preserves job order
        let c = g.cell(1, 2);
        assert_eq!(c.samples, vec![0.8 + 1.0, 0.8 + 2.0, 0.8 + 3.0, 0.8 + 4.0]);
    }

    #[test]
    fn panicking_job_does_not_abort_the_grid() {
        // one poisoned (mean, seed) combination; every other job must
        // still complete, and the failure is attributed to its exact
        // (mean, std, seed) coordinates
        let g = run_grid(&[0.0, 0.5], &[0.1], &[1, 2], 2, |m, s, seed| {
            if m == 0.5 && seed == 2 {
                panic!("injected grid failure");
            }
            m + s + seed as f64
        });
        assert_eq!(g.failures.len(), 1);
        let fail = &g.failures[0];
        assert_eq!((fail.mean, fail.std, fail.seed), (0.5, 0.1, 2));
        assert!(fail.message.contains("injected grid failure"));
        // the healthy cell is intact, order preserved
        assert_eq!(g.cell(0, 0).samples, vec![1.1, 2.1]);
        // the poisoned cell records NaN for the failed seed
        let c = g.cell(1, 0);
        assert_eq!(c.samples.len(), 2);
        assert_eq!(c.samples[0], 1.6);
        assert!(c.samples[1].is_nan());
    }

    #[test]
    fn cell_stats() {
        let c = Cell {
            samples: vec![90.0, 92.0, 94.0],
        };
        assert!((c.mean() - 92.0).abs() < 1e-12);
        assert!((c.std() - 2.0).abs() < 1e-12);
        assert!(c.pm().starts_with("92.00±"));
    }

    #[test]
    fn pulse_grid_is_name_driven_and_full() {
        let preset = presets::preset("om").unwrap();
        let p = PulseSweep {
            dim: 4,
            preset: &preset,
            steps: 50,
            sigma: 0.2,
            threads: 2,
        };
        let methods = vec!["sgd".to_string(), "erider".to_string()];
        let grids =
            pulse_robustness_grid(&methods, &[0.0, 0.4], &[0.1], &[1, 2], &p).unwrap();
        assert_eq!(grids.len(), 2);
        for (_, g) in &grids {
            for mi in 0..2 {
                assert_eq!(g.cell(mi, 0).samples.len(), 2);
                assert!(g.cell(mi, 0).samples.iter().all(|l| l.is_finite()));
            }
        }
        let t = render_pulse_grid("t", &grids);
        assert!(t.render().contains("erider"));
        // unknown names are rejected with the registry listing
        assert!(pulse_robustness_grid(&["nope".to_string()], &[0.0], &[0.1], &[1], &p)
            .is_err());
    }
}
