//! Grid sweeps over (ref_mean, ref_std, seed) — the measurement pattern
//! behind Tables 1/2/8 and Fig. 4. Rust-native (device-substrate)
//! experiments fan out over worker threads; HLO-driven sweeps run on one
//! PJRT client (the artifacts themselves are multi-threaded by XLA).

use crate::util::stats;

/// One cell of a robustness grid: per-seed metric samples.
#[derive(Clone, Debug, Default)]
pub struct Cell {
    pub samples: Vec<f64>,
}

impl Cell {
    pub fn mean(&self) -> f64 {
        stats::mean(&self.samples)
    }

    pub fn std(&self) -> f64 {
        stats::std(&self.samples)
    }

    pub fn pm(&self) -> String {
        crate::util::table::Table::pm(self.mean(), self.std())
    }
}

/// A (mean x std) grid of cells for one method.
#[derive(Clone, Debug)]
pub struct Grid {
    pub means: Vec<f64>,
    pub stds: Vec<f64>,
    pub cells: Vec<Cell>, // row-major [mean][std]
}

impl Grid {
    pub fn new(means: &[f64], stds: &[f64]) -> Grid {
        Grid {
            means: means.to_vec(),
            stds: stds.to_vec(),
            cells: vec![Cell::default(); means.len() * stds.len()],
        }
    }

    pub fn cell_mut(&mut self, mi: usize, si: usize) -> &mut Cell {
        &mut self.cells[mi * self.stds.len() + si]
    }

    pub fn cell(&self, mi: usize, si: usize) -> &Cell {
        &self.cells[mi * self.stds.len() + si]
    }
}

/// Run a closure over every (mean, std, seed) combination on `threads`
/// worker threads; the closure must be Sync and return the metric.
pub fn run_grid<F>(
    means: &[f64],
    stds: &[f64],
    seeds: &[u64],
    threads: usize,
    f: F,
) -> Grid
where
    F: Fn(f64, f64, u64) -> f64 + Sync,
{
    let mut jobs = Vec::new();
    for (mi, &m) in means.iter().enumerate() {
        for (si, &s) in stds.iter().enumerate() {
            for &seed in seeds {
                jobs.push((mi, si, m, s, seed));
            }
        }
    }
    let results = std::sync::Mutex::new(vec![Vec::new(); means.len() * stds.len()]);
    let next = std::sync::atomic::AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..threads.max(1) {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= jobs.len() {
                    break;
                }
                let (mi, si, m, s, seed) = jobs[i];
                let v = f(m, s, seed);
                results.lock().unwrap()[mi * stds.len() + si].push(v);
            });
        }
    });
    let mut grid = Grid::new(means, stds);
    for (i, samples) in results.into_inner().unwrap().into_iter().enumerate() {
        grid.cells[i].samples = samples;
    }
    grid
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_runs_all_combinations() {
        let g = run_grid(&[0.0, 0.5], &[0.1, 0.2, 0.3], &[1, 2, 3, 4], 4, |m, s, seed| {
            m + s + seed as f64
        });
        for mi in 0..2 {
            for si in 0..3 {
                assert_eq!(g.cell(mi, si).samples.len(), 4);
            }
        }
        // deterministic content regardless of thread interleaving
        let c = g.cell(1, 2);
        let mut sorted = c.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(sorted, vec![0.8 + 1.0, 0.8 + 2.0, 0.8 + 3.0, 0.8 + 4.0]);
    }

    #[test]
    fn cell_stats() {
        let c = Cell {
            samples: vec![90.0, 92.0, 94.0],
        };
        assert!((c.mean() - 92.0).abs() < 1e-12);
        assert!((c.std() - 2.0).abs() < 1e-12);
        assert!(c.pm().starts_with("92.00±"));
    }
}
