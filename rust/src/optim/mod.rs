//! Objectives for the theory-validation experiments (Theorems 2.2, C.2,
//! 3.7; Corollary 3.9): strongly convex quadratics and logistic
//! regression, with exact and noisy gradient oracles.

use crate::util::rng::Rng;

/// A differentiable objective with a stochastic gradient oracle.
pub trait Objective: Send + Sync {
    fn dim(&self) -> usize;
    fn loss(&self, w: &[f32]) -> f64;
    fn grad(&self, w: &[f32], out: &mut [f32]);
    /// Stochastic gradient: exact gradient + noise of scale `sigma`
    /// (batch-sampled, allocation-free).
    fn noisy_grad(&self, w: &[f32], sigma: f64, rng: &mut Rng, out: &mut [f32]) {
        self.grad(w, out);
        if sigma > 0.0 {
            rng.add_normal_f32(out, sigma as f32);
        }
    }
    /// The optimum, if known in closed form.
    fn optimum(&self) -> Option<Vec<f32>> {
        None
    }
}

/// Strongly convex quadratic f(w) = 0.5 Σ λ_d (w_d - w*_d)^2.
#[derive(Clone, Debug)]
pub struct Quadratic {
    pub lambda: Vec<f32>,
    pub w_star: Vec<f32>,
}

impl Quadratic {
    /// Condition number kappa: eigenvalues log-spaced in [mu, mu*kappa].
    pub fn new(dim: usize, mu: f64, kappa: f64, w_star_scale: f64, rng: &mut Rng) -> Self {
        let lambda = (0..dim)
            .map(|i| {
                let t = if dim > 1 { i as f64 / (dim - 1) as f64 } else { 0.0 };
                (mu * kappa.powf(t)) as f32
            })
            .collect();
        let w_star = (0..dim)
            .map(|_| (w_star_scale * rng.uniform_in(-1.0, 1.0)) as f32)
            .collect();
        Self { lambda, w_star }
    }
}

impl Objective for Quadratic {
    fn dim(&self) -> usize {
        self.lambda.len()
    }

    fn loss(&self, w: &[f32]) -> f64 {
        w.iter()
            .zip(&self.w_star)
            .zip(&self.lambda)
            .map(|((w, ws), l)| 0.5 * (*l as f64) * ((w - ws) as f64).powi(2))
            .sum()
    }

    fn grad(&self, w: &[f32], out: &mut [f32]) {
        for i in 0..w.len() {
            out[i] = self.lambda[i] * (w[i] - self.w_star[i]);
        }
    }

    fn optimum(&self) -> Option<Vec<f32>> {
        Some(self.w_star.clone())
    }
}

/// L2-regularized logistic regression on a fixed synthetic dataset.
#[derive(Clone, Debug)]
pub struct Logistic {
    pub x: Vec<f32>, // n x d
    pub y: Vec<f32>, // ±1
    pub n: usize,
    pub d: usize,
    pub reg: f32,
}

impl Logistic {
    pub fn synthetic(n: usize, d: usize, reg: f64, rng: &mut Rng) -> Self {
        let mut teacher = vec![0.0f32; d];
        rng.fill_normal_f32(&mut teacher);
        let mut x = vec![0.0f32; n * d];
        rng.fill_normal_f32(&mut x);
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            let mut s = 0.0f32;
            for j in 0..d {
                s += x[i * d + j] * teacher[j];
            }
            let flip = rng.bernoulli(0.05);
            let label = if (s > 0.0) != flip { 1.0 } else { -1.0 };
            y.push(label);
        }
        Self { x, y, n, d, reg: reg as f32 }
    }
}

impl Objective for Logistic {
    fn dim(&self) -> usize {
        self.d
    }

    fn loss(&self, w: &[f32]) -> f64 {
        let mut total = 0.0f64;
        for i in 0..self.n {
            let mut s = 0.0f32;
            for j in 0..self.d {
                s += self.x[i * self.d + j] * w[j];
            }
            let m = (self.y[i] * s) as f64;
            total += (1.0 + (-m).exp()).ln();
        }
        total / self.n as f64
            + 0.5 * self.reg as f64 * w.iter().map(|v| (*v as f64).powi(2)).sum::<f64>()
    }

    fn grad(&self, w: &[f32], out: &mut [f32]) {
        out.fill(0.0);
        for i in 0..self.n {
            let mut s = 0.0f32;
            for j in 0..self.d {
                s += self.x[i * self.d + j] * w[j];
            }
            let m = self.y[i] * s;
            let sig = 1.0 / (1.0 + (m as f64).exp()) as f32; // σ(-m)
            let coef = -self.y[i] * sig / self.n as f32;
            for j in 0..self.d {
                out[j] += coef * self.x[i * self.d + j];
            }
        }
        for j in 0..self.d {
            out[j] += self.reg * w[j];
        }
    }

    fn noisy_grad(&self, w: &[f32], _sigma: f64, rng: &mut Rng, out: &mut [f32]) {
        // minibatch-of-one stochastic gradient (natural noise)
        let i = rng.below(self.n);
        let mut s = 0.0f32;
        for j in 0..self.d {
            s += self.x[i * self.d + j] * w[j];
        }
        let m = self.y[i] * s;
        let sig = 1.0 / (1.0 + (m as f64).exp()) as f32;
        let coef = -self.y[i] * sig;
        for j in 0..self.d {
            out[j] = coef * self.x[i * self.d + j] + self.reg * w[j];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quadratic_grad_is_zero_at_optimum() {
        let mut rng = Rng::from_seed(0);
        let q = Quadratic::new(8, 0.5, 10.0, 0.5, &mut rng);
        let mut g = vec![0.0; 8];
        q.grad(&q.w_star.clone(), &mut g);
        assert!(g.iter().all(|v| v.abs() < 1e-7));
        assert!(q.loss(&q.w_star) < 1e-12);
    }

    #[test]
    fn quadratic_gd_converges() {
        let mut rng = Rng::from_seed(1);
        let q = Quadratic::new(16, 0.2, 20.0, 0.5, &mut rng);
        let mut w = vec![0.0f32; 16];
        let mut g = vec![0.0f32; 16];
        for _ in 0..500 {
            q.grad(&w, &mut g);
            for (wi, gi) in w.iter_mut().zip(&g) {
                *wi -= 0.2 * gi;
            }
        }
        assert!(q.loss(&w) < 1e-6, "{}", q.loss(&w));
    }

    #[test]
    fn logistic_grad_matches_finite_diff() {
        let mut rng = Rng::from_seed(2);
        let obj = Logistic::synthetic(64, 6, 0.01, &mut rng);
        let w: Vec<f32> = (0..6).map(|i| 0.1 * i as f32 - 0.2).collect();
        let mut g = vec![0.0f32; 6];
        obj.grad(&w, &mut g);
        let eps = 1e-3f32;
        for j in 0..6 {
            let mut wp = w.clone();
            wp[j] += eps;
            let mut wm = w.clone();
            wm[j] -= eps;
            let fd = (obj.loss(&wp) - obj.loss(&wm)) / (2.0 * eps as f64);
            assert!((fd - g[j] as f64).abs() < 1e-3, "dim {}: {} vs {}", j, fd, g[j]);
        }
    }

    #[test]
    fn logistic_sgd_reduces_loss() {
        let mut rng = Rng::from_seed(3);
        let obj = Logistic::synthetic(128, 8, 0.01, &mut rng);
        let mut w = vec![0.0f32; 8];
        let mut g = vec![0.0f32; 8];
        let l0 = obj.loss(&w);
        for _ in 0..2000 {
            obj.noisy_grad(&w, 0.0, &mut rng, &mut g);
            for (wi, gi) in w.iter_mut().zip(&g) {
                *wi -= 0.05 * gi;
            }
        }
        assert!(obj.loss(&w) < 0.6 * l0);
    }
}
