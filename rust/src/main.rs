//! `rider` — the launcher. One subcommand per paper experiment plus
//! generic `train` / `calibrate` entry points. See README for usage.

use analog_rider::cli::Args;
use analog_rider::coordinator::experiments::{faults, fig1, theory, training};
use analog_rider::runtime::{Executor, Registry};
use analog_rider::train::{DevParams, PipelineConfig, PipelineTrainer, TrainConfig, Trainer};

fn main() {
    // the library never installs the metrics recorder; the binary does,
    // so every experiment leaves a telemetry trace (see METRICS.md)
    analog_rider::util::metrics::install();
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn ctx_seeds(args: &Args) -> Vec<u64> {
    let n = args.get_usize("seeds", 1);
    (1..=n as u64).collect()
}

/// Registry method list from `--method` / `--methods` (both spellings
/// accepted — the hand-rolled parser ignores unknown flags, so a typo'd
/// spelling would otherwise silently fall back to the default set).
fn method_list(args: &Args, default: &[&str]) -> anyhow::Result<Vec<String>> {
    let key = if args.get("methods").is_some() { "methods" } else { "method" };
    analog_rider::analog::optimizer::resolve_names(&args.get_str_list(key, default))
        .map_err(|e| anyhow::anyhow!(e))
}

fn dispatch(args: &Args) -> anyhow::Result<()> {
    match args.subcommand.as_str() {
        "" | "help" => {
            println!(
                "rider — analog in-memory training with dynamic SP tracking\n\
                 \n\
                 experiments (paper figure/table reproduction):\n\
                 \u{20}  rider fig1   [--side 512] [--seed 42]\n\
                 \u{20}  rider fig2   [--steps N] [--seeds K]\n\
                 \u{20}  rider fig3   [--eta 0.1]\n\
                 \u{20}  rider fig4   [--steps N] [--target 0.2]\n\
                 \u{20}  rider fig5   [--steps N] [--seeds K]\n\
                 \u{20}  rider table1 | table2 | table8  [--steps N] [--seeds K]\n\
                 \u{20}             [--method[s] a,b|all]  (table1/table2 grids)\n\
                 \u{20}  rider table_pipeline [--steps N] [--model fcn] [--method[s] a,b|all]\n\
                 \u{20}             [--stages S] [--workers W] [--staleness D]\n\
                 \u{20}             (sync vs pipelined convergence + wall-clock, equal pulses)\n\
                 \u{20}  rider ablations [--steps N]\n\
                 \u{20}  rider theory [--seed S] [--method[s] erider,residual|all]\n\
                 \n\
                 chaos layer (device fault injection + self-healing):\n\
                 \u{20}  rider faultsweep [--steps N] [--seeds K] [--model fcn]\n\
                 \u{20}             [--method[s] residual,rider,erider|all]\n\
                 \u{20}             [--families drift,stuckbound]  (stuckbound|stucksp|\n\
                 \u{20}              drift|deadlines|tilefail|adc) [--rates 0.05,0.2]\n\
                 \u{20}             [--recovery-pulses 500]  (ZS budget per healed tile)\n\
                 \n\
                 generic (methods by registry name, shared by BOTH the\n\
                 \u{20}   pulse level and the NN scale:\n\
                 \u{20}   sgd|ttv1|ttv2|agad|residual|rider|erider|mtres|digital):\n\
                 \u{20}  rider train --model fcn --algo erider [--steps N] [--ref-mean M]\n\
                 \u{20}             [--ref-std S] [--preset hfo2|om|precise|ideal]\n\
                 \u{20}             [--pipeline-stages S] [--pipeline-workers W] [--staleness D]\n\
                 \u{20}             (S > 0 trains pipelined; D=0 is bit-identical to sync)\n\
                 \u{20}  rider psweep [--method[s] a,b|all] [--means ..] [--stds ..]\n\
                 \u{20}             [--steps N] [--seeds K] [--dim D] [--preset om]\n\
                 \u{20}             [--lr-fast A] [--lr-transfer B] [--eta E] [--flip-p P]\n\
                 \u{20}             [--tiles T] [--stage-steps N]   (mtres stack)\n\
                 \u{20}             [--config file.toml]   ([optimizer] section)\n\
                 \u{20}  rider calibrate --pulses N [--side 128] [--dw-min 1e-3]\n\
                 \u{20}  rider verify (statically check every compiled artifact plan)\n\
                 \u{20}  rider metrics [--pulses N] [--out FILE]  (run a sample device\n\
                 \u{20}             workload, dump Prometheus exposition text; see METRICS.md)\n\
                 \u{20}  rider all    (reduced-size full suite; writes runs/)"
            );
            Ok(())
        }
        "fig1" => {
            let mut p = fig1::Fig1Params::default();
            p.side = args.get_usize("side", p.side);
            p.seed = args.get_u64("seed", p.seed);
            let (a, b) = fig1::run(&p)?;
            print!("{}", a.render());
            print!("{}", b.render());
            Ok(())
        }
        "fig3" => {
            let t = theory::fig3(args.get_f64("eta", 0.1))?;
            print!("{}", t.render());
            Ok(())
        }
        "theory" => {
            let methods = method_list(args, theory::DEFAULT_METHODS)?;
            for t in theory::run(args.get_u64("seed", 7), &methods)? {
                print!("{}", t.render());
            }
            Ok(())
        }
        "psweep" => {
            use analog_rider::coordinator::sweep;
            use analog_rider::device::presets;
            let methods = method_list(args, &["sgd", "ttv2", "agad", "erider", "mtres"])?;
            let means = args.get_f64_list("means", &[0.0, 0.4]);
            let stds = args.get_f64_list("stds", &[0.05, 0.2]);
            let seeds: Vec<u64> = (1..=args.get_u64("seeds", 3)).collect();
            let preset_name = args.get_str("preset", "om");
            let preset = presets::preset(&preset_name)
                .ok_or_else(|| anyhow::anyhow!("unknown preset {preset_name}"))?;
            let threads = std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4);
            let p = sweep::PulseSweep {
                dim: args.get_usize("dim", 16),
                preset: &preset,
                steps: args.get_usize("steps", 2000),
                sigma: args.get_f64("sigma", 0.3),
                threads: args.get_usize("threads", threads),
            };
            // registry defaults, overridable via a config file's
            // [optimizer] section and then per-run --lr-fast etc.
            let cfg = match args.get("config") {
                Some(path) => Some(
                    analog_rider::config::Config::load(path)
                        .map_err(|e| anyhow::anyhow!(e))?,
                ),
                None => None,
            };
            let specs: Vec<_> = methods
                .iter()
                .map(|name| {
                    let mut s = analog_rider::analog::optimizer::spec(name)
                        .expect("resolve_names validated the name");
                    if let Some(cfg) = &cfg {
                        s.apply_config(cfg, "optimizer");
                    }
                    s.apply_args(args);
                    (name.clone(), s)
                })
                .collect();
            let grids = sweep::pulse_robustness_grid_specs(&specs, &means, &stds, &seeds, &p);
            let t = sweep::render_pulse_grid(
                &format!(
                    "Pulse-level robustness: tail loss over (ref mean x ref std), \
                     preset {preset_name}, {} steps",
                    p.steps
                ),
                &grids,
            );
            print!("{}", t.render());
            Ok(())
        }
        "calibrate" => {
            use analog_rider::analog::zs::{self, ZsVariant};
            use analog_rider::device::{presets, DeviceArray};
            use analog_rider::util::rng::Rng;
            let side = args.get_usize("side", 128);
            let n = args.get_u64("pulses", 2000);
            let mut preset = presets::PRECISE.clone();
            preset.dw_min = args.get_f64("dw-min", preset.dw_min);
            let mut rng = Rng::from_seed(args.get_u64("seed", 0));
            let mut arr = DeviceArray::sample(side, side, &preset, 0.4, 0.2, 0.1, &mut rng);
            let res = zs::run(&mut arr, n, ZsVariant::Cyclic, &mut rng);
            println!(
                "ZS over {side}x{side}, N={n}: mean offset {:+.4}, std offset {:+.4}, \
                 rel mean err {:.2}%, pulses {}",
                res.mean_offset(),
                res.std_offset(),
                100.0 * res.rel_mean_error(),
                res.pulses
            );
            Ok(())
        }
        "verify" => {
            let dir = Registry::default_dir();
            if !dir.join("manifest.json").exists() {
                println!("skipping: artifacts not built");
                return Ok(());
            }
            let reg = Registry::load(&dir)?;
            let mut total = analog_rider::runtime::VerifyStats::default();
            let mut failures = 0usize;
            for (name, spec) in &reg.artifacts {
                let src = std::fs::read_to_string(&spec.file)?;
                match analog_rider::runtime::verify_hlo_text(&src) {
                    Ok(st) => {
                        println!(
                            "ok   {name}: {} instrs, {} steps, {} fused groups \
                             ({} members), {} buffers / {} slots (reuse {:.2}x)",
                            st.instructions,
                            st.steps,
                            st.groups,
                            st.members,
                            st.buffers,
                            st.buffer_slots,
                            st.reuse_ratio()
                        );
                        total.computations += st.computations;
                        total.instructions += st.instructions;
                        total.steps += st.steps;
                        total.groups += st.groups;
                        total.members += st.members;
                        total.buffers += st.buffers;
                        total.buffer_slots += st.buffer_slots;
                    }
                    Err(e) => {
                        failures += 1;
                        println!("FAIL {name}: {e}");
                    }
                }
            }
            println!(
                "{} artifacts, {} failures; {} instrs, {} steps, {} fused groups \
                 ({} members), {} buffers / {} slots (reuse {:.2}x)",
                reg.artifacts.len(),
                failures,
                total.instructions,
                total.steps,
                total.groups,
                total.members,
                total.buffers,
                total.buffer_slots,
                total.reuse_ratio()
            );
            if failures > 0 {
                anyhow::bail!("{failures} artifact plan(s) failed verification");
            }
            Ok(())
        }
        "metrics" => {
            use analog_rider::analog::zs::{self, ZsVariant};
            use analog_rider::device::{presets, DeviceArray};
            use analog_rider::util::rng::Rng;
            // artifact-free sample workload: populate the device/ZS
            // series, then dump the Prometheus exposition text
            let mut rng = Rng::from_seed(args.get_u64("seed", 0));
            let mut arr =
                DeviceArray::sample(64, 64, &presets::PRECISE, 0.4, 0.2, 0.1, &mut rng);
            let _ = zs::run(&mut arr, args.get_u64("pulses", 200), ZsVariant::Cyclic, &mut rng);
            let dw = vec![0.01f32; arr.len()];
            for _ in 0..5 {
                arr.analog_update(&dw, &mut rng);
            }
            let text = analog_rider::util::metrics::prometheus_text();
            if let Some(path) = args.get("out") {
                std::fs::write(path, &text)?;
                println!("wrote {path}");
            } else {
                print!("{text}");
            }
            Ok(())
        }
        sub => {
            // everything below needs artifacts
            let reg = Registry::load(Registry::default_dir())?;
            let exec = Executor::cpu()?;
            let ctx = training::ExpCtx {
                exec: &exec,
                reg: &reg,
                steps: args.get_usize("steps", 400),
                seeds: ctx_seeds(args),
            };
            match sub {
                "train" => {
                    let model = args.get_str("model", "fcn");
                    let algo = args.get_str("algo", "erider");
                    let mut cfg = TrainConfig::by_name(&model, &algo)?;
                    cfg.steps = args.get_usize("steps", 500);
                    cfg.ref_mean = args.get_f64("ref-mean", 0.3) as f32;
                    cfg.ref_std = args.get_f64("ref-std", 0.2) as f32;
                    cfg.seed = args.get_u64("seed", 0);
                    // default from the method's registry policy (residual
                    // calibrates, everything else starts at 0)
                    cfg.zs_pulses = args.get_u64("zs-pulses", cfg.zs_pulses);
                    cfg.eval_every = args.get_usize("eval-every", 100);
                    cfg.log = true;
                    if let Some(p) = args.get("preset") {
                        let preset = analog_rider::device::preset(p)
                            .ok_or_else(|| anyhow::anyhow!("unknown preset {p}"))?;
                        cfg.dev = DevParams::from_preset(&preset);
                    }
                    let train = analog_rider::data::Dataset::digits(
                        args.get_usize("train-n", 320),
                        cfg.seed ^ 0xDA7A,
                    );
                    let test = analog_rider::data::Dataset::digits(200, cfg.seed ^ 0x7E57);
                    let rd = analog_rider::coordinator::metrics::RunDir::create("train")?;
                    rd.attach_metrics_trace()?;
                    let stages = args.get_usize("pipeline-stages", 0);
                    let res = if stages > 0 {
                        let pcfg = PipelineConfig {
                            stages,
                            workers: args.get_usize("pipeline-workers", 2),
                            staleness: args.get_u64("staleness", 0),
                            plan_threads: 0,
                        };
                        let mut t = PipelineTrainer::new(&exec, &reg, cfg, pcfg)?;
                        t.train(&train, Some(&test))?
                    } else {
                        let mut t = Trainer::new(&exec, &reg, cfg)?;
                        t.train(&train, Some(&test))?
                    };
                    analog_rider::util::metrics::detach_trace();
                    println!("metrics trace: {}", rd.file("metrics.jsonl").display());
                    println!(
                        "final loss {:.4}, test acc {:.2}%, update pulses {}, \
                         calib pulses {}",
                        res.final_loss(30),
                        res.final_eval_acc,
                        res.cost.update_pulses,
                        res.cost.calibration_pulses
                    );
                    Ok(())
                }
                "fig2" => {
                    print!("{}", training::fig2(&ctx)?.render());
                    Ok(())
                }
                "fig4" => {
                    // validate --methods before the expensive fig4_left sweep
                    let methods = method_list(args, &["ttv2", "agad", "erider", "mtres"])?;
                    print!("{}", training::fig4_left(&ctx, args.get_f64("target", 1.0))?.render());
                    let means = args.get_f64_list("means", &[0.4]);
                    let stds = args.get_f64_list("stds", &[0.05, 0.4, 1.0]);
                    let t = training::robustness_grid(
                        &ctx, "fig4_mr", "convnet3", &methods, &means, &stds, None,
                    )?;
                    print!("{}", t.render());
                    Ok(())
                }
                "fig5" => {
                    print!("{}", training::fig5(&ctx)?.render());
                    Ok(())
                }
                "table1" => {
                    let methods = method_list(args, &["ttv2", "agad", "erider", "mtres"])?;
                    let means = args.get_f64_list("means", &[0.0, 0.4]);
                    let stds = args.get_f64_list("stds", &[0.05, 0.4, 1.0]);
                    let t = training::robustness_grid(
                        &ctx, "table1", "lenet", &methods, &means, &stds, None,
                    )?;
                    print!("{}", t.render());
                    Ok(())
                }
                "table2" => {
                    let methods = method_list(args, &["ttv2", "agad", "erider", "mtres"])?;
                    let means = args.get_f64_list("means", &[0.0, 0.4]);
                    let stds = args.get_f64_list("stds", &[0.05, 0.4, 1.0]);
                    let t = training::robustness_grid(
                        &ctx, "table2", "fcn", &methods, &means, &stds, None,
                    )?;
                    print!("{}", t.render());
                    Ok(())
                }
                "table8" => {
                    print!("{}", training::table8(&ctx)?.render());
                    Ok(())
                }
                "table_pipeline" => {
                    let methods = method_list(args, &["ttv2", "erider"])?;
                    let model = args.get_str("model", "fcn");
                    let t = training::table_pipeline(
                        &ctx,
                        &model,
                        &methods,
                        args.get_usize("stages", 2),
                        args.get_usize("workers", 2),
                        args.get_u64("staleness", 1),
                    )?;
                    print!("{}", t.render());
                    Ok(())
                }
                "faultsweep" => {
                    use analog_rider::device::fault::FaultFamily;
                    use analog_rider::train::RecoveryPolicy;
                    let methods = method_list(args, faults::DEFAULT_METHODS)?;
                    let names = args.get_str_list("families", &["drift", "stuckbound"]);
                    let mut families = Vec::new();
                    for f in &names {
                        families.push(FaultFamily::parse(f).ok_or_else(|| {
                            anyhow::anyhow!(
                                "unknown fault family '{f}' (families: \
                                 stuckbound|stucksp|drift|deadlines|tilefail|adc)"
                            )
                        })?);
                    }
                    let rates = args.get_f64_list("rates", &[0.05, 0.2]);
                    let policy = RecoveryPolicy {
                        zs_pulses: args.get_u64("recovery-pulses", 500),
                        ..RecoveryPolicy::default()
                    };
                    let model = args.get_str("model", "fcn");
                    let t = faults::faultsweep(
                        &ctx, &model, &methods, &families, &rates, &policy,
                    )?;
                    print!("{}", t.render());
                    Ok(())
                }
                "ablations" => {
                    let (t9, t10) = training::ablations(&ctx)?;
                    print!("{}", t9.render());
                    print!("{}", t10.render());
                    Ok(())
                }
                "all" => {
                    // validate --methods before any of the sweeps run
                    let grid_methods = method_list(args, &["ttv2", "agad", "erider", "mtres"])?;
                    let p = fig1::Fig1Params {
                        side: 64,
                        dw_mins: vec![5e-3, 2e-3, 1e-3],
                        ..Default::default()
                    };
                    let (a, b) = fig1::run(&p)?;
                    print!("{}{}", a.render(), b.render());
                    let methods: Vec<String> =
                        theory::DEFAULT_METHODS.iter().map(|s| s.to_string()).collect();
                    for t in theory::run(7, &methods)? {
                        print!("{}", t.render());
                    }
                    print!("{}", theory::fig3(0.1)?.render());
                    print!("{}", training::fig2(&ctx)?.render());
                    print!("{}", training::fig5(&ctx)?.render());
                    let (t9, t10) = training::ablations(&ctx)?;
                    print!("{}{}", t9.render(), t10.render());
                    let t = training::robustness_grid(
                        &ctx, "table2", "fcn", &grid_methods, &[0.0, 0.4], &[0.05, 0.4], None,
                    )?;
                    print!("{}", t.render());
                    Ok(())
                }
                other => anyhow::bail!("unknown subcommand '{other}' (try `rider help`)"),
            }
        }
    }
}
