//! Procedural digit dataset — the offline stand-in for MNIST (DESIGN.md
//! §2). Each digit class 0–9 is a fixed set of strokes in a normalized
//! box, rasterized at 28x28 with a random affine jitter (shift/scale),
//! stroke-thickness variation and pixel noise. The task has the same
//! structure as MNIST (10-way, near-separable, translation-sensitive),
//! which is what the paper's algorithm orderings depend on.

use crate::util::rng::Rng;

pub const SIDE: usize = 28;
pub const D_IN: usize = SIDE * SIDE;

/// Stroke endpoints in a [0,1]^2 box per digit (7-segment-inspired plus
/// diagonals where needed).
fn strokes(digit: usize) -> &'static [((f32, f32), (f32, f32))] {
    // segment coordinates: (x, y) with y down
    const TOP: ((f32, f32), (f32, f32)) = ((0.2, 0.1), (0.8, 0.1));
    const MID: ((f32, f32), (f32, f32)) = ((0.2, 0.5), (0.8, 0.5));
    const BOT: ((f32, f32), (f32, f32)) = ((0.2, 0.9), (0.8, 0.9));
    const TL: ((f32, f32), (f32, f32)) = ((0.2, 0.1), (0.2, 0.5));
    const TR: ((f32, f32), (f32, f32)) = ((0.8, 0.1), (0.8, 0.5));
    const BL: ((f32, f32), (f32, f32)) = ((0.2, 0.5), (0.2, 0.9));
    const BR: ((f32, f32), (f32, f32)) = ((0.8, 0.5), (0.8, 0.9));
    match digit {
        0 => &[TOP, BOT, TL, TR, BL, BR],
        1 => &[((0.5, 0.1), (0.5, 0.9)), ((0.35, 0.25), (0.5, 0.1))],
        2 => &[TOP, TR, MID, BL, BOT],
        3 => &[TOP, TR, MID, BR, BOT],
        4 => &[TL, MID, TR, BR],
        5 => &[TOP, TL, MID, BR, BOT],
        6 => &[TOP, TL, MID, BL, BR, BOT],
        7 => &[TOP, ((0.8, 0.1), (0.4, 0.9))],
        8 => &[TOP, MID, BOT, TL, TR, BL, BR],
        9 => &[TOP, MID, BOT, TL, TR, BR],
        _ => unreachable!(),
    }
}

/// Render one digit into a 28x28 buffer.
pub fn render(digit: usize, rng: &mut Rng, out: &mut [f32]) {
    debug_assert_eq!(out.len(), D_IN);
    out.fill(0.0);
    // affine jitter
    let scale = rng.uniform_in(0.75, 1.0) as f32;
    let dx = rng.uniform_in(-2.5, 2.5) as f32;
    let dy = rng.uniform_in(-2.5, 2.5) as f32;
    let theta = rng.uniform_in(-0.18, 0.18) as f32;
    let (sin, cos) = theta.sin_cos();
    let thick = rng.uniform_in(0.9, 1.6) as f32;
    let cx = SIDE as f32 / 2.0;
    let cy = SIDE as f32 / 2.0;

    for &((x0, y0), (x1, y1)) in strokes(digit) {
        // map to pixel coordinates with jitter
        let map = |x: f32, y: f32| {
            let px = (x - 0.5) * scale * SIDE as f32;
            let py = (y - 0.5) * scale * SIDE as f32;
            (
                cx + cos * px - sin * py + dx,
                cy + sin * px + cos * py + dy,
            )
        };
        let (ax, ay) = map(x0, y0);
        let (bx, by) = map(x1, y1);
        let steps = (((bx - ax).abs() + (by - ay).abs()) as usize + 2) * 2;
        for s in 0..=steps {
            let t = s as f32 / steps as f32;
            let px = ax + t * (bx - ax);
            let py = ay + t * (by - ay);
            // soft disc of radius `thick`
            let r = thick.ceil() as i64;
            for oy in -r..=r {
                for ox in -r..=r {
                    let ix = px.round() as i64 + ox;
                    let iy = py.round() as i64 + oy;
                    if ix < 0 || iy < 0 || ix >= SIDE as i64 || iy >= SIDE as i64 {
                        continue;
                    }
                    let d2 = (px - ix as f32).powi(2) + (py - iy as f32).powi(2);
                    let v = (1.0 - d2 / (thick * thick)).max(0.0);
                    let idx = iy as usize * SIDE + ix as usize;
                    out[idx] = out[idx].max(v);
                }
            }
        }
    }
    // pixel noise + centering: analog arrays drift toward their SP,
    // which injects a common-mode weight shift; zero-mean inputs make
    // the network first layer insensitive to it (standard normalization,
    // same role as MNIST mean subtraction).
    let mut mean = 0.0f32;
    for v in out.iter_mut() {
        *v = (*v + 0.08 * rng.normal() as f32).clamp(0.0, 1.0);
        mean += *v;
    }
    mean /= out.len() as f32;
    for v in out.iter_mut() {
        *v -= mean;
    }
}

/// A rendered dataset: images [n, 784] (flat), labels [n].
#[derive(Clone, Debug)]
pub struct Dataset {
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    pub n: usize,
    pub d: usize,
}

impl Dataset {
    /// Render a class-balanced digit dataset.
    pub fn digits(n: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed, 0xD161);
        let mut x = vec![0.0f32; n * D_IN];
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            let digit = i % 10;
            render(digit, &mut rng, &mut x[i * D_IN..(i + 1) * D_IN]);
            y.push(digit as i32);
        }
        Dataset {
            x,
            y,
            n,
            d: D_IN,
        }
    }

    pub fn sample(&self, i: usize) -> (&[f32], i32) {
        (&self.x[i * self.d..(i + 1) * self.d], self.y[i])
    }

    /// Gather a batch by indices into a flat buffer.
    pub fn gather(&self, idx: &[usize], xout: &mut Vec<f32>, yout: &mut Vec<i32>) {
        xout.clear();
        yout.clear();
        for &i in idx {
            xout.extend_from_slice(&self.x[i * self.d..(i + 1) * self.d]);
            yout.push(self.y[i]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_all_digits_distinct() {
        let mut rng = Rng::from_seed(1);
        let mut imgs = Vec::new();
        for d in 0..10 {
            let mut buf = vec![0.0; D_IN];
            render(d, &mut rng, &mut buf);
            // nontrivial ink (images are mean-centred, so count the
            // positive excursions)
            let ink: f32 = buf.iter().filter(|v| **v > 0.2).sum();
            assert!(ink > 10.0, "digit {d} ink {ink}");
            imgs.push(buf);
        }
        // pairwise distances nonzero
        for a in 0..10 {
            for b in (a + 1)..10 {
                let d2: f32 = imgs[a]
                    .iter()
                    .zip(&imgs[b])
                    .map(|(x, y)| (x - y) * (x - y))
                    .sum();
                assert!(d2 > 1.0, "digits {a},{b} too similar");
            }
        }
    }

    #[test]
    fn same_class_varies() {
        let mut rng = Rng::from_seed(2);
        let mut a = vec![0.0; D_IN];
        let mut b = vec![0.0; D_IN];
        render(3, &mut rng, &mut a);
        render(3, &mut rng, &mut b);
        let d2: f32 = a.iter().zip(&b).map(|(x, y)| (x - y) * (x - y)).sum();
        assert!(d2 > 0.5, "jitter should vary renders");
    }

    #[test]
    fn dataset_balanced_and_bounded() {
        let ds = Dataset::digits(200, 7);
        assert_eq!(ds.n, 200);
        for c in 0..10 {
            assert_eq!(ds.y.iter().filter(|&&y| y == c).count(), 20);
        }
        assert!(ds.x.iter().all(|&v| (-1.0..=1.0).contains(&v)));
    }

    #[test]
    fn deterministic_per_seed() {
        let a = Dataset::digits(20, 9);
        let b = Dataset::digits(20, 9);
        assert_eq!(a.x, b.x);
        let c = Dataset::digits(20, 10);
        assert_ne!(a.x, c.x);
    }
}
