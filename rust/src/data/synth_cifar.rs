//! Synthetic 3-channel texture dataset — the CIFAR-100 stand-in for the
//! convnet3 experiments (Fig. 4 mid/right, Table 8 protocol). Each class
//! is a colored oriented grating with class-specific frequency, phase
//! structure and color balance, plus additive noise; conv layers are
//! required to separate them (orientation/frequency selectivity), which
//! is the property the CIFAR experiments exercise.

use crate::data::digits::Dataset;
use crate::util::rng::Rng;

pub const SIDE: usize = 16;
pub const CH: usize = 3;
pub const D_IN: usize = CH * SIDE * SIDE;
pub const N_CLASSES: usize = 10;

/// Render one texture sample of `class` into `out` (CHW layout).
pub fn render(class: usize, rng: &mut Rng, out: &mut [f32]) {
    debug_assert_eq!(out.len(), D_IN);
    let theta = class as f32 * std::f32::consts::PI / N_CLASSES as f32
        + rng.uniform_in(-0.1, 0.1) as f32;
    let freq = 0.5 + (class % 5) as f32 * 0.35 + rng.uniform_in(-0.05, 0.05) as f32;
    let phase = rng.uniform_in(0.0, std::f32::consts::TAU as f64) as f32;
    // class-specific color mix
    let cmix = [
        0.5 + 0.5 * ((class * 37) as f32 * 0.61).sin(),
        0.5 + 0.5 * ((class * 53) as f32 * 0.37).sin(),
        0.5 + 0.5 * ((class * 71) as f32 * 0.23).sin(),
    ];
    let (sin, cos) = theta.sin_cos();
    for c in 0..CH {
        for yy in 0..SIDE {
            for xx in 0..SIDE {
                let u = xx as f32 / SIDE as f32 - 0.5;
                let v = yy as f32 / SIDE as f32 - 0.5;
                let proj = (u * cos + v * sin) * std::f32::consts::TAU * freq * 4.0;
                let g = (proj + phase).sin() * 0.5 + 0.5;
                let val = cmix[c] * g + 0.1 * rng.normal() as f32;
                out[c * SIDE * SIDE + yy * SIDE + xx] = val.clamp(0.0, 1.0) - 0.5;
            }
        }
    }
}

/// Render a class-balanced texture dataset (reuses `Dataset` container).
pub fn dataset(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed, 0xC1FA);
    let mut x = vec![0.0f32; n * D_IN];
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let class = i % N_CLASSES;
        render(class, &mut rng, &mut x[i * D_IN..(i + 1) * D_IN]);
        y.push(class as i32);
    }
    Dataset { x, y, n, d: D_IN }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classes_distinct_in_mean_image() {
        let ds = dataset(400, 3);
        let mut means = vec![vec![0.0f64; D_IN]; N_CLASSES];
        let mut counts = vec![0usize; N_CLASSES];
        for i in 0..ds.n {
            let (x, y) = ds.sample(i);
            let c = y as usize;
            counts[c] += 1;
            for (m, v) in means[c].iter_mut().zip(x) {
                *m += *v as f64;
            }
        }
        for (m, &c) in means.iter_mut().zip(&counts) {
            for v in m.iter_mut() {
                *v /= c as f64;
            }
        }
        for a in 0..N_CLASSES {
            for b in (a + 1)..N_CLASSES {
                let d2: f64 = means[a]
                    .iter()
                    .zip(&means[b])
                    .map(|(x, y)| (x - y) * (x - y))
                    .sum();
                assert!(d2 > 0.05, "classes {a},{b} mean distance {d2}");
            }
        }
    }

    #[test]
    fn bounded_and_shaped() {
        let ds = dataset(50, 1);
        assert_eq!(ds.d, 768);
        assert!(ds.x.iter().all(|&v| (-0.5..=0.5).contains(&v)));
    }
}
