//! Seeded shuffling batcher: epoch-exact coverage (every sample exactly
//! once per epoch), deterministic per seed — a coordinator invariant
//! property-tested in rust/tests/properties.rs.

use crate::data::digits::Dataset;
use crate::util::rng::Rng;

pub struct Batcher {
    order: Vec<usize>,
    pos: usize,
    pub batch: usize,
    pub epoch: usize,
    rng: Rng,
}

impl Batcher {
    pub fn new(n: usize, batch: usize, seed: u64) -> Batcher {
        assert!(batch > 0 && n >= batch, "need n >= batch");
        let mut rng = Rng::new(seed, 0xBA7C);
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        Batcher {
            order,
            pos: 0,
            batch,
            epoch: 0,
            rng,
        }
    }

    /// Next batch of indices; reshuffles at epoch boundaries. Drops the
    /// final ragged remainder (standard drop-last semantics).
    pub fn next(&mut self) -> &[usize] {
        if self.pos + self.batch > self.order.len() {
            self.rng.shuffle(&mut self.order);
            self.pos = 0;
            self.epoch += 1;
        }
        let s = &self.order[self.pos..self.pos + self.batch];
        self.pos += self.batch;
        s
    }

    pub fn steps_per_epoch(&self) -> usize {
        self.order.len() / self.batch
    }

    /// Fill batch buffers from a dataset.
    pub fn next_batch(&mut self, ds: &Dataset, x: &mut Vec<f32>, y: &mut Vec<i32>) {
        let idx: Vec<usize> = self.next().to_vec();
        ds.gather(&idx, x, y);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_each_sample_once_per_epoch() {
        let mut b = Batcher::new(100, 10, 1);
        let mut seen = vec![0usize; 100];
        for _ in 0..10 {
            for &i in b.next() {
                seen[i] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1), "{seen:?}");
        assert_eq!(b.epoch, 0);
        b.next();
        assert_eq!(b.epoch, 1);
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = Batcher::new(50, 8, 3);
        let mut b = Batcher::new(50, 8, 3);
        for _ in 0..20 {
            assert_eq!(a.next(), b.next());
        }
    }

    #[test]
    fn drop_last_semantics() {
        let b = Batcher::new(53, 10, 1);
        assert_eq!(b.steps_per_epoch(), 5);
    }
}
