//! Data pipeline: procedural datasets (offline substitutes for
//! MNIST / CIFAR — DESIGN.md §2) and the shuffling batcher.

pub mod batcher;
pub mod digits;
pub mod synth_cifar;

pub use batcher::Batcher;
pub use digits::Dataset;
