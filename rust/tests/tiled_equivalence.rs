//! Pins the tiled-substrate determinism contract (see
//! `device/tile.rs` module docs): a single-tile `TiledArray` is
//! bit-identical to a bare `DeviceArray` on every path, multi-tile
//! results never depend on the worker-thread count or the
//! serial/parallel schedule, and ragged tilings cover the logical
//! array exactly.

use analog_rider::device::{presets, DeviceArray, SoftBounds, TileGeometry, TiledArray};
use analog_rider::util::rng::Rng;

const ROWS: usize = 48;
const COLS: usize = 40;

/// A geometry at least as large as the array → a 1×1 grid.
fn single_tile_geom() -> TileGeometry {
    TileGeometry::new(64, 64).unwrap()
}

fn sampled_pair(seed: u64) -> (TiledArray, DeviceArray) {
    let preset = presets::preset("om").unwrap();
    let tiled = TiledArray::sample(
        ROWS,
        COLS,
        single_tile_geom(),
        &preset,
        0.4,
        0.2,
        0.1,
        &mut Rng::from_seed(seed),
    );
    let flat = DeviceArray::sample(ROWS, COLS, &preset, 0.4, 0.2, 0.1, &mut Rng::from_seed(seed));
    (tiled, flat)
}

fn weights(arr: &TiledArray) -> Vec<f32> {
    let mut out = vec![0.0f32; arr.len()];
    arr.read_into(0.0, &mut Rng::from_seed(0), &mut out);
    out
}

#[test]
fn single_tile_sampling_is_bit_identical() {
    let (tiled, flat) = sampled_pair(21);
    assert_eq!(tiled.grid_shape(), (1, 1));
    assert_eq!(weights(&tiled), flat.w);
    assert_eq!(tiled.symmetric_points(), flat.symmetric_points());
    assert_eq!(tiled.mean_g_sq(), flat.mean_g_sq());
}

#[test]
fn single_tile_det_update_is_bit_identical() {
    let (mut tiled, mut flat) = sampled_pair(22);
    let dw: Vec<f32> = (0..ROWS * COLS)
        .map(|i| ((i % 13) as f32 - 6.0) * 0.01)
        .collect();
    for _ in 0..5 {
        tiled.analog_update_det(&dw);
        flat.analog_update_det(&dw);
    }
    assert_eq!(weights(&tiled), flat.w);
    assert_eq!(tiled.pulse_count(), flat.pulse_count);
}

#[test]
fn single_tile_stochastic_paths_are_bit_identical() {
    // OM has c2c > 0, so every op below consumes randomness; the
    // single-tile fast path must hand the caller's RNG straight through
    let (mut tiled, mut flat) = sampled_pair(23);
    let mut rt = Rng::from_seed(101);
    let mut rf = Rng::from_seed(101);
    let dw: Vec<f32> = (0..ROWS * COLS)
        .map(|i| ((i % 7) as f32 - 3.0) * 0.02)
        .collect();
    for _ in 0..4 {
        tiled.analog_update(&dw, &mut rt);
        flat.analog_update(&dw, &mut rf);
    }
    tiled.pulse_all(true, &mut rt);
    flat.pulse_all(true, &mut rf);
    tiled.pulse_all_random(&mut rt);
    flat.pulse_all_random(&mut rf);
    let target = vec![0.1f32; ROWS * COLS];
    tiled.program(&target, &mut rt);
    flat.program(&target, &mut rf);
    assert_eq!(weights(&tiled), flat.w);
    assert_eq!(tiled.pulse_count(), flat.pulse_count);
    // noisy reads draw from the same (shared) stream position
    let mut got_t = vec![0.0f32; tiled.len()];
    let mut got_f = vec![0.0f32; flat.len()];
    tiled.read_into(0.02, &mut rt, &mut got_t);
    flat.read_into(0.02, &mut rf, &mut got_f);
    assert_eq!(got_t, got_f);
}

#[test]
fn worker_count_never_changes_results() {
    let geom = TileGeometry::new(16, 16).unwrap();
    let preset = presets::preset("om").unwrap();
    let base = TiledArray::sample(70, 50, geom, &preset, 0.3, 0.1, 0.1, &mut Rng::from_seed(31));
    let dw: Vec<f32> = (0..70 * 50)
        .map(|i| ((i % 11) as f32 - 5.0) * 0.01)
        .collect();
    let run = |mut arr: TiledArray, parallel: bool, workers: usize| {
        arr.set_parallel(parallel);
        arr.set_workers(workers);
        let mut rng = Rng::from_seed(77);
        for _ in 0..3 {
            arr.analog_update(&dw, &mut rng);
        }
        arr.pulse_all_random(&mut rng);
        let noisy = arr.read(0.02, &mut rng);
        (weights(&arr), noisy, arr.pulse_count())
    };
    let serial = run(base.clone(), false, 0);
    for workers in [1, 2, 4, 64] {
        let par = run(base.clone(), true, workers);
        assert_eq!(par, serial, "workers = {workers}");
    }
}

#[test]
fn ragged_tiling_matches_single_slab_on_uniform_cells() {
    // uniform cells make the det path purely per-cell, so a ragged
    // 32x32 tiling of 70x50 must reproduce the flat array bit-for-bit
    let dev = SoftBounds::from_gamma_rho(1.0, 0.25);
    let geom = TileGeometry::new(32, 32).unwrap();
    let mut tiled = TiledArray::uniform(70, 50, geom, &dev, 0.01, 0.0);
    let mut flat = DeviceArray::uniform(70, 50, &dev, 0.01, 0.0);
    assert_eq!(tiled.grid_shape(), (3, 2));
    let dw: Vec<f32> = (0..70 * 50)
        .map(|i| ((i % 17) as f32 - 8.0) * 0.004)
        .collect();
    for _ in 0..4 {
        tiled.analog_update_det(&dw);
        flat.analog_update_det(&dw);
    }
    assert_eq!(weights(&tiled), flat.w);
    assert_eq!(tiled.pulse_count(), flat.pulse_count);
    assert_eq!(tiled.symmetric_points(), flat.symmetric_points());
}
