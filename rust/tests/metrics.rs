//! Integration tests for the live metrics facade (`util::metrics`):
//! deterministic aggregation under concurrent recording (scoped-thread
//! fan-outs are the crate's concurrency model), exporter golden output
//! for both the Prometheus exposition dump and the JSONL trace lines,
//! and the global install-once facade.

use std::thread;

use analog_rider::util::metrics::{
    self, Kind, MemorySink, MetricId, Recorder, SECONDS_BUCKETS, SPECS,
};

/// Record a fixed global workload split across `workers` threads:
/// worker `w` handles the global indices `[w*per, (w+1)*per)`, so the
/// multiset of recorded samples is identical for every worker count.
/// Observations are integer-valued, so the f64 histogram sum is exact
/// and the totals must be bit-identical regardless of schedule.
fn record_load(sink: &MemorySink, workers: usize) {
    const TOTAL: usize = 1200;
    let per = TOTAL / workers;
    assert_eq!(per * workers, TOTAL, "worker count must divide the load");
    thread::scope(|s| {
        for w in 0..workers {
            s.spawn(move || {
                for g in w * per..(w + 1) * per {
                    sink.counter(MetricId::DevicePulsesTotal, 3);
                    sink.gauge(MetricId::TrainLoss, 0.5);
                    sink.histogram(MetricId::TrainStepSeconds, (g % 7) as f64);
                    sink.gauge_labeled(MetricId::BenchIters, "shared/case", 11.0);
                }
            });
        }
    });
}

#[test]
fn concurrent_recording_is_deterministic_across_worker_counts() {
    let reference = MemorySink::new();
    record_load(&reference, 1);
    let want_counter = reference.counter_value(MetricId::DevicePulsesTotal);
    let want_hist = reference.histogram_totals(MetricId::TrainStepSeconds);
    assert_eq!(want_counter, 3 * 1200);
    assert_eq!(want_hist.0, 1200);
    for workers in [2usize, 4, 8] {
        let s = MemorySink::new();
        record_load(&s, workers);
        assert_eq!(
            s.counter_value(MetricId::DevicePulsesTotal),
            want_counter,
            "{workers} workers"
        );
        assert_eq!(s.gauge_value(MetricId::TrainLoss), Some(0.5));
        let (n, sum) = s.histogram_totals(MetricId::TrainStepSeconds);
        assert_eq!((n, sum), want_hist, "{workers} workers");
        // identical exposition text, too: the whole exporter surface
        // is schedule-independent
        assert_eq!(s.prometheus_text(), reference.prometheus_text());
    }
}

#[test]
fn prometheus_histogram_golden() {
    let s = MemorySink::new();
    s.histogram(MetricId::TrainStepSeconds, 5e-4);
    let text = s.prometheus_text();
    let golden = "# HELP train_step_seconds Wall-clock seconds per trainer step\n\
                  # TYPE train_step_seconds histogram\n\
                  train_step_seconds_bucket{le=\"0.0001\"} 0\n\
                  train_step_seconds_bucket{le=\"0.001\"} 1\n\
                  train_step_seconds_bucket{le=\"0.01\"} 1\n\
                  train_step_seconds_bucket{le=\"0.1\"} 1\n\
                  train_step_seconds_bucket{le=\"1\"} 1\n\
                  train_step_seconds_bucket{le=\"10\"} 1\n\
                  train_step_seconds_bucket{le=\"+Inf\"} 1\n\
                  train_step_seconds_sum 0.0005\n\
                  train_step_seconds_count 1\n";
    assert!(
        text.contains(golden),
        "histogram family must render exactly:\n{text}"
    );
    // bucket cardinality is fixed by the registry
    assert_eq!(
        text.matches("train_step_seconds_bucket").count(),
        SECONDS_BUCKETS.len() + 1
    );
}

#[test]
fn prometheus_label_escaping() {
    let s = MemorySink::new();
    s.gauge_labeled(MetricId::BenchMinNs, "odd\"case\\name", 2.0);
    let text = s.prometheus_text();
    assert!(
        text.contains("bench_min_ns{case=\"odd\\\"case\\\\name\"} 2"),
        "{text}"
    );
}

#[test]
fn jsonl_trace_golden() {
    let s = MemorySink::new();
    s.counter(MetricId::TrainUpdatePulsesTotal, 160);
    s.gauge(MetricId::TrainLoss, 0.5);
    let mut out = String::new();
    s.trace_lines(3, &mut out);
    assert!(out.contains(
        "{\"step\":3,\"key\":\"train_update_pulses_total\",\"type\":\"counter\",\"value\":160}\n"
    ));
    assert!(out.contains(
        "{\"step\":3,\"key\":\"train_loss\",\"type\":\"gauge\",\"value\":0.5}\n"
    ));
    // counters always snapshot (zero totals are data); gauges and
    // histograms only once populated — so a fresh sink contributes
    // exactly the counter rows
    let n_counters = SPECS.iter().filter(|k| k.kind == Kind::Counter).count();
    let mut fresh = String::new();
    MemorySink::new().trace_lines(0, &mut fresh);
    assert_eq!(fresh.lines().count(), n_counters);
}

#[test]
fn global_facade_records_after_install() {
    // install() is one-way and idempotent; the deltas below are ours
    // alone (this binary holds no other global-facade test)
    metrics::install();
    assert!(metrics::enabled());
    let before = metrics::prometheus_text();
    metrics::counter(MetricId::SweepJobsTotal, 2);
    metrics::counter(MetricId::SweepJobsTotal, 3);
    metrics::install(); // second call must not reset anything
    let after = metrics::prometheus_text();
    let get = |text: &str| -> u64 {
        text.lines()
            .find_map(|l| l.strip_prefix("sweep_jobs_total "))
            .expect("counter line present")
            .parse()
            .expect("integer counter")
    };
    assert_eq!(get(&after), get(&before) + 5);
}
