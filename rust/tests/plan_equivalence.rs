//! Planned-engine equivalence gate: the plan (`Executor::run`) must be
//! **bit-for-bit** identical to the scalar reference walker
//! (`Executor::run_ref`) on every checked-in step and ZS artifact, and
//! the threaded `dot` path must be independent of the worker-thread
//! count. This is the contract that lets the fused/threaded/cached
//! engine replace the walker as the production hot path (DESIGN.md
//! "planned interpreter execution").

use analog_rider::runtime::{Executor, HostTensor, Registry};

fn registry() -> Option<Registry> {
    let dir = Registry::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Registry::load(dir).expect("manifest loads"))
}

/// Deterministic value noise: scaled 24-bit hash, different per
/// (artifact, input, element).
fn hash01(seed: u32, i: u32) -> f32 {
    let mut k = seed.wrapping_mul(0x9E37_79B9).wrapping_add(i).wrapping_mul(2654435761);
    k ^= k >> 16;
    (k >> 8) as f32 / 16_777_216.0
}

/// Build deterministic, name-aware inputs for an artifact: small `n`
/// for ZS while-loops, in-range labels, plausible device parameters,
/// hash noise everywhere else.
fn inputs_for(reg: &Registry, name: &str, seed: u32) -> Vec<HostTensor> {
    let model = name.split('_').next().unwrap_or("fcn");
    let n_classes = reg
        .models
        .get(model)
        .map(|m| m.n_classes)
        .unwrap_or(10) as i32;
    let spec = reg.artifact(name).expect("artifact in manifest");
    spec.inputs
        .iter()
        .enumerate()
        .map(|(k, io)| {
            let n = io.numel();
            match io.dtype {
                analog_rider::runtime::Dtype::U32 => {
                    if io.name == "key" {
                        HostTensor::U32(vec![7 + seed, 0x5EED])
                    } else {
                        // ZS pulse budget: keep the while-loop short
                        HostTensor::U32(vec![3; n.max(1)])
                    }
                }
                analog_rider::runtime::Dtype::I32 => HostTensor::I32(
                    (0..n).map(|i| (i as i32 + seed as i32) % n_classes).collect(),
                ),
                analog_rider::runtime::Dtype::F32 => {
                    if io.name == "dev" {
                        // dw_min, sigma_c2c, tau_max, tau_min, out_noise,
                        // inp_res, out_res, out_bound
                        HostTensor::F32(vec![
                            0.01,
                            0.05,
                            1.0,
                            1.0,
                            0.06,
                            1.0 / 127.0,
                            1.0 / 511.0,
                            12.0,
                        ])
                    } else {
                        let centered = io.name.contains('.') || io.name.starts_with('b');
                        HostTensor::F32(
                            (0..n)
                                .map(|i| {
                                    let v = hash01(seed.wrapping_add(k as u32), i as u32);
                                    if centered {
                                        v - 0.5
                                    } else {
                                        v
                                    }
                                })
                                .collect(),
                        )
                    }
                }
            }
        })
        .collect()
}

fn assert_bits_eq(a: &[Vec<f32>], b: &[Vec<f32>], name: &str) {
    assert_eq!(a.len(), b.len(), "{name}: output count");
    for (oi, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.len(), y.len(), "{name}: output {oi} length");
        for (i, (p, q)) in x.iter().zip(y).enumerate() {
            assert_eq!(
                p.to_bits(),
                q.to_bits(),
                "{name}: output {oi}[{i}]: planned {p} vs reference {q}"
            );
        }
    }
}

/// Every step and ZS module: planned path == scalar walker, bit for
/// bit, twice in a row (the second run goes through warmed buffer
/// caches). Debug builds cover the fcn artifacts only — the scalar
/// walker is too slow unoptimized; `./ci.sh e2e` runs the full set in
/// release.
#[test]
fn planned_path_matches_execute_ref_on_all_step_and_zs_artifacts() {
    let Some(reg) = registry() else { return };
    let exec = Executor::cpu().expect("interpreter backend available");
    let mut covered = 0;
    // debug builds: the scalar walker is too slow unoptimized — cover a
    // representative fcn subset and let `./ci.sh e2e` (release) run all
    let debug_set = ["fcn_step_sgd", "fcn_step_digital", "fcn_zs"];
    let names: Vec<String> = reg
        .artifacts
        .keys()
        .filter(|n| n.contains("_step_") || n.ends_with("_zs"))
        .filter(|n| !cfg!(debug_assertions) || debug_set.contains(&n.as_str()))
        .cloned()
        .collect();
    for name in &names {
        let spec = reg.artifact(name).unwrap();
        let inputs = inputs_for(&reg, name, 1);
        let want = exec.run_ref(spec, &inputs).expect("reference path runs");
        let got = exec.run(spec, &inputs).expect("planned path runs");
        assert_bits_eq(&got, &want, name);
        // warmed-cache rerun with different inputs
        let inputs2 = inputs_for(&reg, name, 2);
        let want2 = exec.run_ref(spec, &inputs2).expect("reference rerun");
        let got2 = exec.run(spec, &inputs2).expect("planned rerun");
        assert_bits_eq(&got2, &want2, &format!("{name} (rerun)"));
        covered += 1;
    }
    let floor = if cfg!(debug_assertions) { 3 } else { 20 };
    assert!(
        covered >= floor,
        "only {covered} step/zs artifacts covered — artifacts/ incomplete?"
    );
}

/// Init artifacts exercise the biggest fused u32 hash chains; pin them
/// on both paths too (fcn only in debug).
#[test]
fn planned_path_matches_execute_ref_on_init_artifacts() {
    let Some(reg) = registry() else { return };
    let exec = Executor::cpu().unwrap();
    let names: Vec<String> = reg
        .artifacts
        .keys()
        .filter(|n| n.ends_with("_init"))
        .filter(|n| !cfg!(debug_assertions) || n.starts_with("fcn"))
        .cloned()
        .collect();
    assert!(!names.is_empty());
    for name in &names {
        let spec = reg.artifact(name).unwrap();
        let inputs = vec![
            HostTensor::U32(vec![11, 22]),
            HostTensor::F32(vec![0.4, 0.2, 0.1]),
        ];
        let want = exec.run_ref(spec, &inputs).expect("reference init");
        let got = exec.run(spec, &inputs).expect("planned init");
        assert_bits_eq(&got, &want, name);
    }
}

/// Threaded `dot` determinism: the planned output must not depend on
/// the worker-thread budget (the row-chunking is a function of the
/// shape, never of the machine).
#[test]
fn threaded_dot_is_independent_of_thread_count() {
    let Some(reg) = registry() else { return };
    let exec = Executor::cpu().unwrap();
    let name = "fcn_step_sgd";
    let spec = reg.artifact(name).unwrap();
    let exe = exec.compile(spec).expect("compiles");
    let inputs = inputs_for(&reg, name, 5);
    exe.set_threads(1);
    let serial = exec.run(spec, &inputs).expect("serial run");
    for threads in [2usize, 3, 8, 64] {
        exe.set_threads(threads);
        let par = exec.run(spec, &inputs).expect("parallel run");
        assert_bits_eq(&par, &serial, &format!("{name} threads={threads}"));
    }
}
