//! Pipeline-parallel trainer equivalence: at staleness `D = 0` the
//! pipelined schedule must be bit-for-bit identical to the synchronous
//! [`Trainer`] oracle for every stage/worker topology; at `D > 0` the
//! trajectory may differ from sync but must be a pure function of
//! `(cfg, D)` — never of the worker count or thread timing; and a
//! checkpoint taken between pipelined segments must round-trip through
//! disk and replay bit-exactly, interoperating with the sync flavor.

mod common;

use analog_rider::data::Dataset;
use analog_rider::train::{
    Checkpoint, PipelineConfig, PipelineTrainer, TrainConfig, TrainResult, Trainer,
};
use common::{budget, setup};

fn cfg_for(algo: &str, steps: usize, eval_every: usize, seed: u64) -> TrainConfig {
    let mut cfg = TrainConfig::by_name("fcn", algo).expect("registry name");
    cfg.ref_mean = 0.3;
    cfg.ref_std = 0.2;
    cfg.seed = seed;
    cfg.steps = steps;
    cfg.eval_every = eval_every;
    cfg
}

fn pcfg(stages: usize, workers: usize, staleness: u64) -> PipelineConfig {
    PipelineConfig {
        stages,
        workers,
        staleness,
        plan_threads: 0,
    }
}

/// Bitwise comparison of two runs: every per-step loss, every eval
/// tuple, the final accuracy, the step count and every state leaf.
/// `f64::to_bits` (not `==`) so a NaN disagreement still fails loudly.
fn assert_bit_identical(
    a: &TrainResult,
    state_a: &[Vec<f32>],
    b: &TrainResult,
    state_b: &[Vec<f32>],
    what: &str,
) {
    assert_eq!(a.steps_run, b.steps_run, "{what}: steps_run");
    assert_eq!(a.losses.len(), b.losses.len(), "{what}: loss count");
    for (k, (la, lb)) in a.losses.iter().zip(&b.losses).enumerate() {
        assert_eq!(la.to_bits(), lb.to_bits(), "{what}: loss at step {k}");
    }
    assert_eq!(a.evals.len(), b.evals.len(), "{what}: eval count");
    for ((sa, la, aa), (sb, lb, ab)) in a.evals.iter().zip(&b.evals) {
        assert_eq!(sa, sb, "{what}: eval step");
        assert_eq!(la.to_bits(), lb.to_bits(), "{what}: eval loss at {sa}");
        assert_eq!(aa.to_bits(), ab.to_bits(), "{what}: eval acc at {sa}");
    }
    assert_eq!(
        a.final_eval_acc.to_bits(),
        b.final_eval_acc.to_bits(),
        "{what}: final_eval_acc"
    );
    assert_eq!(state_a.len(), state_b.len(), "{what}: leaf count");
    for (i, (la, lb)) in state_a.iter().zip(state_b).enumerate() {
        assert_eq!(la.len(), lb.len(), "{what}: leaf {i} len");
        for (j, (va, vb)) in la.iter().zip(lb).enumerate() {
            assert_eq!(
                va.to_bits(),
                vb.to_bits(),
                "{what}: leaf {i} element {j}: {va} vs {vb}"
            );
        }
    }
}

#[test]
fn d0_pipelined_is_bit_identical_to_sync() {
    let Some((exec, reg)) = setup() else { return };
    let steps = budget(4, 12);
    let eval_every = budget(2, 5);
    let train = Dataset::digits(64, 11);
    // 50 < eval_batch (200): the ragged-eval path burns a different
    // number of RNG keys per sweep, which the pipeline's static key
    // derivation must reproduce exactly
    let test = Dataset::digits(50, 12);

    let sync = {
        let mut t = Trainer::new(&exec, &reg, cfg_for("erider", steps, eval_every, 5))
            .expect("sync trainer");
        let res = t.train(&train, Some(&test)).expect("sync train");
        (res, t.state.leaves.clone())
    };

    for stages in [1usize, 2, 3] {
        for workers in [1usize, 2, 8] {
            let mut pt = PipelineTrainer::new(
                &exec,
                &reg,
                cfg_for("erider", steps, eval_every, 5),
                pcfg(stages, workers, 0),
            )
            .expect("pipeline trainer");
            let res = pt.train(&train, Some(&test)).expect("pipelined train");
            assert_bit_identical(
                &sync.0,
                &sync.1,
                &res,
                &pt.inner().state.leaves,
                &format!("D=0 stages={stages} workers={workers}"),
            );
        }
    }
}

#[test]
fn stale_pipelining_is_deterministic_across_topology() {
    let Some((exec, reg)) = setup() else { return };
    let steps = budget(5, 10);
    let eval_every = budget(3, 4);
    let train = Dataset::digits(64, 21);
    let test = Dataset::digits(50, 22);

    let run = |stages: usize, workers: usize, d: u64| {
        let mut pt = PipelineTrainer::new(
            &exec,
            &reg,
            cfg_for("ttv2", steps, eval_every, 7),
            pcfg(stages, workers, d),
        )
        .expect("pipeline trainer");
        let res = pt.train(&train, Some(&test)).expect("pipelined train");
        (res, pt.inner().state.leaves.clone())
    };

    // D=2: the trajectory is allowed to differ from sync, but must be
    // identical across every stage count, worker count and whatever
    // interleaving the scheduler happens to produce
    let reference = run(2, 1, 2);
    for (stages, workers) in [(2usize, 2usize), (2, 8), (1, 2), (3, 2)] {
        let got = run(stages, workers, 2);
        assert_bit_identical(
            &reference.0,
            &reference.1,
            &got.0,
            &got.1,
            &format!("D=2 stages={stages} workers={workers}"),
        );
    }

    // D >= steps: every microbatch reads the initial weights; an
    // extreme schedule that maximizes speculative overlap
    let deep_a = run(2, 2, 1000);
    let deep_b = run(2, 8, 1000);
    assert_bit_identical(&deep_a.0, &deep_a.1, &deep_b.0, &deep_b.1, "D=1000");
}

#[test]
fn checkpoint_restore_mid_pipeline_round_trips() {
    let Some((exec, reg)) = setup() else { return };
    let seg1 = budget(3, 6);
    let seg2 = budget(3, 6);
    let train = Dataset::digits(64, 31);

    // segment 1: pipelined with real staleness, then snapshot
    let mut pt = PipelineTrainer::new(
        &exec,
        &reg,
        cfg_for("erider", seg1, 0, 5),
        pcfg(2, 2, 1),
    )
    .expect("pipeline trainer");
    pt.train(&train, None).expect("segment 1");
    let ck = pt.checkpoint(seg1 as u64);

    // disk round-trip (atomic save + load), as in recovery flows
    let path = std::env::temp_dir().join(format!(
        "rpallas_pipeline_ck_{}.ckpt",
        std::process::id()
    ));
    ck.save(&path).expect("save");
    let back = Checkpoint::load(&path).expect("load");
    std::fs::remove_file(&path).ok();
    assert_eq!(back, ck);

    // segment 2 twice from the same checkpoint: bit-identical replay
    pt.inner_mut().cfg.steps = seg2;
    let ahead = pt.train(&train, None).expect("segment 2");
    let state_ahead = pt.inner().state.leaves.clone();
    pt.restore(&back);
    let replay = pt.train(&train, None).expect("segment 2 replay");
    assert_bit_identical(
        &ahead,
        &state_ahead,
        &replay,
        &pt.inner().state.leaves,
        "mid-pipeline restore",
    );

    // flavor interop: restoring the pipelined checkpoint into a fresh
    // synchronous trainer and a fresh D=0 pipeline must agree bit for
    // bit from that point on
    let mut sync = Trainer::new(&exec, &reg, cfg_for("erider", seg2, 0, 5)).expect("sync");
    sync.restore(&back);
    let sync_res = sync.train(&train, None).expect("sync continuation");
    let mut p0 = PipelineTrainer::new(
        &exec,
        &reg,
        cfg_for("erider", seg2, 0, 5),
        pcfg(2, 2, 0),
    )
    .expect("p0");
    p0.restore(&back);
    let p0_res = p0.train(&train, None).expect("d0 continuation");
    assert_bit_identical(
        &sync_res,
        &sync.state.leaves,
        &p0_res,
        &p0.inner().state.leaves,
        "checkpoint interop sync vs D=0",
    );
}
