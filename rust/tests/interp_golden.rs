//! Op-level golden tests for the pure-Rust HLO interpreter: the
//! checked-in kernel artifacts must reproduce `artifacts/parity.json`
//! (vectors from the `kernels/ref.py` oracles) within 1e-5 relative
//! tolerance, executions must be deterministic, and malformed inputs
//! must error cleanly rather than panic.

use analog_rider::runtime::{Executor, HostTensor, Registry};
use analog_rider::util::json::Json;

fn registry() -> Option<Registry> {
    let dir = Registry::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Registry::load(dir).expect("manifest loads"))
}

fn rel_close(got: f32, want: f32, rtol: f32, atol: f32) -> bool {
    (got - want).abs() <= atol + rtol * want.abs()
}

fn dev_vec(dw_min: f32) -> Vec<f32> {
    // layout per manifest dev_index: dw_min, sigma_c2c, tau_max,
    // tau_min, out_noise, inp_res, out_res, out_bound
    vec![dw_min, 0.0, 1.0, 1.0, 0.06, 1.0 / 127.0, 1.0 / 511.0, 12.0]
}

fn parity_cases() -> Option<Json> {
    let path = Registry::default_dir().join("parity.json");
    if !path.exists() {
        eprintln!("skipping: parity.json not built");
        return None;
    }
    Some(Json::parse(&std::fs::read_to_string(path).unwrap()).unwrap())
}

#[test]
fn kernel_artifacts_match_parity_vectors() {
    let Some(reg) = registry() else { return };
    let Some(j) = parity_cases() else { return };
    let exec = Executor::cpu().expect("interpreter backend available");
    let mut n_pulse = 0;
    let mut n_mvm = 0;
    for c in j.get("cases").unwrap().as_arr().unwrap() {
        match c.get("kind").unwrap().as_str().unwrap() {
            "pulse_update" => {
                n_pulse += 1;
                let dw_min = c.get("dw_min").unwrap().as_f64().unwrap() as f32;
                let inputs = [
                    HostTensor::F32(c.get("w").unwrap().as_f32_vec().unwrap()),
                    HostTensor::F32(c.get("dw").unwrap().as_f32_vec().unwrap()),
                    HostTensor::F32(c.get("alpha_p").unwrap().as_f32_vec().unwrap()),
                    HostTensor::F32(c.get("alpha_m").unwrap().as_f32_vec().unwrap()),
                    HostTensor::F32(dev_vec(dw_min)),
                ];
                let out = exec
                    .run_named(&reg, "kernel_pulse_update_det", &inputs)
                    .expect("pulse kernel runs");
                let want = c.get("expected").unwrap().as_f32_vec().unwrap();
                assert_eq!(out[0].len(), want.len());
                for (i, (&g, &w)) in out[0].iter().zip(&want).enumerate() {
                    assert!(
                        rel_close(g, w, 1e-5, 1e-6),
                        "pulse dw_min={dw_min} cell {i}: {g} vs {w}"
                    );
                }
            }
            "analog_mvm" => {
                n_mvm += 1;
                let (b, k, n) = (
                    c.get("b").unwrap().as_usize().unwrap(),
                    c.get("k").unwrap().as_usize().unwrap(),
                    c.get("n").unwrap().as_usize().unwrap(),
                );
                let inputs = [
                    HostTensor::F32(c.get("x").unwrap().as_f32_vec().unwrap()),
                    HostTensor::F32(c.get("w").unwrap().as_f32_vec().unwrap()),
                    HostTensor::F32(dev_vec(0.001)),
                ];
                let name = format!("kernel_analog_mvm_det_{b}x{k}x{n}");
                let out = exec.run_named(&reg, &name, &inputs).expect("mvm kernel runs");
                let want = c.get("expected").unwrap().as_f32_vec().unwrap();
                assert_eq!(out[0].len(), want.len());
                for (i, (&g, &w)) in out[0].iter().zip(&want).enumerate() {
                    assert!(
                        rel_close(g, w, 1e-5, 2e-6),
                        "mvm {b}x{k}x{n} element {i}: {g} vs {w}"
                    );
                }
            }
            other => panic!("unknown parity kind {other}"),
        }
    }
    assert!(n_pulse >= 3 && n_mvm >= 2, "parity file incomplete");
}

#[test]
fn executions_are_deterministic() {
    let Some(reg) = registry() else { return };
    let exec = Executor::cpu().unwrap();
    let run = || {
        exec.run_named(
            &reg,
            "fcn_init",
            &[
                HostTensor::U32(vec![11, 22]),
                HostTensor::F32(vec![0.4, 0.2, 0.1]),
            ],
        )
        .expect("init runs")
    };
    let a = run();
    let b = run();
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x, y, "same key must give bit-identical state");
    }
    // a different key must give a different draw
    let c = exec
        .run_named(
            &reg,
            "fcn_init",
            &[
                HostTensor::U32(vec![12, 22]),
                HostTensor::F32(vec![0.4, 0.2, 0.1]),
            ],
        )
        .unwrap();
    assert_ne!(a[0], c[0], "key change must change the init draw");
}

#[test]
fn init_statistics_match_device_model() {
    // wap/wam sampled with SP ~ N(0.4, 0.2) (clipped +-0.85), slope
    // floor 0.05: check the floor and the recovered SP distribution.
    let Some(reg) = registry() else { return };
    let exec = Executor::cpu().unwrap();
    let state = exec
        .run_named(
            &reg,
            "fcn_init",
            &[
                HostTensor::U32(vec![5, 6]),
                HostTensor::F32(vec![0.4, 0.2, 0.1]),
            ],
        )
        .unwrap();
    let spec = reg.model("fcn").unwrap();
    let wap_idx = spec.state.iter().position(|l| l.role == "wap").unwrap();
    let wam_idx = spec.state.iter().position(|l| l.role == "wam").unwrap();
    let (wap, wam) = (&state[wap_idx], &state[wam_idx]);
    let mut sp_sum = 0.0f64;
    for (&p, &m) in wap.iter().zip(wam) {
        assert!(p >= 0.05 && m >= 0.05, "slope floor violated: {p} {m}");
        sp_sum += ((p - m) / (p + m)) as f64;
    }
    let sp_mean = sp_sum / wap.len() as f64;
    assert!(
        (sp_mean - 0.4).abs() < 0.05,
        "SP mean {sp_mean} should track ref_mean 0.4"
    );
}

#[test]
fn bad_inputs_error_not_panic() {
    let Some(reg) = registry() else { return };
    let exec = Executor::cpu().unwrap();
    // dtype mismatch: key must be u32
    let r = exec.run_named(
        &reg,
        "fcn_init",
        &[
            HostTensor::F32(vec![1.0, 2.0]),
            HostTensor::F32(vec![0.3, 0.2, 0.1]),
        ],
    );
    assert!(r.is_err(), "f32 key must be rejected");
    // arity mismatch
    let r = exec.run_named(&reg, "fcn_init", &[HostTensor::U32(vec![1, 2])]);
    assert!(r.is_err(), "missing params input must be rejected");
    // unknown artifact
    assert!(exec.run_named(&reg, "fcn_warp_drive", &[]).is_err());
}

#[test]
fn zs_while_loop_runs_budgeted_pulses() {
    let Some(reg) = registry() else { return };
    let exec = Executor::cpu().unwrap();
    let state = exec
        .run_named(
            &reg,
            "fcn_init",
            &[
                HostTensor::U32(vec![9, 9]),
                HostTensor::F32(vec![0.4, 0.1, 0.1]),
            ],
        )
        .unwrap();
    let spec = reg.model("fcn").unwrap();
    let mut inputs: Vec<HostTensor> =
        state.iter().map(|v| HostTensor::F32(v.clone())).collect();
    inputs.push(HostTensor::U32(vec![0]));
    inputs.push(HostTensor::U32(vec![7, 7]));
    let mut dev = dev_vec(0.02);
    dev[1] = 0.0;
    inputs.push(HostTensor::F32(dev));
    // n = 0: the while loop must not run; p and q stay as-is (q zero)
    let out = exec.run_named(&reg, "fcn_zs", &inputs).expect("zs n=0 runs");
    let q_idx = spec.state.iter().position(|l| l.role == "q").unwrap();
    assert!(out[q_idx].iter().all(|&v| v == 0.0), "n=0 must leave q at 0");
}
