//! Generic PulseCost accounting across the whole optimizer registry:
//! every method must build from its spec, accumulate update pulses,
//! keep its cost counters monotone, and charge programming events only
//! when the chopper is active. The loops iterate `Method::ALL`, so a
//! method added to the enum is covered here with no further edits (and
//! a name missing from `METHODS` fails the mirror check below).

use analog_rider::analog::optimizer::{self, AnalogOptimizer, Method, OptimizerSpec};
use analog_rider::device::presets;
use analog_rider::optim::Quadratic;
use analog_rider::util::rng::Rng;

const DIM: usize = 8;

fn build(spec: &OptimizerSpec, seed: u64) -> (Box<dyn AnalogOptimizer>, Quadratic, Rng) {
    let mut rng = Rng::from_seed(seed);
    let obj = Quadratic::new(DIM, 1.0, 4.0, 0.3, &mut rng);
    let preset = presets::preset("om").unwrap();
    let opt = spec.build(DIM, &preset, 0.3, 0.1, 0.2, &mut rng);
    (opt, obj, rng)
}

/// Every registry name, derived from the `Method` enum itself so new
/// variants cannot dodge these tests.
fn names() -> impl Iterator<Item = &'static str> {
    Method::ALL.iter().map(|m| m.name())
}

#[test]
fn method_all_mirrors_the_name_registry() {
    let from_enum: Vec<&str> = names().collect();
    assert_eq!(from_enum, optimizer::METHODS, "Method::ALL and METHODS diverged");
}

#[test]
fn every_method_accumulates_update_pulses_monotonically() {
    for name in names() {
        let spec = optimizer::spec(name).expect(name);
        let (mut opt, obj, mut rng) = build(&spec, 11);
        assert_eq!(opt.name(), name, "registry name must round-trip");
        let mut prev = opt.cost();
        for chunk in 0..10 {
            for _ in 0..10 {
                opt.step(&obj, &mut rng);
            }
            let c = opt.cost();
            assert!(
                c.update_pulses >= prev.update_pulses
                    && c.calibration_pulses >= prev.calibration_pulses
                    && c.programming_events >= prev.programming_events
                    && c.digital_ops >= prev.digital_ops,
                "{name}: cost went backwards in chunk {chunk}: {prev:?} -> {c:?}"
            );
            assert!(c.total_pulses() >= prev.total_pulses(), "{name}");
            prev = c;
        }
        if name == "digital" {
            // the baseline arm is pulse-free by definition; its work is
            // accounted as digital ops
            assert_eq!(prev.total_pulses(), 0, "digital must stay pulse-free");
            assert!(prev.digital_ops > 0, "digital: no ops after 100 steps");
        } else {
            assert!(
                prev.update_pulses > 0,
                "{name}: no update pulses after 100 steps"
            );
        }
    }
}

#[test]
fn flip_p_zero_implies_zero_programming_events() {
    for name in names() {
        let mut spec = optimizer::spec(name).expect(name);
        spec.flip_p = 0.0;
        let (mut opt, obj, mut rng) = build(&spec, 13);
        for _ in 0..100 {
            opt.step(&obj, &mut rng);
        }
        assert_eq!(
            opt.cost().programming_events,
            0,
            "{name}: programming events without chopper flips"
        );
    }
}

#[test]
fn calibration_pulses_charged_only_by_two_stage() {
    for name in names() {
        let spec = optimizer::spec(name).expect(name);
        let (mut opt, obj, mut rng) = build(&spec, 17);
        for _ in 0..20 {
            opt.step(&obj, &mut rng);
        }
        let c = opt.cost();
        if name == "residual" {
            assert_eq!(
                c.calibration_pulses,
                spec.zs_pulses * DIM as u64,
                "two-stage ZS budget must be reclassified as calibration"
            );
        } else {
            assert_eq!(c.calibration_pulses, 0, "{name}");
        }
    }
}

#[test]
fn set_reference_round_trips_through_the_trait() {
    for name in names() {
        let spec = optimizer::spec(name).expect(name);
        let (mut opt, _obj, _rng) = build(&spec, 19);
        let q = vec![0.25f32; DIM];
        opt.set_reference(q.clone());
        assert_eq!(opt.sp_reference(), &q[..], "{name}");
    }
}

#[test]
fn both_layers_accept_the_same_name_set_and_err_on_unknown() {
    use analog_rider::train::TrainConfig;
    for name in names() {
        // pulse level
        optimizer::spec_or_err(name).expect(name);
        // NN scale: the same registry drives TrainConfig; no artifacts
        // are needed to resolve a method name
        let cfg = TrainConfig::by_name("fcn", name).expect(name);
        assert_eq!(cfg.algo(), name, "registry name must round-trip");
    }
    // unknown names are an Err listing the registry — never a panic
    let err = optimizer::spec_or_err("sgdd").unwrap_err();
    assert!(err.contains("erider"), "error should list the registry: {err}");
    assert!(TrainConfig::by_name("fcn", "sgdd").is_err());
}

#[test]
fn nn_zs_policy_defaults_come_from_the_registry() {
    use analog_rider::train::TrainConfig;
    // only the two-stage residual pipeline calibrates by default; its
    // budget is the spec's zs_pulses
    for name in names() {
        let cfg = TrainConfig::by_name("fcn", name).unwrap();
        if name == "residual" {
            assert_eq!(cfg.zs_pulses, cfg.spec.zs_pulses);
            assert!(cfg.zs_pulses > 0, "residual must calibrate by default");
        } else {
            assert_eq!(cfg.zs_pulses, 0, "{name}: unexpected default ZS budget");
        }
    }
}
