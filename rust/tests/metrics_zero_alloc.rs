//! Allocation accounting for the metrics facade: `analog_update` is
//! instrumented with a `device_pulses_total` counter, and the facade's
//! cost contract says the disabled path is a single relaxed atomic
//! load and the enabled path a pre-allocated atomic add — neither may
//! touch the heap. Verified with a counting global allocator, first
//! with no recorder installed and then after `metrics::install()`.
//!
//! This binary intentionally holds a single #[test] so no concurrent
//! test can allocate while the hot loop is being counted. The array
//! stays below the row-chunked parallel threshold, where the update
//! path is allocation-free.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use analog_rider::device::{presets, DeviceArray};
use analog_rider::util::metrics;
use analog_rider::util::rng::Rng;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

/// 50 counted iterations of the instrumented update hot path; returns
/// the allocation delta.
fn count_update_allocs(arr: &mut DeviceArray, dw: &[f32], rng: &mut Rng) -> u64 {
    for _ in 0..3 {
        arr.analog_update(dw, rng);
        arr.analog_update_det(dw);
    }
    let before = ALLOCS.load(Ordering::Relaxed);
    let mut acc = 0.0f64;
    for _ in 0..50 {
        arr.analog_update(dw, rng);
        arr.analog_update_det(dw);
        acc += arr.w[0] as f64;
    }
    let after = ALLOCS.load(Ordering::Relaxed);
    assert!(acc.is_finite());
    after - before
}

#[test]
fn instrumented_analog_update_never_allocates() {
    let preset = presets::preset("om").unwrap();
    let mut rng = Rng::from_seed(43);
    let mut arr = DeviceArray::sample(64, 64, &preset, 0.3, 0.1, 0.1, &mut rng);
    let dw: Vec<f32> = (0..arr.len())
        .map(|i| ((i % 7) as f32 - 3.0) * 0.02)
        .collect();

    // no recorder installed: the instrumentation is one relaxed load
    assert!(!metrics::enabled());
    assert_eq!(
        count_update_allocs(&mut arr, &dw, &mut rng),
        0,
        "disabled metrics path touched the heap"
    );

    // recorder installed: counters are pre-allocated atomic adds
    metrics::install();
    assert_eq!(
        count_update_allocs(&mut arr, &dw, &mut rng),
        0,
        "enabled metrics path touched the heap"
    );
    assert!(metrics::prometheus_text().contains("device_pulses_total"));
}
