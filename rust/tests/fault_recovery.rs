//! Recovery-layer integration: checkpoint/restore must resume training
//! bit-for-bit, selective ZS recalibration must charge exactly its
//! pulse budget and touch only the listed tiles, and the NN-scale
//! fault injector must compose with real step artifacts.

mod common;

use analog_rider::device::fault::{FaultFamily, FaultPlan};
use analog_rider::train::fault::NnFaultInjector;
use analog_rider::train::{Checkpoint, TrainConfig, Trainer};
use common::{batches, setup};

#[test]
fn checkpoint_restore_resumes_bit_identical() {
    let Some((exec, reg)) = setup() else { return };
    let mut cfg = TrainConfig::by_name("fcn", "erider").expect("registry name");
    cfg.ref_mean = 0.3;
    cfg.ref_std = 0.2;
    cfg.seed = 5;
    let bs = batches(&reg, 10);
    let mut t = Trainer::new(&exec, &reg, cfg).expect("trainer");
    for (x, y) in &bs[..4] {
        t.step(x, y).expect("warmup step");
    }
    let ck = t.checkpoint(4);
    // run ahead, through the fault-free continuation
    let ahead: Vec<f64> = bs[4..]
        .iter()
        .map(|(x, y)| t.step(x, y).expect("step"))
        .collect();
    let state_ahead = t.state.leaves.clone();

    // round-trip the checkpoint through disk (atomic save + load)
    let path = std::env::temp_dir().join(format!(
        "rpallas_recovery_test_{}.ckpt",
        std::process::id()
    ));
    ck.save(&path).expect("save");
    let back = Checkpoint::load(&path).expect("load");
    std::fs::remove_file(&path).ok();
    assert_eq!(back, ck);

    // rewind and replay the same batches: bit-identical trajectory
    t.restore(&back);
    let replay: Vec<f64> = bs[4..]
        .iter()
        .map(|(x, y)| t.step(x, y).expect("replayed step"))
        .collect();
    assert_eq!(replay, ahead, "restored run must replay bit-for-bit");
    for (a, b) in t.state.leaves.iter().zip(&state_ahead) {
        assert_eq!(a, b);
    }
}

#[test]
fn recalibrate_tiles_charges_budget_and_scopes_to_tiles() {
    let Some((exec, reg)) = setup() else { return };
    let spec = reg.model("fcn").unwrap();
    let mut cfg = TrainConfig::by_name("fcn", "rider").expect("registry name");
    cfg.ref_mean = 0.4;
    cfg.ref_std = 0.1;
    cfg.seed = 7;
    let mut t = Trainer::new(&exec, &reg, cfg).expect("trainer");
    assert_eq!(t.calibration_cost().calibration_pulses, 0);
    let before = t.state.leaves.clone();

    // empty work list: free, state untouched
    assert_eq!(t.recalibrate_tiles(&[], 100).expect("noop recal"), 0);
    for (a, b) in t.state.leaves.iter().zip(&before) {
        assert_eq!(a, b);
    }

    let tile0_weights: u64 = spec
        .state
        .iter()
        .filter(|l| l.role == "w" && l.tile == 0)
        .map(|l| l.numel() as u64)
        .sum();
    assert!(tile0_weights > 0, "fcn must have weights on tile 0");
    let spent = t.recalibrate_tiles(&[0], 50).expect("recalibrate");
    assert_eq!(spent, 50 * tile0_weights);
    assert_eq!(t.calibration_cost().calibration_pulses, spent);
    // leaves on other tiles are untouched
    for (i, leaf) in spec.state.iter().enumerate() {
        if leaf.tile != 0 {
            assert_eq!(t.state.leaves[i], before[i], "leaf {} off-tile", leaf.name);
        }
    }
}

#[test]
fn injected_faults_persist_through_real_steps() {
    let Some((exec, reg)) = setup() else { return };
    let spec = reg.model("fcn").unwrap();
    let mut cfg = TrainConfig::by_name("fcn", "erider").expect("registry name");
    cfg.ref_mean = 0.3;
    cfg.seed = 11;
    let dev = cfg.dev;
    let mut t = Trainer::new(&exec, &reg, cfg).expect("trainer");
    let plan = FaultPlan::of(23, FaultFamily::StuckAtBound, 0.05);
    let inj = NnFaultInjector::compile(&plan, spec, &t.state, &dev);
    assert!(!inj.is_empty(), "5% over fcn weights must pin some cells");
    assert!(!inj.affected_tiles().is_empty());
    inj.apply(&mut t.state);
    let pinned = t.state.leaves.clone();
    let bs = batches(&reg, 2);
    for (x, y) in &bs {
        let loss = t.step(x, y).expect("faulted step");
        assert!(loss.is_finite());
        inj.apply(&mut t.state);
    }
    // pinned cells hold their value across real artifact steps
    let mut held = 0usize;
    for (i, leaf) in spec.state.iter().enumerate() {
        if leaf.role != "w" {
            continue;
        }
        for (a, b) in t.state.leaves[i].iter().zip(&pinned[i]) {
            if *b == dev.tau_max || *b == -dev.tau_min {
                assert_eq!(a, b, "stuck cell moved in {}", leaf.name);
                held += 1;
            }
        }
    }
    assert!(held > 0, "no stuck-at-bound cells found to check");
}
