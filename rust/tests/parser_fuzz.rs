//! Seeded mutation fuzzing of the HLO text parser, mirroring the
//! verify_plans corruption-suite style: ~200 deterministic mutants of a
//! checked-in artifact (truncations, bit flips, in-line token swaps)
//! must each either parse or return `Err` — the parser may never
//! panic. Parse survivors are additionally pushed through the static
//! plan verifier under the same no-panic contract, and a handful of
//! guaranteed-structural corruptions pin the `Err` (not panic, not Ok)
//! behavior exactly.

use std::panic::{catch_unwind, AssertUnwindSafe};

use analog_rider::runtime::xla::HloModuleProto;
use analog_rider::runtime::{verify_hlo_text, Registry};
use analog_rider::util::rng::Rng;

/// Mutation cases per run; 3 families interleaved.
const CASES: usize = 201;

/// The smallest checked-in artifact (~2 KB) keeps 200 parses fast in
/// debug builds; gated like every artifact-dependent test.
fn seed_text() -> Option<String> {
    let path = Registry::default_dir().join("kernel_pulse_update_det.hlo.txt");
    if !path.exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    std::fs::read_to_string(&path).ok()
}

fn mutate(src: &str, case: usize) -> String {
    let mut rng = Rng::new(0xF422_0000 + case as u64, 17);
    let bytes = src.as_bytes();
    match case % 3 {
        0 => {
            // truncate at an arbitrary byte offset
            let cut = rng.below(bytes.len());
            String::from_utf8_lossy(&bytes[..cut]).into_owned()
        }
        1 => {
            // flip 1..=4 random bits anywhere in the text
            let mut b = bytes.to_vec();
            for _ in 0..=rng.below(4) {
                let i = rng.below(b.len());
                b[i] ^= 1 << rng.below(8);
            }
            String::from_utf8_lossy(&b).into_owned()
        }
        _ => {
            // swap two tokens within one line, preserving line structure
            let mut lines: Vec<String> = src.lines().map(str::to_string).collect();
            let li = rng.below(lines.len());
            let mut toks: Vec<String> =
                lines[li].split_whitespace().map(str::to_string).collect();
            if toks.len() >= 2 {
                let a = rng.below(toks.len());
                let b = rng.below(toks.len());
                toks.swap(a, b);
                lines[li] = toks.join(" ");
            }
            lines.join("\n")
        }
    }
}

#[test]
fn mutated_artifacts_never_panic_the_parser() {
    let Some(src) = seed_text() else { return };
    let (mut rejected, mut parsed) = (0usize, 0usize);
    for case in 0..CASES {
        let m = mutate(&src, case);
        let outcome = catch_unwind(AssertUnwindSafe(|| HloModuleProto::from_text(&m).map(|_| ())));
        let Ok(parse) = outcome else {
            panic!("parser panicked on mutant {case} ({} bytes)", m.len());
        };
        match parse {
            Err(_) => rejected += 1,
            Ok(()) => {
                parsed += 1;
                // a parse survivor must also go through the static plan
                // verifier without panicking (Err is fine)
                let v = catch_unwind(AssertUnwindSafe(|| verify_hlo_text(&m).map(|_| ()).err()));
                assert!(v.is_ok(), "plan verifier panicked on mutant {case}");
            }
        }
    }
    // sanity on the suite itself: the mutation families must do real
    // damage — if this fires the fuzzer has gone vacuous, not the
    // parser strict (token swaps inside comments etc. may survive)
    assert!(
        rejected >= CASES / 4,
        "only {rejected}/{CASES} mutants rejected — fuzzer not biting"
    );
    eprintln!("parser fuzz: {rejected} rejected, {parsed} parsed, {CASES} cases");
}

#[test]
fn structural_corruption_is_err_never_panic() {
    // inputs that can never be a module: Err, not panic, not Ok
    assert!(HloModuleProto::from_text("").is_err(), "empty text must not parse");
    assert!(
        HloModuleProto::from_text("not hlo at all {{{").is_err(),
        "garbage must not parse"
    );
    let Some(src) = seed_text() else { return };
    // drop the final closing brace: unterminated computation block
    if let Some(i) = src.rfind('}') {
        assert!(
            HloModuleProto::from_text(&src[..i]).is_err(),
            "unterminated block must not parse"
        );
    }
    // the intact seed must still parse — the corruptions above fail for
    // the right reason, not because the fixture rotted
    assert!(HloModuleProto::from_text(&src).is_ok(), "seed artifact must parse");
}

#[test]
fn from_text_file_missing_path_is_err() {
    let r = catch_unwind(AssertUnwindSafe(|| {
        HloModuleProto::from_text_file("/nonexistent/definitely_missing.hlo.txt").map(|_| ())
    }));
    match r {
        Ok(parse) => assert!(parse.is_err(), "missing file must be Err"),
        Err(_) => panic!("from_text_file panicked on a missing path"),
    }
}
